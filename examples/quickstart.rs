//! Quickstart: the 60-second tour of the public API.
//!
//! Loads the AOT artifacts, trains the small MLP for 60 distributed
//! steps with variance-based gradient compression (Algorithm 1), and
//! prints the numbers the paper cares about: accuracy and compression
//! ratio.
//!
//! Run with:
//! ```text
//! make artifacts && cargo run --release --example quickstart
//! ```

use vgc::compress::CodecSpec;
use vgc::config::TrainConfig;
use vgc::coordinator::Trainer;
use vgc::runtime::{Client, Manifest};

fn main() -> anyhow::Result<()> {
    // 1. The runtime: PJRT CPU client + the artifact manifest written by
    //    `make artifacts` (python is never on this path).
    let manifest = Manifest::load("artifacts")?;
    let client = Client::cpu()?;

    // 2. An experiment config: model + codec + optimizer. Everything has
    //    per-model defaults; here we pick Algorithm 1 with α = 1.5.
    let mut cfg = TrainConfig::defaults("mlp");
    cfg.codec = CodecSpec::Vgc {
        alpha: 1.5,
        zeta: 0.999,
    };
    cfg.steps = 60;
    cfg.eval_every = 30;

    // 3. The coordinator: simulated data-parallel workers, byte-accurate
    //    ring allgatherv, local optimizer updates.
    let mut trainer = Trainer::new(&client, &manifest, cfg)?;
    trainer.run(false)?;

    // 4. Results.
    let m = &trainer.metrics;
    println!("\nquickstart summary");
    println!("  workers            {}", trainer.workers());
    println!("  parameters         {}", trainer.n_params());
    println!("  final accuracy     {:.1}%", m.final_accuracy() * 100.0);
    println!("  compression ratio  {:.1}x (paper metric: N / avg elements sent)", m.compression_ratio());
    Ok(())
}
