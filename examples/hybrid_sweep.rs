//! Hybrid-algorithm ablation (paper Sec. 4.5 / 6.1): sweep the (τ, α)
//! grid of Algorithm 2 on the Table-1 workload and print the
//! accuracy/compression matrix, alongside plain Strom at the same τ
//! values.
//!
//! This regenerates the paper's key qualitative claims:
//!   * the hybrid compresses further than either method alone;
//!   * plain Strom is brittle in τ (good at one value, bad at others)
//!     while the hybrid's variance gate stabilizes it.
//!
//! ```text
//! cargo run --release --example hybrid_sweep [-- STEPS]
//! ```

use vgc::compress::CodecSpec;
use vgc::config::TrainConfig;
use vgc::coordinator::Trainer;
use vgc::runtime::{Client, Manifest};

fn main() -> anyhow::Result<()> {
    let steps: u64 = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(150);

    let manifest = Manifest::load("artifacts")?;
    let client = Client::cpu()?;

    let taus = [0.001f32, 0.01, 0.1];
    let alphas = [1.0f32, 2.0];

    let mut rows: Vec<(String, CodecSpec)> = Vec::new();
    for &tau in &taus {
        rows.push((format!("strom  τ={tau:<5}"), CodecSpec::Strom { tau }));
    }
    for &tau in &taus {
        for &alpha in &alphas {
            rows.push((
                format!("hybrid τ={tau:<5} α={alpha}"),
                CodecSpec::Hybrid {
                    tau,
                    alpha,
                    zeta: 0.999,
                },
            ));
        }
    }

    println!(
        "{:<24} {:>10} {:>12} {:>12}",
        "method", "accuracy", "compression", "final loss"
    );
    for (label, codec) in rows {
        let mut cfg = TrainConfig::defaults("vgg_tiny");
        cfg.codec = codec;
        cfg.steps = steps;
        cfg.eval_every = steps;
        cfg.log_every = 0;
        let mut t = Trainer::new(&client, &manifest, cfg)?;
        t.run(true)?;
        let m = &t.metrics;
        let comp = if m.compression_ratio().is_infinite() {
            "inf".to_string()
        } else {
            format!("{:.1}", m.compression_ratio())
        };
        println!(
            "{:<24} {:>9.1}% {:>12} {:>12.4}",
            label,
            m.final_accuracy() * 100.0,
            comp,
            m.final_loss()
        );
    }
    Ok(())
}
