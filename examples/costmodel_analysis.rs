//! Cluster-planning walkthrough of the Section-5 cost model: for a
//! ResNet-50-scale model on commodity 1GbE vs InfiniBand, how much
//! compression is needed before communication stops being the
//! bottleneck — the paper's "enables distributed deep learning on
//! commodity environments" argument, reproduced as a tool.
//!
//! ```text
//! cargo run --release --example costmodel_analysis
//! ```

use vgc::comm::costmodel::{CostModel, LinkModel};

fn main() {
    let n: u64 = 25_500_000; // ResNet-50
    // The paper's motivating number: fwd+bwd of ResNet-50 per iteration.
    let compute_s = 0.23;

    println!("Section-5 planning: ResNet-50 ({n} params), compute {compute_s}s/iter\n");

    for (link_name, link) in [("1GbE", LinkModel::gige()), ("InfiniBand", LinkModel::infiniband())] {
        println!("--- {link_name} ---");
        println!(
            "{:>4} {:>12} {:>14} {:>16} {:>10}",
            "p", "c needed", "T_r (ms)", "T_v@c (ms)", "util %"
        );
        for p in [4usize, 8, 16, 64] {
            let model = CostModel::new(p, n, link);
            let t_r = model.t_allreduce();
            // Smallest compression ratio (power of 2) that brings the
            // modeled allgatherv under 10% of compute.
            let mut c = 1.0f64;
            while model.t_allgatherv_ratio(c) > 0.1 * compute_s && c < 1e7 {
                c *= 2.0;
            }
            let t_v = model.t_allgatherv_ratio(c);
            let util = compute_s / (compute_s + t_v) * 100.0;
            println!(
                "{p:>4} {c:>12.0} {:>14.1} {:>16.2} {util:>9.1}%",
                t_r * 1e3,
                t_v * 1e3
            );
        }
        println!();
    }

    println!(
        "Reading: on 1GbE an uncompressed ring allreduce costs ~2x the\n\
         compute budget per iteration, while the paper's measured VGC/hybrid\n\
         ratios (10^2..10^4) push communication under 10% of compute -- the\n\
         linear-speedup regime c > p/2 of Sec. 5. InfiniBand reaches the same\n\
         point without compression, which is exactly the paper's framing:\n\
         compression buys commodity hardware the expensive interconnect's\n\
         scaling."
    );
}
