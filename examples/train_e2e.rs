//! End-to-end driver (DESIGN.md experiment E2E): train the transformer
//! language model for a few hundred distributed steps on the synthetic
//! Markov token stream with variance-based gradient compression, and
//! log the loss curve.
//!
//! This is the "all layers compose" proof: the L1 Pallas moments kernel
//! and L2 JAX transformer fwd/bwd run inside one AOT HLO artifact; the
//! L3 Rust coordinator drives the synchronous loop, compresses with
//! Algorithm 1, moves real bytes through the ring allgatherv, and
//! applies Adam locally (Sec. 4.3). The loss curve lands in
//! `e2e_loss_curve.csv` and is quoted in EXPERIMENTS.md §E2E.
//!
//! ```text
//! make artifacts && cargo run --release --example train_e2e [-- STEPS]
//! ```

use vgc::comm::costmodel::{CostModel, LinkModel};
use vgc::compress::CodecSpec;
use vgc::config::TrainConfig;
use vgc::coordinator::Trainer;
use vgc::runtime::{Client, Manifest};

fn main() -> anyhow::Result<()> {
    let steps: u64 = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(300);

    let manifest = Manifest::load("artifacts")?;
    let client = Client::cpu()?;

    let mut cfg = TrainConfig::defaults("transformer");
    cfg.codec = CodecSpec::Vgc {
        alpha: 1.5,
        zeta: 0.999,
    };
    cfg.steps = steps;
    cfg.eval_every = 50;
    cfg.log_every = 10;
    cfg.train_size = 2048;

    println!(
        "e2e: transformer LM, {} workers, codec {}, {} steps",
        manifest.model("transformer")?.workers,
        cfg.codec.label(),
        steps
    );
    let mut trainer = Trainer::new(&client, &manifest, cfg)?;
    let t0 = std::time::Instant::now();
    trainer.run(false)?;
    let wall = t0.elapsed().as_secs_f64();

    let m = &trainer.metrics;
    std::fs::write("e2e_loss_curve.csv", m.loss_curve_csv())?;

    // The paper's economics: what the measured compression buys on the
    // paper's own commodity-interconnect scenario (Section 5).
    let n = trainer.n_params() as u64;
    let model = CostModel::new(trainer.workers(), n, LinkModel::gige());
    let (t_r, t_v) = m.modeled_comm(&model);

    println!("\n=== e2e summary (EXPERIMENTS.md §E2E) ===");
    println!("steps                  {}", m.steps.len());
    println!("first-10-step loss     {:.4}", mean_first(m, 10));
    println!("last-10-step loss      {:.4}", m.tail_loss(10));
    println!(
        "final eval loss        {:.4} (ln vocab = {:.4})",
        m.evals.last().map(|e| e.eval_loss).unwrap_or(f32::NAN),
        (256f32).ln()
    );
    println!("compression ratio      {:.1}x", m.compression_ratio());
    println!("modeled comm/step      allreduce {:.2} ms -> allgatherv {:.2} ms ({:.1}x)",
        t_r * 1e3, t_v * 1e3, t_r / t_v);
    println!("wall                   {wall:.1}s  ({:.2} s/step)", wall / m.steps.len() as f64);
    let ph = trainer.phases;
    println!(
        "phase split            compute {:.1}s | encode {:.1}s | comm+decode {:.1}s | update {:.1}s",
        ph.compute_s, ph.encode_s, ph.comm_decode_s, ph.update_s
    );
    println!("loss curve written to e2e_loss_curve.csv");

    anyhow::ensure!(
        m.tail_loss(10) < mean_first(&trainer.metrics, 10) * 0.8,
        "e2e loss did not decrease"
    );
    Ok(())
}

fn mean_first(m: &vgc::metrics::RunMetrics, k: usize) -> f32 {
    let head = &m.steps[..k.min(m.steps.len())];
    head.iter().map(|r| r.loss).sum::<f32>() / head.len().max(1) as f32
}
