//! Strom (2015) threshold compression — the paper's main sparse
//! baseline.
//!
//! Each worker accumulates gradients into a residual `r_i`; when
//! `|r_i| > τ` the worker sends one sign bit for the element and
//! subtracts `±τ` from the residual ("gradients are decoded up to the
//! threshold and quantization errors are added to the gradients
//! calculated in the next step"). The decoded value is exactly `±τ`.
//!
//! Wire format: u32 count, then count × u32 sign+index words (the paper
//! counts one 32-bit word per sent pair for all algorithms, Sec. 6).

use super::encode::{pack_sign_index, unpack_sign_index, ByteReader, ByteWriter};
use super::{Aggregation, Codec, Message};

pub struct StromCodec {
    tau: f32,
    r: Vec<f32>,
}

impl StromCodec {
    pub fn new(n: usize, tau: f32) -> StromCodec {
        assert!(tau > 0.0, "tau must be positive");
        StromCodec {
            tau,
            r: vec![0.0; n],
        }
    }

    pub fn r(&self) -> &[f32] {
        &self.r
    }

    pub fn tau(&self) -> f32 {
        self.tau
    }
}

impl Codec for StromCodec {
    fn name(&self) -> String {
        format!("strom(tau={})", self.tau)
    }

    fn aggregation(&self) -> Aggregation {
        Aggregation::Sum
    }

    fn encode_step(&mut self, gsum: &[f32], _gsumsq: &[f32]) -> Message {
        assert_eq!(gsum.len(), self.r.len());
        let mut w = ByteWriter::new();
        w.u32(0); // count placeholder
        let mut count = 0u32;
        for i in 0..self.r.len() {
            self.r[i] += gsum[i];
            if self.r[i] > self.tau {
                w.u32(pack_sign_index(false, i as u32));
                self.r[i] -= self.tau;
                count += 1;
            } else if self.r[i] < -self.tau {
                w.u32(pack_sign_index(true, i as u32));
                self.r[i] += self.tau;
                count += 1;
            }
        }
        let mut bytes = w.finish();
        bytes[0..4].copy_from_slice(&count.to_le_bytes());
        Message {
            payload_bits: count as u64 * 32,
            elements: count as u64,
            bytes,
        }
    }

    fn decode_into(&self, bytes: &[u8], out: &mut [f32]) -> anyhow::Result<()> {
        let mut r = ByteReader::new(bytes);
        let count = r.u32()?;
        for _ in 0..count {
            let (neg, index) = unpack_sign_index(r.u32()?);
            let index = index as usize;
            anyhow::ensure!(index < out.len(), "index {index} out of range");
            out[index] += if neg { -self.tau } else { self.tau };
        }
        anyhow::ensure!(r.done(), "trailing bytes");
        Ok(())
    }

    fn residual_l1(&self) -> f64 {
        self.r.iter().map(|x| x.abs() as f64).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;
    use crate::util::rng::Pcg32;

    #[test]
    fn below_threshold_sends_nothing() {
        let mut c = StromCodec::new(4, 0.5);
        let msg = c.encode_step(&[0.4, -0.3, 0.0, 0.49], &[0.0; 4]);
        assert_eq!(msg.elements, 0);
    }

    #[test]
    fn above_threshold_sends_sign_and_subtracts_tau() {
        let mut c = StromCodec::new(3, 0.5);
        let msg = c.encode_step(&[0.7, -0.9, 0.1], &[0.0; 3]);
        assert_eq!(msg.elements, 2);
        let mut out = vec![0.0; 3];
        c.decode_into(&msg.bytes, &mut out).unwrap();
        assert_eq!(out, vec![0.5, -0.5, 0.0]);
        // Residual keeps the remainder (1-bit SGD error feedback).
        assert!((c.r()[0] - 0.2).abs() < 1e-6);
        assert!((c.r()[1] + 0.4).abs() < 1e-6);
        assert!((c.r()[2] - 0.1).abs() < 1e-6);
    }

    #[test]
    fn residual_accumulates_across_steps() {
        let mut c = StromCodec::new(1, 1.0);
        for _ in 0..2 {
            let msg = c.encode_step(&[0.4], &[0.0]);
            assert_eq!(msg.elements, 0);
        }
        // Third step: r = 1.2 > 1.0 -> send one τ, keep 0.2.
        let msg = c.encode_step(&[0.4], &[0.0]);
        assert_eq!(msg.elements, 1);
        assert!((c.r()[0] - 0.2).abs() < 1e-6);
    }

    #[test]
    fn conservation_sent_plus_residual_equals_stream() {
        // Exact invariant: τ·(#pos - #neg per element) + r_i == Σ gsum_i.
        testkit::for_all(
            "strom conservation",
            |rng: &mut Pcg32| {
                let n = testkit::usize_in(rng, 1, 64);
                let steps = testkit::usize_in(rng, 1, 30);
                let tau = testkit::f32_in(rng, 0.01, 0.5);
                let stream: Vec<Vec<f32>> =
                    (0..steps).map(|_| testkit::gradient_vec(rng, n)).collect();
                (tau, stream)
            },
            |(tau, stream)| {
                let n = stream[0].len();
                let mut c = StromCodec::new(n, *tau);
                let mut decoded_total = vec![0.0f32; n];
                for g in stream {
                    let msg = c.encode_step(g, &vec![0.0; n]);
                    c.decode_into(&msg.bytes, &mut decoded_total)
                        .map_err(|e| e.to_string())?;
                }
                for i in 0..n {
                    let total: f32 = stream.iter().map(|g| g[i]).sum();
                    let got = decoded_total[i] + c.r()[i];
                    if (got - total).abs() > 1e-4 * (1.0 + total.abs()) {
                        return Err(format!("i={i}: {got} != {total}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn single_quantum_per_step() {
        // Even a huge spike emits at most one ±τ per step (Alg. 2's
        // single-subtraction form); the rest drains over later steps.
        let mut c = StromCodec::new(1, 0.1);
        let msg = c.encode_step(&[1.0], &[0.0]);
        assert_eq!(msg.elements, 1);
        assert!((c.r()[0] - 0.9).abs() < 1e-6);
        // Drains with zero new gradient.
        let msg2 = c.encode_step(&[0.0], &[0.0]);
        assert_eq!(msg2.elements, 1);
        assert!((c.r()[0] - 0.8).abs() < 1e-6);
    }
}
