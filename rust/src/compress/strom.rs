//! Strom (2015) threshold compression — the paper's main sparse
//! baseline.
//!
//! Each worker accumulates gradients into a residual `r_i`; when
//! `|r_i| > τ` the worker sends one sign bit for the element and
//! subtracts `±τ` from the residual ("gradients are decoded up to the
//! threshold and quantization errors are added to the gradients
//! calculated in the next step"). The decoded value is exactly `±τ`.
//!
//! Wire format: u32 count, then count × u32 sign+index words (the paper
//! counts one 32-bit word per sent pair for all algorithms, Sec. 6).

use super::encode::{pack_sign_index, unpack_sign_index, ByteReader, ByteWriter};
use super::engine::{DecodeBuf, EncodeStats};
use super::{Aggregation, Codec, KnobState};
use crate::util::threadpool::{split_ranges, Task, ThreadPool};

/// Per-shard reusable encode scratch (pooled encode).
#[derive(Default)]
struct ShardScratch {
    bytes: Vec<u8>,
    count: u32,
}

pub struct StromCodec {
    tau: f32,
    r: Vec<f32>,
    shards: Vec<ShardScratch>,
}

impl StromCodec {
    pub fn new(n: usize, tau: f32) -> StromCodec {
        assert!(tau > 0.0, "tau must be positive");
        StromCodec {
            tau,
            r: vec![0.0; n],
            shards: Vec::new(),
        }
    }

    pub fn r(&self) -> &[f32] {
        &self.r
    }

    pub fn tau(&self) -> f32 {
        self.tau
    }
}

impl Codec for StromCodec {
    fn name(&self) -> String {
        format!("strom(tau={})", self.tau)
    }

    fn aggregation(&self) -> Aggregation {
        Aggregation::Sum
    }

    fn encode_step_into(
        &mut self,
        gsum: &[f32],
        _gsumsq: &[f32],
        bytes: &mut Vec<u8>,
    ) -> EncodeStats {
        assert_eq!(gsum.len(), self.r.len());
        let mut w = ByteWriter::over(bytes);
        w.u32(0); // count placeholder
        let count = encode_range(&mut self.r, gsum, self.tau, 0, &mut w);
        w.patch_u32(0, count);
        EncodeStats {
            payload_bits: count as u64 * 32,
            elements: count as u64,
        }
    }

    fn encode_step_pooled(
        &mut self,
        gsum: &[f32],
        _gsumsq: &[f32],
        pool: &ThreadPool,
        bytes: &mut Vec<u8>,
    ) -> EncodeStats {
        if pool.threads() == 1 {
            return self.encode_step_into(gsum, _gsumsq, bytes);
        }
        assert_eq!(gsum.len(), self.r.len());
        let ranges = split_ranges(self.r.len(), pool.threads());
        while self.shards.len() < ranges.len() {
            self.shards.push(ShardScratch::default());
        }
        let tau = self.tau;
        let mut tasks: Vec<Task<'_>> = Vec::with_capacity(ranges.len());
        let mut r_rest: &mut [f32] = &mut self.r;
        let mut shard_iter = self.shards.iter_mut();
        for range in &ranges {
            let len = range.end - range.start;
            let (r_s, r_next) = r_rest.split_at_mut(len);
            r_rest = r_next;
            let scratch = shard_iter.next().expect("scratch sized above");
            let gs = &gsum[range.start..range.end];
            let base = range.start;
            tasks.push(Box::new(move || {
                scratch.bytes.clear();
                let mut w = ByteWriter::append(&mut scratch.bytes);
                scratch.count = encode_range(r_s, gs, tau, base, &mut w);
            }));
        }
        pool.run(tasks);
        // Assemble: count header + shard word streams in index order —
        // byte-identical to the serial message.
        let mut w = ByteWriter::over(bytes);
        w.u32(0);
        let mut count = 0u32;
        for scratch in self.shards[..ranges.len()].iter() {
            w.bytes(&scratch.bytes);
            count += scratch.count;
        }
        w.patch_u32(0, count);
        EncodeStats {
            payload_bits: count as u64 * 32,
            elements: count as u64,
        }
    }

    fn decode_into(&self, bytes: &[u8], out: &mut [f32]) -> anyhow::Result<()> {
        let mut r = ByteReader::new(bytes);
        let count = r.u32()?;
        for _ in 0..count {
            let (neg, index) = unpack_sign_index(r.u32()?);
            let index = index as usize;
            anyhow::ensure!(index < out.len(), "index {index} out of range");
            out[index] += if neg { -self.tau } else { self.tau };
        }
        anyhow::ensure!(r.done(), "trailing bytes");
        Ok(())
    }

    fn decode_entries(&self, bytes: &[u8], buf: &mut DecodeBuf) -> anyhow::Result<()> {
        let n = buf.expected_len();
        let mut r = ByteReader::new(bytes);
        let count = r.u32()?;
        for _ in 0..count {
            let (neg, index) = unpack_sign_index(r.u32()?);
            anyhow::ensure!((index as usize) < n, "index {index} out of range");
            buf.push(index, if neg { -self.tau } else { self.tau });
        }
        anyhow::ensure!(r.done(), "trailing bytes");
        Ok(())
    }

    fn residual_l1(&self) -> f64 {
        self.r.iter().map(|x| x.abs() as f64).sum()
    }

    fn knob(&self) -> Option<KnobState> {
        // Raising τ sends fewer elements ⇒ tighter compression. Decode
        // uses the same τ, so the controller must apply one value to
        // every worker's codec between steps (the Trainer does).
        Some(KnobState {
            name: "tau",
            value: self.tau,
            lo: self.tau * 0.25,
            hi: self.tau * 4.0,
            tighten_up: true,
        })
    }

    fn set_knob(&mut self, value: f32) -> bool {
        if !(value > 0.0 && value.is_finite()) {
            return false;
        }
        self.tau = value;
        true
    }
}

/// The Strom threshold kernel over one contiguous residual shard
/// (global element `i` = local `i` + `base`); emits sign+index words in
/// ascending index order. Shared by the serial and pooled paths.
fn encode_range(r: &mut [f32], gsum: &[f32], tau: f32, base: usize, w: &mut ByteWriter) -> u32 {
    let mut count = 0u32;
    for i in 0..r.len() {
        r[i] += gsum[i];
        if r[i] > tau {
            w.u32(pack_sign_index(false, (i + base) as u32));
            r[i] -= tau;
            count += 1;
        } else if r[i] < -tau {
            w.u32(pack_sign_index(true, (i + base) as u32));
            r[i] += tau;
            count += 1;
        }
    }
    count
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;
    use crate::util::rng::Pcg32;

    #[test]
    fn below_threshold_sends_nothing() {
        let mut c = StromCodec::new(4, 0.5);
        let msg = c.encode_step(&[0.4, -0.3, 0.0, 0.49], &[0.0; 4]);
        assert_eq!(msg.elements, 0);
    }

    #[test]
    fn above_threshold_sends_sign_and_subtracts_tau() {
        let mut c = StromCodec::new(3, 0.5);
        let msg = c.encode_step(&[0.7, -0.9, 0.1], &[0.0; 3]);
        assert_eq!(msg.elements, 2);
        let mut out = vec![0.0; 3];
        c.decode_into(&msg.bytes, &mut out).unwrap();
        assert_eq!(out, vec![0.5, -0.5, 0.0]);
        // Residual keeps the remainder (1-bit SGD error feedback).
        assert!((c.r()[0] - 0.2).abs() < 1e-6);
        assert!((c.r()[1] + 0.4).abs() < 1e-6);
        assert!((c.r()[2] - 0.1).abs() < 1e-6);
    }

    #[test]
    fn residual_accumulates_across_steps() {
        let mut c = StromCodec::new(1, 1.0);
        for _ in 0..2 {
            let msg = c.encode_step(&[0.4], &[0.0]);
            assert_eq!(msg.elements, 0);
        }
        // Third step: r = 1.2 > 1.0 -> send one τ, keep 0.2.
        let msg = c.encode_step(&[0.4], &[0.0]);
        assert_eq!(msg.elements, 1);
        assert!((c.r()[0] - 0.2).abs() < 1e-6);
    }

    #[test]
    fn conservation_sent_plus_residual_equals_stream() {
        // Exact invariant: τ·(#pos - #neg per element) + r_i == Σ gsum_i.
        testkit::for_all(
            "strom conservation",
            |rng: &mut Pcg32| {
                let n = testkit::usize_in(rng, 1, 64);
                let steps = testkit::usize_in(rng, 1, 30);
                let tau = testkit::f32_in(rng, 0.01, 0.5);
                let stream: Vec<Vec<f32>> =
                    (0..steps).map(|_| testkit::gradient_vec(rng, n)).collect();
                (tau, stream)
            },
            |(tau, stream)| {
                let n = stream[0].len();
                let mut c = StromCodec::new(n, *tau);
                let mut decoded_total = vec![0.0f32; n];
                for g in stream {
                    let msg = c.encode_step(g, &vec![0.0; n]);
                    c.decode_into(&msg.bytes, &mut decoded_total)
                        .map_err(|e| e.to_string())?;
                }
                for i in 0..n {
                    let total: f32 = stream.iter().map(|g| g[i]).sum();
                    let got = decoded_total[i] + c.r()[i];
                    if (got - total).abs() > 1e-4 * (1.0 + total.abs()) {
                        return Err(format!("i={i}: {got} != {total}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn single_quantum_per_step() {
        // Even a huge spike emits at most one ±τ per step (Alg. 2's
        // single-subtraction form); the rest drains over later steps.
        let mut c = StromCodec::new(1, 0.1);
        let msg = c.encode_step(&[1.0], &[0.0]);
        assert_eq!(msg.elements, 1);
        assert!((c.r()[0] - 0.9).abs() < 1e-6);
        // Drains with zero new gradient.
        let msg2 = c.encode_step(&[0.0], &[0.0]);
        assert_eq!(msg2.elements, 1);
        assert!((c.r()[0] - 0.8).abs() < 1e-6);
    }
}
