//! Compressed parameter-index encoding (paper Sec. 4.2: "We can further
//! reduce the number of bits by compressing parameter indexes (Strom,
//! 2015; Alistarh et al., 2017)").
//!
//! Sparse messages carry *sorted* parameter indices, so instead of a
//! naive 28-bit field per index we can gap-encode: write the deltas
//! between consecutive indices in Elias-gamma. Dense regions (small
//! gaps) cost a few bits per element; a uniform π-sparse message costs
//! about `log2(1/π) + 2` bits per index instead of 28.
//!
//! [`pack_indices`]/[`unpack_indices`] are the reusable primitive;
//! [`vgc_compact`] applies it to the VGC word stream: per group, the
//! sign+exponent nibbles are packed 4-bit-dense and the indices
//! gap-encoded, which is the paper's suggested upgrade implemented as
//! an optional wire format (`repro train --codec vgc:...,index=gamma`
//! would be the launcher spelling; the ablation bench compares both).

use super::encode::{BitReader, BitWriter};

/// Elias-gamma encode one positive integer (1 ≤ v).
#[inline]
fn gamma_encode(bits: &mut BitWriter, v: u32) {
    debug_assert!(v >= 1);
    let nbits = 32 - v.leading_zeros(); // position of MSB, 1-based
    // nbits-1 zeros, then the value MSB-first... we emit LSB-first
    // streams, so: unary length prefix then the low nbits-1 bits.
    bits.push(0, nbits - 1); // nbits-1 zero bits
    bits.push(1, 1); // stop bit
    bits.push(v & ((1u32 << (nbits - 1)) - 1).max(0), nbits - 1);
}

/// Elias-gamma decode one integer.
#[inline]
fn gamma_decode(bits: &mut BitReader) -> anyhow::Result<u32> {
    let mut zeros = 0u32;
    while bits.pull(1)? == 0 {
        zeros += 1;
        anyhow::ensure!(zeros < 32, "gamma code too long");
    }
    let low = if zeros > 0 { bits.pull(zeros)? } else { 0 };
    Ok((1u32 << zeros) | low)
}

/// Gap-encode a sorted index sequence into a bit stream.
///
/// Gaps are `index[0]+1, index[i]−index[i−1]` (all ≥ 1 for strictly
/// increasing input, which is enforced).
pub fn pack_indices(indices: &[u32]) -> anyhow::Result<Vec<u8>> {
    let mut out = Vec::new();
    let mut bits = BitWriter::over(&mut out);
    let mut prev: i64 = -1;
    for &idx in indices {
        let gap = idx as i64 - prev;
        anyhow::ensure!(gap >= 1, "indices must be strictly increasing");
        gamma_encode(&mut bits, gap as u32);
        prev = idx as i64;
    }
    bits.flush();
    Ok(out)
}

/// Decode `count` gap-encoded indices.
pub fn unpack_indices(bytes: &[u8], count: usize) -> anyhow::Result<Vec<u32>> {
    let mut bits = BitReader::new(bytes);
    let mut out = Vec::with_capacity(count);
    let mut prev: i64 = -1;
    for _ in 0..count {
        let gap = gamma_decode(&mut bits)? as i64;
        prev += gap;
        anyhow::ensure!(prev <= u32::MAX as i64, "index overflow");
        out.push(prev as u32);
    }
    Ok(out)
}

/// Exact bit cost of gamma-encoding the given sorted indices.
pub fn gamma_bits(indices: &[u32]) -> u64 {
    let mut prev: i64 = -1;
    let mut total = 0u64;
    for &idx in indices {
        let gap = (idx as i64 - prev) as u32;
        let nbits = 32 - gap.leading_zeros();
        total += (2 * nbits - 1) as u64;
        prev = idx as i64;
    }
    total
}

/// Compact re-encoding of a VGC-style sparse group: 4-bit sign+exponent
/// codes packed densely + gamma-coded indices, written into a reusable
/// buffer (cleared; capacity kept — the zero-allocation encode path).
/// Returns the exact payload bit count.
pub fn vgc_compact_into(
    indices: &[u32],
    codes: &[(bool, u8)],
    out: &mut Vec<u8>,
) -> anyhow::Result<u64> {
    anyhow::ensure!(indices.len() == codes.len(), "length mismatch");
    let mut bits = BitWriter::over(out);
    let mut prev: i64 = -1;
    for (&idx, &(neg, d)) in indices.iter().zip(codes) {
        let gap = idx as i64 - prev;
        anyhow::ensure!(gap >= 1, "indices must be strictly increasing");
        gamma_encode(&mut bits, gap as u32);
        bits.push(neg as u32, 1);
        bits.push(d as u32, 3);
        prev = idx as i64;
    }
    bits.flush();
    Ok(gamma_bits(indices) + 4 * indices.len() as u64)
}

/// Allocating convenience wrapper over [`vgc_compact_into`]. Returns
/// `(bytes, payload_bits)`.
pub fn vgc_compact(indices: &[u32], codes: &[(bool, u8)]) -> anyhow::Result<(Vec<u8>, u64)> {
    let mut out = Vec::new();
    let payload_bits = vgc_compact_into(indices, codes, &mut out)?;
    Ok((out, payload_bits))
}

/// Decode a compact VGC group into reusable `(indices, codes)` buffers
/// (cleared; capacity kept — the zero-allocation decode path).
pub fn vgc_compact_decode_into(
    bytes: &[u8],
    count: usize,
    indices: &mut Vec<u32>,
    codes: &mut Vec<(bool, u8)>,
) -> anyhow::Result<()> {
    indices.clear();
    codes.clear();
    let mut bits = BitReader::new(bytes);
    let mut prev: i64 = -1;
    for _ in 0..count {
        let gap = gamma_decode(&mut bits)? as i64;
        prev += gap;
        anyhow::ensure!(prev <= u32::MAX as i64, "index overflow");
        indices.push(prev as u32);
        let neg = bits.pull(1)? != 0;
        let d = bits.pull(3)? as u8;
        codes.push((neg, d));
    }
    Ok(())
}

/// Allocating convenience wrapper over [`vgc_compact_decode_into`].
pub fn vgc_compact_decode(
    bytes: &[u8],
    count: usize,
) -> anyhow::Result<(Vec<u32>, Vec<(bool, u8)>)> {
    let mut indices = Vec::with_capacity(count);
    let mut codes = Vec::with_capacity(count);
    vgc_compact_decode_into(bytes, count, &mut indices, &mut codes)?;
    Ok((indices, codes))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;
    use crate::util::rng::Pcg32;

    fn sorted_indices(rng: &mut Pcg32, n_space: u32, count: usize) -> Vec<u32> {
        let mut set = std::collections::BTreeSet::new();
        while set.len() < count {
            set.insert(rng.next_bounded(n_space));
        }
        set.into_iter().collect()
    }

    #[test]
    fn gamma_roundtrip_small_values() {
        let mut bytes = Vec::new();
        let mut bits = BitWriter::over(&mut bytes);
        for v in 1..=200u32 {
            gamma_encode(&mut bits, v);
        }
        bits.flush();
        let mut r = BitReader::new(&bytes);
        for v in 1..=200u32 {
            assert_eq!(gamma_decode(&mut r).unwrap(), v);
        }
    }

    #[test]
    fn index_roundtrip_property() {
        testkit::for_all(
            "gamma index roundtrip",
            |rng: &mut Pcg32| {
                let count = testkit::usize_in(rng, 0, 200);
                sorted_indices(rng, 1 << 20, count)
            },
            |indices| {
                let bytes = pack_indices(indices).map_err(|e| e.to_string())?;
                let back =
                    unpack_indices(&bytes, indices.len()).map_err(|e| e.to_string())?;
                if &back == indices {
                    Ok(())
                } else {
                    Err("mismatch".into())
                }
            },
        );
    }

    #[test]
    fn rejects_unsorted() {
        assert!(pack_indices(&[5, 3]).is_err());
        assert!(pack_indices(&[5, 5]).is_err());
        assert!(pack_indices(&[0, 1, 100]).is_ok());
    }

    #[test]
    fn compact_beats_naive_28bit_at_realistic_sparsity() {
        // At ratio ~100 (1% density) over 1M params, gamma-coded
        // indices + 4-bit codes must beat the 32-bit word format.
        let mut rng = Pcg32::new(3, 3);
        let indices = sorted_indices(&mut rng, 1_000_000, 10_000);
        let codes: Vec<(bool, u8)> = indices
            .iter()
            .map(|_| (rng.next_bool(0.5), rng.next_bounded(8) as u8))
            .collect();
        let (_, payload_bits) = vgc_compact(&indices, &codes).unwrap();
        let naive_bits = 32 * indices.len() as u64;
        assert!(
            payload_bits < naive_bits / 2,
            "compact {payload_bits} vs naive {naive_bits}"
        );
        // ~log2(100) + 2 + 4 ≈ 12.6 bits per element expected.
        let per_elem = payload_bits as f64 / indices.len() as f64;
        assert!((8.0..=18.0).contains(&per_elem), "{per_elem} bits/elem");
    }

    #[test]
    fn compact_roundtrip_property() {
        testkit::for_all(
            "vgc compact roundtrip",
            |rng: &mut Pcg32| {
                let count = testkit::usize_in(rng, 0, 100);
                let indices = sorted_indices(rng, 1 << 16, count);
                let codes: Vec<(bool, u8)> = indices
                    .iter()
                    .map(|_| (rng.next_bool(0.5), rng.next_bounded(8) as u8))
                    .collect();
                (indices, codes)
            },
            |(indices, codes)| {
                let (bytes, _) = vgc_compact(indices, codes).map_err(|e| e.to_string())?;
                let (bi, bc) =
                    vgc_compact_decode(&bytes, indices.len()).map_err(|e| e.to_string())?;
                if &bi == indices && &bc == codes {
                    Ok(())
                } else {
                    Err("mismatch".into())
                }
            },
        );
    }

    #[test]
    fn dense_indices_cost_few_bits() {
        // Consecutive indices: gap = 1 everywhere = 1 bit each.
        let indices: Vec<u32> = (10..1000).collect();
        let bits = gamma_bits(&indices);
        // First gap is 11 (costs 7 bits), rest are 1 bit.
        assert!(bits < indices.len() as u64 + 16, "{bits}");
    }
}
