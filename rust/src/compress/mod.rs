//! Gradient compression codecs — the paper's contribution (S1–S7).
//!
//! Every codec implements [`Codec`]: per step it ingests the worker's
//! Algorithm-1 moment increments (`gsum = Σ_z ∇f_z/B`,
//! `gsumsq = Σ_z (∇f_z/B)²`, both produced by the L1 Pallas kernel
//! through the grad artifact), updates its internal delayed-update
//! state, and emits a self-contained byte message. Decoding is
//! stateless: any worker can decode any peer's message given the codec
//! config, which is what ring allgatherv requires (Sec. 4.3).
//!
//! Codecs: [`vgc::VgcCodec`] (Alg. 1), [`hybrid::HybridCodec`] (Alg. 2),
//! [`strom::StromCodec`], [`qsgd::QsgdCodec`], [`terngrad::TernGradCodec`]
//! baselines, and [`none::NoCompression`].

pub mod adaptive;
pub mod controller;
pub mod encode;
pub mod engine;
pub mod hybrid;
pub mod indexcode;
pub mod none;
pub mod onebit;
pub mod qsgd;
pub mod quant4;
pub mod strom;
pub mod terngrad;
pub mod vgc;

pub use controller::{ControllerConfig, KnobController, KnobUpdate};
pub use engine::{shared_engine, CodecEngine, DecodeBuf, EncodeStats, SharedEngine};

use crate::model::Layout;
use crate::util::rng::Pcg32;
use crate::util::threadpool::ThreadPool;

/// How decoded per-worker contributions combine into the global update.
///
/// The paper's sparse codecs sum (each sent element is a worker's full
/// accumulated delayed gradient); dense codecs conventionally mean.
/// We run everything in Sum mode with sum-consistent learning rates —
/// the paper itself scales LR by the worker count (Sec. 6.1), which is
/// the same thing — but the distinction is kept explicit so dense
/// baselines can also be run in textbook Mean mode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Aggregation {
    Sum,
    Mean,
}

/// One worker's encoded step message plus accounting.
#[derive(Debug, Clone)]
pub struct Message {
    /// Wire bytes (what the fabric actually moves).
    pub bytes: Vec<u8>,
    /// Gradient elements represented (the paper's compression-ratio
    /// denominator: "the average number of parameters sent").
    pub elements: u64,
    /// Exact payload bits (elements × their code width), excluding
    /// container headers — the paper's accounting convention ("we can
    /// ignore ... non-essential information").
    pub payload_bits: u64,
}

impl Message {
    pub fn wire_bits(&self) -> u64 {
        self.bytes.len() as u64 * 8
    }
}

/// A tunable codec's single compression knob: which parameter it is,
/// its current value, the closed range it may move in, and which
/// direction *tightens* (sends fewer elements). The knob is the
/// surface the closed-loop controller ([`controller::KnobController`])
/// drives: ζ for the variance codecs, τ for Strom, π for the
/// adaptive-threshold baseline.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KnobState {
    /// Knob identifier, e.g. `"zeta"`, `"tau"`, `"pi"`.
    pub name: &'static str,
    /// Current (global/scalar) value.
    pub value: f32,
    /// Lower bound of the admissible range.
    pub lo: f32,
    /// Upper bound of the admissible range.
    pub hi: f32,
    /// `true` if raising the value tightens compression (ζ, τ);
    /// `false` if lowering does (π).
    pub tighten_up: bool,
}

impl KnobState {
    /// The value at tightness coordinate `u ∈ [0, 1]`, interpolating
    /// from the *current* value (`u = 0`) to the max-tighten bound
    /// (`u = 1`). Keeps `u = 0` exactly the static configuration.
    pub fn at_tightness(&self, initial: f32, u: f32) -> f32 {
        let bound = if self.tighten_up { self.hi } else { self.lo };
        let u = u.clamp(0.0, 1.0);
        (initial + u * (bound - initial)).clamp(self.lo, self.hi)
    }
}

/// A gradient compression codec; one instance per worker (it owns that
/// worker's residual/variance state). `Sync` so the stateless decode
/// side can be shared across the engine's threads.
pub trait Codec: Send + Sync {
    /// Short identifier, e.g. `vgc(alpha=1.5)`.
    fn name(&self) -> String;

    fn aggregation(&self) -> Aggregation;

    /// Primary encode kernel: ingest this step's moment increments
    /// (`gsumsq` may be ignored by magnitude-only codecs) and write the
    /// wire message into `bytes` (cleared; capacity reused, so
    /// steady-state encodes perform zero heap allocations — §Perf).
    fn encode_step_into(
        &mut self,
        gsum: &[f32],
        gsumsq: &[f32],
        bytes: &mut Vec<u8>,
    ) -> EncodeStats;

    /// Convenience wrapper producing an owned [`Message`].
    fn encode_step(&mut self, gsum: &[f32], gsumsq: &[f32]) -> Message {
        let mut bytes = Vec::new();
        let st = self.encode_step_into(gsum, gsumsq, &mut bytes);
        Message {
            bytes,
            elements: st.elements,
            payload_bits: st.payload_bits,
        }
    }

    /// Shard-parallel encode over `pool`. Implementations MUST produce
    /// bytes, stats and post-step state identical to
    /// [`Codec::encode_step_into`] (the engine's parity contract); the
    /// default simply runs the serial kernel. Used by the engine when
    /// threads outnumber workers.
    fn encode_step_pooled(
        &mut self,
        gsum: &[f32],
        gsumsq: &[f32],
        pool: &ThreadPool,
        bytes: &mut Vec<u8>,
    ) -> EncodeStats {
        let _ = pool;
        self.encode_step_into(gsum, gsumsq, bytes)
    }

    /// Decode a peer message, *accumulating* (`+=`) the decoded update
    /// into `out` (length N). Stateless w.r.t. training state.
    fn decode_into(&self, bytes: &[u8], out: &mut [f32]) -> anyhow::Result<()>;

    /// Decode a peer message into `(index, value)` contribution entries
    /// (message order preserved — the engine replays them to reproduce
    /// the serial accumulation bit-for-bit). Sparse codecs override
    /// this with a direct parse; the default decodes densely through
    /// `decode_into` and emits the nonzero elements. Dropping the zeros
    /// is bit-safe: the decode accumulators start at `+0.0` and can
    /// never become `-0.0` (IEEE round-to-nearest returns `+0.0` for
    /// every cancelling sum), so adding `±0.0` never changes any bit —
    /// and it keeps mostly-zero dense streams (low-bit QSGD, TernGrad)
    /// cheap to replay.
    fn decode_entries(&self, bytes: &[u8], buf: &mut DecodeBuf) -> anyhow::Result<()> {
        let n = buf.expected_len();
        let mut dense = buf.take_dense();
        dense.clear();
        dense.resize(n, 0.0);
        let res = self.decode_into(bytes, &mut dense);
        if res.is_ok() {
            for (i, &v) in dense.iter().enumerate() {
                if v != 0.0 {
                    buf.push(i as u32, v);
                }
            }
        }
        buf.return_dense(dense);
        res
    }

    /// Undelivered mass currently held back by the codec (L1 norm of the
    /// residual), for diagnostics and conservation tests. Dense codecs
    /// return 0.
    fn residual_l1(&self) -> f64 {
        0.0
    }

    /// The codec's tunable knob, if it has one. Non-tunable codecs
    /// (none, qsgd, terngrad, onebit) return `None` and behave exactly
    /// as before the Tunable surface existed.
    fn knob(&self) -> Option<KnobState> {
        None
    }

    /// Set the (global) knob value; returns `false` if the codec has
    /// no knob. Values are clamped to the knob's `[lo, hi]` range by
    /// the implementation. Takes effect at the *next* encode, so all
    /// workers' codecs must be updated together between steps to keep
    /// decode (which may read the knob, e.g. Strom's τ) consistent.
    fn set_knob(&mut self, _value: f32) -> bool {
        false
    }

    /// Set the knob for one contiguous element range `[lo, hi)` only —
    /// the per-bucket surface. Codecs whose knob cannot vary per
    /// element range return `false` (the controller then falls back to
    /// a comm-share-weighted scalar `set_knob`). An empty override set
    /// must leave behavior bit-identical to the scalar path.
    fn set_knob_range(&mut self, _lo: usize, _hi: usize, _value: f32) -> bool {
        false
    }
}

/// Codec selection parsed from CLI / config (see `config` module).
#[derive(Debug, Clone, PartialEq)]
pub enum CodecSpec {
    None,
    Vgc { alpha: f32, zeta: f32 },
    /// VGC with the Sec.-4.2 compressed-index wire format.
    VgcCompact { alpha: f32, zeta: f32 },
    Strom { tau: f32 },
    Hybrid { tau: f32, alpha: f32, zeta: f32 },
    Qsgd { bits: u32, bucket: usize },
    TernGrad,
    /// 1-bit SGD baseline (Seide et al. 2014).
    OneBit,
    /// Adaptive-threshold top-fraction baseline (Dryden et al. 2016).
    Adaptive { pi: f32 },
}

impl CodecSpec {
    /// Parse e.g. `vgc:alpha=1.5`, `strom:tau=0.01`, `qsgd:bits=2,d=128`,
    /// `hybrid:tau=0.01,alpha=2`, `terngrad`, `none`.
    pub fn parse(s: &str) -> anyhow::Result<CodecSpec> {
        let (head, rest) = match s.split_once(':') {
            Some((h, r)) => (h, r),
            None => (s, ""),
        };
        let mut kv = std::collections::BTreeMap::new();
        for part in rest.split(',').filter(|p| !p.is_empty()) {
            let (k, v) = part
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("bad codec param '{part}' in '{s}'"))?;
            kv.insert(k.trim().to_string(), v.trim().to_string());
        }
        let f = |kv: &std::collections::BTreeMap<String, String>, k: &str, d: f32| -> anyhow::Result<f32> {
            match kv.get(k) {
                None => Ok(d),
                Some(v) => v.parse().map_err(|e| anyhow::anyhow!("bad {k}={v}: {e}")),
            }
        };
        // Integer params parse as integers (a float detour would round
        // large values, e.g. buckets above 2^24, and silently accept
        // fractions).
        let u = |kv: &std::collections::BTreeMap<String, String>, k: &str, d: u64| -> anyhow::Result<u64> {
            match kv.get(k) {
                None => Ok(d),
                Some(v) => v
                    .parse()
                    .map_err(|e| anyhow::anyhow!("bad integer {k}={v}: {e}")),
            }
        };
        Ok(match head {
            "none" => CodecSpec::None,
            "vgc" => {
                let alpha = f(&kv, "alpha", 1.5)?;
                let zeta = f(&kv, "zeta", 0.999)?;
                if kv.get("index").map(|s| s.as_str()) == Some("gamma") {
                    CodecSpec::VgcCompact { alpha, zeta }
                } else {
                    CodecSpec::Vgc { alpha, zeta }
                }
            }
            "strom" => CodecSpec::Strom {
                tau: f(&kv, "tau", 0.01)?,
            },
            "hybrid" => CodecSpec::Hybrid {
                tau: f(&kv, "tau", 0.01)?,
                alpha: f(&kv, "alpha", 2.0)?,
                zeta: f(&kv, "zeta", 0.999)?,
            },
            "qsgd" => {
                let bits = u(&kv, "bits", 2)?;
                anyhow::ensure!(
                    (1..=8).contains(&bits),
                    "qsgd bits must be in 1..=8, got {bits}"
                );
                let bucket = u(&kv, "d", 128)?;
                anyhow::ensure!(
                    (1..=u32::MAX as u64).contains(&bucket),
                    "qsgd bucket size d must be in 1..=2^32-1, got {bucket}"
                );
                CodecSpec::Qsgd {
                    bits: bits as u32,
                    bucket: bucket as usize,
                }
            }
            "terngrad" => CodecSpec::TernGrad,
            "onebit" => CodecSpec::OneBit,
            "adaptive" => CodecSpec::Adaptive {
                pi: f(&kv, "pi", 0.01)?,
            },
            other => anyhow::bail!("unknown codec '{other}'"),
        })
    }

    /// Instantiate one worker's codec. `worker_seed` feeds the stochastic
    /// codecs (QSGD/TernGrad rounding).
    pub fn build(&self, layout: &Layout, worker_seed: u64) -> Box<dyn Codec> {
        match *self {
            CodecSpec::None => Box::new(none::NoCompression::new(layout.n())),
            CodecSpec::Vgc { alpha, zeta } => {
                Box::new(vgc::VgcCodec::new(layout.clone(), alpha, zeta))
            }
            CodecSpec::VgcCompact { alpha, zeta } => Box::new(
                vgc::VgcCodec::new(layout.clone(), alpha, zeta).with_compact_indices(true),
            ),
            CodecSpec::Strom { tau } => Box::new(strom::StromCodec::new(layout.n(), tau)),
            CodecSpec::Hybrid { tau, alpha, zeta } => {
                Box::new(hybrid::HybridCodec::new(layout.clone(), tau, alpha, zeta))
            }
            CodecSpec::Qsgd { bits, bucket } => Box::new(qsgd::QsgdCodec::new(
                layout.n(),
                bits,
                bucket,
                Pcg32::new(0x5D01 ^ worker_seed, worker_seed),
            )),
            CodecSpec::TernGrad => Box::new(terngrad::TernGradCodec::new(
                layout.clone(),
                Pcg32::new(0x7E44 ^ worker_seed, worker_seed),
            )),
            CodecSpec::OneBit => Box::new(onebit::OneBitCodec::new(layout.clone())),
            CodecSpec::Adaptive { pi } => {
                Box::new(adaptive::AdaptiveCodec::new(layout.n(), pi))
            }
        }
    }

    pub fn label(&self) -> String {
        match self {
            CodecSpec::None => "none".into(),
            CodecSpec::Vgc { alpha, .. } => format!("vgc(α={alpha})"),
            CodecSpec::VgcCompact { alpha, .. } => format!("vgc-γ(α={alpha})"),
            CodecSpec::Strom { tau } => format!("strom(τ={tau})"),
            CodecSpec::Hybrid { tau, alpha, .. } => format!("hybrid(τ={tau},α={alpha})"),
            CodecSpec::Qsgd { bits, bucket } => format!("qsgd({bits}bit,d={bucket})"),
            CodecSpec::TernGrad => "terngrad".into(),
            CodecSpec::OneBit => "onebit".into(),
            CodecSpec::Adaptive { pi } => format!("adaptive(π={pi})"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_codec_specs() {
        assert_eq!(CodecSpec::parse("none").unwrap(), CodecSpec::None);
        assert_eq!(
            CodecSpec::parse("vgc:alpha=2.0").unwrap(),
            CodecSpec::Vgc { alpha: 2.0, zeta: 0.999 }
        );
        assert_eq!(
            CodecSpec::parse("strom:tau=0.1").unwrap(),
            CodecSpec::Strom { tau: 0.1 }
        );
        assert_eq!(
            CodecSpec::parse("hybrid:tau=0.01,alpha=2").unwrap(),
            CodecSpec::Hybrid { tau: 0.01, alpha: 2.0, zeta: 0.999 }
        );
        assert_eq!(
            CodecSpec::parse("qsgd:bits=3,d=512").unwrap(),
            CodecSpec::Qsgd { bits: 3, bucket: 512 }
        );
        assert!(CodecSpec::parse("bogus").is_err());
        assert!(CodecSpec::parse("vgc:alpha").is_err());
    }

    #[test]
    fn integer_codec_params_parse_exactly_and_validate() {
        // 2^24 + 1 is not representable in f32: the old float detour
        // would silently round it. Must survive exactly.
        assert_eq!(
            CodecSpec::parse("qsgd:bits=3,d=16777217").unwrap(),
            CodecSpec::Qsgd { bits: 3, bucket: 16_777_217 }
        );
        // Out-of-range and non-integer values are loud errors.
        assert!(CodecSpec::parse("qsgd:bits=0").is_err());
        assert!(CodecSpec::parse("qsgd:bits=9").is_err());
        assert!(CodecSpec::parse("qsgd:d=0").is_err());
        assert!(CodecSpec::parse("qsgd:bits=2.5").is_err());
        assert!(CodecSpec::parse("qsgd:d=1.5").is_err());
        assert!(CodecSpec::parse("qsgd:bits=-1").is_err());
    }
}
