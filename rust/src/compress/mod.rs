//! Gradient compression codecs — the paper's contribution (S1–S7).
//!
//! Every codec implements [`Codec`]: per step it ingests the worker's
//! Algorithm-1 moment increments (`gsum = Σ_z ∇f_z/B`,
//! `gsumsq = Σ_z (∇f_z/B)²`, both produced by the L1 Pallas kernel
//! through the grad artifact), updates its internal delayed-update
//! state, and emits a self-contained byte message. Decoding is
//! stateless: any worker can decode any peer's message given the codec
//! config, which is what ring allgatherv requires (Sec. 4.3).
//!
//! Codecs: [`vgc::VgcCodec`] (Alg. 1), [`hybrid::HybridCodec`] (Alg. 2),
//! [`strom::StromCodec`], [`qsgd::QsgdCodec`], [`terngrad::TernGradCodec`]
//! baselines, and [`none::NoCompression`].

pub mod adaptive;
pub mod encode;
pub mod hybrid;
pub mod indexcode;
pub mod none;
pub mod onebit;
pub mod qsgd;
pub mod quant4;
pub mod strom;
pub mod terngrad;
pub mod vgc;

use crate::model::Layout;
use crate::util::rng::Pcg32;

/// How decoded per-worker contributions combine into the global update.
///
/// The paper's sparse codecs sum (each sent element is a worker's full
/// accumulated delayed gradient); dense codecs conventionally mean.
/// We run everything in Sum mode with sum-consistent learning rates —
/// the paper itself scales LR by the worker count (Sec. 6.1), which is
/// the same thing — but the distinction is kept explicit so dense
/// baselines can also be run in textbook Mean mode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Aggregation {
    Sum,
    Mean,
}

/// One worker's encoded step message plus accounting.
#[derive(Debug, Clone)]
pub struct Message {
    /// Wire bytes (what the fabric actually moves).
    pub bytes: Vec<u8>,
    /// Gradient elements represented (the paper's compression-ratio
    /// denominator: "the average number of parameters sent").
    pub elements: u64,
    /// Exact payload bits (elements × their code width), excluding
    /// container headers — the paper's accounting convention ("we can
    /// ignore ... non-essential information").
    pub payload_bits: u64,
}

impl Message {
    pub fn wire_bits(&self) -> u64 {
        self.bytes.len() as u64 * 8
    }
}

/// A gradient compression codec; one instance per worker (it owns that
/// worker's residual/variance state).
pub trait Codec: Send {
    /// Short identifier, e.g. `vgc(alpha=1.5)`.
    fn name(&self) -> String;

    fn aggregation(&self) -> Aggregation;

    /// Ingest this step's moment increments and emit the wire message.
    /// `gsumsq` may be ignored by magnitude-only codecs.
    fn encode_step(&mut self, gsum: &[f32], gsumsq: &[f32]) -> Message;

    /// Decode a peer message, *accumulating* (`+=`) the decoded update
    /// into `out` (length N). Stateless w.r.t. training state.
    fn decode_into(&self, bytes: &[u8], out: &mut [f32]) -> anyhow::Result<()>;

    /// Undelivered mass currently held back by the codec (L1 norm of the
    /// residual), for diagnostics and conservation tests. Dense codecs
    /// return 0.
    fn residual_l1(&self) -> f64 {
        0.0
    }
}

/// Codec selection parsed from CLI / config (see `config` module).
#[derive(Debug, Clone, PartialEq)]
pub enum CodecSpec {
    None,
    Vgc { alpha: f32, zeta: f32 },
    /// VGC with the Sec.-4.2 compressed-index wire format.
    VgcCompact { alpha: f32, zeta: f32 },
    Strom { tau: f32 },
    Hybrid { tau: f32, alpha: f32, zeta: f32 },
    Qsgd { bits: u32, bucket: usize },
    TernGrad,
    /// 1-bit SGD baseline (Seide et al. 2014).
    OneBit,
    /// Adaptive-threshold top-fraction baseline (Dryden et al. 2016).
    Adaptive { pi: f32 },
}

impl CodecSpec {
    /// Parse e.g. `vgc:alpha=1.5`, `strom:tau=0.01`, `qsgd:bits=2,d=128`,
    /// `hybrid:tau=0.01,alpha=2`, `terngrad`, `none`.
    pub fn parse(s: &str) -> anyhow::Result<CodecSpec> {
        let (head, rest) = match s.split_once(':') {
            Some((h, r)) => (h, r),
            None => (s, ""),
        };
        let mut kv = std::collections::BTreeMap::new();
        for part in rest.split(',').filter(|p| !p.is_empty()) {
            let (k, v) = part
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("bad codec param '{part}' in '{s}'"))?;
            kv.insert(k.trim().to_string(), v.trim().to_string());
        }
        let f = |kv: &std::collections::BTreeMap<String, String>, k: &str, d: f32| -> anyhow::Result<f32> {
            match kv.get(k) {
                None => Ok(d),
                Some(v) => v.parse().map_err(|e| anyhow::anyhow!("bad {k}={v}: {e}")),
            }
        };
        Ok(match head {
            "none" => CodecSpec::None,
            "vgc" => {
                let alpha = f(&kv, "alpha", 1.5)?;
                let zeta = f(&kv, "zeta", 0.999)?;
                if kv.get("index").map(|s| s.as_str()) == Some("gamma") {
                    CodecSpec::VgcCompact { alpha, zeta }
                } else {
                    CodecSpec::Vgc { alpha, zeta }
                }
            }
            "strom" => CodecSpec::Strom {
                tau: f(&kv, "tau", 0.01)?,
            },
            "hybrid" => CodecSpec::Hybrid {
                tau: f(&kv, "tau", 0.01)?,
                alpha: f(&kv, "alpha", 2.0)?,
                zeta: f(&kv, "zeta", 0.999)?,
            },
            "qsgd" => CodecSpec::Qsgd {
                bits: f(&kv, "bits", 2.0)? as u32,
                bucket: f(&kv, "d", 128.0)? as usize,
            },
            "terngrad" => CodecSpec::TernGrad,
            "onebit" => CodecSpec::OneBit,
            "adaptive" => CodecSpec::Adaptive {
                pi: f(&kv, "pi", 0.01)?,
            },
            other => anyhow::bail!("unknown codec '{other}'"),
        })
    }

    /// Instantiate one worker's codec. `worker_seed` feeds the stochastic
    /// codecs (QSGD/TernGrad rounding).
    pub fn build(&self, layout: &Layout, worker_seed: u64) -> Box<dyn Codec> {
        match *self {
            CodecSpec::None => Box::new(none::NoCompression::new(layout.n())),
            CodecSpec::Vgc { alpha, zeta } => {
                Box::new(vgc::VgcCodec::new(layout.clone(), alpha, zeta))
            }
            CodecSpec::VgcCompact { alpha, zeta } => Box::new(
                vgc::VgcCodec::new(layout.clone(), alpha, zeta).with_compact_indices(true),
            ),
            CodecSpec::Strom { tau } => Box::new(strom::StromCodec::new(layout.n(), tau)),
            CodecSpec::Hybrid { tau, alpha, zeta } => {
                Box::new(hybrid::HybridCodec::new(layout.clone(), tau, alpha, zeta))
            }
            CodecSpec::Qsgd { bits, bucket } => Box::new(qsgd::QsgdCodec::new(
                layout.n(),
                bits,
                bucket,
                Pcg32::new(0x5D01 ^ worker_seed, worker_seed),
            )),
            CodecSpec::TernGrad => Box::new(terngrad::TernGradCodec::new(
                layout.clone(),
                Pcg32::new(0x7E44 ^ worker_seed, worker_seed),
            )),
            CodecSpec::OneBit => Box::new(onebit::OneBitCodec::new(layout.clone())),
            CodecSpec::Adaptive { pi } => {
                Box::new(adaptive::AdaptiveCodec::new(layout.n(), pi))
            }
        }
    }

    pub fn label(&self) -> String {
        match self {
            CodecSpec::None => "none".into(),
            CodecSpec::Vgc { alpha, .. } => format!("vgc(α={alpha})"),
            CodecSpec::VgcCompact { alpha, .. } => format!("vgc-γ(α={alpha})"),
            CodecSpec::Strom { tau } => format!("strom(τ={tau})"),
            CodecSpec::Hybrid { tau, alpha, .. } => format!("hybrid(τ={tau},α={alpha})"),
            CodecSpec::Qsgd { bits, bucket } => format!("qsgd({bits}bit,d={bucket})"),
            CodecSpec::TernGrad => "terngrad".into(),
            CodecSpec::OneBit => "onebit".into(),
            CodecSpec::Adaptive { pi } => format!("adaptive(π={pi})"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_codec_specs() {
        assert_eq!(CodecSpec::parse("none").unwrap(), CodecSpec::None);
        assert_eq!(
            CodecSpec::parse("vgc:alpha=2.0").unwrap(),
            CodecSpec::Vgc { alpha: 2.0, zeta: 0.999 }
        );
        assert_eq!(
            CodecSpec::parse("strom:tau=0.1").unwrap(),
            CodecSpec::Strom { tau: 0.1 }
        );
        assert_eq!(
            CodecSpec::parse("hybrid:tau=0.01,alpha=2").unwrap(),
            CodecSpec::Hybrid { tau: 0.01, alpha: 2.0, zeta: 0.999 }
        );
        assert_eq!(
            CodecSpec::parse("qsgd:bits=3,d=512").unwrap(),
            CodecSpec::Qsgd { bits: 3, bucket: 512 }
        );
        assert!(CodecSpec::parse("bogus").is_err());
        assert!(CodecSpec::parse("vgc:alpha").is_err());
    }
}
