//! Algorithm 1 — the paper's variance-based compression codec.
//!
//! Per parameter i the codec maintains the delayed update `r_i`
//! (accumulated mini-batch mean gradients) and `v_i` (accumulated mean
//! squared gradients, decayed by ζ while unsent). An element is sent
//! only when it is *unambiguous*: `r_i² > α·v_i` (Eq. 3 — the efficient
//! form of the variance criterion Eq. 1, Appendix A).
//!
//! Sent elements are quantized with the 4-bit sign/exponent code
//! against their group's `M_k` and packed into the paper's 32-bit word
//! (1 sign + 3 exponent + 28 index bits); their `r_i`/`v_i` reset to 0.
//! Quantization error is deliberately NOT carried (Sec. 4.2: "We do not
//! ... accumulate rounding error for the next batch"). Elements whose
//! quantized exponent underflows the 3-bit field (`d > 7`) are dropped
//! by the quantizer and treated as unsent (state kept, decay applied).
//!
//! Wire format (little-endian). Naive (the paper's 32-bit-word format):
//!   u32 n_groups
//!   per group: u32 group_index, i32 mexp, u32 count, count × u32 words
//! Word indices are *global* parameter indices (28-bit, Sec. 4.2).
//!
//! Compact (Sec. 4.2's "compress parameter indexes" upgrade, enabled
//! with `index=gamma`): the first u32 sets bit 31 as a format flag; per
//! group the words are replaced by `u32 byte_len` + an Elias-gamma
//! gap-coded index stream interleaved with dense 4-bit codes (see
//! [`super::indexcode`]).

use super::encode::{pack_word, unpack_word, ByteReader, ByteWriter};
use super::engine::{DecodeBuf, EncodeStats};
use super::indexcode;
use super::quant4;
use super::{Aggregation, Codec, KnobState};
use crate::model::{Layout, ParamGroup};
use crate::util::threadpool::{Task, ThreadPool};

/// Format flag in the leading u32 (bit 31): compact index coding.
const COMPACT_FLAG: u32 = 1 << 31;

/// Per-shard reusable encode scratch (pooled encode).
#[derive(Default)]
struct ShardScratch {
    bytes: Vec<u8>,
    selected: Vec<u32>,
    codes: Vec<(bool, u8)>,
    compact_buf: Vec<u8>,
    stats: EncodeStats,
    groups_sent: u32,
}

pub struct VgcCodec {
    layout: Layout,
    alpha: f32,
    zeta: f32,
    /// Use gamma-coded indices + dense 4-bit codes on the wire.
    compact: bool,
    /// Delayed update accumulator (Σ over steps of Σ_z ∇f_z / B).
    r: Vec<f32>,
    /// Ambiguity accumulator (Σ over steps of Σ_z (∇f_z/B)², ζ-decayed).
    v: Vec<f32>,
    /// Scratch: indices selected this step (reused across steps).
    selected: Vec<u32>,
    /// Scratch: quantized codes for the compact format.
    codes: Vec<(bool, u8)>,
    /// Scratch: per-group compact bitstream (reused across groups).
    compact_buf: Vec<u8>,
    /// Per-shard scratch for the pooled encode (lazily sized).
    shards: Vec<ShardScratch>,
    /// Per-element-range ζ overrides `(lo, hi, ζ)` set by the adaptive
    /// controller via [`Codec::set_knob_range`]; sorted by `lo`,
    /// disjoint. Empty ⇒ the exact legacy whole-vector decay path.
    zeta_ranges: Vec<(usize, usize, f32)>,
}

impl VgcCodec {
    pub fn new(layout: Layout, alpha: f32, zeta: f32) -> VgcCodec {
        assert!(alpha > 0.0, "alpha must be positive");
        assert!(zeta > 0.0 && zeta <= 1.0, "zeta must be in (0, 1]");
        let n = layout.n();
        VgcCodec {
            layout,
            alpha,
            zeta,
            compact: false,
            r: vec![0.0; n],
            v: vec![0.0; n],
            selected: Vec::new(),
            codes: Vec::new(),
            compact_buf: Vec::new(),
            shards: Vec::new(),
            zeta_ranges: Vec::new(),
        }
    }

    /// Enable the Sec.-4.2 compressed-index wire format.
    pub fn with_compact_indices(mut self, compact: bool) -> VgcCodec {
        self.compact = compact;
        self
    }

    /// Read-only view of the delayed-update state (tests/diagnostics).
    pub fn r(&self) -> &[f32] {
        &self.r
    }

    pub fn v(&self) -> &[f32] {
        &self.v
    }

    /// The Eq.-3 send decision for one element.
    #[inline]
    pub fn criterion(r: f32, v: f32, alpha: f32) -> bool {
        r * r > alpha * v
    }
}

impl Codec for VgcCodec {
    fn name(&self) -> String {
        format!("vgc(alpha={},zeta={})", self.alpha, self.zeta)
    }

    fn aggregation(&self) -> Aggregation {
        Aggregation::Sum
    }

    fn encode_step_into(
        &mut self,
        gsum: &[f32],
        gsumsq: &[f32],
        bytes: &mut Vec<u8>,
    ) -> EncodeStats {
        let n = self.layout.n();
        assert_eq!(gsum.len(), n);
        assert_eq!(gsumsq.len(), n);

        let mut w = ByteWriter::over(bytes);
        w.u32(0); // group-count + format-flag placeholder
        let (stats, n_groups_sent) = encode_groups(
            self.layout.groups(),
            0,
            0,
            &mut self.r,
            &mut self.v,
            gsum,
            gsumsq,
            self.alpha,
            self.compact,
            &mut self.selected,
            &mut self.codes,
            &mut self.compact_buf,
            &mut w,
        );

        // Alg. 1 unsent branch: decay v. Sent elements were reset to 0
        // above, so a branchless multiply is semantically identical to
        // the algorithm's else-branch decay — and ~2× faster than the
        // branchy form on this hot loop (§Perf L3). With no per-range ζ
        // overrides this is the exact legacy whole-vector multiply.
        decay_slice(&mut self.v, 0, self.zeta, &self.zeta_ranges);

        let flag = if self.compact { COMPACT_FLAG } else { 0 };
        w.patch_u32(0, n_groups_sent | flag);
        stats
    }

    fn encode_step_pooled(
        &mut self,
        gsum: &[f32],
        gsumsq: &[f32],
        pool: &ThreadPool,
        bytes: &mut Vec<u8>,
    ) -> EncodeStats {
        if pool.threads() == 1 {
            return self.encode_step_into(gsum, gsumsq, bytes);
        }
        let n = self.layout.n();
        assert_eq!(gsum.len(), n);
        assert_eq!(gsumsq.len(), n);
        let spans = shard_groups(self.layout.groups(), pool.threads());
        while self.shards.len() < spans.len() {
            self.shards.push(ShardScratch::default());
        }
        let VgcCodec {
            layout,
            alpha,
            zeta,
            compact,
            r,
            v,
            shards,
            zeta_ranges,
            ..
        } = self;
        let (alpha, zeta, compact) = (*alpha, *zeta, *compact);
        let zeta_ranges: &[(usize, usize, f32)] = zeta_ranges;
        let groups = layout.groups();
        let mut tasks: Vec<Task<'_>> = Vec::with_capacity(spans.len());
        let mut r_rest: &mut [f32] = r;
        let mut v_rest: &mut [f32] = v;
        let mut shard_iter = shards.iter_mut();
        for span in &spans {
            let len = span.elem_hi - span.elem_lo;
            let (r_s, r_next) = r_rest.split_at_mut(len);
            let (v_s, v_next) = v_rest.split_at_mut(len);
            r_rest = r_next;
            v_rest = v_next;
            let scratch = shard_iter.next().expect("scratch sized above");
            let g_slice = &groups[span.group_lo..span.group_hi];
            let gs = &gsum[span.elem_lo..span.elem_hi];
            let qs = &gsumsq[span.elem_lo..span.elem_hi];
            let (base, gi_base) = (span.elem_lo, span.group_lo);
            tasks.push(Box::new(move || {
                scratch.bytes.clear();
                let mut w = ByteWriter::append(&mut scratch.bytes);
                let (stats, sent) = encode_groups(
                    g_slice,
                    gi_base,
                    base,
                    r_s,
                    v_s,
                    gs,
                    qs,
                    alpha,
                    compact,
                    &mut scratch.selected,
                    &mut scratch.codes,
                    &mut scratch.compact_buf,
                    &mut w,
                );
                scratch.stats = stats;
                scratch.groups_sent = sent;
                // ζ decay of this shard's element range (identical to
                // the serial whole-vector pass).
                decay_slice(v_s, base, zeta, zeta_ranges);
            }));
        }
        pool.run(tasks);

        // Assemble: header, then shard bodies concatenated in group
        // order — byte-identical to the serial message.
        let mut w = ByteWriter::over(bytes);
        w.u32(0);
        let mut stats = EncodeStats::default();
        let mut groups_sent = 0u32;
        for scratch in shards[..spans.len()].iter() {
            w.bytes(&scratch.bytes);
            stats.elements += scratch.stats.elements;
            stats.payload_bits += scratch.stats.payload_bits;
            groups_sent += scratch.groups_sent;
        }
        let flag = if compact { COMPACT_FLAG } else { 0 };
        w.patch_u32(0, groups_sent | flag);
        stats
    }

    fn decode_into(&self, bytes: &[u8], out: &mut [f32]) -> anyhow::Result<()> {
        decode_vgc_message(bytes, &self.layout, out)
    }

    fn decode_entries(&self, bytes: &[u8], buf: &mut DecodeBuf) -> anyhow::Result<()> {
        decode_vgc_entries(bytes, &self.layout, buf)
    }

    fn residual_l1(&self) -> f64 {
        self.r.iter().map(|x| x.abs() as f64).sum()
    }

    fn knob(&self) -> Option<KnobState> {
        // Raising ζ toward 1 keeps the variance estimate alive longer,
        // so fewer elements pass Eq. 3 ⇒ tighter compression.
        Some(KnobState {
            name: "zeta",
            value: self.zeta,
            lo: self.zeta.min(0.5).max(1e-3),
            hi: 1.0,
            tighten_up: true,
        })
    }

    fn set_knob(&mut self, value: f32) -> bool {
        if !(value > 0.0 && value <= 1.0) {
            return false;
        }
        self.zeta = value;
        true
    }

    fn set_knob_range(&mut self, lo: usize, hi: usize, value: f32) -> bool {
        if !(value > 0.0 && value <= 1.0) || lo >= hi {
            return false;
        }
        match self.zeta_ranges.iter_mut().find(|e| e.0 == lo && e.1 == hi) {
            Some(entry) => entry.2 = value,
            None => {
                self.zeta_ranges.push((lo, hi, value));
                self.zeta_ranges.sort_unstable_by_key(|e| e.0);
            }
        }
        true
    }
}

/// ζ-decay `v` (covering global elements `base..base + v.len()`) with
/// per-range overrides. `ranges` is sorted by `lo` and disjoint;
/// uncovered elements use the scalar `zeta`. With no ranges this is
/// exactly the legacy branchless whole-vector multiply (bit-identical
/// static path).
fn decay_slice(v: &mut [f32], base: usize, zeta: f32, ranges: &[(usize, usize, f32)]) {
    if ranges.is_empty() {
        for x in v.iter_mut() {
            *x *= zeta;
        }
        return;
    }
    let hi_all = base + v.len();
    let mut cur = base;
    for &(lo, hi, z) in ranges {
        let lo = lo.max(cur).min(hi_all);
        let hi = hi.min(hi_all).max(lo);
        for x in v[cur - base..lo - base].iter_mut() {
            *x *= zeta;
        }
        for x in v[lo - base..hi - base].iter_mut() {
            *x *= z;
        }
        cur = hi;
        if cur >= hi_all {
            break;
        }
    }
    for x in v[cur.min(hi_all) - base..].iter_mut() {
        *x *= zeta;
    }
}

/// One contiguous run of groups assigned to an encode shard.
struct GroupSpan {
    group_lo: usize,
    group_hi: usize,
    elem_lo: usize,
    elem_hi: usize,
}

/// Partition the layout's groups into contiguous element-balanced spans
/// (one encode task each). Spans stay group-aligned so the shard byte
/// streams concatenate into exactly the serial message.
fn shard_groups(groups: &[ParamGroup], parts: usize) -> Vec<GroupSpan> {
    let total: usize = groups.iter().map(|g| g.len).sum();
    let target = total.div_ceil(parts.max(1)).max(1);
    let mut spans = Vec::new();
    let mut group_lo = 0usize;
    let mut elem_lo = 0usize;
    let mut acc = 0usize;
    for (k, g) in groups.iter().enumerate() {
        acc += g.len;
        if acc >= target || k + 1 == groups.len() {
            spans.push(GroupSpan {
                group_lo,
                group_hi: k + 1,
                elem_lo,
                elem_hi: g.offset + g.len,
            });
            group_lo = k + 1;
            elem_lo = g.offset + g.len;
            acc = 0;
        }
    }
    spans
}

/// Mask-pass tile: small enough to stay in L1 / registers, large enough
/// to amortize the second sweep.
const TILE: usize = 256;

/// Encode a contiguous run of groups (Alg. 1) into `w`.
///
/// `r`/`v`/`gsum`/`gsumsq` cover exactly the elements of `groups`
/// (global element `i` lives at local index `i - base`); emitted wire
/// indices are global. Selection runs in two passes per tile: a
/// branchless criterion-mask pass (auto-vectorizes — no data-dependent
/// branches in the float loop), then a gather pass over the mask
/// (§Perf L3). Produces byte-for-byte the fused single-pass stream.
#[allow(clippy::too_many_arguments)]
fn encode_groups(
    groups: &[ParamGroup],
    group_index_base: usize,
    base: usize,
    r: &mut [f32],
    v: &mut [f32],
    gsum: &[f32],
    gsumsq: &[f32],
    alpha: f32,
    compact: bool,
    selected: &mut Vec<u32>,
    codes: &mut Vec<(bool, u8)>,
    compact_buf: &mut Vec<u8>,
    w: &mut ByteWriter,
) -> (EncodeStats, u32) {
    let mut stats = EncodeStats::default();
    let mut groups_sent = 0u32;
    let mut mask = [false; TILE];
    for (k, group) in groups.iter().enumerate() {
        let gi = group_index_base + k;
        let lo = group.offset - base;
        let hi = lo + group.len;

        // Pass 1+2, tiled: branchless accumulate-and-mask, then gather
        // selected indices and the group max M_k over sent values.
        selected.clear();
        let mut m_k = 0f32;
        let mut start = lo;
        while start < hi {
            let end = (start + TILE).min(hi);
            let width = end - start;
            for j in 0..width {
                let i = start + j;
                let ri = r[i] + gsum[i];
                let vi = v[i] + gsumsq[i];
                r[i] = ri;
                v[i] = vi;
                mask[j] = VgcCodec::criterion(ri, vi, alpha);
            }
            for (j, &m) in mask[..width].iter().enumerate() {
                if m {
                    let i = start + j;
                    selected.push((i + base) as u32);
                    m_k = m_k.max(r[i].abs());
                }
            }
            start = end;
        }
        if selected.is_empty() || m_k == 0.0 || !m_k.is_finite() {
            continue;
        }
        let mexp = quant4::floor_log2_exp(m_k);

        // Quantize pass: d>7 underflows are dropped and revert to
        // "unsent" (state kept); kept indices stay sorted by compacting
        // `selected` in place.
        codes.clear();
        let mut kept = 0usize;
        for si in 0..selected.len() {
            let iu = selected[si];
            let i = iu as usize - base;
            if let Some((neg, d)) = quant4::quantize(r[i], mexp) {
                selected[kept] = iu;
                kept += 1;
                codes.push((neg, d));
                // Alg. 1 sent branch: reset both accumulators.
                r[i] = 0.0;
                v[i] = 0.0;
            }
        }
        if kept == 0 {
            continue;
        }
        w.u32(gi as u32);
        w.i32(mexp);
        w.u32(kept as u32);
        if compact {
            let bits = indexcode::vgc_compact_into(&selected[..kept], codes, compact_buf)
                .expect("selected indices are sorted by construction");
            w.u32(compact_buf.len() as u32);
            w.bytes(compact_buf);
            stats.payload_bits += bits;
        } else {
            for (k2, &iu) in selected[..kept].iter().enumerate() {
                let (neg, d) = codes[k2];
                w.u32(pack_word(neg, d, iu));
            }
            stats.payload_bits += kept as u64 * 32;
        }
        stats.elements += kept as u64;
        groups_sent += 1;
    }
    (stats, groups_sent)
}

/// Stateless decode of the VGC wire format, both naive and compact
/// (also used by tests).
pub fn decode_vgc_message(
    bytes: &[u8],
    layout: &Layout,
    out: &mut [f32],
) -> anyhow::Result<()> {
    anyhow::ensure!(out.len() == layout.n(), "output length mismatch");
    let mut r = ByteReader::new(bytes);
    let head = r.u32()?;
    let compact = head & COMPACT_FLAG != 0;
    let n_groups = head & !COMPACT_FLAG;
    for _ in 0..n_groups {
        let gi = r.u32()? as usize;
        let mexp = r.i32()?;
        let count = r.u32()? as usize;
        anyhow::ensure!(gi < layout.n_groups(), "bad group index {gi}");
        let range = layout.groups()[gi].range();
        if compact {
            let byte_len = r.u32()? as usize;
            let block = r.slice(byte_len)?;
            let (indices, codes) = indexcode::vgc_compact_decode(block, count)?;
            for (&index, &(neg, d)) in indices.iter().zip(&codes) {
                let index = index as usize;
                anyhow::ensure!(
                    range.contains(&index),
                    "index {index} outside group {gi} ({range:?})"
                );
                out[index] += quant4::dequantize(neg, d, mexp);
            }
            continue;
        }
        for _ in 0..count {
            let (neg, d, index) = unpack_word(r.u32()?);
            let index = index as usize;
            anyhow::ensure!(
                range.contains(&index),
                "index {index} outside group {gi} ({range:?})"
            );
            out[index] += quant4::dequantize(neg, d, mexp);
        }
    }
    anyhow::ensure!(r.done(), "{} trailing bytes in message", r.remaining());
    Ok(())
}

/// Entry-level decode of the VGC wire format (both variants): pushes
/// exactly the contributions `decode_vgc_message` would accumulate, in
/// the same order, into a reusable [`DecodeBuf`] (the engine's parity
/// contract; zero allocations once scratch capacities converge).
pub fn decode_vgc_entries(
    bytes: &[u8],
    layout: &Layout,
    buf: &mut DecodeBuf,
) -> anyhow::Result<()> {
    anyhow::ensure!(buf.expected_len() == layout.n(), "output length mismatch");
    let mut r = ByteReader::new(bytes);
    let head = r.u32()?;
    let compact = head & COMPACT_FLAG != 0;
    let n_groups = head & !COMPACT_FLAG;
    for _ in 0..n_groups {
        let gi = r.u32()? as usize;
        let mexp = r.i32()?;
        let count = r.u32()? as usize;
        anyhow::ensure!(gi < layout.n_groups(), "bad group index {gi}");
        let range = layout.groups()[gi].range();
        if compact {
            let byte_len = r.u32()? as usize;
            let block = r.slice(byte_len)?;
            let mut idxs = std::mem::take(&mut buf.idx_scratch);
            let mut cds = std::mem::take(&mut buf.code_scratch);
            let mut res = indexcode::vgc_compact_decode_into(block, count, &mut idxs, &mut cds);
            if res.is_ok() {
                for (&index, &(neg, d)) in idxs.iter().zip(cds.iter()) {
                    let i = index as usize;
                    if !range.contains(&i) {
                        res = Err(anyhow::anyhow!(
                            "index {i} outside group {gi} ({range:?})"
                        ));
                        break;
                    }
                    buf.push(index, quant4::dequantize(neg, d, mexp));
                }
            }
            buf.idx_scratch = idxs;
            buf.code_scratch = cds;
            res?;
            continue;
        }
        for _ in 0..count {
            let (neg, d, index) = unpack_word(r.u32()?);
            let i = index as usize;
            anyhow::ensure!(
                range.contains(&i),
                "index {i} outside group {gi} ({range:?})"
            );
            buf.push(index, quant4::dequantize(neg, d, mexp));
        }
    }
    anyhow::ensure!(r.done(), "{} trailing bytes in message", r.remaining());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Message;
    use crate::testkit;
    use crate::util::rng::Pcg32;

    fn layout(n: usize) -> Layout {
        Layout::uniform(n, 7) // deliberately non-power-of-two groups
    }

    fn decode(codec: &VgcCodec, msg: &Message, n: usize) -> Vec<f32> {
        let mut out = vec![0.0; n];
        codec.decode_into(&msg.bytes, &mut out).unwrap();
        out
    }

    #[test]
    #[should_panic(expected = "zeta must be in (0, 1]")]
    fn zeta_zero_is_rejected() {
        let _ = VgcCodec::new(layout(4), 1.0, 0.0);
    }

    #[test]
    fn zeta_one_is_accepted() {
        let _ = VgcCodec::new(layout(4), 1.0, 1.0);
    }

    #[test]
    fn entry_decode_matches_dense_decode_bitwise() {
        use crate::compress::engine::DecodeBuf;
        for compact in [false, true] {
            let n = 257;
            let mut c =
                VgcCodec::new(layout(n), 1.0, 0.999).with_compact_indices(compact);
            let mut rng = Pcg32::new(11, 3);
            let g = testkit::gradient_vec(&mut rng, n);
            let msg = c.encode_step(&g, &vec![0.0; n]);
            let mut dense = vec![0.0f32; n];
            c.decode_into(&msg.bytes, &mut dense).unwrap();
            let mut buf = DecodeBuf::new();
            buf.reset(n);
            c.decode_entries(&msg.bytes, &mut buf).unwrap();
            assert!(buf.is_sorted());
            let mut replay = vec![0.0f32; n];
            buf.apply_range(0, n as u32, &mut replay);
            for i in 0..n {
                assert_eq!(dense[i].to_bits(), replay[i].to_bits(), "i={i}");
            }
        }
    }

    #[test]
    fn pooled_encode_is_byte_identical_to_serial() {
        use crate::util::threadpool::ThreadPool;
        for compact in [false, true] {
            for threads in [2usize, 3, 7] {
                let n = 533; // non-trivial group structure (groups of 7)
                let mut serial =
                    VgcCodec::new(layout(n), 1.0, 0.999).with_compact_indices(compact);
                let mut pooled =
                    VgcCodec::new(layout(n), 1.0, 0.999).with_compact_indices(compact);
                let pool = ThreadPool::new(threads);
                let mut rng = Pcg32::new(17, threads as u64);
                for _ in 0..4 {
                    let g = testkit::gradient_vec(&mut rng, n);
                    let sq: Vec<f32> = g.iter().map(|x| x * x * 0.5).collect();
                    let ms = serial.encode_step(&g, &sq);
                    let mut pb = Vec::new();
                    let st = pooled.encode_step_pooled(&g, &sq, &pool, &mut pb);
                    assert_eq!(ms.bytes, pb, "bytes diverged (threads={threads})");
                    assert_eq!(ms.elements, st.elements);
                    assert_eq!(ms.payload_bits, st.payload_bits);
                }
                assert_eq!(serial.r(), pooled.r());
                assert_eq!(serial.v(), pooled.v());
            }
        }
    }

    #[test]
    fn knob_set_to_initial_is_bit_identical() {
        // set_knob(current ζ) must leave the stream untouched — the
        // adaptive controller's "no adjustment" path is exactly static.
        let n = 257;
        let mut a = VgcCodec::new(layout(n), 1.0, 0.97);
        let mut b = VgcCodec::new(layout(n), 1.0, 0.97);
        let k = b.knob().expect("vgc is tunable");
        assert_eq!(k.name, "zeta");
        assert!(k.tighten_up);
        assert!(b.set_knob(k.value));
        let mut rng = Pcg32::new(21, 4);
        for _ in 0..5 {
            let g = testkit::gradient_vec(&mut rng, n);
            let sq: Vec<f32> = g.iter().map(|x| x * x * 0.5).collect();
            let ma = a.encode_step(&g, &sq);
            let mb = b.encode_step(&g, &sq);
            assert_eq!(ma.bytes, mb.bytes);
        }
        assert_eq!(a.v(), b.v());
    }

    #[test]
    fn ranged_knob_over_full_vector_matches_global_knob() {
        // set_knob_range(0, n, ζ') must decay byte-identically to
        // set_knob(ζ') — same f32 multiplies, different lookup path.
        let n = 533;
        let mut global = VgcCodec::new(layout(n), 1.0, 0.999);
        let mut ranged = VgcCodec::new(layout(n), 1.0, 0.999);
        assert!(global.set_knob(0.9));
        assert!(ranged.set_knob_range(0, n, 0.9));
        let mut rng = Pcg32::new(33, 7);
        for _ in 0..4 {
            let g = testkit::gradient_vec(&mut rng, n);
            let sq: Vec<f32> = g.iter().map(|x| x * x * 0.5).collect();
            let mg = global.encode_step(&g, &sq);
            let mr = ranged.encode_step(&g, &sq);
            assert_eq!(mg.bytes, mr.bytes);
        }
        for i in 0..n {
            assert_eq!(global.v()[i].to_bits(), ranged.v()[i].to_bits(), "i={i}");
        }
    }

    #[test]
    fn ranged_knob_pooled_matches_serial() {
        use crate::util::threadpool::ThreadPool;
        let n = 533;
        let mut serial = VgcCodec::new(layout(n), 1.0, 0.999);
        let mut pooled = VgcCodec::new(layout(n), 1.0, 0.999);
        // Two disjoint ranges straddling shard boundaries.
        for c in [&mut serial, &mut pooled] {
            assert!(c.set_knob_range(10, 200, 0.8));
            assert!(c.set_knob_range(300, 450, 0.95));
        }
        let pool = ThreadPool::new(3);
        let mut rng = Pcg32::new(41, 3);
        for _ in 0..4 {
            let g = testkit::gradient_vec(&mut rng, n);
            let sq: Vec<f32> = g.iter().map(|x| x * x * 0.5).collect();
            let ms = serial.encode_step(&g, &sq);
            let mut pb = Vec::new();
            pooled.encode_step_pooled(&g, &sq, &pool, &mut pb);
            assert_eq!(ms.bytes, pb);
        }
        assert_eq!(serial.v(), pooled.v());
    }

    #[test]
    fn knob_rejects_out_of_domain_values() {
        let mut c = VgcCodec::new(layout(8), 1.0, 0.999);
        assert!(!c.set_knob(0.0));
        assert!(!c.set_knob(1.5));
        assert!(!c.set_knob_range(4, 4, 0.9)); // empty range
        assert!(c.set_knob(1.0));
    }

    #[test]
    fn unambiguous_gradient_is_sent_immediately() {
        let n = 16;
        let mut c = VgcCodec::new(layout(n), 1.0, 0.999);
        // Large mean, tiny variance: passes criterion on step 1.
        let gsum = vec![1.0f32; n];
        let gsumsq = vec![1.0001f32; n]; // v ≈ r² but r² > α·v is false...
        let msg = c.encode_step(&gsum, &gsumsq);
        // r=1, v=1.0001 => 1 > 1.0001 false => nothing sent.
        assert_eq!(msg.elements, 0);
        // Second identical step: r=2, v≈2.0 decayed => 4 > 2.0 true.
        let msg2 = c.encode_step(&gsum, &gsumsq);
        assert_eq!(msg2.elements, n as u64);
    }

    #[test]
    fn ambiguous_gradient_is_delayed() {
        let n = 8;
        let mut c = VgcCodec::new(layout(n), 2.0, 0.999);
        // Mean 0.1 but huge variance: hold back.
        let gsum = vec![0.1f32; n];
        let gsumsq = vec![10.0f32; n];
        let msg = c.encode_step(&gsum, &gsumsq);
        assert_eq!(msg.elements, 0);
        assert!(c.residual_l1() > 0.0);
    }

    #[test]
    fn sent_elements_reset_state() {
        let n = 4;
        let mut c = VgcCodec::new(layout(n), 1.0, 0.999);
        let msg = c.encode_step(&[4.0; 4], &[0.5; 4]);
        assert_eq!(msg.elements, 4);
        assert!(c.r().iter().all(|&x| x == 0.0));
        assert!(c.v().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn decay_applies_only_while_unsent() {
        let n = 2;
        let mut c = VgcCodec::new(layout(n), 1.0, 0.5);
        c.encode_step(&[0.1, 0.1], &[100.0, 100.0]);
        // v = 100 * 0.5 after decay.
        assert!((c.v()[0] - 50.0).abs() < 1e-4);
        c.encode_step(&[0.1, 0.1], &[0.0, 0.0]);
        assert!((c.v()[0] - 25.0).abs() < 1e-4);
        // r accumulated, not decayed.
        assert!((c.r()[0] - 0.2).abs() < 1e-6);
    }

    #[test]
    fn decoded_update_approximates_residual_mass() {
        // When everything is sent, decode(encode(g)) ≈ g within the
        // 4-bit quantizer's [2/3, 4/3] bracket.
        testkit::for_all(
            "vgc decode bracket",
            |rng: &mut Pcg32| {
                let n = testkit::usize_in(rng, 1, 200);
                testkit::gradient_vec(rng, n)
            },
            |g| {
                let n = g.len();
                let mut c = VgcCodec::new(Layout::uniform(n, 16), 1.0, 0.999);
                // Zero variance: every nonzero element passes Eq. 3.
                let msg = c.encode_step(g, &vec![0.0; n]);
                let mut out = vec![0.0; n];
                c.decode_into(&msg.bytes, &mut out).unwrap();
                for i in 0..n {
                    if out[i] != 0.0 {
                        // Bracket: [2/3, 4/3] for rounded values, down to
                        // 1/2 for group-max truncation (M_k just under
                        // 2^(mexp+1) decodes to 2^mexp).
                        let ratio = out[i] / g[i];
                        if !(0.49..=1.34).contains(&ratio) {
                            return Err(format!(
                                "i={i}: g={} decoded={} ratio={ratio}",
                                g[i], out[i]
                            ));
                        }
                    }
                }
                let _ = msg;
                Ok(())
            },
        );
    }

    #[test]
    fn wire_format_roundtrip_and_accounting() {
        let n = 40;
        let mut c = VgcCodec::new(layout(n), 1.0, 0.999);
        let mut gsum = vec![0.0f32; n];
        for (i, g) in gsum.iter_mut().enumerate() {
            if i % 3 == 0 {
                *g = (i as f32 + 1.0) * 0.25;
            }
        }
        let msg = c.encode_step(&gsum, &vec![0.0; n]);
        assert_eq!(msg.elements, (0..n).filter(|i| i % 3 == 0).count() as u64);
        assert_eq!(msg.payload_bits, msg.elements * 32);
        // Wire bytes = payload + 4 (n_groups) + 12 per sent group.
        let out = decode(&c, &msg, n);
        for i in 0..n {
            if i % 3 == 0 {
                assert!(out[i] > 0.0, "element {i} lost");
            } else {
                assert_eq!(out[i], 0.0, "element {i} phantom");
            }
        }
    }

    #[test]
    fn empty_step_produces_empty_message() {
        let mut c = VgcCodec::new(layout(8), 1.0, 0.999);
        let msg = c.encode_step(&[0.0; 8], &[0.0; 8]);
        assert_eq!(msg.elements, 0);
        let out = decode(&c, &msg, 8);
        assert!(out.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn decode_rejects_corrupt_messages() {
        let mut c = VgcCodec::new(layout(8), 1.0, 0.999);
        let msg = c.encode_step(&[1.0; 8], &[0.0; 8]);
        let mut out = vec![0.0; 8];
        // Truncated.
        assert!(c
            .decode_into(&msg.bytes[..msg.bytes.len() - 2], &mut out)
            .is_err());
        // Out-of-group index: flip index bits of the first word.
        let mut bad = msg.bytes.clone();
        let widx = 4 + 12; // n_groups + group header
        bad[widx] = 0xFF;
        bad[widx + 1] = 0xFF;
        assert!(c.decode_into(&bad, &mut out).is_err());
    }

    #[test]
    fn alpha_controls_compression_monotonically() {
        // Larger alpha must send no more elements than smaller alpha,
        // step for step, on identical streams (paper Sec. 4.4).
        let n = 256;
        let mut rng = Pcg32::new(9, 9);
        let mut c1 = VgcCodec::new(layout(n), 1.0, 0.999);
        let mut c2 = VgcCodec::new(layout(n), 2.0, 0.999);
        let mut sent1 = 0u64;
        let mut sent2 = 0u64;
        for _ in 0..50 {
            let g: Vec<f32> = (0..n).map(|_| rng.next_normal() * 0.01).collect();
            let sq: Vec<f32> = g.iter().map(|x| x * x * 8.0).collect();
            sent1 += c1.encode_step(&g, &sq).elements;
            sent2 += c2.encode_step(&g, &sq).elements;
        }
        assert!(sent2 <= sent1, "alpha=2 sent {sent2} > alpha=1 sent {sent1}");
        assert!(sent1 > 0);
    }

    #[test]
    fn variance_criterion_matches_eq1_reduction() {
        // Appendix A: Eq. 3 with the running sums equals Eq. 1's
        // variance form. Verify numerically on random accumulations.
        testkit::for_all(
            "eq3 == eq1 (appendix A identity)",
            |rng: &mut Pcg32| {
                let b = testkit::usize_in(rng, 2, 32);
                let g: Vec<f32> = (0..b).map(|_| rng.next_normal()).collect();
                (g, testkit::f32_in(rng, 1.0, 2.0))
            },
            |(g, alpha)| {
                let b = g.len() as f64;
                let a = *alpha as f64;
                let sum: f64 = g.iter().map(|&x| x as f64).sum();
                let mean = sum / b;
                // Eq. 3 accumulators (mean over batch / squared scaled).
                let r: f64 = mean;
                let v: f64 = g.iter().map(|&x| (x as f64 / b).powi(2)).sum();
                let eq3 = r * r > a * v;
                // Eq. 1: (α'/|B|)·V_B[∇f_z] < (∇f_B)² with
                // α' = α(|B|-1)/(|B|-α) (Appendix A).
                let var: f64 = g
                    .iter()
                    .map(|&x| (x as f64 - mean).powi(2))
                    .sum::<f64>()
                    / (b - 1.0);
                if (b - a).abs() < 1e-9 {
                    return Ok(()); // α'=∞ degenerate point
                }
                let alpha_prime = a * (b - 1.0) / (b - a);
                let eq1 = (alpha_prime / b) * var < mean * mean;
                // The two are equivalent when b > α (the paper's regime).
                if b > a && eq3 != eq1 {
                    return Err(format!("eq3={eq3} eq1={eq1} b={b} α={a}"));
                }
                Ok(())
            },
        );
    }
}

#[cfg(test)]
mod compact_tests {
    use super::*;
    use crate::compress::Codec;
    use crate::testkit;
    use crate::util::rng::Pcg32;

    #[test]
    fn compact_and_naive_decode_identically() {
        // Same stream through both wire formats: decoded updates must be
        // bit-identical (the format changes bits on the wire, not math).
        testkit::for_all(
            "vgc compact == naive decode",
            |rng: &mut Pcg32| {
                let n = testkit::usize_in(rng, 1, 300);
                let steps = testkit::usize_in(rng, 1, 6);
                (0..steps)
                    .map(|_| testkit::gradient_vec(rng, n))
                    .collect::<Vec<_>>()
            },
            |stream| {
                let n = stream[0].len();
                let layout = Layout::uniform(n, 19);
                let mut naive = VgcCodec::new(layout.clone(), 1.0, 0.999);
                let mut compact =
                    VgcCodec::new(layout, 1.0, 0.999).with_compact_indices(true);
                let mut out_n = vec![0.0f32; n];
                let mut out_c = vec![0.0f32; n];
                for g in stream {
                    let sq: Vec<f32> = g.iter().map(|x| x * x * 0.3).collect();
                    let mn = naive.encode_step(g, &sq);
                    let mc = compact.encode_step(g, &sq);
                    if mn.elements != mc.elements {
                        return Err("element counts differ".into());
                    }
                    naive.decode_into(&mn.bytes, &mut out_n).map_err(|e| e.to_string())?;
                    compact
                        .decode_into(&mc.bytes, &mut out_c)
                        .map_err(|e| e.to_string())?;
                }
                if out_n == out_c {
                    Ok(())
                } else {
                    Err("decoded updates differ".into())
                }
            },
        );
    }

    #[test]
    fn compact_payload_is_smaller_at_high_sparsity() {
        // Sparse sends: gamma-coded indices must beat 32-bit words.
        let n = 100_000;
        let layout = Layout::uniform(n, 4096);
        let mut naive = VgcCodec::new(layout.clone(), 1.0, 0.999);
        let mut compact = VgcCodec::new(layout, 1.0, 0.999).with_compact_indices(true);
        // ~1% of elements unambiguous.
        let mut rng = Pcg32::new(5, 5);
        let mut g = vec![0.0f32; n];
        for x in g.iter_mut() {
            if rng.next_bool(0.01) {
                *x = 1.0 + rng.next_f32();
            }
        }
        let sq = vec![0.0f32; n];
        let mn = naive.encode_step(&g, &sq);
        let mc = compact.encode_step(&g, &sq);
        assert_eq!(mn.elements, mc.elements);
        assert!(mc.elements > 0);
        // At 1% density gaps average ~100 ⇒ γ(gap) ≈ 13 bits + 4-bit
        // code ≈ 17 vs 32: expect ≳ 1.7× payload savings.
        assert!(
            (mc.payload_bits as f64) * 1.7 < mn.payload_bits as f64,
            "compact {} vs naive {}",
            mc.payload_bits,
            mn.payload_bits
        );
        assert!((mc.bytes.len() as f64) * 1.5 < mn.bytes.len() as f64);
    }

    #[test]
    fn cross_format_decode_respects_flag() {
        // A compact message decoded by a naive-configured codec must
        // still decode correctly (the flag is in the message).
        let n = 64;
        let layout = Layout::uniform(n, 16);
        let mut compact = VgcCodec::new(layout.clone(), 1.0, 0.999).with_compact_indices(true);
        let naive = VgcCodec::new(layout, 1.0, 0.999);
        let g: Vec<f32> = (0..n).map(|i| (i as f32 + 1.0) * 0.1).collect();
        let msg = compact.encode_step(&g, &vec![0.0; n]);
        let mut out = vec![0.0f32; n];
        naive.decode_into(&msg.bytes, &mut out).unwrap();
        assert!(out.iter().filter(|&&x| x != 0.0).count() > n / 2);
    }
}
