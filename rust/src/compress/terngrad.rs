//! TernGrad (Wen et al., 2017) baseline: ternary stochastic gradients.
//!
//! Per quantization group (layer) with scaler `s_k = max|g|`, each
//! element is sent as `c_i ∈ {−1, 0, +1}` with
//! `P(|c_i| = 1) = |g_i| / s_k` (unbiased: `E[c_i·s_k] = g_i`). The wire
//! carries one f32 scaler per group plus 2 bits per element, matching
//! the paper's description of TernGrad as a 2-bit quantization method.
//!
//! Stateless across steps, like QSGD.

use super::encode::{BitReader, BitWriter, ByteReader, ByteWriter};
use super::engine::EncodeStats;
use super::{Aggregation, Codec};
use crate::model::Layout;
use crate::util::rng::Pcg32;

pub struct TernGradCodec {
    layout: Layout,
    rng: Pcg32,
    /// Reusable scratch for the packed ternary bitstream.
    packed: Vec<u8>,
}

impl TernGradCodec {
    pub fn new(layout: Layout, rng: Pcg32) -> TernGradCodec {
        TernGradCodec {
            layout,
            rng,
            packed: Vec::new(),
        }
    }
}

/// 2-bit codes: 0 = zero, 1 = +1, 2 = −1 (3 unused).
const CODE_ZERO: u32 = 0;
const CODE_POS: u32 = 1;
const CODE_NEG: u32 = 2;

impl Codec for TernGradCodec {
    fn name(&self) -> String {
        "terngrad".into()
    }

    fn aggregation(&self) -> Aggregation {
        Aggregation::Sum
    }

    fn encode_step_into(
        &mut self,
        gsum: &[f32],
        _gsumsq: &[f32],
        bytes: &mut Vec<u8>,
    ) -> EncodeStats {
        let n = self.layout.n();
        assert_eq!(gsum.len(), n);
        let mut w = ByteWriter::over(bytes);
        w.u32(self.layout.n_groups() as u32);
        let mut bits = BitWriter::over(&mut self.packed);
        let mut nonzero = 0u64;
        for group in self.layout.groups() {
            let s_k = gsum[group.range()]
                .iter()
                .fold(0f32, |a, b| a.max(b.abs()));
            w.f32(s_k);
            for &g in &gsum[group.range()] {
                let code = if s_k == 0.0 || g == 0.0 {
                    CODE_ZERO
                } else if self.rng.next_bool(g.abs() / s_k) {
                    nonzero += 1;
                    if g > 0.0 {
                        CODE_POS
                    } else {
                        CODE_NEG
                    }
                } else {
                    CODE_ZERO
                };
                bits.push(code, 2);
            }
        }
        bits.flush();
        w.u32(self.packed.len() as u32);
        w.bytes(&self.packed);
        EncodeStats {
            elements: nonzero,
            payload_bits: n as u64 * 2 + self.layout.n_groups() as u64 * 32,
        }
    }

    fn decode_into(&self, bytes: &[u8], out: &mut [f32]) -> anyhow::Result<()> {
        let n = self.layout.n();
        anyhow::ensure!(out.len() == n, "output length mismatch");
        let mut r = ByteReader::new(bytes);
        let n_groups = r.u32()? as usize;
        anyhow::ensure!(n_groups == self.layout.n_groups(), "group count mismatch");
        let mut scalers = Vec::with_capacity(n_groups);
        for _ in 0..n_groups {
            scalers.push(r.f32()?);
        }
        let packed_len = r.u32()? as usize;
        anyhow::ensure!(r.remaining() == packed_len, "packed length mismatch");
        let mut bits = BitReader::new(&bytes[bytes.len() - packed_len..]);
        for (gi, group) in self.layout.groups().iter().enumerate() {
            let s_k = scalers[gi];
            for i in group.range() {
                match bits.pull(2)? {
                    CODE_ZERO => {}
                    CODE_POS => out[i] += s_k,
                    CODE_NEG => out[i] -= s_k,
                    other => anyhow::bail!("invalid ternary code {other}"),
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn codec(n: usize, seed: u64) -> TernGradCodec {
        TernGradCodec::new(Layout::uniform(n, 16), Pcg32::new(seed, seed))
    }

    #[test]
    fn zero_roundtrip() {
        let mut c = codec(20, 0);
        let msg = c.encode_step(&[0.0; 20], &[0.0; 20]);
        let mut out = vec![0.0; 20];
        c.decode_into(&msg.bytes, &mut out).unwrap();
        assert!(out.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn group_max_always_fires() {
        // P(|c|=1) = 1 for the max element of each group.
        let mut g = vec![0.0f32; 16];
        g[3] = -2.5;
        let mut c = codec(16, 1);
        let msg = c.encode_step(&g, &[0.0; 16]);
        let mut out = vec![0.0; 16];
        c.decode_into(&msg.bytes, &mut out).unwrap();
        assert_eq!(out[3], -2.5);
        assert_eq!(msg.elements, 1);
    }

    #[test]
    fn unbiasedness() {
        let g = vec![0.5f32, -1.0, 0.25, 0.0, 0.75, -0.1, 0.9, -0.6];
        let n = g.len();
        let trials = 4000;
        let mut acc = vec![0.0f64; n];
        for t in 0..trials {
            let mut c = codec(n, t as u64 + 1);
            let msg = c.encode_step(&g, &vec![0.0; n]);
            let mut out = vec![0.0f32; n];
            c.decode_into(&msg.bytes, &mut out).unwrap();
            for i in 0..n {
                acc[i] += out[i] as f64;
            }
        }
        for i in 0..n {
            let mean = acc[i] / trials as f64;
            assert!(
                (mean - g[i] as f64).abs() < 0.05,
                "i={i}: E={mean} vs {}",
                g[i]
            );
        }
    }

    #[test]
    fn decoded_values_are_ternary_multiples() {
        let g: Vec<f32> = (0..32).map(|i| ((i * 7) % 13) as f32 * 0.1 - 0.6).collect();
        let mut c = codec(32, 5);
        let msg = c.encode_step(&g, &vec![0.0; 32]);
        let mut out = vec![0.0; 32];
        c.decode_into(&msg.bytes, &mut out).unwrap();
        let l = Layout::uniform(32, 16);
        for (gi, group) in l.groups().iter().enumerate() {
            let s_k = g[group.range()].iter().fold(0f32, |a, b| a.max(b.abs()));
            for i in group.range() {
                let ok = out[i] == 0.0 || (out[i].abs() - s_k).abs() < 1e-6;
                assert!(ok, "out[{i}]={} not in {{0, ±{s_k}}} (group {gi})", out[i]);
            }
        }
    }

    #[test]
    fn payload_is_2_bits_per_element() {
        let n = 100;
        let mut c = codec(n, 0);
        let msg = c.encode_step(&vec![0.1; n], &vec![0.0; n]);
        let n_groups = Layout::uniform(n, 16).n_groups() as u64;
        assert_eq!(msg.payload_bits, 200 + n_groups * 32);
    }
}
