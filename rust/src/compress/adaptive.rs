//! Adaptive-threshold sparsification (Dryden et al., 2016) — the
//! related-work extension of Strom's method (paper Sec. 3): instead of
//! a user-chosen τ, send a fixed *proportion* π of gradient elements
//! each step (the largest |residual| values), with error feedback.
//!
//! The per-step threshold adapts to the gradient scale, which removes
//! Strom's brittle-τ problem at the cost of a per-step selection pass.
//! Sent values are transmitted exactly (f32) alongside the index, as in
//! Dryden's design — 64 bits per element on the wire, i.e. 2 packed
//! words; `payload_bits` accounts for that honestly.
//!
//! Wire format: u32 count, then count × (u32 index, f32 value).

use super::encode::{ByteReader, ByteWriter};
use super::{Aggregation, Codec, Message};

pub struct AdaptiveCodec {
    /// Fraction of elements to send per step (e.g. 0.01).
    pi: f32,
    r: Vec<f32>,
    /// Scratch |r| for threshold selection (reused).
    mags: Vec<f32>,
}

impl AdaptiveCodec {
    pub fn new(n: usize, pi: f32) -> AdaptiveCodec {
        assert!(pi > 0.0 && pi <= 1.0, "pi must be in (0, 1]");
        AdaptiveCodec {
            pi,
            r: vec![0.0; n],
            mags: Vec::with_capacity(n),
        }
    }

    pub fn r(&self) -> &[f32] {
        &self.r
    }

    /// The adaptive threshold: the k-th largest |r| with k = ceil(π·N).
    fn threshold(&mut self) -> f32 {
        let n = self.r.len();
        let k = ((self.pi * n as f32).ceil() as usize).clamp(1, n);
        self.mags.clear();
        self.mags.extend(self.r.iter().map(|x| x.abs()));
        // select_nth_unstable puts the k-th largest at index k-1 when
        // ordering descending.
        let idx = k - 1;
        self.mags
            .select_nth_unstable_by(idx, |a, b| b.partial_cmp(a).unwrap());
        self.mags[idx]
    }
}

impl Codec for AdaptiveCodec {
    fn name(&self) -> String {
        format!("adaptive(pi={})", self.pi)
    }

    fn aggregation(&self) -> Aggregation {
        Aggregation::Sum
    }

    fn encode_step(&mut self, gsum: &[f32], _gsumsq: &[f32]) -> Message {
        let n = self.r.len();
        assert_eq!(gsum.len(), n);
        for i in 0..n {
            self.r[i] += gsum[i];
        }
        let thr = self.threshold();
        let mut w = ByteWriter::new();
        w.u32(0);
        let mut count = 0u32;
        if thr > 0.0 {
            for i in 0..n {
                if self.r[i].abs() >= thr {
                    w.u32(i as u32);
                    w.f32(self.r[i]);
                    self.r[i] = 0.0; // exact value sent: no residual left
                    count += 1;
                }
            }
        }
        let mut bytes = w.finish();
        bytes[0..4].copy_from_slice(&count.to_le_bytes());
        Message {
            bytes,
            elements: count as u64,
            payload_bits: count as u64 * 64,
        }
    }

    fn decode_into(&self, bytes: &[u8], out: &mut [f32]) -> anyhow::Result<()> {
        let mut r = ByteReader::new(bytes);
        let count = r.u32()?;
        for _ in 0..count {
            let index = r.u32()? as usize;
            let value = r.f32()?;
            anyhow::ensure!(index < out.len(), "index {index} out of range");
            out[index] += value;
        }
        anyhow::ensure!(r.done(), "trailing bytes");
        Ok(())
    }

    fn residual_l1(&self) -> f64 {
        self.r.iter().map(|x| x.abs() as f64).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;
    use crate::util::rng::Pcg32;

    #[test]
    fn sends_top_fraction_by_magnitude() {
        let n = 100;
        let mut c = AdaptiveCodec::new(n, 0.1);
        let g: Vec<f32> = (0..n).map(|i| i as f32 / 100.0).collect();
        let msg = c.encode_step(&g, &vec![0.0; n]);
        assert_eq!(msg.elements, 10);
        let mut out = vec![0.0f32; n];
        c.decode_into(&msg.bytes, &mut out).unwrap();
        // Exactly the 10 largest were delivered, exactly.
        for i in 0..n {
            if i >= 90 {
                assert_eq!(out[i], g[i]);
            } else {
                assert_eq!(out[i], 0.0);
            }
        }
    }

    #[test]
    fn exact_values_mean_exact_conservation() {
        testkit::for_all(
            "adaptive conservation",
            |rng: &mut Pcg32| {
                let n = testkit::usize_in(rng, 2, 80);
                let steps = testkit::usize_in(rng, 1, 15);
                (0..steps)
                    .map(|_| testkit::gradient_vec(rng, n))
                    .collect::<Vec<_>>()
            },
            |stream| {
                let n = stream[0].len();
                let mut c = AdaptiveCodec::new(n, 0.2);
                let mut decoded = vec![0.0f32; n];
                for g in stream {
                    let msg = c.encode_step(g, &vec![0.0; n]);
                    c.decode_into(&msg.bytes, &mut decoded)
                        .map_err(|e| e.to_string())?;
                }
                for i in 0..n {
                    let total: f32 = stream.iter().map(|g| g[i]).sum();
                    let got = decoded[i] + c.r()[i];
                    if (got - total).abs() > 1e-4 * (1.0 + total.abs()) {
                        return Err(format!("i={i}: {got} != {total}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn compression_ratio_is_one_over_pi() {
        // Steady state: elements per step ≈ π·N regardless of scale —
        // the adaptive property that fixes Strom's brittleness.
        for scale in [1e-4f32, 1.0, 1e4] {
            let n = 1000;
            let mut c = AdaptiveCodec::new(n, 0.05);
            let mut rng = Pcg32::new(7, 7);
            let mut total = 0u64;
            for _ in 0..10 {
                let g: Vec<f32> = (0..n).map(|_| rng.next_normal() * scale).collect();
                total += c.encode_step(&g, &vec![0.0; n]).elements;
            }
            let avg = total as f64 / 10.0;
            assert!(
                (45.0..=80.0).contains(&avg),
                "scale {scale}: avg sent {avg}, want ≈ 50"
            );
        }
    }

    #[test]
    fn pi_one_sends_everything_nonzero() {
        let n = 8;
        let mut c = AdaptiveCodec::new(n, 1.0);
        let g = vec![0.5f32; n];
        let msg = c.encode_step(&g, &vec![0.0; n]);
        assert_eq!(msg.elements, n as u64);
        assert_eq!(c.residual_l1(), 0.0);
    }
}
