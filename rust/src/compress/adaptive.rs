//! Adaptive-threshold sparsification (Dryden et al., 2016) — the
//! related-work extension of Strom's method (paper Sec. 3): instead of
//! a user-chosen τ, send a fixed *proportion* π of gradient elements
//! each step (the largest |residual| values), with error feedback.
//!
//! The per-step threshold adapts to the gradient scale, which removes
//! Strom's brittle-τ problem at the cost of a per-step selection pass.
//! Sent values are transmitted exactly (f32) alongside the index, as in
//! Dryden's design — 64 bits per element on the wire, i.e. 2 packed
//! words; `payload_bits` accounts for that honestly.
//!
//! Wire format: u32 count, then count × (u32 index, f32 value).

use super::encode::{ByteReader, ByteWriter};
use super::engine::{DecodeBuf, EncodeStats};
use super::{Aggregation, Codec, KnobState};
use crate::util::threadpool::{split_ranges, Task, ThreadPool};

/// Per-shard reusable encode scratch (pooled encode).
#[derive(Default)]
struct ShardScratch {
    bytes: Vec<u8>,
    count: u32,
}

pub struct AdaptiveCodec {
    /// Fraction of elements to send per step (e.g. 0.01).
    pi: f32,
    r: Vec<f32>,
    /// Scratch |r| for threshold selection (reused).
    mags: Vec<f32>,
    shards: Vec<ShardScratch>,
}

impl AdaptiveCodec {
    pub fn new(n: usize, pi: f32) -> AdaptiveCodec {
        assert!(pi > 0.0 && pi <= 1.0, "pi must be in (0, 1]");
        AdaptiveCodec {
            pi,
            r: vec![0.0; n],
            mags: Vec::with_capacity(n),
            shards: Vec::new(),
        }
    }

    pub fn r(&self) -> &[f32] {
        &self.r
    }

    /// The adaptive threshold: the k-th largest |r| with k = ceil(π·N).
    fn threshold(&mut self) -> f32 {
        let n = self.r.len();
        let k = ((self.pi * n as f32).ceil() as usize).clamp(1, n);
        self.mags.clear();
        self.mags.extend(self.r.iter().map(|x| x.abs()));
        // select_nth_unstable puts the k-th largest at index k-1 when
        // ordering descending.
        let idx = k - 1;
        self.mags
            .select_nth_unstable_by(idx, |a, b| b.partial_cmp(a).unwrap());
        self.mags[idx]
    }
}

impl Codec for AdaptiveCodec {
    fn name(&self) -> String {
        format!("adaptive(pi={})", self.pi)
    }

    fn aggregation(&self) -> Aggregation {
        Aggregation::Sum
    }

    fn encode_step_into(
        &mut self,
        gsum: &[f32],
        _gsumsq: &[f32],
        bytes: &mut Vec<u8>,
    ) -> EncodeStats {
        let n = self.r.len();
        assert_eq!(gsum.len(), n);
        for i in 0..n {
            self.r[i] += gsum[i];
        }
        let thr = self.threshold();
        let mut w = ByteWriter::over(bytes);
        w.u32(0);
        let count = if thr > 0.0 {
            emit_range(&mut self.r, thr, 0, &mut w)
        } else {
            0
        };
        w.patch_u32(0, count);
        EncodeStats {
            elements: count as u64,
            payload_bits: count as u64 * 64,
        }
    }

    fn encode_step_pooled(
        &mut self,
        gsum: &[f32],
        _gsumsq: &[f32],
        pool: &ThreadPool,
        bytes: &mut Vec<u8>,
    ) -> EncodeStats {
        if pool.threads() == 1 {
            return self.encode_step_into(gsum, _gsumsq, bytes);
        }
        let n = self.r.len();
        assert_eq!(gsum.len(), n);
        let ranges = split_ranges(n, pool.threads());
        // Phase 1: accumulate residuals, parallel over disjoint ranges.
        {
            let mut tasks: Vec<Task<'_>> = Vec::with_capacity(ranges.len());
            let mut r_rest: &mut [f32] = &mut self.r;
            for range in &ranges {
                let (r_s, r_next) = r_rest.split_at_mut(range.end - range.start);
                r_rest = r_next;
                let gs = &gsum[range.start..range.end];
                tasks.push(Box::new(move || {
                    for (x, g) in r_s.iter_mut().zip(gs) {
                        *x += g;
                    }
                }));
            }
            pool.run(tasks);
        }
        // Phase 2: the adaptive threshold needs a global order statistic
        // over |r| — stays serial (O(N) select_nth).
        let thr = self.threshold();
        // Phase 3: emit (index, value) pairs, parallel over ranges.
        while self.shards.len() < ranges.len() {
            self.shards.push(ShardScratch::default());
        }
        if thr > 0.0 {
            let mut tasks: Vec<Task<'_>> = Vec::with_capacity(ranges.len());
            let mut r_rest: &mut [f32] = &mut self.r;
            let mut shard_iter = self.shards.iter_mut();
            for range in &ranges {
                let (r_s, r_next) = r_rest.split_at_mut(range.end - range.start);
                r_rest = r_next;
                let scratch = shard_iter.next().expect("scratch sized above");
                let base = range.start;
                tasks.push(Box::new(move || {
                    scratch.bytes.clear();
                    let mut w = ByteWriter::append(&mut scratch.bytes);
                    scratch.count = emit_range(r_s, thr, base, &mut w);
                }));
            }
            pool.run(tasks);
        } else {
            for scratch in self.shards[..ranges.len()].iter_mut() {
                scratch.bytes.clear();
                scratch.count = 0;
            }
        }
        let mut w = ByteWriter::over(bytes);
        w.u32(0);
        let mut count = 0u32;
        for scratch in self.shards[..ranges.len()].iter() {
            w.bytes(&scratch.bytes);
            count += scratch.count;
        }
        w.patch_u32(0, count);
        EncodeStats {
            elements: count as u64,
            payload_bits: count as u64 * 64,
        }
    }

    fn decode_into(&self, bytes: &[u8], out: &mut [f32]) -> anyhow::Result<()> {
        let mut r = ByteReader::new(bytes);
        let count = r.u32()?;
        for _ in 0..count {
            let index = r.u32()? as usize;
            let value = r.f32()?;
            anyhow::ensure!(index < out.len(), "index {index} out of range");
            out[index] += value;
        }
        anyhow::ensure!(r.done(), "trailing bytes");
        Ok(())
    }

    fn decode_entries(&self, bytes: &[u8], buf: &mut DecodeBuf) -> anyhow::Result<()> {
        let n = buf.expected_len();
        let mut r = ByteReader::new(bytes);
        let count = r.u32()?;
        for _ in 0..count {
            let index = r.u32()?;
            let value = r.f32()?;
            anyhow::ensure!((index as usize) < n, "index {index} out of range");
            buf.push(index, value);
        }
        anyhow::ensure!(r.done(), "trailing bytes");
        Ok(())
    }

    fn residual_l1(&self) -> f64 {
        self.r.iter().map(|x| x.abs() as f64).sum()
    }

    fn knob(&self) -> Option<KnobState> {
        // Lowering π sends fewer elements ⇒ tighter compression
        // (tighten_up = false: the tighten bound is `lo`).
        Some(KnobState {
            name: "pi",
            value: self.pi,
            lo: (self.pi * 0.1).max(1e-4),
            hi: 1.0,
            tighten_up: false,
        })
    }

    fn set_knob(&mut self, value: f32) -> bool {
        if !(value > 0.0 && value <= 1.0) {
            return false;
        }
        self.pi = value;
        true
    }
}

/// Emit the (index, exact f32 value) pairs of every element at or above
/// the threshold, resetting their residuals (global element `i` = local
/// `i` + `base`). Shared by the serial and pooled paths.
fn emit_range(r: &mut [f32], thr: f32, base: usize, w: &mut ByteWriter) -> u32 {
    let mut count = 0u32;
    for (i, x) in r.iter_mut().enumerate() {
        if x.abs() >= thr {
            w.u32((i + base) as u32);
            w.f32(*x);
            *x = 0.0; // exact value sent: no residual left
            count += 1;
        }
    }
    count
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;
    use crate::util::rng::Pcg32;

    #[test]
    fn sends_top_fraction_by_magnitude() {
        let n = 100;
        let mut c = AdaptiveCodec::new(n, 0.1);
        let g: Vec<f32> = (0..n).map(|i| i as f32 / 100.0).collect();
        let msg = c.encode_step(&g, &vec![0.0; n]);
        assert_eq!(msg.elements, 10);
        let mut out = vec![0.0f32; n];
        c.decode_into(&msg.bytes, &mut out).unwrap();
        // Exactly the 10 largest were delivered, exactly.
        for i in 0..n {
            if i >= 90 {
                assert_eq!(out[i], g[i]);
            } else {
                assert_eq!(out[i], 0.0);
            }
        }
    }

    #[test]
    fn exact_values_mean_exact_conservation() {
        testkit::for_all(
            "adaptive conservation",
            |rng: &mut Pcg32| {
                let n = testkit::usize_in(rng, 2, 80);
                let steps = testkit::usize_in(rng, 1, 15);
                (0..steps)
                    .map(|_| testkit::gradient_vec(rng, n))
                    .collect::<Vec<_>>()
            },
            |stream| {
                let n = stream[0].len();
                let mut c = AdaptiveCodec::new(n, 0.2);
                let mut decoded = vec![0.0f32; n];
                for g in stream {
                    let msg = c.encode_step(g, &vec![0.0; n]);
                    c.decode_into(&msg.bytes, &mut decoded)
                        .map_err(|e| e.to_string())?;
                }
                for i in 0..n {
                    let total: f32 = stream.iter().map(|g| g[i]).sum();
                    let got = decoded[i] + c.r()[i];
                    if (got - total).abs() > 1e-4 * (1.0 + total.abs()) {
                        return Err(format!("i={i}: {got} != {total}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn compression_ratio_is_one_over_pi() {
        // Steady state: elements per step ≈ π·N regardless of scale —
        // the adaptive property that fixes Strom's brittleness.
        for scale in [1e-4f32, 1.0, 1e4] {
            let n = 1000;
            let mut c = AdaptiveCodec::new(n, 0.05);
            let mut rng = Pcg32::new(7, 7);
            let mut total = 0u64;
            for _ in 0..10 {
                let g: Vec<f32> = (0..n).map(|_| rng.next_normal() * scale).collect();
                total += c.encode_step(&g, &vec![0.0; n]).elements;
            }
            let avg = total as f64 / 10.0;
            assert!(
                (45.0..=80.0).contains(&avg),
                "scale {scale}: avg sent {avg}, want ≈ 50"
            );
        }
    }

    #[test]
    fn pi_one_sends_everything_nonzero() {
        let n = 8;
        let mut c = AdaptiveCodec::new(n, 1.0);
        let g = vec![0.5f32; n];
        let msg = c.encode_step(&g, &vec![0.0; n]);
        assert_eq!(msg.elements, n as u64);
        assert_eq!(c.residual_l1(), 0.0);
    }
}
