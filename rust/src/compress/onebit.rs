//! 1-bit SGD (Seide et al., 2014) baseline — the quantization-based
//! method the paper's related-work section leads with.
//!
//! Every gradient element is transmitted every step using one sign bit.
//! Two key techniques from the paper (Sec. 3):
//!   1. *per-column thresholds*: encode/decode use a separate
//!      reconstruction value per column of each weight matrix — we use
//!      the per-group mean of |residual+gradient| over positive and
//!      negative halves (the standard "mean of the quantized set"
//!      reconstruction), tracked per quantization group;
//!   2. *error feedback*: the quantization error is added to the next
//!      step's gradient.
//!
//! Wire format: per group, two f32 reconstruction values (µ₊, µ₋)
//! followed by a dense sign bitmap. 1 bit/element ⇒ bits-ratio ≈ 32.

use super::encode::{BitReader, BitWriter, ByteReader, ByteWriter};
use super::engine::EncodeStats;
use super::{Aggregation, Codec};
use crate::model::Layout;

pub struct OneBitCodec {
    layout: Layout,
    /// Error-feedback residual.
    e: Vec<f32>,
    /// Reusable scratch for the packed sign bitmap.
    packed: Vec<u8>,
}

impl OneBitCodec {
    pub fn new(layout: Layout) -> OneBitCodec {
        let n = layout.n();
        OneBitCodec {
            layout,
            e: vec![0.0; n],
            packed: Vec::new(),
        }
    }

    pub fn error(&self) -> &[f32] {
        &self.e
    }
}

impl Codec for OneBitCodec {
    fn name(&self) -> String {
        "onebit".into()
    }

    fn aggregation(&self) -> Aggregation {
        Aggregation::Sum
    }

    fn encode_step_into(
        &mut self,
        gsum: &[f32],
        _gsumsq: &[f32],
        bytes: &mut Vec<u8>,
    ) -> EncodeStats {
        let n = self.layout.n();
        assert_eq!(gsum.len(), n);
        let mut w = ByteWriter::over(bytes);
        w.u32(self.layout.n_groups() as u32);
        let mut bits = BitWriter::over(&mut self.packed);

        for group in self.layout.groups().iter() {
            // Corrected gradient = new gradient + carried error.
            // Reconstruction values: mean of positive / negative halves.
            let (mut pos_sum, mut pos_n, mut neg_sum, mut neg_n) = (0f64, 0u32, 0f64, 0u32);
            for i in group.range() {
                let c = gsum[i] + self.e[i];
                if c >= 0.0 {
                    pos_sum += c as f64;
                    pos_n += 1;
                } else {
                    neg_sum += c as f64;
                    neg_n += 1;
                }
            }
            let mu_pos = if pos_n > 0 { (pos_sum / pos_n as f64) as f32 } else { 0.0 };
            let mu_neg = if neg_n > 0 { (neg_sum / neg_n as f64) as f32 } else { 0.0 };
            w.f32(mu_pos);
            w.f32(mu_neg);
            for i in group.range() {
                let c = gsum[i] + self.e[i];
                let (bit, decoded) = if c >= 0.0 { (0u32, mu_pos) } else { (1u32, mu_neg) };
                bits.push(bit, 1);
                // Error feedback: carry what the sign code missed.
                self.e[i] = c - decoded;
            }
        }
        bits.flush();
        w.u32(self.packed.len() as u32);
        w.bytes(&self.packed);
        EncodeStats {
            elements: n as u64, // dense: every element is represented
            payload_bits: n as u64 + self.layout.n_groups() as u64 * 64,
        }
    }

    fn decode_into(&self, bytes: &[u8], out: &mut [f32]) -> anyhow::Result<()> {
        let n = self.layout.n();
        anyhow::ensure!(out.len() == n, "output length mismatch");
        let mut r = ByteReader::new(bytes);
        let n_groups = r.u32()? as usize;
        anyhow::ensure!(n_groups == self.layout.n_groups(), "group count mismatch");
        let mut mus = Vec::with_capacity(n_groups);
        for _ in 0..n_groups {
            let mu_pos = r.f32()?;
            let mu_neg = r.f32()?;
            mus.push((mu_pos, mu_neg));
        }
        let packed_len = r.u32()? as usize;
        anyhow::ensure!(r.remaining() == packed_len, "packed length mismatch");
        let mut bits = BitReader::new(&bytes[bytes.len() - packed_len..]);
        for (gi, group) in self.layout.groups().iter().enumerate() {
            let (mu_pos, mu_neg) = mus[gi];
            for i in group.range() {
                out[i] += if bits.pull(1)? == 0 { mu_pos } else { mu_neg };
            }
        }
        Ok(())
    }

    fn residual_l1(&self) -> f64 {
        self.e.iter().map(|x| x.abs() as f64).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;
    use crate::util::rng::Pcg32;

    fn codec(n: usize) -> OneBitCodec {
        OneBitCodec::new(Layout::uniform(n, 16))
    }

    #[test]
    fn reconstruction_preserves_group_mean() {
        // Decoded group sum equals corrected-gradient group sum exactly
        // (that is what the µ₊/µ₋ reconstruction guarantees).
        let n = 32;
        let mut c = codec(n);
        let mut rng = Pcg32::new(1, 1);
        let g = testkit::gradient_vec(&mut rng, n);
        let msg = c.encode_step(&g, &vec![0.0; n]);
        let mut out = vec![0.0f32; n];
        c.decode_into(&msg.bytes, &mut out).unwrap();
        for group in Layout::uniform(n, 16).groups() {
            let want: f32 = g[group.range()].iter().sum();
            let got: f32 = out[group.range()].iter().sum();
            assert!((want - got).abs() < 1e-4 * (1.0 + want.abs()), "{want} vs {got}");
        }
    }

    #[test]
    fn error_feedback_conserves_mass() {
        // decoded_total + residual == accumulated stream, exactly (the
        // defining property of error-feedback methods).
        testkit::for_all(
            "onebit conservation",
            |rng: &mut Pcg32| {
                let n = testkit::usize_in(rng, 1, 64);
                let steps = testkit::usize_in(rng, 1, 20);
                (0..steps)
                    .map(|_| testkit::gradient_vec(rng, n))
                    .collect::<Vec<_>>()
            },
            |stream| {
                let n = stream[0].len();
                let mut c = OneBitCodec::new(Layout::uniform(n, 8));
                let mut decoded = vec![0.0f32; n];
                for g in stream {
                    let msg = c.encode_step(g, &vec![0.0; n]);
                    c.decode_into(&msg.bytes, &mut decoded)
                        .map_err(|e| e.to_string())?;
                }
                for i in 0..n {
                    let total: f32 = stream.iter().map(|g| g[i]).sum();
                    let got = decoded[i] + c.error()[i];
                    if (got - total).abs() > 2e-3 * (1.0 + total.abs()) {
                        return Err(format!("i={i}: {got} != {total}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn one_bit_per_element_on_wire() {
        // Realistic group size so the per-group µ headers amortize.
        let n = 10_000;
        let mut c = OneBitCodec::new(Layout::uniform(n, 1024));
        let msg = c.encode_step(&vec![0.5; n], &vec![0.0; n]);
        let groups = Layout::uniform(n, 1024).n_groups() as u64;
        assert_eq!(msg.payload_bits, n as u64 + groups * 64);
        // Bits-ratio ≈ 32 (the classic 1-bit SGD headline).
        assert!(32.0 * n as f64 / msg.payload_bits as f64 > 20.0);
    }

    #[test]
    fn all_positive_group_decodes_to_mean() {
        let mut c = codec(4);
        let g = vec![1.0f32, 2.0, 3.0, 4.0];
        let msg = c.encode_step(&g, &[0.0; 4]);
        let mut out = vec![0.0f32; 4];
        c.decode_into(&msg.bytes, &mut out).unwrap();
        for &o in &out {
            assert!((o - 2.5).abs() < 1e-6);
        }
    }
}
