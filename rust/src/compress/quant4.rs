//! The paper's 4-bit sign+exponent quantizer (Sec. 4.2, Appendix B).
//!
//! For a quantization group (one weight matrix / tensor) with max
//! absolute value `M_k`, every selected element `g` is quantized to a
//! signed power of two `± 2^(mexp - d)` where `mexp = ⌊log₂ M_k⌋` and
//! `d ∈ [0, 7]` is the 3-bit exponent code:
//!
//!   1. if `|g| > 2^mexp`, truncate to `2^mexp`;
//!   2. otherwise round to the *closer* of `2^⌊log₂|g|⌋`, `2^⌈log₂|g|⌉`;
//!   3. `d = mexp − log₂ g'`; values with `d > 7` are dropped (too small
//!      relative to the group max to matter).
//!
//! Per Sec. 4.4 this is implemented purely with binary operations and
//! integer arithmetic on the IEEE-754 representation: `2^⌊log₂x⌋` is the
//! mantissa truncated to zero; round-to-closer-power is "add one to the
//! most significant bit of the mantissa, then mask the mantissa to 0"
//! (the tie `1.5·2^e` rounds up, matching the paper's running example).
//! No float ops on the encode path.

const MANTISSA_MASK: u32 = 0x007F_FFFF;
const EXP_MASK: u32 = 0x7F80_0000;
const SIGN_MASK: u32 = 0x8000_0000;
const MANTISSA_MSB: u32 = 0x0040_0000;

/// `⌊log₂ x⌋` for positive finite x, as the raw IEEE exponent (biased).
/// Subnormals collapse to biased exponent 0 (they quantize to d > 7 for
/// any realistic group max, so the inaccuracy is unobservable).
#[inline]
fn biased_floor_log2(bits: u32) -> i32 {
    ((bits & EXP_MASK) >> 23) as i32
}

/// `2^⌊log₂ x⌋` via mantissa truncation (the paper's bit trick).
#[inline]
pub fn pow2_floor(x: f32) -> f32 {
    f32::from_bits(x.to_bits() & !MANTISSA_MASK & !SIGN_MASK)
}

/// Round positive x to the closer of `2^⌊log₂x⌋` / `2^⌈log₂x⌉` via the
/// mantissa-MSB-add trick. Ties (`1.5·2^e`) round up.
#[inline]
pub fn pow2_round(x: f32) -> f32 {
    let bits = x.to_bits() & !SIGN_MASK;
    f32::from_bits((bits + MANTISSA_MSB) & !MANTISSA_MASK)
}

/// The biased-exponent form of `⌊log₂ M_k⌋` used on the wire: we send
/// the *unbiased* exponent (an i32) so the decoder is self-contained.
#[inline]
pub fn floor_log2_exp(m: f32) -> i32 {
    debug_assert!(m > 0.0 && m.is_finite());
    biased_floor_log2(m.to_bits()) - 127
}

/// Quantize one element against the group's `mexp = ⌊log₂ M_k⌋`.
///
/// Returns `Some((negative, d))` with `d ∈ [0,7]`, or `None` if the
/// element is dropped (zero, or `d > 7`).
#[inline]
pub fn quantize(g: f32, mexp: i32) -> Option<(bool, u8)> {
    if g == 0.0 || !g.is_finite() {
        return None;
    }
    let negative = g < 0.0;
    let abs_bits = g.to_bits() & !SIGN_MASK;
    // Step 1+2 fused: round to the closer power of two, then clamp to
    // 2^mexp. (For |g| > 2^mexp the clamp implements the truncation rule;
    // rounding first cannot overshoot past 2^(mexp+1) because |g| <= M_k
    // < 2^(mexp+1).)
    let rounded = (abs_bits + MANTISSA_MSB) & !MANTISSA_MASK;
    let e_unbiased = ((rounded & EXP_MASK) >> 23) as i32 - 127;
    let e = e_unbiased.min(mexp);
    let d = mexp - e;
    if d > 7 {
        return None;
    }
    Some((negative, d as u8))
}

/// Decode a (sign, d) code back to `± 2^(mexp - d)`.
#[inline]
pub fn dequantize(negative: bool, d: u8, mexp: i32) -> f32 {
    let e = mexp - d as i32;
    let v = exp2i(e);
    if negative {
        -v
    } else {
        v
    }
}

/// `2^e` for integer e, exact over the normal f32 range, 0 below it.
#[inline]
pub fn exp2i(e: i32) -> f32 {
    if e < -126 {
        // Would be subnormal; such codes cannot be produced by `quantize`
        // against any normal M_k with d <= 7 unless mexp is near the
        // bottom of the range — decode to the nearest representable.
        return f32::from_bits(1u32 << (23 + e + 149).clamp(0, 22) as u32);
    }
    if e > 127 {
        return f32::INFINITY;
    }
    f32::from_bits(((e + 127) as u32) << 23)
}

/// Relative error bound of the quantizer for kept elements in the
/// rounding regime (|g| ≤ 2^mexp): decoded/true ∈ [2/3, 4/3] (round to
/// the nearer power of two). Truncated elements (2^mexp < |g| ≤ M_k)
/// decode to exactly 2^mexp, so their ratio can reach 1/2 when M_k sits
/// just below 2^(mexp+1). Used by the conservation/bracket tests.
pub const RELATIVE_BRACKET_LO: f32 = 2.0 / 3.0;
pub const RELATIVE_BRACKET_HI: f32 = 4.0 / 3.0;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;
    use crate::util::rng::Pcg32;

    #[test]
    fn pow2_floor_matches_float_math() {
        testkit::for_all(
            "pow2_floor == 2^floor(log2 x)",
            |rng: &mut Pcg32| {
                // Positive normal floats across the whole range.
                let e = testkit::usize_in(rng, 1, 252) as u32; // biased exp, normals
                let m = rng.next_u32() & MANTISSA_MASK;
                f32::from_bits((e << 23) | m)
            },
            |&x| {
                let want = 2f32.powf(x.log2().floor());
                let got = pow2_floor(x);
                if got == want {
                    Ok(())
                } else {
                    Err(format!("x={x}: got {got}, want {want}"))
                }
            },
        );
    }

    #[test]
    fn pow2_round_picks_closer_power() {
        testkit::for_all(
            "pow2_round closer-of-two",
            |rng: &mut Pcg32| {
                let e = testkit::usize_in(rng, 10, 240) as u32;
                let m = rng.next_u32() & MANTISSA_MASK;
                f32::from_bits((e << 23) | m)
            },
            |&x| {
                let lo = pow2_floor(x);
                let hi = lo * 2.0;
                let got = pow2_round(x);
                let closer = if (x - lo) < (hi - x) { lo } else { hi };
                // Tie x == 1.5*lo rounds up — covered by the `<`.
                if got == closer {
                    Ok(())
                } else {
                    Err(format!("x={x}: got {got}, lo {lo} hi {hi}"))
                }
            },
        );
    }

    #[test]
    fn appendix_b_running_example() {
        // Paper Appendix B: elements (0.04, 0.31, -6.25, 22.25, -35.75),
        // M_k = 35.75, ⌊log2 M⌋ = 5 (2^5 = 32).
        let mexp = floor_log2_exp(35.75);
        assert_eq!(mexp, 5);
        // 0.04 -> g' = 0.03125, d = 10 > 7: dropped.
        assert_eq!(quantize(0.04, mexp), None);
        // 0.31 -> g' = 0.25, d = 7, positive.
        assert_eq!(quantize(0.31, mexp), Some((false, 7)));
        // -6.25 -> g' = 8, d = 2, negative.
        assert_eq!(quantize(-6.25, mexp), Some((true, 2)));
        // 22.25 -> g' = 16, d = 1, positive.
        assert_eq!(quantize(22.25, mexp), Some((false, 1)));
        // -35.75 -> truncated to 32, d = 0, negative.
        assert_eq!(quantize(-35.75, mexp), Some((true, 0)));
        // Decoded values.
        assert_eq!(dequantize(false, 7, mexp), 0.25);
        assert_eq!(dequantize(true, 2, mexp), -8.0);
        assert_eq!(dequantize(false, 1, mexp), 16.0);
        assert_eq!(dequantize(true, 0, mexp), -32.0);
    }

    #[test]
    fn quantize_drops_zero_and_nonfinite() {
        assert_eq!(quantize(0.0, 5), None);
        assert_eq!(quantize(-0.0, 5), None);
        assert_eq!(quantize(f32::NAN, 5), None);
        assert_eq!(quantize(f32::INFINITY, 5), None);
    }

    #[test]
    fn decode_brackets_true_value() {
        testkit::for_all(
            "decoded value within [2/3, 4/3] of true (non-truncated)",
            |rng: &mut Pcg32| {
                let g = rng.next_normal() * 10f32.powi(rng.next_bounded(7) as i32 - 3);
                (g, 8.0f32.max(g.abs() * (1.0 + rng.next_f32())))
            },
            |&(g, m)| {
                let mexp = floor_log2_exp(m);
                match quantize(g, mexp) {
                    None => Ok(()), // dropped: nothing to bracket
                    Some((neg, d)) => {
                        let dec = dequantize(neg, d, mexp);
                        if g == 0.0 {
                            return Ok(());
                        }
                        let ratio = dec / g;
                        // Truncated elements (|g| > 2^mexp) can decode
                        // below 2/3; only check the rounding regime.
                        if g.abs() <= exp2i(mexp) {
                            if ratio >= RELATIVE_BRACKET_LO - 1e-6
                                && ratio <= RELATIVE_BRACKET_HI + 1e-6
                            {
                                Ok(())
                            } else {
                                Err(format!("g={g} decoded {dec} ratio {ratio}"))
                            }
                        } else {
                            if dec.signum() == g.signum() {
                                Ok(())
                            } else {
                                Err("sign flip".into())
                            }
                        }
                    }
                }
            },
        );
    }

    #[test]
    fn d_always_in_code_range() {
        testkit::for_all(
            "d in [0,7]",
            |rng: &mut Pcg32| {
                let v = testkit::adversarial_vec(rng, 16);
                let m = v.iter().fold(1e-3f32, |a, b| a.max(b.abs()));
                (v, m)
            },
            |(v, m)| {
                if !m.is_finite() {
                    return Ok(());
                }
                let mexp = floor_log2_exp(*m);
                for &g in v {
                    if let Some((_, d)) = quantize(g, mexp) {
                        if d > 7 {
                            return Err(format!("d={d} out of range for g={g}"));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn exp2i_matches_powi() {
        for e in -126..=127 {
            assert_eq!(exp2i(e), 2f32.powi(e), "e={e}");
        }
    }

    #[test]
    fn group_max_always_encodable() {
        // The max element of a group must never be dropped (d == 0).
        testkit::for_all(
            "group max encodes with d=0",
            |rng: &mut Pcg32| {
                let mut v = testkit::gradient_vec(rng, 32);
                if v.iter().all(|x| *x == 0.0) {
                    v[0] = 1.0;
                }
                v
            },
            |v| {
                let m = v.iter().fold(0f32, |a, b| a.max(b.abs()));
                let mexp = floor_log2_exp(m);
                let &gmax = v
                    .iter()
                    .max_by(|a, b| a.abs().partial_cmp(&b.abs()).unwrap())
                    .unwrap();
                match quantize(gmax, mexp) {
                    Some((_, d)) if d <= 1 => Ok(()),
                    other => Err(format!("max {gmax} quantized to {other:?}")),
                }
            },
        );
    }
}
