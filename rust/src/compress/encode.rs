//! Wire encoding: 32-bit packed words and the byte-stream container.
//!
//! Sec. 4.2 of the paper: each sent gradient element is one 32-bit word
//! — 1 sign bit, 3 exponent bits (the quantizer's `d_i ∈ [0,7]`), and a
//! 28-bit parameter index ("a naive encoding ... because the rest
//! 28-bits are enough"). Strom-style codecs use sign + index only.
//!
//! `ByteWriter`/`ByteReader` give the codecs a common little-endian
//! message container; the communication fabric moves these bytes
//! verbatim, so what the metrics count is what would cross a real wire.

/// Max index representable in the 28-bit field (N must stay below this;
/// ResNet-50's 25.5M parameters fit with room to spare, as the paper
/// notes).
pub const MAX_INDEX: u32 = (1 << 28) - 1;

/// Pack (sign, d, index) into the paper's 32-bit word layout:
/// bit 31 = sign, bits 30..28 = d, bits 27..0 = index.
#[inline]
pub fn pack_word(negative: bool, d: u8, index: u32) -> u32 {
    debug_assert!(d < 8, "d must fit 3 bits");
    debug_assert!(index <= MAX_INDEX, "index must fit 28 bits");
    ((negative as u32) << 31) | ((d as u32) << 28) | index
}

#[inline]
pub fn unpack_word(w: u32) -> (bool, u8, u32) {
    ((w >> 31) != 0, ((w >> 28) & 0x7) as u8, w & MAX_INDEX)
}

/// Sign + index word for threshold codecs (Strom / Hybrid): bit 31 =
/// sign, bits 27..0 = index, exponent field unused (zero).
#[inline]
pub fn pack_sign_index(negative: bool, index: u32) -> u32 {
    debug_assert!(index <= MAX_INDEX);
    ((negative as u32) << 31) | index
}

#[inline]
pub fn unpack_sign_index(w: u32) -> (bool, u32) {
    ((w >> 31) != 0, w & MAX_INDEX)
}

/// Little-endian message writer over a caller-owned buffer.
///
/// The writer *borrows* its output `Vec<u8>` so codecs can reuse one
/// buffer across steps — in the steady state (capacity converged) a
/// whole encode performs zero heap allocations (§Perf L3). Start a
/// fresh message with [`ByteWriter::over`] (clears, keeps capacity) or
/// continue an existing stream with [`ByteWriter::append`] (per-shard
/// bodies concatenated by the engine).
pub struct ByteWriter<'a> {
    buf: &'a mut Vec<u8>,
}

impl<'a> ByteWriter<'a> {
    /// Begin a new message in `buf`: cleared, capacity reused.
    pub fn over(buf: &'a mut Vec<u8>) -> ByteWriter<'a> {
        buf.clear();
        ByteWriter { buf }
    }

    /// Continue writing at the end of `buf` without clearing.
    pub fn append(buf: &'a mut Vec<u8>) -> ByteWriter<'a> {
        ByteWriter { buf }
    }

    #[inline]
    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    #[inline]
    pub fn i32(&mut self, v: i32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    #[inline]
    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn bytes(&mut self, bs: &[u8]) {
        self.buf.extend_from_slice(bs);
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Overwrite 4 bytes at `pos` (header placeholders patched after the
    /// body is known — O(1), no buffer rebuild).
    pub fn patch_u32(&mut self, pos: usize, v: u32) {
        self.buf[pos..pos + 4].copy_from_slice(&v.to_le_bytes());
    }

    /// Drop everything from `pos` on (rewinds an abandoned group header).
    pub fn truncate(&mut self, pos: usize) {
        self.buf.truncate(pos);
    }
}

/// Little-endian message reader with explicit bounds errors (a malformed
/// peer message must fail loudly, never read garbage).
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        ByteReader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        anyhow::ensure!(
            self.pos + n <= self.buf.len(),
            "message truncated: need {n} bytes at {}, have {}",
            self.pos,
            self.buf.len()
        );
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u32(&mut self) -> anyhow::Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn i32(&mut self) -> anyhow::Result<i32> {
        let b = self.take(4)?;
        Ok(i32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn f32(&mut self) -> anyhow::Result<f32> {
        let b = self.take(4)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    /// Borrow the next `n` bytes and advance past them (sub-block
    /// framing, e.g. an embedded bitstream of known length).
    pub fn slice(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        self.take(n)
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn done(&self) -> bool {
        self.remaining() == 0
    }
}

/// Bit-level packer for dense sub-32-bit codes (QSGD, TernGrad, the
/// gamma index coder). Borrows its output buffer like [`ByteWriter`]
/// so hot paths can reuse one scratch `Vec<u8>` across steps.
pub struct BitWriter<'a> {
    out: &'a mut Vec<u8>,
    cur: u64,
    nbits: u32,
}

impl<'a> BitWriter<'a> {
    /// Begin a new bitstream in `out`: cleared, capacity reused.
    pub fn over(out: &'a mut Vec<u8>) -> BitWriter<'a> {
        out.clear();
        BitWriter {
            out,
            cur: 0,
            nbits: 0,
        }
    }

    /// Append the low `width` bits of `v` (LSB-first stream).
    #[inline]
    pub fn push(&mut self, v: u32, width: u32) {
        debug_assert!(width <= 32);
        self.cur |= (v as u64 & ((1u64 << width) - 1)) << self.nbits;
        self.nbits += width;
        while self.nbits >= 8 {
            self.out.push((self.cur & 0xFF) as u8);
            self.cur >>= 8;
            self.nbits -= 8;
        }
    }

    /// Flush the trailing partial byte into the buffer.
    pub fn flush(self) {
        if self.nbits > 0 {
            self.out.push((self.cur & 0xFF) as u8);
        }
    }
}

pub struct BitReader<'a> {
    buf: &'a [u8],
    pos: usize,
    cur: u64,
    nbits: u32,
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        BitReader {
            buf,
            pos: 0,
            cur: 0,
            nbits: 0,
        }
    }

    /// Read `width` bits (LSB-first). Errors on underrun.
    #[inline]
    pub fn pull(&mut self, width: u32) -> anyhow::Result<u32> {
        while self.nbits < width {
            anyhow::ensure!(self.pos < self.buf.len(), "bitstream underrun");
            self.cur |= (self.buf[self.pos] as u64) << self.nbits;
            self.pos += 1;
            self.nbits += 8;
        }
        let v = (self.cur & ((1u64 << width) - 1)) as u32;
        self.cur >>= width;
        self.nbits -= width;
        Ok(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;
    use crate::util::rng::Pcg32;

    #[test]
    fn word_roundtrip_exhaustive_fields() {
        for neg in [false, true] {
            for d in 0..8u8 {
                for index in [0u32, 1, 12345, MAX_INDEX] {
                    let w = pack_word(neg, d, index);
                    assert_eq!(unpack_word(w), (neg, d, index));
                }
            }
        }
    }

    #[test]
    fn sign_index_roundtrip() {
        for neg in [false, true] {
            for index in [0u32, 7, MAX_INDEX] {
                assert_eq!(unpack_sign_index(pack_sign_index(neg, index)), (neg, index));
            }
        }
    }

    #[test]
    fn word_roundtrip_property() {
        testkit::for_all(
            "pack/unpack word",
            |rng: &mut Pcg32| {
                (
                    rng.next_bool(0.5),
                    (rng.next_bounded(8)) as u8,
                    rng.next_bounded(MAX_INDEX + 1),
                )
            },
            |&(neg, d, idx)| {
                if unpack_word(pack_word(neg, d, idx)) == (neg, d, idx) {
                    Ok(())
                } else {
                    Err("mismatch".into())
                }
            },
        );
    }

    #[test]
    fn byte_stream_roundtrip() {
        let mut bytes = Vec::new();
        let mut w = ByteWriter::over(&mut bytes);
        w.u32(0xDEADBEEF);
        w.f32(-1.5);
        w.i32(-42);
        assert_eq!(bytes.len(), 12);
        let mut r = ByteReader::new(&bytes);
        assert_eq!(r.u32().unwrap(), 0xDEADBEEF);
        assert_eq!(r.f32().unwrap(), -1.5);
        assert_eq!(r.i32().unwrap(), -42);
        assert!(r.done());
    }

    #[test]
    fn byte_writer_reuses_capacity_across_messages() {
        let mut bytes = Vec::new();
        {
            let mut w = ByteWriter::over(&mut bytes);
            for i in 0..100u32 {
                w.u32(i);
            }
        }
        let cap = bytes.capacity();
        {
            let mut w = ByteWriter::over(&mut bytes);
            w.u32(7);
            w.patch_u32(0, 9);
        }
        assert_eq!(bytes.capacity(), cap, "over() must keep capacity");
        assert_eq!(bytes, 9u32.to_le_bytes());
        {
            let mut w = ByteWriter::append(&mut bytes);
            w.u32(1);
        }
        assert_eq!(bytes.len(), 8, "append() must not clear");
    }

    #[test]
    fn reader_rejects_truncation() {
        let mut r = ByteReader::new(&[1, 2, 3]);
        assert!(r.u32().is_err());
    }

    #[test]
    fn bit_packing_roundtrip() {
        let mut bytes = Vec::new();
        let mut w = BitWriter::over(&mut bytes);
        let vals: Vec<(u32, u32)> =
            vec![(0b1, 1), (0b10, 2), (0b101, 3), (0xFF, 8), (0x3FFFF, 18), (0, 5)];
        for &(v, width) in &vals {
            w.push(v, width);
        }
        w.flush();
        let mut r = BitReader::new(&bytes);
        for &(v, width) in &vals {
            assert_eq!(r.pull(width).unwrap(), v);
        }
    }

    #[test]
    fn bit_packing_property() {
        testkit::for_all(
            "bit writer/reader",
            |rng: &mut Pcg32| {
                let n = testkit::usize_in(rng, 0, 200);
                (0..n)
                    .map(|_| {
                        let width = 1 + rng.next_bounded(32);
                        (rng.next_u32() & ((1u64 << width) - 1) as u32, width)
                    })
                    .collect::<Vec<(u32, u32)>>()
            },
            |vals| {
                let mut bytes = Vec::new();
                let mut w = BitWriter::over(&mut bytes);
                for &(v, width) in vals {
                    w.push(v, width);
                }
                w.flush();
                let mut r = BitReader::new(&bytes);
                for &(v, width) in vals {
                    if r.pull(width).map_err(|e| e.to_string())? != v {
                        return Err("value mismatch".into());
                    }
                }
                Ok(())
            },
        );
    }
}
