//! Parallel sharded codec engine — the L3 wire path's execution layer
//! (§Perf).
//!
//! The paper's Sec. 5 cost model only wins when L3 encode/decode stays
//! negligible next to CalcGrad; Agarwal et al. show codec overhead
//! routinely erases the communication savings in practice. This module
//! therefore runs the codecs *in parallel* while guaranteeing the wire
//! bytes and decoded updates stay **bit-identical** to the serial path
//! (the trainer's `verify_sync` invariant must keep holding at any
//! thread count):
//!
//! * **Encode** fans out across workers (each worker's codec is an
//!   independent state machine) and, when there are more threads than
//!   workers, across *group-aligned shards* inside one codec via
//!   [`Codec::encode_step_pooled`] — shard byte streams concatenate in
//!   group order, reproducing the serial message exactly.
//! * **Decode** runs in two phases. Phase 1 parses each gathered
//!   message once into a reusable [`DecodeBuf`] of `(index, value)`
//!   contribution entries (parallel across messages). Phase 2 reduces
//!   the buffers into the output vector in parallel across *disjoint
//!   index ranges*; within each range contributions apply in message
//!   order, so every output element sees the exact f32 addition
//!   sequence of the serial `decode_into` loop — bit-identical, with
//!   no cross-thread reduction tree to perturb rounding.
//!
//! All buffers (message bytes, entry buffers, codec scratch) are
//! engine- or codec-owned and reused, so once capacities converge a
//! steady-state step performs zero heap allocations in the codec
//! kernels; the scoped thread fan-out itself costs O(threads) small
//! allocations per phase (see `util::threadpool`).
//!
//! The parity contract, runnable:
//!
//! ```
//! use vgc::compress::{Codec, CodecEngine, CodecSpec};
//! use vgc::model::Layout;
//!
//! let layout = Layout::uniform(512, 128);
//! let spec = CodecSpec::Vgc { alpha: 2.0, zeta: 0.999 };
//! let grad: Vec<f32> = (0..512).map(|i| (i as f32 * 0.37).sin()).collect();
//! let sq: Vec<f32> = grad.iter().map(|x| x * x * 0.5).collect();
//!
//! // The serial reference message…
//! let mut serial = spec.build(&layout, 0);
//! let want = serial.encode_step(&grad, &sq).bytes;
//!
//! // …and the engine's shard-parallel encode: bit-identical.
//! let mut pooled = spec.build(&layout, 0);
//! let mut engine = CodecEngine::new(4);
//! let mut codecs: Vec<&mut dyn Codec> = vec![&mut *pooled];
//! engine.encode_all(&mut codecs, &[grad.as_slice()], &[sq.as_slice()]);
//! assert_eq!(engine.messages()[0], want);
//!
//! // Decoding the gathered messages overwrites the update vector,
//! // bit-identical to the serial decode loop.
//! let mut update = vec![0.0f32; 512];
//! engine.decode_all(&*serial, &[want.clone()], &mut update).unwrap();
//! ```

use crate::util::threadpool::{Task, ThreadPool};

use super::Codec;

/// Per-message accounting produced by the encode kernels (the byte
/// stream itself lands in a caller-provided buffer).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct EncodeStats {
    /// Gradient elements represented (compression-ratio denominator).
    pub elements: u64,
    /// Exact payload bits, excluding container headers.
    pub payload_bits: u64,
}

impl EncodeStats {
    /// Effective wire gain of this step's message: dense f32 bits of
    /// the full gradient over the payload bits actually sent. This is
    /// the per-step feedback signal the adaptive controller consumes
    /// (`n` is the gradient dimension; an empty message reports the
    /// full dense gain rather than dividing by zero).
    pub fn gain(&self, n: usize) -> f64 {
        let dense_bits = n as u64 * 32;
        dense_bits as f64 / self.payload_bits.max(1) as f64
    }
}

/// Reusable decoded-message buffer: the `(index, value)` contribution
/// entries of one wire message, in message order.
///
/// Sparse codecs push only their sent elements; dense codecs push every
/// element. [`DecodeBuf::apply_range`] replays a sub-range of the
/// contributions onto an output slice, preserving the serial
/// accumulation order per index.
pub struct DecodeBuf {
    expected: usize,
    idx: Vec<u32>,
    val: Vec<f32>,
    sorted: bool,
    last: i64,
    /// Dense decode scratch for the default (dense) `decode_entries`.
    dense: Vec<f32>,
    /// Scratch for codecs that stage decoded blocks (compact VGC).
    pub idx_scratch: Vec<u32>,
    pub code_scratch: Vec<(bool, u8)>,
}

impl Default for DecodeBuf {
    fn default() -> Self {
        DecodeBuf::new()
    }
}

impl DecodeBuf {
    pub fn new() -> DecodeBuf {
        DecodeBuf {
            expected: 0,
            idx: Vec::new(),
            val: Vec::new(),
            sorted: true,
            last: -1,
            dense: Vec::new(),
            idx_scratch: Vec::new(),
            code_scratch: Vec::new(),
        }
    }

    /// Clear entries (capacity kept) and record the decode target length
    /// `n`; every pushed index must be `< n`.
    pub fn reset(&mut self, expected_len: usize) {
        self.expected = expected_len;
        self.idx.clear();
        self.val.clear();
        self.sorted = true;
        self.last = -1;
    }

    /// The output-vector length this buffer decodes against.
    pub fn expected_len(&self) -> usize {
        self.expected
    }

    /// Append one contribution. Monotonicity is tracked so the apply
    /// pass can binary-search sorted streams (every in-tree encoder
    /// emits ascending indices) while staying correct for arbitrary
    /// well-formed messages.
    #[inline]
    pub fn push(&mut self, index: u32, value: f32) {
        if (index as i64) < self.last {
            self.sorted = false;
        }
        self.last = index as i64;
        self.idx.push(index);
        self.val.push(value);
    }

    pub fn len(&self) -> usize {
        self.idx.len()
    }

    pub fn is_empty(&self) -> bool {
        self.idx.is_empty()
    }

    pub fn is_sorted(&self) -> bool {
        self.sorted
    }

    /// Move the dense scratch out (and back) around a `decode_into`
    /// call — lets the default dense `decode_entries` borrow both the
    /// scratch and the entry vectors without aliasing.
    pub fn take_dense(&mut self) -> Vec<f32> {
        std::mem::take(&mut self.dense)
    }

    pub fn return_dense(&mut self, dense: Vec<f32>) {
        self.dense = dense;
    }

    /// Replay the contributions whose index falls in `[lo, hi)` onto
    /// `out` (which covers exactly that index range), in entry order.
    pub fn apply_range(&self, lo: u32, hi: u32, out: &mut [f32]) {
        debug_assert_eq!((hi - lo) as usize, out.len());
        if self.sorted {
            let start = self.idx.partition_point(|&i| i < lo);
            let end = self.idx.partition_point(|&i| i < hi);
            for k in start..end {
                out[(self.idx[k] - lo) as usize] += self.val[k];
            }
        } else {
            for k in 0..self.idx.len() {
                let i = self.idx[k];
                if i >= lo && i < hi {
                    out[(i - lo) as usize] += self.val[k];
                }
            }
        }
    }
}

/// One engine shared across concurrent jobs (the service daemon's
/// mode): callers lock for the span of a whole encode→gather→decode
/// step so a job's three phases run against a consistent buffer set.
pub type SharedEngine = std::sync::Arc<std::sync::Mutex<CodecEngine>>;

/// Build a [`SharedEngine`] of the given width.
pub fn shared_engine(threads: usize) -> SharedEngine {
    std::sync::Arc::new(std::sync::Mutex::new(CodecEngine::new(threads)))
}

/// The engine: a thread pool plus reusable per-worker buffers.
pub struct CodecEngine {
    pool: ThreadPool,
    msg_bufs: Vec<Vec<u8>>,
    stats: Vec<EncodeStats>,
    dec_bufs: Vec<DecodeBuf>,
    n_msgs: usize,
}

impl CodecEngine {
    /// `threads == 1` reproduces the serial path exactly (no spawns).
    pub fn new(threads: usize) -> CodecEngine {
        CodecEngine {
            pool: ThreadPool::new(threads),
            msg_bufs: Vec::new(),
            stats: Vec::new(),
            dec_bufs: Vec::new(),
            n_msgs: 0,
        }
    }

    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    /// Encode every worker's step message into the engine's reusable
    /// buffers. `codecs[w]` ingests `gsums[w]`/`gsumsqs[w]`; results are
    /// exposed via [`CodecEngine::messages`] / [`CodecEngine::stats`].
    ///
    /// Strategy: workers fan out across threads when there are at least
    /// as many workers as threads; otherwise each worker encodes with
    /// its codec's shard-parallel kernel. Both produce bytes identical
    /// to the serial `encode_step_into`.
    pub fn encode_all(
        &mut self,
        codecs: &mut [&mut dyn Codec],
        gsums: &[&[f32]],
        gsumsqs: &[&[f32]],
    ) {
        let p = codecs.len();
        assert_eq!(gsums.len(), p, "one gsum slice per worker");
        assert_eq!(gsumsqs.len(), p, "one gsumsq slice per worker");
        while self.msg_bufs.len() < p {
            self.msg_bufs.push(Vec::new());
        }
        while self.stats.len() < p {
            self.stats.push(EncodeStats::default());
        }
        self.n_msgs = p;
        let t = self.pool.threads();
        if t == 1 {
            for w in 0..p {
                self.stats[w] =
                    codecs[w].encode_step_into(gsums[w], gsumsqs[w], &mut self.msg_bufs[w]);
            }
        } else if p >= t {
            let ck = p.div_ceil(t);
            let bufs = &mut self.msg_bufs[..p];
            let stats = &mut self.stats[..p];
            let mut tasks: Vec<Task<'_>> = Vec::with_capacity(t);
            let iter = codecs
                .chunks_mut(ck)
                .zip(bufs.chunks_mut(ck))
                .zip(stats.chunks_mut(ck))
                .zip(gsums.chunks(ck))
                .zip(gsumsqs.chunks(ck));
            for ((((cs, bs), sts), gs), qs) in iter {
                tasks.push(Box::new(move || {
                    for i in 0..cs.len() {
                        sts[i] = cs[i].encode_step_into(gs[i], qs[i], &mut bs[i]);
                    }
                }));
            }
            self.pool.run(tasks);
        } else {
            for w in 0..p {
                self.stats[w] = codecs[w].encode_step_pooled(
                    gsums[w],
                    gsumsqs[w],
                    &self.pool,
                    &mut self.msg_bufs[w],
                );
            }
        }
    }

    /// The messages produced by the last [`CodecEngine::encode_all`].
    pub fn messages(&self) -> &[Vec<u8>] {
        &self.msg_bufs[..self.n_msgs]
    }

    /// Per-worker accounting for the last [`CodecEngine::encode_all`].
    pub fn stats(&self) -> &[EncodeStats] {
        &self.stats[..self.n_msgs]
    }

    /// Decode the gathered messages and *overwrite* `out` with their
    /// accumulated update — bit-identical to zeroing `out` and running
    /// the serial `decode_into` loop over `msgs` in order.
    pub fn decode_all(
        &mut self,
        codec: &dyn Codec,
        msgs: &[Vec<u8>],
        out: &mut [f32],
    ) -> anyhow::Result<()> {
        let p = msgs.len();
        let n = out.len();
        let t = self.pool.threads();
        if t == 1 {
            for x in out.iter_mut() {
                *x = 0.0;
            }
            for m in msgs {
                codec.decode_into(m, out)?;
            }
            return Ok(());
        }
        while self.dec_bufs.len() < p {
            self.dec_bufs.push(DecodeBuf::new());
        }
        // Phase 1: parse every message into its entry buffer, in
        // parallel across messages.
        let mut results: Vec<anyhow::Result<()>> = (0..p).map(|_| Ok(())).collect();
        {
            let ck = p.div_ceil(t).max(1);
            let mut tasks: Vec<Task<'_>> = Vec::with_capacity(t);
            let iter = self.dec_bufs[..p]
                .chunks_mut(ck)
                .zip(msgs.chunks(ck))
                .zip(results.chunks_mut(ck));
            for ((bufs, ms), rs) in iter {
                tasks.push(Box::new(move || {
                    for i in 0..bufs.len() {
                        bufs[i].reset(n);
                        rs[i] = codec.decode_entries(&ms[i], &mut bufs[i]);
                    }
                }));
            }
            self.pool.run(tasks);
        }
        for r in results {
            r?;
        }
        // Phase 2: reduce into disjoint output ranges; each range
        // applies contributions in message order (serial f32 order).
        {
            let bufs = &self.dec_bufs[..p];
            let ck = n.div_ceil(t).max(1);
            let mut tasks: Vec<Task<'_>> = Vec::with_capacity(t);
            let mut lo = 0usize;
            for chunk in out.chunks_mut(ck) {
                let hi = lo + chunk.len();
                let (lo32, hi32) = (lo as u32, hi as u32);
                tasks.push(Box::new(move || {
                    for x in chunk.iter_mut() {
                        *x = 0.0;
                    }
                    for b in bufs {
                        b.apply_range(lo32, hi32, chunk);
                    }
                }));
                lo = hi;
            }
            self.pool.run(tasks);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_buf_tracks_sortedness() {
        let mut b = DecodeBuf::new();
        b.reset(10);
        b.push(1, 1.0);
        b.push(5, 2.0);
        assert!(b.is_sorted());
        assert_eq!(b.len(), 2);
        b.push(3, 3.0);
        assert!(!b.is_sorted());
        b.reset(10);
        assert!(b.is_sorted());
        assert!(b.is_empty());
    }

    #[test]
    fn apply_range_matches_serial_accumulation_order() {
        // Two messages touching overlapping indices: the chunked apply
        // must reproduce the serial per-index addition sequence exactly.
        let n = 8usize;
        let mut b1 = DecodeBuf::new();
        b1.reset(n);
        let mut b2 = DecodeBuf::new();
        b2.reset(n);
        for i in 0..n as u32 {
            b1.push(i, 0.1 + i as f32);
        }
        for i in (0..n as u32).step_by(2) {
            b2.push(i, 1e-8);
        }
        // Serial reference.
        let mut serial = vec![0.0f32; n];
        for (b, _) in [(&b1, 0), (&b2, 1)] {
            for k in 0..b.len() {
                serial[b.idx[k] as usize] += b.val[k];
            }
        }
        // Chunked apply over 3 uneven ranges.
        let mut out = vec![0.0f32; n];
        for (lo, hi) in [(0u32, 3u32), (3, 4), (4, 8)] {
            let chunk = &mut out[lo as usize..hi as usize];
            b1.apply_range(lo, hi, chunk);
            b2.apply_range(lo, hi, chunk);
        }
        for i in 0..n {
            assert_eq!(serial[i].to_bits(), out[i].to_bits(), "i={i}");
        }
    }

    #[test]
    fn unsorted_buffer_still_applies_correctly() {
        let mut b = DecodeBuf::new();
        b.reset(4);
        b.push(3, 1.0);
        b.push(0, 2.0);
        b.push(3, 4.0);
        assert!(!b.is_sorted());
        let mut out = vec![0.0f32; 4];
        b.apply_range(0, 4, &mut out);
        assert_eq!(out, vec![2.0, 0.0, 0.0, 5.0]);
    }
}
