//! QSGD (Alistarh et al., 2017) baseline: bucketed stochastic
//! quantization.
//!
//! Gradients are split into buckets of `d` consecutive elements; each
//! bucket is scaled by its L2 norm and every element is stochastically
//! rounded to one of `s = 2^bits − 1` uniform levels in `[0, 1]`
//! (unbiased: `E[decode] = g`). Following the paper's experimental
//! setup we use two's-complement codes of `bits` magnitude bits plus
//! the sign ("the number of bits ... except for the sign bits"), i.e.
//! `bits + 1` bits per element on the wire plus one f32 norm per
//! bucket.
//!
//! Stateless across steps (QSGD has no residual; its unbiasedness is
//! the convergence argument).

use super::encode::{BitReader, BitWriter, ByteReader, ByteWriter};
use super::engine::EncodeStats;
use super::{Aggregation, Codec};
use crate::util::rng::Pcg32;

pub struct QsgdCodec {
    n: usize,
    bits: u32,
    bucket: usize,
    rng: Pcg32,
    /// Reusable scratch for the packed code bitstream.
    packed: Vec<u8>,
}

impl QsgdCodec {
    pub fn new(n: usize, bits: u32, bucket: usize, rng: Pcg32) -> QsgdCodec {
        assert!((1..=8).contains(&bits), "bits must be in 1..=8");
        assert!(bucket > 0);
        QsgdCodec {
            n,
            bits,
            bucket,
            rng,
            packed: Vec::new(),
        }
    }

    /// Quantization levels `s = 2^bits − 1`.
    pub fn levels(&self) -> u32 {
        (1u32 << self.bits) - 1
    }

    fn code_width(&self) -> u32 {
        self.bits + 1 // magnitude bits + sign bit
    }
}

impl Codec for QsgdCodec {
    fn name(&self) -> String {
        format!("qsgd(bits={},d={})", self.bits, self.bucket)
    }

    fn aggregation(&self) -> Aggregation {
        Aggregation::Sum
    }

    fn encode_step_into(
        &mut self,
        gsum: &[f32],
        _gsumsq: &[f32],
        bytes: &mut Vec<u8>,
    ) -> EncodeStats {
        assert_eq!(gsum.len(), self.n);
        let s = self.levels() as f32;
        let levels = self.levels();
        let width = self.bits;
        let bucket = self.bucket;
        let n = self.n;
        let mut w = ByteWriter::over(bytes);
        let n_buckets = n.div_ceil(bucket);
        w.u32(n_buckets as u32);
        // Norms land contiguously in the byte stream; codes go to the
        // reusable packed bitstream appended after them.
        let mut bitw = BitWriter::over(&mut self.packed);
        let mut nonzero = 0u64;
        for b in 0..n_buckets {
            let range = b * bucket..((b + 1) * bucket).min(n);
            let norm: f32 = gsum[range.clone()]
                .iter()
                .map(|x| x * x)
                .sum::<f32>()
                .sqrt();
            w.f32(norm);
            for &g in &gsum[range] {
                let (sign, level) = if norm == 0.0 || g == 0.0 {
                    (false, 0u32)
                } else {
                    let x = g.abs() / norm * s; // in [0, s]
                    let lo = x.floor();
                    let frac = x - lo;
                    let level = lo as u32 + self.rng.next_bool(frac) as u32;
                    (g < 0.0, level.min(levels))
                };
                if level > 0 {
                    nonzero += 1;
                }
                bitw.push(sign as u32, 1);
                bitw.push(level, width);
            }
        }
        bitw.flush();
        w.u32(self.packed.len() as u32);
        w.bytes(&self.packed);
        EncodeStats {
            // Ratio accounting: QSGD is dense; the honest element count
            // is the nonzeros (zero codes carry no gradient), which is
            // how the paper's QSGD rows land between pure-quantization
            // and sparsification ratios.
            elements: nonzero,
            payload_bits: n as u64 * self.code_width() as u64 + n_buckets as u64 * 32,
        }
    }

    fn decode_into(&self, bytes: &[u8], out: &mut [f32]) -> anyhow::Result<()> {
        anyhow::ensure!(out.len() == self.n, "output length mismatch");
        let s = self.levels() as f32;
        let mut r = ByteReader::new(bytes);
        let n_buckets = r.u32()? as usize;
        anyhow::ensure!(
            n_buckets == self.n.div_ceil(self.bucket),
            "bucket count mismatch"
        );
        let mut norms = Vec::with_capacity(n_buckets);
        for _ in 0..n_buckets {
            norms.push(r.f32()?);
        }
        let packed_len = r.u32()? as usize;
        anyhow::ensure!(r.remaining() == packed_len, "packed length mismatch");
        let mut bits = BitReader::new(&bytes[bytes.len() - packed_len..]);
        for (i, o) in out.iter_mut().enumerate() {
            let sign = bits.pull(1)? != 0;
            let level = bits.pull(self.bits)? as f32;
            let norm = norms[i / self.bucket];
            let v = norm * level / s;
            *o += if sign { -v } else { v };
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;

    fn codec(n: usize, bits: u32, bucket: usize, seed: u64) -> QsgdCodec {
        QsgdCodec::new(n, bits, bucket, Pcg32::new(seed, seed))
    }

    #[test]
    fn zero_gradient_roundtrips_to_zero() {
        let mut c = codec(10, 2, 4, 0);
        let msg = c.encode_step(&[0.0; 10], &[0.0; 10]);
        let mut out = vec![0.0; 10];
        c.decode_into(&msg.bytes, &mut out).unwrap();
        assert!(out.iter().all(|&x| x == 0.0));
        assert_eq!(msg.elements, 0);
    }

    #[test]
    fn decode_error_bounded_by_bucket_norm() {
        testkit::for_all(
            "qsgd per-element error <= norm/s",
            |rng| {
                let n = testkit::usize_in(rng, 1, 300);
                (testkit::gradient_vec(rng, n), testkit::usize_in(rng, 1, 64))
            },
            |(g, bucket)| {
                let n = g.len();
                let mut c = codec(n, 3, *bucket, 7);
                let msg = c.encode_step(g, &vec![0.0; n]);
                let mut out = vec![0.0; n];
                c.decode_into(&msg.bytes, &mut out).map_err(|e| e.to_string())?;
                let s = c.levels() as f32;
                for i in 0..n {
                    let b = i / bucket;
                    let range = b * bucket..((b + 1) * bucket).min(n);
                    let norm: f32 =
                        g[range].iter().map(|x| x * x).sum::<f32>().sqrt();
                    if (out[i] - g[i]).abs() > norm / s + 1e-6 {
                        return Err(format!(
                            "i={i}: |{} - {}| > {}",
                            out[i],
                            g[i],
                            norm / s
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn stochastic_rounding_is_unbiased() {
        // E[decode] == g: average many independent encodings.
        let g = vec![0.3f32, -0.7, 0.05, 0.0, 0.9, -0.2, 0.6, -0.45];
        let n = g.len();
        let trials = 4000;
        let mut acc = vec![0.0f64; n];
        for t in 0..trials {
            let mut c = codec(n, 2, 4, t as u64 + 1);
            let msg = c.encode_step(&g, &vec![0.0; n]);
            let mut out = vec![0.0f32; n];
            c.decode_into(&msg.bytes, &mut out).unwrap();
            for i in 0..n {
                acc[i] += out[i] as f64;
            }
        }
        for i in 0..n {
            let mean = acc[i] / trials as f64;
            assert!(
                (mean - g[i] as f64).abs() < 0.02,
                "i={i}: E[decode]={mean} vs g={}",
                g[i]
            );
        }
    }

    #[test]
    fn payload_bits_match_formula() {
        let n = 100;
        let mut c = codec(n, 2, 32, 0);
        let msg = c.encode_step(&vec![0.5; n], &vec![0.0; n]);
        let n_buckets = n.div_ceil(32) as u64;
        assert_eq!(msg.payload_bits, n as u64 * 3 + n_buckets * 32);
    }

    #[test]
    fn ragged_final_bucket() {
        let n = 10; // bucket 4 -> buckets of 4,4,2
        let g: Vec<f32> = (0..n).map(|i| (i as f32 - 5.0) * 0.1).collect();
        let mut c = codec(n, 4, 4, 3);
        let msg = c.encode_step(&g, &vec![0.0; n]);
        let mut out = vec![0.0; n];
        c.decode_into(&msg.bytes, &mut out).unwrap();
        // With 15 levels the reconstruction is close.
        for i in 0..n {
            assert!((out[i] - g[i]).abs() < 0.15, "i={i}");
        }
    }
}
