//! No-compression baseline: the raw batch-mean gradient as f32.
//!
//! This is the paper's "no compression" row: every parameter is "sent"
//! every step, 32 bits each, compression ratio 1.

use super::encode::{ByteReader, ByteWriter};
use super::engine::EncodeStats;
use super::{Aggregation, Codec};

pub struct NoCompression {
    n: usize,
}

impl NoCompression {
    pub fn new(n: usize) -> NoCompression {
        NoCompression { n }
    }
}

impl Codec for NoCompression {
    fn name(&self) -> String {
        "none".into()
    }

    fn aggregation(&self) -> Aggregation {
        Aggregation::Sum
    }

    fn encode_step_into(
        &mut self,
        gsum: &[f32],
        _gsumsq: &[f32],
        bytes: &mut Vec<u8>,
    ) -> EncodeStats {
        assert_eq!(gsum.len(), self.n);
        let mut w = ByteWriter::over(bytes);
        for &g in gsum {
            w.f32(g);
        }
        EncodeStats {
            elements: self.n as u64,
            payload_bits: self.n as u64 * 32,
        }
    }

    fn decode_into(&self, bytes: &[u8], out: &mut [f32]) -> anyhow::Result<()> {
        anyhow::ensure!(out.len() == self.n, "output length mismatch");
        anyhow::ensure!(
            bytes.len() == 4 * self.n,
            "raw message has {} bytes, expected {}",
            bytes.len(),
            4 * self.n
        );
        let mut r = ByteReader::new(bytes);
        for o in out.iter_mut() {
            *o += r.f32()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_is_exact() {
        let g = vec![1.5f32, -2.25, 0.0, 3.125e-7];
        let mut c = NoCompression::new(4);
        let msg = c.encode_step(&g, &[0.0; 4]);
        assert_eq!(msg.elements, 4);
        assert_eq!(msg.wire_bits(), 128);
        let mut out = vec![0.0f32; 4];
        c.decode_into(&msg.bytes, &mut out).unwrap();
        assert_eq!(out, g);
    }

    #[test]
    fn decode_accumulates() {
        let g = vec![1.0f32, 2.0];
        let mut c = NoCompression::new(2);
        let msg = c.encode_step(&g, &[0.0; 2]);
        let mut out = vec![10.0f32, 20.0];
        c.decode_into(&msg.bytes, &mut out).unwrap();
        assert_eq!(out, vec![11.0, 22.0]);
    }

    #[test]
    fn rejects_wrong_size() {
        let c = NoCompression::new(4);
        let mut out = vec![0.0f32; 4];
        assert!(c.decode_into(&[0u8; 12], &mut out).is_err());
    }
}
