//! Algorithm 2 — the hybrid of variance-based compression and Strom's
//! threshold method.
//!
//! An element is sent only when BOTH hold: `|r_i| > τ` (Strom) and
//! `r_i² > α·v_i` (variance criterion). What is sent is `Sign(r_i)·τ`
//! (one sign+index word); the residual keeps the remainder
//! (`r_i -= Sign(r_i)·τ`). Because only *part* of the accumulated
//! gradient leaves, the squared-sum state must be corrected rather than
//! reset: the paper modifies `a² → (a−b)²`, i.e.
//! `v_i ← max(v_i − 2|r_i|τ + τ², 0)` — note the paper's listing applies
//! this with the *already-decremented* `r_i`, which is what we do —
//! followed by the usual ζ decay (applied to every element in Alg. 2).
//!
//! Wire format: identical to Strom (u32 count + sign/index words); τ is
//! codec config.

use super::encode::{pack_sign_index, unpack_sign_index, ByteReader, ByteWriter};
use super::engine::{DecodeBuf, EncodeStats};
use super::{Aggregation, Codec, KnobState};
use crate::model::Layout;
use crate::util::threadpool::{split_ranges, Task, ThreadPool};

/// Per-shard reusable encode scratch (pooled encode).
#[derive(Default)]
struct ShardScratch {
    bytes: Vec<u8>,
    count: u32,
}

pub struct HybridCodec {
    layout: Layout,
    tau: f32,
    alpha: f32,
    zeta: f32,
    r: Vec<f32>,
    v: Vec<f32>,
    shards: Vec<ShardScratch>,
}

impl HybridCodec {
    pub fn new(layout: Layout, tau: f32, alpha: f32, zeta: f32) -> HybridCodec {
        assert!(tau > 0.0, "tau must be positive");
        assert!(alpha > 0.0, "alpha must be positive");
        assert!(zeta > 0.0 && zeta <= 1.0, "zeta must be in (0, 1]");
        let n = layout.n();
        HybridCodec {
            layout,
            tau,
            alpha,
            zeta,
            r: vec![0.0; n],
            v: vec![0.0; n],
            shards: Vec::new(),
        }
    }

    pub fn r(&self) -> &[f32] {
        &self.r
    }

    pub fn v(&self) -> &[f32] {
        &self.v
    }
}

impl Codec for HybridCodec {
    fn name(&self) -> String {
        format!(
            "hybrid(tau={},alpha={},zeta={})",
            self.tau, self.alpha, self.zeta
        )
    }

    fn aggregation(&self) -> Aggregation {
        Aggregation::Sum
    }

    fn encode_step_into(
        &mut self,
        gsum: &[f32],
        gsumsq: &[f32],
        bytes: &mut Vec<u8>,
    ) -> EncodeStats {
        let n = self.layout.n();
        assert_eq!(gsum.len(), n);
        assert_eq!(gsumsq.len(), n);
        let mut w = ByteWriter::over(bytes);
        w.u32(0);
        let count = encode_range(
            &mut self.r,
            &mut self.v,
            gsum,
            gsumsq,
            self.tau,
            self.alpha,
            self.zeta,
            0,
            &mut w,
        );
        w.patch_u32(0, count);
        EncodeStats {
            payload_bits: count as u64 * 32,
            elements: count as u64,
        }
    }

    fn encode_step_pooled(
        &mut self,
        gsum: &[f32],
        gsumsq: &[f32],
        pool: &ThreadPool,
        bytes: &mut Vec<u8>,
    ) -> EncodeStats {
        if pool.threads() == 1 {
            return self.encode_step_into(gsum, gsumsq, bytes);
        }
        let n = self.layout.n();
        assert_eq!(gsum.len(), n);
        assert_eq!(gsumsq.len(), n);
        let ranges = split_ranges(n, pool.threads());
        while self.shards.len() < ranges.len() {
            self.shards.push(ShardScratch::default());
        }
        let (tau, alpha, zeta) = (self.tau, self.alpha, self.zeta);
        let mut tasks: Vec<Task<'_>> = Vec::with_capacity(ranges.len());
        let mut r_rest: &mut [f32] = &mut self.r;
        let mut v_rest: &mut [f32] = &mut self.v;
        let mut shard_iter = self.shards.iter_mut();
        for range in &ranges {
            let len = range.end - range.start;
            let (r_s, r_next) = r_rest.split_at_mut(len);
            let (v_s, v_next) = v_rest.split_at_mut(len);
            r_rest = r_next;
            v_rest = v_next;
            let scratch = shard_iter.next().expect("scratch sized above");
            let gs = &gsum[range.start..range.end];
            let qs = &gsumsq[range.start..range.end];
            let base = range.start;
            tasks.push(Box::new(move || {
                scratch.bytes.clear();
                let mut w = ByteWriter::append(&mut scratch.bytes);
                scratch.count = encode_range(r_s, v_s, gs, qs, tau, alpha, zeta, base, &mut w);
            }));
        }
        pool.run(tasks);
        let mut w = ByteWriter::over(bytes);
        w.u32(0);
        let mut count = 0u32;
        for scratch in self.shards[..ranges.len()].iter() {
            w.bytes(&scratch.bytes);
            count += scratch.count;
        }
        w.patch_u32(0, count);
        EncodeStats {
            payload_bits: count as u64 * 32,
            elements: count as u64,
        }
    }

    fn decode_into(&self, bytes: &[u8], out: &mut [f32]) -> anyhow::Result<()> {
        let mut r = ByteReader::new(bytes);
        let count = r.u32()?;
        for _ in 0..count {
            let (neg, index) = unpack_sign_index(r.u32()?);
            let index = index as usize;
            anyhow::ensure!(index < out.len(), "index {index} out of range");
            out[index] += if neg { -self.tau } else { self.tau };
        }
        anyhow::ensure!(r.done(), "trailing bytes");
        Ok(())
    }

    fn decode_entries(&self, bytes: &[u8], buf: &mut DecodeBuf) -> anyhow::Result<()> {
        let n = buf.expected_len();
        let mut r = ByteReader::new(bytes);
        let count = r.u32()?;
        for _ in 0..count {
            let (neg, index) = unpack_sign_index(r.u32()?);
            anyhow::ensure!((index as usize) < n, "index {index} out of range");
            buf.push(index, if neg { -self.tau } else { self.tau });
        }
        anyhow::ensure!(r.done(), "trailing bytes");
        Ok(())
    }

    fn residual_l1(&self) -> f64 {
        self.r.iter().map(|x| x.abs() as f64).sum()
    }

    fn knob(&self) -> Option<KnobState> {
        // ζ scalar only: the Alg.-2 kernel decays v elementwise inside
        // the send loop, so a per-range lookup there would cost the hot
        // path — set_knob_range stays unsupported (returns false) and
        // the controller falls back to the comm-weighted scalar.
        Some(KnobState {
            name: "zeta",
            value: self.zeta,
            lo: self.zeta.min(0.5).max(1e-3),
            hi: 1.0,
            tighten_up: true,
        })
    }

    fn set_knob(&mut self, value: f32) -> bool {
        if !(value > 0.0 && value <= 1.0) {
            return false;
        }
        self.zeta = value;
        true
    }
}

/// The Alg.-2 kernel over one contiguous shard (global element `i` =
/// local `i` + `base`). Emits sign+index words in ascending index
/// order; shared by the serial and pooled paths.
#[allow(clippy::too_many_arguments)]
fn encode_range(
    r: &mut [f32],
    v: &mut [f32],
    gsum: &[f32],
    gsumsq: &[f32],
    tau: f32,
    alpha: f32,
    zeta: f32,
    base: usize,
    w: &mut ByteWriter,
) -> u32 {
    let mut count = 0u32;
    for i in 0..r.len() {
        r[i] += gsum[i];
        v[i] += gsumsq[i];
        if r[i].abs() > tau && r[i] * r[i] > alpha * v[i] {
            let neg = r[i] < 0.0;
            w.u32(pack_sign_index(neg, (i + base) as u32));
            count += 1;
            // Alg. 2: r_i -= Sign(r_i)·τ, then the variance
            // correction with the decremented r_i.
            r[i] -= if neg { -tau } else { tau };
            v[i] = (v[i] - 2.0 * r[i].abs() * tau + tau * tau).max(0.0);
        }
        // Alg. 2 decays v unconditionally (outside the if).
        v[i] *= zeta;
    }
    count
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;
    use crate::util::rng::Pcg32;

    fn codec(n: usize, tau: f32, alpha: f32) -> HybridCodec {
        HybridCodec::new(Layout::uniform(n, 8), tau, alpha, 0.999)
    }

    #[test]
    #[should_panic(expected = "zeta must be in (0, 1]")]
    fn zeta_zero_is_rejected() {
        let _ = HybridCodec::new(Layout::uniform(4, 2), 0.1, 1.0, 0.0);
    }

    #[test]
    fn pooled_encode_is_byte_identical_to_serial() {
        use crate::util::threadpool::ThreadPool;
        let n = 301;
        let mut serial = codec(n, 0.02, 1.5);
        let mut pooled = codec(n, 0.02, 1.5);
        let pool = ThreadPool::new(3);
        let mut rng = Pcg32::new(23, 1);
        for _ in 0..5 {
            let g = testkit::gradient_vec(&mut rng, n);
            let sq: Vec<f32> = g.iter().map(|x| x * x).collect();
            let ms = serial.encode_step(&g, &sq);
            let mut pb = Vec::new();
            let st = pooled.encode_step_pooled(&g, &sq, &pool, &mut pb);
            assert_eq!(ms.bytes, pb);
            assert_eq!(ms.elements, st.elements);
        }
        assert_eq!(serial.r(), pooled.r());
        assert_eq!(serial.v(), pooled.v());
    }

    #[test]
    fn requires_both_criteria() {
        // |r| > τ but high variance: held back.
        let mut c = codec(1, 0.5, 1.0);
        assert_eq!(c.encode_step(&[1.0], &[100.0]).elements, 0);
        // Low variance but |r| <= τ: held back.
        let mut c = codec(1, 0.5, 1.0);
        assert_eq!(c.encode_step(&[0.3], &[0.0]).elements, 0);
        // Both: sent.
        let mut c = codec(1, 0.5, 1.0);
        assert_eq!(c.encode_step(&[1.0], &[0.0]).elements, 1);
    }

    #[test]
    fn sends_tau_quantum_and_keeps_remainder() {
        let mut c = codec(2, 0.25, 1.0);
        let msg = c.encode_step(&[1.0, -1.0], &[0.0, 0.0]);
        assert_eq!(msg.elements, 2);
        let mut out = vec![0.0; 2];
        c.decode_into(&msg.bytes, &mut out).unwrap();
        assert_eq!(out, vec![0.25, -0.25]);
        assert!((c.r()[0] - 0.75).abs() < 1e-6);
        assert!((c.r()[1] + 0.75).abs() < 1e-6);
    }

    #[test]
    fn variance_correction_reduces_v() {
        let mut c = codec(1, 0.5, 1.0);
        // r=2, v=1: sent. After: r=1.5, v = max(1 - 2*1.5*0.5 + 0.25, 0)
        //   = max(-0.25, 0) = 0, then ζ decay (still 0).
        c.encode_step(&[2.0], &[1.0]);
        assert_eq!(c.v()[0], 0.0);
        assert!((c.r()[0] - 1.5).abs() < 1e-6);
    }

    #[test]
    fn v_never_negative() {
        testkit::for_all(
            "hybrid v >= 0",
            |rng: &mut Pcg32| {
                let n = testkit::usize_in(rng, 1, 32);
                let steps = testkit::usize_in(rng, 1, 20);
                let stream: Vec<(Vec<f32>, Vec<f32>)> = (0..steps)
                    .map(|_| {
                        let g = testkit::gradient_vec(rng, n);
                        let sq: Vec<f32> = g.iter().map(|x| x * x).collect();
                        (g, sq)
                    })
                    .collect();
                (testkit::f32_in(rng, 0.001, 0.2), stream)
            },
            |(tau, stream)| {
                let n = stream[0].0.len();
                let mut c = HybridCodec::new(Layout::uniform(n, 8), *tau, 1.5, 0.999);
                for (g, sq) in stream {
                    c.encode_step(g, sq);
                    if c.v().iter().any(|&v| v < 0.0) {
                        return Err("negative v".into());
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn sign_flip_suppression() {
        // The paper's Sec. 6.1 hypothesis: after sending +τ, if following
        // gradients flip sign, the variance criterion holds the residual
        // back (unlike plain Strom which keeps draining +τ).
        let mut hybrid = codec(1, 0.1, 1.0);
        let mut strom = super::super::strom::StromCodec::new(1, 0.1);
        // Step 1: strong positive.
        hybrid.encode_step(&[1.0], &[0.01]);
        strom.encode_step(&[1.0], &[0.01]);
        // Steps 2-4: noisy negatives with high variance.
        let mut hybrid_sent = 0;
        let mut strom_sent = 0;
        for _ in 0..3 {
            hybrid_sent += hybrid.encode_step(&[-0.05], &[4.0]).elements;
            strom_sent += strom.encode_step(&[-0.05], &[4.0]).elements;
        }
        // Strom keeps draining its stale positive residual; hybrid stops.
        assert_eq!(hybrid_sent, 0, "hybrid must hold ambiguous residual");
        assert_eq!(strom_sent, 3, "strom drains regardless");
    }
}
