//! Closed-loop per-bucket compression controller (ROADMAP item 2).
//!
//! The paper fixes the variance-decay ζ for the whole run, but the
//! right compression level depends on where a gradient travels:
//! a bucket whose bytes cross a 10:1-oversubscribed hier uplink should
//! tighten while intra-rack buckets relax (GraVAC / Accordion tune the
//! factor online from similar signals — see PAPERS.md).
//!
//! [`KnobController`] closes the loop over the signals the stack
//! already produces: per-bucket comm time from the overlap schedule
//! ([`crate::comm::pipeline::OverlapSchedule`]), link-class byte
//! shares from [`crate::fabric::FabricTelemetry`], and the codec's
//! wire gain ([`super::engine::EncodeStats::gain`]).
//!
//! Control law (deterministic, replayable):
//!
//! ```text
//! pressure_k = comm_k / (cpu / K) · (1 + w_up · uplink_frac) · class_k
//! err_k      = pressure_k − target
//! |err_k| ≤ hysteresis            → hold (dead band)
//! else  u_k += rate · sign(err_k) · min(|err_k|, 1) + dither
//! u_k ∈ [0, 1];  knob_k = KnobState::at_tightness(initial, u_k)
//! ```
//!
//! `u_k = 0` maps to the codec's *initial* knob value, so a controller
//! that never sees pressure above target leaves the run bit-identical
//! to static. The dither is a tiny seeded Pcg32 perturbation (≤ rate/8)
//! that breaks plateau lock-step between buckets; same seed + same
//! telemetry sequence ⇒ same knob trajectory (property-tested).

use super::KnobState;
use crate::util::rng::Pcg32;

/// Tightening stops once the measured wire gain exceeds this ceiling —
/// past ~4096× the payload is a handful of elements and further
/// starvation only hurts convergence.
pub const GAIN_CEILING: f64 = 4096.0;

/// Controller tuning; all fields have conservative defaults.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ControllerConfig {
    /// Pressure target: 1.0 = each bucket's comm exactly fills its
    /// fair share of the compute budget (fully hidden overlap).
    pub target: f64,
    /// Max |Δu| per observation (bounded step size).
    pub rate: f32,
    /// Dead band around `target` — no adjustment inside it.
    pub hysteresis: f64,
    /// Extra pressure per unit of uplink byte fraction (hier fabrics:
    /// bytes crossing slow leader↔leader links count double at 1.0).
    pub uplink_weight: f64,
    /// Seed for the dither stream (replayable).
    pub seed: u64,
}

impl Default for ControllerConfig {
    fn default() -> ControllerConfig {
        ControllerConfig {
            target: 1.0,
            rate: 0.05,
            hysteresis: 0.15,
            uplink_weight: 1.0,
            seed: 0xADA9,
        }
    }
}

/// One knob adjustment decided by [`KnobController::observe`].
///
/// `lo..hi` is the bucket's global element range: apply with
/// [`super::Codec::set_knob_range`] when the codec supports ranged
/// knobs, else fall back to a scalar [`super::Codec::set_knob`] with
/// the comm-share-weighted mean of the per-bucket values.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KnobUpdate {
    pub bucket: usize,
    pub lo: usize,
    pub hi: usize,
    pub name: &'static str,
    pub value: f32,
    /// Tightness coordinate u ∈ [0, 1] after the step.
    pub tightness: f32,
}

/// Deterministic per-bucket feedback controller over one codec knob.
pub struct KnobController {
    cfg: ControllerConfig,
    knob: KnobState,
    initial: f32,
    /// Per-bucket global element ranges (from `form_buckets`).
    buckets: Vec<(usize, usize)>,
    /// Per-bucket link-class pressure multiplier (default 1.0).
    class: Vec<f64>,
    /// Per-bucket tightness coordinate u ∈ [0, 1].
    u: Vec<f32>,
    rng: Pcg32,
}

impl KnobController {
    /// `knob` is the codec's initial [`KnobState`] (u = 0 anchor);
    /// `buckets` are the global element ranges of the overlap buckets.
    pub fn new(
        cfg: ControllerConfig,
        knob: KnobState,
        buckets: Vec<(usize, usize)>,
    ) -> KnobController {
        let n = buckets.len();
        let rng = Pcg32::new(cfg.seed ^ 0xADA7_717E, 0x17);
        KnobController {
            cfg,
            initial: knob.value,
            knob,
            buckets,
            class: vec![1.0; n],
            u: vec![0.0; n],
            rng,
        }
    }

    /// Per-link-class override: multiply bucket `b`'s pressure by `w`
    /// (e.g. > 1 for buckets whose bytes are uplink-heavy on a hier
    /// fabric). Out-of-range buckets are ignored.
    pub fn set_class_weight(&mut self, bucket: usize, w: f64) {
        if let Some(c) = self.class.get_mut(bucket) {
            *c = w.max(0.0);
        }
    }

    /// Current per-bucket tightness coordinates.
    pub fn tightness(&self) -> &[f32] {
        &self.u
    }

    /// The knob name being driven ("zeta", "pi", "tau").
    pub fn knob_name(&self) -> &'static str {
        self.knob.name
    }

    /// Comm-share-weighted scalar knob value — the fallback for codecs
    /// without ranged knobs (weights = last observed comm share).
    pub fn scalar_value(&self, bucket_comm_ps: &[u64]) -> f32 {
        let total: u64 = bucket_comm_ps.iter().sum();
        if total == 0 || self.u.is_empty() {
            return self.knob.at_tightness(self.initial, mean(&self.u));
        }
        let mut acc = 0.0f64;
        for (b, &u) in self.u.iter().enumerate() {
            let w = bucket_comm_ps.get(b).copied().unwrap_or(0) as f64 / total as f64;
            acc += w * self.knob.at_tightness(self.initial, u) as f64;
        }
        acc as f32
    }

    /// Feed one step's telemetry; returns the knob adjustments (empty
    /// when every bucket is inside the dead band or already clamped).
    ///
    /// * `bucket_comm_ps` — per-bucket comm time (overlap schedule)
    /// * `cpu_ps` — the step's compute budget (grad + encode time)
    /// * `uplink_frac` — fraction of wire bytes on slow-class links
    /// * `gain` — measured wire gain this step (dense bits / payload)
    pub fn observe(
        &mut self,
        bucket_comm_ps: &[u64],
        cpu_ps: u64,
        uplink_frac: f64,
        gain: f64,
    ) -> Vec<KnobUpdate> {
        let k = self.buckets.len();
        if k == 0 {
            return Vec::new();
        }
        let fair = (cpu_ps.max(1) as f64 / k as f64).max(1.0);
        let up = 1.0 + self.cfg.uplink_weight * uplink_frac.clamp(0.0, 1.0);
        let mut out = Vec::new();
        for b in 0..k {
            let comm = bucket_comm_ps.get(b).copied().unwrap_or(0) as f64;
            let pressure = comm / fair * up * self.class[b];
            let err = pressure - self.cfg.target;
            if err.abs() <= self.cfg.hysteresis {
                continue; // dead band
            }
            if err > 0.0 && gain >= GAIN_CEILING {
                continue; // already compressing to the bone
            }
            let step = self.cfg.rate as f64 * err.signum() * err.abs().min(1.0);
            let dither = (self.rng.next_f32() as f64 - 0.5) * self.cfg.rate as f64 * 0.25;
            let next = ((self.u[b] as f64 + step + dither).clamp(0.0, 1.0)) as f32;
            if next == self.u[b] {
                continue; // clamped — nothing to report
            }
            self.u[b] = next;
            let (lo, hi) = self.buckets[b];
            out.push(KnobUpdate {
                bucket: b,
                lo,
                hi,
                name: self.knob.name,
                value: self.knob.at_tightness(self.initial, next),
                tightness: next,
            });
        }
        out
    }
}

fn mean(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f32>() / xs.len() as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn zeta_knob() -> KnobState {
        KnobState {
            name: "zeta",
            value: 0.95,
            lo: 0.5,
            hi: 1.0,
            tighten_up: true,
        }
    }

    fn two_buckets() -> Vec<(usize, usize)> {
        vec![(0, 512), (512, 1024)]
    }

    #[test]
    fn dead_band_holds_static() {
        let mut c = KnobController::new(ControllerConfig::default(), zeta_knob(), two_buckets());
        // pressure exactly on target for both buckets: cpu=2000, fair
        // share 1000 each, comm 1000 each ⇒ err 0.
        for _ in 0..20 {
            let ups = c.observe(&[1000, 1000], 2000, 0.0, 10.0);
            assert!(ups.is_empty());
        }
        assert_eq!(c.tightness(), &[0.0, 0.0]);
    }

    #[test]
    fn underloaded_bucket_stays_at_initial() {
        // comm far below target relaxes — but u is already clamped at
        // 0, so the knob never moves off the static value.
        let mut c = KnobController::new(ControllerConfig::default(), zeta_knob(), two_buckets());
        for _ in 0..10 {
            let ups = c.observe(&[10, 10], 100_000, 0.0, 10.0);
            assert!(ups.is_empty());
        }
        assert_eq!(c.tightness(), &[0.0, 0.0]);
    }

    #[test]
    fn overloaded_bucket_tightens_toward_bound() {
        let mut c = KnobController::new(ControllerConfig::default(), zeta_knob(), two_buckets());
        let mut last = 0.95f32;
        for _ in 0..100 {
            // bucket 0 comm-bound (5× fair share), bucket 1 idle.
            for up in c.observe(&[5000, 0], 2000, 0.0, 10.0) {
                assert_eq!(up.bucket, 0);
                assert_eq!(up.name, "zeta");
                assert!(up.value >= last - 0.02, "tightening must be monotone-ish");
                last = up.value;
            }
        }
        assert!(c.tightness()[0] > 0.5, "u0 = {}", c.tightness()[0]);
        assert_eq!(c.tightness()[1], 0.0);
        assert!(last > 0.95 && last <= 1.0);
    }

    #[test]
    fn gain_ceiling_stops_tightening() {
        let mut c = KnobController::new(ControllerConfig::default(), zeta_knob(), two_buckets());
        let ups = c.observe(&[5000, 5000], 2000, 0.0, GAIN_CEILING + 1.0);
        assert!(ups.is_empty());
    }

    #[test]
    fn uplink_fraction_amplifies_pressure() {
        let cfg = ControllerConfig::default();
        let mut flat = KnobController::new(cfg, zeta_knob(), two_buckets());
        let mut hier = KnobController::new(cfg, zeta_knob(), two_buckets());
        for _ in 0..50 {
            flat.observe(&[1200, 1200], 2000, 0.0, 10.0);
            hier.observe(&[1200, 1200], 2000, 0.8, 10.0);
        }
        assert!(
            hier.tightness()[0] > flat.tightness()[0],
            "uplink-heavy run must tighten harder: {} vs {}",
            hier.tightness()[0],
            flat.tightness()[0]
        );
    }

    #[test]
    fn class_weight_tightens_one_bucket_independently() {
        let mut c = KnobController::new(ControllerConfig::default(), zeta_knob(), two_buckets());
        c.set_class_weight(1, 4.0);
        for _ in 0..30 {
            c.observe(&[900, 900], 2000, 0.0, 10.0);
        }
        // Equal comm, but bucket 1's class multiplier pushes it over
        // target while bucket 0 stays inside the dead band.
        assert_eq!(c.tightness()[0], 0.0);
        assert!(c.tightness()[1] > 0.2);
    }

    #[test]
    fn replay_is_deterministic() {
        let cfg = ControllerConfig {
            seed: 77,
            ..ControllerConfig::default()
        };
        let mut a = KnobController::new(cfg, zeta_knob(), two_buckets());
        let mut b = KnobController::new(cfg, zeta_knob(), two_buckets());
        let telemetry: Vec<(Vec<u64>, u64, f64)> = (0..40)
            .map(|i| {
                let c0 = 500 + (i * 137) % 3000;
                let c1 = 200 + (i * 211) % 2500;
                (vec![c0, c1], 2000, (i % 5) as f64 / 5.0)
            })
            .collect();
        for (comm, cpu, up) in &telemetry {
            let ua = a.observe(comm, *cpu, *up, 20.0);
            let ub = b.observe(comm, *cpu, *up, 20.0);
            assert_eq!(ua, ub);
        }
        assert_eq!(a.tightness(), b.tightness());
    }

    #[test]
    fn scalar_fallback_is_comm_weighted() {
        let mut c = KnobController::new(ControllerConfig::default(), zeta_knob(), two_buckets());
        for _ in 0..60 {
            c.observe(&[5000, 0], 2000, 0.0, 10.0);
        }
        // All weight on the tightened bucket ⇒ scalar ≈ its value.
        let s = c.scalar_value(&[5000, 0]);
        let b0 = zeta_knob().at_tightness(0.95, c.tightness()[0]);
        assert!((s - b0).abs() < 1e-6, "s={s} b0={b0}");
    }
}
