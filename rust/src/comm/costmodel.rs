//! Section-5 communication cost model.
//!
//! The paper analyzes iteration speedup analytically:
//!
//! * ring allreduce of uncompressed gradients:
//!   `T_r = 2(p−1)·N·s·β / p`
//! * pipelined ring allgatherv (Träff et al. 2008) with block size m:
//!   `T_v ≤ (Σ_i n_i + (p−1)·m)·β`, with `Σ n_i = N·s·p/c` for average
//!   compression ratio c
//! * hence relative speedup `T_r/T_v ≥ 2(p−1)c / p²` (small m), giving
//!   linear speedup in the `c > p/2` regime.
//!
//! This module reproduces those formulas exactly (experiment A5) and
//! also evaluates `T_v` from *measured* per-node message sizes, which is
//! how the training harness converts its byte accounting into modeled
//! iteration times.

/// Link/interconnect parameters. `beta` is transfer time per BIT.
#[derive(Debug, Clone, Copy)]
pub struct LinkModel {
    /// Seconds per bit (e.g. 1GbE: 1e-9 s/bit).
    pub beta: f64,
    /// Per-message latency in seconds (ignored by the paper for large N;
    /// kept so the harness can show when that assumption breaks).
    pub latency: f64,
}

impl LinkModel {
    /// 1000BASE-T Ethernet — the paper's "commodity interconnect".
    pub fn gige() -> LinkModel {
        LinkModel {
            beta: 1e-9,
            latency: 50e-6,
        }
    }

    /// InfiniBand-class link (the "order of magnitude more expensive"
    /// comparison point; ~100 Gb/s).
    pub fn infiniband() -> LinkModel {
        LinkModel {
            beta: 1e-11,
            latency: 2e-6,
        }
    }
}

/// Fixed experiment geometry for the analytic formulas.
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    /// Workers.
    pub p: usize,
    /// Parameters.
    pub n: u64,
    /// Bits per parameter in the uncompressed baseline (32).
    pub s: u64,
    /// Pipelining block size in bits (m in the paper).
    pub m_bits: u64,
    pub link: LinkModel,
}

impl CostModel {
    pub fn new(p: usize, n: u64, link: LinkModel) -> CostModel {
        CostModel {
            p,
            n,
            s: 32,
            // MVAPICH-style pipelining block: 8 KiB.
            m_bits: 8 * 1024 * 8,
            link,
        }
    }

    /// `T_r`: ring allreduce time for the uncompressed gradient.
    pub fn t_allreduce(&self) -> f64 {
        let p = self.p as f64;
        2.0 * (p - 1.0) * (self.n * self.s) as f64 * self.link.beta / p
            + 2.0 * (p - 1.0) * self.link.latency
    }

    /// `T_v` upper bound from the average compression ratio c
    /// (`Σ n_i = N·s·p/c`).
    pub fn t_allgatherv_ratio(&self, c: f64) -> f64 {
        assert!(c > 0.0);
        let total_bits = (self.n * self.s) as f64 * self.p as f64 / c;
        self.t_allgatherv_bits(&vec![
            (total_bits / self.p as f64) as u64;
            self.p
        ])
    }

    /// `T_v` from measured per-node message sizes (bits):
    /// `T_v ≤ (Σ n_i + (p−1) m)·β` plus per-round latency.
    pub fn t_allgatherv_bits(&self, n_i_bits: &[u64]) -> f64 {
        assert_eq!(n_i_bits.len(), self.p);
        let sum_bits: u64 = n_i_bits.iter().sum();
        (sum_bits as f64 + (self.p as f64 - 1.0) * self.m_bits as f64) * self.link.beta
            + (self.p as f64 - 1.0) * self.link.latency
    }

    /// Relative speedup of compressed allgatherv over allreduce.
    pub fn speedup(&self, c: f64) -> f64 {
        self.t_allreduce() / self.t_allgatherv_ratio(c)
    }

    /// The paper's closed-form lower bound `2(p−1)c/p²` (latency and m
    /// ignored) — tests check `speedup ≥ bound` in the regime the paper
    /// assumes (latency ≈ 0).
    pub fn speedup_lower_bound(&self, c: f64) -> f64 {
        let p = self.p as f64;
        2.0 * (p - 1.0) * c / (p * p)
    }

    /// Compute time for one iteration of the variance accumulation: the
    /// extra 2·N·|B| multiply-adds (Sec. 5), at `flops`/s.
    pub fn variance_overhead_s(&self, batch: u64, flops: f64) -> f64 {
        (2 * self.n * batch) as f64 / flops
    }
}

/// Analytic per-node egress bytes for a ring allgatherv with the given
/// per-node message sizes: node `i` transmits every block except the
/// one that completes its set, `Σ_j n_j − n_((i+1) mod p)`. The fabric
/// simulation must reproduce these counts *exactly* (property-tested
/// in `tests/fabric_sim.rs`).
pub fn ring_gatherv_bytes_per_node(sizes: &[u64]) -> Vec<u64> {
    let p = sizes.len();
    let total: u64 = sizes.iter().sum();
    (0..p)
        .map(|i| if p > 1 { total - sizes[(i + 1) % p] } else { 0 })
        .collect()
}

/// Analytic per-node egress bytes for the 2-D torus allgatherv
/// (`fabric::torus`, node `(r, c)` = id `r·cols + c`): the row phase
/// is a ring within row `r` (every row block except the one arriving
/// on the last row hop, `Σ_{j∈row r} n_j − n_(r, (c+1) mod cols)`),
/// and in the column phase the node sends every block whose origin
/// row is not `(r+1) mod rows` exactly once
/// (`Σ_j n_j − Σ_{j∈row (r+1) mod rows} n_j`). Totals match the flat
/// ring's `p − 1` sends per block.
pub fn torus_gatherv_bytes_per_node(sizes: &[u64], rows: usize, cols: usize) -> Vec<u64> {
    assert_eq!(sizes.len(), rows * cols, "one size per torus node");
    let total: u64 = sizes.iter().sum();
    let row_total =
        |r: usize| -> u64 { (0..cols).map(|c| sizes[r * cols + c]).sum() };
    (0..rows * cols)
        .map(|w| {
            let (r, c) = (w / cols, w % cols);
            let row_part = if cols > 1 {
                row_total(r) - sizes[r * cols + (c + 1) % cols]
            } else {
                0
            };
            let col_part = if rows > 1 {
                total - row_total((r + 1) % rows)
            } else {
                0
            };
            row_part + col_part
        })
        .collect()
}

/// Analytic per-node egress bytes for the hierarchy allgatherv
/// (`fabric::hierarchy`, contiguous `(start, len)` group spans, lowest
/// id leads): a member sends its block up once; a leader of a group
/// with `m` members sends its own block `G−1+m` times, each member
/// block `G−1+m−1` times, and every foreign block `m` times.
pub fn hier_gatherv_bytes_per_node(sizes: &[u64], spans: &[(usize, usize)]) -> Vec<u64> {
    let p: usize = spans.iter().map(|&(_, l)| l).sum();
    assert_eq!(sizes.len(), p, "one size per hierarchy worker");
    let total: u64 = sizes.iter().sum();
    let groups = spans.len();
    let mut out = vec![0u64; p];
    for &(start, len) in spans {
        let m = (len - 1) as u64;
        let group_total: u64 = sizes[start..start + len].iter().sum();
        let foreign = total - group_total;
        out[start] = sizes[start] * (groups as u64 - 1 + m)
            + (group_total - sizes[start]) * (groups as u64 - 1 + m).saturating_sub(1)
            + foreign * m;
        for w in start + 1..start + len {
            out[w] = sizes[w];
        }
    }
    out
}

/// Analytic per-node egress bytes for the 3-D torus allgatherv
/// (`fabric::torus3`, node `(x, y, z)` = id `z·X·Y + y·X + x`). A
/// block born at `o` rings its x-line, is injected from every x-line
/// node into that node's y-line, and from the whole `(x, y)` plane of
/// `z = z_o` into the z-lines — every other node receives it exactly
/// once, `XYZ − 1` sends per block. Node `v` forwards `o`'s block:
///
/// * on `o`'s x-line (`y_v = y_o, z_v = z_o`): one x-forward unless it
///   sits on the last hop (`(x_v − x_o) mod X = X − 1`), plus one
///   y-inject if `Y > 1` and one z-inject if `Z > 1`;
/// * in `o`'s plane but off its x-line (`z_v = z_o, y_v ≠ y_o`): one
///   y-forward unless on the last y-hop, plus one z-inject if `Z > 1`;
/// * off-plane (`z_v ≠ z_o`): one z-forward unless on the last z-hop.
///
/// The fabric simulation must reproduce these counts exactly
/// (property-tested in `tests/fabric_sim.rs`).
pub fn torus3_gatherv_bytes_per_node(
    sizes: &[u64],
    x: usize,
    y: usize,
    z: usize,
) -> Vec<u64> {
    let p = x * y * z;
    assert_eq!(sizes.len(), p, "one size per torus3 node");
    let coord = |w: usize| (w % x, (w / x) % y, w / (x * y));
    (0..p)
        .map(|v| {
            let (xv, yv, zv) = coord(v);
            let mut egress = 0u64;
            for (o, &n) in sizes.iter().enumerate() {
                let (xo, yo, zo) = coord(o);
                let mut sends = 0u64;
                if zv == zo {
                    if yv == yo {
                        let d = (xv + x - xo) % x;
                        if x > 1 && d < x - 1 {
                            sends += 1;
                        }
                        sends += u64::from(y > 1) + u64::from(z > 1);
                    } else {
                        let dy = (yv + y - yo) % y;
                        if dy < y - 1 {
                            sends += 1;
                        }
                        sends += u64::from(z > 1);
                    }
                } else {
                    let dz = (zv + z - zo) % z;
                    if dz < z - 1 {
                        sends += 1;
                    }
                }
                egress += sends * n;
            }
            egress
        })
        .collect()
}

/// Analytic per-node egress bytes for the dragonfly allgatherv
/// (`fabric::dragonfly`, contiguous `(start, len)` group spans, group
/// `a`'s link to group `b` owned round-robin by member
/// `start_a + (b − [b > a]) mod len_a`). A node broadcasts its own
/// block to its `m − 1` group peers; the owner of each outbound link
/// additionally relays its whole group's bytes over that link once
/// and fans everything arriving on the paired inbound link to its
/// `m − 1` peers — `p − 1` sends per block in total.
pub fn dragonfly_gatherv_bytes_per_node(
    sizes: &[u64],
    spans: &[(usize, usize)],
) -> Vec<u64> {
    let p: usize = spans.iter().map(|&(_, l)| l).sum();
    assert_eq!(sizes.len(), p, "one size per dragonfly worker");
    let g = spans.len();
    let owner = |a: usize, b: usize| -> usize {
        let (start, len) = spans[a];
        start + (b - usize::from(b > a)) % len
    };
    let group_total: Vec<u64> = spans
        .iter()
        .map(|&(s, l)| sizes[s..s + l].iter().sum())
        .collect();
    let mut out = vec![0u64; p];
    for (a, &(start, len)) in spans.iter().enumerate() {
        let m = (len - 1) as u64;
        for v in start..start + len {
            let mut egress = sizes[v] * m;
            for b in 0..g {
                if b != a && owner(a, b) == v {
                    egress += group_total[a] + m * group_total[b];
                }
            }
            out[v] = egress;
        }
    }
    out
}

/// Completion-time bracket (seconds) for one simulated allgatherv
/// under the fabric's cut-through port model (uniform latency `L`,
/// zero jitter, no stragglers, unsegmented messages).
///
/// * **Lower**: every port is work-conserving and must serialize each
///   byte it carries exactly once, and no first bit lands before `L`
///   — so completion is at least `L` plus the busiest port's total
///   serialization work.
/// * **Upper**: sends are issued in nondecreasing ready order, so a
///   message starts transmitting within its egress port's total work
///   of its ready time and is delivered within the destination
///   ingress port's total work of the last front arrival. With `T_h`
///   the latest hop-`h` delivery this gives the recurrence
///   `T_h ≤ T_{h−1} + L + W_out_max + W_in_max`, hence
///   `T ≤ hops · (L + W_out_max + W_in_max)`.
#[derive(Debug, Clone, Copy)]
pub struct GatherTimeBound {
    pub lower_s: f64,
    pub upper_s: f64,
}

impl GatherTimeBound {
    /// Whether a simulated wall-clock falls inside the bracket,
    /// tolerating the fabric's per-message picosecond rounding.
    pub fn brackets(&self, sim_s: f64) -> bool {
        let lo = self.lower_s - 1e-9 * self.lower_s.abs() - 1e-6;
        let hi = self.upper_s + 1e-9 * self.upper_s.abs() + 1e-6;
        (lo..=hi).contains(&sim_s)
    }
}

/// The generic port-work bracket (see [`GatherTimeBound`] for the
/// derivation). `lat_lower`/`lat_upper` bound the per-hop propagation
/// latency across the links involved; `hops` is the protocol's
/// longest origin→destination relay chain.
fn port_work_bound(
    lat_lower: f64,
    lat_upper: f64,
    hops: f64,
    w_out: &[f64],
    w_in: &[f64],
) -> GatherTimeBound {
    if hops == 0.0 {
        return GatherTimeBound {
            lower_s: 0.0,
            upper_s: 0.0,
        };
    }
    let max_out = w_out.iter().cloned().fold(0.0, f64::max);
    let max_in = w_in.iter().cloned().fold(0.0, f64::max);
    GatherTimeBound {
        lower_s: lat_lower + max_out.max(max_in),
        upper_s: hops * (lat_upper + max_out + max_in),
    }
}

/// Closed-form completion-time bracket for the star
/// (parameter-server) allgatherv: the hub ingress drains the p-way
/// incast serially (its first delivery completes `L + ser(n_0)` in,
/// the last `L + Σ ser` in), its egress then pushes the whole
/// `(p−1)·Σ ser` fan-out, and the final front still needs `L` — so
/// `2L + (p−1)·Σ ser ≤ T ≤ 2L + (p+1)·Σ ser` (the extra `2·Σ ser`
/// headroom covers the incast that precedes the fan-out and the
/// receivers' own ingress drain).
pub fn star_gather_time_bounds(link: &LinkModel, msg_bytes: &[u64]) -> GatherTimeBound {
    let p = msg_bytes.len();
    if p <= 1 {
        return GatherTimeBound {
            lower_s: 0.0,
            upper_s: 0.0,
        };
    }
    let sum_ser: f64 = msg_bytes.iter().map(|&b| (b * 8) as f64 * link.beta).sum();
    GatherTimeBound {
        lower_s: 2.0 * link.latency + (p as f64 - 1.0) * sum_ser,
        upper_s: 2.0 * link.latency + (p as f64 + 1.0) * sum_ser,
    }
}

/// Leader-group spans for `fabric::tree` with this branch factor:
/// group `g` spans `[g·b, min((g+1)·b, p))`, leaders at multiples of
/// `b` (mirrors `Tree::leader_of`).
pub fn tree_spans(p: usize, branch: usize) -> Vec<(usize, usize)> {
    assert!(branch >= 1, "tree branch must be >= 1");
    let starts = (0..p).step_by(branch);
    starts.map(|s| (s, branch.min(p - s))).collect()
}

/// Closed-form completion-time bracket for the two-level tree
/// allgatherv: identical protocol to the hierarchy with the uplink at
/// the base rate (see [`hier_gather_time_bounds`]).
pub fn tree_gather_time_bounds(
    link: &LinkModel,
    msg_bytes: &[u64],
    branch: usize,
) -> GatherTimeBound {
    let spans = tree_spans(msg_bytes.len(), branch);
    hier_gather_time_bounds(link, link, msg_bytes, &spans)
}

/// Closed-form completion-time bracket for the hierarchy allgatherv
/// (member → leader → leaders over the uplink → members). Per-port
/// serialization work for the leader of a group with `m` members,
/// group bytes `B_g` (own block `n_l`), and `F = Σ − B_g` foreign
/// bytes across `G` groups:
///
/// * egress: `B_g·(G−1)` bytes at the uplink rate (cross-rack
///   exchange) plus `n_l·m + (B_g−n_l)·(m−1) + F·m` at the base rate
///   (intra-group fan-out);
/// * ingress: `B_g − n_l` at the base rate (member up-sends) plus `F`
///   at the uplink rate.
///
/// Members send their own block once and receive everything else at
/// the base rate. The bracket then follows from the generic port-work
/// argument on [`GatherTimeBound`] with a 3-hop relay chain (2 for a
/// single group, 1 when every group is a singleton — a leader mesh).
pub fn hier_gather_time_bounds(
    link: &LinkModel,
    uplink: &LinkModel,
    msg_bytes: &[u64],
    spans: &[(usize, usize)],
) -> GatherTimeBound {
    let p: usize = spans.iter().map(|&(_, len)| len).sum();
    assert_eq!(msg_bytes.len(), p, "one size per hierarchy worker");
    let groups = spans.len() as f64;
    let ser = |bytes: f64, beta: f64| bytes * 8.0 * beta;
    let total: f64 = msg_bytes.iter().map(|&b| b as f64).sum();
    let mut w_out = vec![0.0f64; p];
    let mut w_in = vec![0.0f64; p];
    let mut any_members = false;
    for &(start, len) in spans {
        any_members |= len > 1;
        let m = (len - 1) as f64;
        let own = msg_bytes[start] as f64;
        let slab = &msg_bytes[start..start + len];
        let group: f64 = slab.iter().map(|&b| b as f64).sum();
        let members = group - own;
        let foreign = total - group;
        w_out[start] = ser(group * (groups - 1.0), uplink.beta)
            + ser(own * m + members * (m - 1.0).max(0.0) + foreign * m, link.beta);
        w_in[start] = ser(members, link.beta) + ser(foreign, uplink.beta);
        for u in start + 1..start + len {
            let b = msg_bytes[u] as f64;
            w_out[u] = ser(b, link.beta);
            w_in[u] = ser(total - b, link.beta);
        }
    }
    let hops = if p <= 1 {
        0.0
    } else if spans.len() == 1 {
        2.0
    } else if any_members {
        3.0
    } else {
        1.0
    };
    port_work_bound(
        link.latency.min(uplink.latency),
        link.latency.max(uplink.latency),
        hops,
        &w_out,
        &w_in,
    )
}

/// Analytic-vs-simulated cross-check for one collective.
#[derive(Debug, Clone, Copy)]
pub struct SimCheck {
    /// The paper's pipelined-ring upper bound `T_v` (seconds).
    pub analytic_s: f64,
    /// Wall-clock of the event-driven fabric ring (seconds).
    pub simulated_s: f64,
}

impl SimCheck {
    /// Whether the simulation respects the analytic upper bound. The
    /// bound assumes pipelining with block size m; an *unsegmented*
    /// fabric forwards whole blocks (store-and-forward), so this holds
    /// whenever no single message dwarfs the others (uniform codec
    /// messages in practice). The segmented crosscheck
    /// ([`CostModel::crosscheck_ring_gatherv_segmented`]) holds — and
    /// is tight — for skewed sizes too.
    pub fn within_bound(&self) -> bool {
        self.simulated_s <= self.analytic_s * (1.0 + 1e-9)
    }
}

impl CostModel {
    /// Cross-validate the Section-5 `T_v` bound against the fabric: run
    /// a real event-driven ring allgatherv with these per-node message
    /// sizes (bytes) over this model's link parameters and compare
    /// wall-clocks. The fabric forwards whole messages here; see
    /// [`CostModel::crosscheck_ring_gatherv_segmented`] for the
    /// pipelined variant.
    pub fn crosscheck_ring_gatherv(&self, msg_bytes: &[u64]) -> SimCheck {
        self.crosscheck_with_segments(msg_bytes, 0)
    }

    /// The pipelined crosscheck: messages circulate in segments of the
    /// model's block size `m` (`m_bits / 8`), which is exactly the
    /// pipelining the `T_v` bound assumes — so the simulated time
    /// stays within (and converges to) the bound even when one node's
    /// message dwarfs the others (asserted in `tests/fabric_sim.rs`).
    pub fn crosscheck_ring_gatherv_segmented(&self, msg_bytes: &[u64]) -> SimCheck {
        self.crosscheck_with_segments(msg_bytes, (self.m_bits / 8).max(1) as usize)
    }

    fn crosscheck_with_segments(&self, msg_bytes: &[u64], segment_bytes: usize) -> SimCheck {
        assert_eq!(msg_bytes.len(), self.p);
        let bits: Vec<u64> = msg_bytes.iter().map(|b| b * 8).collect();
        let analytic_s = self.t_allgatherv_bits(&bits);
        let inputs: Vec<Vec<u8>> = msg_bytes.iter().map(|&b| vec![0u8; b as usize]).collect();
        let cfg = crate::fabric::FabricConfig {
            link: crate::fabric::LinkSpec::from_cost_model(&self.link),
            segment_bytes,
            ..crate::fabric::FabricConfig::default()
        };
        let topo = crate::fabric::build_topology(crate::fabric::TopologyKind::Ring, self.p);
        let mut fabric = crate::fabric::Fabric::for_config(&cfg, topo.node_count());
        let sim = topo.allgatherv(&mut fabric, &inputs);
        SimCheck {
            analytic_s,
            simulated_s: sim.time_secs(),
        }
    }
}

/// Pipelined step-time bound for an overlapped bucketed step with `b`
/// equal buckets: `max(T_compute, T_comm) + min(T_compute, T_comm)/b`.
/// The `min/b` term is the fill/drain tail — the first bucket's share
/// of the hidden side before the pipeline is primed (comm-bound: the
/// wire idles for one bucket of compute; compute-bound: one bucket of
/// wire drains after the last gradient). `b = 1` degenerates to the
/// phased sum; `b → ∞` converges to the ideal `max`. The event-clock
/// pipeline (`comm::pipeline::schedule`) should land between this
/// bound and the ideal on uniform buckets.
pub fn pipelined_step_s(compute_s: f64, comm_s: f64, buckets: usize) -> f64 {
    assert!(buckets >= 1, "a pipeline needs at least one bucket");
    compute_s.max(comm_s) + compute_s.min(comm_s) / buckets as f64
}

/// Overlap efficiency of an achieved step time against the ideal
/// `max(T_compute, T_comm)`: 1.0 = perfect overlap; the ROADMAP
/// target ("within ~10% of the max") is ≥ 0.9.
pub fn overlap_efficiency(compute_s: f64, comm_s: f64, achieved_s: f64) -> f64 {
    let ideal = compute_s.max(comm_s);
    if achieved_s <= 0.0 {
        1.0
    } else {
        ideal / achieved_s
    }
}

/// One row of the A5 speedup table.
#[derive(Debug, Clone)]
pub struct SpeedupRow {
    pub p: usize,
    pub c: f64,
    pub t_allreduce: f64,
    pub t_allgatherv: f64,
    pub speedup: f64,
    pub bound: f64,
}

/// Generate the Section-5 speedup series over compression ratios and
/// worker counts (the A5 experiment; ResNet-50-scale N by default).
pub fn speedup_series(n: u64, ps: &[usize], cs: &[f64], link: LinkModel) -> Vec<SpeedupRow> {
    let mut rows = Vec::new();
    for &p in ps {
        let model = CostModel::new(p, n, link);
        for &c in cs {
            rows.push(SpeedupRow {
                p,
                c,
                t_allreduce: model.t_allreduce(),
                t_allgatherv: model.t_allgatherv_ratio(c),
                speedup: model.speedup(c),
                bound: model.speedup_lower_bound(c),
            });
        }
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    const RESNET50_N: u64 = 25_500_000;

    fn no_latency(p: usize) -> CostModel {
        let mut m = CostModel::new(
            p,
            RESNET50_N,
            LinkModel {
                beta: 1e-9,
                latency: 0.0,
            },
        );
        m.m_bits = 64; // "if we set m small enough"
        m
    }

    #[test]
    fn pipelined_bound_brackets_sum_and_max() {
        let (tc, tm) = (3.0e-3, 7.0e-3);
        // One bucket is the phased sum; more buckets approach the max.
        assert!((pipelined_step_s(tc, tm, 1) - (tc + tm)).abs() < 1e-12);
        let mut prev = f64::INFINITY;
        for b in 1..=64 {
            let t = pipelined_step_s(tc, tm, b);
            assert!(t <= prev, "bound must shrink with buckets");
            assert!(t >= tc.max(tm), "never below the ideal max");
            prev = t;
        }
        assert!(pipelined_step_s(tc, tm, 1000) < tc.max(tm) * 1.001);
        // Symmetric in its arguments.
        assert_eq!(pipelined_step_s(tc, tm, 8), pipelined_step_s(tm, tc, 8));
        // Efficiency: phased execution of a balanced step is ~0.5,
        // ideal is 1.0, and a degenerate zero denominator stays sane.
        assert!((overlap_efficiency(5.0, 5.0, 10.0) - 0.5).abs() < 1e-12);
        assert_eq!(overlap_efficiency(5.0, 5.0, 5.0), 1.0);
        assert_eq!(overlap_efficiency(1.0, 1.0, 0.0), 1.0);
    }

    #[test]
    fn t_allreduce_matches_formula() {
        let m = no_latency(8);
        let want = 2.0 * 7.0 * (RESNET50_N * 32) as f64 * 1e-9 / 8.0;
        assert!((m.t_allreduce() - want).abs() < 1e-9 * want.abs());
    }

    #[test]
    fn speedup_respects_paper_lower_bound() {
        for p in [2usize, 4, 8, 16, 64] {
            let m = no_latency(p);
            for c in [1.0, 10.0, 100.0, 1000.0, 10_000.0] {
                let s = m.speedup(c);
                let b = m.speedup_lower_bound(c);
                assert!(
                    s >= b * 0.999,
                    "p={p} c={c}: speedup {s} < bound {b}"
                );
            }
        }
    }

    #[test]
    fn linear_speedup_regime_starts_near_c_equals_p_over_2() {
        // Paper: "we expect linear speedup in c > p/2 range" — i.e. the
        // bound crosses 1 exactly at c = p²/(2(p−1)) ≈ p/2.
        for p in [4usize, 8, 16] {
            let m = no_latency(p);
            let c_star = (p * p) as f64 / (2.0 * (p as f64 - 1.0));
            assert!(m.speedup_lower_bound(c_star * 1.01) > 1.0);
            assert!(m.speedup_lower_bound(c_star * 0.99) < 1.0);
        }
    }

    #[test]
    fn t_v_from_measured_bits_equals_ratio_form() {
        let m = no_latency(8);
        let c = 100.0;
        let per_node = (RESNET50_N * 32) as f64 / c;
        let bits = vec![per_node as u64; 8];
        let a = m.t_allgatherv_bits(&bits);
        let b = m.t_allgatherv_ratio(c);
        assert!((a - b).abs() < 1e-6 * b);
    }

    #[test]
    fn uneven_message_sizes_sum_correctly() {
        let m = no_latency(4);
        let bits = vec![100, 0, 300, 44];
        let want = (444.0 + 3.0 * 64.0) * 1e-9;
        assert!((m.t_allgatherv_bits(&bits) - want).abs() < 1e-15);
    }

    #[test]
    fn variance_overhead_is_negligible_vs_comm() {
        // The paper's claim: 2N|B| madds are negligible. At 1 TFLOP/s,
        // N=25.5M, B=32: ~1.6 ms, vs T_r ≈ 178 ms on 1GbE.
        let m = CostModel::new(8, RESNET50_N, LinkModel::gige());
        let overhead = m.variance_overhead_s(32, 1e12);
        assert!(overhead < 0.05 * m.t_allreduce());
    }

    #[test]
    fn ring_gatherv_bytes_formula() {
        assert_eq!(
            ring_gatherv_bytes_per_node(&[100, 200, 50, 400]),
            vec![550, 700, 350, 650]
        );
        assert_eq!(ring_gatherv_bytes_per_node(&[7]), vec![0]);
    }

    #[test]
    fn torus_gatherv_bytes_formula() {
        // 2x2: node (0,0) row-sends row0−n(0,1) = n0, col-sends
        // total−row1 = n0+n1 → 2·n0 + n1.
        let sizes = [10u64, 20, 30, 40];
        let got = torus_gatherv_bytes_per_node(&sizes, 2, 2);
        assert_eq!(got, vec![10 + 10 + 20, 20 + 20 + 10, 30 + 30 + 40, 40 + 40 + 30]);
        // Total sends = (p−1) copies of every block.
        let total: u64 = got.iter().sum();
        assert_eq!(total, 3 * sizes.iter().sum::<u64>());
        // 1×p degenerates to the ring formula.
        let flat = [5u64, 9, 2];
        assert_eq!(
            torus_gatherv_bytes_per_node(&flat, 1, 3),
            ring_gatherv_bytes_per_node(&flat)
        );
        assert_eq!(torus_gatherv_bytes_per_node(&[7], 1, 1), vec![0]);
    }

    #[test]
    fn hier_gatherv_bytes_formula() {
        // 2 groups of 2: leader 0 sends n0·(1+1) + n1·(1+1−1) + (n2+n3)·1;
        // member 1 sends n1 once.
        let sizes = [10u64, 20, 30, 40];
        let spans = [(0usize, 2usize), (2, 2)];
        let got = hier_gatherv_bytes_per_node(&sizes, &spans);
        assert_eq!(got, vec![2 * 10 + 20 + 70, 20, 2 * 30 + 40 + 30, 40]);
        assert_eq!(hier_gatherv_bytes_per_node(&[7], &[(0, 1)]), vec![0]);
        // One group degenerates to a star with worker 0 as hub.
        let got = hier_gatherv_bytes_per_node(&sizes, &[(0, 4)]);
        assert_eq!(got, vec![3 * 10 + 2 * (20 + 30 + 40), 20, 30, 40]);
    }

    #[test]
    fn torus3_gatherv_bytes_formula() {
        // Total sends = (p−1) copies of every block, any shape.
        for &(x, y, z) in &[(2usize, 3usize, 2usize), (2, 2, 2), (1, 3, 2), (4, 1, 2)] {
            let p = x * y * z;
            let sizes: Vec<u64> = (0..p).map(|w| (w as u64 + 1) * 10).collect();
            let got = torus3_gatherv_bytes_per_node(&sizes, x, y, z);
            assert_eq!(
                got.iter().sum::<u64>(),
                (p as u64 - 1) * sizes.iter().sum::<u64>(),
                "{x}x{y}x{z}"
            );
        }
        // A single plane (Z = 1) is exactly the 2-D torus with
        // rows = Y, cols = X (same node ids, same routes).
        let sizes: Vec<u64> = (0..12).map(|w| (w as u64 * 7) % 90 + 1).collect();
        assert_eq!(
            torus3_gatherv_bytes_per_node(&sizes, 4, 3, 1),
            torus_gatherv_bytes_per_node(&sizes, 3, 4)
        );
        // A single line (Y = Z = 1) is exactly the ring.
        let flat = [5u64, 9, 2, 11];
        assert_eq!(
            torus3_gatherv_bytes_per_node(&flat, 4, 1, 1),
            ring_gatherv_bytes_per_node(&flat)
        );
        assert_eq!(torus3_gatherv_bytes_per_node(&[7], 1, 1, 1), vec![0]);
    }

    #[test]
    fn dragonfly_gatherv_bytes_formula() {
        // 2 groups of 2, sizes 10/20/30/40. Node 0 owns a→b (peer 1
        // owns nothing since g−1 = 1 link round-robins from 0):
        // bcast n0 + relay (n0+n1) + fan (m−1)(n2+n3).
        let sizes = [10u64, 20, 30, 40];
        let spans = [(0usize, 2usize), (2, 2)];
        let got = dragonfly_gatherv_bytes_per_node(&sizes, &spans);
        assert_eq!(got, vec![10 + 30 + 70, 20, 30 + 70 + 30, 40]);
        // Total sends = (p−1) copies of every block.
        assert_eq!(got.iter().sum::<u64>(), 3 * sizes.iter().sum::<u64>());
        // Uneven spans keep the invariant.
        let sizes: Vec<u64> = (0..7).map(|w| w as u64 + 1).collect();
        let spans = [(0usize, 3usize), (3, 2), (5, 2)];
        let got = dragonfly_gatherv_bytes_per_node(&sizes, &spans);
        assert_eq!(got.iter().sum::<u64>(), 6 * sizes.iter().sum::<u64>());
        // One group is a pure broadcast: every node sends m−1 copies
        // of its own block and relays nothing.
        let got = dragonfly_gatherv_bytes_per_node(&[10, 20, 30], &[(0, 3)]);
        assert_eq!(got, vec![20, 40, 60]);
        assert_eq!(dragonfly_gatherv_bytes_per_node(&[7], &[(0, 1)]), vec![0]);
    }

    #[test]
    fn star_time_bounds_formula() {
        let link = LinkModel {
            beta: 1e-9,
            latency: 1e-5,
        };
        let b = star_gather_time_bounds(&link, &[1000, 2000, 1000]);
        // Σ ser = 4000 B · 8 b/B · 1e-9 s/b = 32 µs.
        let sum = 32e-6;
        assert!((b.lower_s - (2e-5 + 2.0 * sum)).abs() < 1e-12);
        assert!((b.upper_s - (2e-5 + 4.0 * sum)).abs() < 1e-12);
        assert!(b.lower_s < b.upper_s);
        // Single worker: nothing moves.
        let b1 = star_gather_time_bounds(&link, &[1000]);
        assert_eq!(b1.lower_s, 0.0);
        assert_eq!(b1.upper_s, 0.0);
        assert!(b1.brackets(0.0));
    }

    #[test]
    fn tree_spans_mirror_fabric_grouping() {
        assert_eq!(tree_spans(10, 4), vec![(0, 4), (4, 4), (8, 2)]);
        assert_eq!(tree_spans(3, 1), vec![(0, 1), (1, 1), (2, 1)]);
        assert_eq!(tree_spans(3, 8), vec![(0, 3)]);
    }

    #[test]
    fn hier_time_bounds_shape() {
        let link = LinkModel {
            beta: 1e-9,
            latency: 1e-5,
        };
        let slow = LinkModel {
            beta: 1e-8,
            latency: 1e-5,
        };
        let sizes = [1000u64, 1000, 1000, 1000];
        let spans = [(0usize, 2usize), (2, 2)];
        let fast = hier_gather_time_bounds(&link, &link, &sizes, &spans);
        let oversub = hier_gather_time_bounds(&link, &slow, &sizes, &spans);
        assert!(fast.lower_s <= fast.upper_s);
        assert!(oversub.lower_s <= oversub.upper_s);
        // A slower uplink raises both ends of the bracket.
        assert!(oversub.lower_s > fast.lower_s);
        assert!(oversub.upper_s > fast.upper_s);
        // The uniform-rate tree form is the hierarchy with uplink=base.
        let tree = tree_gather_time_bounds(&link, &sizes, 2);
        assert_eq!(tree.lower_s, fast.lower_s);
        assert_eq!(tree.upper_s, fast.upper_s);
    }

    #[test]
    fn time_bound_brackets_tolerance() {
        let b = GatherTimeBound {
            lower_s: 1.0,
            upper_s: 2.0,
        };
        assert!(b.brackets(1.0));
        assert!(b.brackets(2.0));
        assert!(b.brackets(1.5));
        assert!(b.brackets(1.0 - 1e-7)); // within abs tolerance
        assert!(!b.brackets(0.5));
        assert!(!b.brackets(2.5));
    }

    #[test]
    fn simulated_ring_respects_analytic_bound_for_uniform_messages() {
        for p in [2usize, 4, 8] {
            let model = CostModel::new(p, 1_000_000, LinkModel::gige());
            let check = model.crosscheck_ring_gatherv(&vec![50_000u64; p]);
            assert!(
                check.within_bound(),
                "p={p}: simulated {}s exceeds analytic bound {}s",
                check.simulated_s,
                check.analytic_s
            );
            // …and the simulation is not degenerate (moves real time).
            assert!(check.simulated_s > 0.0);
        }
    }

    #[test]
    fn series_covers_grid() {
        let rows = speedup_series(1000, &[2, 4], &[1.0, 10.0], LinkModel::gige());
        assert_eq!(rows.len(), 4);
        assert!(rows.iter().all(|r| r.speedup > 0.0));
    }
}
