//! Communication substrate (S8, S9): byte-accurate ring collectives
//! over a simulated fabric, plus the paper's Section-5 analytic cost
//! model.
//!
//! The paper replaces allreduce with **allgatherv** (Sec. 4.3): each
//! worker broadcasts its own sparse message, every worker decodes all
//! of them locally. Both collectives are thin fronts over the
//! event-driven fabric simulator's ring backend (`crate::fabric`):
//! real data movement between per-node endpoints, traffic accounting
//! per node, byte- and bit-identical to the original lockstep rounds.
//! On this default path wall-clock stays *modeled* analytically
//! exactly as the paper's own Section 5 does (DESIGN.md
//! §Substitutions); [`costmodel`] additionally cross-validates the
//! analytic bound against the fabric's simulated wall-clock, and other
//! topologies/link models are reachable through `fabric` directly.

pub mod allgatherv;
pub mod allreduce;
pub mod costmodel;

/// Per-collective traffic accounting.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Traffic {
    /// Bytes each node pushed onto its outgoing link.
    pub bytes_sent_per_node: Vec<u64>,
    /// Ring rounds executed.
    pub rounds: u32,
}

impl Traffic {
    pub fn total_bytes(&self) -> u64 {
        self.bytes_sent_per_node.iter().sum()
    }

    pub fn max_node_bytes(&self) -> u64 {
        self.bytes_sent_per_node.iter().copied().max().unwrap_or(0)
    }
}
