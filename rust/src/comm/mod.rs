//! Communication substrate (S8, S9): byte-accurate ring collectives
//! over a simulated fabric, plus the paper's Section-5 analytic cost
//! model.
//!
//! The paper replaces allreduce with **allgatherv** (Sec. 4.3): each
//! worker broadcasts its own sparse message, every worker decodes all
//! of them locally. Both collectives are thin fronts over the
//! event-driven fabric simulator (`crate::fabric`): real data movement
//! between per-node endpoints, traffic accounting per node, byte- and
//! bit-identical to the original lockstep rounds. `allgatherv::
//! allgatherv` runs on whatever topology/link model the `FabricConfig`
//! names (ring by default; star, tree, 2-D torus, NUMA hierarchy, full
//! mesh; per-link overrides; segmented pipelining at the cost model's
//! block size `m`) and reports the simulated wall-clock of that
//! cluster shape — which is how the trainer's `--topology` flag
//! reaches the comm phase. [`costmodel`] cross-validates the paper's
//! analytic `T_v` bound against the simulated wall-clock, segmented
//! and not.

pub mod allgatherv;
pub mod allreduce;
pub mod costmodel;
pub mod pipeline;

/// Per-collective traffic accounting.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Traffic {
    /// Bytes each node pushed onto its outgoing link.
    pub bytes_sent_per_node: Vec<u64>,
    /// Ring rounds executed.
    pub rounds: u32,
}

impl Traffic {
    pub fn total_bytes(&self) -> u64 {
        self.bytes_sent_per_node.iter().sum()
    }

    pub fn max_node_bytes(&self) -> u64 {
        self.bytes_sent_per_node.iter().copied().max().unwrap_or(0)
    }
}
