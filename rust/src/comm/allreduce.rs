//! Ring allreduce (sum) — the uncompressed baseline collective.
//!
//! A thin front over the fabric's ring backend
//! ([`crate::fabric::ring`]): reduce-scatter (p−1 hops over N/p
//! chunks, each node ends owning the full sum of one chunk) pipelined
//! into the allgather of the reduced chunks. The event-driven protocol
//! accumulates in the same order over the same chunk boundaries as the
//! original lockstep rounds, so results are bit-identical and total
//! bytes per node stay ≈ 2·(p−1)·N·s/p — exactly the paper's `T_r`
//! bandwidth term.

use super::pipeline::{self, OverlapSchedule};
use super::Traffic;
use crate::fabric::{build_topology, Fabric, FabricConfig, Time, TopologyKind};

/// Result: every node's reduced vector plus traffic accounting.
pub struct ReduceResult {
    pub reduced: Vec<Vec<f32>>,
    pub traffic: Traffic,
}

/// Result of an overlapped bucketed allreduce (the dense baseline's
/// counterpart to `allgatherv::allgatherv_overlapped`).
pub struct OverlappedReduce {
    /// Per-bucket reductions concatenated in bucket order. Note the
    /// *sums* are taken per bucket, so chunk boundaries (and thus
    /// float rounding) can differ from a whole-vector allreduce —
    /// this front is the sweep's timing baseline, not a bit-parity
    /// path (the codec pipeline has its own bit-identity guarantee).
    pub reduced: Vec<Vec<f32>>,
    pub schedule: OverlapSchedule,
    pub traffic: Traffic,
    pub segment_bytes: usize,
    pub buckets: usize,
}

/// Bucketed, overlapped allreduce on the configured topology: bucket
/// `k`'s reduce enters the wire at its gradient-ready time (backprop
/// producing buckets in gather order at a uniform rate over
/// `grad_ps`), on one shared fabric so port state carries across
/// buckets. This gives the dense baseline the same segmented-overlap
/// treatment as the compressed pipeline, keeping phased-vs-overlapped
/// comparisons honest.
pub fn allreduce_overlapped(
    cfg: &FabricConfig,
    inputs: &[Vec<f32>],
    weights: &[u64],
    grad_ps: Time,
) -> OverlappedReduce {
    let p = inputs.len();
    assert!(p > 0, "allreduce needs at least one node");
    assert!(!weights.is_empty(), "need at least one bucket");
    let n = inputs[0].len();
    assert!(inputs.iter().all(|v| v.len() == n), "length mismatch");
    let topo = build_topology(cfg.topology, p);
    let mut fabric = Fabric::for_topology(cfg, &*topo);
    let seg = pipeline::effective_segment_bytes(cfg.segment_bytes, fabric.link_table());
    fabric.set_segment_bytes(seg);

    let merged = pipeline::merge_weights(weights, n * 4, seg);
    let param_cuts = pipeline::split_by_weights(n, &merged);
    let ready = pipeline::ready_times(&merged, grad_ps, 0);

    let mut reduced: Vec<Vec<f32>> = vec![Vec::with_capacity(n); p];
    let mut comm = Vec::with_capacity(merged.len());
    let mut traffic = Traffic::default();
    let mut off = 0usize;
    for (&cut, &ready_k) in param_cuts.iter().zip(&ready) {
        let slices: Vec<Vec<f32>> = inputs.iter().map(|v| v[off..off + cut].to_vec()).collect();
        off += cut;
        fabric.advance_to(ready_k);
        let start = fabric.now();
        let sim = topo.allreduce(&mut fabric, &slices);
        comm.push(sim.time_ps - start);
        for (out, part) in reduced.iter_mut().zip(&sim.reduced) {
            out.extend_from_slice(part);
        }
        traffic = sim.traffic; // cumulative across runs: keep the last
    }
    OverlappedReduce {
        reduced,
        schedule: pipeline::schedule(&ready, &comm),
        traffic,
        segment_bytes: seg,
        buckets: merged.len(),
    }
}

/// Elementwise-sum ring allreduce over per-node vectors (equal length).
pub fn ring_allreduce(inputs: &[Vec<f32>]) -> ReduceResult {
    let p = inputs.len();
    assert!(p > 0);
    let topo = build_topology(TopologyKind::Ring, p);
    let mut fabric = Fabric::for_config(&FabricConfig::default(), topo.node_count());
    let sim = topo.allreduce(&mut fabric, inputs);
    ReduceResult {
        reduced: sim.reduced,
        traffic: sim.traffic,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;
    use crate::util::rng::Pcg32;

    #[test]
    fn result_is_elementwise_sum_on_all_nodes() {
        let inputs = vec![
            vec![1.0f32, 2.0, 3.0, 4.0, 5.0],
            vec![10.0, 20.0, 30.0, 40.0, 50.0],
            vec![-1.0, -2.0, -3.0, -4.0, -5.0],
        ];
        let want = vec![10.0f32, 20.0, 30.0, 40.0, 50.0];
        let res = ring_allreduce(&inputs);
        for node in 0..3 {
            assert_eq!(res.reduced[node], want, "node {node}");
        }
    }

    #[test]
    fn traffic_matches_2_p_minus_1_over_p() {
        // N divisible by p: every node sends exactly 2(p-1)N/p elements.
        let p = 4;
        let n = 100;
        let inputs: Vec<Vec<f32>> = (0..p).map(|i| vec![i as f32; n]).collect();
        let res = ring_allreduce(&inputs);
        for i in 0..p {
            assert_eq!(
                res.traffic.bytes_sent_per_node[i],
                (2 * (p - 1) * n / p * 4) as u64
            );
        }
        assert_eq!(res.traffic.rounds, 2 * (p as u32 - 1));
    }

    #[test]
    fn property_sum_for_random_p_and_n() {
        testkit::for_all(
            "ring allreduce == sum",
            |rng: &mut Pcg32| {
                let p = testkit::usize_in(rng, 1, 9);
                let n = testkit::usize_in(rng, 1, 97); // often not divisible by p
                (0..p)
                    .map(|_| testkit::gradient_vec(rng, n))
                    .collect::<Vec<_>>()
            },
            |inputs| {
                let n = inputs[0].len();
                let res = ring_allreduce(inputs);
                for i in 0..n {
                    let want: f64 = inputs.iter().map(|v| v[i] as f64).sum();
                    for node in 0..inputs.len() {
                        let got = res.reduced[node][i] as f64;
                        if (got - want).abs() > 1e-4 * (1.0 + want.abs()) {
                            return Err(format!("node {node} i={i}: {got} != {want}"));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn overlapped_reduce_sums_every_bucket() {
        let p = 4;
        let n = 1000;
        let inputs: Vec<Vec<f32>> = (0..p)
            .map(|i| (0..n).map(|j| (i * n + j) as f32).collect())
            .collect();
        let cfg = FabricConfig::default();
        let res = allreduce_overlapped(&cfg, &inputs, &[1000, 1000, 2000], 0);
        for node in 0..p {
            assert_eq!(res.reduced[node].len(), n, "node {node}");
            for j in 0..n {
                let want: f32 = (0..p).map(|i| (i * n + j) as f32).sum();
                assert_eq!(res.reduced[node][j], want, "node {node} j={j}");
            }
        }
        assert!(res.buckets >= 1);
        assert_eq!(res.segment_bytes, 12_500); // GigE BDP fallback
        assert!(res.schedule.overlapped_ps <= res.schedule.phased_ps);
        // Gating on a long compute hides the wire behind backprop.
        let late = 10 * res.schedule.comm_busy_ps;
        let gated = allreduce_overlapped(&cfg, &inputs, &[1000, 1000, 2000], late);
        assert_eq!(gated.schedule.cpu_ps, late);
        assert!(gated.schedule.overlapped_ps >= late);
        assert!(gated.schedule.overlapped_ps <= gated.schedule.phased_ps);
    }

    #[test]
    fn single_node_identity() {
        let inputs = vec![vec![1.0f32, 2.0]];
        let res = ring_allreduce(&inputs);
        assert_eq!(res.reduced[0], vec![1.0, 2.0]);
        assert_eq!(res.traffic.total_bytes(), 0);
    }

    #[test]
    fn n_smaller_than_p() {
        // Degenerate chunking (empty chunks) must still be correct.
        let inputs: Vec<Vec<f32>> = (0..5).map(|i| vec![i as f32, 1.0]).collect();
        let res = ring_allreduce(&inputs);
        for node in 0..5 {
            assert_eq!(res.reduced[node], vec![10.0, 5.0]);
        }
    }
}
