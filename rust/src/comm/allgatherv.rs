//! Allgatherv: every node ends up holding every node's message.
//!
//! [`allgatherv`] is a thin front over the event-driven fabric: it
//! builds the configured [`crate::fabric::Topology`] (ring by default
//! — the paper's substrate — or star/tree/torus/hierarchy/mesh),
//! wires per-link overrides and gather segmentation from the
//! [`FabricConfig`], and moves the *actual bytes* between per-node
//! endpoints — so a bug in block bookkeeping shows up as corrupted
//! codec messages downstream, not just a wrong counter. The gathered
//! matrix is topology-independent (every backend delivers the same
//! bytes); traffic accounting and the simulated wall-clock
//! ([`GatherResult::time_ps`]) come from the configured cluster shape.
//! The trainer's comm phase calls this front, so `--topology` governs
//! the fabric its decode path runs on.
//!
//! [`ring_allgatherv`] keeps the classic default: the p−1-hop ring
//! circulation with traffic `Σ_j n_j − n_(i+1)` per node and p−1
//! rounds, byte- and bit-identical to the pre-fabric lockstep
//! implementation.

use super::pipeline::{self, OverlapSchedule};
use super::Traffic;
use crate::fabric::{
    build_topology, degraded_topology, Fabric, FabricConfig, FabricReport, FabricTelemetry, Time,
};

/// Result of one allgatherv: `gathered[dst][src]` is node `src`'s
/// message as received by node `dst` (every row must be identical —
/// asserted in debug builds and by tests).
pub struct GatherResult {
    pub gathered: Vec<Vec<Vec<u8>>>,
    pub traffic: Traffic,
    /// Simulated completion time on the configured fabric, ps.
    pub time_ps: Time,
    /// Fault/recovery counters from the fabric (all zero when the
    /// chaos plan is empty or nothing fired).
    pub report: FabricReport,
    /// Per-link snapshot of this collective (bandwidth, bytes, fault
    /// counters) — the feedback signal for `compress::controller`.
    pub telemetry: FabricTelemetry,
}

/// Run an allgatherv over each node's input message on the configured
/// topology/link model. Link faults in `cfg.faults` are masked by
/// retransmission — the gathered bytes are unchanged, only timing and
/// the [`FabricReport`] counters move.
pub fn allgatherv(cfg: &FabricConfig, inputs: &[Vec<u8>]) -> GatherResult {
    let p = inputs.len();
    assert!(p > 0, "allgatherv needs at least one node");
    let topo = build_topology(cfg.topology, p);
    let mut fabric = Fabric::for_topology(cfg, &*topo);
    let sim = topo.allgatherv(&mut fabric, inputs);
    GatherResult {
        gathered: sim.gathered,
        traffic: sim.traffic,
        time_ps: sim.time_ps,
        report: fabric.report(),
        telemetry: fabric.telemetry(Vec::new()),
    }
}

/// Allgatherv over the survivors of a crash: nodes in `dead` take no
/// part, the topology re-spans the live set
/// ([`degraded_topology`] — route-around for ring/torus, leader
/// re-election for star/tree/hier), and the gathered matrix keeps the
/// original worker indexing with empty rows/columns for the dead.
/// `dead` may also name a star's hub (`inputs.len()`). An empty `dead`
/// takes exactly the plain [`allgatherv`] path.
pub fn allgatherv_faulty(cfg: &FabricConfig, inputs: &[Vec<u8>], dead: &[usize]) -> GatherResult {
    if dead.is_empty() {
        return allgatherv(cfg, inputs);
    }
    let p = inputs.len();
    assert!(p > 0, "allgatherv needs at least one node");
    let (topo, rank_map, phys) = degraded_topology(cfg.topology, p, dead);
    let live: Vec<usize> = (0..p).filter(|w| !dead.contains(w)).collect();
    let sub_inputs: Vec<Vec<u8>> = live.iter().map(|&w| inputs[w].clone()).collect();
    let mut fabric = Fabric::for_degraded(cfg, &*topo, rank_map, phys);
    fabric.note_reroutes(dead.len() as u64);
    let sim = topo.allgatherv(&mut fabric, &sub_inputs);
    let mut gathered = vec![vec![Vec::new(); p]; p];
    for (li, &dst) in live.iter().enumerate() {
        for (lj, &src) in live.iter().enumerate() {
            gathered[dst][src] = sim.gathered[li][lj].clone();
        }
    }
    GatherResult {
        gathered,
        traffic: sim.traffic,
        time_ps: sim.time_ps,
        report: fabric.report(),
        telemetry: fabric.telemetry(Vec::new()),
    }
}

/// Run a ring allgatherv over each node's input message (the default
/// fabric config: uniform GigE links, no segmentation).
pub fn ring_allgatherv(inputs: &[Vec<u8>]) -> GatherResult {
    allgatherv(&FabricConfig::default(), inputs)
}

/// Result of an overlapped multi-bucket allgatherv: the fully
/// reassembled messages (bit-identical to one phased [`allgatherv`]
/// over the same inputs) plus the pipeline timing accounting.
pub struct OverlappedGather {
    /// `gathered[dst][src]`: bucket slices concatenated in bucket
    /// index order — byte-identical to `src`'s original message.
    pub gathered: Vec<Vec<Vec<u8>>>,
    /// Overlapped/phased/ideal step accounting (comm durations come
    /// from the event clock; readiness from the compute model).
    pub schedule: OverlapSchedule,
    pub traffic: Traffic,
    pub report: FabricReport,
    /// Effective gather segment (pinned `segment_bytes`, else the BDP
    /// of the slowest link in this fabric's table).
    pub segment_bytes: usize,
    /// Buckets actually gathered, after sub-segment coalescing.
    pub buckets: usize,
    pub events: u64,
    /// Per-link snapshot including per-bucket comm times (the
    /// schedule's comm durations in bucket order) — the feedback
    /// signal for `compress::controller`.
    pub telemetry: FabricTelemetry,
}

/// Async multi-gather front: gather each worker's message as a train
/// of per-bucket slices on one shared fabric, releasing bucket `k`
/// onto the wire at its encode-ready time (`pipeline::ready_times`
/// over `grad_ps`/`encode_ps`) while earlier buckets may still be in
/// flight from the port-state point of view (the event clock and
/// egress/ingress free times carry across bucket runs).
///
/// `weights` are the dense per-bucket byte weights in gather order
/// ([`pipeline::bucket_weights`]); each worker's message is sliced
/// proportionally ([`pipeline::split_by_weights`]) after adjacent
/// sub-segment buckets are coalesced once, globally, against the
/// largest message ([`pipeline::merge_weights`]) — so every worker
/// cuts at the same bucket boundaries and concatenation in bucket
/// order reproduces every message exactly. Decode order is therefore
/// fixed by bucket index, never by completion order.
pub fn allgatherv_overlapped(
    cfg: &FabricConfig,
    inputs: &[Vec<u8>],
    weights: &[u64],
    grad_ps: Time,
    encode_ps: Time,
) -> OverlappedGather {
    let p = inputs.len();
    assert!(p > 0, "allgatherv needs at least one node");
    assert!(!weights.is_empty(), "need at least one bucket");
    let topo = build_topology(cfg.topology, p);
    let mut fabric = Fabric::for_topology(cfg, &*topo);
    let seg = pipeline::effective_segment_bytes(cfg.segment_bytes, fabric.link_table());
    fabric.set_segment_bytes(seg);

    let max_len = inputs.iter().map(Vec::len).max().unwrap_or(0);
    let merged = pipeline::merge_weights(weights, max_len, seg);
    let ready = pipeline::ready_times(&merged, grad_ps, encode_ps);
    let cuts: Vec<Vec<usize>> = inputs
        .iter()
        .map(|m| pipeline::split_by_weights(m.len(), &merged))
        .collect();

    let mut gathered: Vec<Vec<Vec<u8>>> = vec![vec![Vec::new(); p]; p];
    let mut comm = Vec::with_capacity(merged.len());
    let mut offsets = vec![0usize; p];
    let mut traffic = Traffic::default();
    let mut events = 0;
    for (k, &ready_k) in ready.iter().enumerate() {
        let slices: Vec<Vec<u8>> = inputs
            .iter()
            .enumerate()
            .map(|(w, m)| m[offsets[w]..offsets[w] + cuts[w][k]].to_vec())
            .collect();
        for (off, c) in offsets.iter_mut().zip(&cuts) {
            *off += c[k];
        }
        fabric.advance_to(ready_k);
        let start = fabric.now();
        let sim = topo.allgatherv(&mut fabric, &slices);
        comm.push(sim.time_ps - start);
        for (drow, srow) in gathered.iter_mut().zip(&sim.gathered) {
            for (dmsg, smsg) in drow.iter_mut().zip(srow) {
                dmsg.extend_from_slice(smsg);
            }
        }
        // Fabric counters are cumulative across runs: keep the last.
        traffic = sim.traffic;
        events = sim.events;
    }
    let telemetry = fabric.telemetry(comm.clone());
    OverlappedGather {
        gathered,
        schedule: pipeline::schedule(&ready, &comm),
        traffic,
        report: fabric.report(),
        segment_bytes: seg,
        buckets: merged.len(),
        events,
        telemetry,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::TopologyKind;
    use crate::testkit;
    use crate::util::rng::Pcg32;

    fn msgs(sizes: &[usize]) -> Vec<Vec<u8>> {
        sizes
            .iter()
            .enumerate()
            .map(|(i, &s)| (0..s).map(|j| ((i * 131 + j) % 251) as u8).collect())
            .collect()
    }

    #[test]
    fn every_node_receives_every_message_exactly_once() {
        let inputs = msgs(&[10, 0, 5, 33]);
        let res = ring_allgatherv(&inputs);
        for dst in 0..4 {
            for src in 0..4 {
                assert_eq!(
                    res.gathered[dst][src], inputs[src],
                    "dst={dst} src={src}"
                );
            }
        }
        assert_eq!(res.traffic.rounds, 3);
        assert!(res.time_ps > 0);
    }

    #[test]
    fn single_node_is_a_noop() {
        let inputs = msgs(&[7]);
        let res = ring_allgatherv(&inputs);
        assert_eq!(res.gathered[0][0], inputs[0]);
        assert_eq!(res.traffic.total_bytes(), 0);
        assert_eq!(res.traffic.rounds, 0);
    }

    #[test]
    fn traffic_each_node_forwards_all_but_its_final_block() {
        // In a p-ring each node transmits every block except the one it
        // only receives in the last round: total per node = Σ_j n_j − n_(i+1).
        let sizes = [100usize, 200, 50, 400];
        let inputs = msgs(&sizes);
        let res = ring_allgatherv(&inputs);
        let p = sizes.len();
        for i in 0..p {
            let expected: u64 = (0..p)
                .filter(|&j| j != (i + 1) % p)
                .map(|j| sizes[j] as u64)
                .sum();
            assert_eq!(res.traffic.bytes_sent_per_node[i], expected, "node {i}");
        }
    }

    #[test]
    fn configured_topology_changes_timing_not_bytes() {
        let inputs = msgs(&[64, 128, 32, 96]);
        let ring = ring_allgatherv(&inputs);
        let star = allgatherv(
            &FabricConfig {
                topology: TopologyKind::Star,
                ..FabricConfig::default()
            },
            &inputs,
        );
        assert_eq!(ring.gathered, star.gathered, "bytes are topology-invariant");
        assert_ne!(ring.time_ps, star.time_ps, "timing reflects the topology");
    }

    #[test]
    fn link_faults_are_masked_in_the_gathered_bytes() {
        let inputs = msgs(&[64, 128, 32, 96]);
        let clean = ring_allgatherv(&inputs);
        let mut fired = false;
        for seed in 0..4 {
            let res = allgatherv(
                &FabricConfig {
                    seed,
                    faults: crate::fabric::FaultPlan::parse("drop:0-1:0.5,corrupt:2-3:0.4")
                        .unwrap(),
                    ..FabricConfig::default()
                },
                &inputs,
            );
            assert_eq!(res.gathered, clean.gathered, "seed {seed}: bytes fault-invariant");
            assert!(res.time_ps >= clean.time_ps, "seed {seed}");
            fired |= !res.report.is_clean();
        }
        assert!(fired, "faults never fired across 4 seeds");
        assert!(clean.report.is_clean());
    }

    #[test]
    fn degraded_gather_routes_around_the_dead() {
        let inputs = msgs(&[10, 20, 30, 40]);
        for kind in [
            TopologyKind::Ring,
            TopologyKind::Full,
            TopologyKind::Star,
            TopologyKind::Tree { branch: 2 },
            TopologyKind::Torus { rows: 2, cols: 2 },
            TopologyKind::Hier { groups: 2 },
        ] {
            let cfg = FabricConfig {
                topology: kind,
                ..FabricConfig::default()
            };
            let res = allgatherv_faulty(&cfg, &inputs, &[1]);
            for &dst in &[0usize, 2, 3] {
                for &src in &[0usize, 2, 3] {
                    assert_eq!(res.gathered[dst][src], inputs[src], "{kind:?} {dst}<-{src}");
                }
                assert!(res.gathered[dst][1].is_empty(), "{kind:?}");
            }
            assert!(res.gathered[1].iter().all(|m| m.is_empty()), "{kind:?}");
            assert_eq!(res.report.reroutes, 1, "{kind:?}");
        }
        // Killing the star's hub re-elects a worker leader.
        let cfg = FabricConfig {
            topology: TopologyKind::Star,
            ..FabricConfig::default()
        };
        let res = allgatherv_faulty(&cfg, &inputs, &[4]);
        for dst in 0..4 {
            for src in 0..4 {
                assert_eq!(res.gathered[dst][src], inputs[src], "{dst}<-{src}");
            }
        }
    }

    #[test]
    fn overlapped_gather_reassembles_bit_identically() {
        // Across topologies and bucket plans, the reassembled matrix
        // must equal the phased gather's bytes exactly — that is the
        // property the trainer's bit-identity rides on.
        let inputs = msgs(&[700, 0, 333, 1024]);
        let phased = ring_allgatherv(&inputs);
        for kind in [
            TopologyKind::Ring,
            TopologyKind::Star,
            TopologyKind::Torus { rows: 2, cols: 2 },
            TopologyKind::Hier { groups: 2 },
        ] {
            for weights in [vec![1024u64], vec![512, 512], vec![1, 7, 3, 1, 9]] {
                let cfg = FabricConfig {
                    topology: kind,
                    segment_bytes: 64,
                    ..FabricConfig::default()
                };
                let res = allgatherv_overlapped(&cfg, &inputs, &weights, 1_000_000, 500_000);
                assert_eq!(res.gathered, phased.gathered, "{kind:?} {weights:?}");
                assert!(res.schedule.overlapped_ps <= res.schedule.phased_ps);
                assert!(res.buckets >= 1);
                assert_eq!(res.segment_bytes, 64, "pinned segment wins");
            }
        }
        // Unpinned: the segment comes from the table's BDP (GigE).
        let res = allgatherv_overlapped(
            &FabricConfig::default(),
            &inputs,
            &[512, 512],
            0,
            0,
        );
        assert_eq!(res.segment_bytes, 12_500);
        assert_eq!(res.gathered, phased.gathered);
    }

    #[test]
    fn overlapped_gather_timing_matches_the_schedule_model() {
        // With zero readiness the overlapped span is pure wire time,
        // and with huge readiness the wire is fully hidden behind it.
        let inputs = msgs(&[4096, 4096, 4096, 4096]);
        let cfg = FabricConfig {
            segment_bytes: 1024,
            ..FabricConfig::default()
        };
        let eager = allgatherv_overlapped(&cfg, &inputs, &[2048, 2048], 0, 0);
        assert_eq!(eager.schedule.overlapped_ps, eager.schedule.comm_busy_ps);
        assert_eq!(eager.schedule.overlapped_ps, eager.schedule.phased_ps);
        let late: Time = 10 * eager.schedule.comm_busy_ps;
        let gated = allgatherv_overlapped(&cfg, &inputs, &[2048, 2048], late, 0);
        assert_eq!(gated.schedule.cpu_ps, late);
        assert!(gated.schedule.overlapped_ps < gated.schedule.phased_ps);
        // Identical per-bucket wire costs in both schedules.
        assert_eq!(gated.schedule.comm_busy_ps, eager.schedule.comm_busy_ps);
        // Traffic is schedule-invariant and matches the phased gather.
        assert_eq!(gated.traffic.total_bytes(), eager.traffic.total_bytes());
    }

    #[test]
    fn gather_results_carry_link_telemetry() {
        let inputs = msgs(&[64, 128, 32, 96]);
        let res = ring_allgatherv(&inputs);
        assert!(!res.telemetry.links.is_empty());
        assert_eq!(res.telemetry.total_bytes(), res.traffic.total_bytes());
        assert_eq!(res.telemetry.elapsed_ps, res.time_ps);
        assert!(res.telemetry.bucket_comm_ps.is_empty(), "unbucketed");
        // Uniform ring: no slow link class.
        assert_eq!(res.telemetry.uplink_byte_fraction(), 0.0);

        // Overlapped on an oversubscribed hier fabric: per-bucket comm
        // times ride along and the uplink share is positive.
        let cfg = FabricConfig {
            topology: TopologyKind::Hier { groups: 2 },
            segment_bytes: 64,
            ..FabricConfig::default()
        };
        let ov = allgatherv_overlapped(&cfg, &inputs, &[512, 512], 1_000_000, 500_000);
        assert_eq!(ov.telemetry.bucket_comm_ps.len(), ov.buckets);
        assert!(ov.telemetry.uplink_byte_fraction() > 0.0, "hier uplinks carry bytes");
        assert!(ov.telemetry.uplink_byte_fraction() < 1.0);
    }

    #[test]
    fn allgatherv_delivers_for_arbitrary_sizes_and_p() {
        testkit::for_all(
            "allgatherv completeness",
            |rng: &mut Pcg32| {
                let p = testkit::usize_in(rng, 1, 12);
                (0..p)
                    .map(|_| {
                        let len = testkit::usize_in(rng, 0, 64);
                        (0..len).map(|_| rng.next_u32() as u8).collect::<Vec<u8>>()
                    })
                    .collect::<Vec<_>>()
            },
            |inputs| {
                let res = ring_allgatherv(inputs);
                for dst in 0..inputs.len() {
                    for src in 0..inputs.len() {
                        if res.gathered[dst][src] != inputs[src] {
                            return Err(format!("corrupt at dst={dst} src={src}"));
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
