//! Allgatherv: every node ends up holding every node's message.
//!
//! [`allgatherv`] is a thin front over the event-driven fabric: it
//! builds the configured [`crate::fabric::Topology`] (ring by default
//! — the paper's substrate — or star/tree/torus/hierarchy/mesh),
//! wires per-link overrides and gather segmentation from the
//! [`FabricConfig`], and moves the *actual bytes* between per-node
//! endpoints — so a bug in block bookkeeping shows up as corrupted
//! codec messages downstream, not just a wrong counter. The gathered
//! matrix is topology-independent (every backend delivers the same
//! bytes); traffic accounting and the simulated wall-clock
//! ([`GatherResult::time_ps`]) come from the configured cluster shape.
//! The trainer's comm phase calls this front, so `--topology` governs
//! the fabric its decode path runs on.
//!
//! [`ring_allgatherv`] keeps the classic default: the p−1-hop ring
//! circulation with traffic `Σ_j n_j − n_(i+1)` per node and p−1
//! rounds, byte- and bit-identical to the pre-fabric lockstep
//! implementation.

use super::Traffic;
use crate::fabric::{build_topology, degraded_topology, Fabric, FabricConfig, FabricReport, Time};

/// Result of one allgatherv: `gathered[dst][src]` is node `src`'s
/// message as received by node `dst` (every row must be identical —
/// asserted in debug builds and by tests).
pub struct GatherResult {
    pub gathered: Vec<Vec<Vec<u8>>>,
    pub traffic: Traffic,
    /// Simulated completion time on the configured fabric, ps.
    pub time_ps: Time,
    /// Fault/recovery counters from the fabric (all zero when the
    /// chaos plan is empty or nothing fired).
    pub report: FabricReport,
}

/// Run an allgatherv over each node's input message on the configured
/// topology/link model. Link faults in `cfg.faults` are masked by
/// retransmission — the gathered bytes are unchanged, only timing and
/// the [`FabricReport`] counters move.
pub fn allgatherv(cfg: &FabricConfig, inputs: &[Vec<u8>]) -> GatherResult {
    let p = inputs.len();
    assert!(p > 0, "allgatherv needs at least one node");
    let topo = build_topology(cfg.topology, p);
    let mut fabric = Fabric::for_topology(cfg, &*topo);
    let sim = topo.allgatherv(&mut fabric, inputs);
    GatherResult {
        gathered: sim.gathered,
        traffic: sim.traffic,
        time_ps: sim.time_ps,
        report: fabric.report(),
    }
}

/// Allgatherv over the survivors of a crash: nodes in `dead` take no
/// part, the topology re-spans the live set
/// ([`degraded_topology`] — route-around for ring/torus, leader
/// re-election for star/tree/hier), and the gathered matrix keeps the
/// original worker indexing with empty rows/columns for the dead.
/// `dead` may also name a star's hub (`inputs.len()`). An empty `dead`
/// takes exactly the plain [`allgatherv`] path.
pub fn allgatherv_faulty(cfg: &FabricConfig, inputs: &[Vec<u8>], dead: &[usize]) -> GatherResult {
    if dead.is_empty() {
        return allgatherv(cfg, inputs);
    }
    let p = inputs.len();
    assert!(p > 0, "allgatherv needs at least one node");
    let (topo, rank_map, phys) = degraded_topology(cfg.topology, p, dead);
    let live: Vec<usize> = (0..p).filter(|w| !dead.contains(w)).collect();
    let sub_inputs: Vec<Vec<u8>> = live.iter().map(|&w| inputs[w].clone()).collect();
    let mut fabric = Fabric::for_degraded(cfg, &*topo, rank_map, phys);
    fabric.note_reroutes(dead.len() as u64);
    let sim = topo.allgatherv(&mut fabric, &sub_inputs);
    let mut gathered = vec![vec![Vec::new(); p]; p];
    for (li, &dst) in live.iter().enumerate() {
        for (lj, &src) in live.iter().enumerate() {
            gathered[dst][src] = sim.gathered[li][lj].clone();
        }
    }
    GatherResult {
        gathered,
        traffic: sim.traffic,
        time_ps: sim.time_ps,
        report: fabric.report(),
    }
}

/// Run a ring allgatherv over each node's input message (the default
/// fabric config: uniform GigE links, no segmentation).
pub fn ring_allgatherv(inputs: &[Vec<u8>]) -> GatherResult {
    allgatherv(&FabricConfig::default(), inputs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::TopologyKind;
    use crate::testkit;
    use crate::util::rng::Pcg32;

    fn msgs(sizes: &[usize]) -> Vec<Vec<u8>> {
        sizes
            .iter()
            .enumerate()
            .map(|(i, &s)| (0..s).map(|j| ((i * 131 + j) % 251) as u8).collect())
            .collect()
    }

    #[test]
    fn every_node_receives_every_message_exactly_once() {
        let inputs = msgs(&[10, 0, 5, 33]);
        let res = ring_allgatherv(&inputs);
        for dst in 0..4 {
            for src in 0..4 {
                assert_eq!(
                    res.gathered[dst][src], inputs[src],
                    "dst={dst} src={src}"
                );
            }
        }
        assert_eq!(res.traffic.rounds, 3);
        assert!(res.time_ps > 0);
    }

    #[test]
    fn single_node_is_a_noop() {
        let inputs = msgs(&[7]);
        let res = ring_allgatherv(&inputs);
        assert_eq!(res.gathered[0][0], inputs[0]);
        assert_eq!(res.traffic.total_bytes(), 0);
        assert_eq!(res.traffic.rounds, 0);
    }

    #[test]
    fn traffic_each_node_forwards_all_but_its_final_block() {
        // In a p-ring each node transmits every block except the one it
        // only receives in the last round: total per node = Σ_j n_j − n_(i+1).
        let sizes = [100usize, 200, 50, 400];
        let inputs = msgs(&sizes);
        let res = ring_allgatherv(&inputs);
        let p = sizes.len();
        for i in 0..p {
            let expected: u64 = (0..p)
                .filter(|&j| j != (i + 1) % p)
                .map(|j| sizes[j] as u64)
                .sum();
            assert_eq!(res.traffic.bytes_sent_per_node[i], expected, "node {i}");
        }
    }

    #[test]
    fn configured_topology_changes_timing_not_bytes() {
        let inputs = msgs(&[64, 128, 32, 96]);
        let ring = ring_allgatherv(&inputs);
        let star = allgatherv(
            &FabricConfig {
                topology: TopologyKind::Star,
                ..FabricConfig::default()
            },
            &inputs,
        );
        assert_eq!(ring.gathered, star.gathered, "bytes are topology-invariant");
        assert_ne!(ring.time_ps, star.time_ps, "timing reflects the topology");
    }

    #[test]
    fn link_faults_are_masked_in_the_gathered_bytes() {
        let inputs = msgs(&[64, 128, 32, 96]);
        let clean = ring_allgatherv(&inputs);
        let mut fired = false;
        for seed in 0..4 {
            let res = allgatherv(
                &FabricConfig {
                    seed,
                    faults: crate::fabric::FaultPlan::parse("drop:0-1:0.5,corrupt:2-3:0.4")
                        .unwrap(),
                    ..FabricConfig::default()
                },
                &inputs,
            );
            assert_eq!(res.gathered, clean.gathered, "seed {seed}: bytes fault-invariant");
            assert!(res.time_ps >= clean.time_ps, "seed {seed}");
            fired |= !res.report.is_clean();
        }
        assert!(fired, "faults never fired across 4 seeds");
        assert!(clean.report.is_clean());
    }

    #[test]
    fn degraded_gather_routes_around_the_dead() {
        let inputs = msgs(&[10, 20, 30, 40]);
        for kind in [
            TopologyKind::Ring,
            TopologyKind::Full,
            TopologyKind::Star,
            TopologyKind::Tree { branch: 2 },
            TopologyKind::Torus { rows: 2, cols: 2 },
            TopologyKind::Hier { groups: 2 },
        ] {
            let cfg = FabricConfig {
                topology: kind,
                ..FabricConfig::default()
            };
            let res = allgatherv_faulty(&cfg, &inputs, &[1]);
            for &dst in &[0usize, 2, 3] {
                for &src in &[0usize, 2, 3] {
                    assert_eq!(res.gathered[dst][src], inputs[src], "{kind:?} {dst}<-{src}");
                }
                assert!(res.gathered[dst][1].is_empty(), "{kind:?}");
            }
            assert!(res.gathered[1].iter().all(|m| m.is_empty()), "{kind:?}");
            assert_eq!(res.report.reroutes, 1, "{kind:?}");
        }
        // Killing the star's hub re-elects a worker leader.
        let cfg = FabricConfig {
            topology: TopologyKind::Star,
            ..FabricConfig::default()
        };
        let res = allgatherv_faulty(&cfg, &inputs, &[4]);
        for dst in 0..4 {
            for src in 0..4 {
                assert_eq!(res.gathered[dst][src], inputs[src], "{dst}<-{src}");
            }
        }
    }

    #[test]
    fn allgatherv_delivers_for_arbitrary_sizes_and_p() {
        testkit::for_all(
            "allgatherv completeness",
            |rng: &mut Pcg32| {
                let p = testkit::usize_in(rng, 1, 12);
                (0..p)
                    .map(|_| {
                        let len = testkit::usize_in(rng, 0, 64);
                        (0..len).map(|_| rng.next_u32() as u8).collect::<Vec<u8>>()
                    })
                    .collect::<Vec<_>>()
            },
            |inputs| {
                let res = ring_allgatherv(inputs);
                for dst in 0..inputs.len() {
                    for src in 0..inputs.len() {
                        if res.gathered[dst][src] != inputs[src] {
                            return Err(format!("corrupt at dst={dst} src={src}"));
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
