//! Ring allgatherv: every node ends up holding every node's message.
//!
//! Implements the classic p−1-round ring: in round t, node i sends the
//! block that *originated* at node `(i − t) mod p` to its right
//! neighbour `(i+1) mod p`. Bytes genuinely move between per-node
//! mailboxes, so a bug in block bookkeeping shows up as corrupted codec
//! messages downstream, not just a wrong counter.
//!
//! Wall-clock is modeled (not measured) with the paper's pipelined-ring
//! bound (Träff et al. 2008; Sec. 5): see [`costmodel`].

use super::Traffic;

/// Result of one allgatherv: `gathered[dst][src]` is node `src`'s
/// message as received by node `dst` (every row must be identical —
/// asserted in debug builds and by tests).
pub struct GatherResult {
    pub gathered: Vec<Vec<Vec<u8>>>,
    pub traffic: Traffic,
}

/// Run a ring allgatherv over each node's input message.
pub fn ring_allgatherv(inputs: &[Vec<u8>]) -> GatherResult {
    let p = inputs.len();
    assert!(p > 0, "allgatherv needs at least one node");
    // blocks[node][origin] = Option<bytes>
    let mut blocks: Vec<Vec<Option<Vec<u8>>>> = (0..p)
        .map(|i| {
            let mut row = vec![None; p];
            row[i] = Some(inputs[i].clone());
            row
        })
        .collect();
    let mut bytes_sent = vec![0u64; p];

    for t in 0..p.saturating_sub(1) {
        // Compute all sends for this round first (synchronous rounds:
        // everyone sends in parallel), then deliver.
        let mut in_flight: Vec<(usize, usize, Vec<u8>)> = Vec::with_capacity(p);
        for i in 0..p {
            let origin = (i + p - t) % p;
            let block = blocks[i][origin]
                .as_ref()
                .expect("ring invariant: block present")
                .clone();
            bytes_sent[i] += block.len() as u64;
            in_flight.push((origin, (i + 1) % p, block));
        }
        for (origin, dst, block) in in_flight {
            debug_assert!(
                blocks[dst][origin].is_none() || blocks[dst][origin].as_deref() == Some(&block),
                "conflicting delivery"
            );
            blocks[dst][origin] = Some(block);
        }
    }

    let gathered: Vec<Vec<Vec<u8>>> = blocks
        .into_iter()
        .map(|row| {
            row.into_iter()
                .map(|b| b.expect("all blocks delivered after p-1 rounds"))
                .collect()
        })
        .collect();

    GatherResult {
        gathered,
        traffic: Traffic {
            bytes_sent_per_node: bytes_sent,
            rounds: p.saturating_sub(1) as u32,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;
    use crate::util::rng::Pcg32;

    fn msgs(sizes: &[usize]) -> Vec<Vec<u8>> {
        sizes
            .iter()
            .enumerate()
            .map(|(i, &s)| (0..s).map(|j| ((i * 131 + j) % 251) as u8).collect())
            .collect()
    }

    #[test]
    fn every_node_receives_every_message_exactly_once() {
        let inputs = msgs(&[10, 0, 5, 33]);
        let res = ring_allgatherv(&inputs);
        for dst in 0..4 {
            for src in 0..4 {
                assert_eq!(
                    res.gathered[dst][src], inputs[src],
                    "dst={dst} src={src}"
                );
            }
        }
        assert_eq!(res.traffic.rounds, 3);
    }

    #[test]
    fn single_node_is_a_noop() {
        let inputs = msgs(&[7]);
        let res = ring_allgatherv(&inputs);
        assert_eq!(res.gathered[0][0], inputs[0]);
        assert_eq!(res.traffic.total_bytes(), 0);
        assert_eq!(res.traffic.rounds, 0);
    }

    #[test]
    fn traffic_each_node_forwards_all_but_its_final_block() {
        // In a p-ring each node transmits every block except the one it
        // only receives in the last round: total per node = Σ_j n_j − n_(i+1).
        let sizes = [100usize, 200, 50, 400];
        let inputs = msgs(&sizes);
        let res = ring_allgatherv(&inputs);
        let p = sizes.len();
        for i in 0..p {
            let expected: u64 = (0..p)
                .filter(|&j| j != (i + 1) % p)
                .map(|j| sizes[j] as u64)
                .sum();
            assert_eq!(res.traffic.bytes_sent_per_node[i], expected, "node {i}");
        }
    }

    #[test]
    fn allgatherv_delivers_for_arbitrary_sizes_and_p() {
        testkit::for_all(
            "allgatherv completeness",
            |rng: &mut Pcg32| {
                let p = testkit::usize_in(rng, 1, 12);
                (0..p)
                    .map(|_| {
                        let len = testkit::usize_in(rng, 0, 64);
                        (0..len).map(|_| rng.next_u32() as u8).collect::<Vec<u8>>()
                    })
                    .collect::<Vec<_>>()
            },
            |inputs| {
                let res = ring_allgatherv(inputs);
                for dst in 0..inputs.len() {
                    for src in 0..inputs.len() {
                        if res.gathered[dst][src] != inputs[src] {
                            return Err(format!("corrupt at dst={dst} src={src}"));
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
