//! Ring allgatherv: every node ends up holding every node's message.
//!
//! This is now a thin front over the event-driven fabric's ring
//! backend ([`crate::fabric::ring`]): the classic p−1-hop circulation
//! where each node injects its own block rightward and forwards every
//! block it receives except the one that completes its set. Bytes
//! genuinely move between per-node endpoints, so a bug in block
//! bookkeeping shows up as corrupted codec messages downstream, not
//! just a wrong counter. Traffic accounting is unchanged from the
//! pre-fabric lockstep implementation (Σ_j n_j − n_(i+1) per node,
//! p−1 rounds).
//!
//! Wall-clock on this path stays *modeled* as before (the default
//! fabric config is deterministic and contention-free here — see
//! [`costmodel`] for the paper's pipelined-ring bound and its
//! simulated cross-check); callers that want simulated time, jitter,
//! stragglers or other topologies use `fabric` directly.

use super::Traffic;
use crate::fabric::{build_topology, Fabric, FabricConfig, TopologyKind};

/// Result of one allgatherv: `gathered[dst][src]` is node `src`'s
/// message as received by node `dst` (every row must be identical —
/// asserted in debug builds and by tests).
pub struct GatherResult {
    pub gathered: Vec<Vec<Vec<u8>>>,
    pub traffic: Traffic,
}

/// Run a ring allgatherv over each node's input message.
pub fn ring_allgatherv(inputs: &[Vec<u8>]) -> GatherResult {
    let p = inputs.len();
    assert!(p > 0, "allgatherv needs at least one node");
    let topo = build_topology(TopologyKind::Ring, p);
    let mut fabric = Fabric::for_config(&FabricConfig::default(), topo.node_count());
    let sim = topo.allgatherv(&mut fabric, inputs);
    GatherResult {
        gathered: sim.gathered,
        traffic: sim.traffic,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;
    use crate::util::rng::Pcg32;

    fn msgs(sizes: &[usize]) -> Vec<Vec<u8>> {
        sizes
            .iter()
            .enumerate()
            .map(|(i, &s)| (0..s).map(|j| ((i * 131 + j) % 251) as u8).collect())
            .collect()
    }

    #[test]
    fn every_node_receives_every_message_exactly_once() {
        let inputs = msgs(&[10, 0, 5, 33]);
        let res = ring_allgatherv(&inputs);
        for dst in 0..4 {
            for src in 0..4 {
                assert_eq!(
                    res.gathered[dst][src], inputs[src],
                    "dst={dst} src={src}"
                );
            }
        }
        assert_eq!(res.traffic.rounds, 3);
    }

    #[test]
    fn single_node_is_a_noop() {
        let inputs = msgs(&[7]);
        let res = ring_allgatherv(&inputs);
        assert_eq!(res.gathered[0][0], inputs[0]);
        assert_eq!(res.traffic.total_bytes(), 0);
        assert_eq!(res.traffic.rounds, 0);
    }

    #[test]
    fn traffic_each_node_forwards_all_but_its_final_block() {
        // In a p-ring each node transmits every block except the one it
        // only receives in the last round: total per node = Σ_j n_j − n_(i+1).
        let sizes = [100usize, 200, 50, 400];
        let inputs = msgs(&sizes);
        let res = ring_allgatherv(&inputs);
        let p = sizes.len();
        for i in 0..p {
            let expected: u64 = (0..p)
                .filter(|&j| j != (i + 1) % p)
                .map(|j| sizes[j] as u64)
                .sum();
            assert_eq!(res.traffic.bytes_sent_per_node[i], expected, "node {i}");
        }
    }

    #[test]
    fn allgatherv_delivers_for_arbitrary_sizes_and_p() {
        testkit::for_all(
            "allgatherv completeness",
            |rng: &mut Pcg32| {
                let p = testkit::usize_in(rng, 1, 12);
                (0..p)
                    .map(|_| {
                        let len = testkit::usize_in(rng, 0, 64);
                        (0..len).map(|_| rng.next_u32() as u8).collect::<Vec<u8>>()
                    })
                    .collect::<Vec<_>>()
            },
            |inputs| {
                let res = ring_allgatherv(inputs);
                for dst in 0..inputs.len() {
                    for src in 0..inputs.len() {
                        if res.gathered[dst][src] != inputs[src] {
                            return Err(format!("corrupt at dst={dst} src={src}"));
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
