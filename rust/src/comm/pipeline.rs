//! Bucketed comm/compute overlap: tensor fusion, BDP segment sizing,
//! and the pipeline schedule arithmetic.
//!
//! The phased trainer runs encode → gather → decode as strict
//! sequential phases, so a step costs the *sum* of compute and
//! communication. This module supplies the three pieces that turn the
//! step into a pipeline whose cost approaches their *max*:
//!
//! 1. **Bucket formation** ([`form_buckets`]): layer groups are fused
//!    into buckets by greedy fill in *reverse* layer order — backprop
//!    produces the last layer's gradients first, so the model tail is
//!    bucket 0 and can enter the wire while earlier layers are still
//!    computing (ACP-SGD-style tensor fusion, ~MB thresholds).
//! 2. **Segment sizing** ([`bdp_segment_bytes`]): the gather pipeline
//!    segment defaults to the bandwidth-delay product of the slowest
//!    link the fabric's [`LinkTable`] can resolve, so one segment keeps
//!    the worst wire busy for a full round trip. A pinned
//!    `--segment-bytes` always wins ([`effective_segment_bytes`]).
//! 3. **Schedule arithmetic** ([`schedule`]): given per-bucket
//!    readiness times (compute + encode) and per-bucket gather
//!    durations measured on the event clock, the max-plus recurrence
//!    yields the overlapped finish, the phased finish, and the ideal
//!    `max(T_compute, T_comm)` bound — with `overlapped ≤ phased`
//!    guaranteed structurally (same durations, earlier starts).
//!
//! Correctness never rides on the schedule: buckets are byte slices of
//! the *same* encoded messages the phased path sends, reassembled in
//! bucket-index order before decode (`comm::allgatherv::
//! allgatherv_overlapped`), so trained parameters are bit-identical to
//! the phased path for every codec by construction.
//!
//! ```
//! use vgc::comm::pipeline::{form_buckets, bucket_weights, schedule};
//! use vgc::model::Layout;
//!
//! // 4 groups of 256 params (1 KiB dense each), fused at a 2 KiB
//! // threshold: two buckets, and bucket 0 is the model *tail*.
//! let layout = Layout::uniform(1024, 256);
//! let buckets = form_buckets(&layout, 2048);
//! assert_eq!(buckets.len(), 2);
//! assert_eq!(buckets[0].params, 512..1024); // last layers first
//! assert_eq!(buckets[1].params, 0..512);
//!
//! // Overlap hides the shorter side: 2 buckets ready at 10/20 µs,
//! // each needing 30 µs of wire, finish at 70 µs — not the phased
//! // 20 + 60 = 80 µs.
//! let w = bucket_weights(&buckets);
//! assert_eq!(w, vec![2048, 2048]);
//! let sched = schedule(&[10, 20], &[30, 30]);
//! assert_eq!(sched.overlapped_ps, 70);
//! assert_eq!(sched.phased_ps, 80);
//! assert_eq!(sched.ideal_ps(), 60); // max(compute 20, comm 60)
//! ```
//!
//! ```
//! use vgc::comm::pipeline::bdp_segment_bytes;
//! use vgc::fabric::{LinkSpec, LinkTable};
//!
//! // GigE: 1 Gb/s × 2·50 µs RTT = 100 kbit in flight = 12.5 kB.
//! let table = LinkTable::uniform(LinkSpec::gige());
//! assert_eq!(bdp_segment_bytes(&table), 12_500);
//! ```

use std::ops::Range;

use crate::fabric::{LinkTable, Time};
use crate::model::Layout;

/// Smallest BDP segment ever returned: below this, per-segment framing
/// events dominate the simulation for no pipelining benefit.
pub const MIN_SEGMENT_BYTES: usize = 64;

/// One fused layer-group bucket: a contiguous span of layout groups
/// and the contiguous parameter range they cover. Bucket index 0 is
/// the **last** span of the model (reverse layer order — the gather
/// order), so `groups`/`params` of successive buckets walk backward
/// through the layout.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Bucket {
    /// Indices into `layout.groups()`, forward orientation.
    pub groups: Range<usize>,
    /// Parameter index range the groups cover, forward orientation.
    pub params: Range<usize>,
}

impl Bucket {
    /// Dense f32 footprint of this bucket, bytes.
    pub fn dense_bytes(&self) -> u64 {
        self.params.len() as u64 * 4
    }
}

/// Fuse layout groups into buckets by greedy fill in reverse layer
/// order: walk groups from the last to the first, closing a bucket
/// once its dense footprint reaches `bucket_bytes`. `bucket_bytes = 0`
/// disables fusion (one bucket spanning the whole model — the phased
/// layout). Every group lands in exactly one bucket and the buckets'
/// parameter ranges tile `0..layout.n()` back to front.
pub fn form_buckets(layout: &Layout, bucket_bytes: usize) -> Vec<Bucket> {
    let groups = layout.groups();
    if groups.is_empty() {
        return Vec::new();
    }
    if bucket_bytes == 0 {
        return vec![Bucket {
            groups: 0..groups.len(),
            params: 0..layout.n(),
        }];
    }
    let mut out = Vec::new();
    let mut hi = groups.len(); // exclusive group bound of the open bucket
    let mut acc = 0u64; // dense bytes accumulated in the open bucket
    for gi in (0..groups.len()).rev() {
        acc += groups[gi].len as u64 * 4;
        if acc >= bucket_bytes as u64 {
            out.push(span_bucket(layout, gi..hi));
            hi = gi;
            acc = 0;
        }
    }
    if hi > 0 {
        out.push(span_bucket(layout, 0..hi));
    }
    out
}

fn span_bucket(layout: &Layout, groups: Range<usize>) -> Bucket {
    let g = layout.groups();
    let lo = g[groups.start].offset;
    let last = &g[groups.end - 1];
    Bucket {
        params: lo..last.offset + last.len,
        groups,
    }
}

/// Per-bucket dense byte weights, in bucket (gather) order. These
/// weight both the compute/encode readiness model and the
/// proportional slicing of encoded messages.
pub fn bucket_weights(buckets: &[Bucket]) -> Vec<u64> {
    buckets.iter().map(Bucket::dense_bytes).collect()
}

/// Bandwidth-delay product of the slowest link `table` can resolve,
/// in bytes (floor, clamped to [`MIN_SEGMENT_BYTES`]): bandwidth ×
/// one round trip (2 × latency). One such segment keeps the worst
/// wire in the fabric busy while its acknowledgement-equivalent — the
/// next pipeline stage's forward — is still in flight.
pub fn bdp_segment_bytes(table: &LinkTable) -> usize {
    let worst = table.slowest_spec();
    let bits = worst.bandwidth_gbps * 1e9 * (2.0 * worst.latency_us * 1e-6);
    ((bits / 8.0) as usize).max(MIN_SEGMENT_BYTES)
}

/// The gather segment size the pipeline should use: a pinned
/// `--segment-bytes` (`pinned > 0`) wins; otherwise the BDP of the
/// slowest link ([`bdp_segment_bytes`]).
pub fn effective_segment_bytes(pinned: usize, table: &LinkTable) -> usize {
    if pinned > 0 {
        pinned
    } else {
        bdp_segment_bytes(table)
    }
}

/// Coalesce adjacent bucket weights until each bucket's share of a
/// `max_len`-byte message is at least `min_bytes` (normally the
/// segment size — a bucket smaller than one segment only adds
/// per-bucket latency rounds without pipelining anything). The merge
/// is decided once from the *largest* worker message so every worker
/// slices at the same bucket boundaries. A short tail merges into the
/// previous bucket. Never returns an empty plan for non-empty input.
pub fn merge_weights(weights: &[u64], max_len: usize, min_bytes: usize) -> Vec<u64> {
    let total: u64 = weights.iter().sum();
    if weights.is_empty() || total == 0 {
        return vec![total.max(1); usize::from(!weights.is_empty())];
    }
    let mut out: Vec<u64> = Vec::new();
    let mut acc = 0u64;
    for &w in weights {
        acc += w;
        // share of the largest message this merged bucket would get
        let share = (max_len as u128 * acc as u128 / total as u128) as usize;
        if share >= min_bytes {
            out.push(acc);
            acc = 0;
        }
    }
    if acc > 0 {
        match out.last_mut() {
            Some(last) => *last += acc,
            None => out.push(acc),
        }
    }
    out
}

/// Split a `len`-byte message into one slice per weight, proportional
/// with exact total: cut points are `len · cum_weight / total`
/// (integer floor), so slices are non-negative, ordered, and always
/// sum to `len` — concatenating the slices in bucket order reproduces
/// the message byte for byte.
pub fn split_by_weights(len: usize, weights: &[u64]) -> Vec<usize> {
    let total: u64 = weights.iter().sum();
    if weights.is_empty() {
        return Vec::new();
    }
    if total == 0 {
        // Degenerate all-zero weights: everything in the last slice.
        let mut out = vec![0; weights.len()];
        *out.last_mut().unwrap() = len;
        return out;
    }
    let mut out = Vec::with_capacity(weights.len());
    let mut cum = 0u64;
    let mut prev_cut = 0usize;
    for &w in weights {
        cum += w;
        let cut = (len as u128 * cum as u128 / total as u128) as usize;
        out.push(cut - prev_cut);
        prev_cut = cut;
    }
    debug_assert_eq!(out.iter().sum::<usize>(), len);
    out
}

/// Per-bucket encode-finish times (ps) under the pipelined compute
/// model: backprop produces gradients in bucket order at a uniform
/// rate (`grad_ps` total, split by weight), and one encoder drains
/// buckets in order (`encode_ps` total, split by weight), starting
/// each bucket as soon as its gradients exist and the previous encode
/// finished. `ready[k]` is when bucket `k` may enter the wire; the
/// last entry is the step's total compute+encode span.
pub fn ready_times(weights: &[u64], grad_ps: Time, encode_ps: Time) -> Vec<Time> {
    let total: u64 = weights.iter().sum::<u64>().max(1);
    let mut out = Vec::with_capacity(weights.len());
    let mut cum = 0u64;
    let mut enc_prev = 0 as Time;
    let mut fin = 0 as Time;
    for &w in weights {
        cum += w;
        let grad_ready = (grad_ps as u128 * cum as u128 / total as u128) as Time;
        let enc_cum = (encode_ps as u128 * cum as u128 / total as u128) as Time;
        fin = fin.max(grad_ready) + (enc_cum - enc_prev);
        enc_prev = enc_cum;
        out.push(fin);
    }
    out
}

/// The two step-time accountings the sweep and trainer report, built
/// from one set of per-bucket gather durations (see [`schedule`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct OverlapSchedule {
    /// Absolute finish time of each bucket's gather, overlapped.
    pub bucket_done_ps: Vec<Time>,
    /// Overlapped step span: last gather finish (compute hidden
    /// behind communication up to the fill/drain tails).
    pub overlapped_ps: Time,
    /// Phased step span: all compute+encode, then all communication.
    pub phased_ps: Time,
    /// Pure wire time (sum of per-bucket gather durations).
    pub comm_busy_ps: Time,
    /// Compute + encode span (the last readiness time).
    pub cpu_ps: Time,
}

impl OverlapSchedule {
    /// The un-achievable lower bound: perfect overlap with zero
    /// fill/drain, `max(T_compute, T_comm)`.
    pub fn ideal_ps(&self) -> Time {
        self.cpu_ps.max(self.comm_busy_ps)
    }

    /// Overlap efficiency: `ideal / overlapped` ∈ (0, 1]. 1.0 means
    /// the step costs exactly `max(compute, comm)`; the ROADMAP
    /// target ("within ~10% of the max") is ≥ 0.9.
    pub fn efficiency(&self) -> f64 {
        if self.overlapped_ps == 0 {
            1.0
        } else {
            self.ideal_ps() as f64 / self.overlapped_ps as f64
        }
    }

    /// Phased-over-overlapped speedup (≥ 1 by construction).
    pub fn speedup(&self) -> f64 {
        if self.overlapped_ps == 0 {
            1.0
        } else {
            self.phased_ps as f64 / self.overlapped_ps as f64
        }
    }
}

/// Max-plus pipeline recurrence: bucket `k`'s gather starts at
/// `max(ready[k], previous gather finish)` and takes `comm[k]`.
/// Phased runs the same durations after *all* compute+encode
/// (`ready.last()`), so `overlapped_ps ≤ phased_ps` always — the
/// overlapped schedule only moves starts earlier against identical
/// per-bucket costs.
///
/// ```
/// use vgc::comm::pipeline::schedule;
/// // Comm-bound: 3 buckets ready early, the wire never starves.
/// let s = schedule(&[5, 10, 15], &[100, 100, 100]);
/// assert_eq!(s.overlapped_ps, 305); // fill 5, then 300 of wire
/// assert_eq!(s.phased_ps, 315);
/// assert!(s.efficiency() > 0.98);
/// ```
pub fn schedule(ready_ps: &[Time], comm_ps: &[Time]) -> OverlapSchedule {
    assert_eq!(
        ready_ps.len(),
        comm_ps.len(),
        "one readiness time per bucket"
    );
    let mut done = Vec::with_capacity(comm_ps.len());
    let mut fin = 0 as Time;
    let mut busy = 0 as Time;
    for (&r, &c) in ready_ps.iter().zip(comm_ps) {
        fin = fin.max(r) + c;
        busy += c;
        done.push(fin);
    }
    let cpu = ready_ps.last().copied().unwrap_or(0);
    OverlapSchedule {
        overlapped_ps: fin,
        phased_ps: cpu + busy,
        comm_busy_ps: busy,
        cpu_ps: cpu,
        bucket_done_ps: done,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::LinkSpec;
    use crate::testkit;
    use crate::util::rng::Pcg32;

    #[test]
    fn buckets_tile_the_layout_back_to_front() {
        let layout = Layout::uniform(1000, 128); // 8 groups, last short
        for bucket_bytes in [0usize, 1, 256, 1024, 4096, 1 << 20] {
            let buckets = form_buckets(&layout, bucket_bytes);
            assert!(!buckets.is_empty());
            // Walk back to front: bucket 0 must end at n, the last
            // bucket must start at 0, spans must abut.
            assert_eq!(buckets[0].params.end, 1000, "bytes={bucket_bytes}");
            assert_eq!(buckets.last().unwrap().params.start, 0);
            assert_eq!(buckets[0].groups.end, layout.n_groups());
            for w in buckets.windows(2) {
                assert_eq!(w[1].params.end, w[0].params.start);
                assert_eq!(w[1].groups.end, w[0].groups.start);
            }
            let total: usize = buckets.iter().map(|b| b.params.len()).sum();
            assert_eq!(total, 1000);
            // Threshold respected: every bucket but the head of the
            // model reaches the fill target.
            if bucket_bytes > 0 {
                for b in &buckets[..buckets.len() - 1] {
                    assert!(b.dense_bytes() >= bucket_bytes as u64);
                }
            }
        }
        // Degenerate 1-byte threshold: every group its own bucket.
        assert_eq!(form_buckets(&layout, 1).len(), layout.n_groups());
        // No fusion: one bucket over everything.
        let all = form_buckets(&layout, 0);
        assert_eq!(all.len(), 1);
        assert_eq!(all[0].params, 0..1000);
    }

    #[test]
    fn bdp_tracks_the_slowest_link() {
        let mut t = LinkTable::uniform(LinkSpec::infiniband());
        assert_eq!(bdp_segment_bytes(&t), 50_000); // 100 Gb/s × 4 µs RTT
        t.set(0, 1, LinkSpec::gige());
        assert_eq!(bdp_segment_bytes(&t), 12_500); // 1 Gb/s × 100 µs RTT
        let zero = LinkTable::uniform(LinkSpec {
            bandwidth_gbps: 1.0,
            latency_us: 0.0,
            jitter_us: 0.0,
        });
        assert_eq!(bdp_segment_bytes(&zero), MIN_SEGMENT_BYTES);
        // Pinning wins; auto falls back to BDP.
        assert_eq!(effective_segment_bytes(4096, &t), 4096);
        assert_eq!(effective_segment_bytes(0, &t), 12_500);
    }

    #[test]
    fn split_is_exact_and_ordered() {
        testkit::for_all(
            "split_by_weights exactness",
            |rng: &mut Pcg32| {
                let b = testkit::usize_in(rng, 1, 9);
                let weights: Vec<u64> =
                    (0..b).map(|_| testkit::usize_in(rng, 0, 5000) as u64).collect();
                let len = testkit::usize_in(rng, 0, 100_000);
                (len, weights)
            },
            |(len, weights)| {
                let slices = split_by_weights(*len, weights);
                if slices.len() != weights.len() {
                    return Err("slice count".into());
                }
                if slices.iter().sum::<usize>() != *len {
                    return Err(format!("sum {} != len {len}", slices.iter().sum::<usize>()));
                }
                Ok(())
            },
        );
        assert_eq!(split_by_weights(10, &[1, 1]), vec![5, 5]);
        assert_eq!(split_by_weights(0, &[3, 7]), vec![0, 0]);
        assert_eq!(split_by_weights(10, &[0, 0]), vec![0, 10]);
        assert!(split_by_weights(10, &[]).is_empty());
    }

    #[test]
    fn merge_collapses_sub_segment_buckets() {
        // 4 × 1 KiB buckets of a 4 KiB message, 2 KiB segment: pairs.
        assert_eq!(merge_weights(&[1024; 4], 4096, 2048), vec![2048, 2048]);
        // Message far smaller than the segment: one bucket.
        assert_eq!(merge_weights(&[1024; 4], 100, 2048), vec![4096]);
        // Segment already smaller than every share: untouched.
        assert_eq!(merge_weights(&[1024; 4], 4096, 1), vec![1024; 4]);
        // Short tail folds backward.
        assert_eq!(merge_weights(&[4096, 4096, 64], 8256, 2048), vec![4096, 4160]);
        // Weight is conserved in every case.
        testkit::for_all(
            "merge_weights conservation",
            |rng: &mut Pcg32| {
                let b = testkit::usize_in(rng, 1, 9);
                let weights: Vec<u64> =
                    (0..b).map(|_| testkit::usize_in(rng, 1, 5000) as u64).collect();
                let max_len = testkit::usize_in(rng, 0, 20_000);
                let min_bytes = testkit::usize_in(rng, 1, 8192);
                (weights, max_len, min_bytes)
            },
            |(weights, max_len, min_bytes)| {
                let merged = merge_weights(weights, *max_len, *min_bytes);
                if merged.is_empty() {
                    return Err("empty plan".into());
                }
                if merged.iter().sum::<u64>() != weights.iter().sum::<u64>() {
                    return Err("weight not conserved".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn ready_times_honor_both_producers() {
        // Pure backprop: readiness is the cumulative gradient time.
        assert_eq!(ready_times(&[1, 1], 100, 0), vec![50, 100]);
        // Pure encode: a single serial encoder drains in order.
        assert_eq!(ready_times(&[1, 1], 0, 100), vec![50, 100]);
        // Both: encode of bucket 1 waits for its gradients, then
        // costs its share.
        assert_eq!(ready_times(&[1, 1], 100, 100), vec![100, 200]);
        // The last readiness is always the full compute+encode span.
        assert_eq!(*ready_times(&[3, 2, 5], 997, 301).last().unwrap(), 997 + 301);
        assert!(ready_times(&[], 10, 10).is_empty());
    }

    #[test]
    fn schedule_overlapped_never_exceeds_phased() {
        testkit::for_all(
            "overlap bounds",
            |rng: &mut Pcg32| {
                let b = testkit::usize_in(rng, 1, 8);
                let weights: Vec<u64> =
                    (0..b).map(|_| testkit::usize_in(rng, 1, 1000) as u64).collect();
                let grad = testkit::usize_in(rng, 0, 1_000_000) as Time;
                let enc = testkit::usize_in(rng, 0, 1_000_000) as Time;
                let comm: Vec<Time> = (0..b)
                    .map(|_| testkit::usize_in(rng, 0, 1_000_000) as Time)
                    .collect();
                (weights, grad, enc, comm)
            },
            |(weights, grad, enc, comm)| {
                let ready = ready_times(weights, *grad, *enc);
                let s = schedule(&ready, comm);
                if s.overlapped_ps > s.phased_ps {
                    return Err(format!("{} > {}", s.overlapped_ps, s.phased_ps));
                }
                if s.overlapped_ps < s.ideal_ps() {
                    return Err("below the ideal bound".into());
                }
                if !(s.efficiency() > 0.0 && s.efficiency() <= 1.0) {
                    return Err(format!("efficiency {}", s.efficiency()));
                }
                if s.speedup() < 1.0 {
                    return Err("speedup < 1".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn schedule_hides_the_shorter_side() {
        // Comm-bound: compute fully hidden behind the wire after fill.
        let ready = ready_times(&[1; 4], 400, 0);
        let s = schedule(&ready, &[1000; 4]);
        assert_eq!(s.overlapped_ps, 100 + 4000); // fill + wire
        assert_eq!(s.phased_ps, 400 + 4000);
        assert_eq!(s.ideal_ps(), 4000);
        // Compute-bound: wire fully hidden except the last drain.
        let ready = ready_times(&[1; 4], 4000, 0);
        let s = schedule(&ready, &[100; 4]);
        assert_eq!(s.overlapped_ps, 4000 + 100);
        assert_eq!(s.ideal_ps(), 4000);
        // Single bucket degenerates to the phased sum.
        let s = schedule(&[500], &[700]);
        assert_eq!(s.overlapped_ps, 1200);
        assert_eq!(s.phased_ps, 1200);
        assert_eq!(s.speedup(), 1.0);
    }
}
