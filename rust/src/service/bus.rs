//! Typed broadcast event bus for the training service.
//!
//! The daemon's single source of truth for *what happened when*: the
//! scheduler publishes job state transitions, executors publish live
//! step metrics, and every consumer — the NDJSON streaming endpoint,
//! tests, the drain path — observes the same totally-ordered stream.
//!
//! Publishers stamp each event with a global sequence number and fan it
//! out to all live subscribers over `std::sync::mpsc` channels. A
//! bounded replay history lets late subscribers (a client asking for
//! `/jobs/:id/events` after the job already ran) see the full life of a
//! job without racing the scheduler: [`Bus::subscribe`] atomically
//! snapshots the history *and* registers the live channel, so backlog
//! and live stream never gap and never overlap. Disconnected
//! subscribers are pruned on the next publish.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};

use crate::util::json::{num, obj, s, Json};

use super::scheduler::JobState;

/// Events older than this are dropped from the replay history (bounded
/// memory for long-running daemons); live subscribers are unaffected.
const HISTORY_CAP: usize = 16_384;

/// An event plus its global publish order.
#[derive(Debug)]
pub struct Stamped<T> {
    pub seq: u64,
    pub event: T,
}

/// Broadcast bus. Events are `Arc`-shared, so publishing to many
/// subscribers clones nothing but the pointer.
pub struct Bus<T> {
    inner: Mutex<BusInner<T>>,
}

struct BusInner<T> {
    subs: Vec<Sender<Arc<Stamped<T>>>>,
    history: Vec<Arc<Stamped<T>>>,
    next_seq: u64,
}

/// One subscription: everything published before the subscribe call
/// (up to the history cap) plus a live channel for everything after.
pub struct Tap<T> {
    pub backlog: Vec<Arc<Stamped<T>>>,
    pub live: Receiver<Arc<Stamped<T>>>,
}

impl<T> Bus<T> {
    pub fn new() -> Bus<T> {
        Bus {
            inner: Mutex::new(BusInner {
                subs: Vec::new(),
                history: Vec::new(),
                next_seq: 0,
            }),
        }
    }

    /// Publish an event to every live subscriber; returns its sequence
    /// number. Subscribers whose receiver was dropped are pruned here.
    pub fn publish(&self, event: T) -> u64 {
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        let seq = inner.next_seq;
        inner.next_seq += 1;
        let ev = Arc::new(Stamped { seq, event });
        inner.subs.retain(|tx| tx.send(ev.clone()).is_ok());
        inner.history.push(ev);
        if inner.history.len() > HISTORY_CAP {
            let drop_n = inner.history.len() - HISTORY_CAP;
            inner.history.drain(..drop_n);
        }
        seq
    }

    /// Snapshot the history and register a live channel, atomically.
    pub fn subscribe(&self) -> Tap<T> {
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        let (tx, rx) = channel();
        inner.subs.push(tx);
        Tap {
            backlog: inner.history.clone(),
            live: rx,
        }
    }

    /// Events published so far (monotone; not reduced by history drops).
    pub fn published(&self) -> u64 {
        self.inner.lock().unwrap_or_else(|e| e.into_inner()).next_seq
    }
}

impl<T> Default for Bus<T> {
    fn default() -> Self {
        Bus::new()
    }
}

/// NaN/inf have no JSON literal; metric fields encode them as null.
fn finite_or_null(x: f64) -> Json {
    if x.is_finite() {
        num(x)
    } else {
        Json::Null
    }
}

/// The service's typed event vocabulary. Every variant names the job it
/// concerns; `to_json` is the NDJSON wire shape of `/jobs/:id/events`.
#[derive(Debug, Clone)]
pub enum Event {
    /// Accepted into a queue.
    JobQueued {
        job: u64,
        name: String,
        kind: &'static str,
        queue: String,
    },
    /// An executor picked the job up (attempt counts from 1).
    JobStarted { job: u64, attempt: u32 },
    /// Live progress from inside an executor (step metrics).
    JobProgress {
        job: u64,
        done: u64,
        total: u64,
        detail: String,
    },
    /// A training step completed — live per-step metrics for `train`
    /// jobs (loss, cumulative compression ratio, simulated step span).
    Step {
        job: u64,
        step: u64,
        loss: f64,
        comp_ratio: f64,
        sim_step_ps: u64,
    },
    /// The attempt failed and the job re-queued with backoff.
    JobRetry {
        job: u64,
        attempt: u32,
        delay_ms: u64,
        error: String,
    },
    /// The fault plan fired inside a training job (crash or rejoin).
    Fault {
        job: u64,
        step: u64,
        kind: String,
        node: usize,
    },
    /// A training step completed over a reduced membership.
    Degraded {
        job: u64,
        step: u64,
        live: usize,
        total: usize,
    },
    /// The adaptive compression controller (`--adaptive`) moved one
    /// bucket's codec knob after this step.
    Knob {
        job: u64,
        step: u64,
        bucket: usize,
        name: &'static str,
        value: f64,
        gain: f64,
    },
    /// Terminal transition; `summary` is the run summary on success.
    JobFinished {
        job: u64,
        state: JobState,
        summary: Option<Json>,
        error: Option<String>,
    },
    /// The scheduler stopped accepting new jobs (graceful shutdown).
    Drain,
}

impl Event {
    /// The job this event concerns (`None` for daemon-wide events).
    pub fn job(&self) -> Option<u64> {
        match self {
            Event::JobQueued { job, .. }
            | Event::JobStarted { job, .. }
            | Event::JobProgress { job, .. }
            | Event::Step { job, .. }
            | Event::JobRetry { job, .. }
            | Event::Fault { job, .. }
            | Event::Degraded { job, .. }
            | Event::Knob { job, .. }
            | Event::JobFinished { job, .. } => Some(*job),
            Event::Drain => None,
        }
    }

    /// True when this event ends the life of `job` (closes its stream).
    pub fn is_terminal_for(&self, job: u64) -> bool {
        matches!(self, Event::JobFinished { job: j, .. } if *j == job)
    }

    /// One NDJSON line of the event stream.
    pub fn to_json(&self) -> Json {
        match self {
            Event::JobQueued {
                job,
                name,
                kind,
                queue,
            } => obj(vec![
                ("event", s("queued")),
                ("job", num(*job as f64)),
                ("name", s(name)),
                ("kind", s(kind)),
                ("queue", s(queue)),
            ]),
            Event::JobStarted { job, attempt } => obj(vec![
                ("event", s("started")),
                ("job", num(*job as f64)),
                ("attempt", num(*attempt as f64)),
            ]),
            Event::JobProgress {
                job,
                done,
                total,
                detail,
            } => obj(vec![
                ("event", s("progress")),
                ("job", num(*job as f64)),
                ("done", num(*done as f64)),
                ("total", num(*total as f64)),
                ("detail", s(detail)),
            ]),
            Event::Step {
                job,
                step,
                loss,
                comp_ratio,
                sim_step_ps,
            } => obj(vec![
                ("event", s("step")),
                ("job", num(*job as f64)),
                ("step", num(*step as f64)),
                ("loss", finite_or_null(*loss)),
                ("comp_ratio", finite_or_null(*comp_ratio)),
                ("sim_step_ps", num(*sim_step_ps as f64)),
            ]),
            Event::JobRetry {
                job,
                attempt,
                delay_ms,
                error,
            } => obj(vec![
                ("event", s("retry")),
                ("job", num(*job as f64)),
                ("attempt", num(*attempt as f64)),
                ("delay_ms", num(*delay_ms as f64)),
                ("error", s(error)),
            ]),
            Event::Fault {
                job,
                step,
                kind,
                node,
            } => obj(vec![
                ("event", s("fault")),
                ("job", num(*job as f64)),
                ("step", num(*step as f64)),
                ("kind", s(kind)),
                ("node", num(*node as f64)),
            ]),
            Event::Degraded {
                job,
                step,
                live,
                total,
            } => obj(vec![
                ("event", s("degraded")),
                ("job", num(*job as f64)),
                ("step", num(*step as f64)),
                ("live", num(*live as f64)),
                ("total", num(*total as f64)),
            ]),
            Event::Knob {
                job,
                step,
                bucket,
                name,
                value,
                gain,
            } => obj(vec![
                ("event", s("knob")),
                ("job", num(*job as f64)),
                ("step", num(*step as f64)),
                ("bucket", num(*bucket as f64)),
                ("name", s(name)),
                ("value", finite_or_null(*value)),
                ("gain", finite_or_null(*gain)),
            ]),
            Event::JobFinished {
                job,
                state,
                summary,
                error,
            } => obj(vec![
                ("event", s("finished")),
                ("job", num(*job as f64)),
                ("state", s(state.label())),
                ("summary", summary.clone().unwrap_or(Json::Null)),
                (
                    "error",
                    error.as_deref().map(s).unwrap_or(Json::Null),
                ),
            ]),
            Event::Drain => obj(vec![("event", s("drain"))]),
        }
    }
}

/// The daemon's bus instantiation.
pub type EventBus = Bus<Event>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backlog_and_live_partition_the_stream() {
        let bus: Bus<u32> = Bus::new();
        bus.publish(1);
        bus.publish(2);
        let tap = bus.subscribe();
        bus.publish(3);
        let backlog: Vec<u32> = tap.backlog.iter().map(|e| e.event).collect();
        assert_eq!(backlog, vec![1, 2]);
        let live = tap.live.recv().unwrap();
        assert_eq!(live.event, 3);
        assert_eq!(live.seq, 2);
        // Sequence numbers are dense across the backlog/live boundary.
        assert_eq!(tap.backlog.last().unwrap().seq, 1);
        assert_eq!(bus.published(), 3);
    }

    #[test]
    fn dropped_subscribers_are_pruned() {
        let bus: Bus<u32> = Bus::new();
        let tap = bus.subscribe();
        drop(tap);
        bus.publish(7); // must not panic or leak the dead sender
        let tap2 = bus.subscribe();
        bus.publish(8);
        assert_eq!(tap2.live.recv().unwrap().event, 8);
    }

    #[test]
    fn event_json_shapes() {
        let ev = Event::JobQueued {
            job: 3,
            name: "sweep".into(),
            kind: "fabric-sweep",
            queue: "default".into(),
        };
        assert_eq!(ev.job(), Some(3));
        let j = ev.to_json();
        assert_eq!(j.get("event").unwrap().as_str().unwrap(), "queued");
        assert_eq!(j.get("job").unwrap().as_usize().unwrap(), 3);

        let fin = Event::JobFinished {
            job: 3,
            state: JobState::Succeeded,
            summary: Some(obj(vec![("x", num(1.0))])),
            error: None,
        };
        assert!(fin.is_terminal_for(3));
        assert!(!fin.is_terminal_for(4));
        let j = fin.to_json();
        assert_eq!(j.get("state").unwrap().as_str().unwrap(), "succeeded");
        assert_eq!(j.get("error"), Some(&Json::Null));
        assert!(!Event::Drain.is_terminal_for(3));
        assert_eq!(Event::Drain.job(), None);

        let fault = Event::Fault {
            job: 5,
            step: 12,
            kind: "crash".into(),
            node: 2,
        };
        assert_eq!(fault.job(), Some(5));
        assert!(!fault.is_terminal_for(5));
        let j = fault.to_json();
        assert_eq!(j.get("event").unwrap().as_str().unwrap(), "fault");
        assert_eq!(j.get("kind").unwrap().as_str().unwrap(), "crash");
        assert_eq!(j.get("node").unwrap().as_usize().unwrap(), 2);

        let step = Event::Step {
            job: 7,
            step: 42,
            loss: 0.5,
            comp_ratio: f64::NAN,
            sim_step_ps: 1_000_000,
        };
        assert_eq!(step.job(), Some(7));
        assert!(!step.is_terminal_for(7));
        let j = step.to_json();
        assert_eq!(j.get("event").unwrap().as_str().unwrap(), "step");
        assert_eq!(j.get("step").unwrap().as_usize().unwrap(), 42);
        assert_eq!(j.get("loss").unwrap().as_f64().unwrap(), 0.5);
        assert_eq!(j.get("comp_ratio"), Some(&Json::Null)); // NaN -> null
        assert_eq!(j.get("sim_step_ps").unwrap().as_usize().unwrap(), 1_000_000);

        let knob = Event::Knob {
            job: 9,
            step: 17,
            bucket: 2,
            name: "zeta",
            value: 0.97,
            gain: 128.0,
        };
        assert_eq!(knob.job(), Some(9));
        assert!(!knob.is_terminal_for(9));
        let j = knob.to_json();
        assert_eq!(j.get("event").unwrap().as_str().unwrap(), "knob");
        assert_eq!(j.get("bucket").unwrap().as_usize().unwrap(), 2);
        assert_eq!(j.get("name").unwrap().as_str().unwrap(), "zeta");
        assert!((j.get("value").unwrap().as_f64().unwrap() - 0.97).abs() < 1e-9);
        assert_eq!(j.get("gain").unwrap().as_f64().unwrap(), 128.0);

        let deg = Event::Degraded {
            job: 5,
            step: 12,
            live: 3,
            total: 4,
        };
        assert_eq!(deg.job(), Some(5));
        let j = deg.to_json();
        assert_eq!(j.get("event").unwrap().as_str().unwrap(), "degraded");
        assert_eq!(j.get("live").unwrap().as_usize().unwrap(), 3);
        assert_eq!(j.get("total").unwrap().as_usize().unwrap(), 4);
    }
}
