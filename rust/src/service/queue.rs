//! Named job queues: FIFO + priority ordering, per-queue concurrency
//! limits, and delayed (backoff) re-entry.
//!
//! A queue is a passive data structure — the scheduler owns the clock
//! and the worker threads; the queue only answers "who runs next".
//! Ordering is max-priority first, then submission order (FIFO) within
//! a priority band. Retried jobs park in a `delayed` list until their
//! backoff deadline, then [`JobQueue::promote`] moves them back into
//! the ready heap with their original submission sequence, so a retried
//! job does not lose its place to later arrivals of equal priority.

use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::time::Instant;

use anyhow::{bail, ensure, Result};

/// Job handle, unique per daemon lifetime, allocated by the scheduler.
pub type JobId = u64;

/// Static description of one named queue.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct QueueConfig {
    pub name: String,
    pub max_concurrent: usize,
}

impl QueueConfig {
    /// Parse `"train=1,sweeps=2"` — the `--queues` CLI flag shape.
    pub fn parse_list(text: &str) -> Result<Vec<QueueConfig>> {
        let mut out = Vec::new();
        for part in text.split(',').filter(|p| !p.trim().is_empty()) {
            let (name, cap) = match part.split_once('=') {
                Some((n, c)) => (n.trim(), c.trim()),
                None => bail!("queue spec '{part}' is not name=limit"),
            };
            ensure!(!name.is_empty(), "queue spec '{part}' has an empty name");
            let max_concurrent: usize = cap
                .parse()
                .map_err(|_| anyhow::anyhow!("queue '{name}': bad limit '{cap}'"))?;
            ensure!(max_concurrent >= 1, "queue '{name}': limit must be >= 1");
            out.push(QueueConfig {
                name: name.to_string(),
                max_concurrent,
            });
        }
        Ok(out)
    }

    /// Inverse of [`QueueConfig::parse_list`].
    pub fn list_str(configs: &[QueueConfig]) -> String {
        configs
            .iter()
            .map(|q| format!("{}={}", q.name, q.max_concurrent))
            .collect::<Vec<_>>()
            .join(",")
    }
}

/// Heap entry: higher priority wins; ties break to the earlier seq.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct Entry {
    priority: i32,
    seq: u64,
    job: JobId,
}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap: larger compares pop first. Flip the
        // seq comparison so the *older* entry is the larger one.
        self.priority
            .cmp(&other.priority)
            .then(other.seq.cmp(&self.seq))
    }
}

impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// One named queue: a ready heap, a backoff parking lot, and a running
/// counter enforced against `max_concurrent` by the scheduler.
#[derive(Debug)]
pub struct JobQueue {
    pub name: String,
    pub max_concurrent: usize,
    ready: BinaryHeap<Entry>,
    delayed: Vec<(Instant, Entry)>,
    next_seq: u64,
    running: usize,
}

impl JobQueue {
    pub fn new(name: &str, max_concurrent: usize) -> JobQueue {
        JobQueue {
            name: name.to_string(),
            max_concurrent: max_concurrent.max(1),
            ready: BinaryHeap::new(),
            delayed: Vec::new(),
            next_seq: 0,
            running: 0,
        }
    }

    /// Enqueue immediately runnable work.
    pub fn push(&mut self, job: JobId, priority: i32) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.ready.push(Entry { priority, seq, job });
    }

    /// Park work until `at` (retry backoff). Keeps FIFO seq allocation
    /// so promoted entries sort by original arrival within a band.
    pub fn push_after(&mut self, job: JobId, priority: i32, at: Instant) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.delayed.push((at, Entry { priority, seq, job }));
    }

    /// Move every delayed entry whose deadline has passed into the
    /// ready heap; returns how many were promoted.
    pub fn promote(&mut self, now: Instant) -> usize {
        let mut promoted = 0;
        let mut i = 0;
        while i < self.delayed.len() {
            if self.delayed[i].0 <= now {
                let (_, entry) = self.delayed.swap_remove(i);
                self.ready.push(entry);
                promoted += 1;
            } else {
                i += 1;
            }
        }
        promoted
    }

    /// Earliest backoff deadline still parked, if any — the scheduler's
    /// wait-timeout hint.
    pub fn next_delayed(&self) -> Option<Instant> {
        self.delayed.iter().map(|(at, _)| *at).min()
    }

    pub fn has_capacity(&self) -> bool {
        self.running < self.max_concurrent
    }

    /// Pop the best ready job (priority desc, then FIFO). Does not
    /// check capacity — callers pair this with [`JobQueue::start`].
    pub fn pop_ready(&mut self) -> Option<JobId> {
        self.ready.pop().map(|e| e.job)
    }

    pub fn start(&mut self) {
        self.running += 1;
    }

    pub fn finish(&mut self) {
        debug_assert!(self.running > 0);
        self.running = self.running.saturating_sub(1);
    }

    /// Drop a job from ready or delayed (cancellation). Returns true if
    /// it was present.
    pub fn remove(&mut self, job: JobId) -> bool {
        let before = self.ready.len() + self.delayed.len();
        self.ready = self
            .ready
            .drain()
            .filter(|e| e.job != job)
            .collect();
        self.delayed.retain(|(_, e)| e.job != job);
        before != self.ready.len() + self.delayed.len()
    }

    pub fn ready_len(&self) -> usize {
        self.ready.len()
    }

    pub fn delayed_len(&self) -> usize {
        self.delayed.len()
    }

    pub fn running(&self) -> usize {
        self.running
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn fifo_within_priority_band() {
        let mut q = JobQueue::new("default", 1);
        q.push(10, 0);
        q.push(11, 0);
        q.push(12, 0);
        assert_eq!(q.pop_ready(), Some(10));
        assert_eq!(q.pop_ready(), Some(11));
        assert_eq!(q.pop_ready(), Some(12));
        assert_eq!(q.pop_ready(), None);
    }

    #[test]
    fn higher_priority_preempts_fifo() {
        let mut q = JobQueue::new("default", 1);
        q.push(1, 0);
        q.push(2, 5);
        q.push(3, 0);
        q.push(4, 5);
        assert_eq!(q.pop_ready(), Some(2)); // priority 5, earliest
        assert_eq!(q.pop_ready(), Some(4)); // priority 5, later
        assert_eq!(q.pop_ready(), Some(1));
        assert_eq!(q.pop_ready(), Some(3));
    }

    #[test]
    fn delayed_entries_promote_after_deadline() {
        let mut q = JobQueue::new("default", 1);
        let now = Instant::now();
        q.push_after(7, 0, now + Duration::from_millis(50));
        assert_eq!(q.pop_ready(), None);
        assert_eq!(q.promote(now), 0);
        assert_eq!(q.delayed_len(), 1);
        assert_eq!(q.next_delayed(), Some(now + Duration::from_millis(50)));
        assert_eq!(q.promote(now + Duration::from_millis(51)), 1);
        assert_eq!(q.pop_ready(), Some(7));
        assert_eq!(q.next_delayed(), None);
    }

    #[test]
    fn capacity_tracks_running_count() {
        let mut q = JobQueue::new("default", 2);
        assert!(q.has_capacity());
        q.start();
        assert!(q.has_capacity());
        q.start();
        assert!(!q.has_capacity());
        q.finish();
        assert!(q.has_capacity());
    }

    #[test]
    fn remove_drops_ready_and_delayed() {
        let mut q = JobQueue::new("default", 1);
        q.push(1, 0);
        q.push(2, 0);
        q.push_after(3, 0, Instant::now() + Duration::from_secs(60));
        assert!(q.remove(1));
        assert!(q.remove(3));
        assert!(!q.remove(99));
        assert_eq!(q.pop_ready(), Some(2));
        assert_eq!(q.delayed_len(), 0);
    }

    #[test]
    fn parse_list_round_trips() {
        let qs = QueueConfig::parse_list("train=1, sweeps=2").unwrap();
        assert_eq!(qs.len(), 2);
        assert_eq!(qs[0].name, "train");
        assert_eq!(qs[1].max_concurrent, 2);
        assert_eq!(QueueConfig::list_str(&qs), "train=1,sweeps=2");
        assert!(QueueConfig::parse_list("oops").is_err());
        assert!(QueueConfig::parse_list("x=0").is_err());
        assert!(QueueConfig::parse_list("=3").is_err());
    }
}
