//! Job scheduler: worker threads claiming from named queues, bounded
//! retry with exponential backoff, cancellation, and graceful drain.
//!
//! The scheduler is the only writer of job state. All transitions
//! happen under one lock and are published to the event bus *inside*
//! that critical section, so the bus order is the transition order —
//! tests and streaming clients can reconstruct scheduling decisions
//! from events alone. Executors are injected as a closure over
//! [`JobSpec`]; the scheduler knows nothing about training or sweeps.
//!
//! Lifecycle: `Queued → Running → {Succeeded | Cancelled | Backoff →
//! Queued…  | Failed}`. A failed attempt re-queues with delay
//! `base · factor^(attempt−1)` (capped) until `max_retries` re-attempts
//! are spent. Cancellation of a queued job is immediate; cancellation
//! of a running job sets a flag the executor observes at its next step
//! boundary. [`Scheduler::drain`] rejects new submissions, cancels
//! everything not yet started, lets running jobs finish, then the
//! worker threads exit and [`Scheduler::join`] returns.

use std::collections::BTreeMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::util::backoff::Backoff;
use crate::util::json::{num, obj, s, Json};

use super::bus::{Event, EventBus};
use super::jobspec::JobSpec;
use super::queue::{JobId, JobQueue, QueueConfig};

/// Job lifecycle states.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    Queued,
    Running,
    /// Failed an attempt; parked until the backoff deadline.
    Backoff,
    Succeeded,
    Failed,
    Cancelled,
}

impl JobState {
    pub fn label(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Backoff => "backoff",
            JobState::Succeeded => "succeeded",
            JobState::Failed => "failed",
            JobState::Cancelled => "cancelled",
        }
    }

    pub fn is_terminal(&self) -> bool {
        matches!(
            self,
            JobState::Succeeded | JobState::Failed | JobState::Cancelled
        )
    }
}

/// Exponential backoff between retry attempts.
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    pub base_ms: u64,
    pub factor: f64,
    pub max_ms: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            base_ms: 500,
            factor: 2.0,
            max_ms: 30_000,
        }
    }
}

impl RetryPolicy {
    /// Delay before re-attempt after `failures` failed attempts
    /// (`failures` counts from 1): `base · factor^(failures−1)`, capped.
    /// Delegates to [`Backoff`] — the same clamping rules the fabric's
    /// collective retransmit path uses.
    pub fn delay_ms(&self, failures: u32) -> u64 {
        Backoff {
            base: self.base_ms,
            factor: self.factor,
            max: self.max_ms,
        }
        .delay(failures)
    }
}

/// Handed to the executor: identity, cancellation, and a progress path
/// onto the bus. Executors must poll [`JobCtx::check`] (or
/// [`JobCtx::cancelled`]) at step boundaries for cancellation to work.
pub struct JobCtx {
    pub id: JobId,
    pub attempt: u32,
    pub bus: Arc<EventBus>,
    cancel: Arc<AtomicBool>,
}

impl JobCtx {
    /// A context owned by no scheduler — for tests and direct executor
    /// invocation. Never cancelled.
    pub fn detached(bus: &Arc<EventBus>) -> JobCtx {
        JobCtx {
            id: 0,
            attempt: 1,
            bus: bus.clone(),
            cancel: Arc::new(AtomicBool::new(false)),
        }
    }

    pub fn cancelled(&self) -> bool {
        self.cancel.load(Ordering::Relaxed)
    }

    /// Bail with a recognizable error if cancellation was requested.
    pub fn check(&self) -> Result<()> {
        if self.cancelled() {
            bail!("cancelled at step boundary");
        }
        Ok(())
    }

    /// Publish an arbitrary event on the daemon bus (fault/degraded
    /// notifications from inside an executor).
    pub fn publish(&self, event: Event) {
        self.bus.publish(event);
    }

    /// Publish a live progress event (step metrics, sweep cells, …).
    pub fn progress(&self, done: u64, total: u64, detail: &str) {
        self.bus.publish(Event::JobProgress {
            job: self.id,
            done,
            total,
            detail: detail.to_string(),
        });
    }
}

/// The injected work function. Returns the job's summary JSON.
pub type Executor = Arc<dyn Fn(&JobSpec, &JobCtx) -> Result<Json> + Send + Sync>;

struct JobRecord {
    spec: Arc<JobSpec>,
    state: JobState,
    attempts: u32,
    error: Option<String>,
    result: Option<Json>,
    cancel: Arc<AtomicBool>,
}

/// Cloneable read view of one job.
#[derive(Debug, Clone)]
pub struct JobSnapshot {
    pub id: JobId,
    pub name: String,
    pub kind: &'static str,
    pub queue: String,
    pub priority: i32,
    pub state: JobState,
    pub attempts: u32,
    pub error: Option<String>,
    pub result: Option<Json>,
}

impl JobSnapshot {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("id", num(self.id as f64)),
            ("name", s(&self.name)),
            ("kind", s(self.kind)),
            ("queue", s(&self.queue)),
            ("priority", num(self.priority as f64)),
            ("state", s(self.state.label())),
            ("attempts", num(self.attempts as f64)),
            (
                "error",
                self.error.as_deref().map(s).unwrap_or(Json::Null),
            ),
            ("result", self.result.clone().unwrap_or(Json::Null)),
        ])
    }
}

/// Read view of one queue's depths.
#[derive(Debug, Clone)]
pub struct QueueSnapshot {
    pub name: String,
    pub max_concurrent: usize,
    pub running: usize,
    pub ready: usize,
    pub delayed: usize,
}

impl QueueSnapshot {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("name", s(&self.name)),
            ("max_concurrent", num(self.max_concurrent as f64)),
            ("running", num(self.running as f64)),
            ("ready", num(self.ready as f64)),
            ("delayed", num(self.delayed as f64)),
        ])
    }
}

#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// Pre-declared queues; submissions to unknown names auto-create a
    /// concurrency-1 queue.
    pub queues: Vec<QueueConfig>,
    pub retry: RetryPolicy,
    /// Worker threads; the global concurrency ceiling across queues.
    pub threads: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            queues: Vec::new(),
            retry: RetryPolicy::default(),
            threads: 2,
        }
    }
}

struct Inner {
    jobs: BTreeMap<JobId, JobRecord>,
    queues: BTreeMap<String, JobQueue>,
    next_id: JobId,
    draining: bool,
}

struct Shared {
    inner: Mutex<Inner>,
    cv: Condvar,
    bus: Arc<EventBus>,
    exec: Executor,
    retry: RetryPolicy,
}

pub struct Scheduler {
    shared: Arc<Shared>,
    threads: Mutex<Vec<JoinHandle<()>>>,
}

fn lock_inner(shared: &Shared) -> MutexGuard<'_, Inner> {
    shared.inner.lock().unwrap_or_else(|e| e.into_inner())
}

impl Scheduler {
    /// Spawn the worker pool and return the handle.
    pub fn start(cfg: SchedulerConfig, exec: Executor, bus: Arc<EventBus>) -> Scheduler {
        let mut queues = BTreeMap::new();
        for q in &cfg.queues {
            queues.insert(q.name.clone(), JobQueue::new(&q.name, q.max_concurrent));
        }
        let shared = Arc::new(Shared {
            inner: Mutex::new(Inner {
                jobs: BTreeMap::new(),
                queues,
                next_id: 1,
                draining: false,
            }),
            cv: Condvar::new(),
            bus,
            exec,
            retry: cfg.retry,
        });
        let mut threads = Vec::new();
        for i in 0..cfg.threads.max(1) {
            let sh = shared.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("sched-{i}"))
                    .spawn(move || worker_loop(&sh))
                    .expect("spawn scheduler worker"),
            );
        }
        Scheduler {
            shared,
            threads: Mutex::new(threads),
        }
    }

    /// Accept a job; errors while draining.
    pub fn submit(&self, spec: JobSpec) -> Result<JobId> {
        let mut inner = lock_inner(&self.shared);
        if inner.draining {
            bail!("scheduler is draining: not accepting new jobs");
        }
        let id = inner.next_id;
        inner.next_id += 1;
        let queue_name = spec.queue.clone();
        let priority = spec.priority;
        let q = inner
            .queues
            .entry(queue_name.clone())
            .or_insert_with(|| JobQueue::new(&queue_name, 1));
        q.push(id, priority);
        let kind = spec.kind();
        let name = spec.name.clone();
        inner.jobs.insert(
            id,
            JobRecord {
                spec: Arc::new(spec),
                state: JobState::Queued,
                attempts: 0,
                error: None,
                result: None,
                cancel: Arc::new(AtomicBool::new(false)),
            },
        );
        self.shared.bus.publish(Event::JobQueued {
            job: id,
            name,
            kind,
            queue: queue_name,
        });
        self.shared.cv.notify_all();
        Ok(id)
    }

    /// Cancel a job. Queued/backed-off jobs cancel immediately; running
    /// jobs get the flag set and cancel at their next step boundary.
    /// Returns the state *after* this call.
    pub fn cancel(&self, id: JobId) -> Result<JobState> {
        let mut inner = lock_inner(&self.shared);
        let Some(rec) = inner.jobs.get(&id) else {
            bail!("unknown job {id}");
        };
        match rec.state {
            JobState::Queued | JobState::Backoff => {
                let queue_name = rec.spec.queue.clone();
                if let Some(q) = inner.queues.get_mut(&queue_name) {
                    q.remove(id);
                }
                let rec = inner.jobs.get_mut(&id).expect("job exists");
                rec.state = JobState::Cancelled;
                rec.error = Some("cancelled before start".into());
                self.shared.bus.publish(Event::JobFinished {
                    job: id,
                    state: JobState::Cancelled,
                    summary: None,
                    error: rec.error.clone(),
                });
                self.shared.cv.notify_all();
                Ok(JobState::Cancelled)
            }
            JobState::Running => {
                rec.cancel.store(true, Ordering::Relaxed);
                Ok(JobState::Running)
            }
            terminal => Ok(terminal),
        }
    }

    pub fn job(&self, id: JobId) -> Option<JobSnapshot> {
        let inner = lock_inner(&self.shared);
        inner.jobs.get(&id).map(|r| snapshot(id, r))
    }

    pub fn jobs(&self) -> Vec<JobSnapshot> {
        let inner = lock_inner(&self.shared);
        inner.jobs.iter().map(|(id, r)| snapshot(*id, r)).collect()
    }

    pub fn queues(&self) -> Vec<QueueSnapshot> {
        let inner = lock_inner(&self.shared);
        inner
            .queues
            .values()
            .map(|q| QueueSnapshot {
                name: q.name.clone(),
                max_concurrent: q.max_concurrent,
                running: q.running(),
                ready: q.ready_len(),
                delayed: q.delayed_len(),
            })
            .collect()
    }

    pub fn draining(&self) -> bool {
        lock_inner(&self.shared).draining
    }

    /// Block until `id` is terminal or `timeout` elapses. Returns the
    /// last observed state (`None` for unknown jobs); callers decide
    /// whether a non-terminal state means timeout.
    pub fn wait_terminal(&self, id: JobId, timeout: Duration) -> Option<JobState> {
        let deadline = Instant::now() + timeout;
        let mut inner = lock_inner(&self.shared);
        loop {
            match inner.jobs.get(&id) {
                None => return None,
                Some(r) if r.state.is_terminal() => return Some(r.state),
                Some(r) => {
                    let now = Instant::now();
                    if now >= deadline {
                        return Some(r.state);
                    }
                    let wait = deadline - now;
                    inner = match self.shared.cv.wait_timeout(inner, wait) {
                        Ok((g, _)) => g,
                        Err(p) => p.into_inner().0,
                    };
                }
            }
        }
    }

    /// Stop accepting jobs, cancel everything not yet started, let
    /// running jobs finish. Idempotent.
    pub fn drain(&self) {
        let mut inner = lock_inner(&self.shared);
        if inner.draining {
            return;
        }
        inner.draining = true;
        let pending: Vec<JobId> = inner
            .jobs
            .iter()
            .filter(|(_, r)| matches!(r.state, JobState::Queued | JobState::Backoff))
            .map(|(id, _)| *id)
            .collect();
        for id in pending {
            let queue_name = inner.jobs[&id].spec.queue.clone();
            if let Some(q) = inner.queues.get_mut(&queue_name) {
                q.remove(id);
            }
            let rec = inner.jobs.get_mut(&id).expect("job exists");
            rec.state = JobState::Cancelled;
            rec.error = Some("drained before start".into());
            self.shared.bus.publish(Event::JobFinished {
                job: id,
                state: JobState::Cancelled,
                summary: None,
                error: rec.error.clone(),
            });
        }
        self.shared.bus.publish(Event::Drain);
        self.shared.cv.notify_all();
    }

    /// Join the worker pool; call after [`Scheduler::drain`].
    pub fn join(&self) {
        let handles: Vec<JoinHandle<()>> = {
            let mut guard = self.threads.lock().unwrap_or_else(|e| e.into_inner());
            guard.drain(..).collect()
        };
        for h in handles {
            let _ = h.join();
        }
    }

    /// Full state dump: jobs (terminal states included), queue depths,
    /// drain flag. This is what the daemon persists on shutdown.
    pub fn snapshot_json(&self) -> Json {
        let jobs = Json::Arr(self.jobs().iter().map(|j| j.to_json()).collect());
        let queues = Json::Arr(self.queues().iter().map(|q| q.to_json()).collect());
        obj(vec![
            ("jobs", jobs),
            ("queues", queues),
            ("draining", Json::Bool(self.draining())),
        ])
    }
}

fn snapshot(id: JobId, r: &JobRecord) -> JobSnapshot {
    JobSnapshot {
        id,
        name: r.spec.name.clone(),
        kind: r.spec.kind(),
        queue: r.spec.queue.clone(),
        priority: r.spec.priority,
        state: r.state,
        attempts: r.attempts,
        error: r.error.clone(),
        result: r.result.clone(),
    }
}

/// Claim the best runnable job: queues in name order, each queue
/// priority-then-FIFO, capacity respected. Stale entries (cancelled
/// while queued) are dropped on the way.
fn claim_next(inner: &mut Inner) -> Option<(String, JobId)> {
    let names: Vec<String> = inner.queues.keys().cloned().collect();
    for name in names {
        loop {
            let q = inner.queues.get_mut(&name).expect("queue exists");
            if !q.has_capacity() {
                break;
            }
            let Some(job) = q.pop_ready() else {
                break;
            };
            let runnable = matches!(
                inner.jobs.get(&job).map(|r| r.state),
                Some(JobState::Queued) | Some(JobState::Backoff)
            );
            if runnable {
                return Some((name, job));
            }
        }
    }
    None
}

fn total_pending(inner: &Inner) -> usize {
    inner
        .queues
        .values()
        .map(|q| q.running() + q.ready_len() + q.delayed_len())
        .sum()
}

fn worker_loop(shared: &Shared) {
    loop {
        // Phase 1: claim a job (or exit when drained dry).
        let claimed = {
            let mut inner = lock_inner(shared);
            loop {
                let now = Instant::now();
                for q in inner.queues.values_mut() {
                    q.promote(now);
                }
                if let Some((queue_name, job)) = claim_next(&mut inner) {
                    inner
                        .queues
                        .get_mut(&queue_name)
                        .expect("queue exists")
                        .start();
                    let rec = inner.jobs.get_mut(&job).expect("job exists");
                    rec.state = JobState::Running;
                    rec.attempts += 1;
                    let ctx = JobCtx {
                        id: job,
                        attempt: rec.attempts,
                        bus: shared.bus.clone(),
                        cancel: rec.cancel.clone(),
                    };
                    let spec = rec.spec.clone();
                    shared.bus.publish(Event::JobStarted {
                        job,
                        attempt: ctx.attempt,
                    });
                    shared.cv.notify_all();
                    break Some((queue_name, job, spec, ctx));
                }
                if inner.draining && total_pending(&inner) == 0 {
                    shared.cv.notify_all();
                    break None;
                }
                let next_deadline = inner
                    .queues
                    .values()
                    .filter_map(|q| q.next_delayed())
                    .min();
                inner = match next_deadline {
                    Some(at) => {
                        let wait = at
                            .saturating_duration_since(Instant::now())
                            .max(Duration::from_millis(1));
                        match shared.cv.wait_timeout(inner, wait) {
                            Ok((g, _)) => g,
                            Err(p) => p.into_inner().0,
                        }
                    }
                    None => match shared.cv.wait(inner) {
                        Ok(g) => g,
                        Err(p) => p.into_inner(),
                    },
                };
            }
        };
        let Some((queue_name, job, spec, ctx)) = claimed else {
            return;
        };

        // Phase 2: run the executor outside the lock; panics become
        // ordinary failures so one bad job cannot kill the pool.
        let cancelled_flag = ctx.cancel.clone();
        let outcome = catch_unwind(AssertUnwindSafe(|| (shared.exec)(&spec, &ctx)));
        let outcome: Result<Json> = match outcome {
            Ok(r) => r,
            Err(panic) => {
                let msg = panic
                    .downcast_ref::<&str>()
                    .map(|m| m.to_string())
                    .or_else(|| panic.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "executor panicked".to_string());
                Err(anyhow::anyhow!("executor panicked: {msg}"))
            }
        };

        // Phase 3: record the transition.
        let mut inner = lock_inner(shared);
        if let Some(q) = inner.queues.get_mut(&queue_name) {
            q.finish();
        }
        let draining = inner.draining;
        let retry = shared.retry;
        let rec = inner.jobs.get_mut(&job).expect("job exists");
        match outcome {
            Ok(summary) => {
                rec.state = JobState::Succeeded;
                rec.result = Some(summary.clone());
                shared.bus.publish(Event::JobFinished {
                    job,
                    state: JobState::Succeeded,
                    summary: Some(summary),
                    error: None,
                });
            }
            Err(e) => {
                let msg = format!("{e:#}");
                if cancelled_flag.load(Ordering::Relaxed) {
                    rec.state = JobState::Cancelled;
                    rec.error = Some(msg.clone());
                    shared.bus.publish(Event::JobFinished {
                        job,
                        state: JobState::Cancelled,
                        summary: None,
                        error: Some(msg),
                    });
                } else if !draining && rec.attempts <= rec.spec.max_retries {
                    let delay_ms = retry.delay_ms(rec.attempts);
                    rec.state = JobState::Backoff;
                    rec.error = Some(msg.clone());
                    let priority = rec.spec.priority;
                    let attempt = rec.attempts;
                    let at = Instant::now() + Duration::from_millis(delay_ms);
                    if let Some(q) = inner.queues.get_mut(&queue_name) {
                        q.push_after(job, priority, at);
                    }
                    shared.bus.publish(Event::JobRetry {
                        job,
                        attempt,
                        delay_ms,
                        error: msg,
                    });
                } else {
                    rec.state = JobState::Failed;
                    rec.error = Some(msg.clone());
                    shared.bus.publish(Event::JobFinished {
                        job,
                        state: JobState::Failed,
                        summary: None,
                        error: Some(msg),
                    });
                }
            }
        }
        shared.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::benchcodecs::BenchCodecsOpts;
    use crate::service::jobspec::JobPayload;
    use std::sync::atomic::AtomicUsize;

    /// A spec whose payload the test executors ignore; behavior is
    /// keyed on `name`.
    fn spec(name: &str, queue: &str, priority: i32, max_retries: u32) -> JobSpec {
        JobSpec {
            name: name.into(),
            queue: queue.into(),
            priority,
            max_retries,
            payload: JobPayload::BenchCodecs(BenchCodecsOpts::default()),
        }
    }

    fn fast_retry() -> RetryPolicy {
        RetryPolicy {
            base_ms: 20,
            factor: 2.0,
            max_ms: 10_000,
        }
    }

    fn started_order(bus: &EventBus) -> Vec<JobId> {
        bus.subscribe()
            .backlog
            .iter()
            .filter_map(|ev| match &ev.event {
                Event::JobStarted { job, .. } => Some(*job),
                _ => None,
            })
            .collect()
    }

    #[test]
    fn retries_with_increasing_backoff_then_succeeds() {
        let bus = Arc::new(EventBus::new());
        let fails = Arc::new(AtomicUsize::new(0));
        let fails_in = fails.clone();
        let exec: Executor = Arc::new(move |_spec, _ctx| {
            if fails_in.fetch_add(1, Ordering::SeqCst) < 2 {
                bail!("flaky");
            }
            Ok(Json::Null)
        });
        let cfg = SchedulerConfig {
            retry: fast_retry(),
            ..SchedulerConfig::default()
        };
        let sched = Scheduler::start(cfg, exec, bus.clone());
        let t0 = Instant::now();
        let id = sched.submit(spec("flaky", "default", 0, 2)).unwrap();
        let state = sched.wait_terminal(id, Duration::from_secs(10)).unwrap();
        assert_eq!(state, JobState::Succeeded);
        // Two backoffs of 20 ms and 40 ms must have elapsed.
        assert!(t0.elapsed() >= Duration::from_millis(55), "{:?}", t0.elapsed());
        let snap = sched.job(id).unwrap();
        assert_eq!(snap.attempts, 3);
        let delays: Vec<u64> = bus
            .subscribe()
            .backlog
            .iter()
            .filter_map(|ev| match &ev.event {
                Event::JobRetry { delay_ms, .. } => Some(*delay_ms),
                _ => None,
            })
            .collect();
        assert_eq!(delays, vec![20, 40], "backoff must increase");
        sched.drain();
        sched.join();
    }

    #[test]
    fn gives_up_after_max_retries() {
        let bus = Arc::new(EventBus::new());
        let exec: Executor = Arc::new(|_spec, _ctx| bail!("always broken"));
        let cfg = SchedulerConfig {
            retry: fast_retry(),
            ..SchedulerConfig::default()
        };
        let sched = Scheduler::start(cfg, exec, bus);
        let id = sched.submit(spec("doomed", "default", 0, 2)).unwrap();
        let state = sched.wait_terminal(id, Duration::from_secs(10)).unwrap();
        assert_eq!(state, JobState::Failed);
        let snap = sched.job(id).unwrap();
        assert_eq!(snap.attempts, 3); // 1 initial + 2 retries
        assert!(snap.error.unwrap().contains("always broken"));
        sched.drain();
        sched.join();
    }

    #[test]
    fn drain_completes_in_flight_and_cancels_queued() {
        let bus = Arc::new(EventBus::new());
        let exec: Executor = Arc::new(|_spec, ctx| {
            for _ in 0..10 {
                std::thread::sleep(Duration::from_millis(5));
                ctx.check()?;
            }
            Ok(Json::Bool(true))
        });
        let cfg = SchedulerConfig {
            queues: vec![QueueConfig {
                name: "q".into(),
                max_concurrent: 1,
            }],
            ..SchedulerConfig::default()
        };
        let sched = Scheduler::start(cfg, exec, bus);
        let running = sched.submit(spec("in-flight", "q", 0, 0)).unwrap();
        let queued = sched.submit(spec("never-starts", "q", 0, 0)).unwrap();
        // Wait until the first job is actually running.
        let t0 = Instant::now();
        while sched.job(running).unwrap().state != JobState::Running {
            assert!(t0.elapsed() < Duration::from_secs(5), "never started");
            std::thread::sleep(Duration::from_millis(2));
        }
        sched.drain();
        assert!(sched.submit(spec("late", "q", 0, 0)).is_err());
        sched.join(); // workers exit once drained dry
        // In-flight job finished its work; queued one was cancelled,
        // and both terminal states persist in the snapshot.
        assert_eq!(sched.job(running).unwrap().state, JobState::Succeeded);
        let q = sched.job(queued).unwrap();
        assert_eq!(q.state, JobState::Cancelled);
        assert_eq!(q.error.as_deref(), Some("drained before start"));
        let snap = sched.snapshot_json().to_string();
        assert!(snap.contains("\"succeeded\""), "{snap}");
        assert!(snap.contains("\"cancelled\""), "{snap}");
        assert!(snap.contains("\"draining\":true"), "{snap}");
    }

    #[test]
    fn cancel_running_is_observed_within_one_step() {
        let bus = Arc::new(EventBus::new());
        let exec: Executor = Arc::new(|_spec, ctx| {
            for _ in 0..400 {
                std::thread::sleep(Duration::from_millis(5));
                ctx.check()?; // step boundary
            }
            Ok(Json::Null)
        });
        let sched = Scheduler::start(SchedulerConfig::default(), exec, bus);
        let id = sched.submit(spec("long", "default", 0, 0)).unwrap();
        let t0 = Instant::now();
        while sched.job(id).unwrap().state != JobState::Running {
            assert!(t0.elapsed() < Duration::from_secs(5), "never started");
            std::thread::sleep(Duration::from_millis(2));
        }
        let cancel_at = Instant::now();
        assert_eq!(sched.cancel(id).unwrap(), JobState::Running);
        let state = sched.wait_terminal(id, Duration::from_secs(10)).unwrap();
        assert_eq!(state, JobState::Cancelled);
        // Observed within a handful of 5 ms step boundaries, not after
        // the job's full 2 s natural runtime.
        assert!(
            cancel_at.elapsed() < Duration::from_millis(500),
            "{:?}",
            cancel_at.elapsed()
        );
        let snap = sched.job(id).unwrap();
        assert!(snap.error.unwrap().contains("cancelled at step boundary"));
        sched.drain();
        sched.join();
    }

    #[test]
    fn cancel_queued_is_immediate_and_cancel_is_idempotent() {
        let bus = Arc::new(EventBus::new());
        let exec: Executor = Arc::new(|_spec, _ctx| {
            std::thread::sleep(Duration::from_millis(40));
            Ok(Json::Null)
        });
        let cfg = SchedulerConfig {
            queues: vec![QueueConfig {
                name: "q".into(),
                max_concurrent: 1,
            }],
            ..SchedulerConfig::default()
        };
        let sched = Scheduler::start(cfg, exec, bus);
        let blocker = sched.submit(spec("blocker", "q", 0, 0)).unwrap();
        let victim = sched.submit(spec("victim", "q", 0, 0)).unwrap();
        assert_eq!(sched.cancel(victim).unwrap(), JobState::Cancelled);
        assert_eq!(sched.cancel(victim).unwrap(), JobState::Cancelled);
        assert!(sched.cancel(9999).is_err());
        let state = sched.wait_terminal(blocker, Duration::from_secs(10)).unwrap();
        assert_eq!(state, JobState::Succeeded);
        // The cancelled job never ran.
        assert_eq!(sched.job(victim).unwrap().attempts, 0);
        sched.drain();
        sched.join();
    }

    #[test]
    fn priority_then_fifo_within_a_queue() {
        let bus = Arc::new(EventBus::new());
        let gate = Arc::new(AtomicBool::new(false));
        let gate_in = gate.clone();
        let exec: Executor = Arc::new(move |sp, _ctx| {
            if sp.name == "blocker" {
                while !gate_in.load(Ordering::SeqCst) {
                    std::thread::sleep(Duration::from_millis(2));
                }
            }
            Ok(Json::Null)
        });
        let cfg = SchedulerConfig {
            queues: vec![QueueConfig {
                name: "q".into(),
                max_concurrent: 1,
            }],
            threads: 1,
            ..SchedulerConfig::default()
        };
        let sched = Scheduler::start(cfg, exec, bus.clone());
        // The blocker occupies the queue's single slot while the rest
        // pile up, so ordering is decided by the queue, not by racing.
        let b = sched.submit(spec("blocker", "q", 0, 0)).unwrap();
        let c = sched.submit(spec("c", "q", 0, 0)).unwrap();
        let d = sched.submit(spec("d", "q", 5, 0)).unwrap();
        let e = sched.submit(spec("e", "q", 0, 0)).unwrap();
        gate.store(true, Ordering::SeqCst);
        for id in [b, c, d, e] {
            let st = sched.wait_terminal(id, Duration::from_secs(10)).unwrap();
            assert_eq!(st, JobState::Succeeded);
        }
        assert_eq!(started_order(&bus), vec![b, d, c, e]);
        sched.drain();
        sched.join();
    }

    #[test]
    fn per_queue_concurrency_limit_holds() {
        let bus = Arc::new(EventBus::new());
        let cur = Arc::new(AtomicUsize::new(0));
        let peak = Arc::new(AtomicUsize::new(0));
        let (cur_in, peak_in) = (cur.clone(), peak.clone());
        let exec: Executor = Arc::new(move |_sp, _ctx| {
            let now = cur_in.fetch_add(1, Ordering::SeqCst) + 1;
            peak_in.fetch_max(now, Ordering::SeqCst);
            std::thread::sleep(Duration::from_millis(20));
            cur_in.fetch_sub(1, Ordering::SeqCst);
            Ok(Json::Null)
        });
        let cfg = SchedulerConfig {
            queues: vec![QueueConfig {
                name: "narrow".into(),
                max_concurrent: 2,
            }],
            threads: 4,
            ..SchedulerConfig::default()
        };
        let sched = Scheduler::start(cfg, exec, bus);
        let ids: Vec<JobId> = (0..6)
            .map(|i| {
                sched
                    .submit(spec(&format!("j{i}"), "narrow", 0, 0))
                    .unwrap()
            })
            .collect();
        for id in ids {
            let st = sched.wait_terminal(id, Duration::from_secs(10)).unwrap();
            assert_eq!(st, JobState::Succeeded);
        }
        assert!(
            peak.load(Ordering::SeqCst) <= 2,
            "queue limit violated: peak {}",
            peak.load(Ordering::SeqCst)
        );
        sched.drain();
        sched.join();
    }

    #[test]
    fn executor_panic_becomes_failure_not_pool_death() {
        let bus = Arc::new(EventBus::new());
        let exec: Executor = Arc::new(|sp, _ctx| {
            if sp.name == "bomb" {
                panic!("boom");
            }
            Ok(Json::Null)
        });
        let sched = Scheduler::start(SchedulerConfig::default(), exec, bus);
        let bomb = sched.submit(spec("bomb", "default", 0, 0)).unwrap();
        let ok = sched.submit(spec("fine", "default", 0, 0)).unwrap();
        assert_eq!(
            sched.wait_terminal(bomb, Duration::from_secs(10)).unwrap(),
            JobState::Failed
        );
        // The pool survived the panic and still runs jobs.
        assert_eq!(
            sched.wait_terminal(ok, Duration::from_secs(10)).unwrap(),
            JobState::Succeeded
        );
        assert!(sched
            .job(bomb)
            .unwrap()
            .error
            .unwrap()
            .contains("boom"));
        sched.drain();
        sched.join();
    }

    #[test]
    fn backoff_delay_formula() {
        let r = RetryPolicy {
            base_ms: 100,
            factor: 2.0,
            max_ms: 450,
        };
        assert_eq!(r.delay_ms(1), 100);
        assert_eq!(r.delay_ms(2), 200);
        assert_eq!(r.delay_ms(3), 400);
        assert_eq!(r.delay_ms(4), 450); // capped
        assert_eq!(r.delay_ms(63), 450); // no overflow
    }
}
