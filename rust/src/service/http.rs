//! Hand-rolled HTTP/1.1 control plane over `std::net::TcpListener`.
//!
//! Deliberately tiny, matching the repo's no-heavy-deps style: blocking
//! accept loop, one thread per connection, `Connection: close` on every
//! response, no keep-alive, no TLS, no chunked bodies. Routes:
//!
//! | method | path                | effect                              |
//! |--------|---------------------|-------------------------------------|
//! | GET    | `/healthz`          | daemon status JSON                  |
//! | POST   | `/jobs`             | submit a [`JobSpec`] envelope       |
//! | GET    | `/jobs`             | list all jobs                       |
//! | GET    | `/jobs/:id`         | one job's snapshot                  |
//! | GET    | `/jobs/:id/events`  | NDJSON event stream until terminal  |
//! | GET    | `/jobs/:id/result`  | the finished job's result artifact  |
//! | POST   | `/jobs/:id/cancel`  | cancel                              |
//! | GET    | `/queues`           | queue depths                        |
//! | GET    | `/fabric`           | shared fabric config + usage ledger |
//! | POST   | `/shutdown`         | drain and exit (same as SIGTERM)    |
//!
//! The event stream replays the job's full history (the bus keeps a
//! replay window), then follows live events, and closes after the
//! job's terminal event — end-of-stream *is* the completion signal.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

use super::jobspec::JobSpec;
use super::queue::JobId;
use super::Daemon;

/// Submission bodies larger than this are rejected outright.
const MAX_BODY: usize = 4 << 20;

/// A parsed request line + body; headers beyond Content-Length are
/// read and discarded.
struct Request {
    method: String,
    path: String,
    body: Vec<u8>,
}

fn read_request(stream: &mut TcpStream) -> Result<Request> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts.next().context("empty request line")?.to_string();
    let path = parts.next().context("request line has no path")?.to_string();
    let mut content_len = 0usize;
    loop {
        let mut header = String::new();
        reader.read_line(&mut header)?;
        let header = header.trim();
        if header.is_empty() {
            break;
        }
        if let Some((name, value)) = header.split_once(':') {
            if name.trim().eq_ignore_ascii_case("content-length") {
                content_len = value.trim().parse().context("bad Content-Length")?;
            }
        }
    }
    if content_len > MAX_BODY {
        bail!("body too large ({content_len} bytes)");
    }
    let mut body = vec![0u8; content_len];
    reader.read_exact(&mut body)?;
    Ok(Request { method, path, body })
}

fn status_text(code: u16) -> &'static str {
    match code {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

fn respond_json(stream: &mut TcpStream, code: u16, body: &Json) -> std::io::Result<()> {
    let text = body.to_string();
    write!(
        stream,
        "HTTP/1.1 {code} {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{text}",
        status_text(code),
        text.len(),
    )?;
    stream.flush()
}

fn error_json(message: &str) -> Json {
    crate::util::json::obj(vec![("error", crate::util::json::s(message))])
}

/// The accept loop + its listener address.
pub struct ControlPlane {
    pub addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl ControlPlane {
    /// Bind `listen` (port 0 picks an ephemeral port) and serve the
    /// daemon until [`ControlPlane::stop`].
    pub fn start(listen: &str, daemon: Arc<Daemon>) -> Result<ControlPlane> {
        let listener =
            TcpListener::bind(listen).with_context(|| format!("bind {listen}"))?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop_in = stop.clone();
        let handle = std::thread::Builder::new()
            .name("http-accept".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    if stop_in.load(Ordering::Relaxed) {
                        break;
                    }
                    let Ok(mut stream) = conn else { continue };
                    let daemon = daemon.clone();
                    let _ = std::thread::Builder::new()
                        .name("http-conn".into())
                        .spawn(move || {
                            // Broken pipes and parse failures only kill
                            // this connection's thread.
                            let _ = handle_connection(&mut stream, &daemon);
                        });
                }
            })
            .context("spawn accept loop")?;
        Ok(ControlPlane {
            addr,
            stop,
            handle: Some(handle),
        })
    }

    /// Stop accepting; a self-connection unblocks the blocking accept.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn parse_job_path(path: &str) -> Option<(JobId, Option<&str>)> {
    let rest = path.strip_prefix("/jobs/")?;
    let (id_str, action) = match rest.split_once('/') {
        Some((id, act)) => (id, Some(act)),
        None => (rest, None),
    };
    id_str.parse().ok().map(|id| (id, action))
}

fn handle_connection(stream: &mut TcpStream, daemon: &Daemon) -> std::io::Result<()> {
    let req = match read_request(stream) {
        Ok(r) => r,
        Err(e) => return respond_json(stream, 400, &error_json(&format!("{e:#}"))),
    };
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => respond_json(stream, 200, &daemon.health_json()),
        ("GET", "/queues") => respond_json(stream, 200, &daemon.queues_json()),
        ("GET", "/fabric") => respond_json(stream, 200, &daemon.fabric_json()),
        ("GET", "/jobs") => respond_json(stream, 200, &daemon.jobs_json()),
        ("POST", "/jobs") => handle_submit(stream, daemon, &req.body),
        ("POST", "/shutdown") => {
            respond_json(
                stream,
                200,
                &crate::util::json::obj(vec![(
                    "status",
                    crate::util::json::s("draining"),
                )]),
            )?;
            daemon.begin_shutdown();
            Ok(())
        }
        ("GET", path) => match parse_job_path(path) {
            Some((id, None)) => match daemon.scheduler().job(id) {
                Some(snap) => respond_json(stream, 200, &snap.to_json()),
                None => respond_json(stream, 404, &error_json("unknown job")),
            },
            Some((id, Some("events"))) => stream_events(stream, daemon, id),
            Some((id, Some("result"))) => match daemon.scheduler().job(id) {
                // The result artifact exists only after a successful
                // terminal transition; 404 with distinct messages keeps
                // "not yet" and "no such job" diagnosable client-side.
                Some(snap) => match snap.result {
                    Some(r) => respond_json(stream, 200, &r),
                    None => respond_json(
                        stream,
                        404,
                        &error_json(&format!(
                            "job {id} has no result (state: {})",
                            snap.state.label()
                        )),
                    ),
                },
                None => respond_json(stream, 404, &error_json("unknown job")),
            },
            _ => respond_json(stream, 404, &error_json("no such route")),
        },
        ("POST", path) => match parse_job_path(path) {
            Some((id, Some("cancel"))) => match daemon.scheduler().cancel(id) {
                Ok(state) => respond_json(
                    stream,
                    200,
                    &crate::util::json::obj(vec![
                        ("job", crate::util::json::num(id as f64)),
                        ("state", crate::util::json::s(state.label())),
                    ]),
                ),
                Err(e) => respond_json(stream, 404, &error_json(&format!("{e:#}"))),
            },
            _ => respond_json(stream, 404, &error_json("no such route")),
        },
        _ => respond_json(stream, 405, &error_json("method not allowed")),
    }
}

fn handle_submit(
    stream: &mut TcpStream,
    daemon: &Daemon,
    body: &[u8],
) -> std::io::Result<()> {
    let parsed = std::str::from_utf8(body)
        .map_err(|_| anyhow::anyhow!("body is not UTF-8"))
        .and_then(|text| Json::parse(text).map_err(anyhow::Error::from))
        .and_then(|j| JobSpec::from_json(&j));
    let spec = match parsed {
        Ok(sp) => sp,
        Err(e) => return respond_json(stream, 400, &error_json(&format!("{e:#}"))),
    };
    match daemon.scheduler().submit(spec) {
        Ok(id) => respond_json(
            stream,
            200,
            &crate::util::json::obj(vec![
                ("job", crate::util::json::num(id as f64)),
                ("state", crate::util::json::s("queued")),
            ]),
        ),
        // submit only fails while draining — that's 503, try elsewhere.
        Err(e) => respond_json(stream, 503, &error_json(&format!("{e:#}"))),
    }
}

/// Stream a job's events as NDJSON: replay its history, then follow
/// live until the job's terminal event or the daemon stops.
fn stream_events(stream: &mut TcpStream, daemon: &Daemon, id: JobId) -> std::io::Result<()> {
    if daemon.scheduler().job(id).is_none() {
        return respond_json(stream, 404, &error_json("unknown job"));
    }
    // Subscribe BEFORE checking terminality: the tap's backlog+live is
    // gap-free, so however the race with the scheduler falls, the
    // terminal event is in exactly one of the two.
    let tap = daemon.bus().subscribe();
    write!(
        stream,
        "HTTP/1.1 200 OK\r\nContent-Type: application/x-ndjson\r\nConnection: close\r\n\r\n"
    )?;
    let mut done = false;
    for ev in &tap.backlog {
        if ev.event.job() == Some(id) || ev.event.job().is_none() {
            writeln!(stream, "{}", ev.event.to_json())?;
            if ev.event.is_terminal_for(id) {
                done = true;
            }
        }
    }
    stream.flush()?;
    while !done {
        match tap.live.recv_timeout(Duration::from_secs(1)) {
            Ok(ev) => {
                if ev.event.job() == Some(id) || ev.event.job().is_none() {
                    writeln!(stream, "{}", ev.event.to_json())?;
                    stream.flush()?;
                    if ev.event.is_terminal_for(id) {
                        done = true;
                    }
                }
            }
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                if daemon.stopping() {
                    break;
                }
            }
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
        }
    }
    Ok(())
}

/// Minimal blocking HTTP client for `repro submit`/`status`/`cancel`
/// and the integration tests. Returns `(status_code, body)`.
pub fn http_request(
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&str>,
) -> Result<(u16, String)> {
    let mut stream =
        TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
    let body = body.unwrap_or("");
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len(),
    )?;
    stream.flush()?;
    let mut response = String::new();
    stream.read_to_string(&mut response)?;
    parse_response(&response)
}

/// Open `/jobs/:id/events` and hand each NDJSON line to `on_line`;
/// returns when the stream closes (job terminal or daemon gone).
pub fn http_stream(
    addr: &str,
    path: &str,
    on_line: &mut dyn FnMut(&str),
) -> Result<u16> {
    let mut stream =
        TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
    write!(
        stream,
        "GET {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n"
    )?;
    stream.flush()?;
    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line)?;
    let code: u16 = status_line
        .split_whitespace()
        .nth(1)
        .context("bad status line")?
        .parse()?;
    // Skip headers.
    loop {
        let mut header = String::new();
        if reader.read_line(&mut header)? == 0 || header.trim().is_empty() {
            break;
        }
    }
    for line in reader.lines() {
        let line = line?;
        if !line.trim().is_empty() {
            on_line(line.trim());
        }
    }
    Ok(code)
}

fn parse_response(raw: &str) -> Result<(u16, String)> {
    let (head, body) = raw
        .split_once("\r\n\r\n")
        .context("malformed HTTP response")?;
    let code: u16 = head
        .lines()
        .next()
        .context("empty response")?
        .split_whitespace()
        .nth(1)
        .context("bad status line")?
        .parse()?;
    Ok((code, body.to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_paths_parse() {
        assert_eq!(parse_job_path("/jobs/7"), Some((7, None)));
        assert_eq!(parse_job_path("/jobs/7/events"), Some((7, Some("events"))));
        assert_eq!(parse_job_path("/jobs/7/cancel"), Some((7, Some("cancel"))));
        assert_eq!(parse_job_path("/jobs/7/result"), Some((7, Some("result"))));
        assert_eq!(parse_job_path("/jobs/x"), None);
        assert_eq!(parse_job_path("/queues"), None);
    }

    #[test]
    fn responses_parse() {
        let (code, body) =
            parse_response("HTTP/1.1 200 OK\r\nContent-Length: 2\r\n\r\n{}").unwrap();
        assert_eq!(code, 200);
        assert_eq!(body, "{}");
        assert!(parse_response("garbage").is_err());
    }
}
