//! Job specifications: the serde types the control plane accepts.
//!
//! A job spec is an envelope (`job` kind, `name`, `queue`, `priority`,
//! `max_retries`) around one of the existing experiment configurations,
//! so the `experiments/` entry points become executors without learning
//! anything about HTTP or queues. The `spec` object reuses each
//! config's own JSON round-trip (`TrainConfig::from_json`,
//! `FabricSweepOpts::from_json`, `BenchCodecsOpts::from_json`); for the
//! sweep/bench kinds it is optional — absent keys fall back to the same
//! defaults the CLI uses, so `{"job":"bench-codecs"}` is a valid spec.

use anyhow::{bail, Context, Result};

use crate::config::TrainConfig;
use crate::experiments::benchcodecs::BenchCodecsOpts;
use crate::experiments::FabricSweepOpts;
use crate::util::json::{num, obj, s, Json};

/// What the job runs, mirroring the one-shot CLI subcommands.
#[derive(Debug, Clone)]
pub enum JobPayload {
    Train(TrainConfig),
    FabricSweep(FabricSweepOpts),
    BenchCodecs(BenchCodecsOpts),
}

/// One schedulable unit of work.
#[derive(Debug, Clone)]
pub struct JobSpec {
    /// Human label echoed in events and listings.
    pub name: String,
    /// Target queue; unknown names auto-create a concurrency-1 queue.
    pub queue: String,
    /// Higher runs first within the queue; ties are FIFO.
    pub priority: i32,
    /// Re-attempts after failure (0 = fail on first error).
    pub max_retries: u32,
    pub payload: JobPayload,
}

impl JobSpec {
    /// The wire name of the payload kind.
    pub fn kind(&self) -> &'static str {
        match self.payload {
            JobPayload::Train(_) => "train",
            JobPayload::FabricSweep(_) => "fabric-sweep",
            JobPayload::BenchCodecs(_) => "bench-codecs",
        }
    }

    pub fn to_json(&self) -> Json {
        let spec = match &self.payload {
            JobPayload::Train(cfg) => cfg.to_json(),
            JobPayload::FabricSweep(opts) => opts.to_json(),
            JobPayload::BenchCodecs(opts) => opts.to_json(),
        };
        obj(vec![
            ("job", s(self.kind())),
            ("name", s(&self.name)),
            ("queue", s(&self.queue)),
            ("priority", num(self.priority as f64)),
            ("max_retries", num(self.max_retries as f64)),
            ("spec", spec),
        ])
    }

    /// Parse a submission envelope. `spec` is required for `train`
    /// (there is no meaningful default model) and optional otherwise.
    pub fn from_json(j: &Json) -> Result<JobSpec> {
        let kind = j
            .expect("job")
            .context("job spec needs a \"job\" kind")?
            .as_str()?
            .to_string();
        let spec = j.get("spec");
        let payload = match kind.as_str() {
            "train" => {
                let spec = spec.context("train jobs need a \"spec\" object")?;
                JobPayload::Train(TrainConfig::from_json(spec)?)
            }
            "fabric-sweep" => JobPayload::FabricSweep(match spec {
                Some(sp) => FabricSweepOpts::from_json(sp)?,
                None => FabricSweepOpts::default(),
            }),
            "bench-codecs" => JobPayload::BenchCodecs(match spec {
                Some(sp) => BenchCodecsOpts::from_json(sp)?,
                None => BenchCodecsOpts::default(),
            }),
            other => bail!(
                "unknown job kind '{other}' (expected train, fabric-sweep, or bench-codecs)"
            ),
        };
        let name = match j.get("name") {
            Some(n) => n.as_str()?.to_string(),
            None => kind.clone(),
        };
        let queue = match j.get("queue") {
            Some(q) => q.as_str()?.to_string(),
            None => "default".to_string(),
        };
        let priority = match j.get("priority") {
            Some(p) => p.as_f64()? as i32,
            None => 0,
        };
        let max_retries = match j.get("max_retries") {
            Some(r) => r.as_usize()? as u32,
            None => 0,
        };
        Ok(JobSpec {
            name,
            queue,
            priority,
            max_retries,
            payload,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimal_envelopes_parse_with_defaults() {
        let j = Json::parse(r#"{"job":"bench-codecs"}"#).unwrap();
        let spec = JobSpec::from_json(&j).unwrap();
        assert_eq!(spec.kind(), "bench-codecs");
        assert_eq!(spec.name, "bench-codecs");
        assert_eq!(spec.queue, "default");
        assert_eq!(spec.priority, 0);
        assert_eq!(spec.max_retries, 0);

        let j = Json::parse(r#"{"job":"fabric-sweep","queue":"sweeps","priority":3}"#).unwrap();
        let spec = JobSpec::from_json(&j).unwrap();
        assert_eq!(spec.queue, "sweeps");
        assert_eq!(spec.priority, 3);
    }

    #[test]
    fn train_requires_spec() {
        let j = Json::parse(r#"{"job":"train"}"#).unwrap();
        assert!(JobSpec::from_json(&j).is_err());
    }

    #[test]
    fn unknown_kind_is_rejected() {
        let j = Json::parse(r#"{"job":"mine-bitcoin"}"#).unwrap();
        let err = JobSpec::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("unknown job kind"), "{err}");
    }

    #[test]
    fn envelope_round_trips() {
        let opts = FabricSweepOpts {
            workers: vec![4],
            n_params: 4096,
            ..FabricSweepOpts::default()
        };
        let spec = JobSpec {
            name: "tiny-sweep".into(),
            queue: "sweeps".into(),
            priority: -2,
            max_retries: 1,
            payload: JobPayload::FabricSweep(opts),
        };
        let j = spec.to_json();
        let back = JobSpec::from_json(&j).unwrap();
        assert_eq!(back.name, "tiny-sweep");
        assert_eq!(back.priority, -2);
        assert_eq!(back.max_retries, 1);
        // Payload round-trips bit-for-bit through its own serializer.
        assert_eq!(back.to_json().to_string(), j.to_string());
    }
}
