//! Training service daemon (`repro serve`): job queues, a typed event
//! bus, and an HTTP control plane over the existing experiment stack.
//!
//! One daemon owns the expensive shared state — a single parallel
//! [`CodecEngine`](crate::compress::CodecEngine) behind a mutex and a
//! single fabric configuration with a cumulative usage ledger — and
//! schedules `train`, `fabric-sweep`, and `bench-codecs` jobs against
//! it. The split of responsibilities:
//!
//! - [`bus`]: typed broadcast events with replay (the observable truth)
//! - [`queue`]: named queues, priority + FIFO, backoff parking
//! - [`scheduler`]: worker pool, retries, cancellation, drain
//! - [`jobspec`]: the serde envelope the control plane accepts
//! - [`http`]: the hand-rolled HTTP/1.1 control plane
//!
//! Executors reuse the one-shot experiment entry points unchanged, so
//! a job's summary is bit-identical to the equivalent CLI run — the
//! integration tests assert exactly that.
//!
//! "Shared fabric" here means the daemon's model of the cluster: train
//! jobs that leave `fabric` at its default inherit the daemon's fabric
//! config, and every fabric-touching job accounts its simulated
//! traffic and wall-clock into one [`FabricUsage`] ledger, exposed at
//! `GET /fabric`. (Concrete `Fabric` instances stay per-gather by
//! design — they are cheap; the *cluster model* is the shared thing.)

pub mod bus;
pub mod http;
pub mod jobspec;
pub mod queue;
pub mod scheduler;

pub use bus::{Event, EventBus};
pub use jobspec::{JobPayload, JobSpec};
pub use queue::{JobId, QueueConfig};
pub use scheduler::{
    Executor, JobCtx, JobSnapshot, JobState, RetryPolicy, Scheduler, SchedulerConfig,
};

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{ensure, Context, Result};

use crate::compress::{shared_engine, SharedEngine};
use crate::config::TrainConfig;
use crate::coordinator::{RunEvent, Trainer};
use crate::experiments::{self, BenchCodecsOpts, FabricSweepOpts};
use crate::fabric::FabricConfig;
use crate::runtime::{Client, Manifest};
use crate::util::json::{num, obj, s, Json};
use crate::util::threadpool::ThreadPool;

/// POSIX signal plumbing without a libc dependency: raw `signal(2)`
/// FFI on unix, a no-op elsewhere. The handler only flips an atomic —
/// the daemon's poll loop does the actual drain.
pub mod sig {
    use std::sync::atomic::{AtomicBool, Ordering};

    static TERM: AtomicBool = AtomicBool::new(false);

    #[cfg(unix)]
    pub fn install() {
        extern "C" fn on_term(_signum: i32) {
            TERM.store(true, Ordering::SeqCst);
        }
        extern "C" {
            fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
        }
        unsafe {
            signal(15, on_term); // SIGTERM: graceful drain
            signal(2, on_term); // SIGINT: same contract interactively
        }
    }

    #[cfg(not(unix))]
    pub fn install() {}

    /// True once SIGTERM/SIGINT has been delivered.
    pub fn received() -> bool {
        TERM.load(Ordering::SeqCst)
    }
}

/// Cumulative fabric ledger across all jobs this daemon has run.
#[derive(Debug, Clone, Copy, Default)]
pub struct FabricUsage {
    /// Fabric-touching jobs completed.
    pub jobs: u64,
    /// Simulated collective operations (gathers + dense baselines).
    pub gathers: u64,
    /// Total simulated egress bytes.
    pub traffic_bytes: u64,
    /// Total simulated wall-clock, picoseconds.
    pub sim_ps: u64,
}

impl FabricUsage {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("jobs", num(self.jobs as f64)),
            ("gathers", num(self.gathers as f64)),
            ("traffic_bytes", num(self.traffic_bytes as f64)),
            ("sim_ps", num(self.sim_ps as f64)),
        ])
    }
}

/// Daemon configuration, assembled from `repro serve` flags.
#[derive(Debug, Clone)]
pub struct DaemonConfig {
    /// Shared codec-engine width (0 = auto).
    pub codec_threads: usize,
    pub scheduler: SchedulerConfig,
    /// Where train jobs find compiled model artifacts.
    pub artifacts_dir: String,
    /// Snapshot file written on graceful shutdown (terminal job states
    /// survive the process).
    pub state_path: Option<String>,
    /// The daemon's cluster model; inherited by train jobs that leave
    /// their fabric at default.
    pub fabric: FabricConfig,
}

impl Default for DaemonConfig {
    fn default() -> Self {
        DaemonConfig {
            codec_threads: 0,
            scheduler: SchedulerConfig::default(),
            artifacts_dir: "artifacts".into(),
            state_path: None,
            fabric: FabricConfig::default(),
        }
    }
}

/// Everything executors share: the codec engine, the cluster model,
/// and the usage ledger. Captured by the executor closure so the
/// scheduler stays ignorant of training.
pub struct ExecCtx {
    pub engine: SharedEngine,
    pub artifacts_dir: String,
    pub fabric: FabricConfig,
    pub usage: Mutex<FabricUsage>,
}

/// The long-running service: scheduler + bus + shared resources.
pub struct Daemon {
    ctx: Arc<ExecCtx>,
    bus: Arc<EventBus>,
    scheduler: Scheduler,
    stopping: AtomicBool,
    state_path: Option<String>,
}

impl Daemon {
    /// Build the shared engine and start the scheduler pool. The HTTP
    /// listener is attached separately by [`Daemon::run`].
    pub fn start(cfg: DaemonConfig) -> Arc<Daemon> {
        let threads = if cfg.codec_threads == 0 {
            ThreadPool::available()
        } else {
            cfg.codec_threads
        };
        let bus = Arc::new(EventBus::new());
        let ctx = Arc::new(ExecCtx {
            engine: shared_engine(threads),
            artifacts_dir: cfg.artifacts_dir,
            fabric: cfg.fabric,
            usage: Mutex::new(FabricUsage::default()),
        });
        let exec_ctx = ctx.clone();
        let exec: Executor = Arc::new(move |spec, jctx| run_job(&exec_ctx, spec, jctx));
        let scheduler = Scheduler::start(cfg.scheduler, exec, bus.clone());
        Arc::new(Daemon {
            ctx,
            bus,
            scheduler,
            stopping: AtomicBool::new(false),
            state_path: cfg.state_path,
        })
    }

    pub fn scheduler(&self) -> &Scheduler {
        &self.scheduler
    }

    pub fn bus(&self) -> &Arc<EventBus> {
        &self.bus
    }

    pub fn stopping(&self) -> bool {
        self.stopping.load(Ordering::Relaxed)
    }

    /// Request shutdown (POST /shutdown); equivalent to SIGTERM.
    pub fn begin_shutdown(&self) {
        self.stopping.store(true, Ordering::Relaxed);
    }

    pub fn engine_threads(&self) -> usize {
        self.ctx
            .engine
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .threads()
    }

    pub fn health_json(&self) -> Json {
        obj(vec![
            ("status", s(if self.stopping() { "draining" } else { "ok" })),
            ("draining", Json::Bool(self.scheduler.draining())),
            ("engine_threads", num(self.engine_threads() as f64)),
            ("jobs", num(self.scheduler.jobs().len() as f64)),
            ("events", num(self.bus.published() as f64)),
        ])
    }

    pub fn jobs_json(&self) -> Json {
        Json::Arr(self.scheduler.jobs().iter().map(|j| j.to_json()).collect())
    }

    pub fn queues_json(&self) -> Json {
        Json::Arr(
            self.scheduler
                .queues()
                .iter()
                .map(|q| q.to_json())
                .collect(),
        )
    }

    pub fn fabric_json(&self) -> Json {
        let usage = *self.ctx.usage.lock().unwrap_or_else(|e| e.into_inner());
        obj(vec![
            ("config", self.ctx.fabric.to_json()),
            ("usage", usage.to_json()),
        ])
    }

    /// Serve until SIGTERM/SIGINT or POST /shutdown, then drain: stop
    /// accepting jobs, cancel queued ones, finish running ones, stop
    /// the listener, persist the final snapshot, exit.
    pub fn run(self: &Arc<Self>, listen: &str) -> Result<()> {
        sig::install();
        let mut cp = http::ControlPlane::start(listen, self.clone())?;
        // Tests and scripts parse this exact line for the bound port.
        println!("serve: listening on {}", cp.addr);
        println!(
            "serve: engine threads={} fabric={}",
            self.engine_threads(),
            self.ctx.fabric.topology.label()
        );
        while !sig::received() && !self.stopping() {
            std::thread::sleep(Duration::from_millis(50));
        }
        println!("serve: draining (finishing running jobs, rejecting new ones)");
        self.begin_shutdown();
        self.scheduler.drain();
        self.scheduler.join();
        cp.stop();
        if let Some(path) = &self.state_path {
            std::fs::write(path, self.scheduler.snapshot_json().to_string())
                .with_context(|| format!("persist state to {path}"))?;
            println!("serve: state persisted to {path}");
        }
        println!("serve: shutdown complete");
        Ok(())
    }
}

/// NaN/inf have no JSON literal; summaries encode them as null.
fn num_or_null(x: f64) -> Json {
    if x.is_finite() {
        num(x)
    } else {
        Json::Null
    }
}

/// FNV-1a 64 over the little-endian bytes of a float slice: a cheap,
/// stable fingerprint for "are these parameters bit-identical".
pub fn fnv64_f32(xs: &[f32]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for x in xs {
        for b in x.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// Dispatch on the payload kind; the scheduler's injected executor.
fn run_job(ctx: &ExecCtx, spec: &JobSpec, jctx: &JobCtx) -> Result<Json> {
    match &spec.payload {
        JobPayload::Train(cfg) => run_train(ctx, cfg, jctx),
        JobPayload::FabricSweep(opts) => run_fabric_sweep(ctx, opts, jctx),
        JobPayload::BenchCodecs(opts) => run_bench_codecs(opts, jctx),
    }
}

fn run_train(ctx: &ExecCtx, cfg: &TrainConfig, jctx: &JobCtx) -> Result<Json> {
    let mut cfg = cfg.clone();
    if cfg.fabric == FabricConfig::default() {
        // The job did not pin a cluster model: use the daemon's.
        cfg.fabric = ctx.fabric.clone();
    }
    let manifest = Manifest::load(&ctx.artifacts_dir)?;
    let client = Client::cpu()?;
    let total = cfg.steps;
    let progress_every = if cfg.log_every > 0 { cfg.log_every } else { 10 };
    let mut trainer = Trainer::with_engine(&client, &manifest, cfg, ctx.engine.clone())?;
    let finished = trainer.run_with(true, &mut |ev| {
        match ev {
            RunEvent::Step {
                step,
                loss,
                comp_ratio,
                sim_step_ps,
                ..
            } => {
                // Every step goes on the event stream as a typed metric
                // line; the coarser human-readable progress keeps its
                // log_every gating.
                jctx.publish(Event::Step {
                    job: jctx.id,
                    step,
                    loss: loss as f64,
                    comp_ratio,
                    sim_step_ps,
                });
                if step % progress_every == 0 {
                    jctx.progress(step, total, &format!("loss {loss:.4}"));
                }
            }
            RunEvent::Fault { step, kind, node } => {
                jctx.publish(Event::Fault {
                    job: jctx.id,
                    step,
                    kind: kind.to_string(),
                    node,
                });
            }
            RunEvent::Degraded { step, live, total } => {
                jctx.publish(Event::Degraded {
                    job: jctx.id,
                    step,
                    live,
                    total,
                });
            }
            RunEvent::Knob {
                step,
                bucket,
                name,
                value,
                gain,
            } => {
                jctx.publish(Event::Knob {
                    job: jctx.id,
                    step,
                    bucket,
                    name,
                    value: value as f64,
                    gain,
                });
            }
            RunEvent::Eval { .. } => {}
        }
        !jctx.cancelled()
    })?;
    ensure!(finished, "cancelled at step boundary");

    let m = &trainer.metrics;
    let wire: u64 = m.steps.iter().map(|r| r.wire_bytes).sum();
    {
        let mut u = ctx.usage.lock().unwrap_or_else(|e| e.into_inner());
        u.jobs += 1;
        u.gathers += trainer.step_count();
        u.traffic_bytes += wire;
        u.sim_ps += trainer.sim_comm_ps;
    }
    Ok(obj(vec![
        ("kind", s("train")),
        ("model", s(&trainer.cfg.model)),
        ("steps", num(trainer.step_count() as f64)),
        ("final_loss", num_or_null(m.final_loss() as f64)),
        ("final_accuracy", num_or_null(m.final_accuracy() as f64)),
        ("compression_ratio", num_or_null(m.compression_ratio())),
        ("bits_ratio", num_or_null(m.bits_ratio())),
        ("residual_l1", num_or_null(trainer.residual_l1())),
        ("sim_comm_ps", num(trainer.sim_comm_ps as f64)),
        ("sim_phased_ps", num(trainer.sim_phased_ps as f64)),
        ("sim_overlap_ps", num(trainer.sim_overlap_ps as f64)),
        ("fault_report", trainer.fault_report.to_json()),
        (
            "params_fnv64",
            s(&format!("{:016x}", fnv64_f32(&trainer.params))),
        ),
    ]))
}

fn run_fabric_sweep(ctx: &ExecCtx, opts: &FabricSweepOpts, jctx: &JobCtx) -> Result<Json> {
    experiments::validate_sweep(opts)?;
    let total = opts.workers.len() as u64;
    let mut rows = Vec::new();
    // Worker counts are the sweep's outermost axis, so running one
    // count at a time and concatenating reproduces the one-shot row
    // order bit-for-bit while giving cancellation a boundary.
    for (i, &p) in opts.workers.iter().enumerate() {
        jctx.check()?;
        let cell = FabricSweepOpts {
            workers: vec![p],
            ..opts.clone()
        };
        rows.extend(experiments::fabric_sweep(&cell));
        jctx.progress(i as u64 + 1, total, &format!("{p} workers done"));
    }
    {
        let mut u = ctx.usage.lock().unwrap_or_else(|e| e.into_inner());
        u.jobs += 1;
        u.gathers += 2 * rows.len() as u64; // gatherv + dense baseline
        u.traffic_bytes += rows.iter().map(|r| r.traffic_bytes).sum::<u64>();
        u.sim_ps += rows.iter().map(|r| (r.sim_ms * 1e9) as u64).sum::<u64>();
    }
    Ok(obj(vec![
        ("kind", s("fabric-sweep")),
        ("cells", num(rows.len() as f64)),
        ("rows", experiments::fabric_sweep_json(&rows)),
    ]))
}

fn run_bench_codecs(opts: &BenchCodecsOpts, jctx: &JobCtx) -> Result<Json> {
    ensure!(!opts.codecs.is_empty(), "bench-codecs: no codecs listed");
    ensure!(
        opts.threads.iter().all(|&t| t >= 1),
        "bench-codecs: thread counts must be >= 1"
    );
    let total = opts.codecs.len() as u64;
    let mut rows = Vec::new();
    // Codecs are the bench's outermost axis and inputs are rebuilt from
    // a fixed seed per call, so per-codec cells concatenate into the
    // one-shot row order (deterministic fields bit-identical; timing
    // fields are measurements and vary by nature).
    for (i, codec) in opts.codecs.iter().enumerate() {
        jctx.check()?;
        let cell = BenchCodecsOpts {
            codecs: vec![codec.clone()],
            ..opts.clone()
        };
        rows.extend(experiments::bench_codecs(&cell));
        jctx.progress(i as u64 + 1, total, &codec.label());
    }
    Ok(obj(vec![
        ("kind", s("bench-codecs")),
        ("report", experiments::bench_codecs_json(opts, &rows)),
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv64_is_stable_and_order_sensitive() {
        let a = fnv64_f32(&[1.0, 2.0, 3.0]);
        let b = fnv64_f32(&[1.0, 2.0, 3.0]);
        let c = fnv64_f32(&[3.0, 2.0, 1.0]);
        assert_eq!(a, b);
        assert_ne!(a, c);
        // Pinned value: a silent change to the fingerprint would break
        // cross-process comparisons in the integration tests.
        assert_eq!(fnv64_f32(&[]), 0xcbf2_9ce4_8422_2325);
    }

    #[test]
    fn num_or_null_guards_non_finite() {
        assert_eq!(num_or_null(f64::NAN), Json::Null);
        assert_eq!(num_or_null(f64::INFINITY), Json::Null);
        assert_eq!(num_or_null(1.5).to_string(), "1.5");
    }

    #[test]
    fn bench_executor_matches_one_shot_rows() {
        use crate::compress::CodecSpec;
        // Tiny bench: the daemon path (per-codec cells) must produce
        // the same deterministic fields as one bench_codecs call.
        let opts = BenchCodecsOpts {
            n: 4096,
            group: 256,
            workers: 2,
            threads: vec![1],
            alloc_steps: 1,
            codecs: vec![
                CodecSpec::Vgc {
                    alpha: 1.5,
                    zeta: 0.999,
                },
                CodecSpec::Strom { tau: 0.01 },
            ],
        };
        let direct = experiments::bench_codecs(&opts);
        let bus = Arc::new(EventBus::new());
        let ctx = JobCtx::detached(&bus);
        let summary = run_bench_codecs(&opts, &ctx).unwrap();
        let report = summary.get("report").unwrap();
        let rows = report.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), direct.len());
        for (row_json, row) in rows.iter().zip(&direct) {
            assert_eq!(
                row_json.get("codec").unwrap().as_str().unwrap(),
                row.codec
            );
            assert_eq!(
                row_json.get("wire_bytes_per_worker").unwrap().as_f64().unwrap(),
                row.wire_bytes_per_worker as f64
            );
        }
    }
}
