//! Counting global allocator — the §Perf zero-allocation contract's
//! measuring stick, plus live/peak byte tracking for the scale sweep.
//!
//! Binaries (and the `alloc_steady` integration test) opt in with
//!
//! ```ignore
//! #[global_allocator]
//! static GLOBAL: vgc::util::alloc::CountingAlloc = CountingAlloc::new();
//! ```
//!
//! after which [`allocations`] reports the cumulative number of heap
//! allocation events (alloc / alloc_zeroed / realloc) process-wide,
//! and [`live_bytes`]/[`peak_bytes`] the current and high-water heap
//! footprint. `repro bench-codecs` samples the event counter around
//! steady-state codec steps to *record* each path's allocation
//! behavior (the legacy serial path allocates per message by design;
//! the engine's reused buffers do not); `repro scale-sweep` samples
//! the peak counter around each simulated cell to report peak memory.
//! The zero-allocation proof for the reworked kernels themselves lives
//! in `tests/alloc_steady.rs`, which drives
//! `encode_step_into`/`decode_entries` directly. When the counter was
//! never installed everything stays 0 and the reports mark the numbers
//! unavailable.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);
static LIVE_BYTES: AtomicU64 = AtomicU64::new(0);
static PEAK_BYTES: AtomicU64 = AtomicU64::new(0);

/// Record `size` freshly allocated bytes and bump the high-water mark.
fn credit(size: usize) {
    let live = LIVE_BYTES.fetch_add(size as u64, Ordering::Relaxed) + size as u64;
    PEAK_BYTES.fetch_max(live, Ordering::Relaxed);
}

/// Thin wrapper over [`System`] that counts allocation events and
/// tracks live/peak bytes.
pub struct CountingAlloc;

impl CountingAlloc {
    pub const fn new() -> CountingAlloc {
        CountingAlloc
    }
}

impl Default for CountingAlloc {
    fn default() -> Self {
        CountingAlloc::new()
    }
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        let p = System.alloc(layout);
        if !p.is_null() {
            credit(layout.size());
        }
        p
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        let p = System.alloc_zeroed(layout);
        if !p.is_null() {
            credit(layout.size());
        }
        p
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        let p = System.realloc(ptr, layout, new_size);
        if !p.is_null() {
            LIVE_BYTES.fetch_sub(layout.size() as u64, Ordering::Relaxed);
            credit(new_size);
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        LIVE_BYTES.fetch_sub(layout.size() as u64, Ordering::Relaxed);
        System.dealloc(ptr, layout)
    }
}

/// Cumulative allocation events since process start (0 when the counting
/// allocator is not installed as `#[global_allocator]`).
pub fn allocations() -> u64 {
    ALLOCATIONS.load(Ordering::Relaxed)
}

/// Bytes currently allocated (0 when the counter is not installed).
pub fn live_bytes() -> u64 {
    LIVE_BYTES.load(Ordering::Relaxed)
}

/// High-water heap footprint since process start or the last
/// [`reset_peak`] (0 when the counter is not installed).
pub fn peak_bytes() -> u64 {
    PEAK_BYTES.load(Ordering::Relaxed)
}

/// Re-arm the high-water mark at the current live footprint, so a
/// caller can attribute a peak to one phase (per scale-sweep cell).
pub fn reset_peak() {
    PEAK_BYTES.store(LIVE_BYTES.load(Ordering::Relaxed), Ordering::Relaxed);
}

/// True once any allocation has been observed — i.e. the counting
/// allocator is actually installed (every Rust program allocates long
/// before user code runs).
pub fn counting_enabled() -> bool {
    allocations() > 0
}
