//! Counting global allocator — the §Perf zero-allocation contract's
//! measuring stick.
//!
//! Binaries (and the `alloc_steady` integration test) opt in with
//!
//! ```ignore
//! #[global_allocator]
//! static GLOBAL: vgc::util::alloc::CountingAlloc = CountingAlloc::new();
//! ```
//!
//! after which [`allocations`] reports the cumulative number of heap
//! allocation events (alloc / alloc_zeroed / realloc) process-wide.
//! `repro bench-codecs` samples the counter around steady-state codec
//! steps to *record* each path's allocation behavior (the legacy
//! serial path allocates per message by design; the engine's reused
//! buffers do not). The zero-allocation proof for the reworked kernels
//! themselves lives in `tests/alloc_steady.rs`, which drives
//! `encode_step_into`/`decode_entries` directly. When the counter was
//! never installed it stays 0 and the bench reports allocation counts
//! as unavailable.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);

/// Thin wrapper over [`System`] that counts allocation events.
pub struct CountingAlloc;

impl CountingAlloc {
    pub const fn new() -> CountingAlloc {
        CountingAlloc
    }
}

impl Default for CountingAlloc {
    fn default() -> Self {
        CountingAlloc::new()
    }
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

/// Cumulative allocation events since process start (0 when the counting
/// allocator is not installed as `#[global_allocator]`).
pub fn allocations() -> u64 {
    ALLOCATIONS.load(Ordering::Relaxed)
}

/// True once any allocation has been observed — i.e. the counting
/// allocator is actually installed (every Rust program allocates long
/// before user code runs).
pub fn counting_enabled() -> bool {
    allocations() > 0
}
