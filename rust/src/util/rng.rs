//! Deterministic pseudo-random number generation.
//!
//! The crate cannot depend on `rand` (offline build — DESIGN.md
//! §Substitutions), so this module provides the two generators the
//! system needs: SplitMix64 for seeding/stream-splitting and PCG32 for
//! the bulk streams (data synthesis, stochastic rounding in QSGD /
//! TernGrad, property-test case generation). Both are well-known,
//! public-domain algorithms; determinism across runs is a hard
//! requirement for experiment reproducibility.

/// SplitMix64: fast, high-quality 64-bit mixer. Used to derive
/// independent seeds for per-worker / per-purpose streams.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG32 (XSH-RR 64/32): the workhorse stream generator.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    /// Seed a stream. `stream` selects one of 2^63 independent sequences.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Self {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Derive a child generator with an independent stream.
    pub fn split(&mut self, purpose: u64) -> Pcg32 {
        let mut mix = SplitMix64::new(self.next_u64() ^ purpose);
        Pcg32::new(mix.next_u64(), mix.next_u64())
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)` with 24 bits of mantissa entropy.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in `[0, 1)` with 53 bits.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, bound)` (Lemire's method, unbiased).
    #[inline]
    pub fn next_bounded(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u32();
            let m = (x as u64).wrapping_mul(bound as u64);
            let l = m as u32;
            if l >= bound || l >= (bound.wrapping_neg() % bound) {
                return (m >> 32) as u32;
            }
        }
    }

    /// Standard normal via Box–Muller (caches the second value).
    pub fn next_normal(&mut self) -> f32 {
        // Non-caching Box-Muller: two uniforms per normal. Simple and
        // stateless; the throughput difference is irrelevant off the hot
        // path (data synthesis happens once per run).
        let u1 = (1.0 - self.next_f64()).max(f64::MIN_POSITIVE);
        let u2 = self.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Bernoulli draw.
    #[inline]
    pub fn next_bool(&mut self, p: f32) -> bool {
        self.next_f32() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_bounded(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // First outputs for seed 0 (cross-checked reference sequence).
        let mut rng = SplitMix64::new(0);
        let a = rng.next_u64();
        let b = rng.next_u64();
        assert_ne!(a, b);
        let mut rng2 = SplitMix64::new(0);
        assert_eq!(a, rng2.next_u64());
    }

    #[test]
    fn pcg_deterministic_per_seed_and_stream() {
        let mut a = Pcg32::new(42, 1);
        let mut b = Pcg32::new(42, 1);
        let mut c = Pcg32::new(42, 2);
        let va: Vec<u32> = (0..8).map(|_| a.next_u32()).collect();
        let vb: Vec<u32> = (0..8).map(|_| b.next_u32()).collect();
        let vc: Vec<u32> = (0..8).map(|_| c.next_u32()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut rng = Pcg32::new(7, 7);
        for _ in 0..10_000 {
            let x = rng.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn bounded_is_in_range_and_covers() {
        let mut rng = Pcg32::new(3, 9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = rng.next_bounded(10);
            assert!(x < 10);
            seen[x as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments_are_plausible() {
        let mut rng = Pcg32::new(11, 4);
        let n = 100_000;
        let (mut sum, mut sumsq) = (0f64, 0f64);
        for _ in 0..n {
            let x = rng.next_normal() as f64;
            sum += x;
            sumsq += x * x;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut rng = Pcg32::new(5, 5);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn split_streams_diverge() {
        let mut parent = Pcg32::new(1, 1);
        let mut c1 = parent.split(1);
        let mut c2 = parent.split(1);
        assert_ne!(
            (0..4).map(|_| c1.next_u32()).collect::<Vec<_>>(),
            (0..4).map(|_| c2.next_u32()).collect::<Vec<_>>()
        );
    }
}
