//! Bounded exponential backoff, shared by the job scheduler's retry
//! policy (milliseconds) and the fabric's collective retransmit path
//! (picoseconds). One implementation, one set of clamping rules: delay
//! after `failures` failed attempts is `base · factor^(failures−1)`,
//! capped at `max` and floored at `min(base, max)`.

/// Unit-agnostic bounded exponential backoff. `base` and `max` share
/// whatever unit the caller uses (ms for the scheduler, ps for the
/// fabric).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Backoff {
    pub base: u64,
    pub factor: f64,
    pub max: u64,
}

impl Backoff {
    /// Delay before the next attempt after `failures` failed attempts
    /// (`failures` counts from 1).
    pub fn delay(&self, failures: u32) -> u64 {
        let exp = failures.saturating_sub(1).min(63);
        let raw = self.base as f64 * self.factor.powi(exp as i32);
        (raw as u64).min(self.max).max(self.base.min(self.max))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delay_grows_geometrically_and_caps() {
        let b = Backoff {
            base: 100,
            factor: 2.0,
            max: 450,
        };
        assert_eq!(b.delay(1), 100);
        assert_eq!(b.delay(2), 200);
        assert_eq!(b.delay(3), 400);
        assert_eq!(b.delay(4), 450); // capped
        assert_eq!(b.delay(63), 450); // no overflow
        assert_eq!(b.delay(0), 100); // clamped to the floor
    }

    #[test]
    fn floor_is_min_of_base_and_max() {
        // A max below base floors at max, not base.
        let b = Backoff {
            base: 1000,
            factor: 2.0,
            max: 10,
        };
        assert_eq!(b.delay(1), 10);
        assert_eq!(b.delay(5), 10);
    }
}
