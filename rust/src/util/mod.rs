//! Shared substrates: PRNG, JSON, CLI parsing, scoped threading, and
//! small numeric helpers.

pub mod alloc;
pub mod backoff;
pub mod cli;
pub mod json;
pub mod rng;
pub mod threadpool;

/// Mean of a slice (0.0 for empty — callers decide if that is meaningful).
pub fn mean(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f32>() / xs.len() as f32
}

/// Percentile via nearest-rank on a sorted copy. `q` in [0, 1].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((sorted.len() as f64 - 1.0) * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Format a big count with thousands separators for table output.
pub fn with_commas(x: u64) -> String {
    let s = x.to_string();
    let mut out = String::new();
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_percentile() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 100.0);
        assert!((percentile(&xs, 0.5) - 50.0).abs() <= 1.0);
    }

    #[test]
    fn comma_formatting() {
        assert_eq!(with_commas(0), "0");
        assert_eq!(with_commas(999), "999");
        assert_eq!(with_commas(1000), "1,000");
        assert_eq!(with_commas(12_822_400), "12,822,400");
    }
}
