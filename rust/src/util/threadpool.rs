//! Scoped thread pool for the parallel codec engine (§Perf L3).
//!
//! The offline crate set has no `rayon`, so this is a minimal
//! work-queue fan-out built directly on [`std::thread::scope`]: each
//! [`ThreadPool::run`] call spawns up to `threads` scoped OS threads
//! that drain a shared task queue, then joins them all before
//! returning. Tasks may therefore borrow from the caller's stack
//! (mutable disjoint slices, shared inputs) with no `unsafe` and no
//! lifetime erasure — the scope guarantees every borrow outlives every
//! task.
//!
//! Cost model: one `run` call costs O(threads) thread spawns (a few
//! tens of microseconds each), which is negligible against the
//! multi-millisecond encode/decode phases it parallelizes. The codec
//! *kernels* stay allocation-free; the fan-out itself costs O(threads)
//! small allocations per phase (boxed tasks + thread stacks), which is
//! the documented exception to the zero-allocation steady state.

use std::collections::VecDeque;
use std::sync::Mutex;

/// A unit of work: runs once, may borrow caller state for `'s`.
pub type Task<'s> = Box<dyn FnOnce() + Send + 's>;

/// Fixed-width scoped thread pool. `threads == 1` runs every task
/// inline on the caller's thread (the exact serial path, no spawns).
#[derive(Debug, Clone)]
pub struct ThreadPool {
    threads: usize,
}

impl ThreadPool {
    pub fn new(threads: usize) -> ThreadPool {
        ThreadPool {
            threads: threads.max(1),
        }
    }

    /// The machine's available parallelism (fallback 1).
    pub fn available() -> usize {
        std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1)
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run all tasks to completion. Tasks are executed in queue order by
    /// whichever worker is free (completion order is unspecified, so
    /// tasks must write to disjoint state). Panics in a task propagate
    /// to the caller after all threads join.
    pub fn run<'s>(&self, tasks: Vec<Task<'s>>) {
        if self.threads == 1 || tasks.len() <= 1 {
            for t in tasks {
                t();
            }
            return;
        }
        let n_workers = self.threads.min(tasks.len());
        let queue: Mutex<VecDeque<Task<'s>>> = Mutex::new(tasks.into());
        std::thread::scope(|scope| {
            for _ in 0..n_workers {
                scope.spawn(|| loop {
                    let task = queue.lock().unwrap().pop_front();
                    match task {
                        Some(t) => t(),
                        None => break,
                    }
                });
            }
        });
    }
}

/// Split `n` items into at most `parts` contiguous near-equal ranges
/// (the last may be short; empty input yields no ranges).
pub fn split_ranges(n: usize, parts: usize) -> Vec<std::ops::Range<usize>> {
    let mut out = Vec::new();
    if n == 0 {
        return out;
    }
    let chunk = n.div_ceil(parts.max(1));
    let mut lo = 0;
    while lo < n {
        let hi = (lo + chunk).min(n);
        out.push(lo..hi);
        lo = hi;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_every_task_exactly_once() {
        for threads in [1, 2, 3, 8] {
            let pool = ThreadPool::new(threads);
            let counter = AtomicUsize::new(0);
            let tasks: Vec<Task> = (0..20)
                .map(|_| {
                    let c = &counter;
                    Box::new(move || {
                        c.fetch_add(1, Ordering::Relaxed);
                    }) as Task
                })
                .collect();
            pool.run(tasks);
            assert_eq!(counter.load(Ordering::Relaxed), 20, "threads={threads}");
        }
    }

    #[test]
    fn tasks_may_mutate_disjoint_borrowed_slices() {
        let pool = ThreadPool::new(4);
        let mut data = vec![0u64; 1000];
        let mut tasks: Vec<Task> = Vec::new();
        for (k, chunk) in data.chunks_mut(100).enumerate() {
            tasks.push(Box::new(move || {
                for (i, x) in chunk.iter_mut().enumerate() {
                    *x = (k * 100 + i) as u64;
                }
            }));
        }
        pool.run(tasks);
        for (i, &x) in data.iter().enumerate() {
            assert_eq!(x, i as u64);
        }
    }

    #[test]
    fn zero_and_single_task_inputs() {
        let pool = ThreadPool::new(4);
        pool.run(Vec::new());
        let mut hit = false;
        pool.run(vec![Box::new(|| hit = true) as Task]);
        assert!(hit);
    }

    #[test]
    fn clamps_to_one_thread_minimum() {
        assert_eq!(ThreadPool::new(0).threads(), 1);
        assert!(ThreadPool::available() >= 1);
    }

    #[test]
    fn split_ranges_partitions_exactly() {
        assert_eq!(split_ranges(0, 4), vec![]);
        assert_eq!(split_ranges(10, 3), vec![0..4, 4..8, 8..10]);
        assert_eq!(split_ranges(3, 8), vec![0..1, 1..2, 2..3]);
        let rs = split_ranges(1_000_003, 7);
        assert!(rs.len() <= 7);
        assert_eq!(rs.first().unwrap().start, 0);
        assert_eq!(rs.last().unwrap().end, 1_000_003);
        for w in rs.windows(2) {
            assert_eq!(w[0].end, w[1].start);
        }
    }
}
