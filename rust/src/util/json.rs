//! Minimal JSON parser/serializer.
//!
//! Reads `artifacts/manifest.json` (written by the python AOT path) and
//! experiment config files, and writes result records. Hand-rolled
//! because the offline crate set has no `serde_json` (DESIGN.md
//! §Substitutions). Supports the full JSON grammar except `\u` surrogate
//! pairs beyond the BMP (sufficient for our ASCII manifests, and
//! rejected loudly otherwise).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Object keys are ordered (BTreeMap) so that
/// serialization is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- typed accessors (loud failures beat silent defaults) ----

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn expect(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key '{key}'"))
    }

    pub fn as_str(&self) -> anyhow::Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => anyhow::bail!("expected string, got {other:?}"),
        }
    }

    pub fn as_f64(&self) -> anyhow::Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            other => anyhow::bail!("expected number, got {other:?}"),
        }
    }

    pub fn as_usize(&self) -> anyhow::Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            anyhow::bail!("expected non-negative integer, got {x}");
        }
        Ok(x as usize)
    }

    pub fn as_arr(&self) -> anyhow::Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            other => anyhow::bail!("expected array, got {other:?}"),
        }
    }

    pub fn as_bool(&self) -> anyhow::Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => anyhow::bail!("expected bool, got {other:?}"),
        }
    }

    /// Compact serialization (deterministic key order).
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructors for building result records.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(x: f64) -> Json {
    Json::Num(x)
}

pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect_byte(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect_byte(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect_byte(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect_byte(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect_byte(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex in \\u"))?;
                        }
                        if (0xD800..0xE000).contains(&code) {
                            return Err(self.err("surrogate pairs unsupported"));
                        }
                        out.push(char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        if start + len > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..start + len])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                        self.pos = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["{", "[1,", "{\"a\" 1}", "tru", "\"abc", "1 2", "{'a':1}"] {
            assert!(Json::parse(bad).is_err(), "{bad} should fail");
        }
    }

    #[test]
    fn roundtrip_is_stable() {
        let src = r#"{"b":[1,2.5,true,null,"s"],"a":{"x":-7}}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn unicode_escape_and_utf8() {
        let v = Json::parse(r#""é café ☕""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é café ☕");
    }

    #[test]
    fn usize_accessor_rejects_fractions() {
        assert!(Json::parse("1.5").unwrap().as_usize().is_err());
        assert!(Json::parse("-1").unwrap().as_usize().is_err());
        assert_eq!(Json::parse("7").unwrap().as_usize().unwrap(), 7);
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{
            "format_version": 1,
            "models": [{"name": "mlp", "n_params": 26122,
                        "groups": [{"name": "['a']", "offset": 0, "len": 5}]}]
        }"#;
        let v = Json::parse(src).unwrap();
        let m = &v.get("models").unwrap().as_arr().unwrap()[0];
        assert_eq!(m.get("n_params").unwrap().as_usize().unwrap(), 26122);
    }
}
