//! Tiny CLI argument parser (the offline crate set has no `clap`).
//!
//! Supports the subset the launcher needs: `--flag value`,
//! `--flag=value`, boolean `--flag`, positional subcommands, and
//! generated usage text. Unknown flags are hard errors — silent
//! acceptance of a typo'd experiment flag would corrupt a run.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct Args {
    flags: BTreeMap<String, String>,
    bools: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse raw args (excluding argv[0]). `bool_flags` lists flags that
    /// take no value.
    pub fn parse(raw: &[String], bool_flags: &[&str]) -> anyhow::Result<Args> {
        let mut flags = BTreeMap::new();
        let mut bools = Vec::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else if bool_flags.contains(&body) {
                    bools.push(body.to_string());
                } else {
                    i += 1;
                    let v = raw
                        .get(i)
                        .ok_or_else(|| anyhow::anyhow!("--{body} needs a value"))?;
                    flags.insert(body.to_string(), v.clone());
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Ok(Args {
            flags,
            bools,
            positional,
        })
    }

    pub fn from_env(bool_flags: &[&str]) -> anyhow::Result<Args> {
        let raw: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&raw, bool_flags)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn has(&self, flag: &str) -> bool {
        self.bools.iter().any(|b| b == flag) || self.flags.contains_key(flag)
    }

    pub fn get(&self, flag: &str) -> Option<&str> {
        self.flags.get(flag).map(|s| s.as_str())
    }

    pub fn str_or(&self, flag: &str, default: &str) -> String {
        self.get(flag).unwrap_or(default).to_string()
    }

    pub fn parse_or<T: std::str::FromStr>(&self, flag: &str, default: T) -> anyhow::Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(flag) {
            None => Ok(default),
            Some(v) => v
                .parse::<T>()
                .map_err(|e| anyhow::anyhow!("bad value for --{flag}: {e}")),
        }
    }

    pub fn require(&self, flag: &str) -> anyhow::Result<&str> {
        self.get(flag)
            .ok_or_else(|| anyhow::anyhow!("missing required --{flag}"))
    }

    /// Error on any flag not in `known` (typo protection).
    pub fn check_known(&self, known: &[&str]) -> anyhow::Result<()> {
        for k in self.flags.keys().chain(self.bools.iter()) {
            if !known.contains(&k.as_str()) {
                anyhow::bail!("unknown flag --{k}; known: {known:?}");
            }
        }
        Ok(())
    }

    /// Comma-separated list flag.
    pub fn list(&self, flag: &str) -> Vec<String> {
        self.get(flag)
            .map(|v| {
                v.split(',')
                    .map(|s| s.trim().to_string())
                    .filter(|s| !s.is_empty())
                    .collect()
            })
            .unwrap_or_default()
    }

    /// Comma-separated *typed* list flag (e.g. `--bandwidth-gbps 1,10`
    /// or `--workers 4,8,16`). Absent flag → empty vec; any unparsable
    /// element is a hard error.
    pub fn parse_list<T: std::str::FromStr>(&self, flag: &str) -> anyhow::Result<Vec<T>>
    where
        T::Err: std::fmt::Display,
    {
        self.list(flag)
            .iter()
            .map(|v| {
                v.parse::<T>()
                    .map_err(|e| anyhow::anyhow!("bad value '{v}' for --{flag}: {e}"))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn raw(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_flag_styles() {
        let a = Args::parse(
            &raw(&["train", "--model", "mlp", "--alpha=1.5", "--verbose"]),
            &["verbose"],
        )
        .unwrap();
        assert_eq!(a.positional(), &["train"]);
        assert_eq!(a.get("model"), Some("mlp"));
        assert_eq!(a.parse_or::<f64>("alpha", 0.0).unwrap(), 1.5);
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(&raw(&["--model"]), &[]).is_err());
    }

    #[test]
    fn defaults_and_requires() {
        let a = Args::parse(&raw(&[]), &[]).unwrap();
        assert_eq!(a.parse_or::<u32>("steps", 100).unwrap(), 100);
        assert!(a.require("model").is_err());
    }

    #[test]
    fn bad_parse_is_error_not_default() {
        let a = Args::parse(&raw(&["--steps", "abc"]), &[]).unwrap();
        assert!(a.parse_or::<u32>("steps", 1).is_err());
    }

    #[test]
    fn unknown_flag_detection() {
        let a = Args::parse(&raw(&["--modle", "mlp"]), &[]).unwrap();
        assert!(a.check_known(&["model"]).is_err());
        assert!(a.check_known(&["modle"]).is_ok());
    }

    #[test]
    fn list_flag() {
        let a = Args::parse(&raw(&["--alphas", "1, 1.5,2.0"]), &[]).unwrap();
        assert_eq!(a.list("alphas"), vec!["1", "1.5", "2.0"]);
        assert!(a.list("nope").is_empty());
    }

    #[test]
    fn typed_list_flag() {
        let a = Args::parse(&raw(&["--workers", "4, 8,16", "--bw", "1,2.5"]), &[]).unwrap();
        assert_eq!(a.parse_list::<usize>("workers").unwrap(), vec![4, 8, 16]);
        assert_eq!(a.parse_list::<f64>("bw").unwrap(), vec![1.0, 2.5]);
        assert!(a.parse_list::<usize>("nope").unwrap().is_empty());
        assert!(a.parse_list::<usize>("bw").is_err());
    }
}
