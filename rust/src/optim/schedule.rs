//! Learning-rate schedules.
//!
//! The paper's CIFAR-10 Momentum runs halve the LR every 25 epochs
//! (Sec. 6.1); Adam runs use a constant LR. `StepDecay` generalizes the
//! former; `Constant` the latter.

#[derive(Debug, Clone)]
pub enum LrSchedule {
    Constant {
        lr: f32,
    },
    /// `lr · factor^(step / every)` — the paper's halving schedule with
    /// `factor = 0.5`, `every = 25 epochs` worth of steps.
    StepDecay {
        lr: f32,
        factor: f32,
        every: u64,
    },
    /// Linear warmup to `lr` over `warmup` steps, then constant.
    Warmup {
        lr: f32,
        warmup: u64,
    },
}

impl LrSchedule {
    pub fn at(&self, step: u64) -> f32 {
        match *self {
            LrSchedule::Constant { lr } => lr,
            LrSchedule::StepDecay { lr, factor, every } => {
                lr * factor.powi((step / every.max(1)) as i32)
            }
            LrSchedule::Warmup { lr, warmup } => {
                if warmup == 0 || step >= warmup {
                    lr
                } else {
                    lr * (step + 1) as f32 / warmup as f32
                }
            }
        }
    }

    /// Parse `const:0.05`, `step:0.05,0.5,100`, `warmup:0.001,50`.
    pub fn parse(s: &str) -> anyhow::Result<LrSchedule> {
        let (kind, rest) = s
            .split_once(':')
            .ok_or_else(|| anyhow::anyhow!("schedule needs 'kind:params', got '{s}'"))?;
        let parts: Vec<&str> = rest.split(',').collect();
        let f = |i: usize| -> anyhow::Result<f32> {
            parts
                .get(i)
                .ok_or_else(|| anyhow::anyhow!("schedule '{s}' missing param {i}"))?
                .trim()
                .parse()
                .map_err(|e| anyhow::anyhow!("bad schedule param in '{s}': {e}"))
        };
        Ok(match kind {
            "const" => LrSchedule::Constant { lr: f(0)? },
            "step" => LrSchedule::StepDecay {
                lr: f(0)?,
                factor: f(1)?,
                every: f(2)? as u64,
            },
            "warmup" => LrSchedule::Warmup {
                lr: f(0)?,
                warmup: f(1)? as u64,
            },
            other => anyhow::bail!("unknown schedule kind '{other}'"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_is_constant() {
        let s = LrSchedule::Constant { lr: 0.1 };
        assert_eq!(s.at(0), 0.1);
        assert_eq!(s.at(1_000_000), 0.1);
    }

    #[test]
    fn step_decay_halves_on_boundaries() {
        let s = LrSchedule::StepDecay {
            lr: 0.4,
            factor: 0.5,
            every: 100,
        };
        assert_eq!(s.at(0), 0.4);
        assert_eq!(s.at(99), 0.4);
        assert_eq!(s.at(100), 0.2);
        assert_eq!(s.at(250), 0.1);
    }

    #[test]
    fn warmup_ramps_linearly() {
        let s = LrSchedule::Warmup { lr: 1.0, warmup: 10 };
        assert!((s.at(0) - 0.1).abs() < 1e-6);
        assert!((s.at(4) - 0.5).abs() < 1e-6);
        assert_eq!(s.at(10), 1.0);
        assert_eq!(s.at(100), 1.0);
    }

    #[test]
    fn parses_all_kinds() {
        assert_eq!(LrSchedule::parse("const:0.05").unwrap().at(5), 0.05);
        let s = LrSchedule::parse("step:0.4,0.5,100").unwrap();
        assert_eq!(s.at(100), 0.2);
        let w = LrSchedule::parse("warmup:1.0,10").unwrap();
        assert_eq!(w.at(20), 1.0);
        assert!(LrSchedule::parse("cosine:1").is_err());
        assert!(LrSchedule::parse("0.05").is_err());
    }
}
