//! Optimizers (S10): SGD, Momentum SGD, Adam — applied *locally after
//! communication* (Sec. 4.3: "Some optimization methods, such as ADAM,
//! require preprocessing for parameter updates. They are calculated
//! locally after the communication.").
//!
//! The input to `step` is the decoded, aggregated global gradient (the
//! sum of all workers' decoded messages). Gradient elements that were
//! *not* sent are exactly zero here — the paper: "In the combination
//! with optimization methods like Momentum SGD, gradient elements not
//! sent are assumed to be equal to zero."

pub mod schedule;

pub use schedule::LrSchedule;

/// A parameter-update rule over the flat vector.
pub trait Optimizer: Send {
    fn name(&self) -> String;

    /// Apply one update in place: `params -= f(grad)` at learning rate
    /// `lr` (already schedule-resolved by the caller).
    fn step(&mut self, params: &mut [f32], grad: &[f32], lr: f32);
}

/// Plain SGD: `x ← x − γ·g`.
pub struct Sgd;

impl Optimizer for Sgd {
    fn name(&self) -> String {
        "sgd".into()
    }

    fn step(&mut self, params: &mut [f32], grad: &[f32], lr: f32) {
        assert_eq!(params.len(), grad.len());
        for (p, &g) in params.iter_mut().zip(grad) {
            *p -= lr * g;
        }
    }
}

/// Momentum SGD (Sutskever et al. 2013 heavy-ball form):
/// `u ← μ·u + g; x ← x − γ·u`.
pub struct Momentum {
    mu: f32,
    u: Vec<f32>,
}

impl Momentum {
    pub fn new(n: usize, mu: f32) -> Momentum {
        assert!((0.0..1.0).contains(&mu));
        Momentum { mu, u: vec![0.0; n] }
    }
}

impl Optimizer for Momentum {
    fn name(&self) -> String {
        format!("momentum(mu={})", self.mu)
    }

    fn step(&mut self, params: &mut [f32], grad: &[f32], lr: f32) {
        assert_eq!(params.len(), grad.len());
        assert_eq!(params.len(), self.u.len());
        for i in 0..params.len() {
            self.u[i] = self.mu * self.u[i] + grad[i];
            params[i] -= lr * self.u[i];
        }
    }
}

/// Adam (Ba & Kingma 2015) with the paper's default hyperparameters.
pub struct Adam {
    beta1: f32,
    beta2: f32,
    eps: f32,
    m: Vec<f32>,
    v: Vec<f32>,
    t: u64,
}

impl Adam {
    pub fn new(n: usize) -> Adam {
        Adam::with_params(n, 0.9, 0.999, 1e-8)
    }

    pub fn with_params(n: usize, beta1: f32, beta2: f32, eps: f32) -> Adam {
        Adam {
            beta1,
            beta2,
            eps,
            m: vec![0.0; n],
            v: vec![0.0; n],
            t: 0,
        }
    }
}

impl Optimizer for Adam {
    fn name(&self) -> String {
        "adam".into()
    }

    fn step(&mut self, params: &mut [f32], grad: &[f32], lr: f32) {
        assert_eq!(params.len(), grad.len());
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t as i32);
        let b2t = 1.0 - self.beta2.powi(self.t as i32);
        for i in 0..params.len() {
            let g = grad[i];
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * g;
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * g * g;
            let mhat = self.m[i] / b1t;
            let vhat = self.v[i] / b2t;
            params[i] -= lr * mhat / (vhat.sqrt() + self.eps);
        }
    }
}

/// Weight decay applied as a separate decoupled step (the paper's
/// CIFAR runs use weight decay 5e-4).
pub fn apply_weight_decay(params: &mut [f32], lr: f32, wd: f32) {
    if wd == 0.0 {
        return;
    }
    let k = 1.0 - lr * wd;
    for p in params.iter_mut() {
        *p *= k;
    }
}

/// Build an optimizer by name.
pub fn build(name: &str, n: usize) -> anyhow::Result<Box<dyn Optimizer>> {
    Ok(match name {
        "sgd" => Box::new(Sgd),
        "momentum" => Box::new(Momentum::new(n, 0.9)),
        "adam" => Box::new(Adam::new(n)),
        other => anyhow::bail!("unknown optimizer '{other}' (sgd|momentum|adam)"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quadratic_converges(opt: &mut dyn Optimizer, lr: f32) -> f32 {
        // Minimize f(x) = 0.5 Σ (x_i − i)². Gradient: x_i − i.
        let n = 8;
        let mut x = vec![0.0f32; n];
        for _ in 0..500 {
            let g: Vec<f32> = x.iter().enumerate().map(|(i, &xi)| xi - i as f32).collect();
            opt.step(&mut x, &g, lr);
        }
        x.iter()
            .enumerate()
            .map(|(i, &xi)| (xi - i as f32).abs())
            .fold(0.0, f32::max)
    }

    #[test]
    fn sgd_converges_on_quadratic() {
        assert!(quadratic_converges(&mut Sgd, 0.1) < 1e-3);
    }

    #[test]
    fn momentum_converges_on_quadratic() {
        assert!(quadratic_converges(&mut Momentum::new(8, 0.9), 0.05) < 1e-3);
    }

    #[test]
    fn adam_converges_on_quadratic() {
        assert!(quadratic_converges(&mut Adam::new(8), 0.05) < 1e-2);
    }

    #[test]
    fn sgd_matches_closed_form() {
        let mut x = vec![1.0f32];
        Sgd.step(&mut x, &[0.5], 0.2);
        assert!((x[0] - 0.9).abs() < 1e-7);
    }

    #[test]
    fn momentum_accumulates_velocity() {
        let mut m = Momentum::new(1, 0.5);
        let mut x = vec![0.0f32];
        m.step(&mut x, &[1.0], 1.0); // u=1, x=-1
        assert!((x[0] + 1.0).abs() < 1e-7);
        m.step(&mut x, &[1.0], 1.0); // u=1.5, x=-2.5
        assert!((x[0] + 2.5).abs() < 1e-7);
    }

    #[test]
    fn adam_first_step_is_lr_sized() {
        // Bias correction makes |Δx| ≈ lr on step 1 regardless of g scale.
        for g in [1e-4f32, 1.0, 1e4] {
            let mut a = Adam::new(1);
            let mut x = vec![0.0f32];
            a.step(&mut x, &[g], 0.01);
            assert!((x[0].abs() - 0.01).abs() < 1e-4, "g={g}: dx={}", x[0]);
        }
    }

    #[test]
    fn zero_gradient_elements_leave_sgd_params_untouched() {
        // The sparse-codec contract: unsent == zero == no direct update.
        let mut x = vec![1.0f32, 2.0];
        Sgd.step(&mut x, &[0.0, 1.0], 0.1);
        assert_eq!(x[0], 1.0);
        assert!((x[1] - 1.9).abs() < 1e-7);
    }

    #[test]
    fn weight_decay_shrinks_parameters() {
        let mut x = vec![2.0f32, -2.0];
        apply_weight_decay(&mut x, 0.1, 0.5);
        assert!((x[0] - 1.9).abs() < 1e-6);
        assert!((x[1] + 1.9).abs() < 1e-6);
    }

    #[test]
    fn build_by_name() {
        assert!(build("sgd", 4).is_ok());
        assert!(build("momentum", 4).is_ok());
        assert!(build("adam", 4).is_ok());
        assert!(build("lion", 4).is_err());
    }
}
