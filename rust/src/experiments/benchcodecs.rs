//! `repro bench-codecs` — the §Perf L3 wire-path benchmark.
//!
//! Measures per-codec encode/decode throughput over a gradient-realistic
//! stream at `workers` simulated workers, comparing the serial path
//! (`threads = 1`: per-worker `encode_step` + sequential `decode_into`,
//! exactly what the trainer runs pre-engine) against the parallel
//! sharded engine (`threads > 1`), plus steady-state heap-allocation
//! counts when the counting allocator is installed (the `repro` binary
//! installs it; see `util::alloc`).
//!
//! Emits a markdown table and, with `--json`, a `BENCH_codecs.json`
//! record so the perf trajectory is tracked across PRs.

use crate::bench::Bencher;
use crate::compress::{Codec, CodecEngine, CodecSpec};
use crate::model::Layout;
use crate::testkit;
use crate::util::alloc;
use crate::util::json::{num, obj, s, Json};
use crate::util::rng::Pcg32;
use crate::util::threadpool::ThreadPool;

#[derive(Debug, Clone)]
pub struct BenchCodecsOpts {
    /// Gradient elements per worker stream.
    pub n: usize,
    /// Quantization-group size of the synthetic layout.
    pub group: usize,
    /// Simulated workers (one codec instance each).
    pub workers: usize,
    /// Engine widths to measure (1 = the exact legacy serial path).
    pub threads: Vec<usize>,
    /// Steps in the allocation-count probe.
    pub alloc_steps: u32,
    pub codecs: Vec<CodecSpec>,
}

impl Default for BenchCodecsOpts {
    fn default() -> Self {
        BenchCodecsOpts {
            n: 1_000_000,
            group: 4096,
            workers: 8,
            threads: vec![1, ThreadPool::available()],
            alloc_steps: 5,
            codecs: vec![
                CodecSpec::Vgc { alpha: 1.5, zeta: 0.999 },
                CodecSpec::VgcCompact { alpha: 1.5, zeta: 0.999 },
                CodecSpec::Strom { tau: 0.01 },
                CodecSpec::Hybrid { tau: 0.01, alpha: 2.0, zeta: 0.999 },
                CodecSpec::Adaptive { pi: 0.01 },
                CodecSpec::None,
            ],
        }
    }
}

impl BenchCodecsOpts {
    /// Serialize for job specs; inverse of [`BenchCodecsOpts::from_json`].
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("n", num(self.n as f64)),
            ("group", num(self.group as f64)),
            ("workers", num(self.workers as f64)),
            (
                "threads",
                Json::Arr(self.threads.iter().map(|&t| num(t as f64)).collect()),
            ),
            ("alloc_steps", num(self.alloc_steps as f64)),
            (
                "codecs",
                Json::Arr(
                    self.codecs
                        .iter()
                        .map(|c| s(&crate::config::codec_str(c)))
                        .collect(),
                ),
            ),
        ])
    }

    /// Load from JSON; absent keys keep the CLI defaults.
    pub fn from_json(j: &Json) -> anyhow::Result<BenchCodecsOpts> {
        let mut o = BenchCodecsOpts::default();
        if let Some(v) = j.get("n") {
            o.n = v.as_usize()?;
        }
        if let Some(v) = j.get("group") {
            o.group = v.as_usize()?;
        }
        if let Some(v) = j.get("workers") {
            o.workers = v.as_usize()?;
        }
        if let Some(t) = j.get("threads") {
            o.threads = t
                .as_arr()?
                .iter()
                .map(|x| x.as_usize())
                .collect::<anyhow::Result<Vec<_>>>()?;
        }
        if let Some(v) = j.get("alloc_steps") {
            o.alloc_steps = v.as_usize()? as u32;
        }
        if let Some(c) = j.get("codecs") {
            o.codecs = c
                .as_arr()?
                .iter()
                .map(|x| CodecSpec::parse(x.as_str()?))
                .collect::<anyhow::Result<Vec<_>>>()?;
        }
        Ok(o)
    }
}

#[derive(Debug, Clone)]
pub struct BenchCodecsRow {
    pub codec: String,
    pub threads: usize,
    pub encode_elem_per_s: f64,
    pub decode_elem_per_s: f64,
    /// p·n elements over one encode + one decode of all messages.
    pub combined_elem_per_s: f64,
    /// Steady-state heap allocations per step (None when the counting
    /// allocator is not installed).
    pub allocs_per_step: Option<f64>,
    pub wire_bytes_per_worker: u64,
}

/// Run the sweep. Workers all see the same fixed per-worker streams, so
/// serial and parallel rows measure identical work.
pub fn bench_codecs(opts: &BenchCodecsOpts) -> Vec<BenchCodecsRow> {
    let b = Bencher::default();
    let layout = Layout::uniform(opts.n, opts.group);
    let mut rng = Pcg32::new(42, 7);
    let inputs: Vec<(Vec<f32>, Vec<f32>)> = (0..opts.workers)
        .map(|_| {
            let g = testkit::gradient_vec(&mut rng, opts.n);
            let q: Vec<f32> = g.iter().map(|x| x * x * 1.5).collect();
            (g, q)
        })
        .collect();
    let mut rows = Vec::new();
    for spec in &opts.codecs {
        for &threads in &opts.threads {
            rows.push(bench_one(&b, spec, threads, &layout, &inputs, opts));
        }
    }
    rows
}

fn bench_one(
    b: &Bencher,
    spec: &CodecSpec,
    threads: usize,
    layout: &Layout,
    inputs: &[(Vec<f32>, Vec<f32>)],
    opts: &BenchCodecsOpts,
) -> BenchCodecsRow {
    let p = inputs.len();
    let n = layout.n();
    let mut codecs: Vec<Box<dyn Codec>> =
        (0..p).map(|w| spec.build(layout, w as u64)).collect();
    let mut engine = CodecEngine::new(threads);
    let mut update = vec![0.0f32; n];
    let gs: Vec<&[f32]> = inputs.iter().map(|(g, _)| g.as_slice()).collect();
    let qs: Vec<&[f32]> = inputs.iter().map(|(_, q)| q.as_slice()).collect();
    let name = spec.label();

    // Warm the residual state + buffer capacities, and capture one set
    // of messages for the decode benchmark.
    let msgs: Vec<Vec<u8>> = {
        let mut refs: Vec<&mut dyn Codec> = codecs.iter_mut().map(|c| &mut **c).collect();
        engine.encode_all(&mut refs, &gs, &qs);
        engine.encode_all(&mut refs, &gs, &qs);
        engine.messages().to_vec()
    };
    let wire_bytes_per_worker =
        msgs.iter().map(|m| m.len() as u64).sum::<u64>() / p.max(1) as u64;

    let (enc, dec, allocs) = if threads == 1 {
        // The exact legacy serial path: owned-message encode, sequential
        // accumulate decode.
        let enc = b.run(&format!("encode/{name}/serial"), || {
            for w in 0..p {
                let msg = codecs[w].encode_step(gs[w], qs[w]);
                std::hint::black_box(msg.elements);
            }
        });
        let dec = b.run(&format!("decode/{name}/serial"), || {
            for x in update.iter_mut() {
                *x = 0.0;
            }
            for m in &msgs {
                codecs[0].decode_into(m, &mut update).unwrap();
            }
            std::hint::black_box(update[0]);
        });
        let allocs = probe_allocs(opts.alloc_steps, || {
            for w in 0..p {
                let msg = codecs[w].encode_step(gs[w], qs[w]);
                std::hint::black_box(msg.elements);
            }
        });
        (enc, dec, allocs)
    } else {
        let mut refs: Vec<&mut dyn Codec> = codecs.iter_mut().map(|c| &mut **c).collect();
        let enc = b.run(&format!("encode/{name}/t{threads}"), || {
            engine.encode_all(&mut refs, &gs, &qs);
        });
        drop(refs);
        let dec = b.run(&format!("decode/{name}/t{threads}"), || {
            engine.decode_all(&*codecs[0], &msgs, &mut update).unwrap();
            std::hint::black_box(update[0]);
        });
        let mut refs: Vec<&mut dyn Codec> = codecs.iter_mut().map(|c| &mut **c).collect();
        let allocs = probe_allocs(opts.alloc_steps, || {
            engine.encode_all(&mut refs, &gs, &qs);
        });
        (enc, dec, allocs)
    };

    let items = (p * n) as f64;
    let combined = items / (enc.mean.as_secs_f64() + dec.mean.as_secs_f64());
    BenchCodecsRow {
        codec: name,
        threads,
        encode_elem_per_s: enc.throughput(items),
        decode_elem_per_s: dec.throughput(items),
        combined_elem_per_s: combined,
        allocs_per_step: allocs,
        wire_bytes_per_worker,
    }
}

/// Allocation events per iteration of `step` (None when the counting
/// allocator is not installed — the library tests, for example).
fn probe_allocs<F: FnMut()>(steps: u32, mut step: F) -> Option<f64> {
    if !alloc::counting_enabled() {
        return None;
    }
    let steps = steps.max(1);
    step(); // settle capacities
    let before = alloc::allocations();
    for _ in 0..steps {
        step();
    }
    Some((alloc::allocations() - before) as f64 / steps as f64)
}

/// The headline acceptance ratio: best parallel combined throughput
/// over the serial combined throughput for the given codec label.
pub fn speedup_for(rows: &[BenchCodecsRow], codec_label: &str) -> Option<f64> {
    let serial = rows
        .iter()
        .find(|r| r.codec == codec_label && r.threads == 1)?;
    let best = rows
        .iter()
        .filter(|r| r.codec == codec_label && r.threads > 1)
        .map(|r| r.combined_elem_per_s)
        .fold(f64::NAN, f64::max);
    if best.is_nan() {
        None
    } else {
        Some(best / serial.combined_elem_per_s)
    }
}

pub fn bench_codecs_markdown(opts: &BenchCodecsOpts, rows: &[BenchCodecsRow]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "# codec engine bench — n={} workers={} group={}\n\n",
        opts.n, opts.workers, opts.group
    ));
    out.push_str(
        "| codec | threads | encode Melem/s | decode Melem/s | combined Melem/s | allocs/step | wire B/worker |\n",
    );
    out.push_str("|---|---|---|---|---|---|---|\n");
    for r in rows {
        out.push_str(&format!(
            "| {} | {} | {:.2} | {:.2} | {:.2} | {} | {} |\n",
            r.codec,
            r.threads,
            r.encode_elem_per_s / 1e6,
            r.decode_elem_per_s / 1e6,
            r.combined_elem_per_s / 1e6,
            r.allocs_per_step
                .map(|a| format!("{a:.1}"))
                .unwrap_or_else(|| "n/a".into()),
            r.wire_bytes_per_worker,
        ));
    }
    let vgc_label = CodecSpec::Vgc { alpha: 1.5, zeta: 0.999 }.label();
    if let Some(sp) = speedup_for(rows, &vgc_label) {
        out.push_str(&format!(
            "\nvgc combined encode+decode speedup (parallel / serial): {sp:.2}x\n"
        ));
    }
    out
}

pub fn bench_codecs_json(opts: &BenchCodecsOpts, rows: &[BenchCodecsRow]) -> Json {
    let vgc_label = CodecSpec::Vgc { alpha: 1.5, zeta: 0.999 }.label();
    obj(vec![
        ("bench", s("codecs")),
        ("n", num(opts.n as f64)),
        ("workers", num(opts.workers as f64)),
        ("group", num(opts.group as f64)),
        (
            "vgc_parallel_speedup",
            speedup_for(rows, &vgc_label).map(num).unwrap_or(Json::Null),
        ),
        (
            "rows",
            Json::Arr(
                rows.iter()
                    .map(|r| {
                        obj(vec![
                            ("codec", s(&r.codec)),
                            ("threads", num(r.threads as f64)),
                            ("encode_elem_per_s", num(r.encode_elem_per_s)),
                            ("decode_elem_per_s", num(r.decode_elem_per_s)),
                            ("combined_elem_per_s", num(r.combined_elem_per_s)),
                            (
                                "allocs_per_step",
                                r.allocs_per_step.map(num).unwrap_or(Json::Null),
                            ),
                            (
                                "wire_bytes_per_worker",
                                num(r.wire_bytes_per_worker as f64),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn tiny_opts() -> BenchCodecsOpts {
        BenchCodecsOpts {
            n: 2000,
            group: 64,
            workers: 3,
            threads: vec![1, 2],
            alloc_steps: 1,
            codecs: vec![
                CodecSpec::Vgc { alpha: 1.5, zeta: 0.999 },
                CodecSpec::Strom { tau: 0.01 },
            ],
        }
    }

    #[test]
    fn sweep_produces_rows_and_json() {
        // Shrink the bencher budget via a tiny workload; the default
        // Bencher still iterates but each iteration is microseconds.
        let opts = tiny_opts();
        let b = Bencher {
            min_iters: 2,
            budget: Duration::from_millis(5),
            warmup: 1,
        };
        let layout = Layout::uniform(opts.n, opts.group);
        let mut rng = Pcg32::new(1, 1);
        let inputs: Vec<(Vec<f32>, Vec<f32>)> = (0..opts.workers)
            .map(|_| {
                let g = testkit::gradient_vec(&mut rng, opts.n);
                let q: Vec<f32> = g.iter().map(|x| x * x).collect();
                (g, q)
            })
            .collect();
        let mut rows = Vec::new();
        for spec in &opts.codecs {
            for &threads in &opts.threads {
                rows.push(bench_one(&b, spec, threads, &layout, &inputs, &opts));
            }
        }
        assert_eq!(rows.len(), 4);
        assert!(rows.iter().all(|r| r.combined_elem_per_s > 0.0));
        let md = bench_codecs_markdown(&opts, &rows);
        assert!(md.contains("| codec |"), "{md}");
        assert!(md.contains("speedup"), "{md}");
        let j = bench_codecs_json(&opts, &rows).to_string();
        let back = Json::parse(&j).unwrap();
        assert_eq!(back.expect("rows").unwrap().as_arr().unwrap().len(), 4);
    }
}
