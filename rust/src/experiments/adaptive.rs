//! Static-vs-adaptive compression sweep (`repro adaptive-sweep`).
//!
//! For every {codec × topology × inter-rack-gbps} cell the sweep runs
//! the same multi-step encode → overlapped-gather → decode loop twice:
//! once with the codec's knob pinned at its initial value (static, the
//! paper's fixed-ζ regime) and once with the closed-loop
//! [`KnobController`] driving it from fabric telemetry. Each row
//! reports, side by side: mean wire gain, mean overlapped step time,
//! and a divergence proxy (relative L2 between the decoded update and
//! the dense mean gradient), plus how often and how far the controller
//! moved the knob.
//!
//! Non-tunable codecs (qsgd/terngrad/onebit/none) have no knob: their
//! adaptive pass is bit-identical to static and the row shows zero
//! knob moves — property-tested below.

use anyhow::Result;

use crate::comm::allgatherv::allgatherv_overlapped;
use crate::comm::pipeline;
use crate::compress::engine::EncodeStats;
use crate::compress::{Aggregation, Codec, CodecSpec, ControllerConfig, KnobController};
use crate::config::codec_str;
use crate::fabric::{FabricConfig, LinkSpec, TopologyKind};
use crate::model::Layout;
use crate::testkit;
use crate::util::json::{num, obj, s, Json};
use crate::util::rng::Pcg32;

/// Sweep dimensions for the static-vs-adaptive comparison.
#[derive(Debug, Clone)]
pub struct AdaptiveSweepOpts {
    pub topologies: Vec<TopologyKind>,
    pub workers: usize,
    pub codecs: Vec<CodecSpec>,
    /// Bandwidth-skew axis: hierarchy cells are repeated per uplink
    /// bandwidth (Gbps). Empty = the hierarchy's 10:1 default.
    pub inter_rack_gbps: Vec<f64>,
    /// Synthetic gradient dimension.
    pub n_params: usize,
    /// Loop length per mode; the controller needs a few steps of
    /// telemetry to settle, so keep this ≥ ~8.
    pub steps: u64,
    pub bandwidth_gbps: f64,
    pub latency_us: f64,
    /// Tensor-fusion threshold, bytes (0 = one bucket).
    pub bucket_bytes: usize,
    /// Controller pressure target (`--adaptive-target` equivalent).
    pub target: f64,
    /// Synthetic backprop cost feeding bucket-ready times, ns/param.
    pub compute_ns_per_param: f64,
    /// Synthetic serial-encoder cost, ns/param.
    pub encode_ns_per_param: f64,
    pub seed: u64,
}

impl Default for AdaptiveSweepOpts {
    fn default() -> Self {
        AdaptiveSweepOpts {
            topologies: vec![TopologyKind::Ring, TopologyKind::Hier { groups: 0 }],
            workers: 8,
            codecs: vec![
                CodecSpec::Vgc {
                    alpha: 2.0,
                    zeta: 0.999,
                },
                CodecSpec::Strom { tau: 0.01 },
            ],
            inter_rack_gbps: Vec::new(),
            n_params: 65_536,
            steps: 8,
            bandwidth_gbps: 1.0,
            latency_us: 50.0,
            bucket_bytes: 65_536,
            target: 1.0,
            compute_ns_per_param: 50.0,
            encode_ns_per_param: 10.0,
            seed: 0,
        }
    }
}

/// Sanity-check a sweep before running it (CLI entry point).
pub fn validate_adaptive(opts: &AdaptiveSweepOpts) -> Result<()> {
    anyhow::ensure!(!opts.topologies.is_empty(), "sweep lists no topologies");
    anyhow::ensure!(!opts.codecs.is_empty(), "sweep lists no codecs");
    anyhow::ensure!(opts.workers >= 2, "adaptive-sweep needs >= 2 workers");
    anyhow::ensure!(opts.n_params > 0, "n must be positive");
    anyhow::ensure!(opts.steps > 0, "steps must be positive");
    anyhow::ensure!(opts.target > 0.0, "target must be positive");
    anyhow::ensure!(opts.bandwidth_gbps > 0.0, "bandwidth-gbps must be positive");
    anyhow::ensure!(
        opts.inter_rack_gbps.iter().all(|g| *g > 0.0),
        "inter-rack-gbps values must be positive"
    );
    anyhow::ensure!(
        opts.compute_ns_per_param >= 0.0 && opts.encode_ns_per_param >= 0.0,
        "compute-ns and encode-ns must be non-negative"
    );
    for &kind in &opts.topologies {
        let probe = FabricConfig {
            topology: kind,
            inter_rack_gbps: match kind {
                TopologyKind::Hier { .. } => opts.inter_rack_gbps.first().copied(),
                _ => None,
            },
            ..FabricConfig::default()
        };
        probe.validate(opts.workers)?;
    }
    Ok(())
}

/// One cell: the static and adaptive passes of one codec on one fabric.
#[derive(Debug, Clone)]
pub struct AdaptiveSweepRow {
    pub topology: TopologyKind,
    /// Hierarchy cells only: the uplink bandwidth of this cell.
    pub inter_rack_gbps: Option<f64>,
    pub codec: String,
    /// Mean wire gain (dense bits / payload bits) per mode.
    pub static_gain: f64,
    pub adaptive_gain: f64,
    /// Mean overlapped step span per mode, ms.
    pub static_step_ms: f64,
    pub adaptive_step_ms: f64,
    /// Mean relative L2 between the decoded update and the dense mean
    /// gradient per mode (lower = closer to uncompressed SGD).
    pub static_divergence: f64,
    pub adaptive_divergence: f64,
    /// Knob adjustments the controller made across the adaptive pass.
    pub knob_moves: u64,
    /// The knob's final scalar value (comm-weighted for ranged codecs);
    /// `None` when the codec is non-tunable.
    pub final_knob: Option<f32>,
}

/// Everything one pass of the loop accumulates.
struct ModeResult {
    gain: f64,
    step_ms: f64,
    divergence: f64,
    knob_moves: u64,
    final_knob: Option<f32>,
}

/// See `align_bucket_comm` in the trainer: the overlap scheduler may
/// merge adjacent buckets, so redistribute total comm time onto the
/// static bucket layout by dense-byte weight when the counts differ.
fn align_comm(comm: &[u64], weights: &[u64]) -> Vec<u64> {
    if comm.len() == weights.len() {
        return comm.to_vec();
    }
    let total: u128 = comm.iter().map(|&c| c as u128).sum();
    let wsum: u128 = weights.iter().map(|&w| w as u128).sum::<u128>().max(1);
    weights
        .iter()
        .map(|&w| (total * w as u128 / wsum) as u64)
        .collect()
}

/// Run one pass of the encode→gather→decode loop; `adaptive` selects
/// whether the controller is in the loop. Both passes see the exact
/// same gradient stream (seeded per worker, independent of the codec).
fn run_mode(opts: &AdaptiveSweepOpts, cfg: &FabricConfig, spec: &CodecSpec, adaptive: bool) -> ModeResult {
    let p = opts.workers;
    let n = opts.n_params;
    let layout = Layout::uniform(n, 256);
    let buckets = pipeline::form_buckets(&layout, opts.bucket_bytes);
    let weights = pipeline::bucket_weights(&buckets);
    let mut codecs: Vec<Box<dyn Codec>> = (0..p)
        .map(|w| spec.build(&layout, opts.seed.wrapping_add(w as u64)))
        .collect();
    let mut controller = if adaptive {
        codecs[0].knob().map(|knob| {
            let ranges: Vec<(usize, usize)> = buckets
                .iter()
                .map(|b| (b.params.start, b.params.end))
                .collect();
            KnobController::new(
                ControllerConfig {
                    target: opts.target,
                    seed: opts.seed,
                    ..ControllerConfig::default()
                },
                knob,
                ranges,
            )
        })
    } else {
        None
    };
    let grad_ps = (n as f64 * opts.compute_ns_per_param * 1e3) as u64;
    let encode_ps = (n as f64 * opts.encode_ns_per_param * 1e3) as u64;
    let mut rngs: Vec<Pcg32> = (0..p)
        .map(|w| Pcg32::new(opts.seed ^ 0x5EED_FAB, w as u64))
        .collect();

    let mut sum_gain = 0.0f64;
    let mut sum_step_ps = 0u128;
    let mut sum_div = 0.0f64;
    let mut knob_moves = 0u64;
    let mut final_scalar: Option<f32> = None;
    let mut update = vec![0.0f32; n];
    let mut dense = vec![0.0f32; n];
    for _ in 0..opts.steps {
        let grads: Vec<Vec<f32>> = rngs
            .iter_mut()
            .map(|r| testkit::gradient_vec(r, n))
            .collect();
        dense.iter_mut().for_each(|d| *d = 0.0);
        for g in &grads {
            for (d, &x) in dense.iter_mut().zip(g.iter()) {
                *d += x;
            }
        }
        let inv = 1.0 / p as f32;
        dense.iter_mut().for_each(|d| *d *= inv);

        let mut elements = 0u64;
        let mut payload_bits = 0u64;
        let msgs: Vec<Vec<u8>> = codecs
            .iter_mut()
            .zip(&grads)
            .map(|(c, g)| {
                let sq: Vec<f32> = g.iter().map(|x| x * x * 0.5).collect();
                let m = c.encode_step(g, &sq);
                elements += m.elements;
                payload_bits += m.payload_bits;
                m.bytes
            })
            .collect();
        let ov = allgatherv_overlapped(cfg, &msgs, &weights, grad_ps, encode_ps);
        sum_step_ps += ov.schedule.overlapped_ps as u128;

        // Decode worker 0's gathered view — the update every worker
        // applies — and compare it to the dense mean gradient.
        update.iter_mut().for_each(|u| *u = 0.0);
        for m in &ov.gathered[0] {
            codecs[0]
                .decode_into(m, &mut update)
                .expect("self-produced message decodes");
        }
        if codecs[0].aggregation() == Aggregation::Mean {
            update.iter_mut().for_each(|u| *u *= inv);
        }
        let mut err2 = 0.0f64;
        let mut ref2 = 0.0f64;
        for (u, d) in update.iter().zip(dense.iter()) {
            let e = (*u - *d) as f64;
            err2 += e * e;
            ref2 += (*d as f64) * (*d as f64);
        }
        sum_div += (err2 / ref2.max(1e-30)).sqrt();

        let stats = EncodeStats {
            elements,
            payload_bits,
        };
        let gain = stats.gain(n * p);
        sum_gain += gain;

        if let Some(ctl) = controller.as_mut() {
            let comm = align_comm(&ov.telemetry.bucket_comm_ps, &weights);
            let uplink = ov.telemetry.uplink_byte_fraction();
            let ups = ctl.observe(&comm, grad_ps + encode_ps, uplink, gain);
            if !ups.is_empty() {
                let mut ranged = true;
                'apply: for up in &ups {
                    for c in codecs.iter_mut() {
                        if !c.set_knob_range(up.lo, up.hi, up.value) {
                            ranged = false;
                            break 'apply;
                        }
                    }
                }
                if !ranged {
                    let v = ctl.scalar_value(&comm);
                    for c in codecs.iter_mut() {
                        c.set_knob(v);
                    }
                }
                knob_moves += ups.len() as u64;
            }
            final_scalar = Some(ctl.scalar_value(&comm));
        }
    }
    let steps = opts.steps as f64;
    ModeResult {
        gain: sum_gain / steps,
        step_ms: sum_step_ps as f64 * 1e-9 / steps,
        divergence: sum_div / steps,
        knob_moves,
        final_knob: final_scalar,
    }
}

/// Run the full sweep: every codec on every fabric cell, static and
/// adaptive back to back on identical gradient streams.
pub fn adaptive_sweep(opts: &AdaptiveSweepOpts) -> Result<Vec<AdaptiveSweepRow>> {
    validate_adaptive(opts)?;
    let mut rows = Vec::new();
    for &kind in &opts.topologies {
        // Only the hierarchy has an uplink; other topologies get a
        // single cell with the axis unset.
        let uplinks: Vec<Option<f64>> =
            if matches!(kind, TopologyKind::Hier { .. }) && !opts.inter_rack_gbps.is_empty() {
                opts.inter_rack_gbps.iter().copied().map(Some).collect()
            } else {
                vec![None]
            };
        for &uplink in &uplinks {
            let cfg = FabricConfig {
                topology: kind,
                link: LinkSpec {
                    bandwidth_gbps: opts.bandwidth_gbps,
                    latency_us: opts.latency_us,
                    jitter_us: 0.0,
                },
                inter_rack_gbps: uplink,
                seed: opts.seed,
                ..FabricConfig::default()
            };
            for spec in &opts.codecs {
                let st = run_mode(opts, &cfg, spec, false);
                let ad = run_mode(opts, &cfg, spec, true);
                rows.push(AdaptiveSweepRow {
                    topology: kind,
                    inter_rack_gbps: uplink,
                    codec: codec_str(spec),
                    static_gain: st.gain,
                    adaptive_gain: ad.gain,
                    static_step_ms: st.step_ms,
                    adaptive_step_ms: ad.step_ms,
                    static_divergence: st.divergence,
                    adaptive_divergence: ad.divergence,
                    knob_moves: ad.knob_moves,
                    final_knob: ad.final_knob,
                });
            }
        }
    }
    Ok(rows)
}

/// Markdown table of the sweep (the `repro adaptive-sweep` report).
pub fn adaptive_sweep_markdown(opts: &AdaptiveSweepOpts, rows: &[AdaptiveSweepRow]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "### adaptive sweep — N={} params, p={}, {} steps/mode, target {}, \
         {} Gbps, bucket {} B\n\n",
        opts.n_params,
        opts.workers,
        opts.steps,
        opts.target,
        opts.bandwidth_gbps,
        opts.bucket_bytes,
    ));
    out.push_str(
        "| topology | uplink | codec | gain static | gain adaptive | step static \
         | step adaptive | div static | div adaptive | knob moves | final knob |\n",
    );
    out.push_str("|---|---|---|---|---|---|---|---|---|---|---|\n");
    for r in rows {
        out.push_str(&format!(
            "| {} | {} | {} | {:.1}x | {:.1}x | {:.3} ms | {:.3} ms | {:.4} | {:.4} | {} | {} |\n",
            r.topology.label(),
            r.inter_rack_gbps
                .map(|g| format!("{g}"))
                .unwrap_or_else(|| "-".into()),
            r.codec,
            r.static_gain,
            r.adaptive_gain,
            r.static_step_ms,
            r.adaptive_step_ms,
            r.static_divergence,
            r.adaptive_divergence,
            r.knob_moves,
            r.final_knob
                .map(|v| format!("{v:.4}"))
                .unwrap_or_else(|| "-".into()),
        ));
    }
    out
}

/// Serialize sweep rows for EXPERIMENTS.md tooling.
pub fn adaptive_sweep_json(rows: &[AdaptiveSweepRow]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|r| {
                obj(vec![
                    ("topology", s(&r.topology.label())),
                    (
                        "inter_rack_gbps",
                        r.inter_rack_gbps.map(num).unwrap_or(Json::Null),
                    ),
                    ("codec", s(&r.codec)),
                    ("static_gain", num(r.static_gain)),
                    ("adaptive_gain", num(r.adaptive_gain)),
                    ("static_step_ms", num(r.static_step_ms)),
                    ("adaptive_step_ms", num(r.adaptive_step_ms)),
                    ("static_divergence", num(r.static_divergence)),
                    ("adaptive_divergence", num(r.adaptive_divergence)),
                    ("knob_moves", num(r.knob_moves as f64)),
                    (
                        "final_knob",
                        r.final_knob.map(|v| num(v as f64)).unwrap_or(Json::Null),
                    ),
                ])
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_opts() -> AdaptiveSweepOpts {
        AdaptiveSweepOpts {
            topologies: vec![TopologyKind::Hier { groups: 2 }],
            workers: 4,
            // A small alpha keeps the send rate (and thus the wire
            // gain) well under the controller's GAIN_CEILING so the
            // comm-bound cells are free to tighten.
            codecs: vec![CodecSpec::Vgc {
                alpha: 0.5,
                zeta: 0.95,
            }],
            n_params: 4096,
            steps: 6,
            ..AdaptiveSweepOpts::default()
        }
    }

    #[test]
    fn non_tunable_codec_is_bit_identical_across_modes() {
        let opts = AdaptiveSweepOpts {
            codecs: vec![
                CodecSpec::None,
                CodecSpec::Qsgd {
                    bits: 3,
                    bucket: 256,
                },
                CodecSpec::TernGrad,
            ],
            ..tiny_opts()
        };
        let rows = adaptive_sweep(&opts).unwrap();
        assert_eq!(rows.len(), 3);
        for r in &rows {
            assert_eq!(r.knob_moves, 0, "{}: no knob to move", r.codec);
            assert!(r.final_knob.is_none());
            assert_eq!(r.static_gain.to_bits(), r.adaptive_gain.to_bits(), "{}", r.codec);
            assert_eq!(
                r.static_divergence.to_bits(),
                r.adaptive_divergence.to_bits(),
                "{}",
                r.codec
            );
            assert_eq!(
                r.static_step_ms.to_bits(),
                r.adaptive_step_ms.to_bits(),
                "{}",
                r.codec
            );
        }
    }

    #[test]
    fn comm_bound_hier_cell_tightens_and_does_not_regress_step_time() {
        // Slow uplink + cheap compute makes comm the bottleneck: the
        // controller must tighten (knob moves > 0, gain up) and the
        // adaptive pass must match or beat static simulated step time.
        let opts = AdaptiveSweepOpts {
            inter_rack_gbps: vec![0.05],
            compute_ns_per_param: 5.0,
            encode_ns_per_param: 1.0,
            steps: 12,
            ..tiny_opts()
        };
        let rows = adaptive_sweep(&opts).unwrap();
        assert_eq!(rows.len(), 1);
        let r = &rows[0];
        assert!(r.knob_moves > 0, "controller never moved: {r:?}");
        assert!(
            r.adaptive_gain >= r.static_gain,
            "tightening must not lower gain: {r:?}"
        );
        assert!(
            r.adaptive_step_ms <= r.static_step_ms * 1.02 + 1e-6,
            "adaptive regressed step time: {r:?}"
        );
        let knob = r.final_knob.expect("vgc is tunable");
        assert!(knob >= 0.95 && knob <= 1.0, "zeta must stay in [initial, hi]: {knob}");
    }

    #[test]
    fn underloaded_cell_stays_at_static_behavior() {
        // Fast fabric, heavy compute: pressure stays under target, the
        // controller holds u = 0, and both passes agree bit-for-bit.
        let opts = AdaptiveSweepOpts {
            topologies: vec![TopologyKind::Ring],
            bandwidth_gbps: 100.0,
            compute_ns_per_param: 500.0,
            ..tiny_opts()
        };
        let rows = adaptive_sweep(&opts).unwrap();
        let r = &rows[0];
        assert_eq!(r.knob_moves, 0, "{r:?}");
        assert_eq!(r.static_gain.to_bits(), r.adaptive_gain.to_bits());
        assert_eq!(
            r.static_divergence.to_bits(),
            r.adaptive_divergence.to_bits()
        );
    }

    #[test]
    fn report_shapes_cover_all_rows() {
        let opts = AdaptiveSweepOpts {
            topologies: vec![TopologyKind::Ring, TopologyKind::Hier { groups: 2 }],
            inter_rack_gbps: vec![1.0, 0.1],
            ..tiny_opts()
        };
        let rows = adaptive_sweep(&opts).unwrap();
        // ring × 1 cell + hier × 2 uplink cells.
        assert_eq!(rows.len(), 3);
        let md = adaptive_sweep_markdown(&opts, &rows);
        assert!(md.contains("gain adaptive"), "{md}");
        assert!(md.contains("knob moves"), "{md}");
        assert_eq!(
            md.lines().filter(|l| l.starts_with("| ")).count(),
            1 + rows.len()
        );
        let j = adaptive_sweep_json(&rows);
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back.as_arr().unwrap().len(), rows.len());
    }

    #[test]
    fn validation_rejects_bad_axes() {
        let mut o = tiny_opts();
        o.steps = 0;
        assert!(validate_adaptive(&o).is_err());
        let mut o = tiny_opts();
        o.target = 0.0;
        assert!(validate_adaptive(&o).is_err());
        let mut o = tiny_opts();
        o.workers = 1;
        assert!(validate_adaptive(&o).is_err());
        let mut o = tiny_opts();
        o.inter_rack_gbps = vec![-1.0];
        assert!(validate_adaptive(&o).is_err());
    }
}
