//! `repro scale-sweep` — event-loop throughput at cluster scale.
//!
//! Runs a phantom-payload allgatherv (`fabric::fastpath::gather_sized`)
//! across {workers} × {topologies} and reports, per cell: simulated
//! step wall-clock, clock events processed, host events/second, host
//! wall-clock, peak live heap, and which engine ran (closed-form vs
//! full event loop). Phantom payloads keep the collective's protocol,
//! schedule, and counters bit-identical to a real-bytes run (see
//! `docs/SCALE.md`) while allocating no message bodies — which is what
//! makes 4096-node sweeps routine instead of a 17 GB allocation.
//!
//! Byte counters are hard-asserted against the analytic cost-model
//! formulas wherever one exists (ring, torus, torus3, hier,
//! dragonfly), so every sweep run doubles as a scale parity check.
//! `--assert-events-per-sec` / `--assert-wall-ms-max` turn a sweep
//! into a CI performance gate.

use std::time::Instant;

use anyhow::Result;

use crate::fabric::{build_topology, gather_sized, Fabric, FabricConfig, LinkSpec, TopologyKind};
use crate::util::alloc;
use crate::util::json::{num, obj, s, Json};

/// Sweep dimensions for the scale experiment.
#[derive(Debug, Clone)]
pub struct ScaleSweepOpts {
    pub topologies: Vec<TopologyKind>,
    pub workers: Vec<usize>,
    /// Per-worker message size, bytes (phantom — sized, never
    /// allocated).
    pub message_bytes: u64,
    pub bandwidth_gbps: f64,
    pub latency_us: f64,
    /// Uplink bandwidth for hier/dragonfly cells (Gbps); `None` keeps
    /// each topology's oversubscribed default.
    pub inter_rack_gbps: Option<f64>,
    pub seed: u64,
}

impl Default for ScaleSweepOpts {
    fn default() -> Self {
        ScaleSweepOpts {
            topologies: vec![
                TopologyKind::Ring,
                TopologyKind::Torus { rows: 0, cols: 0 },
                TopologyKind::Torus3 { x: 0, y: 0, z: 0 },
                TopologyKind::Hier { groups: 0 },
                TopologyKind::Dragonfly { groups: 0 },
            ],
            workers: vec![256, 1024, 4096],
            message_bytes: 16_384,
            bandwidth_gbps: 10.0,
            latency_us: 5.0,
            inter_rack_gbps: None,
            seed: 0,
        }
    }
}

/// Sanity-check a sweep before running it (mirrors `validate_sweep`).
pub fn validate_scale(opts: &ScaleSweepOpts) -> Result<()> {
    anyhow::ensure!(!opts.topologies.is_empty(), "sweep lists no topologies");
    anyhow::ensure!(!opts.workers.is_empty(), "sweep lists no worker counts");
    anyhow::ensure!(opts.message_bytes > 0, "message-bytes must be positive");
    anyhow::ensure!(opts.bandwidth_gbps > 0.0, "bandwidth-gbps must be positive");
    anyhow::ensure!(opts.latency_us >= 0.0, "latency-us must be non-negative");
    anyhow::ensure!(
        opts.inter_rack_gbps.map_or(true, |g| g > 0.0),
        "inter-rack-gbps must be positive"
    );
    for &kind in &opts.topologies {
        let probe = FabricConfig {
            topology: kind,
            inter_rack_gbps: match kind {
                TopologyKind::Hier { .. } | TopologyKind::Dragonfly { .. } => {
                    opts.inter_rack_gbps
                }
                _ => None,
            },
            ..FabricConfig::default()
        };
        for &p in &opts.workers {
            probe.validate(p)?;
        }
    }
    Ok(())
}

/// One sweep cell.
#[derive(Debug, Clone)]
pub struct ScaleSweepRow {
    pub topology: String,
    pub workers: usize,
    /// `"closed"` or `"event"` — which engine ran the gather.
    pub engine: String,
    /// Simulated allgatherv wall-clock, ms.
    pub sim_ms: f64,
    /// Clock events (closed cells: the events the loop would have
    /// processed, credited by `fast_forward`).
    pub events: u64,
    /// Host throughput: events / host wall-clock.
    pub events_per_sec: f64,
    /// Host wall-clock for the cell, ms.
    pub wall_ms: f64,
    /// Peak live heap during the cell, bytes (0 when the binary's
    /// counting allocator is not installed, e.g. under `cargo test`).
    pub peak_mem_bytes: u64,
}

/// Deterministic per-worker phantom sizes: `message_bytes` with a mild
/// ±12.5% spread so skewed-size code paths are exercised at scale.
pub fn scale_sizes(p: usize, message_bytes: u64, seed: u64) -> Vec<u64> {
    let spread = (message_bytes / 8).max(1);
    (0..p as u64)
        .map(|w| {
            let h = (w ^ seed).wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 33;
            message_bytes - spread / 2 + h % spread
        })
        .collect()
}

/// Run the full sweep.
pub fn scale_sweep(opts: &ScaleSweepOpts) -> Vec<ScaleSweepRow> {
    let mut rows = Vec::new();
    for &p in &opts.workers {
        let sizes = scale_sizes(p, opts.message_bytes, opts.seed);
        for &kind in &opts.topologies {
            let cfg = FabricConfig {
                topology: kind,
                link: LinkSpec {
                    bandwidth_gbps: opts.bandwidth_gbps,
                    latency_us: opts.latency_us,
                    jitter_us: 0.0,
                },
                inter_rack_gbps: match kind {
                    TopologyKind::Hier { .. } | TopologyKind::Dragonfly { .. } => {
                        opts.inter_rack_gbps
                    }
                    _ => None,
                },
                seed: opts.seed,
                ..FabricConfig::default()
            };
            let topo = build_topology(kind, p);
            let resolved = topo.kind();
            let mut fabric = Fabric::for_topology(&cfg, &*topo);
            fabric.set_trace(false);

            alloc::reset_peak();
            let start = Instant::now();
            let (gather, engine) = gather_sized(&*topo, &mut fabric, &sizes);
            let wall = start.elapsed().as_secs_f64();
            let peak_mem_bytes = alloc::peak_bytes();

            // Every cell cross-checks its byte counters against the
            // analytic model — a mismatch is a fabric bug.
            if let Some(expect) = super::analytic_gatherv_bytes(resolved, &sizes) {
                assert_eq!(
                    gather.traffic.bytes_sent_per_node,
                    expect,
                    "{} byte accounting diverged from the analytic model (p={p})",
                    resolved.label()
                );
            }

            rows.push(ScaleSweepRow {
                topology: resolved.label(),
                workers: p,
                engine: engine.label().to_string(),
                sim_ms: gather.time_secs() * 1e3,
                events: gather.events,
                events_per_sec: gather.events as f64 / wall.max(1e-9),
                wall_ms: wall * 1e3,
                peak_mem_bytes,
            });
        }
    }
    rows
}

/// Enforce the CI performance gate over a finished sweep: every
/// event-engine cell must clear the events/sec floor, and every cell
/// must finish under the wall-clock ceiling. Closed-form cells process
/// their events without the loop, so the throughput floor does not
/// apply to them (they'd trivially pass anyway).
pub fn enforce_scale(
    rows: &[ScaleSweepRow],
    min_events_per_sec: Option<f64>,
    max_wall_ms: Option<f64>,
) -> Result<()> {
    for r in rows {
        if let Some(floor) = min_events_per_sec {
            anyhow::ensure!(
                r.engine != "event" || r.events_per_sec >= floor,
                "{} p={}: {:.0} events/sec below the {floor:.0} floor",
                r.topology,
                r.workers,
                r.events_per_sec
            );
        }
        if let Some(ceiling) = max_wall_ms {
            anyhow::ensure!(
                r.wall_ms <= ceiling,
                "{} p={}: {:.1} ms wall-clock over the {ceiling:.1} ms ceiling",
                r.topology,
                r.workers,
                r.wall_ms
            );
        }
    }
    Ok(())
}

/// Markdown table of the sweep (the `repro scale-sweep` report).
pub fn scale_sweep_markdown(opts: &ScaleSweepOpts, rows: &[ScaleSweepRow]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "### scale sweep — {} B/worker (±12.5%), {} Gbps, latency {} us{}\n\n",
        opts.message_bytes,
        opts.bandwidth_gbps,
        opts.latency_us,
        opts.inter_rack_gbps
            .map(|g| format!(", uplink {g} Gbps"))
            .unwrap_or_default()
    ));
    out.push_str("| topology | p | engine | sim step | events | events/sec | wall | peak mem |\n");
    out.push_str("|---|---|---|---|---|---|---|---|\n");
    for r in rows {
        out.push_str(&format!(
            "| {} | {} | {} | {:.3} ms | {} | {} | {:.1} ms | {} |\n",
            r.topology,
            r.workers,
            r.engine,
            r.sim_ms,
            r.events,
            if r.engine == "closed" {
                "-".to_string()
            } else {
                format!("{:.0}", r.events_per_sec)
            },
            r.wall_ms,
            if r.peak_mem_bytes > 0 {
                super::human_bytes(r.peak_mem_bytes as f64)
            } else {
                "n/a".to_string()
            },
        ));
    }
    out
}

/// Serialize the sweep for `BENCH_scale.json`.
pub fn scale_sweep_json(opts: &ScaleSweepOpts, rows: &[ScaleSweepRow]) -> Json {
    obj(vec![
        ("bench", s("scale")),
        ("message_bytes", num(opts.message_bytes as f64)),
        ("bandwidth_gbps", num(opts.bandwidth_gbps)),
        ("latency_us", num(opts.latency_us)),
        (
            "inter_rack_gbps",
            opts.inter_rack_gbps.map(num).unwrap_or(Json::Null),
        ),
        ("seed", num(opts.seed as f64)),
        (
            "rows",
            Json::Arr(
                rows.iter()
                    .map(|r| {
                        obj(vec![
                            ("topology", s(&r.topology)),
                            ("workers", num(r.workers as f64)),
                            ("engine", s(&r.engine)),
                            ("sim_ms", num(r.sim_ms)),
                            ("events", num(r.events as f64)),
                            ("events_per_sec", num(r.events_per_sec)),
                            ("wall_ms", num(r.wall_ms)),
                            ("peak_mem_bytes", num(r.peak_mem_bytes as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_opts() -> ScaleSweepOpts {
        ScaleSweepOpts {
            workers: vec![8, 12],
            message_bytes: 256,
            ..ScaleSweepOpts::default()
        }
    }

    #[test]
    fn sweep_covers_the_grid_and_picks_engines() {
        let opts = tiny_opts();
        validate_scale(&opts).unwrap();
        let rows = scale_sweep(&opts);
        // 5 topologies × 2 worker counts.
        assert_eq!(rows.len(), 10);
        for r in &rows {
            assert!(r.sim_ms > 0.0, "{r:?}");
            assert!(r.events > 0, "{r:?}");
            assert!(r.wall_ms >= 0.0);
            // The uniform sweep fabric runs ring cells closed-form and
            // everything else through the event loop.
            let want = if r.topology == "ring" { "closed" } else { "event" };
            assert_eq!(r.engine, want, "{r:?}");
        }
        // Every topology moves (p−1)·Σ sizes bytes in total; with the
        // same sizes the per-cell event counts all equal p(p−1).
        for &p in &opts.workers {
            let cells: Vec<&ScaleSweepRow> =
                rows.iter().filter(|r| r.workers == p).collect();
            assert!(cells
                .iter()
                .all(|r| r.events == (p * (p - 1)) as u64), "{cells:?}");
        }
    }

    #[test]
    fn phantom_sizes_are_deterministic_and_near_nominal() {
        let a = scale_sizes(64, 1024, 7);
        assert_eq!(a, scale_sizes(64, 1024, 7));
        assert_ne!(a, scale_sizes(64, 1024, 8));
        assert!(a.iter().all(|&n| n >= 960 && n < 1088), "{a:?}");
    }

    #[test]
    fn gate_flags_slow_cells_but_skips_closed_throughput() {
        let rows = vec![
            ScaleSweepRow {
                topology: "ring".into(),
                workers: 8,
                engine: "closed".into(),
                sim_ms: 1.0,
                events: 56,
                events_per_sec: 10.0, // irrelevant: closed-form
                wall_ms: 5.0,
                peak_mem_bytes: 0,
            },
            ScaleSweepRow {
                topology: "hier:3".into(),
                workers: 8,
                engine: "event".into(),
                sim_ms: 1.0,
                events: 56,
                events_per_sec: 100.0,
                wall_ms: 5.0,
                peak_mem_bytes: 0,
            },
        ];
        enforce_scale(&rows, Some(50.0), Some(10.0)).unwrap();
        let err = enforce_scale(&rows, Some(1000.0), None).unwrap_err();
        assert!(err.to_string().contains("below"), "{err}");
        let err = enforce_scale(&rows, None, Some(1.0)).unwrap_err();
        assert!(err.to_string().contains("ceiling"), "{err}");
    }

    #[test]
    fn report_shapes_round_trip() {
        let opts = ScaleSweepOpts {
            topologies: vec![TopologyKind::Ring],
            workers: vec![4],
            message_bytes: 64,
            ..ScaleSweepOpts::default()
        };
        let rows = scale_sweep(&opts);
        let md = scale_sweep_markdown(&opts, &rows);
        assert!(md.contains("| topology |"), "{md}");
        assert_eq!(md.lines().filter(|l| l.starts_with("| ")).count(), 1 + rows.len());
        let j = scale_sweep_json(&opts, &rows);
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back.get("bench").unwrap().as_str().unwrap(), "scale");
        assert_eq!(back.get("rows").unwrap().as_arr().unwrap().len(), 1);
        assert!(!j.to_string().contains("placeholder"));
    }

    #[test]
    fn validation_rejects_bad_axes() {
        let err = validate_scale(&ScaleSweepOpts {
            workers: vec![],
            ..ScaleSweepOpts::default()
        })
        .unwrap_err();
        assert!(err.to_string().contains("worker"), "{err}");
        let err = validate_scale(&ScaleSweepOpts {
            topologies: vec![TopologyKind::Torus3 { x: 2, y: 2, z: 2 }],
            workers: vec![9],
            ..ScaleSweepOpts::default()
        })
        .unwrap_err();
        assert!(err.to_string().contains("torus3"), "{err}");
    }
}
