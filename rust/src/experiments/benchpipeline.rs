//! `repro bench-pipeline` — phased vs overlapped step-time benchmark.
//!
//! Drives the bucketed overlap pipeline (`comm::pipeline`) over a
//! small topology × codec grid and reports, per cell, the phased step
//! span (compute + encode + comm serialized), the overlapped span the
//! schedule achieves, the ideal `max(compute, comm)` floor, and the
//! resulting overlap efficiency. A thin reshaping of
//! [`fabric_sweep`](super::fabric_sweep) with `overlap` forced on, so
//! the numbers are exactly the sweep's `--overlap` columns.
//!
//! Emits a markdown table and, with `--json`, a `BENCH_pipeline.json`
//! record so the pipeline's win is tracked across PRs.

use crate::compress::CodecSpec;
use crate::config::codec_str;
use crate::fabric::TopologyKind;
use crate::util::json::{num, obj, s, Json};

use super::{fabric_sweep, validate_sweep, FabricSweepOpts};

#[derive(Debug, Clone)]
pub struct BenchPipelineOpts {
    pub topologies: Vec<TopologyKind>,
    pub workers: usize,
    pub bandwidth_gbps: f64,
    pub codecs: Vec<CodecSpec>,
    /// Synthetic gradient dimension.
    pub n_params: usize,
    /// Tensor-fusion threshold, bytes.
    pub bucket_bytes: usize,
    /// Pinned gather segment size, bytes (0 = BDP-derived).
    pub segment_bytes: usize,
    /// Synthetic backprop cost, ns/param.
    pub compute_ns_per_param: f64,
    /// Synthetic serial-encode cost, ns/param.
    pub encode_ns_per_param: f64,
    pub seed: u64,
}

impl Default for BenchPipelineOpts {
    fn default() -> Self {
        BenchPipelineOpts {
            topologies: vec![
                TopologyKind::Ring,
                TopologyKind::Torus { rows: 0, cols: 0 },
                TopologyKind::Hier { groups: 2 },
            ],
            workers: 8,
            bandwidth_gbps: 1.0,
            codecs: vec![
                CodecSpec::None,
                CodecSpec::Vgc {
                    alpha: 2.0,
                    zeta: 0.999,
                },
                CodecSpec::Strom { tau: 0.01 },
            ],
            n_params: 65_536,
            bucket_bytes: 65_536,
            segment_bytes: 0,
            compute_ns_per_param: 50.0,
            encode_ns_per_param: 10.0,
            seed: 0,
        }
    }
}

impl BenchPipelineOpts {
    /// The equivalent fabric sweep: one worker count, one bandwidth,
    /// overlap on. Keeping this mapping total means every bench cell
    /// is reproducible as a `fabric-sweep --overlap` row.
    pub fn to_sweep(&self) -> FabricSweepOpts {
        FabricSweepOpts {
            topologies: self.topologies.clone(),
            workers: vec![self.workers],
            bandwidths_gbps: vec![self.bandwidth_gbps],
            codecs: self.codecs.clone(),
            n_params: self.n_params,
            segment_bytes: self.segment_bytes,
            seed: self.seed,
            overlap: true,
            bucket_bytes: self.bucket_bytes,
            compute_ns_per_param: self.compute_ns_per_param,
            encode_ns_per_param: self.encode_ns_per_param,
            ..FabricSweepOpts::default()
        }
    }
}

#[derive(Debug, Clone)]
pub struct BenchPipelineRow {
    pub topology: String,
    pub codec: String,
    /// Compute + encode + comm fully serialized, ms.
    pub phased_ms: f64,
    /// The overlapped schedule's achieved step span, ms.
    pub overlap_ms: f64,
    /// The pipelining floor `max(compute, comm)`, ms.
    pub ideal_ms: f64,
    /// `ideal_ms / overlap_ms` — 1.0 is perfect hiding.
    pub overlap_eff: f64,
    /// `phased_ms / overlap_ms` — the end-to-end win of overlapping.
    pub speedup: f64,
    /// Bucket count after BDP coalescing.
    pub buckets: usize,
    /// The dense f32 allreduce baseline under the same schedule, ms.
    pub dense_overlap_ms: f64,
}

/// Run the benchmark grid (topologies × codecs).
pub fn bench_pipeline(opts: &BenchPipelineOpts) -> anyhow::Result<Vec<BenchPipelineRow>> {
    let sweep = opts.to_sweep();
    validate_sweep(&sweep)?;
    let rows = fabric_sweep(&sweep);
    Ok(rows
        .iter()
        .map(|r| {
            let phased = r.phased_ms.expect("overlap sweep rows carry phased_ms");
            let over = r.overlap_ms.expect("overlap sweep rows carry overlap_ms");
            let eff = r.overlap_eff.expect("overlap sweep rows carry overlap_eff");
            BenchPipelineRow {
                topology: r.topology.clone(),
                codec: r.codec.clone(),
                phased_ms: phased,
                overlap_ms: over,
                ideal_ms: eff * over,
                overlap_eff: eff,
                speedup: if over > 0.0 { phased / over } else { 1.0 },
                buckets: r.buckets.expect("overlap sweep rows carry buckets"),
                dense_overlap_ms: r
                    .dense_overlap_ms
                    .expect("overlap sweep rows carry dense_overlap_ms"),
            }
        })
        .collect())
}

pub fn bench_pipeline_markdown(opts: &BenchPipelineOpts, rows: &[BenchPipelineRow]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "# pipeline bench — N={} p={} {} Gbps, bucket {} B, compute {} ns/param, encode {} ns/param\n\n",
        opts.n_params,
        opts.workers,
        opts.bandwidth_gbps,
        opts.bucket_bytes,
        opts.compute_ns_per_param,
        opts.encode_ns_per_param,
    ));
    out.push_str(
        "| topology | codec | phased | overlapped | ideal | overlap eff | speedup \
         | buckets | dense overlap |\n",
    );
    out.push_str("|---|---|---|---|---|---|---|---|---|\n");
    for r in rows {
        out.push_str(&format!(
            "| {} | {} | {:.3} ms | {:.3} ms | {:.3} ms | {:.3} | {:.2}x | {} | {:.3} ms |\n",
            r.topology,
            r.codec,
            r.phased_ms,
            r.overlap_ms,
            r.ideal_ms,
            r.overlap_eff,
            r.speedup,
            r.buckets,
            r.dense_overlap_ms,
        ));
    }
    out
}

pub fn bench_pipeline_json(opts: &BenchPipelineOpts, rows: &[BenchPipelineRow]) -> Json {
    let worst_eff = rows
        .iter()
        .map(|r| r.overlap_eff)
        .fold(f64::INFINITY, f64::min);
    obj(vec![
        ("bench", s("pipeline")),
        ("n_params", num(opts.n_params as f64)),
        ("workers", num(opts.workers as f64)),
        ("bandwidth_gbps", num(opts.bandwidth_gbps)),
        ("bucket_bytes", num(opts.bucket_bytes as f64)),
        ("compute_ns_per_param", num(opts.compute_ns_per_param)),
        ("encode_ns_per_param", num(opts.encode_ns_per_param)),
        (
            "worst_overlap_eff",
            if worst_eff.is_finite() {
                num(worst_eff)
            } else {
                Json::Null
            },
        ),
        (
            "rows",
            Json::Arr(
                rows.iter()
                    .map(|r| {
                        obj(vec![
                            ("topology", s(&r.topology)),
                            ("codec", s(&r.codec)),
                            ("phased_ms", num(r.phased_ms)),
                            ("overlap_ms", num(r.overlap_ms)),
                            ("ideal_ms", num(r.ideal_ms)),
                            ("overlap_eff", num(r.overlap_eff)),
                            ("speedup", num(r.speedup)),
                            ("buckets", num(r.buckets as f64)),
                            ("dense_overlap_ms", num(r.dense_overlap_ms)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_rows_reshape_the_overlap_sweep() {
        let opts = BenchPipelineOpts {
            topologies: vec![TopologyKind::Ring, TopologyKind::Star],
            workers: 4,
            codecs: vec![
                CodecSpec::None,
                CodecSpec::Vgc {
                    alpha: 2.0,
                    zeta: 0.999,
                },
            ],
            n_params: 4096,
            bucket_bytes: 4096,
            ..BenchPipelineOpts::default()
        };
        let rows = bench_pipeline(&opts).unwrap();
        assert_eq!(rows.len(), 4);
        for r in &rows {
            assert!(
                r.overlap_ms <= r.phased_ms + 1e-9,
                "{} {}: overlapped {} > phased {}",
                r.topology,
                r.codec,
                r.overlap_ms,
                r.phased_ms
            );
            assert!(r.ideal_ms <= r.overlap_ms + 1e-9);
            assert!(r.speedup >= 1.0 - 1e-9);
            assert!(r.buckets >= 1);
            let label = codec_str(
                opts.codecs
                    .iter()
                    .find(|c| codec_str(c) == r.codec)
                    .expect("row codec comes from the opts grid"),
            );
            assert_eq!(label, r.codec);
        }
        let md = bench_pipeline_markdown(&opts, &rows);
        assert!(md.contains("overlap eff"), "{md}");
        assert_eq!(
            md.lines().filter(|l| l.starts_with("| ")).count(),
            1 + rows.len()
        );
        let j = bench_pipeline_json(&opts, &rows).to_string();
        let back = Json::parse(&j).unwrap();
        assert_eq!(back.get("rows").unwrap().as_arr().unwrap().len(), 4);
        assert!(back.get("worst_overlap_eff").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn invalid_grids_are_rejected() {
        let opts = BenchPipelineOpts {
            codecs: Vec::new(),
            ..BenchPipelineOpts::default()
        };
        assert!(bench_pipeline(&opts).is_err());
    }
}
