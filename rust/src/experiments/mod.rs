//! Experiment harnesses: one function per paper table/figure.
//!
//! * [`table1_rows`] / [`table2_rows`] — the codec × optimizer grids of
//!   Tables 1 and 2 (scaled workloads, DESIGN.md §Substitutions).
//! * [`run_grid`] — executes a grid and collects `RowResult`s.
//! * [`print_table`] — paper-shaped console table.
//! * [`fig3_csv`] — the Figure-3 scatter data (accuracy vs ratio).
//! * [`costmodel_report`] — the Section-5 speedup analysis (A5).
//! * [`fabric_sweep`] — simulated {topology × bandwidth × uplink-skew
//!   × workers × codec} step times over the event-driven fabric (F1),
//!   optionally with segmented gather pipelining.
//! * [`benchcodecs`] — §Perf codec-engine throughput sweep
//!   (`repro bench-codecs`, serial vs parallel, `BENCH_codecs.json`).
//! * [`benchpipeline`] — phased vs overlapped step-time bench over the
//!   bucketed pipeline (`repro bench-pipeline`, `BENCH_pipeline.json`).
//! * [`chaos`] — fault-injection sweep over the chaos fabric
//!   (`repro chaos-sweep`, masking/divergence/inflation per scenario).
//! * [`adaptive`] — static-vs-adaptive compression comparison over the
//!   closed-loop knob controller (`repro adaptive-sweep`).
//! * [`scale`] — 256→4096-node event-loop throughput bench over
//!   phantom gathers (`repro scale-sweep`, `BENCH_scale.json`).

pub mod adaptive;
pub mod benchcodecs;
pub mod benchpipeline;
pub mod chaos;
pub mod scale;

pub use adaptive::{
    adaptive_sweep, adaptive_sweep_json, adaptive_sweep_markdown, validate_adaptive,
    AdaptiveSweepOpts, AdaptiveSweepRow,
};
pub use benchcodecs::{
    bench_codecs, bench_codecs_json, bench_codecs_markdown, BenchCodecsOpts, BenchCodecsRow,
};
pub use benchpipeline::{
    bench_pipeline, bench_pipeline_json, bench_pipeline_markdown, BenchPipelineOpts,
    BenchPipelineRow,
};
pub use chaos::{
    chaos_sweep, chaos_sweep_json, chaos_sweep_markdown, validate_chaos, ChaosSweepOpts,
    ChaosSweepRow,
};
pub use scale::{
    enforce_scale, scale_sweep, scale_sweep_json, scale_sweep_markdown, validate_scale,
    ScaleSweepOpts, ScaleSweepRow,
};

use anyhow::Result;

use crate::comm::allgatherv::allgatherv_overlapped;
use crate::comm::allreduce::allreduce_overlapped;
use crate::comm::costmodel::{
    dragonfly_gatherv_bytes_per_node, hier_gatherv_bytes_per_node, ring_gatherv_bytes_per_node,
    speedup_series, torus3_gatherv_bytes_per_node, torus_gatherv_bytes_per_node, CostModel,
    LinkModel,
};
use crate::comm::pipeline;
use crate::compress::CodecSpec;
use crate::config::{codec_str, TrainConfig};
use crate::coordinator::Trainer;
use crate::fabric::{build_topology, Fabric, FabricConfig, LinkSpec, Straggler, TopologyKind};
use crate::model::Layout;
use crate::runtime::{Client, Manifest};
use crate::testkit;
use crate::util::json::{num, obj, s, Json};
use crate::util::rng::Pcg32;

/// The paper's Table-1/2 codec column.
pub fn paper_codecs() -> Vec<(String, CodecSpec)> {
    let mut rows: Vec<(String, CodecSpec)> = vec![("none".into(), CodecSpec::None)];
    for tau in [0.001f32, 0.01, 0.1] {
        rows.push((format!("strom tau={tau}"), CodecSpec::Strom { tau }));
    }
    for alpha in [1.0f32, 1.5, 2.0] {
        rows.push((
            format!("vgc alpha={alpha}"),
            CodecSpec::Vgc { alpha, zeta: 0.999 },
        ));
    }
    for tau in [0.01f32, 0.1] {
        rows.push((
            format!("hybrid tau={tau} alpha=2"),
            CodecSpec::Hybrid {
                tau,
                alpha: 2.0,
                zeta: 0.999,
            },
        ));
    }
    for (bits, d) in [(2u32, 128usize), (3, 512), (4, 512)] {
        rows.push((
            format!("qsgd {bits}bit d={d}"),
            CodecSpec::Qsgd { bits, bucket: d },
        ));
    }
    rows
}

/// One grid cell: a labeled config.
#[derive(Debug, Clone)]
pub struct GridRow {
    pub label: String,
    pub cfg: TrainConfig,
}

/// Build the Table-1 grid (vgg_tiny, 8 workers) for one optimizer.
pub fn table1_rows(optimizer: &str, steps: u64) -> Vec<GridRow> {
    grid_rows("vgg_tiny", optimizer, steps)
}

/// Build the Table-2 grid (resnet_mini, 16 workers) for one optimizer.
pub fn table2_rows(optimizer: &str, steps: u64) -> Vec<GridRow> {
    grid_rows("resnet_mini", optimizer, steps)
}

fn grid_rows(model: &str, optimizer: &str, steps: u64) -> Vec<GridRow> {
    paper_codecs()
        .into_iter()
        .map(|(label, codec)| {
            let mut cfg = TrainConfig::defaults(model);
            cfg.codec = codec;
            cfg.optimizer = optimizer.to_string();
            if optimizer == "adam" {
                cfg.schedule = crate::optim::LrSchedule::Constant { lr: 0.002 };
            }
            cfg.steps = steps;
            GridRow {
                label,
                cfg,
            }
        })
        .collect()
}

/// One completed run's summary.
#[derive(Debug, Clone)]
pub struct RowResult {
    pub label: String,
    pub optimizer: String,
    pub accuracy: f32,
    pub eval_loss: f32,
    pub compression: f64,
    pub bits_ratio: f64,
    pub final_loss: f32,
}

/// Execute every row of a grid sequentially (each run is internally
/// parallel through XLA).
pub fn run_grid(
    client: &Client,
    manifest: &Manifest,
    rows: &[GridRow],
    quiet: bool,
) -> Result<Vec<RowResult>> {
    let mut out = Vec::with_capacity(rows.len());
    for (i, row) in rows.iter().enumerate() {
        if !quiet {
            eprintln!(
                "[{}/{}] {} / {} ...",
                i + 1,
                rows.len(),
                row.label,
                row.cfg.optimizer
            );
        }
        let mut trainer = Trainer::new(client, manifest, row.cfg.clone())?;
        trainer.run(true)?;
        out.push(RowResult {
            label: row.label.clone(),
            optimizer: row.cfg.optimizer.clone(),
            accuracy: trainer.metrics.final_accuracy(),
            eval_loss: trainer
                .metrics
                .evals
                .last()
                .map(|e| e.eval_loss)
                .unwrap_or(f32::NAN),
            compression: trainer.metrics.compression_ratio(),
            bits_ratio: trainer.metrics.bits_ratio(),
            final_loss: trainer.metrics.final_loss(),
        });
    }
    Ok(out)
}

/// Print results in the paper's table shape (one optimizer per block).
pub fn print_table(title: &str, results: &[RowResult]) {
    println!("\n=== {title} ===");
    println!(
        "{:<26} {:>10} {:>9} {:>14} {:>12}",
        "Method", "Accuracy", "Loss", "Compression", "BitsRatio"
    );
    for r in results {
        let acc = if r.accuracy.is_nan() {
            "-".to_string()
        } else {
            format!("{:.1}%", r.accuracy * 100.0)
        };
        let comp = if r.compression.is_infinite() {
            "inf".to_string()
        } else {
            crate::util::with_commas(r.compression.round() as u64)
        };
        println!(
            "{:<26} {:>10} {:>9.3} {:>14} {:>12.1}",
            r.label, acc, r.final_loss, comp, r.bits_ratio
        );
    }
}

/// Figure-3 scatter CSV: `method,optimizer,accuracy,compression`.
pub fn fig3_csv(results: &[RowResult]) -> String {
    let mut out = String::from("method,optimizer,accuracy,compression,bits_ratio\n");
    for r in results {
        out.push_str(&format!(
            "{},{},{},{},{}\n",
            r.label, r.optimizer, r.accuracy, r.compression, r.bits_ratio
        ));
    }
    out
}

/// Serialize results for EXPERIMENTS.md tooling.
pub fn results_json(table: &str, results: &[RowResult]) -> Json {
    Json::Arr(
        results
            .iter()
            .map(|r| {
                obj(vec![
                    ("table", s(table)),
                    ("method", s(&r.label)),
                    ("optimizer", s(&r.optimizer)),
                    ("accuracy", num(r.accuracy as f64)),
                    ("final_loss", num(r.final_loss as f64)),
                    ("compression", num(r.compression)),
                    ("bits_ratio", num(r.bits_ratio)),
                ])
            })
            .collect(),
    )
}

/// The Section-5 (A5) analysis: speedup table over c and p for
/// ResNet-50-scale N on 1GbE, plus the linear-regime boundary.
pub fn costmodel_report() -> String {
    let n = 25_500_000u64;
    let ps = [4usize, 8, 16, 64];
    let cs = [1.0, 4.0, 16.0, 64.0, 256.0, 1024.0, 4096.0, 16384.0];
    let rows = speedup_series(n, &ps, &cs, LinkModel::gige());
    let mut out = String::new();
    out.push_str("Section-5 cost model: ring allreduce vs pipelined ring allgatherv\n");
    out.push_str(&format!("N = {n} params (ResNet-50 scale), 1GbE (beta = 1 ns/bit)\n\n"));
    out.push_str(&format!(
        "{:>4} {:>9} {:>14} {:>14} {:>10} {:>10}\n",
        "p", "c", "T_r (ms)", "T_v (ms)", "speedup", "bound"
    ));
    for r in &rows {
        out.push_str(&format!(
            "{:>4} {:>9} {:>14.3} {:>14.3} {:>10.2} {:>10.2}\n",
            r.p,
            r.c,
            r.t_allreduce * 1e3,
            r.t_allgatherv * 1e3,
            r.speedup,
            r.bound
        ));
    }
    out.push_str("\nlinear-speedup regime boundary (paper: c > p/2):\n");
    for p in ps {
        let c_star = (p * p) as f64 / (2.0 * (p as f64 - 1.0));
        out.push_str(&format!("  p={p:>3}: bound crosses 1 at c = {c_star:.2}\n"));
    }
    out
}

// ---- F1: fabric sweep ----

/// Sweep dimensions for the simulated-cluster experiment.
#[derive(Debug, Clone)]
pub struct FabricSweepOpts {
    pub topologies: Vec<TopologyKind>,
    pub workers: Vec<usize>,
    pub bandwidths_gbps: Vec<f64>,
    /// Bandwidth-skew axis: hierarchy cells are repeated per uplink
    /// bandwidth (Gbps). Empty = the hierarchy's 10:1 default.
    pub inter_rack_gbps: Vec<f64>,
    /// Gather pipeline segment size, bytes (0 = whole messages).
    pub segment_bytes: usize,
    pub codecs: Vec<CodecSpec>,
    /// Synthetic gradient dimension (paper-scale runs use 25.5M; the
    /// default keeps the sweep interactive).
    pub n_params: usize,
    pub latency_us: f64,
    pub jitter_us: f64,
    pub stragglers: Vec<Straggler>,
    pub seed: u64,
    /// Codec warmup steps before the measured message (residual state
    /// makes step-0 messages unrepresentative).
    pub warmup_steps: u32,
    /// Run every cell through the bucketed overlap pipeline as well
    /// (`repro fabric-sweep --overlap`): adds phased-vs-overlapped
    /// step spans, overlap efficiency, and bucket counts per row, and
    /// gives the dense allreduce baseline the same treatment.
    pub overlap: bool,
    /// Tensor-fusion threshold for overlap cells, bytes (`--bucket-bytes`).
    pub bucket_bytes: usize,
    /// Synthetic backprop cost feeding bucket-ready times, ns/param
    /// (`--compute-ns`); the overlap columns measure how much of the
    /// wire hides behind this compute span.
    pub compute_ns_per_param: f64,
    /// Synthetic serial-encoder cost, ns/param (`--encode-ns`).
    pub encode_ns_per_param: f64,
}

impl Default for FabricSweepOpts {
    fn default() -> Self {
        FabricSweepOpts {
            topologies: vec![
                TopologyKind::Ring,
                TopologyKind::Star,
                TopologyKind::Full,
                TopologyKind::Tree { branch: 4 },
                TopologyKind::Torus { rows: 0, cols: 0 },
                TopologyKind::Hier { groups: 0 },
            ],
            workers: vec![8, 16],
            bandwidths_gbps: vec![1.0, 10.0],
            inter_rack_gbps: Vec::new(),
            segment_bytes: 0,
            codecs: vec![
                CodecSpec::None,
                CodecSpec::Vgc {
                    alpha: 2.0,
                    zeta: 0.999,
                },
                CodecSpec::Strom { tau: 0.01 },
            ],
            n_params: 65_536,
            latency_us: 50.0,
            jitter_us: 0.0,
            stragglers: Vec::new(),
            seed: 0,
            warmup_steps: 2,
            overlap: false,
            bucket_bytes: 65_536,
            compute_ns_per_param: 50.0,
            encode_ns_per_param: 10.0,
        }
    }
}

impl FabricSweepOpts {
    /// Serialize for job specs and experiment records.
    pub fn to_json(&self) -> Json {
        let labels: Vec<String> = self.topologies.iter().map(|t| t.label()).collect();
        obj(vec![
            ("topologies", s(&labels.join(","))),
            (
                "workers",
                Json::Arr(self.workers.iter().map(|&w| num(w as f64)).collect()),
            ),
            (
                "bandwidths_gbps",
                Json::Arr(self.bandwidths_gbps.iter().map(|&b| num(b)).collect()),
            ),
            (
                "inter_rack_gbps",
                Json::Arr(self.inter_rack_gbps.iter().map(|&b| num(b)).collect()),
            ),
            ("segment_bytes", num(self.segment_bytes as f64)),
            (
                "codecs",
                Json::Arr(self.codecs.iter().map(|c| s(&codec_str(c))).collect()),
            ),
            ("n_params", num(self.n_params as f64)),
            ("latency_us", num(self.latency_us)),
            ("jitter_us", num(self.jitter_us)),
            ("stragglers", s(&Straggler::list_str(&self.stragglers))),
            ("seed", num(self.seed as f64)),
            ("warmup_steps", num(self.warmup_steps as f64)),
            ("overlap", Json::Bool(self.overlap)),
            ("bucket_bytes", num(self.bucket_bytes as f64)),
            ("compute_ns_per_param", num(self.compute_ns_per_param)),
            ("encode_ns_per_param", num(self.encode_ns_per_param)),
        ])
    }

    /// Load from JSON written by [`FabricSweepOpts::to_json`] (or
    /// hand-written job specs); absent keys keep the CLI defaults.
    pub fn from_json(j: &Json) -> Result<FabricSweepOpts> {
        let mut o = FabricSweepOpts::default();
        if let Some(t) = j.get("topologies") {
            o.topologies = t
                .as_str()?
                .split(',')
                .filter(|x| !x.trim().is_empty())
                .map(|x| TopologyKind::parse(x.trim()))
                .collect::<Result<Vec<_>>>()?;
        }
        if let Some(w) = j.get("workers") {
            o.workers = w
                .as_arr()?
                .iter()
                .map(|x| x.as_usize())
                .collect::<Result<Vec<_>>>()?;
        }
        if let Some(b) = j.get("bandwidths_gbps") {
            o.bandwidths_gbps = b
                .as_arr()?
                .iter()
                .map(|x| x.as_f64())
                .collect::<Result<Vec<_>>>()?;
        }
        if let Some(b) = j.get("inter_rack_gbps") {
            o.inter_rack_gbps = b
                .as_arr()?
                .iter()
                .map(|x| x.as_f64())
                .collect::<Result<Vec<_>>>()?;
        }
        if let Some(v) = j.get("segment_bytes") {
            o.segment_bytes = v.as_usize()?;
        }
        if let Some(c) = j.get("codecs") {
            o.codecs = c
                .as_arr()?
                .iter()
                .map(|x| CodecSpec::parse(x.as_str()?))
                .collect::<Result<Vec<_>>>()?;
        }
        if let Some(v) = j.get("n_params") {
            o.n_params = v.as_usize()?;
        }
        if let Some(v) = j.get("latency_us") {
            o.latency_us = v.as_f64()?;
        }
        if let Some(v) = j.get("jitter_us") {
            o.jitter_us = v.as_f64()?;
        }
        if let Some(v) = j.get("stragglers") {
            o.stragglers = Straggler::parse_list(v.as_str()?)?;
        }
        if let Some(v) = j.get("seed") {
            o.seed = v.as_usize()? as u64;
        }
        if let Some(v) = j.get("warmup_steps") {
            o.warmup_steps = v.as_usize()? as u32;
        }
        if let Some(Json::Bool(b)) = j.get("overlap") {
            o.overlap = *b;
        }
        if let Some(v) = j.get("bucket_bytes") {
            o.bucket_bytes = v.as_usize()?;
        }
        if let Some(v) = j.get("compute_ns_per_param") {
            o.compute_ns_per_param = v.as_f64()?;
        }
        if let Some(v) = j.get("encode_ns_per_param") {
            o.encode_ns_per_param = v.as_f64()?;
        }
        Ok(o)
    }
}

/// Sanity-check a sweep before running it — shared by the CLI and the
/// service daemon's job executor so HTTP submissions get the same
/// errors as flags. Catches empty axes, non-positive bandwidths,
/// fabric configs that cannot host a swept worker count, and straggler
/// nodes outside the smallest swept fabric.
pub fn validate_sweep(opts: &FabricSweepOpts) -> Result<()> {
    anyhow::ensure!(!opts.topologies.is_empty(), "sweep lists no topologies");
    anyhow::ensure!(!opts.workers.is_empty(), "sweep lists no worker counts");
    anyhow::ensure!(!opts.codecs.is_empty(), "sweep lists no codecs");
    anyhow::ensure!(opts.n_params > 0, "n_params must be positive");
    anyhow::ensure!(
        opts.bandwidths_gbps.iter().all(|b| *b > 0.0) && !opts.bandwidths_gbps.is_empty(),
        "bandwidth-gbps values must be positive"
    );
    anyhow::ensure!(
        opts.inter_rack_gbps.iter().all(|g| *g > 0.0),
        "inter-rack-gbps values must be positive"
    );
    anyhow::ensure!(
        opts.compute_ns_per_param >= 0.0 && opts.encode_ns_per_param >= 0.0,
        "compute-ns and encode-ns must be non-negative"
    );
    // Every swept cell must be a valid fabric config for every worker
    // count: pinned torus dims must factor each p, and an uplink axis
    // must reach a hierarchy with at least two groups (the sweep only
    // applies the axis to hier cells, so probe those).
    for &kind in &opts.topologies {
        let probe = FabricConfig {
            topology: kind,
            inter_rack_gbps: match kind {
                TopologyKind::Hier { .. } | TopologyKind::Dragonfly { .. } => {
                    opts.inter_rack_gbps.first().copied()
                }
                _ => None,
            },
            ..FabricConfig::default()
        };
        for &p in &opts.workers {
            probe.validate(p)?;
        }
    }
    if let Some(&min_p) = opts.workers.iter().min() {
        // Every swept fabric must contain every straggler node.
        let min_nodes = opts
            .topologies
            .iter()
            .map(|&k| build_topology(k, min_p).node_count())
            .min()
            .unwrap_or(min_p);
        for st in &opts.stragglers {
            anyhow::ensure!(
                st.node < min_nodes,
                "stragglers name node {} but the smallest swept fabric has {} nodes",
                st.node,
                min_nodes
            );
        }
    }
    Ok(())
}

/// One sweep cell: simulated step communication on one cluster shape.
#[derive(Debug, Clone)]
pub struct FabricSweepRow {
    pub topology: String,
    pub workers: usize,
    pub bandwidth_gbps: f64,
    /// Hierarchy cells only: the uplink bandwidth of this cell.
    pub inter_rack_gbps: Option<f64>,
    pub codec: String,
    /// Mean encoded message size per worker, bytes.
    pub wire_bytes_per_worker: f64,
    /// Total egress bytes across all nodes for the gatherv.
    pub traffic_bytes: u64,
    /// Heaviest single directed link, bytes.
    pub max_link_bytes: u64,
    /// Simulated wall-clock of the codec-message allgatherv, ms.
    pub sim_ms: f64,
    /// Simulated wall-clock of the dense f32 allreduce baseline, ms.
    pub dense_ms: f64,
    /// dense_ms / sim_ms — the end-to-end win of compression+gatherv
    /// (0 for the degenerate single-worker case where nothing moves).
    pub speedup: f64,
    /// Deliveries processed by the gatherv simulation.
    pub events: u64,
    /// Ring only: the paper's analytic `T_v` bound for these messages.
    pub analytic_ms: Option<f64>,
    /// Overlap cells only: phased step span (compute + encode + comm
    /// serialized), ms.
    pub phased_ms: Option<f64>,
    /// Overlap cells only: overlapped step span (comm hidden behind
    /// compute where the schedule allows), ms.
    pub overlap_ms: Option<f64>,
    /// Overlap cells only: ideal `max(compute, comm)` over achieved.
    pub overlap_eff: Option<f64>,
    /// Overlap cells only: bucket count after BDP coalescing.
    pub buckets: Option<usize>,
    /// Overlap cells only: the dense f32 allreduce baseline run through
    /// the same bucketed overlap schedule, ms.
    pub dense_overlap_ms: Option<f64>,
}

/// The deterministic per-worker gradient stream the sweep feeds every
/// codec — and the dense baseline. `[worker][step]`, `steps` vectors.
fn sweep_gradients(p: usize, n: usize, seed: u64, steps: u32) -> Vec<Vec<Vec<f32>>> {
    (0..p)
        .map(|w| {
            let mut rng = Pcg32::new(seed ^ 0x5EED_FAB, w as u64);
            (0..steps)
                .map(|_| testkit::gradient_vec(&mut rng, n))
                .collect()
        })
        .collect()
}

/// Drive one codec over the stream; return each worker's final-step
/// wire message (earlier steps only warm up the residual state).
fn sweep_messages(spec: &CodecSpec, grads: &[Vec<Vec<f32>>], n: usize, seed: u64) -> Vec<Vec<u8>> {
    let layout = Layout::uniform(n, 256);
    grads
        .iter()
        .enumerate()
        .map(|(w, stream)| {
            let mut codec = spec.build(&layout, seed.wrapping_add(w as u64));
            let mut msg = None;
            for g in stream {
                let sq: Vec<f32> = g.iter().map(|x| x * x * 0.5).collect();
                msg = Some(codec.encode_step(g, &sq));
            }
            msg.expect("stream has at least one step").bytes
        })
        .collect()
}

/// Per-worker egress byte counts every topology must reproduce
/// *exactly* (a mismatch is a fabric bug, not an experiment outcome).
/// Star/tree/mesh have no closed form recorded here yet.
pub(crate) fn analytic_gatherv_bytes(kind: TopologyKind, sizes: &[u64]) -> Option<Vec<u64>> {
    match kind {
        TopologyKind::Ring => Some(ring_gatherv_bytes_per_node(sizes)),
        TopologyKind::Torus { rows, cols } => {
            Some(torus_gatherv_bytes_per_node(sizes, rows, cols))
        }
        TopologyKind::Torus3 { x, y, z } => {
            Some(torus3_gatherv_bytes_per_node(sizes, x, y, z))
        }
        TopologyKind::Hier { groups } => Some(hier_gatherv_bytes_per_node(
            sizes,
            &crate::fabric::hierarchy::group_spans(sizes.len(), groups),
        )),
        TopologyKind::Dragonfly { groups } => Some(dragonfly_gatherv_bytes_per_node(
            sizes,
            &crate::fabric::hierarchy::group_spans(sizes.len(), groups),
        )),
        _ => None,
    }
}

/// Run the full sweep. Ring, torus and hierarchy cells are
/// cross-checked against the analytic cost model's byte counts (hard
/// assertion); hierarchy cells additionally fan out over the
/// `inter_rack_gbps` bandwidth-skew axis.
pub fn fabric_sweep(opts: &FabricSweepOpts) -> Vec<FabricSweepRow> {
    let mut rows = Vec::new();
    // Overlap cells share one bucket plan (the layout is the sweep's
    // synthetic gradient, identical across cells) and one synthetic
    // compute/encode span derived from the per-param costs.
    let bucket_weights = if opts.overlap {
        let layout = Layout::uniform(opts.n_params, 256);
        pipeline::bucket_weights(&pipeline::form_buckets(&layout, opts.bucket_bytes))
    } else {
        Vec::new()
    };
    let grad_ps = (opts.n_params as f64 * opts.compute_ns_per_param * 1e3) as u64;
    let encode_ps = (opts.n_params as f64 * opts.encode_ns_per_param * 1e3) as u64;
    for &p in &opts.workers {
        // The gradient stream is codec-independent, so encode once per
        // codec and reuse one dense baseline per (topology, bandwidth).
        let grads = sweep_gradients(p, opts.n_params, opts.seed, opts.warmup_steps + 1);
        let final_grads: Vec<Vec<f32>> = grads
            .iter()
            .map(|stream| stream.last().expect("non-empty stream").clone())
            .collect();
        let encoded: Vec<(String, Vec<Vec<u8>>, Vec<u64>, f64)> = opts
            .codecs
            .iter()
            .map(|codec| {
                let msgs = sweep_messages(codec, &grads, opts.n_params, opts.seed);
                let sizes: Vec<u64> = msgs.iter().map(|m| m.len() as u64).collect();
                let wire = sizes.iter().sum::<u64>() as f64 / p as f64;
                (codec_str(codec), msgs, sizes, wire)
            })
            .collect();
        for &kind in &opts.topologies {
            // Only leader/uplink topologies have an inter-group wire;
            // other topologies get a single cell with the axis unset.
            let uplinks: Vec<Option<f64>> =
                if matches!(
                    kind,
                    TopologyKind::Hier { .. } | TopologyKind::Dragonfly { .. }
                ) && !opts.inter_rack_gbps.is_empty()
                {
                    opts.inter_rack_gbps.iter().copied().map(Some).collect()
                } else {
                    vec![None]
                };
            for &gbps in &opts.bandwidths_gbps {
                for &uplink in &uplinks {
                    let cfg = FabricConfig {
                        topology: kind,
                        link: LinkSpec {
                            bandwidth_gbps: gbps,
                            latency_us: opts.latency_us,
                            jitter_us: opts.jitter_us,
                        },
                        segment_bytes: opts.segment_bytes,
                        inter_rack_gbps: uplink,
                        seed: opts.seed,
                        stragglers: opts.stragglers.clone(),
                        ..FabricConfig::default()
                    };
                    let topo = build_topology(kind, p);
                    // The backend resolves auto dims/groups; report and
                    // cross-check against the resolved shape.
                    let resolved = topo.kind();

                    let mut reduce_fabric = Fabric::for_topology(&cfg, &*topo);
                    let dense = topo.allreduce(&mut reduce_fabric, &final_grads);
                    let dense_ms = dense.time_secs() * 1e3;
                    // The dense baseline gets the same segmented-overlap
                    // treatment (bucketed, gated on gradient readiness,
                    // no encode stage), keeping comparisons honest.
                    let dense_overlap_ms = if opts.overlap {
                        let ov =
                            allreduce_overlapped(&cfg, &final_grads, &bucket_weights, grad_ps);
                        Some(ov.schedule.overlapped_ps as f64 * 1e-9)
                    } else {
                        None
                    };

                    for (label, msgs, sizes, wire_per_worker) in &encoded {
                        let mut gather_fabric = Fabric::for_topology(&cfg, &*topo);
                        let gather = topo.allgatherv(&mut gather_fabric, msgs);
                        let max_link_bytes = gather_fabric.max_link_bytes();

                        if let Some(expect) = analytic_gatherv_bytes(resolved, sizes) {
                            assert_eq!(
                                gather.traffic.bytes_sent_per_node,
                                expect,
                                "{} byte accounting diverged from the analytic model \
                                 (p={p}, codec={label})",
                                resolved.label()
                            );
                        }
                        let analytic_ms = if kind == TopologyKind::Ring {
                            let model =
                                CostModel::new(p, opts.n_params as u64, cfg.link.to_cost_model());
                            let bits: Vec<u64> = sizes.iter().map(|b| b * 8).collect();
                            Some(model.t_allgatherv_bits(&bits) * 1e3)
                        } else {
                            None
                        };

                        let (phased_ms, overlap_ms, overlap_eff, buckets) = if opts.overlap {
                            let ov = allgatherv_overlapped(
                                &cfg,
                                msgs,
                                &bucket_weights,
                                grad_ps,
                                encode_ps,
                            );
                            (
                                Some(ov.schedule.phased_ps as f64 * 1e-9),
                                Some(ov.schedule.overlapped_ps as f64 * 1e-9),
                                Some(ov.schedule.efficiency()),
                                Some(ov.buckets),
                            )
                        } else {
                            (None, None, None, None)
                        };

                        let sim_ms = gather.time_secs() * 1e3;
                        rows.push(FabricSweepRow {
                            topology: resolved.label(),
                            workers: p,
                            bandwidth_gbps: gbps,
                            inter_rack_gbps: uplink,
                            codec: label.clone(),
                            wire_bytes_per_worker: *wire_per_worker,
                            traffic_bytes: gather.traffic.total_bytes(),
                            max_link_bytes,
                            sim_ms,
                            dense_ms,
                            speedup: if sim_ms > 0.0 { dense_ms / sim_ms } else { 0.0 },
                            events: gather.events,
                            analytic_ms,
                            phased_ms,
                            overlap_ms,
                            overlap_eff,
                            buckets,
                            dense_overlap_ms,
                        });
                    }
                }
            }
        }
    }
    rows
}

/// Markdown table of the sweep (the `repro fabric-sweep` report).
pub fn fabric_sweep_markdown(opts: &FabricSweepOpts, rows: &[FabricSweepRow]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "### fabric sweep — N={} params, latency {} us, jitter {} us{}{}\n\n",
        opts.n_params,
        opts.latency_us,
        opts.jitter_us,
        if opts.segment_bytes > 0 {
            format!(", segment {} B", opts.segment_bytes)
        } else {
            String::new()
        },
        if opts.stragglers.is_empty() {
            String::new()
        } else {
            format!(", stragglers {}", Straggler::list_str(&opts.stragglers))
        }
    ));
    if opts.overlap {
        // The overlap report swaps the raw-gather bookkeeping columns
        // for the pipeline's phased-vs-overlapped comparison.
        out.push_str(
            "| topology | p | Gbps | uplink | codec | wire/worker | phased \
             | overlapped | overlap eff | buckets | dense overlap | speedup |\n",
        );
        out.push_str("|---|---|---|---|---|---|---|---|---|---|---|---|\n");
        for r in rows {
            out.push_str(&format!(
                "| {} | {} | {} | {} | {} | {} | {} | {} | {} | {} | {} | {:.2}x |\n",
                r.topology,
                r.workers,
                r.bandwidth_gbps,
                r.inter_rack_gbps
                    .map(|g| format!("{g}"))
                    .unwrap_or_else(|| "-".into()),
                r.codec,
                human_bytes(r.wire_bytes_per_worker),
                r.phased_ms
                    .map(|v| format!("{v:.3} ms"))
                    .unwrap_or_else(|| "-".into()),
                r.overlap_ms
                    .map(|v| format!("{v:.3} ms"))
                    .unwrap_or_else(|| "-".into()),
                r.overlap_eff
                    .map(|v| format!("{v:.3}"))
                    .unwrap_or_else(|| "-".into()),
                r.buckets
                    .map(|v| format!("{v}"))
                    .unwrap_or_else(|| "-".into()),
                r.dense_overlap_ms
                    .map(|v| format!("{v:.3} ms"))
                    .unwrap_or_else(|| "-".into()),
                r.speedup,
            ));
        }
        return out;
    }
    out.push_str(
        "| topology | p | Gbps | uplink | codec | wire/worker | sim gatherv \
         | dense allreduce | speedup | analytic T_v | max link | events |\n",
    );
    out.push_str("|---|---|---|---|---|---|---|---|---|---|---|---|\n");
    for r in rows {
        out.push_str(&format!(
            "| {} | {} | {} | {} | {} | {} | {:.3} ms | {:.3} ms | {:.2}x | {} | {} | {} |\n",
            r.topology,
            r.workers,
            r.bandwidth_gbps,
            r.inter_rack_gbps
                .map(|g| format!("{g}"))
                .unwrap_or_else(|| "-".into()),
            r.codec,
            human_bytes(r.wire_bytes_per_worker),
            r.sim_ms,
            r.dense_ms,
            r.speedup,
            r.analytic_ms
                .map(|a| format!("{a:.3} ms"))
                .unwrap_or_else(|| "-".into()),
            human_bytes(r.max_link_bytes as f64),
            r.events,
        ));
    }
    out
}

fn human_bytes(b: f64) -> String {
    if b >= 1e6 {
        format!("{:.2} MB", b / 1e6)
    } else if b >= 1e3 {
        format!("{:.1} KB", b / 1e3)
    } else {
        format!("{b:.0} B")
    }
}

/// Serialize sweep rows for EXPERIMENTS.md tooling.
pub fn fabric_sweep_json(rows: &[FabricSweepRow]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|r| {
                obj(vec![
                    ("topology", s(&r.topology)),
                    ("workers", num(r.workers as f64)),
                    ("bandwidth_gbps", num(r.bandwidth_gbps)),
                    (
                        "inter_rack_gbps",
                        r.inter_rack_gbps.map(num).unwrap_or(Json::Null),
                    ),
                    ("codec", s(&r.codec)),
                    ("wire_bytes_per_worker", num(r.wire_bytes_per_worker)),
                    ("traffic_bytes", num(r.traffic_bytes as f64)),
                    ("max_link_bytes", num(r.max_link_bytes as f64)),
                    ("sim_ms", num(r.sim_ms)),
                    ("dense_ms", num(r.dense_ms)),
                    ("speedup", num(r.speedup)),
                    ("events", num(r.events as f64)),
                    (
                        "analytic_ms",
                        r.analytic_ms.map(num).unwrap_or(Json::Null),
                    ),
                    ("phased_ms", r.phased_ms.map(num).unwrap_or(Json::Null)),
                    ("overlap_ms", r.overlap_ms.map(num).unwrap_or(Json::Null)),
                    (
                        "overlap_eff",
                        r.overlap_eff.map(num).unwrap_or(Json::Null),
                    ),
                    (
                        "buckets",
                        r.buckets.map(|b| num(b as f64)).unwrap_or(Json::Null),
                    ),
                    (
                        "dense_overlap_ms",
                        r.dense_overlap_ms.map(num).unwrap_or(Json::Null),
                    ),
                ])
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_codec_grid_matches_table1_rows() {
        let rows = paper_codecs();
        // 1 none + 3 strom + 3 vgc + 2 hybrid + 3 qsgd = 12 methods.
        assert_eq!(rows.len(), 12);
        assert!(rows.iter().any(|(l, _)| l.contains("vgc alpha=1.5")));
        assert!(rows.iter().any(|(l, _)| l.contains("hybrid tau=0.1")));
    }

    #[test]
    fn grids_use_right_models() {
        let t1 = table1_rows("momentum", 10);
        assert!(t1.iter().all(|r| r.cfg.model == "vgg_tiny"));
        let t2 = table2_rows("adam", 10);
        assert!(t2.iter().all(|r| r.cfg.model == "resnet_mini"));
        assert!(t2.iter().all(|r| r.cfg.steps == 10));
    }

    #[test]
    fn costmodel_report_contains_linear_regime() {
        let rep = costmodel_report();
        assert!(rep.contains("speedup"));
        assert!(rep.contains("c > p/2"));
    }

    fn tiny_sweep_opts() -> FabricSweepOpts {
        FabricSweepOpts {
            topologies: vec![TopologyKind::Ring, TopologyKind::Star],
            workers: vec![3],
            bandwidths_gbps: vec![1.0],
            codecs: vec![
                CodecSpec::None,
                CodecSpec::Vgc {
                    alpha: 2.0,
                    zeta: 0.999,
                },
            ],
            n_params: 2048,
            ..FabricSweepOpts::default()
        }
    }

    #[test]
    fn fabric_sweep_covers_grid_and_checks_ring_bytes() {
        let opts = tiny_sweep_opts();
        let rows = fabric_sweep(&opts);
        // 2 codecs × 2 topologies × 1 bandwidth × 1 worker count.
        assert_eq!(rows.len(), 4);
        assert!(rows
            .iter()
            .all(|r| r.sim_ms > 0.0 && r.dense_ms > 0.0 && r.events > 0));
        // Ring rows carry the analytic bound; star rows don't.
        for r in &rows {
            assert_eq!(r.analytic_ms.is_some(), r.topology == "ring", "{r:?}");
        }
        // Compression beats the dense wire format on the same topology.
        let ring_none = rows
            .iter()
            .find(|r| r.topology == "ring" && r.codec == "none")
            .unwrap();
        let ring_vgc = rows
            .iter()
            .find(|r| r.topology == "ring" && r.codec.starts_with("vgc"))
            .unwrap();
        assert!(
            ring_vgc.speedup > ring_none.speedup,
            "vgc {} <= none {}",
            ring_vgc.speedup,
            ring_none.speedup
        );
        assert!(ring_vgc.wire_bytes_per_worker < ring_none.wire_bytes_per_worker);
    }

    #[test]
    fn fabric_sweep_covers_new_topologies_with_skew_axis() {
        let opts = FabricSweepOpts {
            topologies: vec![
                TopologyKind::Torus { rows: 0, cols: 0 },
                TopologyKind::Hier { groups: 2 },
            ],
            workers: vec![4],
            bandwidths_gbps: vec![1.0],
            inter_rack_gbps: vec![1.0, 0.05],
            segment_bytes: 512,
            codecs: vec![CodecSpec::None],
            n_params: 2048,
            ..FabricSweepOpts::default()
        };
        let rows = fabric_sweep(&opts);
        // torus × 1 uplink-cell + hier × 2 uplink-cells.
        assert_eq!(rows.len(), 3);
        // Auto dims resolve in the report label.
        assert!(rows.iter().any(|r| r.topology == "torus:2x2"), "{rows:?}");
        let hier: Vec<&FabricSweepRow> = rows
            .iter()
            .filter(|r| r.topology == "hier:2")
            .collect();
        assert_eq!(hier.len(), 2);
        assert!(hier.iter().all(|r| r.inter_rack_gbps.is_some()));
        // A 20x slower uplink must slow the simulated gather.
        let fast = hier.iter().find(|r| r.inter_rack_gbps == Some(1.0)).unwrap();
        let slow = hier
            .iter()
            .find(|r| r.inter_rack_gbps == Some(0.05))
            .unwrap();
        assert!(
            slow.sim_ms > fast.sim_ms,
            "uplink skew had no effect: {} vs {}",
            fast.sim_ms,
            slow.sim_ms
        );
        // Non-hier rows leave the axis unset.
        assert!(rows
            .iter()
            .filter(|r| r.topology.starts_with("torus"))
            .all(|r| r.inter_rack_gbps.is_none()));
    }

    #[test]
    fn overlap_sweep_hides_comm_behind_compute() {
        let opts = FabricSweepOpts {
            topologies: vec![
                TopologyKind::Ring,
                TopologyKind::Torus { rows: 0, cols: 0 },
                TopologyKind::Hier { groups: 2 },
            ],
            workers: vec![8],
            bandwidths_gbps: vec![1.0],
            codecs: vec![
                CodecSpec::None,
                CodecSpec::Vgc {
                    alpha: 2.0,
                    zeta: 0.999,
                },
            ],
            overlap: true,
            ..FabricSweepOpts::default()
        };
        let rows = fabric_sweep(&opts);
        assert_eq!(rows.len(), 6);
        let md = fabric_sweep_markdown(&opts, &rows);
        assert!(md.contains("overlap eff"), "{md}");
        assert_eq!(md.lines().filter(|l| l.starts_with("| ")).count(), 1 + rows.len());
        for r in &rows {
            let phased = r.phased_ms.expect("overlap rows carry phased_ms");
            let over = r.overlap_ms.expect("overlap rows carry overlap_ms");
            assert!(
                over <= phased + 1e-9,
                "{} {}: overlapped {over} > phased {phased}",
                r.topology,
                r.codec
            );
            assert!(r.buckets.unwrap() >= 1);
            assert!(r.dense_overlap_ms.unwrap() > 0.0);
            let eff = r.overlap_eff.unwrap();
            assert!(eff > 0.0 && eff <= 1.0 + 1e-9, "eff {eff}");
            // Acceptance: for dense-size messages the overlapped step
            // lands within ~10% of the ideal max(compute, comm) on
            // every topology at default bandwidths.
            if r.codec == "none" {
                assert!(eff >= 0.9, "{} eff {eff} < 0.9", r.topology);
            }
        }
        // With overlap off the pipeline columns stay unset.
        let plain = fabric_sweep(&FabricSweepOpts {
            overlap: false,
            ..opts
        });
        assert!(plain.iter().all(|r| r.phased_ms.is_none()
            && r.overlap_ms.is_none()
            && r.overlap_eff.is_none()
            && r.buckets.is_none()
            && r.dense_overlap_ms.is_none()));
    }

    #[test]
    fn fabric_sweep_report_shapes() {
        let opts = tiny_sweep_opts();
        let rows = fabric_sweep(&opts);
        let md = fabric_sweep_markdown(&opts, &rows);
        assert!(md.contains("| topology |"), "{md}");
        assert_eq!(md.lines().filter(|l| l.starts_with("| ")).count(), 1 + rows.len());
        let j = fabric_sweep_json(&rows);
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back.as_arr().unwrap().len(), rows.len());
    }

    #[test]
    fn fig3_csv_shape() {
        let results = vec![RowResult {
            label: "vgc alpha=1".into(),
            optimizer: "adam".into(),
            accuracy: 0.9,
            eval_loss: f32::NAN,
            compression: 100.0,
            bits_ratio: 120.0,
            final_loss: 0.2,
        }];
        let csv = fig3_csv(&results);
        assert_eq!(csv.lines().count(), 2);
        assert!(csv.contains("vgc alpha=1,adam,0.9,100,120"));
    }
}
