//! Experiment harnesses: one function per paper table/figure.
//!
//! * [`table1_rows`] / [`table2_rows`] — the codec × optimizer grids of
//!   Tables 1 and 2 (scaled workloads, DESIGN.md §Substitutions).
//! * [`run_grid`] — executes a grid and collects `RowResult`s.
//! * [`print_table`] — paper-shaped console table.
//! * [`fig3_csv`] — the Figure-3 scatter data (accuracy vs ratio).
//! * [`costmodel_report`] — the Section-5 speedup analysis (A5).

use anyhow::Result;

use crate::comm::costmodel::{speedup_series, LinkModel};
use crate::compress::CodecSpec;
use crate::config::TrainConfig;
use crate::coordinator::Trainer;
use crate::runtime::{Client, Manifest};
use crate::util::json::{num, obj, s, Json};

/// The paper's Table-1/2 codec column.
pub fn paper_codecs() -> Vec<(String, CodecSpec)> {
    let mut rows: Vec<(String, CodecSpec)> = vec![("none".into(), CodecSpec::None)];
    for tau in [0.001f32, 0.01, 0.1] {
        rows.push((format!("strom tau={tau}"), CodecSpec::Strom { tau }));
    }
    for alpha in [1.0f32, 1.5, 2.0] {
        rows.push((
            format!("vgc alpha={alpha}"),
            CodecSpec::Vgc { alpha, zeta: 0.999 },
        ));
    }
    for tau in [0.01f32, 0.1] {
        rows.push((
            format!("hybrid tau={tau} alpha=2"),
            CodecSpec::Hybrid {
                tau,
                alpha: 2.0,
                zeta: 0.999,
            },
        ));
    }
    for (bits, d) in [(2u32, 128usize), (3, 512), (4, 512)] {
        rows.push((
            format!("qsgd {bits}bit d={d}"),
            CodecSpec::Qsgd { bits, bucket: d },
        ));
    }
    rows
}

/// One grid cell: a labeled config.
#[derive(Debug, Clone)]
pub struct GridRow {
    pub label: String,
    pub cfg: TrainConfig,
}

/// Build the Table-1 grid (vgg_tiny, 8 workers) for one optimizer.
pub fn table1_rows(optimizer: &str, steps: u64) -> Vec<GridRow> {
    grid_rows("vgg_tiny", optimizer, steps)
}

/// Build the Table-2 grid (resnet_mini, 16 workers) for one optimizer.
pub fn table2_rows(optimizer: &str, steps: u64) -> Vec<GridRow> {
    grid_rows("resnet_mini", optimizer, steps)
}

fn grid_rows(model: &str, optimizer: &str, steps: u64) -> Vec<GridRow> {
    paper_codecs()
        .into_iter()
        .map(|(label, codec)| {
            let mut cfg = TrainConfig::defaults(model);
            cfg.codec = codec;
            cfg.optimizer = optimizer.to_string();
            if optimizer == "adam" {
                cfg.schedule = crate::optim::LrSchedule::Constant { lr: 0.002 };
            }
            cfg.steps = steps;
            GridRow {
                label,
                cfg,
            }
        })
        .collect()
}

/// One completed run's summary.
#[derive(Debug, Clone)]
pub struct RowResult {
    pub label: String,
    pub optimizer: String,
    pub accuracy: f32,
    pub eval_loss: f32,
    pub compression: f64,
    pub bits_ratio: f64,
    pub final_loss: f32,
}

/// Execute every row of a grid sequentially (each run is internally
/// parallel through XLA).
pub fn run_grid(
    client: &Client,
    manifest: &Manifest,
    rows: &[GridRow],
    quiet: bool,
) -> Result<Vec<RowResult>> {
    let mut out = Vec::with_capacity(rows.len());
    for (i, row) in rows.iter().enumerate() {
        if !quiet {
            eprintln!(
                "[{}/{}] {} / {} ...",
                i + 1,
                rows.len(),
                row.label,
                row.cfg.optimizer
            );
        }
        let mut trainer = Trainer::new(client, manifest, row.cfg.clone())?;
        trainer.run(true)?;
        out.push(RowResult {
            label: row.label.clone(),
            optimizer: row.cfg.optimizer.clone(),
            accuracy: trainer.metrics.final_accuracy(),
            eval_loss: trainer
                .metrics
                .evals
                .last()
                .map(|e| e.eval_loss)
                .unwrap_or(f32::NAN),
            compression: trainer.metrics.compression_ratio(),
            bits_ratio: trainer.metrics.bits_ratio(),
            final_loss: trainer.metrics.final_loss(),
        });
    }
    Ok(out)
}

/// Print results in the paper's table shape (one optimizer per block).
pub fn print_table(title: &str, results: &[RowResult]) {
    println!("\n=== {title} ===");
    println!(
        "{:<26} {:>10} {:>9} {:>14} {:>12}",
        "Method", "Accuracy", "Loss", "Compression", "BitsRatio"
    );
    for r in results {
        let acc = if r.accuracy.is_nan() {
            "-".to_string()
        } else {
            format!("{:.1}%", r.accuracy * 100.0)
        };
        let comp = if r.compression.is_infinite() {
            "inf".to_string()
        } else {
            crate::util::with_commas(r.compression.round() as u64)
        };
        println!(
            "{:<26} {:>10} {:>9.3} {:>14} {:>12.1}",
            r.label, acc, r.final_loss, comp, r.bits_ratio
        );
    }
}

/// Figure-3 scatter CSV: `method,optimizer,accuracy,compression`.
pub fn fig3_csv(results: &[RowResult]) -> String {
    let mut out = String::from("method,optimizer,accuracy,compression,bits_ratio\n");
    for r in results {
        out.push_str(&format!(
            "{},{},{},{},{}\n",
            r.label, r.optimizer, r.accuracy, r.compression, r.bits_ratio
        ));
    }
    out
}

/// Serialize results for EXPERIMENTS.md tooling.
pub fn results_json(table: &str, results: &[RowResult]) -> Json {
    Json::Arr(
        results
            .iter()
            .map(|r| {
                obj(vec![
                    ("table", s(table)),
                    ("method", s(&r.label)),
                    ("optimizer", s(&r.optimizer)),
                    ("accuracy", num(r.accuracy as f64)),
                    ("final_loss", num(r.final_loss as f64)),
                    ("compression", num(r.compression)),
                    ("bits_ratio", num(r.bits_ratio)),
                ])
            })
            .collect(),
    )
}

/// The Section-5 (A5) analysis: speedup table over c and p for
/// ResNet-50-scale N on 1GbE, plus the linear-regime boundary.
pub fn costmodel_report() -> String {
    let n = 25_500_000u64;
    let ps = [4usize, 8, 16, 64];
    let cs = [1.0, 4.0, 16.0, 64.0, 256.0, 1024.0, 4096.0, 16384.0];
    let rows = speedup_series(n, &ps, &cs, LinkModel::gige());
    let mut out = String::new();
    out.push_str("Section-5 cost model: ring allreduce vs pipelined ring allgatherv\n");
    out.push_str(&format!("N = {n} params (ResNet-50 scale), 1GbE (beta = 1 ns/bit)\n\n"));
    out.push_str(&format!(
        "{:>4} {:>9} {:>14} {:>14} {:>10} {:>10}\n",
        "p", "c", "T_r (ms)", "T_v (ms)", "speedup", "bound"
    ));
    for r in &rows {
        out.push_str(&format!(
            "{:>4} {:>9} {:>14.3} {:>14.3} {:>10.2} {:>10.2}\n",
            r.p,
            r.c,
            r.t_allreduce * 1e3,
            r.t_allgatherv * 1e3,
            r.speedup,
            r.bound
        ));
    }
    out.push_str("\nlinear-speedup regime boundary (paper: c > p/2):\n");
    for p in ps {
        let c_star = (p * p) as f64 / (2.0 * (p as f64 - 1.0));
        out.push_str(&format!("  p={p:>3}: bound crosses 1 at c = {c_star:.2}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_codec_grid_matches_table1_rows() {
        let rows = paper_codecs();
        // 1 none + 3 strom + 3 vgc + 2 hybrid + 3 qsgd = 12 methods.
        assert_eq!(rows.len(), 12);
        assert!(rows.iter().any(|(l, _)| l.contains("vgc alpha=1.5")));
        assert!(rows.iter().any(|(l, _)| l.contains("hybrid tau=0.1")));
    }

    #[test]
    fn grids_use_right_models() {
        let t1 = table1_rows("momentum", 10);
        assert!(t1.iter().all(|r| r.cfg.model == "vgg_tiny"));
        let t2 = table2_rows("adam", 10);
        assert!(t2.iter().all(|r| r.cfg.model == "resnet_mini"));
        assert!(t2.iter().all(|r| r.cfg.steps == 10));
    }

    #[test]
    fn costmodel_report_contains_linear_regime() {
        let rep = costmodel_report();
        assert!(rep.contains("speedup"));
        assert!(rep.contains("c > p/2"));
    }

    #[test]
    fn fig3_csv_shape() {
        let results = vec![RowResult {
            label: "vgc alpha=1".into(),
            optimizer: "adam".into(),
            accuracy: 0.9,
            eval_loss: f32::NAN,
            compression: 100.0,
            bits_ratio: 120.0,
            final_loss: 0.2,
        }];
        let csv = fig3_csv(&results);
        assert_eq!(csv.lines().count(), 2);
        assert!(csv.contains("vgc alpha=1,adam,0.9,100,120"));
    }
}
