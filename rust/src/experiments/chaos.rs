//! Chaos sweep (`repro chaos-sweep`): a {topology × fault-scenario ×
//! codec} grid over the fault-tolerant fabric.
//!
//! Each cell drives a short synthetic training stream (the same
//! deterministic gradients as the fabric sweep) through the codec and
//! the chaos-enabled allgatherv, then compares the *accumulated
//! aggregated update* against the fault-free run of the same cell:
//!
//! * **masked** — the update is bit-identical to the fault-free run.
//!   Link faults (drops, corruption, flaps) must always be masked:
//!   retransmission recovers the bytes and only timing moves.
//! * **divergence** — relative L2 distance of the accumulated update
//!   from the fault-free baseline. Non-zero only for membership
//!   changes (`crash:`), where renormalized aggregation over the
//!   survivors is a *different* (still correct-on-average) estimator.
//! * **inflation** — total simulated comm time over the fault-free
//!   baseline. `max_step_inflation` isolates the worst single step:
//!   a crash bills a detection bracket (two delivery timeouts of the
//!   largest in-flight message) at the step it fires, while later
//!   steps run a smaller collective and may individually be *faster*.
//!
//! Crashed workers follow `--on-crash renorm` semantics: their
//! residual state dies with them, the step aggregates over survivors
//! with `1/live` weighting, and a rejoining worker restarts from a
//! fresh codec state.

use anyhow::{ensure, Result};

use crate::comm::allgatherv::allgatherv_faulty;
use crate::compress::{Codec, CodecSpec};
use crate::config::codec_str;
use crate::fabric::{build_topology, FabricConfig, FabricReport, FaultPlan, LinkSpec, TopologyKind};
use crate::model::Layout;
use crate::util::json::{num, obj, s, Json};

/// Sweep dimensions for the chaos experiment.
#[derive(Debug, Clone)]
pub struct ChaosSweepOpts {
    pub topologies: Vec<TopologyKind>,
    /// One worker count per sweep (membership is the varying axis).
    pub workers: usize,
    /// Fault scenarios in the `--faults` spec grammar; `none` (or the
    /// empty string) is the fault-free control row.
    pub scenarios: Vec<String>,
    pub codecs: Vec<CodecSpec>,
    /// Synthetic gradient dimension.
    pub n_params: usize,
    /// Simulated training steps per cell.
    pub steps: u32,
    pub bandwidth_gbps: f64,
    pub latency_us: f64,
    pub seed: u64,
}

impl Default for ChaosSweepOpts {
    fn default() -> Self {
        ChaosSweepOpts {
            topologies: vec![
                TopologyKind::Ring,
                TopologyKind::Star,
                TopologyKind::Hier { groups: 0 },
            ],
            workers: 8,
            scenarios: vec![
                "none".into(),
                "drop:0-1:0.3".into(),
                "flap:0-1@0..40".into(),
                "crash:1@2+2".into(),
                "crash:1@2".into(),
            ],
            codecs: vec![
                CodecSpec::None,
                CodecSpec::Vgc {
                    alpha: 2.0,
                    zeta: 0.999,
                },
            ],
            n_params: 16_384,
            steps: 6,
            bandwidth_gbps: 1.0,
            latency_us: 50.0,
            seed: 0,
        }
    }
}

/// Parse one scenario cell (`none` means an empty plan).
pub fn parse_scenario(spec: &str) -> Result<FaultPlan> {
    let t = spec.trim();
    if t.is_empty() || t == "none" {
        return Ok(FaultPlan::default());
    }
    FaultPlan::parse(t)
}

/// Sanity-check a chaos sweep before running it: every scenario must
/// parse, fit every swept topology, and leave at least one live worker
/// at every step.
pub fn validate_chaos(opts: &ChaosSweepOpts) -> Result<()> {
    ensure!(!opts.topologies.is_empty(), "chaos sweep lists no topologies");
    ensure!(!opts.scenarios.is_empty(), "chaos sweep lists no scenarios");
    ensure!(!opts.codecs.is_empty(), "chaos sweep lists no codecs");
    ensure!(opts.workers >= 2, "chaos sweep needs at least 2 workers");
    ensure!(opts.n_params > 0, "n_params must be positive");
    ensure!(opts.steps >= 1, "chaos sweep needs at least one step");
    ensure!(opts.bandwidth_gbps > 0.0, "bandwidth-gbps must be positive");
    ensure!(opts.latency_us >= 0.0, "latency-us must be non-negative");
    for scen in &opts.scenarios {
        let plan = parse_scenario(scen)?;
        for step in 0..opts.steps as u64 {
            let dead_workers = plan
                .dead_at_step(step)
                .iter()
                .filter(|&&d| d < opts.workers)
                .count();
            ensure!(
                dead_workers < opts.workers,
                "scenario '{scen}' leaves no live workers at step {step}"
            );
        }
        for &kind in &opts.topologies {
            let probe = FabricConfig {
                topology: kind,
                faults: plan.clone(),
                ..FabricConfig::default()
            };
            probe.validate(opts.workers)?;
        }
    }
    Ok(())
}

/// One chaos cell's outcome.
#[derive(Debug, Clone)]
pub struct ChaosSweepRow {
    pub topology: String,
    pub codec: String,
    /// Canonical scenario spec (`none` for the control row).
    pub scenario: String,
    /// Accumulated update bit-identical to the fault-free run.
    pub masked: bool,
    /// Relative L2 distance of the accumulated update from the
    /// fault-free baseline (0 when masked).
    pub divergence: f64,
    /// Total simulated comm time, ms.
    pub sim_ms: f64,
    /// Fault-free baseline total, ms.
    pub clean_ms: f64,
    /// `sim_ms / clean_ms` — may be < 1 for permanent crashes, where
    /// the surviving collective is smaller.
    pub inflation: f64,
    /// Worst single-step time over the same step of the baseline.
    pub max_step_inflation: f64,
    pub report: FabricReport,
}

/// Run one cell: `steps` of encode → chaos gather → renormalized
/// decode-accumulate. Returns the accumulated aggregated update, the
/// per-step simulated times, and the fault counters.
fn chaos_cell(
    opts: &ChaosSweepOpts,
    kind: TopologyKind,
    spec: &CodecSpec,
    plan: &FaultPlan,
) -> (Vec<f32>, Vec<u64>, FabricReport) {
    let p = opts.workers;
    let n = opts.n_params;
    let layout = Layout::uniform(n, 256);
    let grads = super::sweep_gradients(p, n, opts.seed, opts.steps);
    let link = LinkSpec {
        bandwidth_gbps: opts.bandwidth_gbps,
        latency_us: opts.latency_us,
        jitter_us: 0.0,
    };
    let cfg = FabricConfig {
        topology: kind,
        link,
        seed: opts.seed,
        faults: plan.clone(),
        ..FabricConfig::default()
    };
    let mut codecs: Vec<Box<dyn Codec>> = (0..p)
        .map(|w| spec.build(&layout, opts.seed.wrapping_add(w as u64)))
        .collect();
    let mut acc = vec![0.0f32; n];
    let mut step_ps = Vec::with_capacity(opts.steps as usize);
    let mut report = FabricReport::default();
    for step in 0..opts.steps as u64 {
        // Renorm semantics: a crashing worker's residual dies with it;
        // a rejoining worker restarts from fresh codec state.
        for c in &plan.crashes {
            if c.at_step == step && c.node < p {
                codecs[c.node] = spec.build(&layout, opts.seed.wrapping_add(c.node as u64));
            }
        }
        let dead = plan.dead_at_step(step);
        let dead_workers: Vec<usize> = dead.iter().copied().filter(|&d| d < p).collect();
        let msgs: Vec<Vec<u8>> = (0..p)
            .map(|w| {
                if dead_workers.contains(&w) {
                    Vec::new()
                } else {
                    let g = &grads[w][step as usize];
                    let sq: Vec<f32> = g.iter().map(|x| x * x * 0.5).collect();
                    codecs[w].encode_step(g, &sq).bytes
                }
            })
            .collect();
        let res = allgatherv_faulty(&cfg, &msgs, &dead);
        let mut t = res.time_ps;
        if plan.crashes.iter().any(|c| c.at_step == step) {
            // Detection bracket: the survivors time out on the dead
            // peer before rerouting — bill two delivery timeouts of
            // the largest in-flight message at the crash step.
            let largest = msgs.iter().map(|m| m.len() as u64).max().unwrap_or(0);
            t += 2 * (link.ser_ps(largest) + link.latency_ps());
        }
        step_ps.push(t);
        report.absorb(&res.report);

        let live = p - dead_workers.len();
        let viewer = (0..p)
            .find(|w| !dead_workers.contains(w))
            .expect("validated: at least one live worker");
        let mut upd = vec![0.0f32; n];
        for bytes in &res.gathered[viewer] {
            if bytes.is_empty() {
                continue;
            }
            codecs[viewer]
                .decode_into(bytes, &mut upd)
                .expect("decode gathered chaos message");
        }
        let inv = 1.0 / live as f32;
        for (a, u) in acc.iter_mut().zip(&upd) {
            *a += u * inv;
        }
    }
    (acc, step_ps, report)
}

fn rel_l2(a: &[f32], b: &[f32]) -> f64 {
    let mut diff = 0.0f64;
    let mut norm = 0.0f64;
    for (x, y) in a.iter().zip(b) {
        diff += (*x as f64 - *y as f64).powi(2);
        norm += (*y as f64).powi(2);
    }
    if norm > 0.0 {
        (diff / norm).sqrt()
    } else if diff > 0.0 {
        f64::INFINITY
    } else {
        0.0
    }
}

/// Run the full chaos sweep (validates first).
pub fn chaos_sweep(opts: &ChaosSweepOpts) -> Result<Vec<ChaosSweepRow>> {
    validate_chaos(opts)?;
    let mut rows = Vec::new();
    for &kind in &opts.topologies {
        let label = build_topology(kind, opts.workers).kind().label();
        for spec in &opts.codecs {
            let clean = FaultPlan::default();
            let (base, base_ps, _) = chaos_cell(opts, kind, spec, &clean);
            let clean_total: u64 = base_ps.iter().sum();
            let clean_ms = clean_total as f64 * 1e-9;
            for scen in &opts.scenarios {
                let plan = parse_scenario(scen)?;
                let (acc, step_ps, report) = if plan.is_empty() {
                    (base.clone(), base_ps.clone(), FabricReport::default())
                } else {
                    chaos_cell(opts, kind, spec, &plan)
                };
                let total: u64 = step_ps.iter().sum();
                let sim_ms = total as f64 * 1e-9;
                let masked = acc
                    .iter()
                    .zip(&base)
                    .all(|(x, y)| x.to_bits() == y.to_bits());
                let max_step_inflation = step_ps
                    .iter()
                    .zip(&base_ps)
                    .map(|(&t, &c)| if c > 0 { t as f64 / c as f64 } else { 0.0 })
                    .fold(0.0f64, f64::max);
                rows.push(ChaosSweepRow {
                    topology: label.clone(),
                    codec: codec_str(spec),
                    scenario: if plan.is_empty() {
                        "none".into()
                    } else {
                        plan.spec_str()
                    },
                    masked,
                    divergence: rel_l2(&acc, &base),
                    sim_ms,
                    clean_ms,
                    inflation: if clean_total > 0 {
                        total as f64 / clean_total as f64
                    } else {
                        0.0
                    },
                    max_step_inflation,
                    report,
                });
            }
        }
    }
    Ok(rows)
}

/// Markdown table of the sweep (the `repro chaos-sweep` report).
pub fn chaos_sweep_markdown(opts: &ChaosSweepOpts, rows: &[ChaosSweepRow]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "### chaos sweep — p={}, N={} params, {} steps, {} Gbps, latency {} us, seed {}\n\n",
        opts.workers, opts.n_params, opts.steps, opts.bandwidth_gbps, opts.latency_us, opts.seed
    ));
    out.push_str(
        "| topology | codec | scenario | masked | divergence | sim comm | clean \
         | inflation | worst step | retries | retx bytes | drops | corrupt | reroutes |\n",
    );
    out.push_str("|---|---|---|---|---|---|---|---|---|---|---|---|---|---|\n");
    for r in rows {
        out.push_str(&format!(
            "| {} | {} | {} | {} | {:.3e} | {:.3} ms | {:.3} ms | {:.2}x | {:.2}x \
             | {} | {} | {} | {} | {} |\n",
            r.topology,
            r.codec,
            r.scenario,
            if r.masked { "yes" } else { "NO" },
            r.divergence,
            r.sim_ms,
            r.clean_ms,
            r.inflation,
            r.max_step_inflation,
            r.report.retries,
            r.report.retransmitted_bytes,
            r.report.drops,
            r.report.corruptions,
            r.report.reroutes,
        ));
    }
    out
}

fn num_or_null(x: f64) -> Json {
    if x.is_finite() {
        num(x)
    } else {
        Json::Null
    }
}

/// Serialize chaos rows for EXPERIMENTS.md tooling and CI smoke.
pub fn chaos_sweep_json(rows: &[ChaosSweepRow]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|r| {
                obj(vec![
                    ("topology", s(&r.topology)),
                    ("codec", s(&r.codec)),
                    ("scenario", s(&r.scenario)),
                    ("masked", Json::Bool(r.masked)),
                    ("divergence", num_or_null(r.divergence)),
                    ("sim_ms", num(r.sim_ms)),
                    ("clean_ms", num(r.clean_ms)),
                    ("inflation", num(r.inflation)),
                    ("max_step_inflation", num(r.max_step_inflation)),
                    ("report", r.report.to_json()),
                ])
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_opts() -> ChaosSweepOpts {
        ChaosSweepOpts {
            topologies: vec![TopologyKind::Ring],
            workers: 4,
            scenarios: vec!["none".into()],
            codecs: vec![CodecSpec::None],
            n_params: 512,
            steps: 3,
            ..ChaosSweepOpts::default()
        }
    }

    #[test]
    fn control_row_is_trivially_masked() {
        let rows = chaos_sweep(&tiny_opts()).unwrap();
        assert_eq!(rows.len(), 1);
        let r = &rows[0];
        assert!(r.masked);
        assert_eq!(r.divergence, 0.0);
        assert_eq!(r.inflation, 1.0);
        assert!(r.report.is_clean());
    }

    #[test]
    fn link_faults_are_masked_but_slower() {
        let mut fired = false;
        for seed in 0..4 {
            let opts = ChaosSweepOpts {
                scenarios: vec!["drop:0-1:0.7,corrupt:1-2:0.5".into()],
                seed,
                ..tiny_opts()
            };
            let rows = chaos_sweep(&opts).unwrap();
            let r = &rows[0];
            assert!(r.masked, "seed {seed}: link faults must be masked");
            assert_eq!(r.divergence, 0.0, "seed {seed}");
            assert!(r.inflation >= 1.0, "seed {seed}");
            fired |= !r.report.is_clean();
            assert_eq!(r.report.retries, r.report.drops + r.report.corruptions);
        }
        assert!(fired, "chaos never fired across 4 seeds");
    }

    #[test]
    fn permanent_crash_diverges_and_inflates_the_crash_step() {
        let opts = ChaosSweepOpts {
            topologies: vec![TopologyKind::Ring, TopologyKind::Star],
            scenarios: vec!["crash:1@1".into()],
            codecs: vec![CodecSpec::Vgc {
                alpha: 2.0,
                zeta: 0.999,
            }],
            ..tiny_opts()
        };
        let rows = chaos_sweep(&opts).unwrap();
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert!(!r.masked, "{}: renorm over survivors must diverge", r.topology);
            assert!(r.divergence > 0.0, "{}", r.topology);
            assert!(
                r.max_step_inflation > 1.0,
                "{}: crash step bills a detection bracket ({})",
                r.topology,
                r.max_step_inflation
            );
            assert!(r.report.reroutes > 0, "{}", r.topology);
        }
    }

    #[test]
    fn transient_crash_recovers_membership() {
        // crash:1@1+1 — dead only for step 1, back at step 2. The
        // update diverges (renorm at step 1) but reroutes stop firing
        // after the rejoin: exactly one degraded step.
        let opts = ChaosSweepOpts {
            scenarios: vec!["crash:1@1+1".into()],
            ..tiny_opts()
        };
        let rows = chaos_sweep(&opts).unwrap();
        let r = &rows[0];
        assert!(!r.masked);
        assert_eq!(r.report.reroutes, 1);
    }

    #[test]
    fn validate_rejects_bad_scenarios() {
        let mut opts = tiny_opts();
        opts.scenarios = vec!["crash:0@0,crash:1@0,crash:2@0,crash:3@0".into()];
        let err = chaos_sweep(&opts).unwrap_err().to_string();
        assert!(err.contains("no live workers"), "{err}");

        let mut opts = tiny_opts();
        opts.scenarios = vec!["drop:9-0:0.5".into()];
        let err = chaos_sweep(&opts).unwrap_err().to_string();
        assert!(err.contains("out of range"), "{err}");

        let mut opts = tiny_opts();
        opts.scenarios = vec!["explode:everything".into()];
        assert!(chaos_sweep(&opts).is_err());
    }

    #[test]
    fn report_shapes_roundtrip() {
        let opts = ChaosSweepOpts {
            scenarios: vec!["none".into(), "crash:1@1".into()],
            ..tiny_opts()
        };
        let rows = chaos_sweep(&opts).unwrap();
        let md = chaos_sweep_markdown(&opts, &rows);
        assert!(md.contains("| topology |"), "{md}");
        assert_eq!(
            md.lines().filter(|l| l.starts_with("| ")).count(),
            1 + rows.len()
        );
        let j = chaos_sweep_json(&rows);
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back.as_arr().unwrap().len(), rows.len());
        let first = &back.as_arr().unwrap()[0];
        assert_eq!(first.get("masked").unwrap(), &Json::Bool(true));
    }
}
