//! Property-based testing harness (the offline crate set has no
//! `proptest`; DESIGN.md §Substitutions).
//!
//! Deliberately small: seeded case generation with failure reporting of
//! the exact seed + case index, so any failing property is reproducible
//! with `VGC_PROP_SEED=<seed>`. Generators compose through plain
//! closures over [`crate::util::rng::Pcg32`].

use crate::util::rng::Pcg32;

/// Number of cases per property (override with VGC_PROP_CASES).
pub fn default_cases() -> u32 {
    std::env::var("VGC_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64)
}

fn base_seed() -> u64 {
    std::env::var("VGC_PROP_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0x5EED_CAFE)
}

/// Run `prop` against `cases` generated inputs. On failure, panics with
/// the seed/case needed to replay deterministically.
pub fn for_all<T, G, P>(name: &str, gen: G, prop: P)
where
    G: Fn(&mut Pcg32) -> T,
    P: Fn(&T) -> Result<(), String>,
    T: std::fmt::Debug,
{
    let seed = base_seed();
    let cases = default_cases();
    for case in 0..cases {
        let mut rng = Pcg32::new(seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15), case as u64);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed (seed={seed}, case={case}): {msg}\ninput: {input:#?}"
            );
        }
    }
}

// ---- common generators ----

/// Gradient-like vector: mixture of near-zero noise, moderate values and
/// occasional large spikes — the distribution the codecs actually see.
pub fn gradient_vec(rng: &mut Pcg32, n: usize) -> Vec<f32> {
    (0..n)
        .map(|_| {
            let kind = rng.next_bounded(10);
            let scale = match kind {
                0 => 1.0,          // big
                1..=3 => 1e-2,     // medium
                _ => 1e-4,         // small
            };
            rng.next_normal() * scale
        })
        .collect()
}

/// Vector with exact zeros, subnormals, extremes — quantizer edge cases.
pub fn adversarial_vec(rng: &mut Pcg32, n: usize) -> Vec<f32> {
    (0..n)
        .map(|_| match rng.next_bounded(8) {
            0 => 0.0,
            1 => -0.0,
            2 => f32::MIN_POSITIVE / 2.0, // subnormal
            3 => f32::MAX / 2.0,
            4 => -f32::MAX / 2.0,
            5 => 1e-38,
            _ => rng.next_normal(),
        })
        .collect()
}

pub fn usize_in(rng: &mut Pcg32, lo: usize, hi: usize) -> usize {
    lo + rng.next_bounded((hi - lo + 1) as u32) as usize
}

pub fn f32_in(rng: &mut Pcg32, lo: f32, hi: f32) -> f32 {
    lo + rng.next_f32() * (hi - lo)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn for_all_passes_trivial_property() {
        for_all(
            "vec length",
            |rng| {
                let n = usize_in(rng, 0, 50);
                gradient_vec(rng, n)
            },
            |v| {
                if v.len() <= 50 {
                    Ok(())
                } else {
                    Err("too long".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn for_all_reports_failures() {
        for_all("always fails", |rng| rng.next_u32(), |_| Err("nope".into()));
    }

    #[test]
    fn generators_are_deterministic_per_seed() {
        let mut a = Pcg32::new(1, 2);
        let mut b = Pcg32::new(1, 2);
        assert_eq!(gradient_vec(&mut a, 32), gradient_vec(&mut b, 32));
    }

    #[test]
    fn adversarial_vec_contains_edge_values() {
        let mut rng = Pcg32::new(3, 3);
        let v = adversarial_vec(&mut rng, 4096);
        assert!(v.iter().any(|x| *x == 0.0));
        assert!(v.iter().any(|x| x.abs() > 1e30));
        assert!(v.iter().any(|x| x.abs() < 1e-30 && *x != 0.0));
    }
}
