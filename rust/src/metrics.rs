//! Experiment metrics (S17): loss curves, the paper's compression
//! ratio, traffic, and modeled communication times.
//!
//! Compression ratio follows the paper's definition (Sec. 6): "the
//! number of the total parameters of networks divided by the average
//! number of parameters sent" (per worker per step). For dense
//! sub-32-bit codecs (QSGD/TernGrad) the element count alone would hide
//! their real wire cost, so the bits-based ratio
//! `32·N / avg payload bits` is tracked alongside.

use crate::comm::costmodel::CostModel;
use crate::util::json::{num, obj, s, Json};

/// One training step's record.
#[derive(Debug, Clone)]
pub struct StepRecord {
    pub step: u64,
    pub loss: f32,
    pub lr: f32,
    /// Elements sent, summed over workers.
    pub elements_sent: u64,
    /// Payload bits, summed over workers.
    pub payload_bits: u64,
    /// Wire bytes, summed over workers.
    pub wire_bytes: u64,
}

/// A periodic evaluation record.
#[derive(Debug, Clone)]
pub struct EvalRecord {
    pub step: u64,
    /// Classifier accuracy in [0,1], or NaN for LMs.
    pub accuracy: f32,
    /// Eval loss (LMs), or NaN for classifiers.
    pub eval_loss: f32,
}

/// Accumulated metrics for one training run.
#[derive(Debug, Clone, Default)]
pub struct RunMetrics {
    pub steps: Vec<StepRecord>,
    pub evals: Vec<EvalRecord>,
    pub n_params: usize,
    pub workers: usize,
}

impl RunMetrics {
    pub fn new(n_params: usize, workers: usize) -> RunMetrics {
        RunMetrics {
            n_params,
            workers,
            ..Default::default()
        }
    }

    pub fn record_step(&mut self, rec: StepRecord) {
        self.steps.push(rec);
    }

    pub fn record_eval(&mut self, rec: EvalRecord) {
        self.evals.push(rec);
    }

    /// Average elements sent per worker per step.
    pub fn avg_elements_per_worker_step(&self) -> f64 {
        if self.steps.is_empty() {
            return 0.0;
        }
        let total: u64 = self.steps.iter().map(|r| r.elements_sent).sum();
        total as f64 / (self.steps.len() as f64 * self.workers as f64)
    }

    /// The paper's compression ratio: N / avg elements sent.
    pub fn compression_ratio(&self) -> f64 {
        let avg = self.avg_elements_per_worker_step();
        if avg == 0.0 {
            f64::INFINITY
        } else {
            self.n_params as f64 / avg
        }
    }

    /// Bits-based ratio: 32·N / avg payload bits per worker per step.
    pub fn bits_ratio(&self) -> f64 {
        if self.steps.is_empty() {
            return 1.0;
        }
        let total: u64 = self.steps.iter().map(|r| r.payload_bits).sum();
        let avg = total as f64 / (self.steps.len() as f64 * self.workers as f64);
        if avg == 0.0 {
            f64::INFINITY
        } else {
            32.0 * self.n_params as f64 / avg
        }
    }

    pub fn final_loss(&self) -> f32 {
        self.steps.last().map(|r| r.loss).unwrap_or(f32::NAN)
    }

    /// Mean loss over the last `k` recorded steps (smoothed curve tail).
    pub fn tail_loss(&self, k: usize) -> f32 {
        if self.steps.is_empty() {
            return f32::NAN;
        }
        let tail = &self.steps[self.steps.len().saturating_sub(k)..];
        tail.iter().map(|r| r.loss).sum::<f32>() / tail.len() as f32
    }

    pub fn final_accuracy(&self) -> f32 {
        self.evals.last().map(|e| e.accuracy).unwrap_or(f32::NAN)
    }

    /// Best (max) eval accuracy across the run.
    pub fn best_accuracy(&self) -> f32 {
        self.evals
            .iter()
            .map(|e| e.accuracy)
            .fold(f32::NAN, |a, b| if b > a || a.is_nan() { b } else { a })
    }

    /// Mean wire bytes per worker per step (feeds the fabric
    /// simulation of the run's communication pattern).
    pub fn avg_wire_bytes_per_worker_step(&self) -> f64 {
        if self.steps.is_empty() {
            return 0.0;
        }
        self.steps.iter().map(|r| r.wire_bytes).sum::<u64>() as f64
            / (self.steps.len() as f64 * self.workers as f64)
    }

    /// Modeled per-step communication times (allreduce baseline vs this
    /// run's measured allgatherv bits) under a link model.
    pub fn modeled_comm(&self, model: &CostModel) -> (f64, f64) {
        let t_r = model.t_allreduce();
        if self.steps.is_empty() {
            return (t_r, t_r);
        }
        let per_worker_bits: u64 = (self
            .steps
            .iter()
            .map(|r| r.payload_bits)
            .sum::<u64>() as f64
            / (self.steps.len() as f64 * self.workers as f64)) as u64;
        let t_v = model.t_allgatherv_bits(&vec![per_worker_bits; self.workers]);
        (t_r, t_v)
    }

    /// JSON record for EXPERIMENTS.md tooling.
    pub fn to_json(&self, label: &str) -> Json {
        obj(vec![
            ("label", s(label)),
            ("n_params", num(self.n_params as f64)),
            ("workers", num(self.workers as f64)),
            ("steps", num(self.steps.len() as f64)),
            ("final_loss", num(self.final_loss() as f64)),
            ("final_accuracy", num(self.final_accuracy() as f64)),
            ("best_accuracy", num(self.best_accuracy() as f64)),
            ("compression_ratio", num(self.compression_ratio())),
            ("bits_ratio", num(self.bits_ratio())),
        ])
    }

    /// CSV of the loss curve (`step,loss,lr,elements,payload_bits`).
    pub fn loss_curve_csv(&self) -> String {
        let mut out = String::from("step,loss,lr,elements_sent,payload_bits\n");
        for r in &self.steps {
            out.push_str(&format!(
                "{},{},{},{},{}\n",
                r.step, r.loss, r.lr, r.elements_sent, r.payload_bits
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::costmodel::LinkModel;

    fn rec(step: u64, elements: u64, bits: u64) -> StepRecord {
        StepRecord {
            step,
            loss: 1.0,
            lr: 0.1,
            elements_sent: elements,
            payload_bits: bits,
            wire_bytes: bits / 8,
        }
    }

    #[test]
    fn compression_ratio_matches_paper_definition() {
        let mut m = RunMetrics::new(1000, 2);
        // 2 workers × 2 steps; 10 elements each step per worker.
        m.record_step(rec(0, 20, 640));
        m.record_step(rec(1, 20, 640));
        assert!((m.avg_elements_per_worker_step() - 10.0).abs() < 1e-9);
        assert!((m.compression_ratio() - 100.0).abs() < 1e-9);
        assert!((m.bits_ratio() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn avg_wire_bytes_averages_over_workers_and_steps() {
        let mut m = RunMetrics::new(1000, 2);
        m.record_step(rec(0, 20, 640)); // 80 wire bytes total
        m.record_step(rec(1, 20, 1280)); // 160 wire bytes total
        assert!((m.avg_wire_bytes_per_worker_step() - 60.0).abs() < 1e-9);
        assert_eq!(RunMetrics::new(10, 2).avg_wire_bytes_per_worker_step(), 0.0);
    }

    #[test]
    fn no_compression_has_ratio_one() {
        let mut m = RunMetrics::new(100, 1);
        m.record_step(rec(0, 100, 3200));
        assert!((m.compression_ratio() - 1.0).abs() < 1e-9);
        assert!((m.bits_ratio() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn nothing_sent_is_infinite_ratio() {
        let mut m = RunMetrics::new(100, 1);
        m.record_step(rec(0, 0, 0));
        assert!(m.compression_ratio().is_infinite());
    }

    #[test]
    fn tail_loss_smooths() {
        let mut m = RunMetrics::new(10, 1);
        for i in 0..10 {
            m.record_step(StepRecord {
                step: i,
                loss: i as f32,
                lr: 0.1,
                elements_sent: 0,
                payload_bits: 0,
            wire_bytes: 0,
            });
        }
        assert_eq!(m.tail_loss(2), 8.5);
        assert_eq!(m.final_loss(), 9.0);
    }

    #[test]
    fn best_accuracy_tracks_max() {
        let mut m = RunMetrics::new(10, 1);
        for (step, acc) in [(0u64, 0.3f32), (1, 0.7), (2, 0.6)] {
            m.record_eval(EvalRecord {
                step,
                accuracy: acc,
                eval_loss: f32::NAN,
            });
        }
        assert_eq!(m.best_accuracy(), 0.7);
        assert_eq!(m.final_accuracy(), 0.6);
    }

    #[test]
    fn modeled_comm_speedup_grows_with_compression() {
        let model = CostModel::new(8, 1_000_000, LinkModel::gige());
        let mut dense = RunMetrics::new(1_000_000, 8);
        dense.record_step(rec(0, 8_000_000, 8 * 32_000_000));
        let mut sparse = RunMetrics::new(1_000_000, 8);
        sparse.record_step(rec(0, 8_000, 8 * 32_000));
        let (t_r, t_v_dense) = dense.modeled_comm(&model);
        let (_, t_v_sparse) = sparse.modeled_comm(&model);
        assert!(t_v_sparse < t_v_dense);
        // With realistic latency + pipelining block the speedup is
        // capped below the pure-bandwidth bound; still large.
        assert!(t_r / t_v_sparse > 30.0);
    }

    #[test]
    fn csv_and_json_emit() {
        let mut m = RunMetrics::new(10, 1);
        m.record_step(rec(0, 5, 160));
        let csv = m.loss_curve_csv();
        assert!(csv.starts_with("step,loss"));
        assert_eq!(csv.lines().count(), 2);
        let j = m.to_json("x");
        assert_eq!(j.get("label").unwrap().as_str().unwrap(), "x");
    }
}
