//! 2-D torus topology: a `rows × cols` wraparound grid, the
//! fixed-degree fabric of TPU pods and many HPC interconnects.
//!
//! Node `(r, c)` is id `r·cols + c` and has four neighbours (right and
//! down are used by the collectives; the wraparound keeps every node's
//! degree constant). Allgatherv runs in two pipelined ring phases per
//! block: the origin circulates its block rightward along its **row**
//! (`cols − 1` hops), and every node of that row — origin included —
//! injects the block downward along its **column** (`rows − 1` hops).
//! Each of the other `p − 1` nodes therefore receives every block
//! exactly once, and per-block traffic is the p−1-send optimum of the
//! flat ring while the longest route shrinks from `p − 1` to
//! `(rows − 1) + (cols − 1)` hops. The two phases overlap per block —
//! a column injection starts the moment a row hop lands, without
//! waiting for the row circulation to finish — and per segment when
//! the fabric configures gather segmentation
//! (`FabricConfig::segment_bytes`).
//!
//! Allreduce is dimension-wise: every node exchanges vectors within
//! its row and sums in ascending column order (identical bits on every
//! node of a row), then exchanges the row-sums within its column and
//! sums in ascending row order — `(rows − 1) + (cols − 1)` vector
//! sends per node versus the flat mesh's `p − 1`.
//!
//! `torus` (no dims) picks the most-square factorization of the worker
//! count ([`auto_dims`]); `torus:RxC` pins the shape and requires
//! `R·C` workers. A `1×p` torus degenerates to the ring's hop
//! structure; a `p×1` likewise by columns.
//!
//! ```
//! use vgc::fabric::{build_topology, Fabric, FabricConfig, TopologyKind};
//!
//! let topo = build_topology(TopologyKind::Torus { rows: 0, cols: 0 }, 6);
//! assert_eq!(topo.kind(), TopologyKind::Torus { rows: 2, cols: 3 });
//! let mut fabric = Fabric::for_topology(&FabricConfig::default(), &*topo);
//! let inputs: Vec<Vec<u8>> = (0..6).map(|w| vec![w as u8; 16]).collect();
//! let out = topo.allgatherv(&mut fabric, &inputs);
//! assert_eq!(out.gathered[5][0], inputs[0]);
//! ```

use super::collectives::{traffic_from, GatherState, SegPayloads, SimGather, SimReduce};
use super::topology::{Topology, TopologyKind};
use super::{Fabric, Msg, Payload, Protocol};
use crate::comm::Traffic;

/// Block circulating rightward along the origin's row.
const TAG_ROW: u8 = 0;
/// Block circulating downward along a column.
const TAG_COL: u8 = 1;

/// The most-square `rows × cols = p` factorization (`rows ≤ cols`):
/// the largest divisor of `p` not exceeding `√p`. Primes degenerate to
/// `1 × p` (a ring).
pub fn auto_dims(p: usize) -> (usize, usize) {
    assert!(p > 0, "topology needs at least one worker");
    let mut rows = (p as f64).sqrt() as usize;
    while rows > 1 && p % rows != 0 {
        rows -= 1;
    }
    let rows = rows.max(1);
    (rows, p / rows)
}

pub struct Torus {
    rows: usize,
    cols: usize,
}

impl Torus {
    /// `rows`/`cols` of 0 mean "auto" (see [`auto_dims`]); explicit
    /// dims must factor the worker count exactly.
    pub fn new(workers: usize, rows: usize, cols: usize) -> Torus {
        assert!(workers > 0, "topology needs at least one worker");
        let (rows, cols) = if rows == 0 || cols == 0 {
            auto_dims(workers)
        } else {
            (rows, cols)
        };
        assert_eq!(
            rows * cols,
            workers,
            "torus {rows}x{cols} needs {} workers, got {workers}",
            rows * cols
        );
        Torus { rows, cols }
    }

    fn p(&self) -> usize {
        self.rows * self.cols
    }

    fn row_of(&self, w: usize) -> usize {
        w / self.cols
    }

    fn col_of(&self, w: usize) -> usize {
        w % self.cols
    }

    /// Right neighbour within the row (wraps).
    fn right(&self, w: usize) -> usize {
        self.row_of(w) * self.cols + (self.col_of(w) + 1) % self.cols
    }

    /// Down neighbour within the column (wraps).
    fn down(&self, w: usize) -> usize {
        ((self.row_of(w) + 1) % self.rows) * self.cols + self.col_of(w)
    }

    /// Drive one gather (real or phantom payloads) through the event
    /// loop — both `allgatherv` flavors run this identical code.
    fn run_gather(&self, fabric: &mut Fabric, segs: SegPayloads, state: GatherState) -> SimGather {
        let mut proto = TorusGather {
            t: self,
            segs,
            state,
        };
        let time_ps = if self.p() > 1 { fabric.run(&mut proto) } else { 0 };
        SimGather {
            gathered: proto.state.into_gathered(),
            traffic: traffic_from(fabric, self.gather_rounds()),
            time_ps,
            events: fabric.events(),
        }
    }
}

struct TorusGather<'t> {
    t: &'t Torus,
    segs: SegPayloads,
    state: GatherState,
}

impl Protocol for TorusGather<'_> {
    fn start(&mut self) -> Vec<(usize, usize, Msg)> {
        let mut out = Vec::new();
        for w in 0..self.t.p() {
            for si in 0..self.segs.seg_count(w) {
                let payload = self.segs.payload(w, si);
                if self.t.cols > 1 {
                    out.push((
                        w,
                        self.t.right(w),
                        Msg {
                            origin: w,
                            seg: si as u32,
                            hop: 1,
                            tag: TAG_ROW,
                            payload: payload.clone(),
                        },
                    ));
                }
                if self.t.rows > 1 {
                    out.push((
                        w,
                        self.t.down(w),
                        Msg {
                            origin: w,
                            seg: si as u32,
                            hop: 1,
                            tag: TAG_COL,
                            payload,
                        },
                    ));
                }
            }
        }
        out
    }

    fn on_deliver(&mut self, node: usize, msg: &Msg) -> Vec<(usize, Msg)> {
        self.state
            .store_payload(node, msg.origin, msg.seg as usize, &msg.payload);
        let mut out = Vec::new();
        match msg.tag {
            TAG_ROW => {
                // Keep the row circulation going…
                if msg.hop < (self.t.cols - 1) as u32 {
                    out.push((
                        self.t.right(node),
                        Msg {
                            origin: msg.origin,
                            seg: msg.seg,
                            hop: msg.hop + 1,
                            tag: TAG_ROW,
                            payload: msg.payload.clone(),
                        },
                    ));
                }
                // …and inject the block into this node's column.
                if self.t.rows > 1 {
                    out.push((
                        self.t.down(node),
                        Msg {
                            origin: msg.origin,
                            seg: msg.seg,
                            hop: 1,
                            tag: TAG_COL,
                            payload: msg.payload.clone(),
                        },
                    ));
                }
            }
            TAG_COL => {
                if msg.hop < (self.t.rows - 1) as u32 {
                    out.push((
                        self.t.down(node),
                        Msg {
                            origin: msg.origin,
                            seg: msg.seg,
                            hop: msg.hop + 1,
                            tag: TAG_COL,
                            payload: msg.payload.clone(),
                        },
                    ));
                }
            }
            other => unreachable!("unknown torus gather tag {other}"),
        }
        out
    }
}

struct TorusReduce<'t> {
    t: &'t Torus,
    inputs: Vec<Vec<f32>>,
    /// Row-phase vectors at each node, by column index of the sender.
    row_got: Vec<Vec<Option<Vec<f32>>>>,
    /// Column-phase row-sums at each node, by row index of the sender.
    col_got: Vec<Vec<Option<Vec<f32>>>>,
}

impl TorusReduce<'_> {
    /// Sum this node's row set in ascending column order — identical
    /// bits on every node of the row.
    fn row_sum(&self, node: usize) -> Vec<f32> {
        let n = self.inputs[node].len();
        let mut sum = vec![0.0f32; n];
        for slot in &self.row_got[node] {
            let v = slot.as_ref().expect("row vector missing");
            for (k, x) in v.iter().enumerate() {
                sum[k] += x;
            }
        }
        sum
    }

    /// The row phase finished at `node`: record its row-sum and fan it
    /// down the column.
    fn row_ready(&mut self, node: usize, hop: u32) -> Vec<(usize, Msg)> {
        let sum = self.row_sum(node);
        let r = self.t.row_of(node);
        self.col_got[node][r] = Some(sum.clone());
        let payload = Payload::F32(sum);
        (0..self.t.rows)
            .filter(|&r2| r2 != r)
            .map(|r2| {
                (
                    r2 * self.t.cols + self.t.col_of(node),
                    Msg {
                        origin: node,
                        seg: 0,
                        hop,
                        tag: TAG_COL,
                        payload: payload.clone(),
                    },
                )
            })
            .collect()
    }
}

impl Protocol for TorusReduce<'_> {
    fn start(&mut self) -> Vec<(usize, usize, Msg)> {
        let mut out = Vec::new();
        for w in 0..self.t.p() {
            let payload = Payload::F32(self.inputs[w].clone());
            let r = self.t.row_of(w);
            for c2 in 0..self.t.cols {
                let peer = r * self.t.cols + c2;
                if peer != w {
                    out.push((
                        w,
                        peer,
                        Msg {
                            origin: w,
                            seg: 0,
                            hop: 1,
                            tag: TAG_ROW,
                            payload: payload.clone(),
                        },
                    ));
                }
            }
        }
        // Single-column rows are complete at t = 0.
        if self.t.cols == 1 {
            for w in 0..self.t.p() {
                for (dst, msg) in self.row_ready(w, 1) {
                    out.push((w, dst, msg));
                }
            }
        }
        out
    }

    fn on_deliver(&mut self, node: usize, msg: &Msg) -> Vec<(usize, Msg)> {
        let Payload::F32(v) = &msg.payload else {
            unreachable!("reduce protocol only moves f32 vectors")
        };
        match msg.tag {
            TAG_ROW => {
                self.row_got[node][self.t.col_of(msg.origin)] = Some(v.clone());
                if self.row_got[node].iter().all(|s| s.is_some()) {
                    self.row_ready(node, msg.hop + 1)
                } else {
                    Vec::new()
                }
            }
            TAG_COL => {
                self.col_got[node][self.t.row_of(msg.origin)] = Some(v.clone());
                Vec::new()
            }
            other => unreachable!("unknown torus reduce tag {other}"),
        }
    }
}

impl Topology for Torus {
    fn kind(&self) -> TopologyKind {
        TopologyKind::Torus {
            rows: self.rows,
            cols: self.cols,
        }
    }

    fn workers(&self) -> usize {
        self.p()
    }

    fn gather_rounds(&self) -> u32 {
        (self.rows - 1 + self.cols - 1) as u32
    }

    fn reduce_rounds(&self) -> u32 {
        u32::from(self.cols > 1) + u32::from(self.rows > 1)
    }

    fn allgatherv(&self, fabric: &mut Fabric, inputs: &[Vec<u8>]) -> SimGather {
        assert_eq!(inputs.len(), self.p(), "one input message per worker");
        let seg = fabric.segment_bytes();
        self.run_gather(
            fabric,
            SegPayloads::real(inputs, seg),
            GatherState::new(inputs, seg),
        )
    }

    fn allgatherv_sized(&self, fabric: &mut Fabric, sizes: &[u64]) -> SimGather {
        assert_eq!(sizes.len(), self.p(), "one size per worker");
        let seg = fabric.segment_bytes();
        self.run_gather(
            fabric,
            SegPayloads::phantom(sizes, seg),
            GatherState::sized(sizes, seg),
        )
    }

    fn allreduce(&self, fabric: &mut Fabric, inputs: &[Vec<f32>]) -> SimReduce {
        assert_eq!(inputs.len(), self.p());
        let n = inputs[0].len();
        assert!(inputs.iter().all(|v| v.len() == n), "length mismatch");
        if self.p() == 1 {
            return SimReduce {
                reduced: vec![inputs[0].clone()],
                traffic: Traffic {
                    bytes_sent_per_node: vec![0],
                    rounds: 0,
                },
                time_ps: 0,
                events: 0,
            };
        }
        let mut proto = TorusReduce {
            t: self,
            inputs: inputs.to_vec(),
            row_got: (0..self.p())
                .map(|w| {
                    let mut row = vec![None; self.cols];
                    row[self.col_of(w)] = Some(inputs[w].clone());
                    row
                })
                .collect(),
            col_got: vec![vec![None; self.rows]; self.p()],
        };
        let time_ps = fabric.run(&mut proto);
        let reduced: Vec<Vec<f32>> = proto
            .col_got
            .iter()
            .map(|slots| {
                let mut out = vec![0.0f32; n];
                for slot in slots {
                    let v = slot.as_ref().expect("torus reduce under-delivered");
                    for (k, x) in v.iter().enumerate() {
                        out[k] += x;
                    }
                }
                out
            })
            .collect();
        SimReduce {
            reduced,
            traffic: traffic_from(fabric, self.reduce_rounds()),
            time_ps,
            events: fabric.events(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{FabricConfig, LinkSpec};

    fn fabric(nodes: usize) -> Fabric {
        Fabric::for_config(
            &FabricConfig {
                link: LinkSpec {
                    bandwidth_gbps: 1.0,
                    latency_us: 1.0,
                    jitter_us: 0.0,
                },
                ..FabricConfig::default()
            },
            nodes,
        )
    }

    #[test]
    fn auto_dims_prefers_square() {
        assert_eq!(auto_dims(1), (1, 1));
        assert_eq!(auto_dims(4), (2, 2));
        assert_eq!(auto_dims(6), (2, 3));
        assert_eq!(auto_dims(8), (2, 4));
        assert_eq!(auto_dims(12), (3, 4));
        assert_eq!(auto_dims(16), (4, 4));
        assert_eq!(auto_dims(7), (1, 7)); // prime ⇒ ring
    }

    #[test]
    #[should_panic(expected = "needs 6 workers")]
    fn explicit_dims_must_factor_workers() {
        Torus::new(7, 2, 3);
    }

    #[test]
    fn neighbour_math_wraps() {
        let t = Torus::new(6, 2, 3);
        assert_eq!(t.right(0), 1);
        assert_eq!(t.right(2), 0); // row wrap
        assert_eq!(t.down(0), 3);
        assert_eq!(t.down(4), 1); // column wrap
    }

    #[test]
    fn gather_delivers_for_awkward_shapes() {
        for (rows, cols) in [(1usize, 1usize), (1, 5), (5, 1), (2, 2), (2, 3), (3, 3)] {
            let p = rows * cols;
            let inputs: Vec<Vec<u8>> =
                (0..p).map(|w| vec![w as u8 + 1; (w * 17) % 31 + 1]).collect();
            let topo = Torus::new(p, rows, cols);
            let mut f = fabric(topo.node_count());
            let res = topo.allgatherv(&mut f, &inputs);
            for dst in 0..p {
                for src in 0..p {
                    assert_eq!(
                        res.gathered[dst][src], inputs[src],
                        "{rows}x{cols} dst={dst} src={src}"
                    );
                }
            }
        }
    }

    #[test]
    fn per_block_traffic_is_p_minus_1_sends() {
        // Every block is sent exactly p−1 times in total (the flat
        // ring's optimum), whatever the grid shape.
        let (rows, cols) = (2, 3);
        let p = rows * cols;
        let inputs: Vec<Vec<u8>> = (0..p).map(|_| vec![9u8; 10]).collect();
        let topo = Torus::new(p, rows, cols);
        let mut f = fabric(topo.node_count());
        let res = topo.allgatherv(&mut f, &inputs);
        assert_eq!(res.traffic.total_bytes(), (p * (p - 1) * 10) as u64);
        assert_eq!(res.events as usize, p * (p - 1));
        assert_eq!(res.traffic.rounds, (rows - 1 + cols - 1) as u32);
    }

    #[test]
    fn reduce_matches_sum_for_awkward_shapes() {
        for (rows, cols) in [(1usize, 1usize), (1, 4), (4, 1), (2, 2), (2, 3), (3, 2)] {
            let p = rows * cols;
            let inputs: Vec<Vec<f32>> = (0..p)
                .map(|w| (0..5).map(|k| (w * 5 + k) as f32 * 0.25).collect())
                .collect();
            let topo = Torus::new(p, rows, cols);
            let mut f = fabric(topo.node_count());
            let res = topo.allreduce(&mut f, &inputs);
            for k in 0..5 {
                let want: f32 = inputs.iter().map(|v| v[k]).sum();
                for node in 0..p {
                    let got = res.reduced[node][k];
                    assert!(
                        (got - want).abs() < 1e-3,
                        "{rows}x{cols} node={node} k={k}: {got} != {want}"
                    );
                }
            }
        }
    }

    #[test]
    fn torus_shortens_the_longest_route() {
        // 4x4 torus: max 6 hops vs the 16-ring's 15. Per-node egress
        // load is identical (p−1 blocks), so a latency-dominated link
        // isolates the hop-count win.
        let p = 16;
        let high_latency = FabricConfig {
            link: LinkSpec {
                bandwidth_gbps: 1.0,
                latency_us: 500.0,
                jitter_us: 0.0,
            },
            ..FabricConfig::default()
        };
        let inputs: Vec<Vec<u8>> = (0..p).map(|_| vec![3u8; 125]).collect();
        let torus = Torus::new(p, 4, 4);
        let ring = crate::fabric::ring::Ring::new(p);
        let mut ft = Fabric::for_config(&high_latency, p);
        let mut fr = Fabric::for_config(&high_latency, p);
        let tt = torus.allgatherv(&mut ft, &inputs).time_ps;
        let tr = ring.allgatherv(&mut fr, &inputs).time_ps;
        assert!(
            tt * 2 < tr,
            "torus {tt} ps not clearly faster than ring {tr} ps"
        );
    }
}
