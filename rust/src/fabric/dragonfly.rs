//! Dragonfly topology: groups with all-to-all *global* links, one
//! per group pair, owned by distinct members so the global traffic
//! spreads across the group's NICs instead of funneling through its
//! leader (the Cray Slingshot / Aries wiring).
//!
//! Workers partition into `g` contiguous balanced groups (the same
//! spans as [`super::hierarchy`]). For every ordered group pair
//! `(a, b)` one member of `a` — [`Dragonfly::owner`]`(a, b)`, chosen
//! round-robin so ownership balances — terminates the global link to
//! group `b`. Allgatherv routes each block in ≤ 3 hops:
//!
//! 1. **local broadcast** — the origin sends its block to every peer
//!    in its group;
//! 2. **global crossing** — each member that owns a link `(a, b)`
//!    forwards every group-`a` block (its own included) to the peer
//!    owner `(b, a)`, so each block crosses each group pair exactly
//!    once;
//! 3. **remote broadcast** — the receiving owner fans the block to
//!    the rest of its group.
//!
//! Total sends per block is the `p − 1` optimum. Allreduce delegates
//! to the shared leader-based [`super::groups::GroupReduce`] (group
//! aggregates cross leader links once per pair), so the global-link
//! overrides also cover the leader pairs. Like `hier`, the uplinks
//! resolve to `FabricConfig::inter_rack_gbps` (default: base
//! bandwidth / 10).
//!
//! `dragonfly` (no count) picks `≈ √p` groups
//! ([`super::hierarchy::auto_groups`]); `dragonfly:<g>` pins it.

use super::collectives::{traffic_from, GatherState, SegPayloads, SimGather, SimReduce};
use super::groups::{GroupReduce, GroupSpans};
use super::hierarchy::{auto_groups, group_spans, DEFAULT_OVERSUBSCRIPTION};
use super::topology::{Topology, TopologyKind};
use super::{Fabric, FabricConfig, LinkSpec, Msg, Protocol};
use std::collections::BTreeMap;

/// Block broadcast within a group (local or remote side).
const TAG_BCAST: u8 = 0;
/// Block crossing a global inter-group link.
const TAG_GLOBAL: u8 = 1;

pub struct Dragonfly {
    p: usize,
    spans: GroupSpans,
}

impl Dragonfly {
    /// `groups` of 0 means "auto" (`≈ √p`, see
    /// [`super::hierarchy::auto_groups`]).
    pub fn new(workers: usize, groups: usize) -> Dragonfly {
        assert!(workers > 0, "topology needs at least one worker");
        let g = if groups == 0 {
            auto_groups(workers)
        } else {
            groups
        };
        assert!(
            g >= 1 && g <= workers,
            "dragonfly wants {g} groups but only {workers} workers"
        );
        Dragonfly {
            p: workers,
            spans: GroupSpans::from_spans(workers, group_spans(workers, g)),
        }
    }

    fn groups(&self) -> usize {
        self.spans.groups()
    }

    /// All workers of group `g` (leader included).
    fn span_nodes(&self, g: usize) -> std::ops::Range<usize> {
        let (start, len) = self.spans.span(g);
        start..start + len
    }

    /// The member of group `a` that terminates the global link to
    /// group `b` (`a != b`): round-robin over `a`'s members so each
    /// NIC owns `⌈(g−1)/m_a⌉` links at most.
    fn owner(&self, a: usize, b: usize) -> usize {
        debug_assert_ne!(a, b, "no global link within a group");
        let (start, len) = self.spans.span(a);
        start + (b - usize::from(b > a)) % len
    }

    /// Drive one gather (real or phantom payloads) through the event
    /// loop — both `allgatherv` flavors run this identical code.
    fn run_gather(&self, fabric: &mut Fabric, segs: SegPayloads, state: GatherState) -> SimGather {
        let mut proto = DragonflyGather {
            d: self,
            segs,
            state,
        };
        let time_ps = if self.p > 1 { fabric.run(&mut proto) } else { 0 };
        SimGather {
            gathered: proto.state.into_gathered(),
            traffic: traffic_from(fabric, self.gather_rounds()),
            time_ps,
            events: fabric.events(),
        }
    }
}

struct DragonflyGather<'d> {
    d: &'d Dragonfly,
    segs: SegPayloads,
    state: GatherState,
}

impl DragonflyGather<'_> {
    /// The global crossings `node` owes for a group-`a` block: one
    /// send per group pair it owns, to the peer owner on the far side.
    fn global_sends(&self, node: usize, a: usize, msg: &Msg, hop: u32) -> Vec<(usize, Msg)> {
        (0..self.d.groups())
            .filter(|&b| b != a && self.d.owner(a, b) == node)
            .map(|b| {
                (
                    self.d.owner(b, a),
                    Msg {
                        origin: msg.origin,
                        seg: msg.seg,
                        hop,
                        tag: TAG_GLOBAL,
                        payload: msg.payload.clone(),
                    },
                )
            })
            .collect()
    }
}

impl Protocol for DragonflyGather<'_> {
    fn start(&mut self) -> Vec<(usize, usize, Msg)> {
        let mut out = Vec::new();
        for w in 0..self.d.p {
            let a = self.d.spans.group_of(w);
            for si in 0..self.segs.seg_count(w) {
                let msg = Msg {
                    origin: w,
                    seg: si as u32,
                    hop: 1,
                    tag: TAG_BCAST,
                    payload: self.segs.payload(w, si),
                };
                for v in self.d.span_nodes(a) {
                    if v != w {
                        out.push((w, v, msg.clone()));
                    }
                }
                for (dst, global) in self.global_sends(w, a, &msg, 1) {
                    out.push((w, dst, global));
                }
            }
        }
        out
    }

    fn on_deliver(&mut self, node: usize, msg: &Msg) -> Vec<(usize, Msg)> {
        self.state
            .store_payload(node, msg.origin, msg.seg as usize, &msg.payload);
        let a = self.d.spans.group_of(node);
        match msg.tag {
            TAG_BCAST => {
                // A same-group origin's block: cross every global link
                // this node owns. Remote-origin broadcasts terminate.
                if self.d.spans.group_of(msg.origin) == a {
                    self.global_sends(node, a, msg, msg.hop + 1)
                } else {
                    Vec::new()
                }
            }
            TAG_GLOBAL => {
                // Landed on the far-side owner: fan to the rest of the
                // group.
                self.d
                    .span_nodes(a)
                    .filter(|&v| v != node)
                    .map(|v| {
                        (
                            v,
                            Msg {
                                origin: msg.origin,
                                seg: msg.seg,
                                hop: msg.hop + 1,
                                tag: TAG_BCAST,
                                payload: msg.payload.clone(),
                            },
                        )
                    })
                    .collect()
            }
            other => unreachable!("unknown dragonfly gather tag {other}"),
        }
    }
}

impl Topology for Dragonfly {
    fn kind(&self) -> TopologyKind {
        TopologyKind::Dragonfly {
            groups: self.groups(),
        }
    }

    fn workers(&self) -> usize {
        self.p
    }

    fn link_overrides(&self, cfg: &FabricConfig) -> Vec<(usize, usize, LinkSpec)> {
        if self.groups() < 2 {
            return Vec::new();
        }
        let uplink = LinkSpec {
            bandwidth_gbps: cfg
                .inter_rack_gbps
                .unwrap_or(cfg.link.bandwidth_gbps / DEFAULT_OVERSUBSCRIPTION),
            ..cfg.link
        };
        // Gather crosses owner↔owner links; reduce (GroupReduce)
        // crosses leader↔leader links. Both are inter-group wires, so
        // both get the uplink spec; the map dedups overlaps (a leader
        // often owns links too).
        let mut edges: BTreeMap<(usize, usize), LinkSpec> = BTreeMap::new();
        for a in 0..self.groups() {
            for b in 0..self.groups() {
                if a != b {
                    edges.insert((self.owner(a, b), self.owner(b, a)), uplink);
                    edges.insert((self.spans.leader(a), self.spans.leader(b)), uplink);
                }
            }
        }
        edges.into_iter().map(|((s, d), l)| (s, d, l)).collect()
    }

    fn gather_rounds(&self) -> u32 {
        if self.p > 1 {
            3
        } else {
            0
        }
    }

    fn reduce_rounds(&self) -> u32 {
        if self.p > 1 {
            3
        } else {
            0
        }
    }

    fn allgatherv(&self, fabric: &mut Fabric, inputs: &[Vec<u8>]) -> SimGather {
        assert_eq!(inputs.len(), self.p, "one input message per worker");
        let seg = fabric.segment_bytes();
        self.run_gather(
            fabric,
            SegPayloads::real(inputs, seg),
            GatherState::new(inputs, seg),
        )
    }

    fn allgatherv_sized(&self, fabric: &mut Fabric, sizes: &[u64]) -> SimGather {
        assert_eq!(sizes.len(), self.p, "one size per worker");
        let seg = fabric.segment_bytes();
        self.run_gather(
            fabric,
            SegPayloads::phantom(sizes, seg),
            GatherState::sized(sizes, seg),
        )
    }

    fn allreduce(&self, fabric: &mut Fabric, inputs: &[Vec<f32>]) -> SimReduce {
        assert_eq!(inputs.len(), self.p);
        let n = inputs[0].len();
        assert!(inputs.iter().all(|v| v.len() == n), "length mismatch");
        let mut proto = GroupReduce::new(&self.spans, inputs);
        let time_ps = if self.p > 1 { fabric.run(&mut proto) } else { 0 };
        let reduced: Vec<Vec<f32>> = if self.p == 1 {
            vec![inputs[0].clone()]
        } else {
            proto.into_totals()
        };
        SimReduce {
            reduced,
            traffic: traffic_from(fabric, self.reduce_rounds()),
            time_ps,
            events: fabric.events(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::FabricConfig;

    fn fast_cfg() -> FabricConfig {
        FabricConfig {
            link: LinkSpec {
                bandwidth_gbps: 1.0,
                latency_us: 1.0,
                jitter_us: 0.0,
            },
            topology: TopologyKind::Dragonfly { groups: 0 },
            ..FabricConfig::default()
        }
    }

    fn fabric_for(topo: &Dragonfly, cfg: &FabricConfig) -> Fabric {
        Fabric::for_topology(cfg, topo)
    }

    #[test]
    fn ownership_round_robins_and_balances() {
        // 9 workers, 3 groups of 3: group 0 = {0,1,2}.
        let d = Dragonfly::new(9, 3);
        assert_eq!(d.owner(0, 1), 0);
        assert_eq!(d.owner(0, 2), 1);
        assert_eq!(d.owner(1, 0), 3);
        assert_eq!(d.owner(1, 2), 4);
        assert_eq!(d.owner(2, 0), 6);
        assert_eq!(d.owner(2, 1), 7);
        // Single-member groups own every link.
        let d = Dragonfly::new(3, 3);
        assert_eq!(d.owner(0, 1), 0);
        assert_eq!(d.owner(0, 2), 0);
        assert_eq!(d.owner(2, 1), 2);
    }

    #[test]
    fn gather_delivers_for_awkward_shapes() {
        for (p, g) in [
            (7usize, 3usize),
            (8, 2),
            (9, 3),
            (5, 5),
            (5, 1),
            (2, 2),
            (1, 1),
        ] {
            let inputs: Vec<Vec<u8>> =
                (0..p).map(|w| vec![w as u8 + 1; (w * 11) % 23 + 1]).collect();
            let topo = Dragonfly::new(p, g);
            let mut f = fabric_for(&topo, &fast_cfg());
            let res = topo.allgatherv(&mut f, &inputs);
            for dst in 0..p {
                for src in 0..p {
                    assert_eq!(
                        res.gathered[dst][src], inputs[src],
                        "p={p} g={g} dst={dst} src={src}"
                    );
                }
            }
        }
    }

    #[test]
    fn per_block_traffic_is_p_minus_1_sends() {
        for (p, g) in [(9usize, 3usize), (8, 2), (7, 3), (6, 1)] {
            let inputs: Vec<Vec<u8>> = (0..p).map(|_| vec![9u8; 10]).collect();
            let topo = Dragonfly::new(p, g);
            let mut f = fabric_for(&topo, &fast_cfg());
            let res = topo.allgatherv(&mut f, &inputs);
            assert_eq!(
                res.traffic.total_bytes(),
                (p * (p - 1) * 10) as u64,
                "p={p} g={g}"
            );
            assert_eq!(res.events as usize, p * (p - 1), "p={p} g={g}");
        }
    }

    #[test]
    fn global_links_cross_each_group_pair_once_per_block() {
        // 9 workers, 3 groups. Block 1 (member of group 0) crosses the
        // 0→1 global link (owner 0 → owner 3) exactly once.
        let inputs: Vec<Vec<u8>> = (0..9).map(|w| vec![w as u8; 100]).collect();
        let topo = Dragonfly::new(9, 3);
        let mut f = fabric_for(&topo, &fast_cfg());
        let res = topo.allgatherv(&mut f, &inputs);
        assert_eq!(res.traffic.rounds, 3);
        // owner(0,1)=0 → owner(1,0)=3 carries all 3 group-0 blocks.
        assert_eq!(f.links()[&(0, 3)].messages, 3);
        // owner(0,2)=1 → owner(2,0)=6 likewise.
        assert_eq!(f.links()[&(1, 6)].messages, 3);
    }

    #[test]
    fn uplink_overrides_cover_owner_and_leader_pairs() {
        let topo = Dragonfly::new(9, 3);
        let cfg = FabricConfig {
            inter_rack_gbps: Some(0.25),
            ..fast_cfg()
        };
        let ov = topo.link_overrides(&cfg);
        assert!(ov.iter().all(|&(_, _, l)| l.bandwidth_gbps == 0.25));
        let f = fabric_for(&topo, &cfg);
        // Owner pair for (0,2): 1 → 6.
        assert_eq!(f.link_table().spec(1, 6).bandwidth_gbps, 0.25);
        // Leader pair 0 → 3 (also the (0,1) owner pair).
        assert_eq!(f.link_table().spec(0, 3).bandwidth_gbps, 0.25);
        // Intra-group links stay at base bandwidth.
        assert_eq!(f.link_table().spec(0, 1).bandwidth_gbps, 1.0);
        // Default uplink: 10:1 oversubscription.
        let f = fabric_for(&topo, &fast_cfg());
        assert_eq!(f.link_table().spec(0, 3).bandwidth_gbps, 0.1);
        // Single group ⇒ no overrides.
        assert!(Dragonfly::new(4, 1).link_overrides(&fast_cfg()).is_empty());
    }

    #[test]
    fn reduce_matches_sum_for_awkward_shapes() {
        for (p, g) in [(7usize, 3usize), (9, 3), (5, 5), (5, 1), (1, 1)] {
            let inputs: Vec<Vec<f32>> = (0..p)
                .map(|w| (0..6).map(|k| (w * 6 + k) as f32 * 0.5).collect())
                .collect();
            let topo = Dragonfly::new(p, g);
            let mut f = fabric_for(&topo, &fast_cfg());
            let res = topo.allreduce(&mut f, &inputs);
            for k in 0..6 {
                let want: f32 = inputs.iter().map(|v| v[k]).sum();
                for node in 0..p {
                    let got = res.reduced[node][k];
                    assert!(
                        (got - want).abs() < 1e-3,
                        "p={p} g={g} node={node} k={k}: {got} != {want}"
                    );
                }
            }
        }
    }

    #[test]
    fn spreading_ownership_beats_the_leader_funnel() {
        // Same spans, same uplink bandwidth: hier funnels every
        // cross-group block through the two leaders' NICs; dragonfly
        // spreads the crossings over the members. With a slow uplink
        // the dragonfly gather must finish no later.
        use crate::fabric::hierarchy::Hierarchy;
        let p = 12;
        let inputs: Vec<Vec<u8>> = (0..p).map(|_| vec![6u8; 10_000]).collect();
        let drag = Dragonfly::new(p, 4);
        let hier = Hierarchy::new(p, 4);
        let cfg = FabricConfig {
            inter_rack_gbps: Some(0.05),
            ..fast_cfg()
        };
        let mut fd = fabric_for(&drag, &cfg);
        let td = drag.allgatherv(&mut fd, &inputs).time_ps;
        let hier_cfg = FabricConfig {
            topology: TopologyKind::Hier { groups: 4 },
            ..cfg
        };
        let mut fh = Fabric::for_topology(&hier_cfg, &hier);
        let th = hier.allgatherv(&mut fh, &inputs).time_ps;
        assert!(
            td <= th,
            "dragonfly {td} ps slower than hier's leader funnel {th} ps"
        );
    }
}
