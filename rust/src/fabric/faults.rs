//! Deterministic fault injection: the chaos plan and its counters.
//!
//! A [`FaultPlan`] describes everything the fabric and trainer may
//! throw at a run: node crashes at a training step (with optional
//! rejoin after a delta), directed-link outage windows ("flaps",
//! microseconds relative to each collective's start), and per-link
//! random message drop/corruption rates. Plans parse from a compact
//! `--faults` spec, round-trip through JSON plan files, and are
//! validated like stragglers — a fault naming a node or edge the
//! fabric does not have is a config error, not a no-op. Everything
//! randomized draws from a dedicated fault RNG stream seeded from the
//! fabric seed, so a `(seed, plan)` pair replays bit-for-bit.
//!
//! Spec grammar (comma-separated entries):
//!
//! * `crash:N@S` — node `N` crashes at step `S` and never returns;
//! * `crash:N@S+D` — …and rejoins at step `S+D`;
//! * `flap:A-B@T1..T2` — the directed link `A → B` is down during
//!   `[T1, T2)` µs of every collective;
//! * `drop:A-B:R` — each message on `A → B` is lost with probability
//!   `R`;
//! * `corrupt:A-B:R` — …or delivered corrupted (and discarded by the
//!   receiver) with probability `R`.

use anyhow::{bail, ensure, Context, Result};

use crate::util::json::{num, obj, s, Json};

/// Ceiling on per-link drop + corruption probability: above this the
/// retransmit loop's geometric progress guarantee gets too weak to
/// bound simulation work.
pub const MAX_LOSS_RATE: f64 = 0.9;

/// A node crash at training step `at_step`; the node is dead for steps
/// `[at_step, rejoin_step)` and back from `rejoin_step` on (`None` =
/// never returns).
#[derive(Debug, Clone, PartialEq)]
pub struct Crash {
    pub node: usize,
    pub at_step: u64,
    pub rejoin_step: Option<u64>,
}

/// A directed-link outage window, µs relative to each collective's
/// start: messages whose transmission begins inside `[down_us, up_us)`
/// are lost and retransmitted after the link comes back.
#[derive(Debug, Clone, PartialEq)]
pub struct LinkFlap {
    pub src: usize,
    pub dst: usize,
    pub down_us: f64,
    pub up_us: f64,
}

/// Per-directed-link random loss: each message is dropped with
/// probability `drop`, else delivered corrupted (receiver discards it)
/// with probability `corrupt`.
#[derive(Debug, Clone, PartialEq)]
pub struct LinkChaos {
    pub src: usize,
    pub dst: usize,
    pub drop: f64,
    pub corrupt: f64,
}

/// The full fault schedule for a run. Empty (the default) is
/// guaranteed zero-cost: the fabric takes exactly the fault-free code
/// path and disturbs no RNG stream.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FaultPlan {
    pub crashes: Vec<Crash>,
    pub flaps: Vec<LinkFlap>,
    pub chaos: Vec<LinkChaos>,
}

impl FaultPlan {
    /// Parse the `--faults` spec grammar (see module docs). The empty
    /// string is the empty plan.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for entry in spec.split(',').map(str::trim).filter(|e| !e.is_empty()) {
            let (kind, rest) = entry
                .split_once(':')
                .with_context(|| format!("fault entry '{entry}' needs KIND:ARGS"))?;
            match kind {
                "crash" => {
                    let (node, when) = rest
                        .split_once('@')
                        .with_context(|| format!("crash '{entry}' needs NODE@STEP"))?;
                    let node: usize = node
                        .parse()
                        .map_err(|e| anyhow::anyhow!("crash node '{node}': {e}"))?;
                    let (step, delta) = match when.split_once('+') {
                        Some((st, d)) => (st, Some(d)),
                        None => (when, None),
                    };
                    let at_step: u64 = step
                        .parse()
                        .map_err(|e| anyhow::anyhow!("crash step '{step}': {e}"))?;
                    let rejoin_step = match delta {
                        None => None,
                        Some(d) => {
                            let d: u64 = d
                                .parse()
                                .map_err(|e| anyhow::anyhow!("crash rejoin delta '{d}': {e}"))?;
                            ensure!(d >= 1, "crash rejoin delta must be >= 1 in '{entry}'");
                            Some(at_step + d)
                        }
                    };
                    plan.crashes.push(Crash {
                        node,
                        at_step,
                        rejoin_step,
                    });
                }
                "flap" => {
                    let (edge, window) = rest
                        .split_once('@')
                        .with_context(|| format!("flap '{entry}' needs SRC-DST@T1..T2"))?;
                    let (src, dst) = parse_edge(edge)?;
                    let (t1, t2) = window
                        .split_once("..")
                        .with_context(|| format!("flap window '{window}' needs T1..T2"))?;
                    let down_us: f64 = t1
                        .parse()
                        .map_err(|e| anyhow::anyhow!("flap start '{t1}': {e}"))?;
                    let up_us: f64 = t2
                        .parse()
                        .map_err(|e| anyhow::anyhow!("flap end '{t2}': {e}"))?;
                    ensure!(
                        down_us >= 0.0 && up_us.is_finite() && up_us > down_us,
                        "flap window must satisfy 0 <= T1 < T2 in '{entry}'"
                    );
                    plan.flaps.push(LinkFlap {
                        src,
                        dst,
                        down_us,
                        up_us,
                    });
                }
                "drop" | "corrupt" => {
                    let (edge, rate) = rest
                        .rsplit_once(':')
                        .with_context(|| format!("{kind} '{entry}' needs SRC-DST:RATE"))?;
                    let (src, dst) = parse_edge(edge)?;
                    let rate: f64 = rate
                        .parse()
                        .map_err(|e| anyhow::anyhow!("{kind} rate '{rate}': {e}"))?;
                    ensure!(
                        rate > 0.0 && rate <= MAX_LOSS_RATE,
                        "{kind} rate must be in (0, {MAX_LOSS_RATE}] in '{entry}'"
                    );
                    let idx = match plan.chaos.iter().position(|c| c.src == src && c.dst == dst) {
                        Some(i) => i,
                        None => {
                            plan.chaos.push(LinkChaos {
                                src,
                                dst,
                                drop: 0.0,
                                corrupt: 0.0,
                            });
                            plan.chaos.len() - 1
                        }
                    };
                    let slot = &mut plan.chaos[idx];
                    if kind == "drop" {
                        slot.drop = rate;
                    } else {
                        slot.corrupt = rate;
                    }
                }
                other => bail!("unknown fault kind '{other}' in '{entry}'"),
            }
        }
        plan.validate_shape()?;
        Ok(plan)
    }

    /// The canonical spec string (parses back via [`FaultPlan::parse`]).
    pub fn spec_str(&self) -> String {
        let mut out: Vec<String> = Vec::new();
        for c in &self.crashes {
            match c.rejoin_step {
                None => out.push(format!("crash:{}@{}", c.node, c.at_step)),
                Some(r) => out.push(format!("crash:{}@{}+{}", c.node, c.at_step, r - c.at_step)),
            }
        }
        for f in &self.flaps {
            out.push(format!("flap:{}-{}@{}..{}", f.src, f.dst, f.down_us, f.up_us));
        }
        for c in &self.chaos {
            if c.drop > 0.0 {
                out.push(format!("drop:{}-{}:{}", c.src, c.dst, c.drop));
            }
            if c.corrupt > 0.0 {
                out.push(format!("corrupt:{}-{}:{}", c.src, c.dst, c.corrupt));
            }
        }
        out.join(",")
    }

    /// No faults at all.
    pub fn is_empty(&self) -> bool {
        self.crashes.is_empty() && self.flaps.is_empty() && self.chaos.is_empty()
    }

    /// No link-level faults (the part of the plan the transport layer
    /// handles; crashes are membership-level).
    pub fn link_faults_empty(&self) -> bool {
        self.flaps.is_empty() && self.chaos.is_empty()
    }

    /// Physical nodes dead for training step `step`, ascending and
    /// deduplicated.
    pub fn dead_at_step(&self, step: u64) -> Vec<usize> {
        let mut dead: Vec<usize> = self
            .crashes
            .iter()
            .filter(|c| {
                step >= c.at_step
                    && match c.rejoin_step {
                        Some(r) => step < r,
                        None => true,
                    }
            })
            .map(|c| c.node)
            .collect();
        dead.sort_unstable();
        dead.dedup();
        dead
    }

    /// Nodes that rejoin exactly at `step` (for residual-flush
    /// accounting under `--on-crash flush-rejoin`).
    pub fn rejoining_at_step(&self, step: u64) -> Vec<usize> {
        let mut back: Vec<usize> = self
            .crashes
            .iter()
            .filter(|c| c.rejoin_step == Some(step))
            .map(|c| c.node)
            .collect();
        back.sort_unstable();
        back.dedup();
        back
    }

    /// Internal consistency (rates, windows, orderings) — everything
    /// that does not need a node count.
    fn validate_shape(&self) -> Result<()> {
        for c in &self.crashes {
            if let Some(r) = c.rejoin_step {
                ensure!(
                    r > c.at_step,
                    "crash of node {} rejoins at step {r}, not after its crash step {}",
                    c.node,
                    c.at_step
                );
            }
        }
        for f in &self.flaps {
            ensure!(f.src != f.dst, "flap names the self-edge {}-{}", f.src, f.dst);
            ensure!(
                f.down_us >= 0.0 && f.up_us.is_finite() && f.up_us > f.down_us,
                "flap {}-{} window must satisfy 0 <= T1 < T2",
                f.src,
                f.dst
            );
        }
        for c in &self.chaos {
            ensure!(c.src != c.dst, "loss names the self-edge {}-{}", c.src, c.dst);
            ensure!(
                c.drop >= 0.0 && c.corrupt >= 0.0 && c.drop + c.corrupt <= MAX_LOSS_RATE,
                "combined drop+corrupt rate on {}-{} exceeds {MAX_LOSS_RATE}",
                c.src,
                c.dst
            );
        }
        Ok(())
    }

    /// Validate against a concrete fabric size, like stragglers: every
    /// fault must name nodes the fabric actually has.
    pub fn validate(&self, nodes: usize) -> Result<()> {
        self.validate_shape()?;
        for c in &self.crashes {
            ensure!(
                c.node < nodes,
                "crash node {} out of range (fabric has {nodes} nodes)",
                c.node
            );
        }
        for f in &self.flaps {
            ensure!(
                f.src < nodes && f.dst < nodes,
                "flap edge {}-{} out of range (fabric has {nodes} nodes)",
                f.src,
                f.dst
            );
        }
        for c in &self.chaos {
            ensure!(
                c.src < nodes && c.dst < nodes,
                "loss edge {}-{} out of range (fabric has {nodes} nodes)",
                c.src,
                c.dst
            );
        }
        Ok(())
    }

    /// Structured JSON for plan files (round-trips via
    /// [`FaultPlan::from_json`]).
    pub fn to_json(&self) -> Json {
        obj(vec![
            (
                "crashes",
                Json::Arr(
                    self.crashes
                        .iter()
                        .map(|c| {
                            obj(vec![
                                ("node", num(c.node as f64)),
                                ("at_step", num(c.at_step as f64)),
                                (
                                    "rejoin_step",
                                    c.rejoin_step.map(|r| num(r as f64)).unwrap_or(Json::Null),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "flaps",
                Json::Arr(
                    self.flaps
                        .iter()
                        .map(|f| {
                            obj(vec![
                                ("src", num(f.src as f64)),
                                ("dst", num(f.dst as f64)),
                                ("down_us", num(f.down_us)),
                                ("up_us", num(f.up_us)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "chaos",
                Json::Arr(
                    self.chaos
                        .iter()
                        .map(|c| {
                            obj(vec![
                                ("src", num(c.src as f64)),
                                ("dst", num(c.dst as f64)),
                                ("drop", num(c.drop)),
                                ("corrupt", num(c.corrupt)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Load a plan file: either the structured object written by
    /// [`FaultPlan::to_json`] or a plain `"spec string"`.
    pub fn from_json(j: &Json) -> Result<FaultPlan> {
        if let Json::Str(spec) = j {
            return FaultPlan::parse(spec);
        }
        let mut plan = FaultPlan::default();
        if let Some(arr) = j.get("crashes") {
            for c in expect_arr(arr, "crashes")? {
                plan.crashes.push(Crash {
                    node: c.expect("node")?.as_usize()?,
                    at_step: c.expect("at_step")?.as_usize()? as u64,
                    rejoin_step: match c.get("rejoin_step") {
                        None | Some(Json::Null) => None,
                        Some(v) => Some(v.as_usize()? as u64),
                    },
                });
            }
        }
        if let Some(arr) = j.get("flaps") {
            for f in expect_arr(arr, "flaps")? {
                plan.flaps.push(LinkFlap {
                    src: f.expect("src")?.as_usize()?,
                    dst: f.expect("dst")?.as_usize()?,
                    down_us: f.expect("down_us")?.as_f64()?,
                    up_us: f.expect("up_us")?.as_f64()?,
                });
            }
        }
        if let Some(arr) = j.get("chaos") {
            for c in expect_arr(arr, "chaos")? {
                plan.chaos.push(LinkChaos {
                    src: c.expect("src")?.as_usize()?,
                    dst: c.expect("dst")?.as_usize()?,
                    drop: c.expect("drop")?.as_f64()?,
                    corrupt: c.expect("corrupt")?.as_f64()?,
                });
            }
        }
        plan.validate_shape()?;
        Ok(plan)
    }
}

fn parse_edge(edge: &str) -> Result<(usize, usize)> {
    let (a, b) = edge
        .split_once('-')
        .with_context(|| format!("edge '{edge}' needs SRC-DST"))?;
    let src: usize = a
        .parse()
        .map_err(|e| anyhow::anyhow!("edge src '{a}': {e}"))?;
    let dst: usize = b
        .parse()
        .map_err(|e| anyhow::anyhow!("edge dst '{b}': {e}"))?;
    ensure!(src != dst, "edge '{edge}' is a self-edge");
    Ok((src, dst))
}

fn expect_arr<'j>(j: &'j Json, what: &str) -> Result<&'j [Json]> {
    match j {
        Json::Arr(v) => Ok(v),
        other => bail!("fault plan key '{what}' must be an array, got {other:?}"),
    }
}

/// Counters for everything the fault layer did during a run: how much
/// chaos was injected and how much work masking it cost.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FabricReport {
    /// Messages lost outright (flap windows + random drops).
    pub drops: u64,
    /// Messages delivered corrupted and discarded by the receiver.
    pub corruptions: u64,
    /// Retransmission attempts issued.
    pub retries: u64,
    /// Bytes re-pushed onto egress ports by retransmissions.
    pub retransmitted_bytes: u64,
    /// Collective-level route-arounds (degraded-topology rebuilds
    /// after node loss).
    pub reroutes: u64,
}

impl FabricReport {
    /// Accumulate another report (per-step reports into a run total).
    pub fn absorb(&mut self, other: &FabricReport) {
        self.drops += other.drops;
        self.corruptions += other.corruptions;
        self.retries += other.retries;
        self.retransmitted_bytes += other.retransmitted_bytes;
        self.reroutes += other.reroutes;
    }

    /// True when nothing at all happened (the fault-free fingerprint).
    pub fn is_clean(&self) -> bool {
        *self == FabricReport::default()
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("drops", num(self.drops as f64)),
            ("corruptions", num(self.corruptions as f64)),
            ("retries", num(self.retries as f64)),
            ("retransmitted_bytes", num(self.retransmitted_bytes as f64)),
            ("reroutes", num(self.reroutes as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_round_trips_through_parse_and_str() {
        let spec = "crash:1@3+2,crash:4@10,flap:0-1@10..50,drop:0-2:0.2,corrupt:2-0:0.05";
        let plan = FaultPlan::parse(spec).unwrap();
        assert_eq!(plan.crashes.len(), 2);
        assert_eq!(plan.crashes[0].rejoin_step, Some(5));
        assert_eq!(plan.crashes[1].rejoin_step, None);
        assert_eq!(plan.flaps.len(), 1);
        assert_eq!(plan.chaos.len(), 2);
        let back = FaultPlan::parse(&plan.spec_str()).unwrap();
        assert_eq!(back, plan);
    }

    #[test]
    fn drop_and_corrupt_on_one_edge_merge() {
        let plan = FaultPlan::parse("drop:0-1:0.2,corrupt:0-1:0.1").unwrap();
        assert_eq!(plan.chaos.len(), 1);
        assert_eq!(plan.chaos[0].drop, 0.2);
        assert_eq!(plan.chaos[0].corrupt, 0.1);
        assert_eq!(FaultPlan::parse(&plan.spec_str()).unwrap(), plan);
    }

    #[test]
    fn bad_specs_are_loud() {
        assert!(FaultPlan::parse("crash:1").is_err()); // no step
        assert!(FaultPlan::parse("crash:1@3+0").is_err()); // zero delta
        assert!(FaultPlan::parse("flap:0-0@1..2").is_err()); // self-edge
        assert!(FaultPlan::parse("flap:0-1@5..5").is_err()); // empty window
        assert!(FaultPlan::parse("drop:0-1:0.99").is_err()); // above ceiling
        assert!(FaultPlan::parse("drop:0-1:0.5,corrupt:0-1:0.5").is_err()); // combined
        assert!(FaultPlan::parse("meteor:0-1:1").is_err()); // unknown kind
        assert!(FaultPlan::parse("").unwrap().is_empty());
    }

    #[test]
    fn validate_checks_node_ranges() {
        let plan = FaultPlan::parse("crash:5@1,drop:0-1:0.1").unwrap();
        assert!(plan.validate(6).is_ok());
        let err = plan.validate(4).unwrap_err().to_string();
        assert!(err.contains("out of range"), "{err}");
    }

    #[test]
    fn membership_windows() {
        let plan = FaultPlan::parse("crash:1@3+2,crash:2@4").unwrap();
        assert!(plan.dead_at_step(2).is_empty());
        assert_eq!(plan.dead_at_step(3), vec![1]);
        assert_eq!(plan.dead_at_step(4), vec![1, 2]);
        assert_eq!(plan.dead_at_step(5), vec![2]); // node 1 rejoined
        assert_eq!(plan.rejoining_at_step(5), vec![1]);
        assert!(plan.rejoining_at_step(4).is_empty());
    }

    #[test]
    fn json_round_trips_both_shapes() {
        let plan = FaultPlan::parse("crash:1@3+2,flap:0-1@10..50,drop:0-2:0.2").unwrap();
        let j = Json::parse(&plan.to_json().to_string()).unwrap();
        assert_eq!(FaultPlan::from_json(&j).unwrap(), plan);
        // A bare spec string is also a valid plan file body.
        let j = Json::parse("\"crash:1@3+2\"").unwrap();
        assert_eq!(
            FaultPlan::from_json(&j).unwrap(),
            FaultPlan::parse("crash:1@3+2").unwrap()
        );
    }

    #[test]
    fn report_absorbs_and_fingerprints() {
        let mut total = FabricReport::default();
        assert!(total.is_clean());
        total.absorb(&FabricReport {
            drops: 2,
            retries: 3,
            retransmitted_bytes: 100,
            ..FabricReport::default()
        });
        total.absorb(&FabricReport {
            corruptions: 1,
            reroutes: 1,
            ..FabricReport::default()
        });
        assert!(!total.is_clean());
        assert_eq!(total.drops, 2);
        assert_eq!(total.retries, 3);
        let j = total.to_json();
        assert_eq!(j.get("retransmitted_bytes").unwrap().as_usize().unwrap(), 100);
    }
}
