//! 2-level hierarchical topology: workers grouped under leaders
//! (think rack-local aggregation), leaders fully connected.
//!
//! Group `g` spans workers `[g·b, min((g+1)·b, p))` for branch factor
//! `b`; the lowest id in each group is its leader (leaders are
//! themselves workers — no extra infrastructure node). Blocks flow
//! member → leader → other leaders → their members (segment-wise when
//! the fabric configures gather segmentation), so cross-group traffic
//! crosses each leader pair exactly once per block — the bandwidth
//! hierarchy a flat ring or mesh cannot express. For the
//! group-count-parameterized variant with *slow inter-group uplinks*
//! see [`super::hierarchy`].
//!
//! Degenerate branches recover the other topologies: `b = 1` is a full
//! mesh over all workers; `b ≥ p` is a single star with worker 0 as
//! hub.

use super::collectives::{split_all, traffic_from, GatherState, SimGather, SimReduce};
use super::topology::{Topology, TopologyKind};
use super::{Fabric, Msg, Payload, Protocol};

/// Member block/vector travelling up to its leader.
const TAG_UP: u8 = 0;
/// Leader-to-leader exchange.
const TAG_XCHG: u8 = 1;
/// Leader fan-out down to its members.
const TAG_DOWN: u8 = 2;

pub struct Tree {
    p: usize,
    branch: usize,
}

impl Tree {
    pub fn new(workers: usize, branch: usize) -> Tree {
        assert!(workers > 0, "topology needs at least one worker");
        assert!(branch >= 1, "tree branch must be >= 1");
        Tree { p: workers, branch }
    }

    fn leader_of(&self, w: usize) -> usize {
        (w / self.branch) * self.branch
    }

    fn is_leader(&self, w: usize) -> bool {
        w % self.branch == 0
    }

    fn leaders(&self) -> Vec<usize> {
        (0..self.p).step_by(self.branch).collect()
    }

    /// Members of `leader`'s group, excluding the leader itself.
    fn members(&self, leader: usize) -> Vec<usize> {
        (leader + 1..(leader + self.branch).min(self.p)).collect()
    }
}

struct TreeGather<'t> {
    t: &'t Tree,
    segs: Vec<Vec<Vec<u8>>>,
    state: GatherState,
}

impl TreeGather<'_> {
    fn msg(&self, origin: usize, seg: u32, hop: u32, tag: u8, payload: &Payload) -> Msg {
        Msg {
            origin,
            seg,
            hop,
            tag,
            payload: payload.clone(),
        }
    }
}

impl Protocol for TreeGather<'_> {
    fn start(&mut self) -> Vec<(usize, usize, Msg)> {
        let mut out = Vec::new();
        for w in 0..self.t.p {
            for (si, sg) in self.segs[w].iter().enumerate() {
                let si = si as u32;
                let payload = Payload::Bytes(sg.clone());
                if self.t.is_leader(w) {
                    for l in self.t.leaders() {
                        if l != w {
                            out.push((w, l, self.msg(w, si, 1, TAG_XCHG, &payload)));
                        }
                    }
                    for m in self.t.members(w) {
                        out.push((w, m, self.msg(w, si, 1, TAG_DOWN, &payload)));
                    }
                } else {
                    out.push((w, self.t.leader_of(w), self.msg(w, si, 1, TAG_UP, &payload)));
                }
            }
        }
        out
    }

    fn on_deliver(&mut self, node: usize, msg: &Msg) -> Vec<(usize, Msg)> {
        let Payload::Bytes(b) = &msg.payload else {
            unreachable!("gather protocol only moves bytes")
        };
        self.state.store(node, msg.origin, msg.seg as usize, b);
        if !self.t.is_leader(node) {
            return Vec::new();
        }
        let mut out = Vec::new();
        match msg.tag {
            TAG_UP => {
                // A member segment: cross to the other leaders and to
                // the rest of this group.
                for l in self.t.leaders() {
                    if l != node {
                        out.push((
                            l,
                            self.msg(msg.origin, msg.seg, msg.hop + 1, TAG_XCHG, &msg.payload),
                        ));
                    }
                }
                for m in self.t.members(node) {
                    if m != msg.origin {
                        out.push((
                            m,
                            self.msg(msg.origin, msg.seg, msg.hop + 1, TAG_DOWN, &msg.payload),
                        ));
                    }
                }
            }
            TAG_XCHG => {
                // Another group's segment: fan down to this group.
                for m in self.t.members(node) {
                    out.push((
                        m,
                        self.msg(msg.origin, msg.seg, msg.hop + 1, TAG_DOWN, &msg.payload),
                    ));
                }
            }
            other => unreachable!("leader received unexpected tag {other}"),
        }
        out
    }
}

struct TreeReduce<'t> {
    t: &'t Tree,
    n: usize,
    inputs: Vec<Vec<f32>>,
    /// Member vectors buffered at leaders, by worker id.
    up: Vec<Option<Vec<f32>>>,
    /// Group partials buffered at every leader, by leader id.
    partials: Vec<Vec<Option<Vec<f32>>>>,
    /// Final sums as seen by each worker.
    totals: Vec<Option<Vec<f32>>>,
}

impl TreeReduce<'_> {
    /// Sum this leader's group (leader + members, ascending id).
    fn group_partial(&self, leader: usize) -> Vec<f32> {
        let mut sum = self.inputs[leader].clone();
        for m in self.t.members(leader) {
            let v = self.up[m].as_ref().expect("member vector missing");
            for (k, x) in v.iter().enumerate() {
                sum[k] += x;
            }
        }
        sum
    }

    /// Once a leader holds every group partial, the grand total
    /// (ascending leader order) and the fan-out sends.
    fn try_finish(&mut self, leader: usize, hop: u32) -> Vec<(usize, Msg)> {
        let leaders = self.t.leaders();
        if leaders.iter().any(|&l| self.partials[leader][l].is_none()) {
            return Vec::new();
        }
        let mut total = vec![0.0f32; self.n];
        for &l in &leaders {
            let v = self.partials[leader][l].as_ref().unwrap();
            for (k, x) in v.iter().enumerate() {
                total[k] += x;
            }
        }
        self.totals[leader] = Some(total.clone());
        let payload = Payload::F32(total);
        self.t
            .members(leader)
            .into_iter()
            .map(|m| {
                (
                    m,
                    Msg {
                        origin: leader,
                        seg: 0,
                        hop,
                        tag: TAG_DOWN,
                        payload: payload.clone(),
                    },
                )
            })
            .collect()
    }

    /// Leader's own group is complete: record the partial, exchange it,
    /// and possibly finish (single-leader trees finish immediately).
    fn group_ready(&mut self, leader: usize, hop: u32) -> Vec<(usize, Msg)> {
        let partial = self.group_partial(leader);
        self.partials[leader][leader] = Some(partial.clone());
        let payload = Payload::F32(partial);
        let mut out: Vec<(usize, Msg)> = self
            .t
            .leaders()
            .into_iter()
            .filter(|&l| l != leader)
            .map(|l| {
                (
                    l,
                    Msg {
                        origin: leader,
                        seg: 0,
                        hop,
                        tag: TAG_XCHG,
                        payload: payload.clone(),
                    },
                )
            })
            .collect();
        out.extend(self.try_finish(leader, hop + 1));
        out
    }
}

impl Protocol for TreeReduce<'_> {
    fn start(&mut self) -> Vec<(usize, usize, Msg)> {
        let mut out = Vec::new();
        for w in 0..self.t.p {
            if !self.t.is_leader(w) {
                out.push((
                    w,
                    self.t.leader_of(w),
                    Msg {
                        origin: w,
                        seg: 0,
                        hop: 1,
                        tag: TAG_UP,
                        payload: Payload::F32(self.inputs[w].clone()),
                    },
                ));
            }
        }
        // Leaders whose whole group is themselves are ready at t = 0.
        for l in self.t.leaders() {
            if self.t.members(l).is_empty() {
                for (dst, msg) in self.group_ready(l, 1) {
                    out.push((l, dst, msg));
                }
            }
        }
        out
    }

    fn on_deliver(&mut self, node: usize, msg: &Msg) -> Vec<(usize, Msg)> {
        let Payload::F32(v) = &msg.payload else {
            unreachable!("reduce protocol only moves f32 vectors")
        };
        match msg.tag {
            TAG_UP => {
                self.up[msg.origin] = Some(v.clone());
                let complete = self
                    .t
                    .members(node)
                    .iter()
                    .all(|&m| self.up[m].is_some());
                if complete {
                    self.group_ready(node, msg.hop + 1)
                } else {
                    Vec::new()
                }
            }
            TAG_XCHG => {
                self.partials[node][msg.origin] = Some(v.clone());
                self.try_finish(node, msg.hop + 1)
            }
            TAG_DOWN => {
                self.totals[node] = Some(v.clone());
                Vec::new()
            }
            other => unreachable!("unknown tree reduce tag {other}"),
        }
    }
}

impl Topology for Tree {
    fn kind(&self) -> TopologyKind {
        TopologyKind::Tree {
            branch: self.branch,
        }
    }

    fn workers(&self) -> usize {
        self.p
    }

    fn gather_rounds(&self) -> u32 {
        if self.p > 1 {
            3
        } else {
            0
        }
    }

    fn reduce_rounds(&self) -> u32 {
        if self.p > 1 {
            3
        } else {
            0
        }
    }

    fn allgatherv(&self, fabric: &mut Fabric, inputs: &[Vec<u8>]) -> SimGather {
        assert_eq!(inputs.len(), self.p, "one input message per worker");
        let seg = fabric.segment_bytes();
        let mut proto = TreeGather {
            t: self,
            segs: split_all(inputs, seg),
            state: GatherState::new(inputs, seg),
        };
        let time_ps = if self.p > 1 { fabric.run(&mut proto) } else { 0 };
        SimGather {
            gathered: proto.state.into_gathered(),
            traffic: traffic_from(fabric, self.gather_rounds()),
            time_ps,
            events: fabric.events(),
        }
    }

    fn allreduce(&self, fabric: &mut Fabric, inputs: &[Vec<f32>]) -> SimReduce {
        assert_eq!(inputs.len(), self.p);
        let n = inputs[0].len();
        assert!(inputs.iter().all(|v| v.len() == n), "length mismatch");
        let mut proto = TreeReduce {
            t: self,
            n,
            inputs: inputs.to_vec(),
            up: vec![None; self.p],
            partials: vec![vec![None; self.p]; self.p],
            totals: vec![None; self.p],
        };
        let time_ps = if self.p > 1 { fabric.run(&mut proto) } else { 0 };
        let reduced: Vec<Vec<f32>> = if self.p == 1 {
            vec![inputs[0].clone()]
        } else {
            proto
                .totals
                .iter()
                .map(|slot| slot.clone().expect("tree reduce under-delivered"))
                .collect()
        };
        SimReduce {
            reduced,
            traffic: traffic_from(fabric, self.reduce_rounds()),
            time_ps,
            events: fabric.events(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{FabricConfig, LinkSpec};

    fn fabric(nodes: usize) -> Fabric {
        Fabric::for_config(
            &FabricConfig {
                link: LinkSpec {
                    bandwidth_gbps: 1.0,
                    latency_us: 1.0,
                    jitter_us: 0.0,
                },
                ..FabricConfig::default()
            },
            nodes,
        )
    }

    #[test]
    fn grouping_math() {
        let t = Tree::new(10, 4);
        assert_eq!(t.leaders(), vec![0, 4, 8]);
        assert_eq!(t.leader_of(5), 4);
        assert_eq!(t.members(8), vec![9]);
        assert_eq!(t.members(0), vec![1, 2, 3]);
    }

    #[test]
    fn gather_delivers_across_groups() {
        for (p, b) in [(7usize, 3usize), (8, 4), (5, 1), (3, 8), (2, 2)] {
            let inputs: Vec<Vec<u8>> =
                (0..p).map(|w| vec![w as u8 + 1; (w * 13) % 29 + 1]).collect();
            let topo = Tree::new(p, b);
            let mut f = fabric(topo.node_count());
            let res = topo.allgatherv(&mut f, &inputs);
            for dst in 0..p {
                for src in 0..p {
                    assert_eq!(
                        res.gathered[dst][src], inputs[src],
                        "p={p} b={b} dst={dst} src={src}"
                    );
                }
            }
        }
    }

    #[test]
    fn reduce_matches_sum_for_awkward_shapes() {
        for (p, b) in [(7usize, 3usize), (4, 2), (5, 1), (3, 8), (1, 4)] {
            let inputs: Vec<Vec<f32>> = (0..p)
                .map(|w| (0..6).map(|k| (w * 6 + k) as f32 * 0.5).collect())
                .collect();
            let topo = Tree::new(p, b);
            let mut f = fabric(topo.node_count());
            let res = topo.allreduce(&mut f, &inputs);
            for k in 0..6 {
                let want: f32 = inputs.iter().map(|v| v[k]).sum();
                for node in 0..p {
                    let got = res.reduced[node][k];
                    assert!(
                        (got - want).abs() < 1e-3,
                        "p={p} b={b} node={node} k={k}: {got} != {want}"
                    );
                }
            }
        }
    }

    #[test]
    fn cross_group_traffic_crosses_leader_links_once_per_block() {
        // 4 workers, branch 2: groups {0,1} and {2,3}. Worker 1's block
        // must cross the 0→2 leader link exactly once.
        let inputs: Vec<Vec<u8>> = (0..4).map(|w| vec![w as u8; 100]).collect();
        let topo = Tree::new(4, 2);
        let mut f = fabric(topo.node_count());
        let res = topo.allgatherv(&mut f, &inputs);
        assert_eq!(res.traffic.rounds, 3);
        // Leader 0 sends: its own block to {1, 2}, member 1's block to
        // {2}, and group 2's two blocks down to {1} → 5 sends.
        assert_eq!(f.links()[&(0, 2)].messages, 2); // blocks 0 and 1 cross once each
        assert_eq!(f.links()[&(2, 0)].messages, 2); // blocks 2 and 3 likewise
    }
}
