//! 2-level hierarchical topology: workers grouped under leaders
//! (think rack-local aggregation), leaders fully connected.
//!
//! Group `g` spans workers `[g·b, min((g+1)·b, p))` for branch factor
//! `b`; the lowest id in each group is its leader (leaders are
//! themselves workers — no extra infrastructure node). Blocks flow
//! member → leader → other leaders → their members (segment-wise when
//! the fabric configures gather segmentation), so cross-group traffic
//! crosses each leader pair exactly once per block — the bandwidth
//! hierarchy a flat ring or mesh cannot express. The protocol itself
//! lives in `fabric::groups`, shared with the group-count-
//! parameterized variant (see [`super::hierarchy`]) that adds *slow
//! inter-group uplinks*.
//!
//! Degenerate branches recover the other topologies: `b = 1` is a full
//! mesh over all workers; `b ≥ p` is a single star with worker 0 as
//! hub.

use super::collectives::{traffic_from, SimGather, SimReduce};
use super::groups::{GroupGather, GroupReduce, GroupSpans};
use super::topology::{Topology, TopologyKind};
use super::Fabric;

pub struct Tree {
    p: usize,
    branch: usize,
    spans: GroupSpans,
}

impl Tree {
    pub fn new(workers: usize, branch: usize) -> Tree {
        assert!(workers > 0, "topology needs at least one worker");
        assert!(branch >= 1, "tree branch must be >= 1");
        Tree {
            p: workers,
            branch,
            spans: GroupSpans::from_branch(workers, branch),
        }
    }

    fn leader_of(&self, w: usize) -> usize {
        self.spans.leader(self.spans.group_of(w))
    }

    fn leaders(&self) -> Vec<usize> {
        self.spans.leaders()
    }

    /// Members of `leader`'s group, excluding the leader itself.
    fn members(&self, leader: usize) -> Vec<usize> {
        self.spans.members(self.spans.group_of(leader))
    }
}

impl Topology for Tree {
    fn kind(&self) -> TopologyKind {
        TopologyKind::Tree {
            branch: self.branch,
        }
    }

    fn workers(&self) -> usize {
        self.p
    }

    fn gather_rounds(&self) -> u32 {
        if self.p > 1 {
            3
        } else {
            0
        }
    }

    fn reduce_rounds(&self) -> u32 {
        if self.p > 1 {
            3
        } else {
            0
        }
    }

    fn allgatherv(&self, fabric: &mut Fabric, inputs: &[Vec<u8>]) -> SimGather {
        assert_eq!(inputs.len(), self.p, "one input message per worker");
        let seg = fabric.segment_bytes();
        let mut proto = GroupGather::new(&self.spans, inputs, seg);
        let time_ps = if self.p > 1 { fabric.run(&mut proto) } else { 0 };
        SimGather {
            gathered: proto.into_gathered(),
            traffic: traffic_from(fabric, self.gather_rounds()),
            time_ps,
            events: fabric.events(),
        }
    }

    fn allgatherv_sized(&self, fabric: &mut Fabric, sizes: &[u64]) -> SimGather {
        assert_eq!(sizes.len(), self.p, "one size per worker");
        let seg = fabric.segment_bytes();
        let mut proto = GroupGather::sized(&self.spans, sizes, seg);
        let time_ps = if self.p > 1 { fabric.run(&mut proto) } else { 0 };
        SimGather {
            gathered: proto.into_gathered(),
            traffic: traffic_from(fabric, self.gather_rounds()),
            time_ps,
            events: fabric.events(),
        }
    }

    fn allreduce(&self, fabric: &mut Fabric, inputs: &[Vec<f32>]) -> SimReduce {
        assert_eq!(inputs.len(), self.p);
        let n = inputs[0].len();
        assert!(inputs.iter().all(|v| v.len() == n), "length mismatch");
        let mut proto = GroupReduce::new(&self.spans, inputs);
        let time_ps = if self.p > 1 { fabric.run(&mut proto) } else { 0 };
        let reduced: Vec<Vec<f32>> = if self.p == 1 {
            vec![inputs[0].clone()]
        } else {
            proto.into_totals()
        };
        SimReduce {
            reduced,
            traffic: traffic_from(fabric, self.reduce_rounds()),
            time_ps,
            events: fabric.events(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{FabricConfig, LinkSpec};

    fn fabric(nodes: usize) -> Fabric {
        Fabric::for_config(
            &FabricConfig {
                link: LinkSpec {
                    bandwidth_gbps: 1.0,
                    latency_us: 1.0,
                    jitter_us: 0.0,
                },
                ..FabricConfig::default()
            },
            nodes,
        )
    }

    #[test]
    fn grouping_math() {
        let t = Tree::new(10, 4);
        assert_eq!(t.leaders(), vec![0, 4, 8]);
        assert_eq!(t.leader_of(5), 4);
        assert_eq!(t.members(8), vec![9]);
        assert_eq!(t.members(0), vec![1, 2, 3]);
    }

    #[test]
    fn gather_delivers_across_groups() {
        for (p, b) in [(7usize, 3usize), (8, 4), (5, 1), (3, 8), (2, 2)] {
            let inputs: Vec<Vec<u8>> =
                (0..p).map(|w| vec![w as u8 + 1; (w * 13) % 29 + 1]).collect();
            let topo = Tree::new(p, b);
            let mut f = fabric(topo.node_count());
            let res = topo.allgatherv(&mut f, &inputs);
            for dst in 0..p {
                for src in 0..p {
                    assert_eq!(
                        res.gathered[dst][src], inputs[src],
                        "p={p} b={b} dst={dst} src={src}"
                    );
                }
            }
        }
    }

    #[test]
    fn reduce_matches_sum_for_awkward_shapes() {
        for (p, b) in [(7usize, 3usize), (4, 2), (5, 1), (3, 8), (1, 4)] {
            let inputs: Vec<Vec<f32>> = (0..p)
                .map(|w| (0..6).map(|k| (w * 6 + k) as f32 * 0.5).collect())
                .collect();
            let topo = Tree::new(p, b);
            let mut f = fabric(topo.node_count());
            let res = topo.allreduce(&mut f, &inputs);
            for k in 0..6 {
                let want: f32 = inputs.iter().map(|v| v[k]).sum();
                for node in 0..p {
                    let got = res.reduced[node][k];
                    assert!(
                        (got - want).abs() < 1e-3,
                        "p={p} b={b} node={node} k={k}: {got} != {want}"
                    );
                }
            }
        }
    }

    #[test]
    fn cross_group_traffic_crosses_leader_links_once_per_block() {
        // 4 workers, branch 2: groups {0,1} and {2,3}. Worker 1's block
        // must cross the 0→2 leader link exactly once.
        let inputs: Vec<Vec<u8>> = (0..4).map(|w| vec![w as u8; 100]).collect();
        let topo = Tree::new(4, 2);
        let mut f = fabric(topo.node_count());
        let res = topo.allgatherv(&mut f, &inputs);
        assert_eq!(res.traffic.rounds, 3);
        // Leader 0 sends: its own block to {1, 2}, member 1's block to
        // {2}, and group 2's two blocks down to {1} → 5 sends.
        assert_eq!(f.links()[&(0, 2)].messages, 2); // blocks 0 and 1 cross once each
        assert_eq!(f.links()[&(2, 0)].messages, 2); // blocks 2 and 3 likewise
    }
}
