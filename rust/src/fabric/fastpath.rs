//! Closed-form fast path for uniform gather phases.
//!
//! A "uniform phase" — every link at the default spec, no jitter, no
//! chaos plan, no stragglers, no segmentation, no degraded rank map,
//! trace recording off ([`Fabric::full_loop_reason`] returns `None`)
//! — makes every hop's timing a pure function of (egress-free,
//! ingress-free, ready), which is exactly what
//! `Fabric::wire_fast` computes. For the topologies whose send
//! schedule is statically known (ring and full mesh), the whole
//! collective can then be *replayed* send-by-send in dependency order
//! without scheduling a single clock event: identical port-state
//! arithmetic in identical per-port order produces bit-identical
//! traffic counters and a tick-identical finish time (property-tested
//! in `tests/scale_parity.rs`), at a fraction of the event loop's
//! constant factor.
//!
//! Why dependency-order replay is exact: the engine reads and updates
//! both port cursors at *send-call* time, and each ring/mesh port is
//! touched by exactly one sender whose sends occur in round order in
//! both schemes — so per-port operation sequences coincide even
//! though the event loop interleaves rounds across nodes. Topologies
//! with data-dependent schedules (leader fan-outs keyed on arrival
//! completion) and any non-uniform phase fall back to the full event
//! loop via [`Topology::allgatherv_sized`] — same results, full
//! generality. Forcing the event loop for a parity check is just
//! calling `allgatherv_sized` directly.

use super::collectives::{traffic_from, SimGather};
use super::topology::{Topology, TopologyKind};
use super::Fabric;

/// Which tier actually ran a sized gather.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Engine {
    /// Closed-form replay — no clock events scheduled.
    Closed,
    /// The full event loop.
    Event,
}

impl Engine {
    /// Stable lowercase name for reports (`BENCH_scale.json`).
    pub fn label(&self) -> &'static str {
        match self {
            Engine::Closed => "closed",
            Engine::Event => "event",
        }
    }
}

/// Run a sized (phantom-payload) allgatherv through the fastest exact
/// engine: the closed-form tier when the fabric is a uniform phase and
/// the topology's schedule is statically known, the event loop
/// otherwise. Results are identical either way — the engine choice is
/// an optimization, never an approximation.
pub fn gather_sized(
    topo: &dyn Topology,
    fabric: &mut Fabric,
    sizes: &[u64],
) -> (SimGather, Engine) {
    assert_eq!(
        sizes.len(),
        topo.workers(),
        "one size per worker for a sized gather"
    );
    if fabric.full_loop_reason().is_none() {
        match topo.kind() {
            TopologyKind::Ring => {
                return (closed_ring(fabric, sizes, topo.gather_rounds()), Engine::Closed)
            }
            TopologyKind::Full => {
                return (closed_mesh(fabric, sizes, topo.gather_rounds()), Engine::Closed)
            }
            _ => {}
        }
    }
    (topo.allgatherv_sized(fabric, sizes), Engine::Event)
}

/// Ring circulation replayed round-major: round 0 sends every block
/// one hop right at `t0`; round `k` forwards the block each node
/// received in round `k − 1` the moment it landed. Per-node port
/// cursors see the same operation sequence as the event loop.
fn closed_ring(fabric: &mut Fabric, sizes: &[u64], rounds: u32) -> SimGather {
    let p = sizes.len();
    if p < 2 {
        return SimGather {
            gathered: Vec::new(),
            traffic: traffic_from(fabric, rounds),
            time_ps: 0,
            events: fabric.events(),
        };
    }
    let t0 = fabric.now();
    let mut finish = t0;
    // arr[v]: when the block v forwards next round became available
    // at v (round 0: its own block, ready at t0).
    let mut arr = vec![t0; p];
    let mut next = vec![t0; p];
    for k in 0..p - 1 {
        for (v, &ready) in arr.iter().enumerate() {
            // v forwards the block that originated k hops behind it.
            let origin = (v + p - k) % p;
            let d = fabric.wire_fast(v, (v + 1) % p, sizes[origin], ready);
            next[(v + 1) % p] = d;
            finish = finish.max(d);
        }
        std::mem::swap(&mut arr, &mut next);
    }
    let events = (p * (p - 1)) as u64;
    fabric.fast_forward(finish, events);
    SimGather {
        gathered: Vec::new(),
        traffic: traffic_from(fabric, rounds),
        time_ps: fabric.now(),
        events: fabric.events(),
    }
}

/// Full-mesh gather replayed in `start()` order (sender-major): every
/// send is ready at `t0`; contention is purely the sender's egress and
/// the receiver's ingress cursors.
fn closed_mesh(fabric: &mut Fabric, sizes: &[u64], rounds: u32) -> SimGather {
    let p = sizes.len();
    let t0 = fabric.now();
    let mut finish = t0;
    let mut events = 0u64;
    for w in 0..p {
        for v in 0..p {
            if v != w {
                let d = fabric.wire_fast(w, v, sizes[w], t0);
                finish = finish.max(d);
                events += 1;
            }
        }
    }
    fabric.fast_forward(finish, events);
    SimGather {
        gathered: Vec::new(),
        traffic: traffic_from(fabric, rounds),
        time_ps: fabric.now(),
        events: fabric.events(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::topology::{build_topology, FullMesh};
    use crate::fabric::{FabricConfig, LinkSpec};

    fn quiet_cfg() -> FabricConfig {
        FabricConfig {
            link: LinkSpec {
                bandwidth_gbps: 1.0,
                latency_us: 1.0,
                jitter_us: 0.0,
            },
            ..FabricConfig::default()
        }
    }

    fn quiet_fabric(nodes: usize) -> Fabric {
        let mut f = Fabric::for_config(&quiet_cfg(), nodes);
        f.set_trace(false);
        f
    }

    fn sizes_for(p: usize) -> Vec<u64> {
        (0..p).map(|w| ((w * 37) % 900 + 100) as u64).collect()
    }

    #[test]
    fn closed_ring_is_tick_identical_to_the_event_loop() {
        for p in [2usize, 3, 5, 8, 13] {
            let topo = build_topology(TopologyKind::Ring, p);
            let sizes = sizes_for(p);
            let mut fc = quiet_fabric(p);
            let (closed, engine) = gather_sized(&*topo, &mut fc, &sizes);
            assert_eq!(engine, Engine::Closed, "p={p}");
            let mut fe = quiet_fabric(p);
            let event = topo.allgatherv_sized(&mut fe, &sizes);
            assert_eq!(closed.time_ps, event.time_ps, "p={p} clocks diverged");
            assert_eq!(closed.events, event.events, "p={p}");
            assert_eq!(
                closed.traffic.bytes_sent_per_node, event.traffic.bytes_sent_per_node,
                "p={p}"
            );
            assert_eq!(fc.now(), fe.now(), "p={p} fabric clocks diverged");
        }
    }

    #[test]
    fn closed_mesh_is_tick_identical_to_the_event_loop() {
        for p in [1usize, 2, 4, 7] {
            let topo = FullMesh::new(p);
            let sizes = sizes_for(p);
            let mut fc = quiet_fabric(p);
            let (closed, engine) = gather_sized(&topo, &mut fc, &sizes);
            assert_eq!(engine, Engine::Closed, "p={p}");
            let mut fe = quiet_fabric(p);
            let event = topo.allgatherv_sized(&mut fe, &sizes);
            assert_eq!(closed.time_ps, event.time_ps, "p={p} clocks diverged");
            assert_eq!(closed.events, event.events, "p={p}");
            assert_eq!(
                closed.traffic.bytes_sent_per_node, event.traffic.bytes_sent_per_node,
                "p={p}"
            );
        }
    }

    #[test]
    fn non_uniform_phases_fall_back_to_the_event_loop() {
        let topo = build_topology(TopologyKind::Ring, 4);
        let sizes = sizes_for(4);

        // Trace recording forces the full loop.
        let mut f = Fabric::for_config(&quiet_cfg(), 4);
        assert!(f.full_loop_reason().is_some());
        let (_, engine) = gather_sized(&*topo, &mut f, &sizes);
        assert_eq!(engine, Engine::Event);

        // One overridden link forces the full loop.
        let mut f = Fabric::for_config(
            &FabricConfig {
                link_overrides: vec![(
                    0,
                    1,
                    LinkSpec {
                        bandwidth_gbps: 0.5,
                        latency_us: 1.0,
                        jitter_us: 0.0,
                    },
                )],
                ..quiet_cfg()
            },
            4,
        );
        f.set_trace(false);
        assert!(f.full_loop_reason().is_some());
        let (_, engine) = gather_sized(&*topo, &mut f, &sizes);
        assert_eq!(engine, Engine::Event);

        // Jitter forces the full loop.
        let mut f = Fabric::for_config(
            &FabricConfig {
                link: LinkSpec {
                    bandwidth_gbps: 1.0,
                    latency_us: 1.0,
                    jitter_us: 0.5,
                },
                ..FabricConfig::default()
            },
            4,
        );
        f.set_trace(false);
        assert!(f.full_loop_reason().is_some());
        let (_, engine) = gather_sized(&*topo, &mut f, &sizes);
        assert_eq!(engine, Engine::Event);
    }

    #[test]
    fn leader_topologies_always_use_the_event_loop() {
        let topo = build_topology(TopologyKind::Hier { groups: 2 }, 6);
        let mut f = quiet_fabric(6);
        let (res, engine) = gather_sized(&*topo, &mut f, &sizes_for(6));
        assert_eq!(engine, Engine::Event);
        assert!(res.events > 0, "event loop actually ran");
    }

    #[test]
    fn closed_runs_leave_the_clock_continuable() {
        // Two back-to-back closed gathers share port state exactly like
        // two event-loop runs on one fabric.
        let topo = build_topology(TopologyKind::Ring, 4);
        let sizes = sizes_for(4);
        let mut fc = quiet_fabric(4);
        gather_sized(&*topo, &mut fc, &sizes);
        let (second_closed, engine) = gather_sized(&*topo, &mut fc, &sizes);
        assert_eq!(engine, Engine::Closed);
        let mut fe = quiet_fabric(4);
        topo.allgatherv_sized(&mut fe, &sizes);
        let second_event = topo.allgatherv_sized(&mut fe, &sizes);
        assert_eq!(second_closed.time_ps, second_event.time_ps);
        assert_eq!(
            second_closed.traffic.bytes_sent_per_node,
            second_event.traffic.bytes_sent_per_node
        );
    }
}
