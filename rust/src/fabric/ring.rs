//! Ring topology: the paper's substrate, now event-driven.
//!
//! Allgatherv is the classic p−1-hop circulation: each worker injects
//! its own block rightward and forwards every block it receives except
//! the one that completes its set (origin `(i+1) mod p`). When the
//! fabric configures a segment size (`FabricConfig::segment_bytes`,
//! the cost model's block size `m`), every block circulates as
//! independent segments, so a long message pipelines through the hops
//! instead of store-and-forwarding whole — the simulated time then
//! converges to the paper's pipelined `T_v` bound even for skewed
//! per-node message sizes (property-tested in `tests/fabric_sim.rs`).
//! Allreduce is the two-phase ring (reduce-scatter then allgather)
//! over the same chunk boundaries as the lockstep `comm::allreduce`,
//! with the accumulation performed in the same order — so the fronts
//! in `comm` return **bit-identical** results and **byte-identical**
//! traffic to the pre-fabric implementations, while wall-clock now
//! emerges from the event clock (pipelined hops, stragglers, jitter)
//! instead of a closed-form bound.

use super::collectives::{
    chunk_range, traffic_from, GatherState, SegPayloads, SimGather, SimReduce,
};
use super::topology::{Topology, TopologyKind};
use super::{Fabric, Msg, Payload, Protocol};
use crate::comm::Traffic;

const TAG_GATHER: u8 = 0;
/// Reduce-scatter phase of allreduce.
const TAG_RS: u8 = 1;
/// Allgather phase of allreduce.
const TAG_AG: u8 = 2;

pub struct Ring {
    p: usize,
}

impl Ring {
    pub fn new(workers: usize) -> Ring {
        assert!(workers > 0, "topology needs at least one worker");
        Ring { p: workers }
    }

    fn right(&self, i: usize) -> usize {
        (i + 1) % self.p
    }

    /// Drive one gather (real or phantom payloads) through the event
    /// loop — both `allgatherv` flavors run this identical code.
    fn run_gather(&self, fabric: &mut Fabric, segs: SegPayloads, state: GatherState) -> SimGather {
        let mut proto = RingGather {
            p: self.p,
            segs,
            state,
        };
        let time_ps = if self.p > 1 { fabric.run(&mut proto) } else { 0 };
        SimGather {
            gathered: proto.state.into_gathered(),
            traffic: traffic_from(fabric, self.gather_rounds()),
            time_ps,
            events: fabric.events(),
        }
    }
}

struct RingGather {
    p: usize,
    segs: SegPayloads,
    state: GatherState,
}

impl Protocol for RingGather {
    fn start(&mut self) -> Vec<(usize, usize, Msg)> {
        let mut out = Vec::new();
        for w in 0..self.p {
            for si in 0..self.segs.seg_count(w) {
                out.push((
                    w,
                    (w + 1) % self.p,
                    Msg {
                        origin: w,
                        seg: si as u32,
                        hop: 1,
                        tag: TAG_GATHER,
                        payload: self.segs.payload(w, si),
                    },
                ));
            }
        }
        out
    }

    fn on_deliver(&mut self, node: usize, msg: &Msg) -> Vec<(usize, Msg)> {
        self.state
            .store_payload(node, msg.origin, msg.seg as usize, &msg.payload);
        // Forward everything except the block that completes this
        // node's set — exactly p−1 egress blocks per node, the same
        // Σ_j n_j − n_(i+1) accounting as the lockstep ring (the split
        // into segments leaves byte totals untouched).
        if msg.origin != (node + 1) % self.p {
            vec![(
                (node + 1) % self.p,
                Msg {
                    origin: msg.origin,
                    seg: msg.seg,
                    hop: msg.hop + 1,
                    tag: TAG_GATHER,
                    payload: msg.payload.clone(),
                },
            )]
        } else {
            Vec::new()
        }
    }
}

struct RingReduce {
    p: usize,
    n: usize,
    inputs: Vec<Vec<f32>>,
    /// Fully-reduced chunks as they land: `chunks[node][chunk]`.
    chunks: Vec<Vec<Option<Vec<f32>>>>,
}

impl Protocol for RingReduce {
    fn start(&mut self) -> Vec<(usize, usize, Msg)> {
        (0..self.p)
            .map(|w| {
                let payload = self.inputs[w][chunk_range(self.n, self.p, w)].to_vec();
                (
                    w,
                    (w + 1) % self.p,
                    Msg {
                        origin: w, // chunk id
                        seg: 0,
                        hop: 1,
                        tag: TAG_RS,
                        payload: Payload::F32(payload),
                    },
                )
            })
            .collect()
    }

    fn on_deliver(&mut self, node: usize, msg: &Msg) -> Vec<(usize, Msg)> {
        let Payload::F32(partial) = &msg.payload else {
            unreachable!("reduce protocol only moves f32 chunks")
        };
        let c = msg.origin;
        let right = (node + 1) % self.p;
        match msg.tag {
            TAG_RS => {
                // Accumulate exactly as the lockstep ring does:
                // receiver's own slice += incoming partial.
                let r = chunk_range(self.n, self.p, c);
                let mut acc = self.inputs[node][r].to_vec();
                for (k, v) in partial.iter().enumerate() {
                    acc[k] += v;
                }
                if msg.hop < (self.p - 1) as u32 {
                    vec![(
                        right,
                        Msg {
                            origin: c,
                            seg: 0,
                            hop: msg.hop + 1,
                            tag: TAG_RS,
                            payload: Payload::F32(acc),
                        },
                    )]
                } else {
                    // p−1 hops done: chunk c is fully reduced here
                    // (node == (c + p − 1) mod p). Keep it and start
                    // circulating it (phase 2) immediately — the two
                    // phases pipeline per chunk.
                    self.chunks[node][c] = Some(acc.clone());
                    vec![(
                        right,
                        Msg {
                            origin: c,
                            seg: 0,
                            hop: 1,
                            tag: TAG_AG,
                            payload: Payload::F32(acc),
                        },
                    )]
                }
            }
            TAG_AG => {
                self.chunks[node][c] = Some(partial.clone());
                if msg.hop < (self.p - 1) as u32 {
                    vec![(
                        right,
                        Msg {
                            origin: c,
                            seg: 0,
                            hop: msg.hop + 1,
                            tag: TAG_AG,
                            payload: msg.payload.clone(),
                        },
                    )]
                } else {
                    Vec::new()
                }
            }
            other => unreachable!("unknown ring reduce tag {other}"),
        }
    }
}

impl Topology for Ring {
    fn kind(&self) -> TopologyKind {
        TopologyKind::Ring
    }

    fn workers(&self) -> usize {
        self.p
    }

    fn gather_rounds(&self) -> u32 {
        self.p.saturating_sub(1) as u32
    }

    fn reduce_rounds(&self) -> u32 {
        2 * self.p.saturating_sub(1) as u32
    }

    fn allgatherv(&self, fabric: &mut Fabric, inputs: &[Vec<u8>]) -> SimGather {
        assert_eq!(inputs.len(), self.p, "one input message per worker");
        let seg = fabric.segment_bytes();
        self.run_gather(
            fabric,
            SegPayloads::real(inputs, seg),
            GatherState::new(inputs, seg),
        )
    }

    fn allgatherv_sized(&self, fabric: &mut Fabric, sizes: &[u64]) -> SimGather {
        assert_eq!(sizes.len(), self.p, "one size per worker");
        let seg = fabric.segment_bytes();
        self.run_gather(
            fabric,
            SegPayloads::phantom(sizes, seg),
            GatherState::sized(sizes, seg),
        )
    }

    fn allreduce(&self, fabric: &mut Fabric, inputs: &[Vec<f32>]) -> SimReduce {
        assert_eq!(inputs.len(), self.p);
        let n = inputs[0].len();
        assert!(inputs.iter().all(|v| v.len() == n), "length mismatch");
        if self.p == 1 {
            return SimReduce {
                reduced: vec![inputs[0].clone()],
                traffic: Traffic {
                    bytes_sent_per_node: vec![0],
                    rounds: 0,
                },
                time_ps: 0,
                events: 0,
            };
        }
        let mut proto = RingReduce {
            p: self.p,
            n,
            inputs: inputs.to_vec(),
            chunks: vec![vec![None; self.p]; self.p],
        };
        let time_ps = fabric.run(&mut proto);
        let reduced: Vec<Vec<f32>> = proto
            .chunks
            .iter()
            .map(|row| {
                let mut out = vec![0.0f32; n];
                for (c, slot) in row.iter().enumerate() {
                    let chunk = slot.as_ref().expect("ring reduce under-delivered");
                    out[chunk_range(n, self.p, c)].copy_from_slice(chunk);
                }
                out
            })
            .collect();
        SimReduce {
            reduced,
            traffic: traffic_from(fabric, self.reduce_rounds()),
            time_ps,
            events: fabric.events(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{FabricConfig, LinkSpec, Straggler};

    fn fabric_with(p: usize, stragglers: Vec<Straggler>) -> Fabric {
        Fabric::for_config(
            &FabricConfig {
                link: LinkSpec {
                    bandwidth_gbps: 1.0,
                    latency_us: 1.0,
                    jitter_us: 0.0,
                },
                stragglers,
                ..FabricConfig::default()
            },
            p,
        )
    }

    #[test]
    fn gather_traffic_matches_lockstep_accounting() {
        let sizes = [100usize, 200, 50, 400];
        let inputs: Vec<Vec<u8>> = sizes.iter().map(|&s| vec![7u8; s]).collect();
        let topo = Ring::new(4);
        let mut f = fabric_with(4, Vec::new());
        let res = topo.allgatherv(&mut f, &inputs);
        for i in 0..4 {
            let expected: u64 = (0..4)
                .filter(|&j| j != (i + 1) % 4)
                .map(|j| sizes[j] as u64)
                .sum();
            assert_eq!(res.traffic.bytes_sent_per_node[i], expected, "node {i}");
        }
        assert_eq!(res.traffic.rounds, 3);
        for dst in 0..4 {
            for src in 0..4 {
                assert_eq!(res.gathered[dst][src], inputs[src]);
            }
        }
    }

    #[test]
    fn uniform_gather_time_is_hops_times_ser_plus_latency() {
        // 4 workers, 125-byte (1000-bit = 1 µs) blocks, 1 µs latency:
        // pipelined hops never queue, so completion = 3 × (1 + 1) µs.
        let inputs: Vec<Vec<u8>> = (0..4).map(|_| vec![0u8; 125]).collect();
        let topo = Ring::new(4);
        let mut f = fabric_with(4, Vec::new());
        let res = topo.allgatherv(&mut f, &inputs);
        assert_eq!(res.time_ps, 3 * 2_000_000);
        assert_eq!(res.events, 12); // p(p−1) deliveries
    }

    #[test]
    fn segmented_gather_is_byte_identical_and_faster_when_skewed() {
        // One 100 KB message among 100 B peers: whole-block forwarding
        // costs ~3 full serializations on the critical path; segmented
        // circulation overlaps them.
        let sizes = [100_000usize, 100, 100, 100];
        let inputs: Vec<Vec<u8>> = sizes.iter().map(|&s| vec![5u8; s]).collect();
        let topo = Ring::new(4);
        let mut whole = fabric_with(4, Vec::new());
        let t_whole = topo.allgatherv(&mut whole, &inputs);
        let mut seg_fabric = Fabric::for_config(
            &FabricConfig {
                link: LinkSpec {
                    bandwidth_gbps: 1.0,
                    latency_us: 1.0,
                    jitter_us: 0.0,
                },
                segment_bytes: 8192,
                ..FabricConfig::default()
            },
            4,
        );
        let t_seg = topo.allgatherv(&mut seg_fabric, &inputs);
        for dst in 0..4 {
            for src in 0..4 {
                assert_eq!(t_seg.gathered[dst][src], inputs[src]);
            }
        }
        assert_eq!(
            t_seg.traffic.bytes_sent_per_node,
            t_whole.traffic.bytes_sent_per_node,
            "segmentation must not change byte accounting"
        );
        assert!(
            t_seg.time_ps * 2 < t_whole.time_ps,
            "segmentation did not pipeline: {} vs {}",
            t_seg.time_ps,
            t_whole.time_ps
        );
    }

    #[test]
    fn straggler_stretches_completion() {
        let inputs: Vec<Vec<u8>> = (0..4).map(|_| vec![0u8; 12_500]).collect();
        let topo = Ring::new(4);
        let mut healthy = fabric_with(4, Vec::new());
        let t0 = topo.allgatherv(&mut healthy, &inputs).time_ps;
        let mut slowed = fabric_with(
            4,
            vec![Straggler {
                node: 2,
                slowdown: 10.0,
            }],
        );
        let t1 = topo.allgatherv(&mut slowed, &inputs).time_ps;
        assert!(t1 > t0, "straggler had no effect: {t0} vs {t1}");
    }

    #[test]
    fn reduce_matches_elementwise_sum() {
        let inputs = vec![
            vec![1.0f32, 2.0, 3.0, 4.0, 5.0],
            vec![10.0, 20.0, 30.0, 40.0, 50.0],
            vec![-1.0, -2.0, -3.0, -4.0, -5.0],
        ];
        let topo = Ring::new(3);
        let mut f = fabric_with(3, Vec::new());
        let res = topo.allreduce(&mut f, &inputs);
        let want = vec![10.0f32, 20.0, 30.0, 40.0, 50.0];
        for node in 0..3 {
            assert_eq!(res.reduced[node], want, "node {node}");
        }
        assert_eq!(res.traffic.rounds, 4);
    }

    #[test]
    fn reduce_traffic_matches_two_phase_accounting() {
        let p = 4;
        let n = 100;
        let inputs: Vec<Vec<f32>> = (0..p).map(|i| vec![i as f32; n]).collect();
        let topo = Ring::new(p);
        let mut f = fabric_with(p, Vec::new());
        let res = topo.allreduce(&mut f, &inputs);
        for i in 0..p {
            assert_eq!(
                res.traffic.bytes_sent_per_node[i],
                (2 * (p - 1) * n / p * 4) as u64,
                "node {i}"
            );
        }
    }

    #[test]
    fn n_smaller_than_p_still_reduces() {
        let inputs: Vec<Vec<f32>> = (0..5).map(|i| vec![i as f32, 1.0]).collect();
        let topo = Ring::new(5);
        let mut f = fabric_with(5, Vec::new());
        let res = topo.allreduce(&mut f, &inputs);
        for node in 0..5 {
            assert_eq!(res.reduced[node], vec![10.0, 5.0]);
        }
    }
}
