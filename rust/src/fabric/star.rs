//! Star topology: a parameter-server hub.
//!
//! Workers 0..p are leaves; node `p` is a dedicated hub (it holds no
//! gradient of its own). Allgatherv relays every block through the hub
//! (up, then fan-out), per pipeline segment when the fabric configures
//! one — so a long block starts fanning out before it has fully
//! arrived; allreduce ships full vectors up, reduces at the hub in
//! worker order, and fans the sum back out. The hub's ingress port
//! serializes the p-way incast and its egress port the p·(p−1)
//! fan-out — the classic PS bottleneck the sweep quantifies against
//! the ring.

use super::collectives::{traffic_from, GatherState, SegPayloads, SimGather, SimReduce};
use super::topology::{Topology, TopologyKind};
use super::{Fabric, Msg, Payload, Protocol};

/// Block/vector travelling worker → hub.
const TAG_UP: u8 = 0;
/// Block/sum travelling hub → worker.
const TAG_DOWN: u8 = 1;

pub struct Star {
    p: usize,
}

impl Star {
    pub fn new(workers: usize) -> Star {
        assert!(workers > 0, "topology needs at least one worker");
        Star { p: workers }
    }

    fn hub(&self) -> usize {
        self.p
    }

    /// Drive one gather (real or phantom payloads) through the event
    /// loop — both `allgatherv` flavors run this identical code.
    fn run_gather(&self, fabric: &mut Fabric, segs: SegPayloads, state: GatherState) -> SimGather {
        let mut proto = StarGather {
            p: self.p,
            hub: self.hub(),
            segs,
            state,
        };
        let time_ps = fabric.run(&mut proto);
        SimGather {
            gathered: proto.state.into_gathered(),
            traffic: traffic_from(fabric, self.gather_rounds()),
            time_ps,
            events: fabric.events(),
        }
    }
}

struct StarGather {
    p: usize,
    hub: usize,
    segs: SegPayloads,
    state: GatherState,
}

impl Protocol for StarGather {
    fn start(&mut self) -> Vec<(usize, usize, Msg)> {
        if self.p == 1 {
            return Vec::new();
        }
        let mut out = Vec::new();
        for w in 0..self.p {
            for si in 0..self.segs.seg_count(w) {
                out.push((
                    w,
                    self.hub,
                    Msg {
                        origin: w,
                        seg: si as u32,
                        hop: 1,
                        tag: TAG_UP,
                        payload: self.segs.payload(w, si),
                    },
                ));
            }
        }
        out
    }

    fn on_deliver(&mut self, node: usize, msg: &Msg) -> Vec<(usize, Msg)> {
        if node == self.hub {
            // Fan the segment out to every worker that lacks it.
            (0..self.p)
                .filter(|&v| v != msg.origin)
                .map(|v| {
                    (
                        v,
                        Msg {
                            origin: msg.origin,
                            seg: msg.seg,
                            hop: msg.hop + 1,
                            tag: TAG_DOWN,
                            payload: msg.payload.clone(),
                        },
                    )
                })
                .collect()
        } else {
            self.state
                .store_payload(node, msg.origin, msg.seg as usize, &msg.payload);
            Vec::new()
        }
    }
}

struct StarReduce {
    p: usize,
    hub: usize,
    inputs: Vec<Vec<f32>>,
    /// Vectors buffered at the hub, by worker id.
    up: Vec<Option<Vec<f32>>>,
    /// The fan-out sum as received by each worker.
    down: Vec<Option<Vec<f32>>>,
}

impl Protocol for StarReduce {
    fn start(&mut self) -> Vec<(usize, usize, Msg)> {
        (0..self.p)
            .map(|w| {
                (
                    w,
                    self.hub,
                    Msg {
                        origin: w,
                        seg: 0,
                        hop: 1,
                        tag: TAG_UP,
                        payload: Payload::F32(self.inputs[w].clone()),
                    },
                )
            })
            .collect()
    }

    fn on_deliver(&mut self, node: usize, msg: &Msg) -> Vec<(usize, Msg)> {
        let Payload::F32(v) = &msg.payload else {
            unreachable!("reduce protocol only moves f32 vectors")
        };
        if node == self.hub {
            self.up[msg.origin] = Some(v.clone());
            if self.up.iter().any(|b| b.is_none()) {
                return Vec::new();
            }
            // Last contribution arrived: reduce in worker order and fan
            // the identical sum back out.
            let n = v.len();
            let mut sum = vec![0.0f32; n];
            for slot in &self.up {
                for (k, x) in slot.as_ref().unwrap().iter().enumerate() {
                    sum[k] += x;
                }
            }
            (0..self.p)
                .map(|w| {
                    (
                        w,
                        Msg {
                            origin: w,
                            seg: 0,
                            hop: msg.hop + 1,
                            tag: TAG_DOWN,
                            payload: Payload::F32(sum.clone()),
                        },
                    )
                })
                .collect()
        } else {
            self.down[node] = Some(v.clone());
            Vec::new()
        }
    }
}

impl Topology for Star {
    fn kind(&self) -> TopologyKind {
        TopologyKind::Star
    }

    fn workers(&self) -> usize {
        self.p
    }

    fn node_count(&self) -> usize {
        self.p + 1
    }

    fn gather_rounds(&self) -> u32 {
        if self.p > 1 {
            2
        } else {
            0
        }
    }

    fn reduce_rounds(&self) -> u32 {
        2
    }

    fn allgatherv(&self, fabric: &mut Fabric, inputs: &[Vec<u8>]) -> SimGather {
        assert_eq!(inputs.len(), self.p, "one input message per worker");
        let seg = fabric.segment_bytes();
        self.run_gather(
            fabric,
            SegPayloads::real(inputs, seg),
            GatherState::new(inputs, seg),
        )
    }

    fn allgatherv_sized(&self, fabric: &mut Fabric, sizes: &[u64]) -> SimGather {
        assert_eq!(sizes.len(), self.p, "one size per worker");
        let seg = fabric.segment_bytes();
        self.run_gather(
            fabric,
            SegPayloads::phantom(sizes, seg),
            GatherState::sized(sizes, seg),
        )
    }

    fn allreduce(&self, fabric: &mut Fabric, inputs: &[Vec<f32>]) -> SimReduce {
        assert_eq!(inputs.len(), self.p);
        let n = inputs[0].len();
        assert!(inputs.iter().all(|v| v.len() == n), "length mismatch");
        let mut proto = StarReduce {
            p: self.p,
            hub: self.hub(),
            inputs: inputs.to_vec(),
            up: vec![None; self.p],
            down: vec![None; self.p],
        };
        let time_ps = fabric.run(&mut proto);
        let reduced: Vec<Vec<f32>> = proto
            .down
            .iter()
            .map(|slot| slot.clone().expect("star reduce under-delivered"))
            .collect();
        SimReduce {
            reduced,
            traffic: traffic_from(fabric, self.reduce_rounds()),
            time_ps,
            events: fabric.events(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{FabricConfig, LinkSpec};

    fn fabric(nodes: usize) -> Fabric {
        Fabric::for_config(
            &FabricConfig {
                link: LinkSpec {
                    bandwidth_gbps: 1.0,
                    latency_us: 1.0,
                    jitter_us: 0.0,
                },
                ..FabricConfig::default()
            },
            nodes,
        )
    }

    #[test]
    fn gather_relays_every_block_through_the_hub() {
        let inputs = vec![vec![1u8; 8], vec![2u8; 16], vec![3u8; 4]];
        let topo = Star::new(3);
        let mut f = fabric(topo.node_count());
        let res = topo.allgatherv(&mut f, &inputs);
        for dst in 0..3 {
            for src in 0..3 {
                assert_eq!(res.gathered[dst][src], inputs[src]);
            }
        }
        // Workers send their own block once; the hub re-sends every
        // block p−1 times.
        assert_eq!(res.traffic.bytes_sent_per_node[0], 8);
        assert_eq!(res.traffic.bytes_sent_per_node[1], 16);
        assert_eq!(res.traffic.bytes_sent_per_node[2], 4);
        assert_eq!(res.traffic.bytes_sent_per_node[3], 2 * (8 + 16 + 4));
        assert_eq!(res.traffic.rounds, 2);
    }

    #[test]
    fn reduce_sums_in_worker_order_everywhere() {
        let inputs = vec![vec![1.0f32, -1.0], vec![2.0, 0.5], vec![3.0, 0.25]];
        let topo = Star::new(3);
        let mut f = fabric(topo.node_count());
        let res = topo.allreduce(&mut f, &inputs);
        for node in 0..3 {
            assert_eq!(res.reduced[node], vec![6.0, -0.25], "node {node}");
        }
    }

    #[test]
    fn hub_fanout_is_slower_than_full_mesh() {
        use crate::fabric::topology::FullMesh;
        let inputs: Vec<Vec<u8>> = (0..8).map(|_| vec![0u8; 12_500]).collect();
        let star = Star::new(8);
        let mesh = FullMesh::new(8);
        let mut fs = fabric(star.node_count());
        let mut fm = fabric(mesh.node_count());
        let ts = star.allgatherv(&mut fs, &inputs).time_ps;
        let tm = mesh.allgatherv(&mut fm, &inputs).time_ps;
        assert!(
            ts > tm,
            "hub bottleneck missing: star {ts} ps vs mesh {tm} ps"
        );
    }

    #[test]
    fn single_worker_star_gathers_trivially() {
        let topo = Star::new(1);
        let mut f = fabric(topo.node_count());
        let res = topo.allgatherv(&mut f, &[vec![5u8; 3]]);
        assert_eq!(res.gathered[0][0], vec![5u8; 3]);
        assert_eq!(res.time_ps, 0);
    }
}
