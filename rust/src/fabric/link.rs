//! Link model: bandwidth / latency / jitter per directed edge, per-link
//! overrides ([`LinkTable`]), and per-link traffic accounting.
//!
//! The fabric uses a cut-through port model (see `fabric::Fabric`): a
//! message occupies the sender's egress port for its serialization
//! time, its first bit lands `latency (+ jitter)` after transmission
//! starts, and delivery completes one (receiver-rate) serialization
//! time after the first bit clears the receiver's ingress queue. On an
//! uncontended path that reduces to the classic
//! `ser + latency` store-and-forward hop; under fan-in/fan-out the
//! port queues produce incast and broadcast bottlenecks (the
//! parameter-server hub effect).
//!
//! # LinkTable semantics
//!
//! Every directed edge `(src, dst)` resolves to exactly one
//! [`LinkSpec`]. A [`LinkTable`] holds one uniform *default* spec plus
//! a sparse override map; [`LinkTable::spec`] returns the override when
//! `(src, dst)` has one and the default otherwise. Overrides are
//! directed — overriding `(0, 1)` leaves `(1, 0)` on the default — and
//! layer in a fixed precedence order when a fabric is built
//! (`Fabric::for_topology`): topology-derived overrides (e.g. the
//! hierarchy's slow inter-rack uplinks) are applied first, then the
//! explicit `FabricConfig::link_overrides`, so user configuration
//! always wins. Serialization/latency/jitter of a hop are billed
//! entirely at the resolved spec of that hop's directed edge.
//!
//! ```
//! use vgc::fabric::{LinkSpec, LinkTable};
//!
//! let mut table = LinkTable::uniform(LinkSpec::gige());
//! table.set(0, 1, LinkSpec::infiniband());
//! assert_eq!(table.spec(0, 1).bandwidth_gbps, 100.0); // overridden
//! assert_eq!(table.spec(1, 0).bandwidth_gbps, 1.0); // directed: default
//! assert_eq!(table.overrides(), 1);
//! ```

use std::collections::BTreeMap;

use super::clock::{Time, PS_PER_US};
use crate::comm::costmodel::LinkModel;

/// Link parameters in human units. Conversions to picoseconds happen
/// at send time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkSpec {
    /// Bandwidth in Gbit/s (1 Gbps ⇒ 1000 ps/bit).
    pub bandwidth_gbps: f64,
    /// One-way propagation latency, microseconds.
    pub latency_us: f64,
    /// Max uniform extra latency per message, microseconds (0 = none).
    pub jitter_us: f64,
}

impl LinkSpec {
    /// 1000BASE-T Ethernet — the paper's "commodity interconnect".
    pub fn gige() -> LinkSpec {
        LinkSpec {
            bandwidth_gbps: 1.0,
            latency_us: 50.0,
            jitter_us: 0.0,
        }
    }

    /// InfiniBand-class link (~100 Gb/s, 2 µs).
    pub fn infiniband() -> LinkSpec {
        LinkSpec {
            bandwidth_gbps: 100.0,
            latency_us: 2.0,
            jitter_us: 0.0,
        }
    }

    /// Build from the Section-5 cost model's parameters
    /// (`beta` seconds/bit, `latency` seconds).
    pub fn from_cost_model(link: &LinkModel) -> LinkSpec {
        LinkSpec {
            bandwidth_gbps: 1e-9 / link.beta,
            latency_us: link.latency * 1e6,
            jitter_us: 0.0,
        }
    }

    /// The matching cost-model parameters, for analytic cross-checks.
    pub fn to_cost_model(&self) -> LinkModel {
        LinkModel {
            beta: 1e-9 / self.bandwidth_gbps,
            latency: self.latency_us / 1e6,
        }
    }

    /// Serialization time for `bytes` at this link's rate, in ps.
    pub fn ser_ps(&self, bytes: u64) -> Time {
        let ps_per_bit = 1000.0 / self.bandwidth_gbps;
        ((bytes * 8) as f64 * ps_per_bit).ceil() as Time
    }

    pub fn latency_ps(&self) -> Time {
        (self.latency_us * PS_PER_US).round() as Time
    }

    pub fn jitter_ps(&self) -> Time {
        (self.jitter_us * PS_PER_US).round() as Time
    }

    /// Whether sends on this link draw from the jitter RNG — the
    /// per-link predicate behind the fabric's closed-form fast-path
    /// eligibility check (`Fabric::full_loop_reason`).
    pub fn has_jitter(&self) -> bool {
        self.jitter_ps() > 0
    }
}

/// A directed-edge link resolver: one uniform default spec plus sparse
/// per-link overrides (see the module docs for precedence semantics).
#[derive(Debug, Clone, PartialEq)]
pub struct LinkTable {
    default: LinkSpec,
    overrides: BTreeMap<(usize, usize), LinkSpec>,
}

impl LinkTable {
    /// Every directed edge uses `default`.
    pub fn uniform(default: LinkSpec) -> LinkTable {
        LinkTable {
            default,
            overrides: BTreeMap::new(),
        }
    }

    /// The spec used when no override matches.
    pub fn default_spec(&self) -> &LinkSpec {
        &self.default
    }

    /// Override the directed edge `src → dst`. Later calls win.
    pub fn set(&mut self, src: usize, dst: usize, spec: LinkSpec) {
        assert!(src != dst, "link override on self-edge {src}");
        self.overrides.insert((src, dst), spec);
    }

    /// Resolve the spec for the directed edge `src → dst`.
    pub fn spec(&self, src: usize, dst: usize) -> &LinkSpec {
        self.overrides.get(&(src, dst)).unwrap_or(&self.default)
    }

    /// Number of overridden directed edges.
    pub fn overrides(&self) -> usize {
        self.overrides.len()
    }

    /// Whether every directed edge resolves to the same spec — a
    /// precondition for the closed-form fast path, which replays one
    /// uniform link arithmetic for all hops.
    pub fn is_uniform(&self) -> bool {
        self.overrides.is_empty()
    }

    /// Largest node id named by an override, if any (for range checks).
    pub fn max_node(&self) -> Option<usize> {
        self.overrides.keys().map(|&(s, d)| s.max(d)).max()
    }

    /// Iterate the overridden directed edges and their specs, in
    /// deterministic `(src, dst)` order.
    pub fn iter_overrides(&self) -> impl Iterator<Item = (&(usize, usize), &LinkSpec)> {
        self.overrides.iter()
    }

    /// The slowest spec any directed edge can resolve to: lowest
    /// bandwidth among the default and every override, breaking ties
    /// toward the higher latency (the conservative choice for a
    /// bandwidth-delay-product bound). This is a property of the
    /// *table*, not of a traffic pattern — an override on an unused
    /// edge still counts, which is the right bias for sizing pipeline
    /// segments (a segment must survive the worst wire it could cross).
    pub fn slowest_spec(&self) -> LinkSpec {
        let mut worst = self.default;
        for spec in self.overrides.values() {
            let slower = spec.bandwidth_gbps < worst.bandwidth_gbps
                || (spec.bandwidth_gbps == worst.bandwidth_gbps
                    && spec.latency_us > worst.latency_us);
            if slower {
                worst = *spec;
            }
        }
        worst
    }
}

/// Parse a comma-separated per-link override list:
/// `SRC-DST:GBPS[:LAT_US[:JIT_US]]`, e.g. `0-1:0.1` (slow the directed
/// edge 0→1 to 0.1 Gbps) or `0-1:0.1:200:5`. Omitted latency/jitter
/// inherit `base`.
pub fn parse_link_overrides(
    spec: &str,
    base: &LinkSpec,
) -> anyhow::Result<Vec<(usize, usize, LinkSpec)>> {
    let mut out = Vec::new();
    for part in spec.split(',').filter(|s| !s.trim().is_empty()) {
        let fields: Vec<&str> = part.trim().split(':').collect();
        anyhow::ensure!(
            (2..=4).contains(&fields.len()),
            "link override '{part}': want SRC-DST:GBPS[:LAT_US[:JIT_US]]"
        );
        let (src, dst) = fields[0]
            .split_once('-')
            .ok_or_else(|| anyhow::anyhow!("link override '{part}': want SRC-DST endpoints"))?;
        let src: usize = src.trim().parse()?;
        let dst: usize = dst.trim().parse()?;
        anyhow::ensure!(src != dst, "link override '{part}': self-edge");
        let mut link = *base;
        link.bandwidth_gbps = fields[1].trim().parse()?;
        anyhow::ensure!(
            link.bandwidth_gbps > 0.0,
            "link override '{part}': bandwidth must be positive"
        );
        if let Some(lat) = fields.get(2) {
            link.latency_us = lat.trim().parse()?;
            anyhow::ensure!(link.latency_us >= 0.0, "link override '{part}': latency < 0");
        }
        if let Some(jit) = fields.get(3) {
            link.jitter_us = jit.trim().parse()?;
            anyhow::ensure!(link.jitter_us >= 0.0, "link override '{part}': jitter < 0");
        }
        out.push((src, dst, link));
    }
    Ok(out)
}

/// Canonical string form of an override list (parses back via
/// [`parse_link_overrides`]; always writes the full 4-field form).
pub fn link_overrides_str(list: &[(usize, usize, LinkSpec)]) -> String {
    list.iter()
        .map(|(s, d, l)| {
            format!(
                "{s}-{d}:{}:{}:{}",
                l.bandwidth_gbps, l.latency_us, l.jitter_us
            )
        })
        .collect::<Vec<_>>()
        .join(",")
}

/// Traffic carried by one directed link over a collective.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LinkStat {
    pub bytes: u64,
    pub messages: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gige_serialization_math() {
        let l = LinkSpec::gige();
        // 1 MB at 1 Gbps = 8e6 bits * 1000 ps/bit = 8 ms.
        assert_eq!(l.ser_ps(1_000_000), 8_000_000_000);
        assert_eq!(l.latency_ps(), 50_000_000);
        assert_eq!(l.jitter_ps(), 0);
        assert!(!l.has_jitter());
        assert!(LinkSpec {
            jitter_us: 0.5,
            ..l
        }
        .has_jitter());
    }

    #[test]
    fn infiniband_is_100x_faster() {
        let g = LinkSpec::gige().ser_ps(1 << 20);
        let i = LinkSpec::infiniband().ser_ps(1 << 20);
        assert_eq!(g, i * 100);
    }

    #[test]
    fn cost_model_roundtrip() {
        for spec in [LinkSpec::gige(), LinkSpec::infiniband()] {
            let back = LinkSpec::from_cost_model(&spec.to_cost_model());
            assert!((back.bandwidth_gbps - spec.bandwidth_gbps).abs() < 1e-9);
            assert!((back.latency_us - spec.latency_us).abs() < 1e-9);
        }
        // And the canonical constants line up with costmodel's presets.
        let m = LinkSpec::gige().to_cost_model();
        assert!((m.beta - 1e-9).abs() < 1e-21);
        assert!((m.latency - 50e-6).abs() < 1e-12);
    }

    #[test]
    fn zero_bytes_serialize_instantly() {
        assert_eq!(LinkSpec::gige().ser_ps(0), 0);
    }

    #[test]
    fn table_resolves_directed_overrides() {
        let mut t = LinkTable::uniform(LinkSpec::gige());
        assert_eq!(t.overrides(), 0);
        assert_eq!(t.max_node(), None);
        assert!(t.is_uniform());
        t.set(2, 5, LinkSpec::infiniband());
        assert!(!t.is_uniform());
        assert_eq!(t.spec(2, 5).bandwidth_gbps, 100.0);
        assert_eq!(t.spec(5, 2).bandwidth_gbps, 1.0);
        assert_eq!(t.spec(0, 1).latency_us, 50.0);
        assert_eq!(t.overrides(), 1);
        assert_eq!(t.max_node(), Some(5));
        // Later set wins.
        t.set(2, 5, LinkSpec::gige());
        assert_eq!(t.spec(2, 5).bandwidth_gbps, 1.0);
        assert_eq!(t.overrides(), 1);
    }

    #[test]
    fn slowest_spec_scans_default_and_overrides() {
        let mut t = LinkTable::uniform(LinkSpec::infiniband());
        assert_eq!(t.slowest_spec(), LinkSpec::infiniband());
        t.set(0, 1, LinkSpec::gige());
        assert_eq!(t.slowest_spec(), LinkSpec::gige());
        // Equal bandwidth, higher latency wins the tie.
        let laggy = LinkSpec {
            latency_us: 500.0,
            ..LinkSpec::gige()
        };
        t.set(1, 0, laggy);
        assert_eq!(t.slowest_spec(), laggy);
        // A fast override never displaces a slow default.
        let s = LinkTable::uniform(LinkSpec::gige());
        assert_eq!(s.slowest_spec(), LinkSpec::gige());
        assert_eq!(t.iter_overrides().count(), 2);
    }

    #[test]
    #[should_panic(expected = "self-edge")]
    fn table_rejects_self_edges() {
        LinkTable::uniform(LinkSpec::gige()).set(3, 3, LinkSpec::gige());
    }

    #[test]
    fn override_spec_roundtrip() {
        let base = LinkSpec::gige();
        let list = parse_link_overrides("0-1:0.1, 4-2:10:5:1", &base).unwrap();
        assert_eq!(list.len(), 2);
        assert_eq!(list[0].0, 0);
        assert_eq!(list[0].1, 1);
        assert_eq!(list[0].2.bandwidth_gbps, 0.1);
        assert_eq!(list[0].2.latency_us, base.latency_us); // inherited
        assert_eq!(list[1].2.latency_us, 5.0);
        assert_eq!(list[1].2.jitter_us, 1.0);
        let s = link_overrides_str(&list);
        assert_eq!(parse_link_overrides(&s, &base).unwrap(), list);
        assert!(parse_link_overrides("", &base).unwrap().is_empty());
    }

    #[test]
    fn bad_override_specs_are_loud() {
        let base = LinkSpec::gige();
        assert!(parse_link_overrides("0-1", &base).is_err()); // no rate
        assert!(parse_link_overrides("01:5", &base).is_err()); // no edge
        assert!(parse_link_overrides("2-2:5", &base).is_err()); // self-edge
        assert!(parse_link_overrides("0-1:0", &base).is_err()); // zero rate
        assert!(parse_link_overrides("0-1:1:-2", &base).is_err()); // neg lat
    }
}
