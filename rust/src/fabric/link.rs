//! Link model: bandwidth / latency / jitter per directed edge, plus
//! per-link traffic accounting.
//!
//! The fabric uses a cut-through port model (see `fabric::Fabric`): a
//! message occupies the sender's egress port for its serialization
//! time, its first bit lands `latency (+ jitter)` after transmission
//! starts, and delivery completes one (receiver-rate) serialization
//! time after the first bit clears the receiver's ingress queue. On an
//! uncontended path that reduces to the classic
//! `ser + latency` store-and-forward hop; under fan-in/fan-out the
//! port queues produce incast and broadcast bottlenecks (the
//! parameter-server hub effect).

use super::clock::{Time, PS_PER_US};
use crate::comm::costmodel::LinkModel;

/// Uniform link parameters in human units. Conversions to picoseconds
/// happen at send time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkSpec {
    /// Bandwidth in Gbit/s (1 Gbps ⇒ 1000 ps/bit).
    pub bandwidth_gbps: f64,
    /// One-way propagation latency, microseconds.
    pub latency_us: f64,
    /// Max uniform extra latency per message, microseconds (0 = none).
    pub jitter_us: f64,
}

impl LinkSpec {
    /// 1000BASE-T Ethernet — the paper's "commodity interconnect".
    pub fn gige() -> LinkSpec {
        LinkSpec {
            bandwidth_gbps: 1.0,
            latency_us: 50.0,
            jitter_us: 0.0,
        }
    }

    /// InfiniBand-class link (~100 Gb/s, 2 µs).
    pub fn infiniband() -> LinkSpec {
        LinkSpec {
            bandwidth_gbps: 100.0,
            latency_us: 2.0,
            jitter_us: 0.0,
        }
    }

    /// Build from the Section-5 cost model's parameters
    /// (`beta` seconds/bit, `latency` seconds).
    pub fn from_cost_model(link: &LinkModel) -> LinkSpec {
        LinkSpec {
            bandwidth_gbps: 1e-9 / link.beta,
            latency_us: link.latency * 1e6,
            jitter_us: 0.0,
        }
    }

    /// The matching cost-model parameters, for analytic cross-checks.
    pub fn to_cost_model(&self) -> LinkModel {
        LinkModel {
            beta: 1e-9 / self.bandwidth_gbps,
            latency: self.latency_us / 1e6,
        }
    }

    /// Serialization time for `bytes` at this link's rate, in ps.
    pub fn ser_ps(&self, bytes: u64) -> Time {
        let ps_per_bit = 1000.0 / self.bandwidth_gbps;
        ((bytes * 8) as f64 * ps_per_bit).ceil() as Time
    }

    pub fn latency_ps(&self) -> Time {
        (self.latency_us * PS_PER_US).round() as Time
    }

    pub fn jitter_ps(&self) -> Time {
        (self.jitter_us * PS_PER_US).round() as Time
    }
}

/// Traffic carried by one directed link over a collective.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LinkStat {
    pub bytes: u64,
    pub messages: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gige_serialization_math() {
        let l = LinkSpec::gige();
        // 1 MB at 1 Gbps = 8e6 bits * 1000 ps/bit = 8 ms.
        assert_eq!(l.ser_ps(1_000_000), 8_000_000_000);
        assert_eq!(l.latency_ps(), 50_000_000);
        assert_eq!(l.jitter_ps(), 0);
    }

    #[test]
    fn infiniband_is_100x_faster() {
        let g = LinkSpec::gige().ser_ps(1 << 20);
        let i = LinkSpec::infiniband().ser_ps(1 << 20);
        assert_eq!(g, i * 100);
    }

    #[test]
    fn cost_model_roundtrip() {
        for spec in [LinkSpec::gige(), LinkSpec::infiniband()] {
            let back = LinkSpec::from_cost_model(&spec.to_cost_model());
            assert!((back.bandwidth_gbps - spec.bandwidth_gbps).abs() < 1e-9);
            assert!((back.latency_us - spec.latency_us).abs() < 1e-9);
        }
        // And the canonical constants line up with costmodel's presets.
        let m = LinkSpec::gige().to_cost_model();
        assert!((m.beta - 1e-9).abs() < 1e-21);
        assert!((m.latency - 50e-6).abs() < 1e-12);
    }

    #[test]
    fn zero_bytes_serialize_instantly() {
        assert_eq!(LinkSpec::gige().ser_ps(0), 0);
    }
}
