//! NUMA-aware hierarchy topology: workers partitioned into groups
//! (racks / NUMA domains) with fast intra-group links and slow
//! inter-group uplinks.
//!
//! `hier:<g>` splits the workers into `g` contiguous, balanced groups
//! ([`group_spans`]); `hier` alone picks `≈ √p` groups
//! ([`auto_groups`]). The lowest id of each group is its leader, and
//! leaders are themselves workers — no extra infrastructure node.
//! Collectives run the three NUMA phases (shared with [`super::tree`]
//! via `fabric::groups`):
//!
//! 1. **reduce/collect within** — members send to their group leader
//!    over fast intra-group links;
//! 2. **exchange across** — leaders swap group aggregates (or blocks)
//!    pairwise over the slow uplinks, so each datum crosses the rack
//!    boundary exactly once;
//! 3. **broadcast within** — leaders fan results back to members.
//!
//! The bandwidth skew is what distinguishes this from [`super::tree`]:
//! via [`Topology::link_overrides`] every leader↔leader edge resolves
//! to an uplink [`LinkSpec`] whose bandwidth is
//! `FabricConfig::inter_rack_gbps` (default: the base bandwidth / 10,
//! the classic 10:1 oversubscription). Explicit
//! `FabricConfig::link_overrides` still win (see `LinkTable`). Gather
//! traffic pipelines per segment when `FabricConfig::segment_bytes`
//! is set, so a long block starts crossing the uplink before it has
//! fully climbed out of its rack.
//!
//! ```
//! use vgc::fabric::{build_topology, Fabric, FabricConfig, TopologyKind};
//!
//! let cfg = FabricConfig {
//!     topology: TopologyKind::Hier { groups: 2 },
//!     inter_rack_gbps: Some(0.1),
//!     ..FabricConfig::default()
//! };
//! let topo = build_topology(cfg.topology, 4);
//! let mut fabric = Fabric::for_topology(&cfg, &*topo);
//! // Leaders 0 and 2 talk over the 0.1 Gbps uplink; members don't.
//! assert_eq!(fabric.link_table().spec(0, 2).bandwidth_gbps, 0.1);
//! assert_eq!(fabric.link_table().spec(0, 1).bandwidth_gbps, 1.0);
//! let inputs: Vec<Vec<u8>> = (0..4).map(|w| vec![w as u8; 16]).collect();
//! let out = topo.allgatherv(&mut fabric, &inputs);
//! assert_eq!(out.gathered[3][0], inputs[0]);
//! ```

use super::collectives::{traffic_from, SimGather, SimReduce};
use super::groups::{GroupGather, GroupReduce, GroupSpans};
use super::topology::{Topology, TopologyKind};
use super::{Fabric, FabricConfig, LinkSpec};

/// Uplink bandwidth when `FabricConfig::inter_rack_gbps` is unset:
/// 10:1 oversubscription of the intra-group links.
pub const DEFAULT_OVERSUBSCRIPTION: f64 = 10.0;

/// Contiguous balanced partition of `p` workers into `groups` groups,
/// as `(start, len)` spans; the first `p mod groups` groups take the
/// extra worker.
pub fn group_spans(p: usize, groups: usize) -> Vec<(usize, usize)> {
    let g = groups.clamp(1, p.max(1));
    let base = p / g;
    let extra = p % g;
    let mut out = Vec::with_capacity(g);
    let mut start = 0;
    for i in 0..g {
        let len = base + usize::from(i < extra);
        out.push((start, len));
        start += len;
    }
    out
}

/// The auto group count for `hier` with no explicit `:g`: `≈ √p`,
/// balancing intra-group fan-in against uplink crossings.
pub fn auto_groups(p: usize) -> usize {
    ((p as f64).sqrt().round() as usize).clamp(1, p.max(1))
}

pub struct Hierarchy {
    p: usize,
    spans: GroupSpans,
}

impl Hierarchy {
    /// `groups` of 0 means "auto" (see [`auto_groups`]).
    pub fn new(workers: usize, groups: usize) -> Hierarchy {
        assert!(workers > 0, "topology needs at least one worker");
        let g = if groups == 0 {
            auto_groups(workers)
        } else {
            groups
        };
        assert!(
            g >= 1 && g <= workers,
            "hier wants {g} groups but only {workers} workers"
        );
        Hierarchy {
            p: workers,
            spans: GroupSpans::from_spans(workers, group_spans(workers, g)),
        }
    }

    fn groups(&self) -> usize {
        self.spans.groups()
    }

    fn group_of(&self, w: usize) -> usize {
        self.spans.group_of(w)
    }

    fn is_leader(&self, w: usize) -> bool {
        self.spans.is_leader(w)
    }

    fn leaders(&self) -> Vec<usize> {
        self.spans.leaders()
    }

    /// Members of group `g`, excluding its leader.
    fn members(&self, g: usize) -> Vec<usize> {
        self.spans.members(g)
    }
}

impl Topology for Hierarchy {
    fn kind(&self) -> TopologyKind {
        TopologyKind::Hier {
            groups: self.groups(),
        }
    }

    fn workers(&self) -> usize {
        self.p
    }

    fn link_overrides(&self, cfg: &FabricConfig) -> Vec<(usize, usize, LinkSpec)> {
        if self.groups() < 2 {
            return Vec::new();
        }
        let uplink = LinkSpec {
            bandwidth_gbps: cfg
                .inter_rack_gbps
                .unwrap_or(cfg.link.bandwidth_gbps / DEFAULT_OVERSUBSCRIPTION),
            ..cfg.link
        };
        let leaders = self.leaders();
        let mut out = Vec::new();
        for &a in &leaders {
            for &b in &leaders {
                if a != b {
                    out.push((a, b, uplink));
                }
            }
        }
        out
    }

    fn gather_rounds(&self) -> u32 {
        if self.p > 1 {
            3
        } else {
            0
        }
    }

    fn reduce_rounds(&self) -> u32 {
        if self.p > 1 {
            3
        } else {
            0
        }
    }

    fn allgatherv(&self, fabric: &mut Fabric, inputs: &[Vec<u8>]) -> SimGather {
        assert_eq!(inputs.len(), self.p, "one input message per worker");
        let seg = fabric.segment_bytes();
        let mut proto = GroupGather::new(&self.spans, inputs, seg);
        let time_ps = if self.p > 1 { fabric.run(&mut proto) } else { 0 };
        SimGather {
            gathered: proto.into_gathered(),
            traffic: traffic_from(fabric, self.gather_rounds()),
            time_ps,
            events: fabric.events(),
        }
    }

    fn allgatherv_sized(&self, fabric: &mut Fabric, sizes: &[u64]) -> SimGather {
        assert_eq!(sizes.len(), self.p, "one size per worker");
        let seg = fabric.segment_bytes();
        let mut proto = GroupGather::sized(&self.spans, sizes, seg);
        let time_ps = if self.p > 1 { fabric.run(&mut proto) } else { 0 };
        SimGather {
            gathered: proto.into_gathered(),
            traffic: traffic_from(fabric, self.gather_rounds()),
            time_ps,
            events: fabric.events(),
        }
    }

    fn allreduce(&self, fabric: &mut Fabric, inputs: &[Vec<f32>]) -> SimReduce {
        assert_eq!(inputs.len(), self.p);
        let n = inputs[0].len();
        assert!(inputs.iter().all(|v| v.len() == n), "length mismatch");
        let mut proto = GroupReduce::new(&self.spans, inputs);
        let time_ps = if self.p > 1 { fabric.run(&mut proto) } else { 0 };
        let reduced: Vec<Vec<f32>> = if self.p == 1 {
            vec![inputs[0].clone()]
        } else {
            proto.into_totals()
        };
        SimReduce {
            reduced,
            traffic: traffic_from(fabric, self.reduce_rounds()),
            time_ps,
            events: fabric.events(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::FabricConfig;

    fn fabric_for(topo: &Hierarchy, cfg: &FabricConfig) -> Fabric {
        Fabric::for_topology(cfg, topo)
    }

    fn fast_cfg() -> FabricConfig {
        FabricConfig {
            link: LinkSpec {
                bandwidth_gbps: 1.0,
                latency_us: 1.0,
                jitter_us: 0.0,
            },
            topology: TopologyKind::Hier { groups: 0 },
            ..FabricConfig::default()
        }
    }

    #[test]
    fn spans_balance_and_cover() {
        assert_eq!(group_spans(8, 3), vec![(0, 3), (3, 3), (6, 2)]);
        assert_eq!(group_spans(4, 2), vec![(0, 2), (2, 2)]);
        assert_eq!(group_spans(3, 5), vec![(0, 1), (1, 1), (2, 1)]); // clamped
        assert_eq!(group_spans(5, 1), vec![(0, 5)]);
        assert_eq!(auto_groups(9), 3);
        assert_eq!(auto_groups(1), 1);
        assert_eq!(auto_groups(6), 2);
    }

    #[test]
    fn leadership_math() {
        let h = Hierarchy::new(8, 3);
        assert_eq!(h.leaders(), vec![0, 3, 6]);
        assert_eq!(h.group_of(4), 1);
        assert_eq!(h.members(2), vec![7]);
        assert_eq!(h.members(0), vec![1, 2]);
        assert!(h.is_leader(3));
        assert!(!h.is_leader(4));
    }

    #[test]
    fn uplink_overrides_cover_exactly_the_leader_pairs() {
        let h = Hierarchy::new(8, 3);
        let cfg = FabricConfig {
            inter_rack_gbps: Some(0.25),
            ..fast_cfg()
        };
        let ov = h.link_overrides(&cfg);
        assert_eq!(ov.len(), 6); // 3 leaders, ordered pairs
        assert!(ov.iter().all(|&(_, _, l)| l.bandwidth_gbps == 0.25));
        let f = fabric_for(&h, &cfg);
        assert_eq!(f.link_table().spec(0, 3).bandwidth_gbps, 0.25);
        assert_eq!(f.link_table().spec(3, 6).bandwidth_gbps, 0.25);
        assert_eq!(f.link_table().spec(0, 1).bandwidth_gbps, 1.0); // intra
        // Default uplink: 10:1 oversubscription.
        let f = fabric_for(&h, &fast_cfg());
        assert_eq!(f.link_table().spec(0, 3).bandwidth_gbps, 0.1);
    }

    #[test]
    fn gather_delivers_for_awkward_shapes() {
        for (p, g) in [(7usize, 3usize), (8, 2), (5, 5), (5, 1), (2, 2), (1, 1)] {
            let inputs: Vec<Vec<u8>> =
                (0..p).map(|w| vec![w as u8 + 1; (w * 11) % 23 + 1]).collect();
            let topo = Hierarchy::new(p, g);
            let mut f = fabric_for(&topo, &fast_cfg());
            let res = topo.allgatherv(&mut f, &inputs);
            for dst in 0..p {
                for src in 0..p {
                    assert_eq!(
                        res.gathered[dst][src], inputs[src],
                        "p={p} g={g} dst={dst} src={src}"
                    );
                }
            }
        }
    }

    #[test]
    fn reduce_matches_sum_for_awkward_shapes() {
        for (p, g) in [(7usize, 3usize), (8, 2), (5, 5), (5, 1), (1, 1)] {
            let inputs: Vec<Vec<f32>> = (0..p)
                .map(|w| (0..6).map(|k| (w * 6 + k) as f32 * 0.5).collect())
                .collect();
            let topo = Hierarchy::new(p, g);
            let mut f = fabric_for(&topo, &fast_cfg());
            let res = topo.allreduce(&mut f, &inputs);
            for k in 0..6 {
                let want: f32 = inputs.iter().map(|v| v[k]).sum();
                for node in 0..p {
                    let got = res.reduced[node][k];
                    assert!(
                        (got - want).abs() < 1e-3,
                        "p={p} g={g} node={node} k={k}: {got} != {want}"
                    );
                }
            }
        }
    }

    #[test]
    fn slower_uplinks_slow_the_collective() {
        let p = 8;
        let inputs: Vec<Vec<u8>> = (0..p).map(|_| vec![6u8; 10_000]).collect();
        let topo = Hierarchy::new(p, 2);
        let time_at = |uplink: f64| {
            let cfg = FabricConfig {
                inter_rack_gbps: Some(uplink),
                ..fast_cfg()
            };
            let mut f = fabric_for(&topo, &cfg);
            topo.allgatherv(&mut f, &inputs).time_ps
        };
        let fast = time_at(1.0); // uplink == intra bandwidth
        let slow = time_at(0.05);
        assert!(
            slow > fast,
            "uplink bandwidth had no effect: {fast} vs {slow}"
        );
    }

    #[test]
    fn cross_rack_traffic_crosses_each_uplink_once_per_block() {
        // 4 workers in 2 racks: {0,1} and {2,3}. Worker 1's block must
        // cross the 0→2 uplink exactly once.
        let inputs: Vec<Vec<u8>> = (0..4).map(|w| vec![w as u8; 100]).collect();
        let topo = Hierarchy::new(4, 2);
        let mut f = fabric_for(&topo, &fast_cfg());
        let res = topo.allgatherv(&mut f, &inputs);
        assert_eq!(res.traffic.rounds, 3);
        assert_eq!(f.links()[&(0, 2)].messages, 2); // blocks 0 and 1
        assert_eq!(f.links()[&(2, 0)].messages, 2); // blocks 2 and 3
    }
}
