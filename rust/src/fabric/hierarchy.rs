//! NUMA-aware hierarchy topology: workers partitioned into groups
//! (racks / NUMA domains) with fast intra-group links and slow
//! inter-group uplinks.
//!
//! `hier:<g>` splits the workers into `g` contiguous, balanced groups
//! ([`group_spans`]); `hier` alone picks `≈ √p` groups
//! ([`auto_groups`]). The lowest id of each group is its leader, and
//! leaders are themselves workers — no extra infrastructure node.
//! Collectives run the three NUMA phases:
//!
//! 1. **reduce/collect within** — members send to their group leader
//!    over fast intra-group links;
//! 2. **exchange across** — leaders swap group aggregates (or blocks)
//!    pairwise over the slow uplinks, so each datum crosses the rack
//!    boundary exactly once;
//! 3. **broadcast within** — leaders fan results back to members.
//!
//! The bandwidth skew is what distinguishes this from [`super::tree`]:
//! via [`Topology::link_overrides`] every leader↔leader edge resolves
//! to an uplink [`LinkSpec`] whose bandwidth is
//! `FabricConfig::inter_rack_gbps` (default: the base bandwidth / 10,
//! the classic 10:1 oversubscription). Explicit
//! `FabricConfig::link_overrides` still win (see `LinkTable`). Gather
//! traffic pipelines per segment when `FabricConfig::segment_bytes`
//! is set, so a long block starts crossing the uplink before it has
//! fully climbed out of its rack.
//!
//! ```
//! use vgc::fabric::{build_topology, Fabric, FabricConfig, TopologyKind};
//!
//! let cfg = FabricConfig {
//!     topology: TopologyKind::Hier { groups: 2 },
//!     inter_rack_gbps: Some(0.1),
//!     ..FabricConfig::default()
//! };
//! let topo = build_topology(cfg.topology, 4);
//! let mut fabric = Fabric::for_topology(&cfg, &*topo);
//! // Leaders 0 and 2 talk over the 0.1 Gbps uplink; members don't.
//! assert_eq!(fabric.link_table().spec(0, 2).bandwidth_gbps, 0.1);
//! assert_eq!(fabric.link_table().spec(0, 1).bandwidth_gbps, 1.0);
//! let inputs: Vec<Vec<u8>> = (0..4).map(|w| vec![w as u8; 16]).collect();
//! let out = topo.allgatherv(&mut fabric, &inputs);
//! assert_eq!(out.gathered[3][0], inputs[0]);
//! ```

use super::collectives::{split_all, traffic_from, GatherState, SimGather, SimReduce};
use super::topology::{Topology, TopologyKind};
use super::{Fabric, FabricConfig, LinkSpec, Msg, Payload, Protocol};

/// Member block/vector travelling up to its group leader.
const TAG_UP: u8 = 0;
/// Leader-to-leader exchange across the uplinks.
const TAG_XCHG: u8 = 1;
/// Leader fan-out down to its members.
const TAG_DOWN: u8 = 2;

/// Uplink bandwidth when `FabricConfig::inter_rack_gbps` is unset:
/// 10:1 oversubscription of the intra-group links.
pub const DEFAULT_OVERSUBSCRIPTION: f64 = 10.0;

/// Contiguous balanced partition of `p` workers into `groups` groups,
/// as `(start, len)` spans; the first `p mod groups` groups take the
/// extra worker.
pub fn group_spans(p: usize, groups: usize) -> Vec<(usize, usize)> {
    let g = groups.clamp(1, p.max(1));
    let base = p / g;
    let extra = p % g;
    let mut out = Vec::with_capacity(g);
    let mut start = 0;
    for i in 0..g {
        let len = base + usize::from(i < extra);
        out.push((start, len));
        start += len;
    }
    out
}

/// The auto group count for `hier` with no explicit `:g`: `≈ √p`,
/// balancing intra-group fan-in against uplink crossings.
pub fn auto_groups(p: usize) -> usize {
    ((p as f64).sqrt().round() as usize).clamp(1, p.max(1))
}

pub struct Hierarchy {
    p: usize,
    spans: Vec<(usize, usize)>,
}

impl Hierarchy {
    /// `groups` of 0 means "auto" (see [`auto_groups`]).
    pub fn new(workers: usize, groups: usize) -> Hierarchy {
        assert!(workers > 0, "topology needs at least one worker");
        let g = if groups == 0 {
            auto_groups(workers)
        } else {
            groups
        };
        assert!(
            g >= 1 && g <= workers,
            "hier wants {g} groups but only {workers} workers"
        );
        Hierarchy {
            p: workers,
            spans: group_spans(workers, g),
        }
    }

    fn groups(&self) -> usize {
        self.spans.len()
    }

    fn group_of(&self, w: usize) -> usize {
        self.spans
            .iter()
            .position(|&(s, l)| w >= s && w < s + l)
            .expect("worker outside every span")
    }

    fn leader(&self, g: usize) -> usize {
        self.spans[g].0
    }

    fn is_leader(&self, w: usize) -> bool {
        self.spans.iter().any(|&(s, _)| s == w)
    }

    fn leaders(&self) -> Vec<usize> {
        self.spans.iter().map(|&(s, _)| s).collect()
    }

    /// Members of group `g`, excluding its leader.
    fn members(&self, g: usize) -> Vec<usize> {
        let (s, l) = self.spans[g];
        (s + 1..s + l).collect()
    }
}

struct HierGather<'t> {
    t: &'t Hierarchy,
    segs: Vec<Vec<Vec<u8>>>,
    state: GatherState,
}

impl HierGather<'_> {
    fn msg(&self, origin: usize, seg: u32, hop: u32, tag: u8, payload: &Payload) -> Msg {
        Msg {
            origin,
            seg,
            hop,
            tag,
            payload: payload.clone(),
        }
    }
}

impl Protocol for HierGather<'_> {
    fn start(&mut self) -> Vec<(usize, usize, Msg)> {
        let mut out = Vec::new();
        for w in 0..self.t.p {
            let g = self.t.group_of(w);
            for (si, sg) in self.segs[w].iter().enumerate() {
                let si = si as u32;
                let payload = Payload::Bytes(sg.clone());
                if self.t.is_leader(w) {
                    for l in self.t.leaders() {
                        if l != w {
                            out.push((w, l, self.msg(w, si, 1, TAG_XCHG, &payload)));
                        }
                    }
                    for m in self.t.members(g) {
                        out.push((w, m, self.msg(w, si, 1, TAG_DOWN, &payload)));
                    }
                } else {
                    out.push((w, self.t.leader(g), self.msg(w, si, 1, TAG_UP, &payload)));
                }
            }
        }
        out
    }

    fn on_deliver(&mut self, node: usize, msg: &Msg) -> Vec<(usize, Msg)> {
        let Payload::Bytes(b) = &msg.payload else {
            unreachable!("gather protocol only moves bytes")
        };
        self.state.store(node, msg.origin, msg.seg as usize, b);
        if !self.t.is_leader(node) {
            return Vec::new();
        }
        let g = self.t.group_of(node);
        let mut out = Vec::new();
        match msg.tag {
            TAG_UP => {
                // A member segment: cross the uplinks and fan to the
                // rest of this group.
                for l in self.t.leaders() {
                    if l != node {
                        out.push((
                            l,
                            self.msg(msg.origin, msg.seg, msg.hop + 1, TAG_XCHG, &msg.payload),
                        ));
                    }
                }
                for m in self.t.members(g) {
                    if m != msg.origin {
                        out.push((
                            m,
                            self.msg(msg.origin, msg.seg, msg.hop + 1, TAG_DOWN, &msg.payload),
                        ));
                    }
                }
            }
            TAG_XCHG => {
                // Another rack's segment: broadcast within.
                for m in self.t.members(g) {
                    out.push((
                        m,
                        self.msg(msg.origin, msg.seg, msg.hop + 1, TAG_DOWN, &msg.payload),
                    ));
                }
            }
            other => unreachable!("leader received unexpected tag {other}"),
        }
        out
    }
}

struct HierReduce<'t> {
    t: &'t Hierarchy,
    n: usize,
    inputs: Vec<Vec<f32>>,
    /// Member vectors buffered at leaders, by member worker id.
    up: Vec<Option<Vec<f32>>>,
    /// Group partials buffered per receiving group, by sender group.
    partials: Vec<Vec<Option<Vec<f32>>>>,
    /// Final sums as seen by each worker.
    totals: Vec<Option<Vec<f32>>>,
}

impl HierReduce<'_> {
    /// Sum group `g` (leader + members, ascending id) — phase 1.
    fn group_partial(&self, g: usize) -> Vec<f32> {
        let mut sum = self.inputs[self.t.leader(g)].clone();
        for m in self.t.members(g) {
            let v = self.up[m].as_ref().expect("member vector missing");
            for (k, x) in v.iter().enumerate() {
                sum[k] += x;
            }
        }
        sum
    }

    /// Once group `g`'s leader holds every group partial, the grand
    /// total (ascending group order) and the phase-3 fan-out.
    fn try_finish(&mut self, g: usize, hop: u32) -> Vec<(usize, Msg)> {
        if self.partials[g].iter().any(|p| p.is_none()) {
            return Vec::new();
        }
        let mut total = vec![0.0f32; self.n];
        for slot in &self.partials[g] {
            let v = slot.as_ref().unwrap();
            for (k, x) in v.iter().enumerate() {
                total[k] += x;
            }
        }
        let leader = self.t.leader(g);
        self.totals[leader] = Some(total.clone());
        let payload = Payload::F32(total);
        self.t
            .members(g)
            .into_iter()
            .map(|m| {
                (
                    m,
                    Msg {
                        origin: leader,
                        seg: 0,
                        hop,
                        tag: TAG_DOWN,
                        payload: payload.clone(),
                    },
                )
            })
            .collect()
    }

    /// Group `g` is reduced: record the partial, exchange it across
    /// the uplinks (phase 2), and possibly finish (a single-group
    /// hierarchy finishes immediately).
    fn group_ready(&mut self, g: usize, hop: u32) -> Vec<(usize, Msg)> {
        let partial = self.group_partial(g);
        self.partials[g][g] = Some(partial.clone());
        let leader = self.t.leader(g);
        let payload = Payload::F32(partial);
        let mut out: Vec<(usize, Msg)> = self
            .t
            .leaders()
            .into_iter()
            .filter(|&l| l != leader)
            .map(|l| {
                (
                    l,
                    Msg {
                        origin: leader,
                        seg: 0,
                        hop,
                        tag: TAG_XCHG,
                        payload: payload.clone(),
                    },
                )
            })
            .collect();
        out.extend(self.try_finish(g, hop + 1));
        out
    }
}

impl Protocol for HierReduce<'_> {
    fn start(&mut self) -> Vec<(usize, usize, Msg)> {
        let mut out = Vec::new();
        for w in 0..self.t.p {
            if !self.t.is_leader(w) {
                out.push((
                    w,
                    self.t.leader(self.t.group_of(w)),
                    Msg {
                        origin: w,
                        seg: 0,
                        hop: 1,
                        tag: TAG_UP,
                        payload: Payload::F32(self.inputs[w].clone()),
                    },
                ));
            }
        }
        // Single-worker groups are reduced at t = 0.
        for g in 0..self.t.groups() {
            if self.t.members(g).is_empty() {
                let leader = self.t.leader(g);
                for (dst, msg) in self.group_ready(g, 1) {
                    out.push((leader, dst, msg));
                }
            }
        }
        out
    }

    fn on_deliver(&mut self, node: usize, msg: &Msg) -> Vec<(usize, Msg)> {
        let Payload::F32(v) = &msg.payload else {
            unreachable!("reduce protocol only moves f32 vectors")
        };
        match msg.tag {
            TAG_UP => {
                self.up[msg.origin] = Some(v.clone());
                let g = self.t.group_of(node);
                let complete = self
                    .t
                    .members(g)
                    .iter()
                    .all(|&m| self.up[m].is_some());
                if complete {
                    self.group_ready(g, msg.hop + 1)
                } else {
                    Vec::new()
                }
            }
            TAG_XCHG => {
                let g = self.t.group_of(node);
                self.partials[g][self.t.group_of(msg.origin)] = Some(v.clone());
                self.try_finish(g, msg.hop + 1)
            }
            TAG_DOWN => {
                self.totals[node] = Some(v.clone());
                Vec::new()
            }
            other => unreachable!("unknown hier reduce tag {other}"),
        }
    }
}

impl Topology for Hierarchy {
    fn kind(&self) -> TopologyKind {
        TopologyKind::Hier {
            groups: self.groups(),
        }
    }

    fn workers(&self) -> usize {
        self.p
    }

    fn link_overrides(&self, cfg: &FabricConfig) -> Vec<(usize, usize, LinkSpec)> {
        if self.groups() < 2 {
            return Vec::new();
        }
        let uplink = LinkSpec {
            bandwidth_gbps: cfg
                .inter_rack_gbps
                .unwrap_or(cfg.link.bandwidth_gbps / DEFAULT_OVERSUBSCRIPTION),
            ..cfg.link
        };
        let leaders = self.leaders();
        let mut out = Vec::new();
        for &a in &leaders {
            for &b in &leaders {
                if a != b {
                    out.push((a, b, uplink));
                }
            }
        }
        out
    }

    fn gather_rounds(&self) -> u32 {
        if self.p > 1 {
            3
        } else {
            0
        }
    }

    fn reduce_rounds(&self) -> u32 {
        if self.p > 1 {
            3
        } else {
            0
        }
    }

    fn allgatherv(&self, fabric: &mut Fabric, inputs: &[Vec<u8>]) -> SimGather {
        assert_eq!(inputs.len(), self.p, "one input message per worker");
        let seg = fabric.segment_bytes();
        let mut proto = HierGather {
            t: self,
            segs: split_all(inputs, seg),
            state: GatherState::new(inputs, seg),
        };
        let time_ps = if self.p > 1 { fabric.run(&mut proto) } else { 0 };
        SimGather {
            gathered: proto.state.into_gathered(),
            traffic: traffic_from(fabric, self.gather_rounds()),
            time_ps,
            events: fabric.events(),
        }
    }

    fn allreduce(&self, fabric: &mut Fabric, inputs: &[Vec<f32>]) -> SimReduce {
        assert_eq!(inputs.len(), self.p);
        let n = inputs[0].len();
        assert!(inputs.iter().all(|v| v.len() == n), "length mismatch");
        let mut proto = HierReduce {
            t: self,
            n,
            inputs: inputs.to_vec(),
            up: vec![None; self.p],
            partials: vec![vec![None; self.groups()]; self.groups()],
            totals: vec![None; self.p],
        };
        let time_ps = if self.p > 1 { fabric.run(&mut proto) } else { 0 };
        let reduced: Vec<Vec<f32>> = if self.p == 1 {
            vec![inputs[0].clone()]
        } else {
            proto
                .totals
                .iter()
                .map(|slot| slot.clone().expect("hier reduce under-delivered"))
                .collect()
        };
        SimReduce {
            reduced,
            traffic: traffic_from(fabric, self.reduce_rounds()),
            time_ps,
            events: fabric.events(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::FabricConfig;

    fn fabric_for(topo: &Hierarchy, cfg: &FabricConfig) -> Fabric {
        Fabric::for_topology(cfg, topo)
    }

    fn fast_cfg() -> FabricConfig {
        FabricConfig {
            link: LinkSpec {
                bandwidth_gbps: 1.0,
                latency_us: 1.0,
                jitter_us: 0.0,
            },
            topology: TopologyKind::Hier { groups: 0 },
            ..FabricConfig::default()
        }
    }

    #[test]
    fn spans_balance_and_cover() {
        assert_eq!(group_spans(8, 3), vec![(0, 3), (3, 3), (6, 2)]);
        assert_eq!(group_spans(4, 2), vec![(0, 2), (2, 2)]);
        assert_eq!(group_spans(3, 5), vec![(0, 1), (1, 1), (2, 1)]); // clamped
        assert_eq!(group_spans(5, 1), vec![(0, 5)]);
        assert_eq!(auto_groups(9), 3);
        assert_eq!(auto_groups(1), 1);
        assert_eq!(auto_groups(6), 2);
    }

    #[test]
    fn leadership_math() {
        let h = Hierarchy::new(8, 3);
        assert_eq!(h.leaders(), vec![0, 3, 6]);
        assert_eq!(h.group_of(4), 1);
        assert_eq!(h.members(2), vec![7]);
        assert_eq!(h.members(0), vec![1, 2]);
        assert!(h.is_leader(3));
        assert!(!h.is_leader(4));
    }

    #[test]
    fn uplink_overrides_cover_exactly_the_leader_pairs() {
        let h = Hierarchy::new(8, 3);
        let cfg = FabricConfig {
            inter_rack_gbps: Some(0.25),
            ..fast_cfg()
        };
        let ov = h.link_overrides(&cfg);
        assert_eq!(ov.len(), 6); // 3 leaders, ordered pairs
        assert!(ov.iter().all(|&(_, _, l)| l.bandwidth_gbps == 0.25));
        let f = fabric_for(&h, &cfg);
        assert_eq!(f.link_table().spec(0, 3).bandwidth_gbps, 0.25);
        assert_eq!(f.link_table().spec(3, 6).bandwidth_gbps, 0.25);
        assert_eq!(f.link_table().spec(0, 1).bandwidth_gbps, 1.0); // intra
        // Default uplink: 10:1 oversubscription.
        let f = fabric_for(&h, &fast_cfg());
        assert_eq!(f.link_table().spec(0, 3).bandwidth_gbps, 0.1);
    }

    #[test]
    fn gather_delivers_for_awkward_shapes() {
        for (p, g) in [(7usize, 3usize), (8, 2), (5, 5), (5, 1), (2, 2), (1, 1)] {
            let inputs: Vec<Vec<u8>> =
                (0..p).map(|w| vec![w as u8 + 1; (w * 11) % 23 + 1]).collect();
            let topo = Hierarchy::new(p, g);
            let mut f = fabric_for(&topo, &fast_cfg());
            let res = topo.allgatherv(&mut f, &inputs);
            for dst in 0..p {
                for src in 0..p {
                    assert_eq!(
                        res.gathered[dst][src], inputs[src],
                        "p={p} g={g} dst={dst} src={src}"
                    );
                }
            }
        }
    }

    #[test]
    fn reduce_matches_sum_for_awkward_shapes() {
        for (p, g) in [(7usize, 3usize), (8, 2), (5, 5), (5, 1), (1, 1)] {
            let inputs: Vec<Vec<f32>> = (0..p)
                .map(|w| (0..6).map(|k| (w * 6 + k) as f32 * 0.5).collect())
                .collect();
            let topo = Hierarchy::new(p, g);
            let mut f = fabric_for(&topo, &fast_cfg());
            let res = topo.allreduce(&mut f, &inputs);
            for k in 0..6 {
                let want: f32 = inputs.iter().map(|v| v[k]).sum();
                for node in 0..p {
                    let got = res.reduced[node][k];
                    assert!(
                        (got - want).abs() < 1e-3,
                        "p={p} g={g} node={node} k={k}: {got} != {want}"
                    );
                }
            }
        }
    }

    #[test]
    fn slower_uplinks_slow_the_collective() {
        let p = 8;
        let inputs: Vec<Vec<u8>> = (0..p).map(|_| vec![6u8; 10_000]).collect();
        let topo = Hierarchy::new(p, 2);
        let time_at = |uplink: f64| {
            let cfg = FabricConfig {
                inter_rack_gbps: Some(uplink),
                ..fast_cfg()
            };
            let mut f = fabric_for(&topo, &cfg);
            topo.allgatherv(&mut f, &inputs).time_ps
        };
        let fast = time_at(1.0); // uplink == intra bandwidth
        let slow = time_at(0.05);
        assert!(
            slow > fast,
            "uplink bandwidth had no effect: {fast} vs {slow}"
        );
    }

    #[test]
    fn cross_rack_traffic_crosses_each_uplink_once_per_block() {
        // 4 workers in 2 racks: {0,1} and {2,3}. Worker 1's block must
        // cross the 0→2 uplink exactly once.
        let inputs: Vec<Vec<u8>> = (0..4).map(|w| vec![w as u8; 100]).collect();
        let topo = Hierarchy::new(4, 2);
        let mut f = fabric_for(&topo, &fast_cfg());
        let res = topo.allgatherv(&mut f, &inputs);
        assert_eq!(res.traffic.rounds, 3);
        assert_eq!(f.links()[&(0, 2)].messages, 2); // blocks 0 and 1
        assert_eq!(f.links()[&(2, 0)].messages, 2); // blocks 2 and 3
    }
}
