//! Shared group-span machinery for the leader-based topologies.
//!
//! `tree` (fixed-width groups from a branch factor) and `hierarchy`
//! (count-parameterized balanced spans) run the *same* three-phase
//! leader protocol — members send up to their leader, leaders exchange
//! pairwise, leaders fan back down — and differ only in how workers
//! are partitioned into groups and which links are overridden.
//! [`GroupSpans`] captures the partition once and
//! [`GroupGather`]/[`GroupReduce`] implement the protocol once, so
//! fault handling lands in a single place: leader re-election after a
//! crash is simply rebuilding the spans over the survivor set, where
//! the lowest surviving id of each span leads.

use super::collectives::{GatherState, SegPayloads};
use super::{Msg, Payload, Protocol};

/// Member block/vector travelling up to its group leader.
const TAG_UP: u8 = 0;
/// Leader-to-leader exchange.
const TAG_XCHG: u8 = 1;
/// Leader fan-out down to its members.
const TAG_DOWN: u8 = 2;

/// A contiguous partition of `p` workers into leader-led groups, as
/// `(start, len)` spans. The lowest id of each span is its leader;
/// leaders are themselves workers — no extra infrastructure node.
#[derive(Debug, Clone)]
pub struct GroupSpans {
    p: usize,
    spans: Vec<(usize, usize)>,
}

impl GroupSpans {
    /// Fixed-width grouping (tree): group `g` spans
    /// `[g·branch, min((g+1)·branch, p))`.
    pub fn from_branch(p: usize, branch: usize) -> GroupSpans {
        assert!(p > 0, "topology needs at least one worker");
        assert!(branch >= 1, "group branch must be >= 1");
        let mut spans = Vec::new();
        let mut start = 0;
        while start < p {
            let len = branch.min(p - start);
            spans.push((start, len));
            start += len;
        }
        GroupSpans { p, spans }
    }

    /// Grouping from precomputed spans (hier's balanced partition).
    /// The spans must tile `0..p` contiguously.
    pub fn from_spans(p: usize, spans: Vec<(usize, usize)>) -> GroupSpans {
        assert!(p > 0, "topology needs at least one worker");
        debug_assert_eq!(
            spans.iter().map(|&(_, l)| l).sum::<usize>(),
            p,
            "spans must cover every worker exactly once"
        );
        GroupSpans { p, spans }
    }

    /// Worker count.
    pub fn workers(&self) -> usize {
        self.p
    }

    /// Number of groups.
    pub fn groups(&self) -> usize {
        self.spans.len()
    }

    /// The group containing worker `w`.
    pub fn group_of(&self, w: usize) -> usize {
        self.spans
            .iter()
            .position(|&(s, l)| w >= s && w < s + l)
            .expect("worker outside every span")
    }

    /// The leader (lowest id) of group `g`.
    pub fn leader(&self, g: usize) -> usize {
        self.spans[g].0
    }

    /// Whether worker `w` leads its group.
    pub fn is_leader(&self, w: usize) -> bool {
        self.spans.iter().any(|&(s, _)| s == w)
    }

    /// All group leaders, in ascending group order.
    pub fn leaders(&self) -> Vec<usize> {
        self.spans.iter().map(|&(s, _)| s).collect()
    }

    /// Members of group `g`, excluding its leader.
    pub fn members(&self, g: usize) -> Vec<usize> {
        let (s, l) = self.spans[g];
        (s + 1..s + l).collect()
    }

    /// Group `g`'s `(start, len)` span — allocation-free, for callers
    /// on a per-delivery hot path.
    pub fn span(&self, g: usize) -> (usize, usize) {
        self.spans[g]
    }
}

/// The three-phase leader-based allgatherv: members up, leaders
/// exchange, leaders fan down (segment-wise when the fabric configures
/// gather segmentation).
pub struct GroupGather<'g> {
    g: &'g GroupSpans,
    segs: SegPayloads,
    state: GatherState,
}

impl<'g> GroupGather<'g> {
    pub fn new(g: &'g GroupSpans, inputs: &[Vec<u8>], seg: usize) -> GroupGather<'g> {
        GroupGather {
            g,
            segs: SegPayloads::real(inputs, seg),
            state: GatherState::new(inputs, seg),
        }
    }

    /// Phantom-payload variant: identical protocol, sizes only.
    pub fn sized(g: &'g GroupSpans, sizes: &[u64], seg: usize) -> GroupGather<'g> {
        GroupGather {
            g,
            segs: SegPayloads::phantom(sizes, seg),
            state: GatherState::sized(sizes, seg),
        }
    }

    pub fn into_gathered(self) -> Vec<Vec<Vec<u8>>> {
        self.state.into_gathered()
    }

    fn msg(&self, origin: usize, seg: u32, hop: u32, tag: u8, payload: &Payload) -> Msg {
        Msg {
            origin,
            seg,
            hop,
            tag,
            payload: payload.clone(),
        }
    }
}

impl Protocol for GroupGather<'_> {
    fn start(&mut self) -> Vec<(usize, usize, Msg)> {
        let mut out = Vec::new();
        for w in 0..self.g.workers() {
            let grp = self.g.group_of(w);
            for si in 0..self.segs.seg_count(w) {
                let payload = self.segs.payload(w, si);
                let si = si as u32;
                if self.g.is_leader(w) {
                    for l in self.g.leaders() {
                        if l != w {
                            out.push((w, l, self.msg(w, si, 1, TAG_XCHG, &payload)));
                        }
                    }
                    for m in self.g.members(grp) {
                        out.push((w, m, self.msg(w, si, 1, TAG_DOWN, &payload)));
                    }
                } else {
                    out.push((w, self.g.leader(grp), self.msg(w, si, 1, TAG_UP, &payload)));
                }
            }
        }
        out
    }

    fn on_deliver(&mut self, node: usize, msg: &Msg) -> Vec<(usize, Msg)> {
        self.state
            .store_payload(node, msg.origin, msg.seg as usize, &msg.payload);
        if !self.g.is_leader(node) {
            return Vec::new();
        }
        let grp = self.g.group_of(node);
        let mut out = Vec::new();
        match msg.tag {
            TAG_UP => {
                // A member segment: cross to the other leaders and to
                // the rest of this group.
                for l in self.g.leaders() {
                    if l != node {
                        out.push((
                            l,
                            self.msg(msg.origin, msg.seg, msg.hop + 1, TAG_XCHG, &msg.payload),
                        ));
                    }
                }
                for m in self.g.members(grp) {
                    if m != msg.origin {
                        out.push((
                            m,
                            self.msg(msg.origin, msg.seg, msg.hop + 1, TAG_DOWN, &msg.payload),
                        ));
                    }
                }
            }
            TAG_XCHG => {
                // Another group's segment: fan down to this group.
                for m in self.g.members(grp) {
                    out.push((
                        m,
                        self.msg(msg.origin, msg.seg, msg.hop + 1, TAG_DOWN, &msg.payload),
                    ));
                }
            }
            other => unreachable!("leader received unexpected tag {other}"),
        }
        out
    }
}

/// The three-phase leader-based allreduce: group partials at the
/// leader (leader + members, ascending id), pairwise exchange of
/// partials, grand total in ascending group order, fan-out down.
pub struct GroupReduce<'g> {
    g: &'g GroupSpans,
    n: usize,
    inputs: Vec<Vec<f32>>,
    /// Member vectors buffered at leaders, by member worker id.
    up: Vec<Option<Vec<f32>>>,
    /// Group partials buffered per receiving group, by sender group.
    partials: Vec<Vec<Option<Vec<f32>>>>,
    /// Final sums as seen by each worker.
    totals: Vec<Option<Vec<f32>>>,
}

impl<'g> GroupReduce<'g> {
    pub fn new(g: &'g GroupSpans, inputs: &[Vec<f32>]) -> GroupReduce<'g> {
        let p = g.workers();
        let gn = g.groups();
        GroupReduce {
            g,
            n: inputs[0].len(),
            inputs: inputs.to_vec(),
            up: vec![None; p],
            partials: vec![vec![None; gn]; gn],
            totals: vec![None; p],
        }
    }

    pub fn into_totals(self) -> Vec<Vec<f32>> {
        self.totals
            .into_iter()
            .map(|slot| slot.expect("group reduce under-delivered"))
            .collect()
    }

    /// Sum group `grp` (leader + members, ascending id) — phase 1.
    fn group_partial(&self, grp: usize) -> Vec<f32> {
        let mut sum = self.inputs[self.g.leader(grp)].clone();
        for m in self.g.members(grp) {
            let v = self.up[m].as_ref().expect("member vector missing");
            for (k, x) in v.iter().enumerate() {
                sum[k] += x;
            }
        }
        sum
    }

    /// Once group `grp`'s leader holds every group partial, the grand
    /// total (ascending group order) and the phase-3 fan-out.
    fn try_finish(&mut self, grp: usize, hop: u32) -> Vec<(usize, Msg)> {
        if self.partials[grp].iter().any(|p| p.is_none()) {
            return Vec::new();
        }
        let mut total = vec![0.0f32; self.n];
        for slot in &self.partials[grp] {
            let v = slot.as_ref().unwrap();
            for (k, x) in v.iter().enumerate() {
                total[k] += x;
            }
        }
        let leader = self.g.leader(grp);
        self.totals[leader] = Some(total.clone());
        let payload = Payload::F32(total);
        self.g
            .members(grp)
            .into_iter()
            .map(|m| {
                (
                    m,
                    Msg {
                        origin: leader,
                        seg: 0,
                        hop,
                        tag: TAG_DOWN,
                        payload: payload.clone(),
                    },
                )
            })
            .collect()
    }

    /// Group `grp` is reduced: record the partial, exchange it across
    /// the leader links (phase 2), and possibly finish (a single-group
    /// partition finishes immediately).
    fn group_ready(&mut self, grp: usize, hop: u32) -> Vec<(usize, Msg)> {
        let partial = self.group_partial(grp);
        self.partials[grp][grp] = Some(partial.clone());
        let leader = self.g.leader(grp);
        let payload = Payload::F32(partial);
        let mut out: Vec<(usize, Msg)> = self
            .g
            .leaders()
            .into_iter()
            .filter(|&l| l != leader)
            .map(|l| {
                (
                    l,
                    Msg {
                        origin: leader,
                        seg: 0,
                        hop,
                        tag: TAG_XCHG,
                        payload: payload.clone(),
                    },
                )
            })
            .collect();
        out.extend(self.try_finish(grp, hop + 1));
        out
    }
}

impl Protocol for GroupReduce<'_> {
    fn start(&mut self) -> Vec<(usize, usize, Msg)> {
        let mut out = Vec::new();
        for w in 0..self.g.workers() {
            if !self.g.is_leader(w) {
                out.push((
                    w,
                    self.g.leader(self.g.group_of(w)),
                    Msg {
                        origin: w,
                        seg: 0,
                        hop: 1,
                        tag: TAG_UP,
                        payload: Payload::F32(self.inputs[w].clone()),
                    },
                ));
            }
        }
        // Groups that are just their leader are reduced at t = 0.
        for grp in 0..self.g.groups() {
            if self.g.members(grp).is_empty() {
                let leader = self.g.leader(grp);
                for (dst, msg) in self.group_ready(grp, 1) {
                    out.push((leader, dst, msg));
                }
            }
        }
        out
    }

    fn on_deliver(&mut self, node: usize, msg: &Msg) -> Vec<(usize, Msg)> {
        let Payload::F32(v) = &msg.payload else {
            unreachable!("reduce protocol only moves f32 vectors")
        };
        match msg.tag {
            TAG_UP => {
                self.up[msg.origin] = Some(v.clone());
                let grp = self.g.group_of(node);
                let complete = self
                    .g
                    .members(grp)
                    .iter()
                    .all(|&m| self.up[m].is_some());
                if complete {
                    self.group_ready(grp, msg.hop + 1)
                } else {
                    Vec::new()
                }
            }
            TAG_XCHG => {
                let grp = self.g.group_of(node);
                self.partials[grp][self.g.group_of(msg.origin)] = Some(v.clone());
                self.try_finish(grp, msg.hop + 1)
            }
            TAG_DOWN => {
                self.totals[node] = Some(v.clone());
                Vec::new()
            }
            other => unreachable!("unknown group reduce tag {other}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn branch_spans_tile_the_workers() {
        let g = GroupSpans::from_branch(10, 4);
        assert_eq!(g.groups(), 3);
        assert_eq!(g.leaders(), vec![0, 4, 8]);
        assert_eq!(g.members(2), vec![9]);
        assert_eq!(g.group_of(5), 1);
        assert!(g.is_leader(8));
        assert!(!g.is_leader(9));
    }

    #[test]
    fn span_constructor_round_trips_hier_partitions() {
        let g = GroupSpans::from_spans(5, vec![(0, 2), (2, 2), (4, 1)]);
        assert_eq!(g.groups(), 3);
        assert_eq!(g.leaders(), vec![0, 2, 4]);
        assert_eq!(g.members(0), vec![1]);
        assert!(g.members(2).is_empty());
    }

    /// Tree and hierarchy are two front-ends over this one protocol:
    /// when their spans coincide (p=8: branch 4 ⇔ 2 balanced groups)
    /// and the hierarchy's uplink is pinned to the base bandwidth, the
    /// collectives must agree byte-for-byte *and* tick-for-tick.
    #[test]
    fn tree_and_hier_with_matching_spans_run_the_identical_protocol() {
        use crate::fabric::hierarchy::Hierarchy;
        use crate::fabric::tree::Tree;
        use crate::fabric::{Fabric, FabricConfig, LinkSpec, Topology, TopologyKind};

        let p = 8;
        let tree = Tree::new(p, 4);
        let hier = Hierarchy::new(p, 2);
        let cfg = |kind: TopologyKind, uplink: Option<f64>| FabricConfig {
            topology: kind,
            link: LinkSpec {
                bandwidth_gbps: 1.0,
                latency_us: 1.0,
                jitter_us: 0.0,
            },
            inter_rack_gbps: uplink,
            ..FabricConfig::default()
        };
        // Uplink = base bandwidth neutralizes the hierarchy's only
        // distinguishing feature (the oversubscribed leader links).
        let tree_cfg = cfg(tree.kind(), None);
        let hier_cfg = cfg(hier.kind(), Some(1.0));

        let inputs: Vec<Vec<u8>> =
            (0..p).map(|w| vec![w as u8 + 1; (w * 17) % 31 + 1]).collect();
        let mut ft = Fabric::for_topology(&tree_cfg, &tree);
        let mut fh = Fabric::for_topology(&hier_cfg, &hier);
        let gt = tree.allgatherv(&mut ft, &inputs);
        let gh = hier.allgatherv(&mut fh, &inputs);
        assert_eq!(gt.gathered, gh.gathered, "gathered bytes diverged");
        assert_eq!(gt.time_ps, gh.time_ps, "simulated clocks diverged");
        assert_eq!(gt.traffic.rounds, gh.traffic.rounds);

        let vecs: Vec<Vec<f32>> = (0..p)
            .map(|w| (0..5).map(|k| (w * 5 + k) as f32 * 0.25).collect())
            .collect();
        let mut ft = Fabric::for_topology(&tree_cfg, &tree);
        let mut fh = Fabric::for_topology(&hier_cfg, &hier);
        let rt = tree.allreduce(&mut ft, &vecs);
        let rh = hier.allreduce(&mut fh, &vecs);
        for (a, b) in rt.reduced.iter().zip(rh.reduced.iter()) {
            let ab: Vec<u32> = a.iter().map(|x| x.to_bits()).collect();
            let bb: Vec<u32> = b.iter().map(|x| x.to_bits()).collect();
            assert_eq!(ab, bb, "reduced totals diverged bit-wise");
        }
        assert_eq!(rt.time_ps, rh.time_ps);
    }
}
