//! Shared collective plumbing: result types, gather bookkeeping, and
//! the chunking rule the ring allreduce inherits from `comm`.
//!
//! Every topology backend produces the same result shapes, so callers
//! (the `comm` fronts, `fabric-sweep`, tests) are topology-agnostic:
//! `gathered[dst][src]` is worker `src`'s message as received by
//! worker `dst`; `reduced[w]` is worker `w`'s copy of the elementwise
//! sum. Byte identity with the lockstep `comm` implementations is a
//! hard invariant (tested property-style in `tests/fabric_sim.rs`).

use super::clock::Time;
use super::Fabric;
use crate::comm::Traffic;

/// An allgatherv outcome over any topology.
pub struct SimGather {
    /// `gathered[dst][src]` — every row must equal the input row.
    pub gathered: Vec<Vec<Vec<u8>>>,
    /// Per-*node* egress bytes (workers first, then any infrastructure
    /// nodes such as the parameter-server hub) + logical round count.
    pub traffic: Traffic,
    /// Simulated completion time, ps.
    pub time_ps: Time,
    /// Deliveries processed.
    pub events: u64,
}

impl SimGather {
    pub fn time_secs(&self) -> f64 {
        self.time_ps as f64 * 1e-12
    }
}

/// An allreduce outcome over any topology.
pub struct SimReduce {
    pub reduced: Vec<Vec<f32>>,
    pub traffic: Traffic,
    pub time_ps: Time,
    pub events: u64,
}

impl SimReduce {
    pub fn time_secs(&self) -> f64 {
        self.time_ps as f64 * 1e-12
    }
}

/// Pack the fabric's accounting into the `comm::Traffic` shape.
pub fn traffic_from(fabric: &Fabric, rounds: u32) -> Traffic {
    Traffic {
        bytes_sent_per_node: fabric.bytes_sent_per_node(),
        rounds,
    }
}

/// Per-worker block bookkeeping for gather protocols: which origins
/// each worker holds. Duplicate deliveries of conflicting content are
/// protocol bugs and assert.
pub struct GatherState {
    blocks: Vec<Vec<Option<Vec<u8>>>>,
}

impl GatherState {
    /// Seed each worker with its own block.
    pub fn new(inputs: &[Vec<u8>]) -> GatherState {
        let p = inputs.len();
        GatherState {
            blocks: (0..p)
                .map(|i| {
                    let mut row = vec![None; p];
                    row[i] = Some(inputs[i].clone());
                    row
                })
                .collect(),
        }
    }

    /// Record that `worker` received `origin`'s block.
    pub fn store(&mut self, worker: usize, origin: usize, bytes: &[u8]) {
        let slot = &mut self.blocks[worker][origin];
        debug_assert!(
            slot.is_none() || slot.as_deref() == Some(bytes),
            "conflicting delivery of origin {origin} at worker {worker}"
        );
        if slot.is_none() {
            *slot = Some(bytes.to_vec());
        }
    }

    /// True once `worker` holds every origin.
    pub fn complete(&self, worker: usize) -> bool {
        self.blocks[worker].iter().all(|b| b.is_some())
    }

    /// Consume into the `gathered[dst][src]` matrix; panics if any
    /// block never arrived (the protocol under-delivered).
    pub fn into_gathered(self) -> Vec<Vec<Vec<u8>>> {
        self.blocks
            .into_iter()
            .enumerate()
            .map(|(w, row)| {
                row.into_iter()
                    .enumerate()
                    .map(|(o, b)| {
                        b.unwrap_or_else(|| panic!("worker {w} never received origin {o}"))
                    })
                    .collect()
            })
            .collect()
    }
}

/// Chunk boundaries for the ring allreduce — identical to the lockstep
/// `comm::allreduce` rule so byte counts and f32 sums match exactly:
/// chunk `c` covers `[c·n/p, (c+1)·n/p)`.
pub fn chunk_range(n: usize, p: usize, c: usize) -> std::ops::Range<usize> {
    let start = |c: usize| c * n / p;
    start(c % p)..start(c % p + 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gather_state_tracks_completion() {
        let inputs = vec![vec![1u8], vec![2, 2], vec![]];
        let mut gs = GatherState::new(&inputs);
        assert!(!gs.complete(0));
        gs.store(0, 1, &[2, 2]);
        gs.store(0, 2, &[]);
        assert!(gs.complete(0));
        gs.store(1, 0, &[1]);
        gs.store(1, 2, &[]);
        gs.store(2, 0, &[1]);
        gs.store(2, 1, &[2, 2]);
        let g = gs.into_gathered();
        for dst in 0..3 {
            for src in 0..3 {
                assert_eq!(g[dst][src], inputs[src]);
            }
        }
    }

    #[test]
    #[should_panic(expected = "never received")]
    fn incomplete_gather_panics_on_assembly() {
        let gs = GatherState::new(&[vec![1u8], vec![2u8]]);
        let _ = gs.into_gathered();
    }

    #[test]
    fn chunk_ranges_partition_exactly() {
        for (n, p) in [(100, 4), (97, 8), (3, 5), (0, 2)] {
            let mut covered = 0usize;
            for c in 0..p {
                let r = chunk_range(n, p, c);
                assert_eq!(r.start, covered);
                covered = r.end;
            }
            assert_eq!(covered, n);
        }
    }

    #[test]
    fn chunk_range_wraps_modulo_p() {
        assert_eq!(chunk_range(100, 4, 5), chunk_range(100, 4, 1));
    }
}
