//! Shared collective plumbing: result types, (segmented) gather
//! bookkeeping, and the chunking rule the ring allreduce inherits from
//! `comm`.
//!
//! Every topology backend produces the same result shapes, so callers
//! (the `comm` fronts, `fabric-sweep`, tests) are topology-agnostic:
//! `gathered[dst][src]` is worker `src`'s message as received by
//! worker `dst`; `reduced[w]` is worker `w`'s copy of the elementwise
//! sum. Byte identity with the lockstep `comm` implementations is a
//! hard invariant (tested property-style in `tests/fabric_sim.rs`).
//!
//! Gather protocols optionally pipeline: when the fabric is configured
//! with a segment size (`FabricConfig::segment_bytes`, the cost
//! model's block size `m`), [`split_message`] cuts each wire message
//! into segments that traverse the topology independently and
//! [`GatherState`] reassembles them in order — so a long message no
//! longer store-and-forwards whole at every hop, and the simulated
//! ring time converges to the paper's pipelined `T_v` bound even for
//! skewed per-node message sizes.

use super::clock::Time;
use super::{Fabric, Payload};
use crate::comm::Traffic;

/// An allgatherv outcome over any topology.
pub struct SimGather {
    /// `gathered[dst][src]` — every row must equal the input row.
    /// Empty for sized (phantom) gathers, which move no content
    /// (`Topology::allgatherv_sized`).
    pub gathered: Vec<Vec<Vec<u8>>>,
    /// Per-*node* egress bytes (workers first, then any infrastructure
    /// nodes such as the parameter-server hub) + logical round count.
    pub traffic: Traffic,
    /// Simulated completion time, ps.
    pub time_ps: Time,
    /// Deliveries processed.
    pub events: u64,
}

impl SimGather {
    pub fn time_secs(&self) -> f64 {
        self.time_ps as f64 * 1e-12
    }
}

/// An allreduce outcome over any topology.
pub struct SimReduce {
    pub reduced: Vec<Vec<f32>>,
    pub traffic: Traffic,
    pub time_ps: Time,
    pub events: u64,
}

impl SimReduce {
    pub fn time_secs(&self) -> f64 {
        self.time_ps as f64 * 1e-12
    }
}

/// Pack the fabric's accounting into the `comm::Traffic` shape.
pub fn traffic_from(fabric: &Fabric, rounds: u32) -> Traffic {
    Traffic {
        bytes_sent_per_node: fabric.bytes_sent_per_node(),
        rounds,
    }
}

/// Split one wire message into pipeline segments of at most
/// `seg_bytes` bytes (`0` disables segmentation). Every message yields
/// at least one segment, so empty messages still traverse the
/// protocol and reassemble.
pub fn split_message(bytes: &[u8], seg_bytes: usize) -> Vec<Vec<u8>> {
    if seg_bytes == 0 || bytes.len() <= seg_bytes {
        return vec![bytes.to_vec()];
    }
    bytes.chunks(seg_bytes).map(|c| c.to_vec()).collect()
}

/// Per-worker segment lists for a whole input set.
pub fn split_all(inputs: &[Vec<u8>], seg_bytes: usize) -> Vec<Vec<Vec<u8>>> {
    inputs.iter().map(|m| split_message(m, seg_bytes)).collect()
}

/// Segments `split_message` produces for a message of `len` bytes.
fn seg_count(len: usize, seg_bytes: usize) -> usize {
    if seg_bytes == 0 || len == 0 {
        1
    } else {
        len.div_ceil(seg_bytes)
    }
}

/// Segment *sizes* for a message of `len` bytes — exactly the lengths
/// [`split_message`] would produce, without materializing content.
/// The phantom gather path relies on this mirroring being exact so a
/// sized run bills byte-for-byte what a real run would.
pub fn split_size(len: u64, seg_bytes: usize) -> Vec<u64> {
    let seg = seg_bytes as u64;
    if seg == 0 || len <= seg {
        return vec![len];
    }
    let count = len.div_ceil(seg);
    let mut out = vec![seg; count as usize];
    *out.last_mut().expect("count >= 2") = len - (count - 1) * seg;
    out
}

/// A gather protocol's per-worker segment payloads: real codec bytes,
/// or phantom sizes that traverse the identical protocol/engine code
/// while moving no content. Timing never depends on payload content
/// (links bill sizes; jitter draws are per send in call order), so a
/// phantom run is tick-identical to a real run of the same sizes —
/// the tier-2 fast path `tests/scale_parity.rs` pins.
pub enum SegPayloads {
    Real(Vec<Vec<Vec<u8>>>),
    Phantom(Vec<Vec<u64>>),
}

impl SegPayloads {
    /// Real mode: split every input message into pipeline segments.
    pub fn real(inputs: &[Vec<u8>], seg_bytes: usize) -> SegPayloads {
        SegPayloads::Real(split_all(inputs, seg_bytes))
    }

    /// Phantom mode: per-worker segment sizes only.
    pub fn phantom(sizes: &[u64], seg_bytes: usize) -> SegPayloads {
        SegPayloads::Phantom(sizes.iter().map(|&n| split_size(n, seg_bytes)).collect())
    }

    /// Segments worker `w`'s message was cut into.
    pub fn seg_count(&self, w: usize) -> usize {
        match self {
            SegPayloads::Real(s) => s[w].len(),
            SegPayloads::Phantom(s) => s[w].len(),
        }
    }

    /// The wire payload for segment `si` of worker `w`'s message.
    pub fn payload(&self, w: usize, si: usize) -> Payload {
        match self {
            SegPayloads::Real(s) => Payload::Bytes(s[w][si].clone()),
            SegPayloads::Phantom(s) => Payload::Phantom(s[w][si]),
        }
    }
}

/// Per-worker block bookkeeping for gather protocols: which origin
/// segments each worker holds. Duplicate deliveries of conflicting
/// content are protocol bugs and assert. Segments may arrive out of
/// order (jitter reorders same-link deliveries); reassembly is by
/// segment index, not arrival order.
///
/// Phantom mode ([`GatherState::sized`]) keeps only O(p) counters —
/// received vs expected segments per worker — since there is no
/// content to reassemble; a p×p×seg matrix of empty slots would cost
/// hundreds of MB at 4096 nodes for bookkeeping nobody reads.
pub struct GatherState {
    blocks: Blocks,
}

enum Blocks {
    /// `blocks[worker][origin][seg]`.
    Real(Vec<Vec<Vec<Option<Vec<u8>>>>>),
    Phantom {
        /// Segments worker `w` holds (own block pre-seeded).
        received: Vec<u64>,
        /// Total segments worker `w` must end up holding.
        expected: Vec<u64>,
    },
}

impl GatherState {
    /// Seed each worker with its own (pre-split) block.
    pub fn new(inputs: &[Vec<u8>], seg_bytes: usize) -> GatherState {
        let p = inputs.len();
        GatherState {
            blocks: Blocks::Real(
                (0..p)
                    .map(|i| {
                        (0..p)
                            .map(|o| {
                                if o == i {
                                    split_message(&inputs[i], seg_bytes)
                                        .into_iter()
                                        .map(Some)
                                        .collect()
                                } else {
                                    vec![None; seg_count(inputs[o].len(), seg_bytes)]
                                }
                            })
                            .collect()
                    })
                    .collect(),
            ),
        }
    }

    /// Phantom-mode bookkeeping for a sized gather: counters only.
    pub fn sized(sizes: &[u64], seg_bytes: usize) -> GatherState {
        let segs: Vec<u64> = sizes
            .iter()
            .map(|&n| seg_count(n as usize, seg_bytes) as u64)
            .collect();
        let total: u64 = segs.iter().sum();
        GatherState {
            blocks: Blocks::Phantom {
                received: segs,
                expected: vec![total; sizes.len()],
            },
        }
    }

    /// Record that `worker` received segment `seg` of `origin`'s block.
    pub fn store(&mut self, worker: usize, origin: usize, seg: usize, bytes: &[u8]) {
        match &mut self.blocks {
            Blocks::Real(blocks) => {
                let slot = &mut blocks[worker][origin][seg];
                debug_assert!(
                    slot.is_none() || slot.as_deref() == Some(bytes),
                    "conflicting delivery of origin {origin} segment {seg} at worker {worker}"
                );
                if slot.is_none() {
                    *slot = Some(bytes.to_vec());
                }
            }
            Blocks::Phantom { received, .. } => received[worker] += 1,
        }
    }

    /// Record a delivery of either payload kind — the one store call
    /// every protocol makes, so real and phantom runs execute the
    /// identical protocol code path.
    pub fn store_payload(&mut self, worker: usize, origin: usize, seg: usize, payload: &Payload) {
        match payload {
            Payload::Bytes(b) => self.store(worker, origin, seg, b),
            Payload::Phantom(_) => match &mut self.blocks {
                Blocks::Phantom { received, .. } => received[worker] += 1,
                Blocks::Real(_) => {
                    unreachable!("phantom delivery into a real-bytes gather state")
                }
            },
            Payload::F32(_) => unreachable!("f32 payload in a gather protocol"),
        }
    }

    /// True once `worker` holds every segment of every origin.
    pub fn complete(&self, worker: usize) -> bool {
        match &self.blocks {
            Blocks::Real(blocks) => blocks[worker].iter().flatten().all(|b| b.is_some()),
            Blocks::Phantom { received, expected } => received[worker] >= expected[worker],
        }
    }

    /// Consume into the `gathered[dst][src]` matrix, concatenating
    /// segments in index order; panics if any segment never arrived
    /// (the protocol under-delivered). Phantom mode yields an empty
    /// matrix after asserting every worker completed.
    pub fn into_gathered(self) -> Vec<Vec<Vec<u8>>> {
        match self.blocks {
            Blocks::Real(blocks) => blocks
                .into_iter()
                .enumerate()
                .map(|(w, row)| {
                    row.into_iter()
                        .enumerate()
                        .map(|(o, segs)| {
                            let mut msg = Vec::new();
                            for (si, b) in segs.into_iter().enumerate() {
                                let seg = b.unwrap_or_else(|| {
                                    panic!("worker {w} never received origin {o} segment {si}")
                                });
                                msg.extend_from_slice(&seg);
                            }
                            msg
                        })
                        .collect()
                })
                .collect(),
            Blocks::Phantom { received, expected } => {
                for (w, (r, e)) in received.iter().zip(&expected).enumerate() {
                    assert!(
                        r >= e,
                        "worker {w} received {r} of {e} expected segments"
                    );
                }
                Vec::new()
            }
        }
    }
}

/// Chunk boundaries for the ring allreduce — identical to the lockstep
/// `comm::allreduce` rule so byte counts and f32 sums match exactly:
/// chunk `c` covers `[c·n/p, (c+1)·n/p)`.
pub fn chunk_range(n: usize, p: usize, c: usize) -> std::ops::Range<usize> {
    let start = |c: usize| c * n / p;
    start(c % p)..start(c % p + 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gather_state_tracks_completion() {
        let inputs = vec![vec![1u8], vec![2, 2], vec![]];
        let mut gs = GatherState::new(&inputs, 0);
        assert!(!gs.complete(0));
        gs.store(0, 1, 0, &[2, 2]);
        gs.store(0, 2, 0, &[]);
        assert!(gs.complete(0));
        gs.store(1, 0, 0, &[1]);
        gs.store(1, 2, 0, &[]);
        gs.store(2, 0, 0, &[1]);
        gs.store(2, 1, 0, &[2, 2]);
        let g = gs.into_gathered();
        for dst in 0..3 {
            for src in 0..3 {
                assert_eq!(g[dst][src], inputs[src]);
            }
        }
    }

    #[test]
    #[should_panic(expected = "never received")]
    fn incomplete_gather_panics_on_assembly() {
        let gs = GatherState::new(&[vec![1u8], vec![2u8]], 0);
        let _ = gs.into_gathered();
    }

    #[test]
    fn split_message_covers_edges() {
        assert_eq!(split_message(&[], 0), vec![Vec::<u8>::new()]);
        assert_eq!(split_message(&[], 4), vec![Vec::<u8>::new()]);
        assert_eq!(split_message(&[1, 2, 3], 0), vec![vec![1, 2, 3]]);
        assert_eq!(split_message(&[1, 2, 3], 3), vec![vec![1, 2, 3]]);
        assert_eq!(split_message(&[1, 2, 3], 2), vec![vec![1, 2], vec![3]]);
        for (len, seg) in [(0usize, 0usize), (0, 3), (7, 3), (7, 0), (6, 3), (1, 9)] {
            let msg: Vec<u8> = (0..len as u8).collect();
            let parts = split_message(&msg, seg);
            assert_eq!(parts.len(), seg_count(len, seg), "len={len} seg={seg}");
            assert_eq!(parts.concat(), msg, "len={len} seg={seg}");
        }
    }

    #[test]
    fn segmented_state_reassembles_out_of_order() {
        let inputs = vec![vec![9u8; 5], vec![1, 2, 3, 4, 5, 6, 7]];
        let mut gs = GatherState::new(&inputs, 3);
        // Worker 0 receives origin 1's segments in reverse order.
        gs.store(0, 1, 2, &[7]);
        gs.store(0, 1, 1, &[4, 5, 6]);
        assert!(!gs.complete(0));
        gs.store(0, 1, 0, &[1, 2, 3]);
        assert!(gs.complete(0));
        gs.store(1, 0, 1, &[9, 9]);
        gs.store(1, 0, 0, &[9, 9, 9]);
        let g = gs.into_gathered();
        for dst in 0..2 {
            for src in 0..2 {
                assert_eq!(g[dst][src], inputs[src], "dst={dst} src={src}");
            }
        }
    }

    #[test]
    fn split_size_mirrors_split_message_exactly() {
        for (len, seg) in [
            (0usize, 0usize),
            (0, 4),
            (3, 0),
            (3, 3),
            (3, 2),
            (7, 3),
            (4096, 512),
            (4097, 512),
            (1, 9),
        ] {
            let msg: Vec<u8> = (0..len).map(|i| i as u8).collect();
            let real: Vec<u64> = split_message(&msg, seg)
                .iter()
                .map(|s| s.len() as u64)
                .collect();
            assert_eq!(split_size(len as u64, seg), real, "len={len} seg={seg}");
        }
    }

    #[test]
    fn seg_payloads_agree_across_modes() {
        let inputs = vec![vec![1u8; 7], vec![2u8; 3], vec![]];
        let sizes: Vec<u64> = inputs.iter().map(|m| m.len() as u64).collect();
        for seg in [0usize, 2, 4, 16] {
            let real = SegPayloads::real(&inputs, seg);
            let phantom = SegPayloads::phantom(&sizes, seg);
            for w in 0..inputs.len() {
                assert_eq!(real.seg_count(w), phantom.seg_count(w), "w={w} seg={seg}");
                for si in 0..real.seg_count(w) {
                    assert_eq!(
                        real.payload(w, si).size_bytes(),
                        phantom.payload(w, si).size_bytes(),
                        "w={w} si={si} seg={seg}"
                    );
                }
            }
        }
    }

    #[test]
    fn phantom_state_counts_to_completion() {
        let sizes = [5u64, 7, 0];
        let mut gs = GatherState::sized(&sizes, 3);
        // Worker 0 holds its own 2 segments of 8 expected
        // (2 + 3 + 1 segment counts + own... totals per worker: 6).
        assert!(!gs.complete(0));
        gs.store_payload(0, 1, 0, &Payload::Phantom(3));
        gs.store_payload(0, 1, 1, &Payload::Phantom(3));
        gs.store_payload(0, 1, 2, &Payload::Phantom(1));
        assert!(!gs.complete(0));
        gs.store_payload(0, 2, 0, &Payload::Phantom(0));
        assert!(gs.complete(0));
        assert!(!gs.complete(1));
    }

    #[test]
    #[should_panic(expected = "received")]
    fn incomplete_phantom_gather_panics_on_assembly() {
        let gs = GatherState::sized(&[4, 4], 0);
        let _ = gs.into_gathered();
    }

    #[test]
    fn complete_phantom_gather_yields_empty_matrix() {
        let mut gs = GatherState::sized(&[4, 4], 0);
        gs.store_payload(0, 1, 0, &Payload::Phantom(4));
        gs.store_payload(1, 0, 0, &Payload::Phantom(4));
        assert!(gs.complete(0) && gs.complete(1));
        assert!(gs.into_gathered().is_empty());
    }

    #[test]
    fn chunk_ranges_partition_exactly() {
        for (n, p) in [(100, 4), (97, 8), (3, 5), (0, 2)] {
            let mut covered = 0usize;
            for c in 0..p {
                let r = chunk_range(n, p, c);
                assert_eq!(r.start, covered);
                covered = r.end;
            }
            assert_eq!(covered, n);
        }
    }

    #[test]
    fn chunk_range_wraps_modulo_p() {
        assert_eq!(chunk_range(100, 4, 5), chunk_range(100, 4, 1));
    }
}
