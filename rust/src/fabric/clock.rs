//! Deterministic discrete-event clock.
//!
//! The fabric never sleeps: simulated time is a `u64` picosecond
//! counter advanced by popping the earliest scheduled event. Ties are
//! broken by insertion sequence number, so two runs that schedule the
//! same events in the same order replay identically — the foundation
//! of the fabric's determinism guarantee (tested in
//! `tests/fabric_sim.rs`).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Simulated time in picoseconds. 1 Gbps = 1 bit/ns = 1000 ps/bit, so
/// picoseconds resolve both commodity and InfiniBand-class links; u64
/// picoseconds cover ~213 simulated days.
pub type Time = u64;

/// Picoseconds per microsecond (the CLI's human unit).
pub const PS_PER_US: f64 = 1e6;

struct Entry<E> {
    at: Time,
    seq: u64,
    ev: E,
}

// BinaryHeap is a max-heap; invert the ordering to pop earliest
// (time, seq) first. Only (at, seq) participate — the payload needs no
// Ord.
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        (other.at, other.seq).cmp(&(self.at, self.seq))
    }
}

impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}

impl<E> Eq for Entry<E> {}

/// Min-heap event queue + current simulated time.
pub struct SimClock<E> {
    now: Time,
    seq: u64,
    processed: u64,
    heap: BinaryHeap<Entry<E>>,
}

impl<E> Default for SimClock<E> {
    fn default() -> Self {
        SimClock::new()
    }
}

impl<E> SimClock<E> {
    pub fn new() -> SimClock<E> {
        SimClock {
            now: 0,
            seq: 0,
            processed: 0,
            heap: BinaryHeap::new(),
        }
    }

    /// Current simulated time (the timestamp of the last popped event).
    pub fn now(&self) -> Time {
        self.now
    }

    /// Events popped so far (the fabric's throughput denominator).
    pub fn processed(&self) -> u64 {
        self.processed
    }

    pub fn pending(&self) -> usize {
        self.heap.len()
    }

    /// Schedule `ev` at absolute time `at`. Scheduling in the past is a
    /// causality bug in the caller, not a recoverable condition.
    pub fn schedule(&mut self, at: Time, ev: E) {
        assert!(
            at >= self.now,
            "event scheduled in the past ({} < {})",
            at,
            self.now
        );
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Entry { at, seq, ev });
    }

    /// Jump `now` forward to `t` (no-op if `t` is in the past). Only
    /// legal while the queue is idle — advancing over pending events
    /// would deliver them late and break causality.
    pub fn advance_to(&mut self, t: Time) {
        assert!(
            self.heap.is_empty(),
            "advance_to with {} events pending",
            self.heap.len()
        );
        self.now = self.now.max(t);
    }

    /// Pop the earliest event, advancing `now` to its timestamp.
    pub fn pop(&mut self) -> Option<(Time, E)> {
        let e = self.heap.pop()?;
        self.now = e.at;
        self.processed += 1;
        Some((e.at, e.ev))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut c = SimClock::new();
        c.schedule(30, "c");
        c.schedule(10, "a");
        c.schedule(20, "b");
        assert_eq!(c.pop(), Some((10, "a")));
        assert_eq!(c.pop(), Some((20, "b")));
        assert_eq!(c.pop(), Some((30, "c")));
        assert_eq!(c.pop(), None);
        assert_eq!(c.now(), 30);
        assert_eq!(c.processed(), 3);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut c = SimClock::new();
        for i in 0..32 {
            c.schedule(5, i);
        }
        let order: Vec<i32> = std::iter::from_fn(|| c.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, (0..32).collect::<Vec<_>>());
    }

    #[test]
    fn can_schedule_at_now_while_draining() {
        let mut c = SimClock::new();
        c.schedule(10, 0);
        let (t, _) = c.pop().unwrap();
        c.schedule(t, 1); // zero-delay follow-up is legal
        assert_eq!(c.pop(), Some((10, 1)));
    }

    #[test]
    fn advance_to_moves_forward_only() {
        let mut c: SimClock<()> = SimClock::new();
        c.advance_to(100);
        assert_eq!(c.now(), 100);
        c.advance_to(50); // in the past: no-op
        assert_eq!(c.now(), 100);
        c.schedule(100, ());
        assert_eq!(c.pop(), Some((100, ())));
    }

    #[test]
    #[should_panic(expected = "events pending")]
    fn advance_over_pending_events_panics() {
        let mut c = SimClock::new();
        c.schedule(10, ());
        c.advance_to(20);
    }

    #[test]
    #[should_panic(expected = "scheduled in the past")]
    fn scheduling_in_the_past_panics() {
        let mut c = SimClock::new();
        c.schedule(10, 0);
        c.pop();
        c.schedule(5, 1);
    }
}
