//! Deterministic discrete-event clock.
//!
//! The fabric never sleeps: simulated time is a `u64` picosecond
//! counter advanced by popping the earliest scheduled event. Ties are
//! broken by insertion sequence number, so two runs that schedule the
//! same events in the same order replay identically — the foundation
//! of the fabric's determinism guarantee (tested in
//! `tests/fabric_sim.rs`).
//!
//! # Queue design (see docs/SCALE.md)
//!
//! A single global `BinaryHeap` makes every schedule/pop `O(log E)` in
//! the *total* pending event count — at 4096 nodes a mesh start phase
//! alone holds ~16.7M pending deliveries and the heap dominates the
//! profile. The clock therefore splits the queue:
//!
//! * **Lanes** ([`SimClock::schedule_lane`]): one FIFO `VecDeque` per
//!   destination port. The fabric resolves ingress contention at
//!   *send-call* time, so per-destination delivery times are already
//!   nondecreasing in schedule order — within a lane, FIFO order *is*
//!   `(at, seq)` order, and a push is `O(1)`. A small merge heap holds
//!   exactly one head entry per non-empty lane, so a pop is
//!   `O(log active-lanes)` instead of `O(log total-events)`.
//! * **Overflow**: the classic global heap, used by [`SimClock::schedule`]
//!   (retransmit timers, protocol timers, out-of-order lane pushes —
//!   correctness never depends on a caller picking the right queue).
//!
//! A pop compares the lane-head heap against the overflow heap by
//! `(at, seq)` and takes the smaller, which reproduces the single-heap
//! pop order *exactly* — the tick-identity contract `tests/scale_parity.rs`
//! pins.

use std::cmp::Ordering;
use std::collections::{BinaryHeap, VecDeque};

/// Simulated time in picoseconds. 1 Gbps = 1 bit/ns = 1000 ps/bit, so
/// picoseconds resolve both commodity and InfiniBand-class links; u64
/// picoseconds cover ~213 simulated days.
pub type Time = u64;

/// Picoseconds per microsecond (the CLI's human unit).
pub const PS_PER_US: f64 = 1e6;

struct Entry<E> {
    at: Time,
    seq: u64,
    ev: E,
}

// BinaryHeap is a max-heap; invert the ordering to pop earliest
// (time, seq) first. Only (at, seq) participate — the payload needs no
// Ord.
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        (other.at, other.seq).cmp(&(self.at, self.seq))
    }
}

impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}

impl<E> Eq for Entry<E> {}

/// One lane's front event in the merge heap (inverted ordering, like
/// [`Entry`]). `seq` makes the ordering total, so equal-time heads pop
/// in schedule order across lanes too.
#[derive(PartialEq, Eq)]
struct Head {
    at: Time,
    seq: u64,
    lane: usize,
}

impl Ord for Head {
    fn cmp(&self, other: &Self) -> Ordering {
        (other.at, other.seq).cmp(&(self.at, self.seq))
    }
}

impl PartialOrd for Head {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Min-heap event queue + current simulated time, with optional
/// per-lane FIFO queues for the nondecreasing-time fast path (see the
/// module docs).
pub struct SimClock<E> {
    now: Time,
    seq: u64,
    processed: u64,
    pending: usize,
    overflow: BinaryHeap<Entry<E>>,
    lanes: Vec<VecDeque<(Time, u64, E)>>,
    heads: BinaryHeap<Head>,
}

impl<E> Default for SimClock<E> {
    fn default() -> Self {
        SimClock::new()
    }
}

impl<E> SimClock<E> {
    /// A clock with no lanes — every event goes through the global
    /// heap, the pre-scale behavior.
    pub fn new() -> SimClock<E> {
        SimClock::with_lanes(0)
    }

    /// A clock with `lanes` FIFO lanes (the fabric uses one per node —
    /// its per-ingress-port delivery queue).
    pub fn with_lanes(lanes: usize) -> SimClock<E> {
        SimClock {
            now: 0,
            seq: 0,
            processed: 0,
            pending: 0,
            overflow: BinaryHeap::new(),
            lanes: (0..lanes).map(|_| VecDeque::new()).collect(),
            heads: BinaryHeap::new(),
        }
    }

    /// Current simulated time (the timestamp of the last popped event).
    pub fn now(&self) -> Time {
        self.now
    }

    /// Events popped so far (the fabric's throughput denominator).
    pub fn processed(&self) -> u64 {
        self.processed
    }

    pub fn pending(&self) -> usize {
        self.pending
    }

    /// Schedule `ev` at absolute time `at` on the global heap.
    /// Scheduling in the past is a causality bug in the caller, not a
    /// recoverable condition.
    pub fn schedule(&mut self, at: Time, ev: E) {
        assert!(
            at >= self.now,
            "event scheduled in the past ({} < {})",
            at,
            self.now
        );
        let seq = self.seq;
        self.seq += 1;
        self.pending += 1;
        self.overflow.push(Entry { at, seq, ev });
    }

    /// Schedule `ev` at absolute time `at` on FIFO lane `lane`. The
    /// fast path requires `at` to be no earlier than the lane's tail
    /// (true for per-destination deliveries, whose times the fabric
    /// makes nondecreasing at send time); an out-of-order push falls
    /// back to the global heap, so callers never need to prove
    /// monotonicity — only benefit from it.
    pub fn schedule_lane(&mut self, at: Time, lane: usize, ev: E) {
        assert!(
            at >= self.now,
            "event scheduled in the past ({} < {})",
            at,
            self.now
        );
        let q = &mut self.lanes[lane];
        if let Some(&(back_at, _, _)) = q.back() {
            if at < back_at {
                // Out of order for this lane: the heap keeps it exact.
                let seq = self.seq;
                self.seq += 1;
                self.pending += 1;
                self.overflow.push(Entry { at, seq, ev });
                return;
            }
        }
        let seq = self.seq;
        self.seq += 1;
        self.pending += 1;
        if q.is_empty() {
            self.heads.push(Head { at, seq, lane });
        }
        q.push_back((at, seq, ev));
    }

    /// Jump `now` forward to `t` (no-op if `t` is in the past). Only
    /// legal while the queue is idle — advancing over pending events
    /// would deliver them late and break causality.
    pub fn advance_to(&mut self, t: Time) {
        assert!(
            self.pending == 0,
            "advance_to with {} events pending",
            self.pending
        );
        self.now = self.now.max(t);
    }

    /// Account for `events` that a closed-form fast path resolved
    /// without event-by-event simulation, landing the clock at `t`
    /// (see `fabric::fastpath`). Only legal while the queue is idle —
    /// the whole point is that nothing was pending to simulate.
    pub fn fast_forward(&mut self, t: Time, events: u64) {
        assert!(
            self.pending == 0,
            "fast_forward with {} events pending",
            self.pending
        );
        self.now = self.now.max(t);
        self.processed += events;
    }

    /// Pop the earliest event by `(at, seq)` across the lanes and the
    /// global heap, advancing `now` to its timestamp.
    pub fn pop(&mut self) -> Option<(Time, E)> {
        let lane_key = self.heads.peek().map(|h| (h.at, h.seq));
        let heap_key = self.overflow.peek().map(|e| (e.at, e.seq));
        let take_lane = match (lane_key, heap_key) {
            (None, None) => return None,
            (Some(_), None) => true,
            (None, Some(_)) => false,
            (Some(l), Some(h)) => l < h,
        };
        self.pending -= 1;
        self.processed += 1;
        if take_lane {
            let h = self.heads.pop().expect("peeked head vanished");
            let q = &mut self.lanes[h.lane];
            let (at, seq, ev) = q.pop_front().expect("head entry for empty lane");
            debug_assert_eq!((at, seq), (h.at, h.seq), "lane head out of sync");
            if let Some(&(nat, nseq, _)) = q.front() {
                self.heads.push(Head {
                    at: nat,
                    seq: nseq,
                    lane: h.lane,
                });
            }
            self.now = at;
            Some((at, ev))
        } else {
            let e = self.overflow.pop().expect("peeked entry vanished");
            self.now = e.at;
            Some((e.at, e.ev))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut c = SimClock::new();
        c.schedule(30, "c");
        c.schedule(10, "a");
        c.schedule(20, "b");
        assert_eq!(c.pop(), Some((10, "a")));
        assert_eq!(c.pop(), Some((20, "b")));
        assert_eq!(c.pop(), Some((30, "c")));
        assert_eq!(c.pop(), None);
        assert_eq!(c.now(), 30);
        assert_eq!(c.processed(), 3);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut c = SimClock::new();
        for i in 0..32 {
            c.schedule(5, i);
        }
        let order: Vec<i32> = std::iter::from_fn(|| c.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, (0..32).collect::<Vec<_>>());
    }

    #[test]
    fn can_schedule_at_now_while_draining() {
        let mut c = SimClock::new();
        c.schedule(10, 0);
        let (t, _) = c.pop().unwrap();
        c.schedule(t, 1); // zero-delay follow-up is legal
        assert_eq!(c.pop(), Some((10, 1)));
    }

    #[test]
    fn advance_to_moves_forward_only() {
        let mut c: SimClock<()> = SimClock::new();
        c.advance_to(100);
        assert_eq!(c.now(), 100);
        c.advance_to(50); // in the past: no-op
        assert_eq!(c.now(), 100);
        c.schedule(100, ());
        assert_eq!(c.pop(), Some((100, ())));
    }

    #[test]
    #[should_panic(expected = "events pending")]
    fn advance_over_pending_events_panics() {
        let mut c = SimClock::new();
        c.schedule(10, ());
        c.advance_to(20);
    }

    #[test]
    #[should_panic(expected = "scheduled in the past")]
    fn scheduling_in_the_past_panics() {
        let mut c = SimClock::new();
        c.schedule(10, 0);
        c.pop();
        c.schedule(5, 1);
    }

    #[test]
    fn lanes_and_heap_pop_in_global_seq_order() {
        // The same (at, seq) stream split across two lanes and the
        // overflow heap must pop exactly like a single heap would:
        // time-major, insertion-order within ties.
        let mut c = SimClock::with_lanes(2);
        c.schedule_lane(10, 0, "l0-a");
        c.schedule(10, "heap-a");
        c.schedule_lane(10, 1, "l1-a");
        c.schedule_lane(20, 0, "l0-b");
        c.schedule(15, "heap-b");
        c.schedule_lane(20, 1, "l1-b");
        let order: Vec<&str> = std::iter::from_fn(|| c.pop().map(|(_, e)| e)).collect();
        assert_eq!(
            order,
            vec!["l0-a", "heap-a", "l1-a", "heap-b", "l0-b", "l1-b"]
        );
        assert_eq!(c.pending(), 0);
        assert_eq!(c.processed(), 6);
    }

    #[test]
    fn out_of_order_lane_push_falls_back_to_the_heap() {
        let mut c = SimClock::with_lanes(1);
        c.schedule_lane(50, 0, "late");
        c.schedule_lane(10, 0, "early"); // violates lane monotonicity
        assert_eq!(c.pop(), Some((10, "early")));
        assert_eq!(c.pop(), Some((50, "late")));
        assert_eq!(c.pop(), None);
    }

    #[test]
    fn lane_ties_break_by_insertion_order_across_lanes() {
        let mut c = SimClock::with_lanes(3);
        for i in 0..30u32 {
            c.schedule_lane(7, (i % 3) as usize, i);
        }
        let order: Vec<u32> = std::iter::from_fn(|| c.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, (0..30).collect::<Vec<_>>());
    }

    #[test]
    fn fast_forward_accounts_skipped_events() {
        let mut c: SimClock<()> = SimClock::with_lanes(4);
        c.fast_forward(1_000, 12);
        assert_eq!(c.now(), 1_000);
        assert_eq!(c.processed(), 12);
        c.fast_forward(500, 3); // time only moves forward
        assert_eq!(c.now(), 1_000);
        assert_eq!(c.processed(), 15);
    }

    #[test]
    #[should_panic(expected = "events pending")]
    fn fast_forward_over_pending_events_panics() {
        let mut c = SimClock::with_lanes(1);
        c.schedule_lane(10, 0, ());
        c.fast_forward(20, 1);
    }
}
