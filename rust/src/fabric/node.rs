//! Node endpoints: per-node ports, performance profile, straggler
//! injection, and traffic accounting.

use super::clock::{Time, PS_PER_US};

/// Per-node performance profile.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NodePerf {
    /// Multiplier on this node's port serialization times (> 1 = a slow
    /// NIC/CPU; straggler injection sets this).
    pub slowdown: f64,
    /// Fixed processing delay added before each send the node issues in
    /// reaction to a delivery (protocol handling cost), ps.
    pub compute_ps: Time,
}

impl Default for NodePerf {
    fn default() -> Self {
        NodePerf {
            slowdown: 1.0,
            compute_ps: 0,
        }
    }
}

/// A straggler directive: slow node `node` down by `slowdown`×.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Straggler {
    pub node: usize,
    pub slowdown: f64,
}

impl Straggler {
    /// Parse a comma-separated spec like `"0:4,3:2.5"` (node:slowdown).
    pub fn parse_list(spec: &str) -> anyhow::Result<Vec<Straggler>> {
        let mut out = Vec::new();
        for part in spec.split(',').filter(|s| !s.trim().is_empty()) {
            let (node, factor) = part
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("straggler spec '{part}': want node:slowdown"))?;
            let s = Straggler {
                node: node.trim().parse()?,
                slowdown: factor.trim().parse()?,
            };
            anyhow::ensure!(
                s.slowdown >= 1.0,
                "straggler slowdown must be >= 1 (got {})",
                s.slowdown
            );
            out.push(s);
        }
        Ok(out)
    }

    /// Canonical string form (parses back via [`Straggler::parse_list`]).
    pub fn list_str(list: &[Straggler]) -> String {
        list.iter()
            .map(|s| format!("{}:{}", s.node, s.slowdown))
            .collect::<Vec<_>>()
            .join(",")
    }
}

/// One simulated endpoint: a worker or an infrastructure node (e.g. the
/// parameter-server hub).
#[derive(Debug, Clone)]
pub struct Node {
    pub id: usize,
    pub perf: NodePerf,
    /// Egress port free-at time (ps). Sends queue here.
    pub egress_free: Time,
    /// Ingress port free-at time (ps). Incast queues here.
    pub ingress_free: Time,
    pub sent_bytes: u64,
    pub sent_messages: u64,
    pub recv_bytes: u64,
    pub recv_messages: u64,
}

impl Node {
    pub fn new(id: usize) -> Node {
        Node {
            id,
            perf: NodePerf::default(),
            egress_free: 0,
            ingress_free: 0,
            sent_bytes: 0,
            sent_messages: 0,
            recv_bytes: 0,
            recv_messages: 0,
        }
    }

    /// Serialization time scaled by this node's slowdown.
    pub fn scaled(&self, ser: Time) -> Time {
        if self.perf.slowdown == 1.0 {
            ser
        } else {
            (ser as f64 * self.perf.slowdown).ceil() as Time
        }
    }

    /// Protocol processing delay before reactive sends.
    pub fn compute_delay(&self) -> Time {
        self.scaled(self.perf.compute_ps)
    }
}

/// Convert a microsecond figure to the node-profile ps unit.
pub fn us_to_ps(us: f64) -> Time {
    (us * PS_PER_US).round() as Time
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn straggler_spec_roundtrip() {
        let list = Straggler::parse_list("0:4,3:2.5").unwrap();
        assert_eq!(list.len(), 2);
        assert_eq!(list[0].node, 0);
        assert!((list[0].slowdown - 4.0).abs() < 1e-12);
        assert_eq!(Straggler::list_str(&list), "0:4,3:2.5");
        assert!(Straggler::parse_list("").unwrap().is_empty());
    }

    #[test]
    fn bad_straggler_specs_are_loud() {
        assert!(Straggler::parse_list("3").is_err());
        assert!(Straggler::parse_list("x:2").is_err());
        assert!(Straggler::parse_list("0:0.5").is_err()); // speedups disallowed
    }

    #[test]
    fn slowdown_scales_serialization() {
        let mut n = Node::new(0);
        assert_eq!(n.scaled(1000), 1000);
        n.perf.slowdown = 2.5;
        assert_eq!(n.scaled(1000), 2500);
    }
}
