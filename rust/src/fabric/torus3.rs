//! 3-D torus topology: an `X × Y × Z` wraparound grid, the fabric of
//! TPU-v4-class pods and HPC machines like the K computer.
//!
//! Node `(x, y, z)` is id `z·X·Y + y·X + x`. Allgatherv generalizes
//! the 2-D torus's two pipelined ring phases to three: the origin
//! circulates its block along its **x-line** (`X − 1` hops), every
//! node holding the block injects it down its **y-line** (`Y − 1`
//! hops), and every node of the resulting z-plane injects it along
//! its **z-line** (`Z − 1` hops). Each block is delivered exactly
//! `XYZ − 1` times — the flat ring's per-block optimum — while the
//! longest route shrinks to `(X−1) + (Y−1) + (Z−1)` hops. Phases
//! overlap per block and per segment exactly as in the 2-D torus; a
//! `Z = 1` torus3 runs the *identical* event schedule as the
//! corresponding 2-D `torus` (asserted tick-for-tick in the tests).
//!
//! Allreduce is dimension-wise: exchange within the x-line and sum in
//! ascending x order, exchange the line-sums within the y-line (sum
//! ascending y), then the plane-sums along z (sum ascending z) —
//! `(X−1) + (Y−1) + (Z−1)` vector sends per node.
//!
//! `torus3` (no dims) picks a near-cubic factorization
//! ([`auto_dims3`]); `torus3:XxYxZ` pins the shape and requires
//! `X·Y·Z` workers.

use super::collectives::{traffic_from, GatherState, SegPayloads, SimGather, SimReduce};
use super::topology::{Topology, TopologyKind};
use super::torus::auto_dims;
use super::{Fabric, Msg, Payload, Protocol};
use crate::comm::Traffic;

/// Block circulating along the origin's x-line.
const TAG_X: u8 = 0;
/// Block circulating down a y-line.
const TAG_Y: u8 = 1;
/// Block circulating along a z-line.
const TAG_Z: u8 = 2;

/// A near-cubic `x × y × z = p` factorization: `x` is the largest
/// divisor of `p` not exceeding `∛p`, and the remainder splits
/// near-square ([`auto_dims`]). Primes degenerate to `1 × 1 × p`
/// (a ring); `p = 4096` gives `16 × 16 × 16`.
pub fn auto_dims3(p: usize) -> (usize, usize, usize) {
    assert!(p > 0, "topology needs at least one worker");
    let mut x = (p as f64).cbrt().round() as usize;
    x = x.min(p).max(1);
    while x > 1 && p % x != 0 {
        x -= 1;
    }
    let (y, z) = auto_dims(p / x);
    (x, y, z)
}

pub struct Torus3 {
    x: usize,
    y: usize,
    z: usize,
}

impl Torus3 {
    /// Dims of 0 mean "auto" (see [`auto_dims3`]); explicit dims must
    /// factor the worker count exactly.
    pub fn new(workers: usize, x: usize, y: usize, z: usize) -> Torus3 {
        assert!(workers > 0, "topology needs at least one worker");
        let (x, y, z) = if x == 0 || y == 0 || z == 0 {
            auto_dims3(workers)
        } else {
            (x, y, z)
        };
        assert_eq!(
            x * y * z,
            workers,
            "torus3 {x}x{y}x{z} needs {} workers, got {workers}",
            x * y * z
        );
        Torus3 { x, y, z }
    }

    fn p(&self) -> usize {
        self.x * self.y * self.z
    }

    fn x_of(&self, w: usize) -> usize {
        w % self.x
    }

    fn y_of(&self, w: usize) -> usize {
        (w / self.x) % self.y
    }

    fn z_of(&self, w: usize) -> usize {
        w / (self.x * self.y)
    }

    fn id(&self, x: usize, y: usize, z: usize) -> usize {
        z * self.x * self.y + y * self.x + x
    }

    /// Next neighbour along the x-line (wraps).
    fn xnext(&self, w: usize) -> usize {
        self.id((self.x_of(w) + 1) % self.x, self.y_of(w), self.z_of(w))
    }

    /// Next neighbour along the y-line (wraps).
    fn ynext(&self, w: usize) -> usize {
        self.id(self.x_of(w), (self.y_of(w) + 1) % self.y, self.z_of(w))
    }

    /// Next neighbour along the z-line (wraps).
    fn znext(&self, w: usize) -> usize {
        self.id(self.x_of(w), self.y_of(w), (self.z_of(w) + 1) % self.z)
    }

    /// Drive one gather (real or phantom payloads) through the event
    /// loop — both `allgatherv` flavors run this identical code.
    fn run_gather(&self, fabric: &mut Fabric, segs: SegPayloads, state: GatherState) -> SimGather {
        let mut proto = Torus3Gather {
            t: self,
            segs,
            state,
        };
        let time_ps = if self.p() > 1 { fabric.run(&mut proto) } else { 0 };
        SimGather {
            gathered: proto.state.into_gathered(),
            traffic: traffic_from(fabric, self.gather_rounds()),
            time_ps,
            events: fabric.events(),
        }
    }
}

struct Torus3Gather<'t> {
    t: &'t Torus3,
    segs: SegPayloads,
    state: GatherState,
}

impl Protocol for Torus3Gather<'_> {
    fn start(&mut self) -> Vec<(usize, usize, Msg)> {
        let mut out = Vec::new();
        for w in 0..self.t.p() {
            for si in 0..self.segs.seg_count(w) {
                let payload = self.segs.payload(w, si);
                if self.t.x > 1 {
                    out.push((
                        w,
                        self.t.xnext(w),
                        Msg {
                            origin: w,
                            seg: si as u32,
                            hop: 1,
                            tag: TAG_X,
                            payload: payload.clone(),
                        },
                    ));
                }
                if self.t.y > 1 {
                    out.push((
                        w,
                        self.t.ynext(w),
                        Msg {
                            origin: w,
                            seg: si as u32,
                            hop: 1,
                            tag: TAG_Y,
                            payload: payload.clone(),
                        },
                    ));
                }
                if self.t.z > 1 {
                    out.push((
                        w,
                        self.t.znext(w),
                        Msg {
                            origin: w,
                            seg: si as u32,
                            hop: 1,
                            tag: TAG_Z,
                            payload,
                        },
                    ));
                }
            }
        }
        out
    }

    fn on_deliver(&mut self, node: usize, msg: &Msg) -> Vec<(usize, Msg)> {
        self.state
            .store_payload(node, msg.origin, msg.seg as usize, &msg.payload);
        let fwd = |dst: usize, hop: u32, tag: u8| {
            (
                dst,
                Msg {
                    origin: msg.origin,
                    seg: msg.seg,
                    hop,
                    tag,
                    payload: msg.payload.clone(),
                },
            )
        };
        let mut out = Vec::new();
        match msg.tag {
            TAG_X => {
                // Keep the x circulation going…
                if msg.hop < (self.t.x - 1) as u32 {
                    out.push(fwd(self.t.xnext(node), msg.hop + 1, TAG_X));
                }
                // …and inject the block into this node's y- and z-lines.
                if self.t.y > 1 {
                    out.push(fwd(self.t.ynext(node), 1, TAG_Y));
                }
                if self.t.z > 1 {
                    out.push(fwd(self.t.znext(node), 1, TAG_Z));
                }
            }
            TAG_Y => {
                if msg.hop < (self.t.y - 1) as u32 {
                    out.push(fwd(self.t.ynext(node), msg.hop + 1, TAG_Y));
                }
                if self.t.z > 1 {
                    out.push(fwd(self.t.znext(node), 1, TAG_Z));
                }
            }
            TAG_Z => {
                if msg.hop < (self.t.z - 1) as u32 {
                    out.push(fwd(self.t.znext(node), msg.hop + 1, TAG_Z));
                }
            }
            other => unreachable!("unknown torus3 gather tag {other}"),
        }
        out
    }
}

struct Torus3Reduce<'t> {
    t: &'t Torus3,
    inputs: Vec<Vec<f32>>,
    /// X-phase vectors at each node, by x index of the sender.
    x_got: Vec<Vec<Option<Vec<f32>>>>,
    /// Y-phase line-sums at each node, by y index of the sender.
    y_got: Vec<Vec<Option<Vec<f32>>>>,
    /// Z-phase plane-sums at each node, by z index of the sender.
    z_got: Vec<Vec<Option<Vec<f32>>>>,
}

impl Torus3Reduce<'_> {
    fn sum_slots(slots: &[Option<Vec<f32>>], n: usize) -> Vec<f32> {
        let mut sum = vec![0.0f32; n];
        for slot in slots {
            let v = slot.as_ref().expect("reduce vector missing");
            for (k, x) in v.iter().enumerate() {
                sum[k] += x;
            }
        }
        sum
    }

    /// The x phase finished at `node`: record its line-sum and fan it
    /// down the y-line; a `Y = 1` line cascades straight to z.
    fn x_ready(&mut self, node: usize, hop: u32) -> Vec<(usize, Msg)> {
        let n = self.inputs[node].len();
        let sum = Self::sum_slots(&self.x_got[node], n);
        let y = self.t.y_of(node);
        self.y_got[node][y] = Some(sum.clone());
        let payload = Payload::F32(sum);
        let mut out: Vec<(usize, Msg)> = (0..self.t.y)
            .filter(|&y2| y2 != y)
            .map(|y2| {
                (
                    self.t.id(self.t.x_of(node), y2, self.t.z_of(node)),
                    Msg {
                        origin: node,
                        seg: 0,
                        hop,
                        tag: TAG_Y,
                        payload: payload.clone(),
                    },
                )
            })
            .collect();
        if self.y_got[node].iter().all(|s| s.is_some()) {
            out.extend(self.y_ready(node, hop + 1));
        }
        out
    }

    /// The y phase finished at `node`: record its plane-sum and fan it
    /// along the z-line.
    fn y_ready(&mut self, node: usize, hop: u32) -> Vec<(usize, Msg)> {
        let n = self.inputs[node].len();
        let sum = Self::sum_slots(&self.y_got[node], n);
        let z = self.t.z_of(node);
        self.z_got[node][z] = Some(sum.clone());
        let payload = Payload::F32(sum);
        (0..self.t.z)
            .filter(|&z2| z2 != z)
            .map(|z2| {
                (
                    self.t.id(self.t.x_of(node), self.t.y_of(node), z2),
                    Msg {
                        origin: node,
                        seg: 0,
                        hop,
                        tag: TAG_Z,
                        payload: payload.clone(),
                    },
                )
            })
            .collect()
    }
}

impl Protocol for Torus3Reduce<'_> {
    fn start(&mut self) -> Vec<(usize, usize, Msg)> {
        let mut out = Vec::new();
        for w in 0..self.t.p() {
            let payload = Payload::F32(self.inputs[w].clone());
            for x2 in 0..self.t.x {
                let peer = self.t.id(x2, self.t.y_of(w), self.t.z_of(w));
                if peer != w {
                    out.push((
                        w,
                        peer,
                        Msg {
                            origin: w,
                            seg: 0,
                            hop: 1,
                            tag: TAG_X,
                            payload: payload.clone(),
                        },
                    ));
                }
            }
        }
        // Single-node x-lines are complete at t = 0.
        if self.t.x == 1 {
            for w in 0..self.t.p() {
                for (dst, msg) in self.x_ready(w, 1) {
                    out.push((w, dst, msg));
                }
            }
        }
        out
    }

    fn on_deliver(&mut self, node: usize, msg: &Msg) -> Vec<(usize, Msg)> {
        let Payload::F32(v) = &msg.payload else {
            unreachable!("reduce protocol only moves f32 vectors")
        };
        match msg.tag {
            TAG_X => {
                self.x_got[node][self.t.x_of(msg.origin)] = Some(v.clone());
                if self.x_got[node].iter().all(|s| s.is_some()) {
                    self.x_ready(node, msg.hop + 1)
                } else {
                    Vec::new()
                }
            }
            TAG_Y => {
                self.y_got[node][self.t.y_of(msg.origin)] = Some(v.clone());
                if self.y_got[node].iter().all(|s| s.is_some()) {
                    self.y_ready(node, msg.hop + 1)
                } else {
                    Vec::new()
                }
            }
            TAG_Z => {
                self.z_got[node][self.t.z_of(msg.origin)] = Some(v.clone());
                Vec::new()
            }
            other => unreachable!("unknown torus3 reduce tag {other}"),
        }
    }
}

impl Topology for Torus3 {
    fn kind(&self) -> TopologyKind {
        TopologyKind::Torus3 {
            x: self.x,
            y: self.y,
            z: self.z,
        }
    }

    fn workers(&self) -> usize {
        self.p()
    }

    fn gather_rounds(&self) -> u32 {
        (self.x - 1 + self.y - 1 + self.z - 1) as u32
    }

    fn reduce_rounds(&self) -> u32 {
        u32::from(self.x > 1) + u32::from(self.y > 1) + u32::from(self.z > 1)
    }

    fn allgatherv(&self, fabric: &mut Fabric, inputs: &[Vec<u8>]) -> SimGather {
        assert_eq!(inputs.len(), self.p(), "one input message per worker");
        let seg = fabric.segment_bytes();
        self.run_gather(
            fabric,
            SegPayloads::real(inputs, seg),
            GatherState::new(inputs, seg),
        )
    }

    fn allgatherv_sized(&self, fabric: &mut Fabric, sizes: &[u64]) -> SimGather {
        assert_eq!(sizes.len(), self.p(), "one size per worker");
        let seg = fabric.segment_bytes();
        self.run_gather(
            fabric,
            SegPayloads::phantom(sizes, seg),
            GatherState::sized(sizes, seg),
        )
    }

    fn allreduce(&self, fabric: &mut Fabric, inputs: &[Vec<f32>]) -> SimReduce {
        assert_eq!(inputs.len(), self.p());
        let n = inputs[0].len();
        assert!(inputs.iter().all(|v| v.len() == n), "length mismatch");
        if self.p() == 1 {
            return SimReduce {
                reduced: vec![inputs[0].clone()],
                traffic: Traffic {
                    bytes_sent_per_node: vec![0],
                    rounds: 0,
                },
                time_ps: 0,
                events: 0,
            };
        }
        let mut proto = Torus3Reduce {
            t: self,
            inputs: inputs.to_vec(),
            x_got: (0..self.p())
                .map(|w| {
                    let mut line = vec![None; self.x];
                    line[self.x_of(w)] = Some(inputs[w].clone());
                    line
                })
                .collect(),
            y_got: vec![vec![None; self.y]; self.p()],
            z_got: vec![vec![None; self.z]; self.p()],
        };
        let time_ps = fabric.run(&mut proto);
        let reduced: Vec<Vec<f32>> = proto
            .z_got
            .iter()
            .map(|slots| Torus3Reduce::sum_slots(slots, n))
            .collect();
        SimReduce {
            reduced,
            traffic: traffic_from(fabric, self.reduce_rounds()),
            time_ps,
            events: fabric.events(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::torus::Torus;
    use crate::fabric::{FabricConfig, LinkSpec};

    fn fabric(nodes: usize) -> Fabric {
        Fabric::for_config(
            &FabricConfig {
                link: LinkSpec {
                    bandwidth_gbps: 1.0,
                    latency_us: 1.0,
                    jitter_us: 0.0,
                },
                ..FabricConfig::default()
            },
            nodes,
        )
    }

    #[test]
    fn auto_dims3_prefers_cubic() {
        assert_eq!(auto_dims3(1), (1, 1, 1));
        assert_eq!(auto_dims3(8), (2, 2, 2));
        assert_eq!(auto_dims3(12), (2, 2, 3));
        assert_eq!(auto_dims3(64), (4, 4, 4));
        assert_eq!(auto_dims3(4096), (16, 16, 16));
        assert_eq!(auto_dims3(7), (1, 1, 7)); // prime ⇒ ring
    }

    #[test]
    #[should_panic(expected = "needs 8 workers")]
    fn explicit_dims_must_factor_workers() {
        Torus3::new(9, 2, 2, 2);
    }

    #[test]
    fn coordinate_math_round_trips() {
        let t = Torus3::new(24, 2, 3, 4);
        for w in 0..24 {
            assert_eq!(t.id(t.x_of(w), t.y_of(w), t.z_of(w)), w);
        }
        assert_eq!(t.xnext(1), 0); // x wrap
        assert_eq!(t.ynext(4), 0); // y wrap
        assert_eq!(t.znext(18), 0); // z wrap
    }

    #[test]
    fn gather_delivers_for_awkward_shapes() {
        for (x, y, z) in [
            (1usize, 1usize, 1usize),
            (1, 1, 5),
            (5, 1, 1),
            (2, 2, 2),
            (2, 3, 2),
            (1, 3, 2),
        ] {
            let p = x * y * z;
            let inputs: Vec<Vec<u8>> =
                (0..p).map(|w| vec![w as u8 + 1; (w * 17) % 31 + 1]).collect();
            let topo = Torus3::new(p, x, y, z);
            let mut f = fabric(topo.node_count());
            let res = topo.allgatherv(&mut f, &inputs);
            for dst in 0..p {
                for src in 0..p {
                    assert_eq!(
                        res.gathered[dst][src], inputs[src],
                        "{x}x{y}x{z} dst={dst} src={src}"
                    );
                }
            }
        }
    }

    #[test]
    fn per_block_traffic_is_p_minus_1_sends() {
        let (x, y, z) = (2, 3, 2);
        let p = x * y * z;
        let inputs: Vec<Vec<u8>> = (0..p).map(|_| vec![9u8; 10]).collect();
        let topo = Torus3::new(p, x, y, z);
        let mut f = fabric(topo.node_count());
        let res = topo.allgatherv(&mut f, &inputs);
        assert_eq!(res.traffic.total_bytes(), (p * (p - 1) * 10) as u64);
        assert_eq!(res.events as usize, p * (p - 1));
        assert_eq!(res.traffic.rounds, (x - 1 + y - 1 + z - 1) as u32);
    }

    #[test]
    fn reduce_matches_sum_for_awkward_shapes() {
        for (x, y, z) in [
            (1usize, 1usize, 1usize),
            (1, 4, 1),
            (4, 1, 1),
            (1, 1, 4),
            (2, 2, 2),
            (2, 3, 2),
        ] {
            let p = x * y * z;
            let inputs: Vec<Vec<f32>> = (0..p)
                .map(|w| (0..5).map(|k| (w * 5 + k) as f32 * 0.25).collect())
                .collect();
            let topo = Torus3::new(p, x, y, z);
            let mut f = fabric(topo.node_count());
            let res = topo.allreduce(&mut f, &inputs);
            for k in 0..5 {
                let want: f32 = inputs.iter().map(|v| v[k]).sum();
                for node in 0..p {
                    let got = res.reduced[node][k];
                    assert!(
                        (got - want).abs() < 1e-3,
                        "{x}x{y}x{z} node={node} k={k}: {got} != {want}"
                    );
                }
            }
        }
    }

    /// A `Z = 1` torus3 is the 2-D torus with `X = cols`, `Y = rows`
    /// under the identity id mapping — same sends in the same order,
    /// so bytes, traffic, events, AND the simulated clock must agree
    /// exactly.
    #[test]
    fn z1_torus3_is_tick_identical_to_the_2d_torus() {
        let (rows, cols) = (3, 4);
        let p = rows * cols;
        let inputs: Vec<Vec<u8>> =
            (0..p).map(|w| vec![w as u8 + 1; (w * 13) % 41 + 1]).collect();
        let t2 = Torus::new(p, rows, cols);
        let t3 = Torus3::new(p, cols, rows, 1);
        let mut f2 = fabric(p);
        let mut f3 = fabric(p);
        let g2 = t2.allgatherv(&mut f2, &inputs);
        let g3 = t3.allgatherv(&mut f3, &inputs);
        assert_eq!(g2.gathered, g3.gathered, "gathered bytes diverged");
        assert_eq!(g2.time_ps, g3.time_ps, "simulated clocks diverged");
        assert_eq!(g2.events, g3.events);
        assert_eq!(
            g2.traffic.bytes_sent_per_node,
            g3.traffic.bytes_sent_per_node
        );

        let vecs: Vec<Vec<f32>> = (0..p)
            .map(|w| (0..5).map(|k| (w * 5 + k) as f32 * 0.25).collect())
            .collect();
        let mut f2 = fabric(p);
        let mut f3 = fabric(p);
        let r2 = t2.allreduce(&mut f2, &vecs);
        let r3 = t3.allreduce(&mut f3, &vecs);
        for (a, b) in r2.reduced.iter().zip(r3.reduced.iter()) {
            let ab: Vec<u32> = a.iter().map(|v| v.to_bits()).collect();
            let bb: Vec<u32> = b.iter().map(|v| v.to_bits()).collect();
            assert_eq!(ab, bb, "reduced totals diverged bit-wise");
        }
        assert_eq!(r2.time_ps, r3.time_ps);
    }
}
