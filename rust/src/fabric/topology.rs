//! Pluggable topology backends: the trait, the selector enum, and the
//! fully-connected mesh reference implementation.
//!
//! A topology owns the routing/protocol logic for both collectives and
//! is judged on two axes the sweep reports: simulated wall-clock and
//! per-link traffic. Ring ([`super::ring`]) is the paper's substrate;
//! star ([`super::star`]) models a parameter server; tree
//! ([`super::tree`]) a 2-level hierarchical cluster (e.g. rack-local
//! leaders); torus ([`super::torus`]) a 2-D wraparound grid;
//! hierarchy ([`super::hierarchy`]) a NUMA-aware group topology with
//! slow inter-rack uplinks; [`FullMesh`] here is the contention-free
//! upper bound. See docs/TOPOLOGIES.md for per-topology cost formulas
//! and when-to-use guidance.

use super::collectives::{traffic_from, GatherState, SegPayloads, SimGather, SimReduce};
use super::{Fabric, FabricConfig, LinkSpec, Msg, Payload, Protocol};

/// Topology selector, parsed from `--topology`.
///
/// `Torus { rows: 0, cols: 0 }` and `Hier { groups: 0 }` mean "auto":
/// the dimensions/group count are derived from the worker count when
/// the backend is built ([`build_topology`]), and the backend's
/// [`Topology::kind`] reports the resolved values.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TopologyKind {
    Ring,
    Full,
    Star,
    Tree { branch: usize },
    Torus { rows: usize, cols: usize },
    Torus3 { x: usize, y: usize, z: usize },
    Hier { groups: usize },
    Dragonfly { groups: usize },
}

/// Every accepted `--topology` form, for error messages and usage.
pub const TOPOLOGY_FORMS: &str =
    "ring|full|star|tree[:branch]|torus[:RxC]|torus3[:XxYxZ]|hier[:groups]|dragonfly[:groups]";

/// Parse a `RxC` torus dimension spec (e.g. `4x2`).
pub fn parse_dims(s: &str) -> anyhow::Result<(usize, usize)> {
    let (r, c) = s
        .split_once('x')
        .ok_or_else(|| anyhow::anyhow!("torus dims '{s}': want RxC (e.g. 4x2)"))?;
    let rows: usize = r
        .trim()
        .parse()
        .map_err(|e| anyhow::anyhow!("torus rows '{r}': {e}"))?;
    let cols: usize = c
        .trim()
        .parse()
        .map_err(|e| anyhow::anyhow!("torus cols '{c}': {e}"))?;
    anyhow::ensure!(rows >= 1 && cols >= 1, "torus dims must be >= 1 (got {s})");
    Ok((rows, cols))
}

/// Parse a `XxYxZ` 3-D torus dimension spec (e.g. `4x4x2`).
pub fn parse_dims3(s: &str) -> anyhow::Result<(usize, usize, usize)> {
    let parts: Vec<&str> = s.split('x').collect();
    anyhow::ensure!(
        parts.len() == 3,
        "torus3 dims '{s}': want XxYxZ (e.g. 4x4x2)"
    );
    let mut dims = [0usize; 3];
    for (i, part) in parts.iter().enumerate() {
        dims[i] = part
            .trim()
            .parse()
            .map_err(|e| anyhow::anyhow!("torus3 dim '{part}': {e}"))?;
    }
    anyhow::ensure!(
        dims.iter().all(|&d| d >= 1),
        "torus3 dims must be >= 1 (got {s})"
    );
    Ok((dims[0], dims[1], dims[2]))
}

impl TopologyKind {
    /// Parse `ring`, `full`, `star`, `tree` (branch 4) or `tree:<b>`,
    /// `torus` (near-square auto dims) or `torus:<R>x<C>`, `hier`
    /// (auto group count) or `hier:<g>`.
    pub fn parse(s: &str) -> anyhow::Result<TopologyKind> {
        let (head, rest) = match s.split_once(':') {
            Some((h, r)) => (h, Some(r)),
            None => (s, None),
        };
        match (head, rest) {
            ("ring", None) => Ok(TopologyKind::Ring),
            ("full", None) => Ok(TopologyKind::Full),
            ("star", None) => Ok(TopologyKind::Star),
            ("tree", None) => Ok(TopologyKind::Tree { branch: 4 }),
            ("tree", Some(b)) => {
                let branch: usize = b
                    .parse()
                    .map_err(|e| anyhow::anyhow!("tree branch '{b}': {e}"))?;
                anyhow::ensure!(branch >= 1, "tree branch must be >= 1");
                Ok(TopologyKind::Tree { branch })
            }
            ("torus", None) => Ok(TopologyKind::Torus { rows: 0, cols: 0 }),
            ("torus", Some(d)) => {
                let (rows, cols) = parse_dims(d)?;
                Ok(TopologyKind::Torus { rows, cols })
            }
            ("torus3", None) => Ok(TopologyKind::Torus3 { x: 0, y: 0, z: 0 }),
            ("torus3", Some(d)) => {
                let (x, y, z) = parse_dims3(d)?;
                Ok(TopologyKind::Torus3 { x, y, z })
            }
            ("hier", None) => Ok(TopologyKind::Hier { groups: 0 }),
            ("hier", Some(g)) => {
                let groups: usize = g
                    .parse()
                    .map_err(|e| anyhow::anyhow!("hier groups '{g}': {e}"))?;
                anyhow::ensure!(groups >= 1, "hier groups must be >= 1");
                Ok(TopologyKind::Hier { groups })
            }
            ("dragonfly", None) => Ok(TopologyKind::Dragonfly { groups: 0 }),
            ("dragonfly", Some(g)) => {
                let groups: usize = g
                    .parse()
                    .map_err(|e| anyhow::anyhow!("dragonfly groups '{g}': {e}"))?;
                anyhow::ensure!(groups >= 1, "dragonfly groups must be >= 1");
                Ok(TopologyKind::Dragonfly { groups })
            }
            _ => anyhow::bail!("unknown topology '{s}' ({TOPOLOGY_FORMS})"),
        }
    }

    /// Canonical string form (parses back).
    pub fn label(&self) -> String {
        match self {
            TopologyKind::Ring => "ring".into(),
            TopologyKind::Full => "full".into(),
            TopologyKind::Star => "star".into(),
            TopologyKind::Tree { branch } => format!("tree:{branch}"),
            TopologyKind::Torus { rows: 0, cols: 0 } => "torus".into(),
            TopologyKind::Torus { rows, cols } => format!("torus:{rows}x{cols}"),
            TopologyKind::Torus3 { x: 0, y: 0, z: 0 } => "torus3".into(),
            TopologyKind::Torus3 { x, y, z } => format!("torus3:{x}x{y}x{z}"),
            TopologyKind::Hier { groups: 0 } => "hier".into(),
            TopologyKind::Hier { groups } => format!("hier:{groups}"),
            TopologyKind::Dragonfly { groups: 0 } => "dragonfly".into(),
            TopologyKind::Dragonfly { groups } => format!("dragonfly:{groups}"),
        }
    }

    /// Check that this kind can be instantiated for `workers`
    /// endpoints (a CLI-friendly version of the constructor asserts).
    pub fn validate(&self, workers: usize) -> anyhow::Result<()> {
        anyhow::ensure!(workers > 0, "topology needs at least one worker");
        match *self {
            TopologyKind::Torus { rows, cols } if rows > 0 && cols > 0 => {
                anyhow::ensure!(
                    rows * cols == workers,
                    "torus {rows}x{cols} needs {} workers, got {workers}",
                    rows * cols
                );
            }
            TopologyKind::Torus3 { x, y, z } if x > 0 && y > 0 && z > 0 => {
                anyhow::ensure!(
                    x * y * z == workers,
                    "torus3 {x}x{y}x{z} needs {} workers, got {workers}",
                    x * y * z
                );
            }
            TopologyKind::Hier { groups } if groups > 0 => {
                anyhow::ensure!(
                    groups <= workers,
                    "hier wants {groups} groups but only {workers} workers"
                );
            }
            TopologyKind::Dragonfly { groups } if groups > 0 => {
                anyhow::ensure!(
                    groups <= workers,
                    "dragonfly wants {groups} groups but only {workers} workers"
                );
            }
            _ => {}
        }
        Ok(())
    }
}

/// A cluster wiring + collective protocol implementation.
pub trait Topology {
    /// The (auto-resolved) selector this backend was built from.
    fn kind(&self) -> TopologyKind;
    /// Participating workers (collective endpoints).
    fn workers(&self) -> usize;
    /// Total simulated nodes, including infrastructure (e.g. the hub).
    fn node_count(&self) -> usize {
        self.workers()
    }
    /// Per-link specs this topology imposes on its fabric (e.g. slow
    /// inter-rack uplinks); explicit `FabricConfig::link_overrides`
    /// are applied on top (see `Fabric::for_topology`).
    fn link_overrides(&self, _cfg: &FabricConfig) -> Vec<(usize, usize, LinkSpec)> {
        Vec::new()
    }
    /// Logical round count for gatherv (`Traffic::rounds`).
    fn gather_rounds(&self) -> u32;
    /// Logical round count for allreduce.
    fn reduce_rounds(&self) -> u32;
    /// Every worker ends holding every worker's byte message.
    fn allgatherv(&self, fabric: &mut Fabric, inputs: &[Vec<u8>]) -> SimGather;
    /// Sizes-only gather: the identical protocol and event schedule as
    /// [`Topology::allgatherv`], but payloads are phantom byte counts —
    /// no content is materialized, so a 4096-node sweep costs O(p)
    /// memory instead of O(p²·bytes). `gathered` comes back empty;
    /// traffic, timing, and event counts are exactly those of a real
    /// run with these message sizes.
    fn allgatherv_sized(&self, fabric: &mut Fabric, sizes: &[u64]) -> SimGather;
    /// Every worker ends holding the elementwise sum of all inputs.
    fn allreduce(&self, fabric: &mut Fabric, inputs: &[Vec<f32>]) -> SimReduce;
}

/// Instantiate a backend for `workers` endpoints.
pub fn build_topology(kind: TopologyKind, workers: usize) -> Box<dyn Topology> {
    match kind {
        TopologyKind::Ring => Box::new(super::ring::Ring::new(workers)),
        TopologyKind::Full => Box::new(FullMesh::new(workers)),
        TopologyKind::Star => Box::new(super::star::Star::new(workers)),
        TopologyKind::Tree { branch } => Box::new(super::tree::Tree::new(workers, branch)),
        TopologyKind::Torus { rows, cols } => {
            Box::new(super::torus::Torus::new(workers, rows, cols))
        }
        TopologyKind::Torus3 { x, y, z } => Box::new(super::torus3::Torus3::new(workers, x, y, z)),
        TopologyKind::Hier { groups } => {
            Box::new(super::hierarchy::Hierarchy::new(workers, groups))
        }
        TopologyKind::Dragonfly { groups } => {
            Box::new(super::dragonfly::Dragonfly::new(workers, groups))
        }
    }
}

/// Map a topology onto the survivors of a crash: the collective runs
/// over the live ranks only, routed around the dead nodes. Returns the
/// survivor topology (defined over logical ranks `0..q`), the rank map
/// (`map[logical] = physical`), and the physical node count — the
/// inputs [`super::Fabric::for_degraded`] needs. Ring, mesh, tree, and
/// hierarchy re-span over the survivor set; a torus re-tiles to a
/// near-square grid (route-around); a dead star hub hands aggregation
/// to the lowest surviving worker (leader re-election, becoming a
/// single-group tree). `dead` may name the star's hub (`workers`).
pub fn degraded_topology(
    kind: TopologyKind,
    workers: usize,
    dead: &[usize],
) -> (Box<dyn Topology>, Vec<usize>, usize) {
    let live: Vec<usize> = (0..workers).filter(|w| !dead.contains(w)).collect();
    assert!(!live.is_empty(), "no survivors to run a collective over");
    let q = live.len();
    match kind {
        TopologyKind::Star => {
            let hub = workers;
            let phys = workers + 1;
            if dead.contains(&hub) {
                let topo = build_topology(TopologyKind::Tree { branch: q }, q);
                (topo, live, phys)
            } else {
                let mut map = live;
                map.push(hub);
                (build_topology(TopologyKind::Star, q), map, phys)
            }
        }
        TopologyKind::Torus { .. } => {
            let topo = build_topology(TopologyKind::Torus { rows: 0, cols: 0 }, q);
            (topo, live, workers)
        }
        TopologyKind::Torus3 { .. } => {
            let topo = build_topology(TopologyKind::Torus3 { x: 0, y: 0, z: 0 }, q);
            (topo, live, workers)
        }
        TopologyKind::Hier { groups } => {
            // Keep the group count where possible; fewer survivors than
            // groups collapses to one group per survivor.
            let g = if groups == 0 { 0 } else { groups.min(q) };
            (build_topology(TopologyKind::Hier { groups: g }, q), live, workers)
        }
        TopologyKind::Dragonfly { groups } => {
            let g = if groups == 0 { 0 } else { groups.min(q) };
            (
                build_topology(TopologyKind::Dragonfly { groups: g }, q),
                live,
                workers,
            )
        }
        k => (build_topology(k, q), live, workers),
    }
}

// ---- fully-connected mesh ----

/// Every pair of workers has a direct path; collectives are one
/// logical round with no forwarding. Egress/ingress port contention is
/// the only queueing (each node still pushes p−1 copies through its
/// own NIC).
pub struct FullMesh {
    p: usize,
}

impl FullMesh {
    pub fn new(workers: usize) -> FullMesh {
        assert!(workers > 0, "topology needs at least one worker");
        FullMesh { p: workers }
    }

    /// Drive one gather (real or phantom payloads) through the event
    /// loop — both `allgatherv` flavors run this identical code.
    fn run_gather(&self, fabric: &mut Fabric, segs: SegPayloads, state: GatherState) -> SimGather {
        let mut proto = MeshGather {
            p: self.p,
            segs,
            state,
        };
        let time_ps = fabric.run(&mut proto);
        SimGather {
            gathered: proto.state.into_gathered(),
            traffic: traffic_from(fabric, self.gather_rounds()),
            time_ps,
            events: fabric.events(),
        }
    }
}

struct MeshGather {
    p: usize,
    segs: SegPayloads,
    state: GatherState,
}

impl Protocol for MeshGather {
    fn start(&mut self) -> Vec<(usize, usize, Msg)> {
        let mut out = Vec::new();
        for w in 0..self.p {
            for v in 0..self.p {
                if v != w {
                    for si in 0..self.segs.seg_count(w) {
                        out.push((
                            w,
                            v,
                            Msg {
                                origin: w,
                                seg: si as u32,
                                hop: 0,
                                tag: 0,
                                payload: self.segs.payload(w, si),
                            },
                        ));
                    }
                }
            }
        }
        out
    }

    fn on_deliver(&mut self, node: usize, msg: &Msg) -> Vec<(usize, Msg)> {
        self.state
            .store_payload(node, msg.origin, msg.seg as usize, &msg.payload);
        Vec::new()
    }
}

struct MeshReduce {
    p: usize,
    inputs: Vec<Vec<f32>>,
    got: Vec<Vec<Option<Vec<f32>>>>,
}

impl Protocol for MeshReduce {
    fn start(&mut self) -> Vec<(usize, usize, Msg)> {
        let mut out = Vec::new();
        for w in 0..self.p {
            for v in 0..self.p {
                if v != w {
                    out.push((
                        w,
                        v,
                        Msg {
                            origin: w,
                            seg: 0,
                            hop: 0,
                            tag: 0,
                            payload: Payload::F32(self.inputs[w].clone()),
                        },
                    ));
                }
            }
        }
        out
    }

    fn on_deliver(&mut self, node: usize, msg: &Msg) -> Vec<(usize, Msg)> {
        if let Payload::F32(v) = &msg.payload {
            self.got[node][msg.origin] = Some(v.clone());
        }
        Vec::new()
    }
}

impl Topology for FullMesh {
    fn kind(&self) -> TopologyKind {
        TopologyKind::Full
    }

    fn workers(&self) -> usize {
        self.p
    }

    fn gather_rounds(&self) -> u32 {
        u32::from(self.p > 1)
    }

    fn reduce_rounds(&self) -> u32 {
        u32::from(self.p > 1)
    }

    fn allgatherv(&self, fabric: &mut Fabric, inputs: &[Vec<u8>]) -> SimGather {
        assert_eq!(inputs.len(), self.p, "one input message per worker");
        let seg = fabric.segment_bytes();
        self.run_gather(
            fabric,
            SegPayloads::real(inputs, seg),
            GatherState::new(inputs, seg),
        )
    }

    fn allgatherv_sized(&self, fabric: &mut Fabric, sizes: &[u64]) -> SimGather {
        assert_eq!(sizes.len(), self.p, "one size per worker");
        let seg = fabric.segment_bytes();
        self.run_gather(
            fabric,
            SegPayloads::phantom(sizes, seg),
            GatherState::sized(sizes, seg),
        )
    }

    fn allreduce(&self, fabric: &mut Fabric, inputs: &[Vec<f32>]) -> SimReduce {
        assert_eq!(inputs.len(), self.p);
        let n = inputs[0].len();
        assert!(inputs.iter().all(|v| v.len() == n), "length mismatch");
        let mut got: Vec<Vec<Option<Vec<f32>>>> = vec![vec![None; self.p]; self.p];
        for (w, row) in got.iter_mut().enumerate() {
            row[w] = Some(inputs[w].clone());
        }
        let mut proto = MeshReduce {
            p: self.p,
            inputs: inputs.to_vec(),
            got,
        };
        let time_ps = fabric.run(&mut proto);
        // Sum in origin order on every node — identical bits everywhere.
        let reduced: Vec<Vec<f32>> = proto
            .got
            .iter()
            .map(|row| {
                let mut out = vec![0.0f32; n];
                for slot in row {
                    let v = slot.as_ref().expect("mesh reduce under-delivered");
                    for (k, x) in v.iter().enumerate() {
                        out[k] += x;
                    }
                }
                out
            })
            .collect();
        SimReduce {
            reduced,
            traffic: traffic_from(fabric, self.reduce_rounds()),
            time_ps,
            events: fabric.events(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{FabricConfig, LinkSpec};

    fn fabric(p: usize) -> Fabric {
        Fabric::for_config(
            &FabricConfig {
                link: LinkSpec {
                    bandwidth_gbps: 1.0,
                    latency_us: 1.0,
                    jitter_us: 0.0,
                },
                ..FabricConfig::default()
            },
            p,
        )
    }

    #[test]
    fn kind_parse_and_label_roundtrip() {
        for k in [
            TopologyKind::Ring,
            TopologyKind::Full,
            TopologyKind::Star,
            TopologyKind::Tree { branch: 4 },
            TopologyKind::Tree { branch: 8 },
            TopologyKind::Torus { rows: 0, cols: 0 },
            TopologyKind::Torus { rows: 4, cols: 2 },
            TopologyKind::Torus3 { x: 0, y: 0, z: 0 },
            TopologyKind::Torus3 { x: 4, y: 2, z: 2 },
            TopologyKind::Hier { groups: 0 },
            TopologyKind::Hier { groups: 3 },
            TopologyKind::Dragonfly { groups: 0 },
            TopologyKind::Dragonfly { groups: 4 },
        ] {
            assert_eq!(TopologyKind::parse(&k.label()).unwrap(), k);
        }
        assert_eq!(
            TopologyKind::parse("tree").unwrap(),
            TopologyKind::Tree { branch: 4 }
        );
        // The one-time `torus` parse bug: it must now resolve to the
        // auto-dims torus instead of an error.
        assert_eq!(
            TopologyKind::parse("torus").unwrap(),
            TopologyKind::Torus { rows: 0, cols: 0 }
        );
        assert_eq!(
            TopologyKind::parse("hier").unwrap(),
            TopologyKind::Hier { groups: 0 }
        );
        assert!(TopologyKind::parse("tree:0").is_err());
        assert!(TopologyKind::parse("torus:0x2").is_err());
        assert!(TopologyKind::parse("torus:4").is_err());
        assert!(TopologyKind::parse("torus3:4x4").is_err());
        assert!(TopologyKind::parse("torus3:0x2x2").is_err());
        assert!(TopologyKind::parse("hier:0").is_err());
        assert!(TopologyKind::parse("dragonfly:0").is_err());
    }

    #[test]
    fn parse_errors_enumerate_the_accepted_set() {
        let err = TopologyKind::parse("moebius").unwrap_err().to_string();
        for form in ["ring", "full", "star", "tree", "torus", "torus3", "hier", "dragonfly"] {
            assert!(err.contains(form), "'{form}' missing from: {err}");
        }
    }

    #[test]
    fn validate_checks_shape_against_workers() {
        assert!(TopologyKind::Torus { rows: 2, cols: 3 }.validate(6).is_ok());
        assert!(TopologyKind::Torus { rows: 2, cols: 3 }.validate(7).is_err());
        assert!(TopologyKind::Torus { rows: 0, cols: 0 }.validate(7).is_ok()); // auto
        assert!(TopologyKind::Hier { groups: 4 }.validate(3).is_err());
        assert!(TopologyKind::Hier { groups: 0 }.validate(3).is_ok()); // auto
        assert!(TopologyKind::Torus3 { x: 2, y: 2, z: 2 }.validate(8).is_ok());
        assert!(TopologyKind::Torus3 { x: 2, y: 2, z: 2 }.validate(9).is_err());
        assert!(TopologyKind::Torus3 { x: 0, y: 0, z: 0 }.validate(9).is_ok()); // auto
        assert!(TopologyKind::Dragonfly { groups: 4 }.validate(3).is_err());
        assert!(TopologyKind::Dragonfly { groups: 0 }.validate(3).is_ok()); // auto
        assert!(TopologyKind::Ring.validate(0).is_err());
    }

    #[test]
    fn degraded_topologies_respan_the_survivors() {
        // Ring loses node 1 of 4: three survivors keep their ids.
        let (topo, map, phys) = degraded_topology(TopologyKind::Ring, 4, &[1]);
        assert_eq!(topo.workers(), 3);
        assert_eq!(map, vec![0, 2, 3]);
        assert_eq!(phys, 4);
        // A star with a live hub keeps it as the last logical node.
        let (topo, map, phys) = degraded_topology(TopologyKind::Star, 4, &[2]);
        assert_eq!(topo.kind(), TopologyKind::Star);
        assert_eq!(topo.node_count(), 4); // 3 workers + hub
        assert_eq!(map, vec![0, 1, 3, 4]);
        assert_eq!(phys, 5);
        // A dead hub hands aggregation to the lowest surviving worker.
        let (topo, map, _) = degraded_topology(TopologyKind::Star, 4, &[4]);
        assert_eq!(topo.kind(), TopologyKind::Tree { branch: 4 });
        assert_eq!(map, vec![0, 1, 2, 3]);
        // A torus re-tiles near-square over the survivors.
        let (topo, _, _) = degraded_topology(TopologyKind::Torus { rows: 2, cols: 3 }, 6, &[5]);
        assert_eq!(topo.workers(), 5);
        // Hierarchy clamps its group count to the survivor count.
        let (topo, _, _) = degraded_topology(TopologyKind::Hier { groups: 3 }, 4, &[0, 2]);
        assert_eq!(topo.kind(), TopologyKind::Hier { groups: 2 });
        // A 3-D torus re-tiles over the survivors like the 2-D one.
        let (topo, _, _) =
            degraded_topology(TopologyKind::Torus3 { x: 2, y: 2, z: 2 }, 8, &[7]);
        assert_eq!(topo.workers(), 7);
        // Dragonfly clamps its group count like hier.
        let (topo, _, _) = degraded_topology(TopologyKind::Dragonfly { groups: 3 }, 4, &[0, 2]);
        assert_eq!(topo.kind(), TopologyKind::Dragonfly { groups: 2 });
    }

    #[test]
    fn mesh_gather_delivers_everything_in_one_round() {
        let inputs = vec![vec![1u8; 10], vec![2u8; 3], vec![3u8; 7], vec![]];
        let topo = FullMesh::new(4);
        let mut f = fabric(topo.node_count());
        let res = topo.allgatherv(&mut f, &inputs);
        for dst in 0..4 {
            for src in 0..4 {
                assert_eq!(res.gathered[dst][src], inputs[src]);
            }
        }
        assert_eq!(res.traffic.rounds, 1);
        // Each worker pushes p−1 copies of its own message.
        for (w, input) in inputs.iter().enumerate() {
            assert_eq!(
                res.traffic.bytes_sent_per_node[w],
                3 * input.len() as u64,
                "worker {w}"
            );
        }
        assert_eq!(res.events, 12); // p(p−1) deliveries
    }

    #[test]
    fn mesh_reduce_is_elementwise_sum() {
        let inputs = vec![vec![1.0f32, -2.0], vec![0.5, 0.5], vec![2.5, 10.0]];
        let topo = FullMesh::new(3);
        let mut f = fabric(3);
        let res = topo.allreduce(&mut f, &inputs);
        for node in 0..3 {
            assert_eq!(res.reduced[node], vec![4.0, 8.5], "node {node}");
        }
    }

    #[test]
    fn single_worker_mesh_is_a_noop() {
        let topo = FullMesh::new(1);
        let mut f = fabric(1);
        let res = topo.allgatherv(&mut f, &[vec![9u8; 5]]);
        assert_eq!(res.gathered[0][0], vec![9u8; 5]);
        assert_eq!(res.time_ps, 0);
        assert_eq!(res.traffic.rounds, 0);
    }
}
