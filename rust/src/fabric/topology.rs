//! Pluggable topology backends: the trait, the selector enum, and the
//! fully-connected mesh reference implementation.
//!
//! A topology owns the routing/protocol logic for both collectives and
//! is judged on two axes the sweep reports: simulated wall-clock and
//! per-link traffic. Ring ([`super::ring`]) is the paper's substrate;
//! star ([`super::star`]) models a parameter server; tree
//! ([`super::tree`]) a 2-level hierarchical cluster (e.g. rack-local
//! leaders); [`FullMesh`] here is the contention-free upper bound.

use super::collectives::{traffic_from, GatherState, SimGather, SimReduce};
use super::{Fabric, Msg, Payload, Protocol};

/// Topology selector, parsed from `--topology`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TopologyKind {
    Ring,
    Full,
    Star,
    Tree { branch: usize },
}

impl TopologyKind {
    /// Parse `ring`, `full`, `star`, `tree` (branch 4) or `tree:<b>`.
    pub fn parse(s: &str) -> anyhow::Result<TopologyKind> {
        let (head, rest) = match s.split_once(':') {
            Some((h, r)) => (h, Some(r)),
            None => (s, None),
        };
        match (head, rest) {
            ("ring", None) => Ok(TopologyKind::Ring),
            ("full", None) => Ok(TopologyKind::Full),
            ("star", None) => Ok(TopologyKind::Star),
            ("tree", None) => Ok(TopologyKind::Tree { branch: 4 }),
            ("tree", Some(b)) => {
                let branch: usize = b
                    .parse()
                    .map_err(|e| anyhow::anyhow!("tree branch '{b}': {e}"))?;
                anyhow::ensure!(branch >= 1, "tree branch must be >= 1");
                Ok(TopologyKind::Tree { branch })
            }
            _ => anyhow::bail!("unknown topology '{s}' (ring|full|star|tree[:branch])"),
        }
    }

    /// Canonical string form (parses back).
    pub fn label(&self) -> String {
        match self {
            TopologyKind::Ring => "ring".into(),
            TopologyKind::Full => "full".into(),
            TopologyKind::Star => "star".into(),
            TopologyKind::Tree { branch } => format!("tree:{branch}"),
        }
    }
}

/// A cluster wiring + collective protocol implementation.
pub trait Topology {
    fn kind(&self) -> TopologyKind;
    /// Participating workers (collective endpoints).
    fn workers(&self) -> usize;
    /// Total simulated nodes, including infrastructure (e.g. the hub).
    fn node_count(&self) -> usize {
        self.workers()
    }
    /// Logical round count for gatherv (`Traffic::rounds`).
    fn gather_rounds(&self) -> u32;
    /// Logical round count for allreduce.
    fn reduce_rounds(&self) -> u32;
    /// Every worker ends holding every worker's byte message.
    fn allgatherv(&self, fabric: &mut Fabric, inputs: &[Vec<u8>]) -> SimGather;
    /// Every worker ends holding the elementwise sum of all inputs.
    fn allreduce(&self, fabric: &mut Fabric, inputs: &[Vec<f32>]) -> SimReduce;
}

/// Instantiate a backend for `workers` endpoints.
pub fn build_topology(kind: TopologyKind, workers: usize) -> Box<dyn Topology> {
    match kind {
        TopologyKind::Ring => Box::new(super::ring::Ring::new(workers)),
        TopologyKind::Full => Box::new(FullMesh::new(workers)),
        TopologyKind::Star => Box::new(super::star::Star::new(workers)),
        TopologyKind::Tree { branch } => Box::new(super::tree::Tree::new(workers, branch)),
    }
}

// ---- fully-connected mesh ----

/// Every pair of workers has a direct path; collectives are one
/// logical round with no forwarding. Egress/ingress port contention is
/// the only queueing (each node still pushes p−1 copies through its
/// own NIC).
pub struct FullMesh {
    p: usize,
}

impl FullMesh {
    pub fn new(workers: usize) -> FullMesh {
        assert!(workers > 0, "topology needs at least one worker");
        FullMesh { p: workers }
    }
}

struct MeshGather {
    p: usize,
    inputs: Vec<Vec<u8>>,
    state: GatherState,
}

impl Protocol for MeshGather {
    fn start(&mut self) -> Vec<(usize, usize, Msg)> {
        let mut out = Vec::new();
        for w in 0..self.p {
            for v in 0..self.p {
                if v != w {
                    out.push((
                        w,
                        v,
                        Msg {
                            origin: w,
                            hop: 0,
                            tag: 0,
                            payload: Payload::Bytes(self.inputs[w].clone()),
                        },
                    ));
                }
            }
        }
        out
    }

    fn on_deliver(&mut self, node: usize, msg: &Msg) -> Vec<(usize, Msg)> {
        if let Payload::Bytes(b) = &msg.payload {
            self.state.store(node, msg.origin, b);
        }
        Vec::new()
    }
}

struct MeshReduce {
    p: usize,
    inputs: Vec<Vec<f32>>,
    got: Vec<Vec<Option<Vec<f32>>>>,
}

impl Protocol for MeshReduce {
    fn start(&mut self) -> Vec<(usize, usize, Msg)> {
        let mut out = Vec::new();
        for w in 0..self.p {
            for v in 0..self.p {
                if v != w {
                    out.push((
                        w,
                        v,
                        Msg {
                            origin: w,
                            hop: 0,
                            tag: 0,
                            payload: Payload::F32(self.inputs[w].clone()),
                        },
                    ));
                }
            }
        }
        out
    }

    fn on_deliver(&mut self, node: usize, msg: &Msg) -> Vec<(usize, Msg)> {
        if let Payload::F32(v) = &msg.payload {
            self.got[node][msg.origin] = Some(v.clone());
        }
        Vec::new()
    }
}

impl Topology for FullMesh {
    fn kind(&self) -> TopologyKind {
        TopologyKind::Full
    }

    fn workers(&self) -> usize {
        self.p
    }

    fn gather_rounds(&self) -> u32 {
        u32::from(self.p > 1)
    }

    fn reduce_rounds(&self) -> u32 {
        u32::from(self.p > 1)
    }

    fn allgatherv(&self, fabric: &mut Fabric, inputs: &[Vec<u8>]) -> SimGather {
        assert_eq!(inputs.len(), self.p, "one input message per worker");
        let mut proto = MeshGather {
            p: self.p,
            inputs: inputs.to_vec(),
            state: GatherState::new(inputs),
        };
        let time_ps = fabric.run(&mut proto);
        SimGather {
            gathered: proto.state.into_gathered(),
            traffic: traffic_from(fabric, self.gather_rounds()),
            time_ps,
            events: fabric.events(),
        }
    }

    fn allreduce(&self, fabric: &mut Fabric, inputs: &[Vec<f32>]) -> SimReduce {
        assert_eq!(inputs.len(), self.p);
        let n = inputs[0].len();
        assert!(inputs.iter().all(|v| v.len() == n), "length mismatch");
        let mut got: Vec<Vec<Option<Vec<f32>>>> = vec![vec![None; self.p]; self.p];
        for (w, row) in got.iter_mut().enumerate() {
            row[w] = Some(inputs[w].clone());
        }
        let mut proto = MeshReduce {
            p: self.p,
            inputs: inputs.to_vec(),
            got,
        };
        let time_ps = fabric.run(&mut proto);
        // Sum in origin order on every node — identical bits everywhere.
        let reduced: Vec<Vec<f32>> = proto
            .got
            .iter()
            .map(|row| {
                let mut out = vec![0.0f32; n];
                for slot in row {
                    let v = slot.as_ref().expect("mesh reduce under-delivered");
                    for (k, x) in v.iter().enumerate() {
                        out[k] += x;
                    }
                }
                out
            })
            .collect();
        SimReduce {
            reduced,
            traffic: traffic_from(fabric, self.reduce_rounds()),
            time_ps,
            events: fabric.events(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{FabricConfig, LinkSpec};

    fn fabric(p: usize) -> Fabric {
        Fabric::for_config(
            &FabricConfig {
                link: LinkSpec {
                    bandwidth_gbps: 1.0,
                    latency_us: 1.0,
                    jitter_us: 0.0,
                },
                ..FabricConfig::default()
            },
            p,
        )
    }

    #[test]
    fn kind_parse_and_label_roundtrip() {
        for k in [
            TopologyKind::Ring,
            TopologyKind::Full,
            TopologyKind::Star,
            TopologyKind::Tree { branch: 4 },
            TopologyKind::Tree { branch: 8 },
        ] {
            assert_eq!(TopologyKind::parse(&k.label()).unwrap(), k);
        }
        assert_eq!(
            TopologyKind::parse("tree").unwrap(),
            TopologyKind::Tree { branch: 4 }
        );
        assert!(TopologyKind::parse("torus").is_err());
        assert!(TopologyKind::parse("tree:0").is_err());
    }

    #[test]
    fn mesh_gather_delivers_everything_in_one_round() {
        let inputs = vec![vec![1u8; 10], vec![2u8; 3], vec![3u8; 7], vec![]];
        let topo = FullMesh::new(4);
        let mut f = fabric(topo.node_count());
        let res = topo.allgatherv(&mut f, &inputs);
        for dst in 0..4 {
            for src in 0..4 {
                assert_eq!(res.gathered[dst][src], inputs[src]);
            }
        }
        assert_eq!(res.traffic.rounds, 1);
        // Each worker pushes p−1 copies of its own message.
        for (w, input) in inputs.iter().enumerate() {
            assert_eq!(
                res.traffic.bytes_sent_per_node[w],
                3 * input.len() as u64,
                "worker {w}"
            );
        }
        assert_eq!(res.events, 12); // p(p−1) deliveries
    }

    #[test]
    fn mesh_reduce_is_elementwise_sum() {
        let inputs = vec![vec![1.0f32, -2.0], vec![0.5, 0.5], vec![2.5, 10.0]];
        let topo = FullMesh::new(3);
        let mut f = fabric(3);
        let res = topo.allreduce(&mut f, &inputs);
        for node in 0..3 {
            assert_eq!(res.reduced[node], vec![4.0, 8.5], "node {node}");
        }
    }

    #[test]
    fn single_worker_mesh_is_a_noop() {
        let topo = FullMesh::new(1);
        let mut f = fabric(1);
        let res = topo.allgatherv(&mut f, &[vec![9u8; 5]]);
        assert_eq!(res.gathered[0][0], vec![9u8; 5]);
        assert_eq!(res.time_ps, 0);
        assert_eq!(res.traffic.rounds, 0);
    }
}
