//! `fabric` — event-driven cluster/network simulation (S16).
//!
//! The paper's Section 5 argues VGC "enables distributed deep learning
//! …with commodity environments" from a purely analytic cost model;
//! this subsystem lets the repo *simulate* that claim instead of only
//! asserting it. A cluster is a set of [`node::Node`] endpoints
//! exchanging [`Msg`]s over links with configurable bandwidth, latency
//! and jitter ([`link::LinkSpec`], resolved per directed edge by a
//! [`link::LinkTable`]), with per-node straggler injection
//! ([`node::Straggler`]), driven by a deterministic discrete-event
//! clock ([`clock::SimClock`]) — no real sleeping, reproducible under
//! `util::rng` seeds.
//!
//! On top of the engine, pluggable [`topology::Topology`] backends
//! (ring, fully-connected, parameter-server hub, 2-level tree, 2-D
//! torus, NUMA-aware hierarchy) expose `allgatherv`/`allreduce`
//! collectives that move the *actual bytes*, so the byte-accurate
//! codec path runs unchanged over any topology. Gather messages can be
//! pipelined in segments of the cost model's block size `m`
//! ([`FabricConfig::segment_bytes`]). `comm::allgatherv` /
//! `comm::allreduce` are thin fronts over the configured topology;
//! `repro fabric-sweep` sweeps {topology × bandwidth × workers ×
//! codec} end to end. See DESIGN.md §Fabric and docs/TOPOLOGIES.md.
//!
//! Timing model (cut-through ports):
//!
//! * a send occupies the source egress port for `ser × slowdown(src)`,
//!   queued FIFO behind earlier sends;
//! * the first bit lands `latency + jitter` after transmission starts;
//! * delivery completes `ser × slowdown(dst)` after the first bit
//!   clears the destination ingress queue (incast contention).
//!
//! Uncontended, a hop costs the classic `ser + latency`; contention at
//! ports reproduces hub incast and broadcast bottlenecks.
//!
//! ```
//! use vgc::fabric::{build_topology, Fabric, LinkSpec, TopologyKind};
//!
//! let topo = build_topology(TopologyKind::Torus { rows: 2, cols: 2 }, 4);
//! let mut fabric = Fabric::new(LinkSpec::gige(), topo.node_count(), 0);
//! let inputs: Vec<Vec<u8>> = (0..4).map(|w| vec![w as u8; 32]).collect();
//! let out = topo.allgatherv(&mut fabric, &inputs);
//! assert_eq!(out.gathered[3][1], inputs[1]);
//! assert!(out.time_ps > 0);
//! ```

pub mod clock;
pub mod collectives;
pub mod dragonfly;
pub mod fastpath;
pub mod faults;
pub mod groups;
pub mod hierarchy;
pub mod link;
pub mod node;
pub mod ring;
pub mod star;
pub mod topology;
pub mod torus;
pub mod torus3;
pub mod tree;

use std::collections::BTreeMap;

pub use clock::{SimClock, Time};
pub use collectives::{SimGather, SimReduce};
pub use fastpath::{gather_sized, Engine};
pub use faults::{FabricReport, FaultPlan};
pub use link::{LinkSpec, LinkStat, LinkTable};
pub use node::{Node, NodePerf, Straggler};
pub use topology::{build_topology, degraded_topology, Topology, TopologyKind};

use crate::util::backoff::Backoff;
use crate::util::cli::Args;
use crate::util::json::{num, obj, s, Json};
use crate::util::rng::Pcg32;

/// Failed transmissions of one message on one hop after which the
/// simulation gives up. With loss rates capped at
/// [`faults::MAX_LOSS_RATE`] the chance of hitting this is
/// astronomically small; reaching it means the plan describes a link
/// that cannot make progress.
const MAX_SEND_ATTEMPTS: u32 = 1_000;

/// Message payloads: wire bytes (codec messages), f32 vectors (dense
/// allreduce partials), or sized-but-contentless phantoms (the
/// scale-sweep fast tier — see `Topology::allgatherv_sized`). Sizes
/// are what the links bill for; timing never depends on content, so a
/// phantom of `n` bytes traverses the engine tick-identically to any
/// real `n`-byte message.
#[derive(Debug, Clone, PartialEq)]
pub enum Payload {
    Bytes(Vec<u8>),
    F32(Vec<f32>),
    Phantom(u64),
}

impl Payload {
    pub fn size_bytes(&self) -> u64 {
        match self {
            Payload::Bytes(b) => b.len() as u64,
            Payload::F32(v) => v.len() as u64 * 4,
            Payload::Phantom(b) => *b,
        }
    }
}

/// One in-flight message. `origin` identifies the block/chunk the
/// payload represents; `seg` its pipeline segment index (0 when
/// unsegmented); `hop` counts forwarding steps; `tag` distinguishes
/// protocol phases (topology-specific).
#[derive(Debug, Clone, PartialEq)]
pub struct Msg {
    pub origin: usize,
    pub seg: u32,
    pub hop: u32,
    pub tag: u8,
    pub payload: Payload,
}

/// Events in the clock queue: a successful delivery handed to the
/// protocol, or a retransmit timer for a message the chaos plan
/// dropped or corrupted in flight. `dst`/`src` are logical ranks (see
/// [`Fabric::for_degraded`]). The message itself lives in the
/// [`MsgArena`] — queue entries stay small and `Msg` moves exactly
/// once per hop instead of rippling through every heap sift.
enum Ev {
    Delivery {
        dst: usize,
        slot: u32,
    },
    Retransmit {
        src: usize,
        dst: usize,
        slot: u32,
        attempt: u32,
    },
}

/// Slab of in-flight [`Msg`] state, indexed by the `slot` ids queue
/// events carry. Slots are recycled through a free list, so steady
/// state holds exactly the in-flight message count regardless of how
/// many events a collective schedules over its lifetime.
#[derive(Default)]
struct MsgArena {
    slots: Vec<Option<Msg>>,
    free: Vec<u32>,
}

impl MsgArena {
    fn put(&mut self, msg: Msg) -> u32 {
        match self.free.pop() {
            Some(i) => {
                self.slots[i as usize] = Some(msg);
                i
            }
            None => {
                assert!(self.slots.len() < u32::MAX as usize, "message arena overflow");
                self.slots.push(Some(msg));
                (self.slots.len() - 1) as u32
            }
        }
    }

    fn take(&mut self, slot: u32) -> Msg {
        let msg = self.slots[slot as usize]
            .take()
            .expect("empty message arena slot");
        self.free.push(slot);
        msg
    }
}

/// Transport-level fault state compiled from a [`FaultPlan`], keyed by
/// *physical* directed edges.
#[derive(Default)]
struct ChaosState {
    active: bool,
    /// `(drop, corrupt)` probabilities per directed edge.
    rates: BTreeMap<(usize, usize), (f64, f64)>,
    /// Outage windows per directed edge, ps relative to run start.
    flaps: BTreeMap<(usize, usize), Vec<(Time, Time)>>,
}

impl ChaosState {
    fn from_plan(plan: &FaultPlan, node_count: usize) -> ChaosState {
        let mut st = ChaosState::default();
        for f in &plan.flaps {
            assert!(
                f.src < node_count && f.dst < node_count,
                "flap edge {}-{} out of range (fabric has {node_count} nodes)",
                f.src,
                f.dst
            );
            st.flaps.entry((f.src, f.dst)).or_default().push((
                (f.down_us * 1_000_000.0) as Time, // us -> ps
                (f.up_us * 1_000_000.0) as Time,
            ));
        }
        for c in &plan.chaos {
            assert!(
                c.src < node_count && c.dst < node_count,
                "loss edge {}-{} out of range (fabric has {node_count} nodes)",
                c.src,
                c.dst
            );
            st.rates.insert((c.src, c.dst), (c.drop, c.corrupt));
        }
        st.active = !(st.rates.is_empty() && st.flaps.is_empty());
        st
    }

    /// If `t_rel` falls inside a down window of `edge`, the window's
    /// end (ps relative to run start).
    fn down_until(&self, edge: (usize, usize), t_rel: Time) -> Option<Time> {
        self.flaps
            .get(&edge)?
            .iter()
            .find(|&&(down, up)| t_rel >= down && t_rel < up)
            .map(|&(_, up)| up)
    }
}

/// One line of the event trace: enough to prove two runs identical and
/// to debug a protocol. Recorded in send order.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceEvent {
    pub sent: Time,
    pub delivered: Time,
    pub src: usize,
    pub dst: usize,
    pub origin: usize,
    pub tag: u8,
    pub bytes: u64,
}

/// A collective protocol driven by the engine: `start` injects the
/// t = 0 sends `(src, dst, msg)`; `on_deliver` reacts to a delivery at
/// `node` with follow-up sends `(dst, msg)` from that node.
pub trait Protocol {
    fn start(&mut self) -> Vec<(usize, usize, Msg)>;
    fn on_deliver(&mut self, node: usize, msg: &Msg) -> Vec<(usize, Msg)>;
}

/// The simulated cluster: nodes + per-edge link model + event clock.
///
/// Fault injection draws from `fault_rng`, a stream separate from the
/// jitter `rng`, so the same seed produces bit-identical timing with
/// and without a chaos plan on the paths the plan leaves untouched.
pub struct Fabric {
    table: LinkTable,
    segment_bytes: usize,
    nodes: Vec<Node>,
    clock: SimClock<Ev>,
    rng: Pcg32,
    fault_rng: Pcg32,
    chaos: ChaosState,
    report: FabricReport,
    /// `rank_map[logical] = physical` when running a degraded
    /// collective over a survivor subset (see
    /// [`Fabric::for_degraded`]); `None` = identity.
    rank_map: Option<Vec<usize>>,
    /// Start time of the current `run` — flap windows are relative to
    /// it, so each collective sees the plan's windows afresh.
    run_t0: Time,
    links: BTreeMap<(usize, usize), LinkStat>,
    trace: Vec<TraceEvent>,
    /// Trace recording toggle (default on — replay tests depend on
    /// it). Large-scale sweeps turn it off: at 4096 nodes one gather
    /// records ~17M trace lines (~1 GB) nobody reads.
    trace_enabled: bool,
    arena: MsgArena,
}

impl Fabric {
    /// Build a fabric of `node_count` endpoints (workers plus any
    /// infrastructure nodes the topology needs) with a uniform link
    /// model and no segmentation.
    pub fn new(link: LinkSpec, node_count: usize, seed: u64) -> Fabric {
        Fabric {
            table: LinkTable::uniform(link),
            segment_bytes: 0,
            nodes: (0..node_count).map(Node::new).collect(),
            // One delivery lane per ingress port: the fabric resolves
            // ingress contention at send time, so per-port delivery
            // times are nondecreasing in schedule order and qualify
            // for the clock's O(1) FIFO lanes.
            clock: SimClock::with_lanes(node_count),
            rng: Pcg32::new(seed, 0xFAB),
            fault_rng: Pcg32::new(seed, 0xFA17),
            chaos: ChaosState::default(),
            report: FabricReport::default(),
            rank_map: None,
            run_t0: 0,
            links: BTreeMap::new(),
            trace: Vec::new(),
            trace_enabled: true,
            arena: MsgArena::default(),
        }
    }

    /// Build from a config for a topology needing `node_count` nodes.
    /// A straggler spec or link override naming a node that does not
    /// exist is a config error, not a no-op — silently dropping it
    /// would let `describe()` report a degradation the simulation
    /// never applied.
    pub fn for_config(cfg: &FabricConfig, node_count: usize) -> Fabric {
        Fabric::build(cfg, node_count, &[])
    }

    /// Build for a concrete topology: like [`Fabric::for_config`], but
    /// topology-derived link overrides (e.g. the hierarchy's slow
    /// inter-rack uplinks) are applied first, so explicit
    /// `FabricConfig::link_overrides` always win.
    pub fn for_topology(cfg: &FabricConfig, topo: &dyn Topology) -> Fabric {
        Fabric::build(cfg, topo.node_count(), &topo.link_overrides(cfg))
    }

    /// Build for a degraded collective over a survivor set. The
    /// topology is defined over *logical* ranks `0..topo.node_count()`
    /// and `rank_map[logical]` names the physical node backing each
    /// rank. Ports, link specs, stragglers, traffic accounting, chaos
    /// edges, and the trace all stay physical; protocols keep speaking
    /// logical ranks. Topology-derived link overrides (logical — e.g.
    /// a re-elected hierarchy leader's uplinks) are translated through
    /// the map; explicit config overrides stay physical and still win.
    pub fn for_degraded(
        cfg: &FabricConfig,
        topo: &dyn Topology,
        rank_map: Vec<usize>,
        phys_nodes: usize,
    ) -> Fabric {
        assert_eq!(
            rank_map.len(),
            topo.node_count(),
            "rank map must cover every logical node"
        );
        assert!(
            rank_map.iter().all(|&p| p < phys_nodes),
            "rank map names a node outside the physical fabric"
        );
        let translated: Vec<(usize, usize, LinkSpec)> = topo
            .link_overrides(cfg)
            .into_iter()
            .map(|(a, b, spec)| (rank_map[a], rank_map[b], spec))
            .collect();
        let mut f = Fabric::build(cfg, phys_nodes, &translated);
        f.rank_map = Some(rank_map);
        f
    }

    fn build(
        cfg: &FabricConfig,
        node_count: usize,
        topo_overrides: &[(usize, usize, LinkSpec)],
    ) -> Fabric {
        let mut f = Fabric::new(cfg.link, node_count, cfg.seed);
        f.segment_bytes = cfg.segment_bytes;
        for s in &cfg.stragglers {
            assert!(
                s.node < f.nodes.len(),
                "straggler node {} out of range (fabric has {} nodes)",
                s.node,
                f.nodes.len()
            );
            f.nodes[s.node].perf.slowdown = s.slowdown;
        }
        for &(src, dst, spec) in topo_overrides {
            f.set_link(src, dst, spec);
        }
        for &(src, dst, spec) in &cfg.link_overrides {
            f.set_link(src, dst, spec);
        }
        f.chaos = ChaosState::from_plan(&cfg.faults, node_count);
        f
    }

    /// Override the link model of the directed edge `src → dst`.
    pub fn set_link(&mut self, src: usize, dst: usize, spec: LinkSpec) {
        assert!(
            src < self.nodes.len() && dst < self.nodes.len(),
            "link override {src}->{dst} out of range (fabric has {} nodes)",
            self.nodes.len()
        );
        self.table.set(src, dst, spec);
    }

    /// The per-edge link resolver.
    pub fn link_table(&self) -> &LinkTable {
        &self.table
    }

    /// Gather pipeline segment size, bytes (0 = unsegmented).
    pub fn segment_bytes(&self) -> usize {
        self.segment_bytes
    }

    /// Re-pin the gather segment size between collectives — the hook
    /// the overlapped pipeline uses to apply a bandwidth-delay-product
    /// segment derived from this fabric's own [`LinkTable`] (see
    /// `comm::pipeline::bdp_segment_bytes`).
    pub fn set_segment_bytes(&mut self, seg: usize) {
        self.segment_bytes = seg;
    }

    /// Jump the event clock forward to absolute time `t` (no-op when
    /// `t` has already passed). Only legal between `run`s. This is how
    /// a scheduler releases the next collective at a compute-side
    /// readiness time — e.g. "bucket k's encode finishes at `t`; its
    /// gather may not start earlier" — while port state (egress/
    /// ingress free times) carries over, so back-to-back bucket
    /// gathers still contend for the same wires.
    pub fn advance_to(&mut self, t: Time) {
        self.clock.advance_to(t);
    }

    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    pub fn node(&self, id: usize) -> &Node {
        &self.nodes[id]
    }

    /// Simulated time of the last delivery (collective completion).
    pub fn now(&self) -> Time {
        self.clock.now()
    }

    pub fn now_secs(&self) -> f64 {
        self.clock.now() as f64 * 1e-12
    }

    /// Events processed so far (deliveries plus any retransmit timers
    /// — the event-throughput denominator).
    pub fn events(&self) -> u64 {
        self.clock.processed()
    }

    /// Fault and recovery counters accumulated across every `run` on
    /// this fabric. All zeros when no fault fired.
    pub fn report(&self) -> FabricReport {
        self.report
    }

    /// Record `n` route-arounds (dead nodes the caller mapped out of a
    /// collective). The transport cannot see membership changes — the
    /// comm layer reports them here so one [`FabricReport`] carries
    /// the whole story.
    pub fn note_reroutes(&mut self, n: u64) {
        self.report.reroutes += n;
    }

    /// Physical node behind logical rank `n`.
    fn phys(&self, n: usize) -> usize {
        match &self.rank_map {
            Some(m) => m[n],
            None => n,
        }
    }

    /// Retransmit timeout after `attempt` previous failed tries of a
    /// hop: the cost model's analytic per-hop bracket (serialization +
    /// latency + worst-case jitter) as the base, with the same bounded
    /// exponential [`Backoff`] the job scheduler uses — in ps.
    fn rto(&self, spec: &LinkSpec, bytes: u64, attempt: u32) -> Time {
        let hop = (spec.ser_ps(bytes) + spec.latency_ps() + spec.jitter_ps()).max(1);
        let b = Backoff {
            base: hop,
            factor: 2.0,
            max: hop.saturating_mul(64),
        };
        b.delay(attempt + 1)
    }

    /// Per-directed-link traffic accounting, deterministic order.
    pub fn links(&self) -> &BTreeMap<(usize, usize), LinkStat> {
        &self.links
    }

    /// Heaviest single directed link, in bytes.
    pub fn max_link_bytes(&self) -> u64 {
        self.links.values().map(|l| l.bytes).max().unwrap_or(0)
    }

    /// The recorded event trace (send order). Empty when recording is
    /// disabled ([`Fabric::set_trace`]).
    pub fn trace(&self) -> &[TraceEvent] {
        &self.trace
    }

    /// Toggle trace recording (default on). Scale sweeps turn it off —
    /// a 4096-node gather would record ~17M lines nobody reads — and
    /// the closed-form fast path *requires* it off, since skipping the
    /// event loop cannot reproduce per-send trace lines.
    pub fn set_trace(&mut self, on: bool) {
        self.trace_enabled = on;
    }

    /// Whether trace recording is on.
    pub fn trace_enabled(&self) -> bool {
        self.trace_enabled
    }

    /// Why the *closed-form* fast path may not replace the event loop
    /// on this fabric, or `None` when a uniform phase qualifies (see
    /// `fabric::fastpath` and docs/SCALE.md): the closed tier replays
    /// one uniform, jitter-free, fault-free link arithmetic, so any
    /// feature that makes a hop's timing depend on per-send state it
    /// does not model forces the full loop.
    pub fn full_loop_reason(&self) -> Option<&'static str> {
        if self.clock.pending() > 0 {
            return Some("events already pending on the clock");
        }
        if self.chaos.active {
            return Some("chaos plan active (drops/corruption/flaps)");
        }
        if self.trace_enabled {
            return Some("trace recording enabled");
        }
        if self.segment_bytes != 0 {
            return Some("gather segmentation enabled");
        }
        if self.rank_map.is_some() {
            return Some("degraded rank map in effect");
        }
        if !self.table.is_uniform() {
            return Some("per-link overrides present");
        }
        if self.table.default_spec().has_jitter() {
            return Some("link jitter draws from the RNG");
        }
        if self
            .nodes
            .iter()
            .any(|n| n.perf.slowdown != 1.0 || n.perf.compute_ps > 0)
        {
            return Some("straggler or compute-delay node profiles");
        }
        None
    }

    /// One uniform-phase hop resolved closed-form: the exact `send`
    /// arithmetic for the eligible case (uniform links, no jitter, no
    /// chaos, no stragglers — guaranteed by [`Fabric::full_loop_reason`])
    /// with delivery-side accounting billed immediately, since no pop
    /// will happen. Returns the delivery completion time.
    pub(super) fn wire_fast(&mut self, src: usize, dst: usize, bytes: u64, ready: Time) -> Time {
        debug_assert!(src != dst, "self-send from node {src}");
        let spec = *self.table.default_spec();
        let ser = spec.ser_ps(bytes);

        let start_tx = ready.max(self.nodes[src].egress_free);
        self.nodes[src].egress_free = start_tx + ser;
        self.nodes[src].sent_bytes += bytes;
        self.nodes[src].sent_messages += 1;

        let front = start_tx + spec.latency_ps();
        let tx_tail = start_tx + ser + spec.latency_ps();

        let stat = self.links.entry((src, dst)).or_default();
        stat.bytes += bytes;
        stat.messages += 1;

        let rx_start = front.max(self.nodes[dst].ingress_free);
        let delivered = (rx_start + ser).max(tx_tail);
        self.nodes[dst].ingress_free = delivered;
        self.nodes[dst].recv_bytes += bytes;
        self.nodes[dst].recv_messages += 1;
        delivered
    }

    /// Land the clock at `t` crediting `events` closed-form-resolved
    /// events (see [`SimClock::fast_forward`]).
    pub(super) fn fast_forward(&mut self, t: Time, events: u64) {
        self.clock.fast_forward(t, events);
    }

    /// Bytes each node pushed onto its egress port.
    pub fn bytes_sent_per_node(&self) -> Vec<u64> {
        self.nodes.iter().map(|n| n.sent_bytes).collect()
    }

    /// Schedule a message from logical `src` to logical `dst`, not
    /// before `ready`. `attempt` counts prior failed transmissions of
    /// this message on this hop (0 for a first send). Egress time and
    /// link traffic are billed even for transmissions the chaos plan
    /// kills — the bits were pushed onto the wire either way.
    fn send(&mut self, src: usize, dst: usize, msg: Msg, ready: Time, attempt: u32) {
        assert!(src != dst, "self-send from node {src}");
        let (psrc, pdst) = (self.phys(src), self.phys(dst));
        let spec = *self.table.spec(psrc, pdst);
        let bytes = msg.payload.size_bytes();
        let ser = spec.ser_ps(bytes);

        let tx_ser = self.nodes[psrc].scaled(ser);
        let start_tx = ready.max(self.nodes[psrc].egress_free);
        self.nodes[psrc].egress_free = start_tx + tx_ser;
        self.nodes[psrc].sent_bytes += bytes;
        self.nodes[psrc].sent_messages += 1;

        let jitter_max = spec.jitter_ps();
        let jitter = if jitter_max > 0 {
            (self.rng.next_f64() * jitter_max as f64) as Time
        } else {
            0
        };
        let front = start_tx + spec.latency_ps() + jitter;
        let tx_tail = start_tx + tx_ser + spec.latency_ps() + jitter;

        let stat = self.links.entry((psrc, pdst)).or_default();
        stat.bytes += bytes;
        stat.messages += 1;

        if self.chaos.active {
            // Link down when transmission starts: the bits die on the
            // wire (egress spent, ingress never touched). Retry once
            // the window ends, plus the per-hop backoff.
            let t_rel = start_tx.saturating_sub(self.run_t0);
            if let Some(up_rel) = self.chaos.down_until((psrc, pdst), t_rel) {
                self.report.drops += 1;
                if self.trace_enabled {
                    self.trace.push(TraceEvent {
                        sent: start_tx,
                        delivered: tx_tail,
                        src: psrc,
                        dst: pdst,
                        origin: msg.origin,
                        tag: msg.tag,
                        bytes,
                    });
                }
                let at = (self.run_t0 + up_rel).max(tx_tail) + self.rto(&spec, bytes, attempt);
                let slot = self.arena.put(msg);
                self.clock.schedule(at, Ev::Retransmit { src, dst, slot, attempt });
                return;
            }
            if let Some(&(p_drop, p_corrupt)) = self.chaos.rates.get(&(psrc, pdst)) {
                let u = self.fault_rng.next_f64();
                if u < p_drop {
                    // Random loss: same shape as a flap drop.
                    self.report.drops += 1;
                    if self.trace_enabled {
                        self.trace.push(TraceEvent {
                            sent: start_tx,
                            delivered: tx_tail,
                            src: psrc,
                            dst: pdst,
                            origin: msg.origin,
                            tag: msg.tag,
                            bytes,
                        });
                    }
                    let at = tx_tail + self.rto(&spec, bytes, attempt);
                    let slot = self.arena.put(msg);
                    self.clock.schedule(at, Ev::Retransmit { src, dst, slot, attempt });
                    return;
                }
                if u < p_drop + p_corrupt {
                    // Corruption: full delivery timing — the garbage
                    // occupies the ingress port like a real message —
                    // but the receiver discards it on checksum.
                    let rx_ser = self.nodes[pdst].scaled(ser);
                    let rx_start = front.max(self.nodes[pdst].ingress_free);
                    let delivered = (rx_start + rx_ser).max(tx_tail);
                    self.nodes[pdst].ingress_free = delivered;
                    self.report.corruptions += 1;
                    if self.trace_enabled {
                        self.trace.push(TraceEvent {
                            sent: start_tx,
                            delivered,
                            src: psrc,
                            dst: pdst,
                            origin: msg.origin,
                            tag: msg.tag,
                            bytes,
                        });
                    }
                    let at = delivered + self.rto(&spec, bytes, attempt);
                    let slot = self.arena.put(msg);
                    self.clock.schedule(at, Ev::Retransmit { src, dst, slot, attempt });
                    return;
                }
            }
        }

        // Delivery completes when the receiver has drained the message
        // (ingress queue + rx serialization) AND the sender has pushed
        // the last bit (tx serialization + propagation) — whichever is
        // later. Uncontended equal-rate hops reduce to ser + latency.
        let rx_ser = self.nodes[pdst].scaled(ser);
        let rx_start = front.max(self.nodes[pdst].ingress_free);
        let delivered = (rx_start + rx_ser).max(tx_tail);
        self.nodes[pdst].ingress_free = delivered;

        if self.trace_enabled {
            self.trace.push(TraceEvent {
                sent: start_tx,
                delivered,
                src: psrc,
                dst: pdst,
                origin: msg.origin,
                tag: msg.tag,
                bytes,
            });
        }
        let slot = self.arena.put(msg);
        // Per-ingress-port delivery times are nondecreasing in send
        // order (ingress_free was just advanced to `delivered`), so
        // the physical destination's FIFO lane preserves exact
        // (time, seq) pop order at O(1) per push.
        self.clock.schedule_lane(delivered, pdst, Ev::Delivery { dst, slot });
    }

    /// Drive a protocol to completion; returns the finish time (ps).
    /// Running a second protocol on the same fabric continues the
    /// clock (back-to-back collectives share port state). Flap windows
    /// in the fault plan are relative to this run's start.
    pub fn run(&mut self, proto: &mut dyn Protocol) -> Time {
        let t0 = self.clock.now();
        self.run_t0 = t0;
        for (src, dst, msg) in proto.start() {
            self.send(src, dst, msg, t0, 0);
        }
        while let Some((t, ev)) = self.clock.pop() {
            match ev {
                Ev::Delivery { dst, slot } => {
                    let msg = self.arena.take(slot);
                    let pdst = self.phys(dst);
                    self.nodes[pdst].recv_bytes += msg.payload.size_bytes();
                    self.nodes[pdst].recv_messages += 1;
                    let outs = proto.on_deliver(dst, &msg);
                    if !outs.is_empty() {
                        let ready = t + self.nodes[pdst].compute_delay();
                        for (to, m) in outs {
                            self.send(dst, to, m, ready, 0);
                        }
                    }
                }
                Ev::Retransmit {
                    src,
                    dst,
                    slot,
                    attempt,
                } => {
                    let msg = self.arena.take(slot);
                    let attempt = attempt + 1;
                    assert!(
                        attempt <= MAX_SEND_ATTEMPTS,
                        "link {src}->{dst} unrecoverable: \
                         {MAX_SEND_ATTEMPTS} failed transmissions"
                    );
                    self.report.retries += 1;
                    self.report.retransmitted_bytes += msg.payload.size_bytes();
                    self.send(src, dst, msg, t, attempt);
                }
            }
        }
        self.clock.now()
    }
}

/// One directed link's share of a [`FabricTelemetry`] snapshot.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkSample {
    pub src: usize,
    pub dst: usize,
    /// Bytes billed on this edge (including killed transmissions).
    pub bytes: u64,
    pub messages: u64,
    /// Configured bandwidth (Gbps) from the [`LinkTable`] — the
    /// deterministic link-class signal (uplinks are *configured* slow).
    pub gbps: f64,
    /// Achieved throughput over the snapshot window (Gbps): what the
    /// link actually moved per unit time including queueing, jitter
    /// and retransmits.
    pub achieved_gbps: f64,
}

/// Per-step fabric feedback for the adaptive compression controller
/// (`compress::controller`): per-link traffic + bandwidth, the fault/
/// recovery counters, and — when the overlap pipeline produced one —
/// per-bucket comm times. Snapshot semantics: counters are cumulative
/// over the fabric's lifetime (one collective when the caller builds a
/// fresh [`Fabric`] per step, which the comm layer does).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FabricTelemetry {
    pub links: Vec<LinkSample>,
    pub report: FabricReport,
    /// Simulated time covered by the snapshot (ps).
    pub elapsed_ps: Time,
    /// Per-bucket comm time from the overlap schedule (empty for
    /// unbucketed collectives).
    pub bucket_comm_ps: Vec<Time>,
}

impl FabricTelemetry {
    /// Snapshot `fabric` after a run. `bucket_comm_ps` is the overlap
    /// schedule's per-bucket comm time (empty when unbucketed).
    pub fn from_fabric(fabric: &Fabric, bucket_comm_ps: Vec<Time>) -> FabricTelemetry {
        let elapsed_ps = fabric.now();
        let links = fabric
            .links()
            .iter()
            .map(|(&(src, dst), stat)| LinkSample {
                src,
                dst,
                bytes: stat.bytes,
                messages: stat.messages,
                gbps: fabric.link_table().spec(src, dst).bandwidth_gbps,
                // bytes·8 bits over elapsed_ps ps ⇒ Gbps = b·8000/ps.
                achieved_gbps: if elapsed_ps > 0 {
                    stat.bytes as f64 * 8000.0 / elapsed_ps as f64
                } else {
                    0.0
                },
            })
            .collect();
        FabricTelemetry {
            links,
            report: fabric.report(),
            elapsed_ps,
            bucket_comm_ps,
        }
    }

    /// Total bytes billed across every link.
    pub fn total_bytes(&self) -> u64 {
        self.links.iter().map(|l| l.bytes).sum()
    }

    /// Fastest configured bandwidth among links that carried traffic.
    pub fn max_gbps(&self) -> f64 {
        self.links.iter().map(|l| l.gbps).fold(0.0, f64::max)
    }

    /// Fraction of wire bytes that crossed slow-class links (configured
    /// bandwidth below half the fabric's fastest link) — on a hier
    /// fabric with oversubscribed uplinks this is exactly the uplink
    /// byte share. Classification uses *configured* bandwidth, so it is
    /// deterministic across jitter seeds.
    pub fn uplink_byte_fraction(&self) -> f64 {
        let total = self.total_bytes();
        if total == 0 {
            return 0.0;
        }
        let cutoff = self.max_gbps() * 0.5;
        let slow: u64 = self
            .links
            .iter()
            .filter(|l| l.gbps < cutoff)
            .map(|l| l.bytes)
            .sum();
        slow as f64 / total as f64
    }
}

impl Fabric {
    /// Telemetry snapshot of this fabric's current counters (see
    /// [`FabricTelemetry::from_fabric`]).
    pub fn telemetry(&self, bucket_comm_ps: Vec<Time>) -> FabricTelemetry {
        FabricTelemetry::from_fabric(self, bucket_comm_ps)
    }
}

/// Full fabric configuration: topology choice + link model + per-link
/// overrides + gather segmentation + seeds + straggler injection +
/// chaos plan. Serializes into the experiment record and parses from
/// CLI flags (`--topology`, `--torus-dims`, `--hier-groups`,
/// `--bandwidth-gbps`, `--latency-us`, `--jitter-us`,
/// `--inter-rack-gbps`, `--segment-bytes`, `--link-overrides`,
/// `--stragglers`, `--fabric-seed`, `--faults`, `--fault-plan`).
#[derive(Debug, Clone, PartialEq)]
pub struct FabricConfig {
    pub topology: TopologyKind,
    pub link: LinkSpec,
    /// Explicit per-directed-edge link overrides (win over
    /// topology-derived ones; see [`LinkTable`]).
    pub link_overrides: Vec<(usize, usize, LinkSpec)>,
    /// Gather pipeline segment size in bytes (0 = off). Set to the
    /// cost model's block size `m` to make the simulated ring converge
    /// to the pipelined `T_v` bound for skewed message sizes.
    pub segment_bytes: usize,
    /// Inter-group uplink bandwidth for the `hier` and `dragonfly`
    /// topologies, Gbps (`None` = a 10:1 oversubscribed default).
    pub inter_rack_gbps: Option<f64>,
    pub seed: u64,
    pub stragglers: Vec<Straggler>,
    /// Fault injection plan (crashes, link flaps, loss/corruption
    /// rates; see [`FaultPlan`]). Empty = no chaos, bit-identical to
    /// the plain path.
    pub faults: FaultPlan,
}

impl Default for FabricConfig {
    fn default() -> Self {
        FabricConfig {
            topology: TopologyKind::Ring,
            link: LinkSpec::gige(),
            link_overrides: Vec::new(),
            segment_bytes: 0,
            inter_rack_gbps: None,
            seed: 0,
            stragglers: Vec::new(),
            faults: FaultPlan::default(),
        }
    }
}

impl FabricConfig {
    /// The flag names [`FabricConfig::override_from`] consumes (for
    /// `Args::check_known` lists).
    pub const FLAGS: &'static [&'static str] = &[
        "topology",
        "torus-dims",
        "hier-groups",
        "bandwidth-gbps",
        "latency-us",
        "jitter-us",
        "inter-rack-gbps",
        "segment-bytes",
        "link-overrides",
        "stragglers",
        "fabric-seed",
        "faults",
        "fault-plan",
    ];

    /// Apply CLI flag overrides.
    pub fn override_from(mut self, args: &Args) -> anyhow::Result<FabricConfig> {
        if let Some(t) = args.get("topology") {
            self.topology = TopologyKind::parse(t)?;
        }
        if let Some(d) = args.get("torus-dims") {
            anyhow::ensure!(
                matches!(self.topology, TopologyKind::Torus { .. }),
                "--torus-dims requires --topology torus"
            );
            let (rows, cols) = topology::parse_dims(d)?;
            self.topology = TopologyKind::Torus { rows, cols };
        }
        if let Some(g) = args.get("hier-groups") {
            anyhow::ensure!(
                matches!(self.topology, TopologyKind::Hier { .. }),
                "--hier-groups requires --topology hier"
            );
            let groups: usize = g
                .parse()
                .map_err(|e| anyhow::anyhow!("hier groups '{g}': {e}"))?;
            anyhow::ensure!(groups >= 1, "--hier-groups must be >= 1");
            self.topology = TopologyKind::Hier { groups };
        }
        self.link.bandwidth_gbps = args.parse_or("bandwidth-gbps", self.link.bandwidth_gbps)?;
        self.link.latency_us = args.parse_or("latency-us", self.link.latency_us)?;
        self.link.jitter_us = args.parse_or("jitter-us", self.link.jitter_us)?;
        if let Some(g) = args.get("inter-rack-gbps") {
            anyhow::ensure!(
                matches!(
                    self.topology,
                    TopologyKind::Hier { .. } | TopologyKind::Dragonfly { .. }
                ),
                "--inter-rack-gbps only applies to --topology hier or dragonfly"
            );
            let gbps: f64 = g
                .parse()
                .map_err(|e| anyhow::anyhow!("inter-rack gbps '{g}': {e}"))?;
            anyhow::ensure!(gbps > 0.0, "--inter-rack-gbps must be positive");
            self.inter_rack_gbps = Some(gbps);
        }
        self.segment_bytes = args.parse_or("segment-bytes", self.segment_bytes)?;
        if let Some(spec) = args.get("link-overrides") {
            self.link_overrides = link::parse_link_overrides(spec, &self.link)?;
        }
        self.seed = args.parse_or("fabric-seed", self.seed)?;
        if let Some(spec) = args.get("stragglers") {
            self.stragglers = Straggler::parse_list(spec)?;
        }
        if let Some(spec) = args.get("faults") {
            anyhow::ensure!(
                args.get("fault-plan").is_none(),
                "--faults and --fault-plan are mutually exclusive"
            );
            self.faults = FaultPlan::parse(spec)?;
        }
        if let Some(path) = args.get("fault-plan") {
            let text = std::fs::read_to_string(path)
                .map_err(|e| anyhow::anyhow!("fault plan '{path}': {e}"))?;
            let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("fault plan '{path}': {e}"))?;
            self.faults = FaultPlan::from_json(&j)
                .map_err(|e| anyhow::anyhow!("fault plan '{path}': {e}"))?;
        }
        anyhow::ensure!(
            self.link.bandwidth_gbps > 0.0,
            "--bandwidth-gbps must be positive"
        );
        anyhow::ensure!(self.link.latency_us >= 0.0, "--latency-us must be >= 0");
        anyhow::ensure!(self.link.jitter_us >= 0.0, "--jitter-us must be >= 0");
        Ok(self)
    }

    /// Validate the whole config against a concrete worker count: the
    /// topology shape must host `workers`, and every knob must reach a
    /// link it names — an uplink on a hierarchy that resolves to a
    /// single group would be silently unused while `describe()` still
    /// advertised it, which is a config error, not a no-op (the same
    /// contract as out-of-range stragglers).
    pub fn validate(&self, workers: usize) -> anyhow::Result<()> {
        self.topology.validate(workers)?;
        if let Some(gbps) = self.inter_rack_gbps {
            let groups = match self.topology {
                TopologyKind::Hier { groups: 0 } | TopologyKind::Dragonfly { groups: 0 } => {
                    hierarchy::auto_groups(workers)
                }
                TopologyKind::Hier { groups } | TopologyKind::Dragonfly { groups } => groups,
                _ => anyhow::bail!(
                    "inter-rack uplink ({gbps} Gbps) only applies to the hier and \
                     dragonfly topologies, not {}",
                    self.topology.label()
                ),
            };
            anyhow::ensure!(
                groups >= 2,
                "inter-rack uplink ({gbps} Gbps) has no inter-group link to apply: \
                 {} resolves to a single group for {workers} worker{}",
                self.topology.label(),
                if workers == 1 { "" } else { "s" }
            );
        }
        let nodes = build_topology(self.topology, workers).node_count();
        self.faults.validate(nodes)?;
        Ok(())
    }

    /// One-line human description for run summaries.
    pub fn describe(&self) -> String {
        let mut out = format!(
            "{} @ {} Gbps, {} us latency",
            self.topology.label(),
            self.link.bandwidth_gbps,
            self.link.latency_us
        );
        if self.link.jitter_us > 0.0 {
            out.push_str(&format!(", jitter {} us", self.link.jitter_us));
        }
        if let Some(g) = self.inter_rack_gbps {
            out.push_str(&format!(", uplink {g} Gbps"));
        }
        if self.segment_bytes > 0 {
            out.push_str(&format!(", segment {} B", self.segment_bytes));
        }
        if !self.link_overrides.is_empty() {
            out.push_str(&format!(
                ", {} link override{}",
                self.link_overrides.len(),
                if self.link_overrides.len() == 1 { "" } else { "s" }
            ));
        }
        if !self.stragglers.is_empty() {
            out.push_str(&format!(
                ", stragglers {}",
                Straggler::list_str(&self.stragglers)
            ));
        }
        if !self.faults.is_empty() {
            out.push_str(&format!(", faults {}", self.faults.spec_str()));
        }
        out
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("topology", s(&self.topology.label())),
            ("bandwidth_gbps", num(self.link.bandwidth_gbps)),
            ("latency_us", num(self.link.latency_us)),
            ("jitter_us", num(self.link.jitter_us)),
            (
                "inter_rack_gbps",
                self.inter_rack_gbps.map(num).unwrap_or(Json::Null),
            ),
            ("segment_bytes", num(self.segment_bytes as f64)),
            (
                "link_overrides",
                s(&link::link_overrides_str(&self.link_overrides)),
            ),
            ("seed", num(self.seed as f64)),
            ("stragglers", s(&Straggler::list_str(&self.stragglers))),
            ("faults", s(&self.faults.spec_str())),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<FabricConfig> {
        let link = LinkSpec {
            bandwidth_gbps: j.expect("bandwidth_gbps")?.as_f64()?,
            latency_us: j.expect("latency_us")?.as_f64()?,
            jitter_us: j.expect("jitter_us")?.as_f64()?,
        };
        // New fields are optional so configs recorded before they
        // existed still load.
        let inter_rack_gbps = match j.get("inter_rack_gbps") {
            None | Some(Json::Null) => None,
            Some(v) => Some(v.as_f64()?),
        };
        let segment_bytes = match j.get("segment_bytes") {
            None => 0,
            Some(v) => v.as_usize()?,
        };
        let link_overrides = match j.get("link_overrides") {
            None => Vec::new(),
            Some(v) => link::parse_link_overrides(v.as_str()?, &link)?,
        };
        let faults = match j.get("faults") {
            None => FaultPlan::default(),
            Some(v) => FaultPlan::parse(v.as_str()?)?,
        };
        Ok(FabricConfig {
            topology: TopologyKind::parse(j.expect("topology")?.as_str()?)?,
            link,
            link_overrides,
            segment_bytes,
            inter_rack_gbps,
            seed: j.expect("seed")?.as_f64()? as u64,
            stragglers: Straggler::parse_list(j.expect("stragglers")?.as_str()?)?,
            faults,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct OneShot {
        delivered: Vec<(usize, usize)>,
    }

    impl Protocol for OneShot {
        fn start(&mut self) -> Vec<(usize, usize, Msg)> {
            vec![(
                0,
                1,
                Msg {
                    origin: 0,
                    seg: 0,
                    hop: 0,
                    tag: 0,
                    payload: Payload::Bytes(vec![0u8; 125]), // 1000 bits
                },
            )]
        }
        fn on_deliver(&mut self, node: usize, msg: &Msg) -> Vec<(usize, Msg)> {
            self.delivered.push((node, msg.origin));
            Vec::new()
        }
    }

    #[test]
    fn single_hop_costs_ser_plus_latency() {
        let link = LinkSpec {
            bandwidth_gbps: 1.0,
            latency_us: 1.0,
            jitter_us: 0.0,
        };
        let mut f = Fabric::new(link, 2, 0);
        let mut p = OneShot {
            delivered: Vec::new(),
        };
        let t = f.run(&mut p);
        // 1000 bits at 1 Gbps = 1 us ser; + 1 us latency = 2 us.
        assert_eq!(t, 2_000_000);
        assert_eq!(p.delivered, vec![(1, 0)]);
        assert_eq!(f.node(0).sent_bytes, 125);
        assert_eq!(f.node(1).recv_bytes, 125);
        assert_eq!(f.links()[&(0, 1)].messages, 1);
        assert_eq!(f.events(), 1);
    }

    #[test]
    fn link_override_slows_only_its_directed_edge() {
        let link = LinkSpec {
            bandwidth_gbps: 1.0,
            latency_us: 1.0,
            jitter_us: 0.0,
        };
        let slow = LinkSpec {
            bandwidth_gbps: 0.1,
            ..link
        };
        let mut f = Fabric::for_config(
            &FabricConfig {
                link,
                link_overrides: vec![(0, 1, slow)],
                ..FabricConfig::default()
            },
            2,
        );
        let mut p = OneShot {
            delivered: Vec::new(),
        };
        // 1000 bits at 0.1 Gbps = 10 us ser; + 1 us latency = 11 us.
        assert_eq!(f.run(&mut p), 11_000_000);
        // The reverse edge is untouched.
        assert_eq!(f.link_table().spec(1, 0).bandwidth_gbps, 1.0);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn link_override_beyond_fabric_panics() {
        let cfg = FabricConfig {
            link_overrides: vec![(0, 9, LinkSpec::gige())],
            ..FabricConfig::default()
        };
        Fabric::for_config(&cfg, 2);
    }

    #[test]
    fn straggler_sender_slows_delivery() {
        let link = LinkSpec {
            bandwidth_gbps: 1.0,
            latency_us: 1.0,
            jitter_us: 0.0,
        };
        let mut f = Fabric::for_config(
            &FabricConfig {
                link,
                stragglers: vec![Straggler {
                    node: 0,
                    slowdown: 3.0,
                }],
                ..FabricConfig::default()
            },
            2,
        );
        let mut p = OneShot {
            delivered: Vec::new(),
        };
        let t = f.run(&mut p);
        // rx ser is unscaled (receiver is healthy): latency dominates the
        // slow tx only through the later start of reception.
        assert!(t > 2_000_000, "straggler did not slow the hop: {t}");
    }

    #[test]
    fn fabric_config_flags_and_json_roundtrip() {
        let raw: Vec<String> = [
            "--topology",
            "tree:8",
            "--bandwidth-gbps",
            "10",
            "--latency-us",
            "5",
            "--jitter-us",
            "2",
            "--segment-bytes",
            "8192",
            "--link-overrides",
            "0-1:0.5,2-0:20:1:0",
            "--stragglers",
            "1:4",
            "--fabric-seed",
            "9",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let args = Args::parse(&raw, &[]).unwrap();
        let cfg = FabricConfig::default().override_from(&args).unwrap();
        assert_eq!(cfg.topology, TopologyKind::Tree { branch: 8 });
        assert_eq!(cfg.link.bandwidth_gbps, 10.0);
        assert_eq!(cfg.segment_bytes, 8192);
        assert_eq!(cfg.link_overrides.len(), 2);
        assert_eq!(cfg.link_overrides[0].2.bandwidth_gbps, 0.5);
        // Unspecified override fields inherit the (overridden) base.
        assert_eq!(cfg.link_overrides[0].2.latency_us, 5.0);
        assert_eq!(cfg.link_overrides[1].2.latency_us, 1.0);
        assert_eq!(cfg.stragglers.len(), 1);
        assert_eq!(cfg.seed, 9);

        let back =
            FabricConfig::from_json(&Json::parse(&cfg.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, cfg);
    }

    #[test]
    fn torus_and_hier_flags_shape_the_topology() {
        let parse = |raw: &[&str]| {
            let raw: Vec<String> = raw.iter().map(|s| s.to_string()).collect();
            let args = Args::parse(&raw, &[]).unwrap();
            FabricConfig::default().override_from(&args)
        };
        let cfg = parse(&["--topology", "torus", "--torus-dims", "4x2"]).unwrap();
        assert_eq!(cfg.topology, TopologyKind::Torus { rows: 4, cols: 2 });
        let cfg = parse(&["--topology", "hier", "--hier-groups", "3"]).unwrap();
        assert_eq!(cfg.topology, TopologyKind::Hier { groups: 3 });
        let cfg = parse(&["--topology", "hier:2", "--inter-rack-gbps", "0.25"]).unwrap();
        assert_eq!(cfg.inter_rack_gbps, Some(0.25));
        // The modifier flags demand their topology.
        assert!(parse(&["--torus-dims", "2x2"]).is_err());
        assert!(parse(&["--topology", "ring", "--hier-groups", "2"]).is_err());
        assert!(parse(&["--topology", "ring", "--inter-rack-gbps", "1"]).is_err());
        assert!(parse(&["--topology", "hier", "--inter-rack-gbps", "0"]).is_err());
    }

    #[test]
    fn validate_rejects_uplinks_that_reach_no_link() {
        let hier_uplink = |groups: usize| FabricConfig {
            topology: TopologyKind::Hier { groups },
            inter_rack_gbps: Some(0.5),
            ..FabricConfig::default()
        };
        assert!(hier_uplink(2).validate(4).is_ok());
        // Auto groups resolve to 1 for 2 workers: the uplink would be
        // silently unused while describe() still advertised it.
        let err = hier_uplink(0).validate(2).unwrap_err().to_string();
        assert!(err.contains("single group"), "{err}");
        assert!(hier_uplink(1).validate(8).is_err());
        // An uplink on a non-hier topology is just as unreachable.
        let cfg = FabricConfig {
            topology: TopologyKind::Ring,
            inter_rack_gbps: Some(0.5),
            ..FabricConfig::default()
        };
        assert!(cfg.validate(4).is_err());
        // The shape check still runs first.
        assert!(FabricConfig {
            topology: TopologyKind::Torus { rows: 2, cols: 3 },
            ..FabricConfig::default()
        }
        .validate(7)
        .is_err());
    }

    #[test]
    fn pre_fabric_json_configs_still_load() {
        // Recorded before link_overrides/segment_bytes/inter_rack
        // existed: absent keys default off.
        let old = r#"{"topology":"ring","bandwidth_gbps":1,"latency_us":50,
            "jitter_us":0,"seed":0,"stragglers":""}"#;
        let cfg = FabricConfig::from_json(&Json::parse(old).unwrap()).unwrap();
        assert_eq!(cfg, FabricConfig::default());
    }

    fn chaos_cfg(spec: &str, seed: u64) -> FabricConfig {
        FabricConfig {
            link: LinkSpec {
                bandwidth_gbps: 1.0,
                latency_us: 1.0,
                jitter_us: 0.0,
            },
            seed,
            faults: FaultPlan::parse(spec).unwrap(),
            ..FabricConfig::default()
        }
    }

    #[test]
    fn random_drops_are_retransmitted_and_masked() {
        // Retransmission must mask every loss: the protocol sees one
        // delivery no matter how many attempts the wire ate. A 0.9
        // drop rate makes at least one loss across 8 seeds all but
        // certain (P(none) = 0.1^8) without depending on one seed's
        // draw sequence.
        let mut any_dropped = false;
        for seed in 0..8 {
            let mut f = Fabric::for_config(&chaos_cfg("drop:0-1:0.9", seed), 2);
            let mut p = OneShot {
                delivered: Vec::new(),
            };
            let t = f.run(&mut p);
            assert_eq!(p.delivered, vec![(1, 0)], "seed {seed}");
            let r = f.report();
            assert_eq!(r.retries, r.drops, "every drop retried once, seed {seed}");
            assert_eq!(r.retransmitted_bytes, r.retries * 125, "seed {seed}");
            assert_eq!(r.corruptions, 0, "seed {seed}");
            if r.drops > 0 {
                any_dropped = true;
                assert!(t > 2_000_000, "retries must cost time, seed {seed}: {t}");
            } else {
                assert_eq!(t, 2_000_000, "clean run keeps exact timing, seed {seed}");
            }
        }
        assert!(any_dropped, "0.9 drop rate never fired across 8 seeds");
    }

    #[test]
    fn corruption_occupies_the_wire_then_retries() {
        let mut any_corrupted = false;
        for seed in 0..8 {
            let mut f = Fabric::for_config(&chaos_cfg("corrupt:0-1:0.9", seed), 2);
            let mut p = OneShot {
                delivered: Vec::new(),
            };
            f.run(&mut p);
            assert_eq!(p.delivered, vec![(1, 0)], "seed {seed}");
            let r = f.report();
            assert_eq!(r.retries, r.corruptions, "seed {seed}");
            assert_eq!(r.drops, 0, "seed {seed}");
            any_corrupted |= r.corruptions > 0;
        }
        assert!(any_corrupted, "0.9 corrupt rate never fired across 8 seeds");
    }

    #[test]
    fn flap_window_delays_delivery_past_the_outage() {
        // Link 0->1 is down for the first 100 us. The t = 0 attempt
        // dies; the retransmit fires at window end + one-hop backoff
        // (2 us) and delivers ser + latency later: 104 us exactly.
        let mut f = Fabric::for_config(&chaos_cfg("flap:0-1@0..100", 0), 2);
        let mut p = OneShot {
            delivered: Vec::new(),
        };
        let t = f.run(&mut p);
        assert_eq!(p.delivered, vec![(1, 0)]);
        assert_eq!(t, 104_000_000);
        let r = f.report();
        assert_eq!((r.drops, r.retries), (1, 1));
        assert_eq!(r.retransmitted_bytes, 125);
        // Both attempts were billed on the wire.
        assert_eq!(f.links()[&(0, 1)].messages, 2);
        assert_eq!(f.node(0).sent_messages, 2);
        assert_eq!(f.node(1).recv_messages, 1);
    }

    #[test]
    fn chaos_replays_are_bit_identical() {
        let run = || {
            let mut f =
                Fabric::for_config(&chaos_cfg("drop:0-1:0.5,corrupt:0-1:0.3,flap:0-1@0..3", 7), 2);
            let mut p = OneShot {
                delivered: Vec::new(),
            };
            let t = f.run(&mut p);
            (t, f.report(), f.trace().to_vec())
        };
        let (t1, r1, trace1) = run();
        let (t2, r2, trace2) = run();
        assert_eq!(t1, t2);
        assert_eq!(r1, r2);
        assert_eq!(trace1, trace2);
    }

    #[test]
    fn validate_rejects_fault_edges_outside_the_topology() {
        let cfg = FabricConfig {
            faults: FaultPlan::parse("drop:9-0:0.5").unwrap(),
            ..FabricConfig::default()
        };
        let err = cfg.validate(4).unwrap_err().to_string();
        assert!(err.contains("out of range"), "{err}");
        // A star's hub (node p) is a legal fault target even though it
        // is not a worker.
        let cfg = FabricConfig {
            topology: TopologyKind::Star,
            faults: FaultPlan::parse("drop:4-0:0.5").unwrap(),
            ..FabricConfig::default()
        };
        assert!(cfg.validate(4).is_ok());
        assert!(cfg.validate(3).is_err());
    }

    #[test]
    fn telemetry_snapshots_links_and_classifies_uplinks() {
        let link = LinkSpec {
            bandwidth_gbps: 10.0,
            latency_us: 1.0,
            jitter_us: 0.0,
        };
        let slow = LinkSpec {
            bandwidth_gbps: 1.0, // < half of 10 ⇒ uplink class
            ..link
        };
        let mut f = Fabric::for_config(
            &FabricConfig {
                link,
                link_overrides: vec![(0, 1, slow)],
                ..FabricConfig::default()
            },
            3,
        );
        // Two sends: 0->1 over the slow link, 0->2 over the fast one.
        struct TwoSends;
        impl Protocol for TwoSends {
            fn start(&mut self) -> Vec<(usize, usize, Msg)> {
                let m = |origin| Msg {
                    origin,
                    seg: 0,
                    hop: 0,
                    tag: 0,
                    payload: Payload::Bytes(vec![0u8; 100]),
                };
                vec![(0, 1, m(0)), (0, 2, m(1))]
            }
            fn on_deliver(&mut self, _node: usize, _msg: &Msg) -> Vec<(usize, Msg)> {
                Vec::new()
            }
        }
        f.run(&mut TwoSends);
        let t = f.telemetry(vec![7, 9]);
        assert_eq!(t.links.len(), 2);
        assert_eq!(t.total_bytes(), 200);
        assert_eq!(t.max_gbps(), 10.0);
        assert!((t.uplink_byte_fraction() - 0.5).abs() < 1e-12);
        assert_eq!(t.bucket_comm_ps, vec![7, 9]);
        assert_eq!(t.elapsed_ps, f.now());
        for l in &t.links {
            assert!(l.achieved_gbps > 0.0);
            assert!(l.achieved_gbps <= l.gbps + 1e-9, "{l:?}");
        }
        // Uniform fabric ⇒ no slow class at all.
        let mut u = Fabric::new(link, 2, 0);
        u.run(&mut OneShot {
            delivered: Vec::new(),
        });
        assert_eq!(u.telemetry(Vec::new()).uplink_byte_fraction(), 0.0);
    }

    #[test]
    fn describe_mentions_topology_and_degradations() {
        let cfg = FabricConfig {
            segment_bytes: 8192,
            inter_rack_gbps: Some(0.5),
            link_overrides: vec![(0, 1, LinkSpec::gige())],
            stragglers: vec![Straggler {
                node: 2,
                slowdown: 2.0,
            }],
            ..FabricConfig::default()
        };
        let d = cfg.describe();
        assert!(d.contains("ring"), "{d}");
        assert!(d.contains("2:2"), "{d}");
        assert!(d.contains("segment 8192 B"), "{d}");
        assert!(d.contains("uplink 0.5 Gbps"), "{d}");
        assert!(d.contains("1 link override"), "{d}");
    }
}
