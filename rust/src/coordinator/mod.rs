//! L3 coordinator (S14): the paper's distributed-training system.
//!
//! `Trainer` runs the synchronous data-parallel loop over simulated
//! workers; `WorkerState` holds each worker's codec + shard. See
//! DESIGN.md §1 for the full step anatomy and the substitution notes
//! (in-process workers, modeled wall-clock).

pub mod trainer;
pub mod worker;

pub use trainer::{PhaseTimes, RunEvent, Trainer};
pub use worker::WorkerState;
