//! The synchronous data-parallel training loop (S14) — the system's
//! leader.
//!
//! One step (Fig. 1 of the paper, whole-system view):
//!
//! 1. **CalcGrad** — one batched XLA call computes every worker's
//!    Algorithm-1 moment increments (L2 grad artifact; the inner
//!    reduction is the L1 Pallas kernel).
//! 2. **Encode** — each worker's codec ingests its increments, applies
//!    the variance criterion, quantizes and packs its message.
//! 3. **CommunicateAndUpdate** — messages travel a byte-accurate
//!    allgatherv over the *configured* fabric topology (`--topology`:
//!    ring by default, or star/tree/torus/hierarchy/mesh with per-link
//!    overrides and optional segment pipelining); every worker decodes
//!    all messages and sums them into the global update; the optimizer
//!    applies it locally (Sec. 4.3). The fabric's simulated step time
//!    accumulates in [`Trainer::sim_comm_ps`] for the run summary.
//!
//! With `--bucket-bytes`/`--overlap` step (3) runs through the
//! bucketed pipeline front ([`crate::comm::pipeline`]): parameters
//! fuse into buckets in reverse layer order, each worker's encoded
//! message is sliced proportionally to the dense bucket weights, and
//! bucket *k*'s gather enters the wire at its gradient-ready time so
//! communication hides behind the rest of backprop and encode. The
//! concatenated slices reproduce every message byte-for-byte, so
//! decode — and therefore training math — is bit-identical to the
//! phased path; only the simulated clock changes
//! ([`Trainer::sim_overlap_ps`] vs [`Trainer::sim_phased_ps`]).
//!
//! All workers apply identical updates from identical gathered bytes,
//! so one parameter vector represents them all; `verify_sync`
//! cross-decodes from two workers' gathered views to prove it.
//! Changing the topology never changes the gathered bytes — only the
//! simulated wall-clock and traffic shape — so training math is
//! fabric-invariant (asserted in `tests/training_integration.rs`).

use anyhow::Result;

use super::worker::WorkerState;
use crate::comm::allgatherv::{allgatherv, allgatherv_faulty, allgatherv_overlapped};
use crate::comm::pipeline;
use crate::compress::engine::EncodeStats;
use crate::compress::{
    shared_engine, Aggregation, Codec, ControllerConfig, KnobController, KnobUpdate,
    SharedEngine,
};
use crate::config::{CrashPolicy, TrainConfig};
use crate::data::shard::Shard;
use crate::data::{ImageDataset, TokenDataset};
use crate::fabric::FabricReport;
use crate::metrics::{EvalRecord, RunMetrics, StepRecord};
use crate::model::Layout;
use crate::optim::{apply_weight_decay, build as build_optimizer, Optimizer};
use crate::runtime::{Client, Dtype, EvalOutput, Manifest, ModelRuntime};

enum DataSource {
    Images {
        train: ImageDataset,
        test: ImageDataset,
    },
    Tokens {
        train: TokenDataset,
        test: TokenDataset,
    },
}

/// Wall-clock accounting per phase, for the §Perf record.
#[derive(Debug, Clone, Copy, Default)]
pub struct PhaseTimes {
    pub compute_s: f64,
    pub encode_s: f64,
    pub comm_decode_s: f64,
    pub update_s: f64,
}

/// What [`Trainer::run_with`] reports to its observer after each step
/// or evaluation. The observer returns `false` to stop the run at that
/// step boundary (cooperative cancellation).
pub enum RunEvent<'a> {
    Step {
        step: u64,
        loss: f32,
        lr: f32,
        /// Cumulative compression ratio so far (dense bits / sent bits).
        comp_ratio: f64,
        /// Simulated span of this step (compute + encode + comm;
        /// overlapped when the bucketed pipeline is on), ps.
        sim_step_ps: u64,
    },
    Eval {
        record: &'a EvalRecord,
    },
    /// A fault-plan membership change at this step: `kind` is
    /// `"crash"` or `"rejoin"`.
    Fault {
        step: u64,
        kind: &'static str,
        node: usize,
    },
    /// The step's collective ran over a reduced membership
    /// (`--on-crash renorm` with dead workers).
    Degraded {
        step: u64,
        live: usize,
        total: usize,
    },
    /// The adaptive controller (`--adaptive`) adjusted one bucket's
    /// codec knob after this step.
    Knob {
        step: u64,
        bucket: usize,
        /// Knob name ("zeta", "pi", "tau").
        name: &'static str,
        value: f32,
        /// Measured wire gain that step (dense bits / payload bits).
        gain: f64,
    },
}

pub struct Trainer<'c> {
    rt: ModelRuntime<'c>,
    layout: Layout,
    pub cfg: TrainConfig,
    pub params: Vec<f32>,
    workers: Vec<WorkerState>,
    optimizer: Box<dyn Optimizer>,
    data: DataSource,
    pub metrics: RunMetrics,
    pub phases: PhaseTimes,
    /// Accumulated fabric-simulated comm time across steps, ps — the
    /// step-communication wall-clock the configured topology predicts.
    /// With the bucketed pipeline on this counts wire-busy time only
    /// (the span comm actually occupies, excluding overlap-hidden
    /// compute); the legacy phased path is unchanged.
    pub sim_comm_ps: u64,
    /// Simulated span of the most recent step (compute + encode +
    /// comm; overlapped when the bucketed pipeline is on), ps.
    pub sim_step_ps: u64,
    /// Accumulated phased (no-overlap) step span across steps, ps —
    /// what the run would have cost serializing compute before comm.
    pub sim_phased_ps: u64,
    /// Accumulated (possibly overlapped) step span across steps, ps.
    /// Equals `sim_phased_ps` when the pipeline is off; never exceeds
    /// it when on.
    pub sim_overlap_ps: u64,
    /// Accumulated fault/recovery counters across steps (all zero on a
    /// fault-free run).
    pub fault_report: FabricReport,
    step: u64,
    /// Parallel sharded codec engine (`--codec-threads`); width 1 takes
    /// the exact legacy serial path. Behind `Arc<Mutex>` so the service
    /// daemon can share one engine across concurrent jobs — each step
    /// locks it for the whole encode→gather→decode span, and engine
    /// output is bit-identical at any width, so sharing never changes
    /// results.
    engine: SharedEngine,
    /// Per-bucket dense-byte weights from `--bucket-bytes` tensor
    /// fusion (reverse layer order; a single bucket when 0). Encoded
    /// messages are sliced proportionally to these for the overlapped
    /// gather, so bucket boundaries never touch message bytes.
    bucket_weights: Vec<u64>,
    /// Closed-loop knob controller (`--adaptive` with a tunable codec;
    /// `None` = static compression, the exact legacy path).
    controller: Option<KnobController>,
    /// Knob adjustments made after the most recent step, drained into
    /// [`RunEvent::Knob`] by [`Trainer::run_with`]:
    /// `(bucket, name, value, gain)`.
    pending_knobs: Vec<(usize, &'static str, f32, f64)>,
    /// Latest applied ranged knob per bucket — replayed onto a codec
    /// rebuilt after a renorm crash so knob state stays uniform.
    applied_knobs: Vec<KnobUpdate>,
    /// Latest applied scalar fallback knob (scalar-only codecs).
    applied_scalar: Option<f32>,
    // Reused step buffers (hot path: no per-step allocation).
    xs_f32: Vec<f32>,
    xs_i32: Vec<i32>,
    ys: Vec<i32>,
    update: Vec<f32>,
    update_check: Vec<f32>,
}

impl<'c> Trainer<'c> {
    pub fn new(client: &'c Client, manifest: &Manifest, cfg: TrainConfig) -> Result<Self> {
        let engine = shared_engine(cfg.resolved_codec_threads());
        Trainer::with_engine(client, manifest, cfg, engine)
    }

    /// Build against an existing (possibly shared) codec engine — the
    /// service daemon's path. The engine width may differ from
    /// `cfg.codec_threads`; results are identical either way.
    pub fn with_engine(
        client: &'c Client,
        manifest: &Manifest,
        cfg: TrainConfig,
        engine: SharedEngine,
    ) -> Result<Self> {
        let rt = ModelRuntime::load(client, manifest, &cfg.model)?;
        let entry = rt.entry.clone();
        let layout = Layout::from_manifest(&entry)?;
        let params = manifest.load_params(&entry)?;
        let p = entry.workers;
        // Fail before the run if the fabric config cannot host this
        // model's cluster (e.g. --torus-dims that don't factor the
        // workers, or an uplink on a single-group hierarchy).
        cfg.fabric.validate(p)?;
        // flush-rejoin can only mask a crash whose worker comes back.
        if cfg.on_crash == CrashPolicy::FlushRejoin {
            for c in &cfg.fabric.faults.crashes {
                anyhow::ensure!(
                    c.node >= p || c.rejoin_step.is_some(),
                    "--on-crash flush-rejoin requires every worker crash to rejoin \
                     (crash:{}@{} has no +delta)",
                    c.node,
                    c.at_step
                );
            }
        }

        let data = match entry.sample_dtype {
            Dtype::F32 => DataSource::Images {
                train: ImageDataset::synth_split(
                    cfg.seed,
                    0,
                    cfg.train_size,
                    &entry.sample_shape,
                    entry.n_classes,
                    cfg.signal,
                ),
                test: ImageDataset::synth_split(
                    cfg.seed,
                    1,
                    cfg.test_size,
                    &entry.sample_shape,
                    entry.n_classes,
                    cfg.signal,
                ),
            },
            Dtype::I32 => DataSource::Tokens {
                train: TokenDataset::synth_split(
                    cfg.seed,
                    0,
                    cfg.train_size,
                    entry.sample_elems(),
                    entry.n_classes,
                ),
                test: TokenDataset::synth_split(
                    cfg.seed,
                    1,
                    cfg.test_size.max(entry.eval_batch),
                    entry.sample_elems(),
                    entry.n_classes,
                ),
            },
        };
        let train_len = match &data {
            DataSource::Images { train, .. } => train.len(),
            DataSource::Tokens { train, .. } => train.len(),
        };

        let workers: Vec<WorkerState> = (0..p)
            .map(|w| {
                WorkerState::new(
                    w,
                    cfg.codec.build(&layout, cfg.seed.wrapping_add(w as u64)),
                    Shard::new(train_len, w, p, cfg.seed),
                )
            })
            .collect();

        let optimizer = build_optimizer(&cfg.optimizer, entry.n_params)?;
        let n = entry.n_params;
        let b = entry.batch;
        let elems = entry.sample_elems();
        let buckets = pipeline::form_buckets(&layout, cfg.bucket_bytes);
        let bucket_weights = pipeline::bucket_weights(&buckets);
        // `--adaptive` with a non-tunable codec (qsgd/terngrad/onebit/
        // none) degrades to the static path: there is no knob to move.
        let controller = if cfg.adaptive {
            workers[0].codec.knob().map(|knob| {
                let ranges: Vec<(usize, usize)> = buckets
                    .iter()
                    .map(|b| (b.params.start, b.params.end))
                    .collect();
                KnobController::new(
                    ControllerConfig {
                        target: cfg.adaptive_target,
                        seed: cfg.seed,
                        ..ControllerConfig::default()
                    },
                    knob,
                    ranges,
                )
            })
        } else {
            None
        };
        Ok(Trainer {
            engine,
            bucket_weights,
            controller,
            pending_knobs: Vec::new(),
            applied_knobs: Vec::new(),
            applied_scalar: None,
            rt,
            layout,
            metrics: RunMetrics::new(n, p),
            phases: PhaseTimes::default(),
            sim_comm_ps: 0,
            sim_step_ps: 0,
            sim_phased_ps: 0,
            sim_overlap_ps: 0,
            fault_report: FabricReport::default(),
            workers,
            optimizer,
            data,
            params,
            cfg,
            step: 0,
            xs_f32: vec![0.0; p * b * elems],
            xs_i32: vec![0; p * b * elems],
            ys: vec![0; p * b],
            update: vec![0.0; n],
            update_check: Vec::new(),
        })
    }

    pub fn layout(&self) -> &Layout {
        &self.layout
    }

    pub fn n_params(&self) -> usize {
        self.rt.n_params()
    }

    pub fn workers(&self) -> usize {
        self.rt.workers()
    }

    pub fn step_count(&self) -> u64 {
        self.step
    }

    /// Total undelivered residual mass across workers (diagnostics).
    pub fn residual_l1(&self) -> f64 {
        self.workers.iter().map(|w| w.codec.residual_l1()).sum()
    }

    fn fill_batches(&mut self, dead_workers: &[usize]) {
        let e = &self.rt.entry;
        let b = e.batch;
        let elems = e.sample_elems();
        for w in 0..e.workers {
            if dead_workers.contains(&w) {
                // A dead worker's shard cursor freezes: it resumes from
                // where it left off when it rejoins.
                continue;
            }
            let idxs = self.workers[w].shard.next_batch(b);
            match &self.data {
                DataSource::Images { train, .. } => {
                    for (k, &i) in idxs.iter().enumerate() {
                        let dst = (w * b + k) * elems;
                        self.xs_f32[dst..dst + elems].copy_from_slice(train.sample(i));
                        self.ys[w * b + k] = train.labels[i];
                    }
                }
                DataSource::Tokens { train, .. } => {
                    for (k, &i) in idxs.iter().enumerate() {
                        let dst = (w * b + k) * elems;
                        self.xs_i32[dst..dst + elems].copy_from_slice(train.sequence(i));
                        self.ys[w * b + k] = 0; // unused by LMs
                    }
                }
            }
        }
    }

    /// Workers the fault plan takes out of this step's membership
    /// epoch under the active crash policy, plus any dead
    /// infrastructure node (a star hub). Under `flush-rejoin` worker
    /// crashes are masked (the rejoining peer replays the work), so
    /// only infrastructure deaths reach the collective.
    fn membership(&self, step: u64) -> (Vec<usize>, Vec<usize>) {
        let p = self.workers.len();
        let dead_all = self.cfg.fabric.faults.dead_at_step(step);
        let dead_gather: Vec<usize> = match self.cfg.on_crash {
            CrashPolicy::Renorm => dead_all,
            CrashPolicy::FlushRejoin => dead_all.into_iter().filter(|&d| d >= p).collect(),
        };
        let dead_workers: Vec<usize> =
            dead_gather.iter().copied().filter(|&d| d < p).collect();
        (dead_gather, dead_workers)
    }

    /// Run one full synchronous step; returns the step's mean loss.
    pub fn train_step(&mut self) -> Result<f32> {
        let e = self.rt.entry.clone();
        let (dead_gather, dead_workers) = self.membership(self.step);
        // A worker that dies under renorm loses its codec state: the
        // residual is discarded, not flushed (docs/FAULTS.md).
        if self.cfg.on_crash == CrashPolicy::Renorm {
            for i in 0..self.cfg.fabric.faults.crashes.len() {
                let c = self.cfg.fabric.faults.crashes[i].clone();
                if c.at_step == self.step && c.node < e.workers {
                    self.workers[c.node].codec = self
                        .cfg
                        .codec
                        .build(&self.layout, self.cfg.seed.wrapping_add(c.node as u64));
                    // A rebuilt codec restarts at the static knob; replay
                    // the controller's applied adjustments so knob state
                    // stays uniform across the cluster (strom decode reads
                    // τ from the codec, not the wire).
                    if self.controller.is_some() {
                        let codec = &mut *self.workers[c.node].codec;
                        if let Some(v) = self.applied_scalar {
                            codec.set_knob(v);
                        } else {
                            for up in &self.applied_knobs {
                                codec.set_knob_range(up.lo, up.hi, up.value);
                            }
                        }
                    }
                }
            }
        }
        // A rejoining worker pulls the replicated state (params +, under
        // flush-rejoin, the flushed residual) from a peer: bill one
        // state transfer on the base link per rejoin.
        let rejoins = self
            .cfg
            .fabric
            .faults
            .rejoining_at_step(self.step)
            .iter()
            .filter(|&&n| n < e.workers)
            .count() as u64;
        if rejoins > 0 {
            let state_bytes = e.n_params as u64 * 4;
            let transfer =
                self.cfg.fabric.link.ser_ps(state_bytes) + self.cfg.fabric.link.latency_ps();
            self.sim_comm_ps += transfer * rejoins;
        }
        self.fill_batches(&dead_workers);

        // (1) CalcGrad: batched multi-worker moments via PJRT.
        let t0 = std::time::Instant::now();
        let moments = match e.sample_dtype {
            Dtype::F32 => self.rt.step(&self.params, Some(&self.xs_f32), None, &self.ys)?,
            Dtype::I32 => self.rt.step(&self.params, None, Some(&self.xs_i32), &self.ys)?,
        };
        let grad_s = t0.elapsed().as_secs_f64();
        self.phases.compute_s += grad_s;

        // (2) Encode per worker — fanned out across workers (and
        // group-aligned shards) when `--codec-threads` > 1; the engine
        // produces bytes bit-identical to the serial path. The lock
        // spans encode→gather→decode so a shared engine's buffers stay
        // consistent for the whole step even with concurrent jobs.
        let t1 = std::time::Instant::now();
        let mut engine = self.engine.lock().unwrap_or_else(|e| e.into_inner());
        // Degraded steps take the serial path: the sharded engine
        // assumes full membership, and serial/parallel encodes are
        // bit-identical so mixing them across steps changes nothing.
        let parallel = engine.threads() > 1 && dead_workers.is_empty();
        let mut elements = 0u64;
        let mut payload_bits = 0u64;
        let mut wire_bytes = 0u64;
        let mut msgs: Vec<Vec<u8>> = Vec::new();
        if parallel {
            let mut codecs: Vec<&mut dyn Codec> = self
                .workers
                .iter_mut()
                .map(|w| &mut *w.codec)
                .collect();
            let gsums: Vec<&[f32]> = (0..e.workers).map(|w| moments.gsum_of(w)).collect();
            let gsumsqs: Vec<&[f32]> =
                (0..e.workers).map(|w| moments.gsumsq_of(w)).collect();
            engine.encode_all(&mut codecs, &gsums, &gsumsqs);
            for st in engine.stats() {
                elements += st.elements;
                payload_bits += st.payload_bits;
            }
            for m in engine.messages() {
                wire_bytes += m.len() as u64;
            }
        } else {
            msgs.reserve(e.workers);
            for w in 0..e.workers {
                if dead_workers.contains(&w) {
                    // Dead workers contribute nothing this epoch; the
                    // gather carries an empty slot for them.
                    msgs.push(Vec::new());
                    continue;
                }
                let msg = self.workers[w]
                    .codec
                    .encode_step(moments.gsum_of(w), moments.gsumsq_of(w));
                elements += msg.elements;
                payload_bits += msg.payload_bits;
                wire_bytes += msg.bytes.len() as u64;
                msgs.push(msg.bytes);
            }
        }
        let encode_s = t1.elapsed().as_secs_f64();
        self.phases.encode_s += encode_s;

        // (3) Communicate: byte-accurate allgatherv over the configured
        // fabric topology, then decode. With `--bucket-bytes` or
        // `--overlap` the gather runs through the bucketed pipeline
        // front: the same message bytes travel, sliced into fused
        // buckets that enter the wire at their gradient-ready times
        // (measured compute/encode wall-clock mapped onto the fabric's
        // event clock), so decode input stays bit-identical while the
        // simulated clock hides comm behind compute. Degraded steps
        // fall back to the phased faulty gather, whose empty-slot
        // semantics the pipeline front doesn't model.
        let t2 = std::time::Instant::now();
        let pipelined =
            (self.cfg.bucket_bytes > 0 || self.cfg.overlap) && dead_gather.is_empty();
        let grad_ps = (grad_s * 1e12) as u64;
        let encode_ps = (encode_s * 1e12) as u64;
        // Per-step feedback for the adaptive controller: (per-bucket
        // comm time, uplink byte fraction). Left `None` on static runs
        // and on degraded steps (whose phased gather has no per-bucket
        // clock and whose membership skews the pressure signal).
        let adaptive = self.controller.is_some();
        let mut step_link: Option<(Vec<u64>, f64)> = None;
        let gathered: Vec<Vec<Vec<u8>>> = if pipelined {
            let inputs: &[Vec<u8>] = if parallel { engine.messages() } else { &msgs };
            let ov = allgatherv_overlapped(
                &self.cfg.fabric,
                inputs,
                &self.bucket_weights,
                grad_ps,
                encode_ps,
            );
            self.sim_comm_ps += ov.schedule.comm_busy_ps;
            self.sim_step_ps = ov.schedule.overlapped_ps;
            self.sim_phased_ps += ov.schedule.phased_ps;
            self.sim_overlap_ps += ov.schedule.overlapped_ps;
            self.fault_report.absorb(&ov.report);
            if adaptive {
                step_link = Some((
                    ov.telemetry.bucket_comm_ps.clone(),
                    ov.telemetry.uplink_byte_fraction(),
                ));
            }
            ov.gathered
        } else {
            let res = if parallel {
                allgatherv_faulty(&self.cfg.fabric, engine.messages(), &dead_gather)
            } else {
                allgatherv_faulty(&self.cfg.fabric, &msgs, &dead_gather)
            };
            self.sim_comm_ps += res.time_ps;
            self.sim_step_ps = grad_ps + encode_ps + res.time_ps;
            self.sim_phased_ps += self.sim_step_ps;
            self.sim_overlap_ps += self.sim_step_ps;
            self.fault_report.absorb(&res.report);
            if adaptive && dead_gather.is_empty() {
                step_link =
                    Some((vec![res.time_ps], res.telemetry.uplink_byte_fraction()));
            }
            res.gathered
        };
        let live = e.workers - dead_workers.len();
        anyhow::ensure!(live > 0, "no surviving workers at step {}", self.step);
        // The decoding representative must be a survivor (worker 0 on
        // fault-free steps — the exact legacy path).
        let decoder = (0..e.workers)
            .find(|w| !dead_workers.contains(w))
            .expect("live > 0 guarantees a survivor");
        if parallel {
            // Parallel decode: parse each gathered message once, then
            // reduce disjoint index ranges in message order — bit-equal
            // to the serial loop below (verify_sync cross-checks it
            // against a serial decode every step when enabled).
            engine.decode_all(&*self.workers[0].codec, &gathered[0], &mut self.update)?;
        } else {
            self.update.iter_mut().for_each(|u| *u = 0.0);
            for src_msg in &gathered[decoder] {
                if src_msg.is_empty() {
                    continue; // a dead worker's slot
                }
                self.workers[decoder]
                    .codec
                    .decode_into(src_msg, &mut self.update)?;
            }
        }
        if self.workers[decoder].codec.aggregation() == Aggregation::Mean {
            let inv = 1.0 / live as f32;
            self.update.iter_mut().for_each(|u| *u *= inv);
        }
        if self.cfg.verify_sync && live > 1 {
            // A different surviving worker decodes its own gathered
            // view; the updates must be bit-identical (synchrony
            // invariant over the live membership).
            self.update_check.clear();
            self.update_check.resize(e.n_params, 0.0);
            let last = (0..e.workers)
                .rev()
                .find(|w| !dead_workers.contains(w))
                .expect("live > 1 guarantees a second survivor");
            for src_msg in &gathered[last] {
                if src_msg.is_empty() {
                    continue;
                }
                self.workers[last]
                    .codec
                    .decode_into(src_msg, &mut self.update_check)?;
            }
            if self.workers[last].codec.aggregation() == Aggregation::Mean {
                let inv = 1.0 / live as f32;
                self.update_check.iter_mut().for_each(|u| *u *= inv);
            }
            anyhow::ensure!(
                self.update == self.update_check,
                "worker desync at step {}",
                self.step
            );
        }
        self.phases.comm_decode_s += t2.elapsed().as_secs_f64();
        drop(engine); // release the shared engine before the local math

        // Closed-loop knob adjustment (`--adaptive`): feed the step's
        // telemetry to the controller and push any knob moves onto every
        // worker's codec so the cluster keeps one compression policy.
        self.pending_knobs.clear();
        if let (Some(ctl), Some((bucket_comm, uplink_frac))) =
            (self.controller.as_mut(), step_link)
        {
            let comm = align_bucket_comm(&bucket_comm, &self.bucket_weights);
            let stats = EncodeStats {
                elements,
                payload_bits,
            };
            let gain = stats.gain(e.n_params * live);
            let updates = ctl.observe(&comm, grad_ps + encode_ps, uplink_frac, gain);
            if !updates.is_empty() {
                let mut ranged = true;
                'apply: for up in &updates {
                    for w in &mut self.workers {
                        if !w.codec.set_knob_range(up.lo, up.hi, up.value) {
                            // A scalar-only codec rejects before mutating,
                            // and every worker runs the same codec type, so
                            // nothing was applied yet.
                            ranged = false;
                            break 'apply;
                        }
                    }
                }
                if ranged {
                    for up in &updates {
                        match self
                            .applied_knobs
                            .iter_mut()
                            .find(|a| a.bucket == up.bucket)
                        {
                            Some(a) => *a = *up,
                            None => self.applied_knobs.push(*up),
                        }
                    }
                } else {
                    // Scalar-only codec (strom/hybrid): collapse the
                    // per-bucket targets to a comm-share-weighted mean.
                    let v = ctl.scalar_value(&comm);
                    for w in &mut self.workers {
                        w.codec.set_knob(v);
                    }
                    self.applied_scalar = Some(v);
                }
                for up in &updates {
                    self.pending_knobs.push((up.bucket, up.name, up.value, gain));
                }
            }
        }

        // (4) Update locally (identical on all workers).
        let t3 = std::time::Instant::now();
        let lr = self.cfg.schedule.at(self.step);
        self.optimizer.step(&mut self.params, &self.update, lr);
        apply_weight_decay(&mut self.params, lr, self.cfg.weight_decay);
        self.phases.update_s += t3.elapsed().as_secs_f64();

        let loss = moments.mean_loss();
        self.metrics.record_step(StepRecord {
            step: self.step,
            loss,
            lr,
            elements_sent: elements,
            payload_bits,
            wire_bytes,
        });
        self.step += 1;
        Ok(loss)
    }

    /// Evaluate on the held-out set; records and returns the record.
    pub fn evaluate(&mut self) -> Result<EvalRecord> {
        let e = self.rt.entry.clone();
        let rec = match &self.data {
            DataSource::Images { test, .. } => {
                let be = e.eval_batch;
                let elems = e.sample_elems();
                let mut correct = 0usize;
                let mut total = 0usize;
                let mut x = vec![0.0f32; be * elems];
                let mut labels = vec![0i32; be];
                let n_batches = test.len() / be;
                for bi in 0..n_batches.max(1) {
                    let count = be.min(test.len() - bi * be);
                    if count == 0 {
                        break;
                    }
                    for k in 0..be {
                        let i = (bi * be + k).min(test.len() - 1);
                        x[k * elems..(k + 1) * elems].copy_from_slice(test.sample(i));
                        labels[k] = test.labels[i];
                    }
                    match self.rt.eval(&self.params, Some(&x), None)? {
                        EvalOutput::Logits(logits) => {
                            for k in 0..count {
                                let row = &logits[k * e.n_classes..(k + 1) * e.n_classes];
                                let mut best = 0;
                                for (c, &v) in row.iter().enumerate() {
                                    if v > row[best] {
                                        best = c;
                                    }
                                }
                                if best as i32 == labels[k] {
                                    correct += 1;
                                }
                            }
                            total += count;
                        }
                        other => anyhow::bail!("expected logits, got {other:?}"),
                    }
                }
                EvalRecord {
                    step: self.step,
                    accuracy: correct as f32 / total.max(1) as f32,
                    eval_loss: f32::NAN,
                }
            }
            DataSource::Tokens { test, .. } => {
                let be = e.eval_batch;
                let elems = e.sample_elems();
                let mut x = vec![0i32; be * elems];
                for k in 0..be {
                    let i = k.min(test.len() - 1);
                    x[k * elems..(k + 1) * elems].copy_from_slice(test.sequence(i));
                }
                match self.rt.eval(&self.params, None, Some(&x))? {
                    EvalOutput::Loss(l) => EvalRecord {
                        step: self.step,
                        accuracy: f32::NAN,
                        eval_loss: l,
                    },
                    other => anyhow::bail!("expected loss, got {other:?}"),
                }
            }
        };
        self.metrics.record_eval(rec.clone());
        Ok(rec)
    }

    /// Run the configured number of steps with periodic eval + logging.
    pub fn run(&mut self, quiet: bool) -> Result<()> {
        self.run_with(quiet, &mut |_| true).map(|_| ())
    }

    /// [`Trainer::run`] with an observer: called after every step and
    /// evaluation; returning `false` stops the run at that step
    /// boundary. Returns `Ok(true)` if the run completed, `Ok(false)`
    /// if the observer stopped it. The service daemon uses this to
    /// publish live progress and honor cancellation.
    pub fn run_with(
        &mut self,
        quiet: bool,
        observe: &mut dyn FnMut(RunEvent<'_>) -> bool,
    ) -> Result<bool> {
        let steps = self.cfg.steps;
        for _ in 0..steps {
            let loss = self.train_step()?;
            let s = self.step;
            let lr = self.cfg.schedule.at(s - 1);
            // Surface this step's knob moves (`--adaptive`) before the
            // Step event so observers see cause before effect.
            let knobs = std::mem::take(&mut self.pending_knobs);
            for (bucket, name, value, gain) in knobs {
                if !observe(RunEvent::Knob {
                    step: s - 1,
                    bucket,
                    name,
                    value,
                    gain,
                }) {
                    return Ok(false);
                }
            }
            // Surface the fault plan's membership events for the step
            // just executed (step index s − 1).
            if !self.cfg.fabric.faults.is_empty() {
                let fstep = s - 1;
                let crashes = self.cfg.fabric.faults.crashes.clone();
                for c in &crashes {
                    if c.at_step == fstep
                        && !observe(RunEvent::Fault {
                            step: fstep,
                            kind: "crash",
                            node: c.node,
                        })
                    {
                        return Ok(false);
                    }
                    if c.rejoin_step == Some(fstep)
                        && !observe(RunEvent::Fault {
                            step: fstep,
                            kind: "rejoin",
                            node: c.node,
                        })
                    {
                        return Ok(false);
                    }
                }
                let (_, dead_workers) = self.membership(fstep);
                if !dead_workers.is_empty()
                    && !observe(RunEvent::Degraded {
                        step: fstep,
                        live: self.workers.len() - dead_workers.len(),
                        total: self.workers.len(),
                    })
                {
                    return Ok(false);
                }
            }
            if !quiet && self.cfg.log_every > 0 && s % self.cfg.log_every == 0 {
                println!(
                    "step {s:>5}  loss {loss:>8.4}  lr {:>8.5}  ratio {:>10.1}  residual_l1 {:.3e}",
                    lr,
                    self.metrics.compression_ratio(),
                    self.residual_l1(),
                );
            }
            if self.cfg.eval_every > 0 && s % self.cfg.eval_every == 0 {
                let rec = self.evaluate()?;
                if !quiet {
                    if rec.accuracy.is_nan() {
                        println!("eval  step {s:>5}  loss {:.4}", rec.eval_loss);
                    } else {
                        println!("eval  step {s:>5}  accuracy {:.4}", rec.accuracy);
                    }
                }
                if !observe(RunEvent::Eval { record: &rec }) {
                    return Ok(false);
                }
            }
            if !observe(RunEvent::Step {
                step: s,
                loss,
                lr,
                comp_ratio: self.metrics.compression_ratio(),
                sim_step_ps: self.sim_step_ps,
            }) {
                return Ok(false);
            }
        }
        // Final eval if the loop didn't land on an eval step.
        if self.cfg.eval_every > 0 && self.step % self.cfg.eval_every != 0 {
            let rec = self.evaluate()?;
            let _ = observe(RunEvent::Eval { record: &rec });
        }
        Ok(true)
    }
}

/// Map the overlap schedule's per-bucket comm times onto the static
/// `form_buckets` layout the controller indexes. The scheduler may
/// merge adjacent buckets on a given step (message-length floor), so
/// when the counts differ the total comm time is redistributed across
/// the static buckets proportionally to their dense-byte weights.
fn align_bucket_comm(comm: &[u64], weights: &[u64]) -> Vec<u64> {
    if comm.len() == weights.len() {
        return comm.to_vec();
    }
    let total: u128 = comm.iter().map(|&c| c as u128).sum();
    let wsum: u128 = weights.iter().map(|&w| w as u128).sum::<u128>().max(1);
    weights
        .iter()
        .map(|&w| (total * w as u128 / wsum) as u64)
        .collect()
}
