//! Per-worker state: compression codec + data shard.
//!
//! In a real deployment each worker process owns this state; in the
//! in-process simulator the leader holds one `WorkerState` per logical
//! worker. The gradient *computation* for all workers happens in a
//! single batched XLA call (see `model.py`), so a worker here is purely
//! its codec state and its view of the data.

use crate::compress::Codec;
use crate::data::shard::Shard;

pub struct WorkerState {
    pub id: usize,
    pub codec: Box<dyn Codec>,
    pub shard: Shard,
}

impl WorkerState {
    pub fn new(id: usize, codec: Box<dyn Codec>, shard: Shard) -> WorkerState {
        WorkerState { id, codec, shard }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::CodecSpec;
    use crate::model::Layout;

    #[test]
    fn workers_get_independent_codec_state() {
        let layout = Layout::uniform(8, 4);
        let spec = CodecSpec::Vgc {
            alpha: 1.0,
            zeta: 0.999,
        };
        let mut w0 = WorkerState::new(
            0,
            spec.build(&layout, 0),
            Shard::new(64, 0, 2, 0),
        );
        let w1 = WorkerState::new(1, spec.build(&layout, 1), Shard::new(64, 1, 2, 0));
        // Feeding w0 must not affect w1's residual.
        w0.codec.encode_step(&[0.1; 8], &[10.0; 8]);
        assert!(w0.codec.residual_l1() > 0.0);
        assert_eq!(w1.codec.residual_l1(), 0.0);
    }
}
