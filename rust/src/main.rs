//! `repro` — the launcher CLI for the VGC reproduction.
//!
//! Subcommands map 1:1 onto DESIGN.md's experiment index:
//!
//! ```text
//! repro train --model vgg_tiny --codec vgc:alpha=1.5 [--steps N ...]
//! repro table1 [--optimizers adam,momentum] [--steps N] [--out results.json]
//! repro table2 [...]
//! repro fig3   [--out fig3.csv]          # scatter data from both tables
//! repro costmodel                         # Section-5 (A5) analysis
//! repro inspect                           # artifact manifest summary
//! ```

use anyhow::Result;

use vgc::config::TrainConfig;
use vgc::coordinator::Trainer;
use vgc::experiments;
use vgc::runtime::{Client, Manifest};
use vgc::util::cli::Args;

const USAGE: &str = "\
repro — Variance-based Gradient Compression (ICLR'18) reproduction

USAGE:
  repro train     --model <name> [--codec SPEC] [--optimizer sgd|momentum|adam]
                  [--lr SCHED] [--steps N] [--seed S] [--weight-decay W]
                  [--train-size N] [--test-size N] [--signal F]
                  [--eval-every K] [--log-every K] [--verify-sync]
                  [--loss-curve FILE.csv] [--artifacts DIR]
  repro table1    [--optimizers adam,momentum] [--steps N] [--out FILE.json]
  repro table2    [--optimizers adam,momentum] [--steps N] [--out FILE.json]
  repro fig3      [--steps N] [--out FILE.csv]
  repro costmodel
  repro inspect   [--artifacts DIR]

Codec SPECs: none | vgc:alpha=A[,zeta=Z] | strom:tau=T |
             hybrid:tau=T,alpha=A | qsgd:bits=B,d=D | terngrad
LR SCHEDs:   const:LR | step:LR,FACTOR,EVERY | warmup:LR,STEPS
";

const TRAIN_FLAGS: &[&str] = &[
    "model", "codec", "optimizer", "lr", "steps", "seed", "weight-decay",
    "train-size", "test-size", "signal", "eval-every", "log-every",
    "verify-sync", "loss-curve", "artifacts",
];

fn artifacts_dir(args: &Args) -> String {
    args.str_or("artifacts", "artifacts")
}

fn main() -> Result<()> {
    let args = Args::from_env(&["verify-sync", "quiet"])?;
    let cmd = args.positional().first().map(|s| s.as_str()).unwrap_or("");
    match cmd {
        "train" => cmd_train(&args),
        "table1" => cmd_table(&args, "table1"),
        "table2" => cmd_table(&args, "table2"),
        "fig3" => cmd_fig3(&args),
        "costmodel" => {
            print!("{}", experiments::costmodel_report());
            Ok(())
        }
        "inspect" => cmd_inspect(&args),
        "" | "help" | "--help" => {
            print!("{USAGE}");
            Ok(())
        }
        other => {
            eprint!("unknown command '{other}'\n\n{USAGE}");
            std::process::exit(2);
        }
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    args.check_known(TRAIN_FLAGS)?;
    let model = args.require("model")?;
    let cfg = TrainConfig::defaults(model).override_from(args)?;
    let manifest = Manifest::load(artifacts_dir(args))?;
    let client = Client::cpu()?;
    println!(
        "model={model} codec={} optimizer={} steps={} (platform: {})",
        cfg.codec.label(),
        cfg.optimizer,
        cfg.steps,
        client.platform()
    );
    let mut trainer = Trainer::new(&client, &manifest, cfg)?;
    let t0 = std::time::Instant::now();
    trainer.run(false)?;
    let wall = t0.elapsed().as_secs_f64();

    let m = &trainer.metrics;
    println!("\n--- run summary ---");
    println!("final loss         {:.4}", m.final_loss());
    if !m.final_accuracy().is_nan() {
        println!("final accuracy     {:.2}%", m.final_accuracy() * 100.0);
    }
    println!("compression ratio  {:.1}", m.compression_ratio());
    println!("bits ratio         {:.1}", m.bits_ratio());
    println!("residual L1        {:.3e}", trainer.residual_l1());
    let ph = trainer.phases;
    println!(
        "wall {wall:.1}s  (compute {:.1}s, encode {:.1}s, comm+decode {:.1}s, update {:.1}s)",
        ph.compute_s, ph.encode_s, ph.comm_decode_s, ph.update_s
    );
    if let Some(path) = args.get("loss-curve") {
        std::fs::write(path, m.loss_curve_csv())?;
        println!("loss curve written to {path}");
    }
    Ok(())
}

fn parse_optimizers(args: &Args) -> Vec<String> {
    let list = args.list("optimizers");
    if list.is_empty() {
        vec!["adam".into(), "momentum".into()]
    } else {
        list
    }
}

fn cmd_table(args: &Args, which: &str) -> Result<()> {
    args.check_known(&["optimizers", "steps", "out", "artifacts", "quiet"])?;
    let steps = args.parse_or("steps", 300u64)?;
    let manifest = Manifest::load(artifacts_dir(args))?;
    let client = Client::cpu()?;
    let mut all = Vec::new();
    for opt in parse_optimizers(args) {
        let rows = match which {
            "table1" => experiments::table1_rows(&opt, steps),
            _ => experiments::table2_rows(&opt, steps),
        };
        let results = experiments::run_grid(&client, &manifest, &rows, args.has("quiet"))?;
        experiments::print_table(
            &format!(
                "{} ({}, {} steps) — paper Table {}",
                if which == "table1" {
                    "CIFAR-10-like / vgg_tiny"
                } else {
                    "ImageNet-like / resnet_mini"
                },
                opt,
                steps,
                if which == "table1" { 1 } else { 2 }
            ),
            &results,
        );
        all.extend(results);
    }
    if let Some(path) = args.get("out") {
        std::fs::write(path, experiments::results_json(which, &all).to_string())?;
        println!("\nresults written to {path}");
    }
    Ok(())
}

fn cmd_fig3(args: &Args) -> Result<()> {
    args.check_known(&["steps", "out", "artifacts", "quiet", "from"])?;
    // Preferred path: derive the scatter from saved table results
    // (`--from table1_results.json,table2_results.json`) instead of
    // re-running both grids.
    if args.has("from") {
        let mut csv = String::from("method,optimizer,accuracy,compression,bits_ratio\n");
        let mut count = 0usize;
        for path in args.list("from") {
            let text = std::fs::read_to_string(&path)?;
            let rows = vgc::util::json::Json::parse(&text)
                .map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
            for r in rows.as_arr()? {
                csv.push_str(&format!(
                    "{}:{},{},{},{},{}\n",
                    r.expect("table")?.as_str()?,
                    r.expect("method")?.as_str()?,
                    r.expect("optimizer")?.as_str()?,
                    r.expect("accuracy")?.as_f64()?,
                    r.expect("compression")?.as_f64()?,
                    r.expect("bits_ratio")?.as_f64()?,
                ));
                count += 1;
            }
        }
        let path = args.str_or("out", "fig3.csv");
        std::fs::write(&path, &csv)?;
        println!("figure-3 scatter data ({count} points) written to {path}");
        return Ok(());
    }
    let steps = args.parse_or("steps", 300u64)?;
    let manifest = Manifest::load(artifacts_dir(args))?;
    let client = Client::cpu()?;
    let mut all = Vec::new();
    for (table, builder) in [
        (
            "table1",
            experiments::table1_rows as fn(&str, u64) -> Vec<experiments::GridRow>,
        ),
        ("table2", experiments::table2_rows),
    ] {
        for opt in ["adam", "momentum"] {
            let rows = builder(opt, steps);
            let mut results =
                experiments::run_grid(&client, &manifest, &rows, args.has("quiet"))?;
            for r in &mut results {
                r.label = format!("{table}:{}", r.label);
            }
            all.extend(results);
        }
    }
    let csv = experiments::fig3_csv(&all);
    let path = args.str_or("out", "fig3.csv");
    std::fs::write(&path, &csv)?;
    println!(
        "figure-3 scatter data ({} points) written to {path}",
        all.len()
    );
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    args.check_known(&["artifacts"])?;
    let manifest = Manifest::load(artifacts_dir(args))?;
    println!("artifact manifest (fingerprint {})", manifest.fingerprint);
    for m in &manifest.models {
        println!(
            "  {:<14} N={:<9} P={:<3} B={:<3} eval_batch={:<4} groups={:<4} kind={}",
            m.name,
            m.n_params,
            m.workers,
            m.batch,
            m.eval_batch,
            m.groups.len(),
            m.kind
        );
    }
    for e in &manifest.moments_bench {
        println!("  [bench] moments b={} n={} ({})", e.b, e.n, e.hlo);
    }
    for e in &manifest.criterion {
        println!("  [bench] criterion n={} ({})", e.n, e.hlo);
    }
    Ok(())
}
